"""Metadata-scan RPC trajectory: readdir-plus + attr cache + statahead +
batched glimpse (ISSUE-5).

Workload: a builder client populates a 1024-entry striped directory; a
COLD second client then runs an `ls -l`-shaped scan (readdir + full
attrs for every entry). Modes:

  * per_entry    — dir_pages=0, statahead off: one lookup RPC per name
    (the seed shape; PR 4's data-path wins don't help metadata);
  * statahead    — dir_pages=0, statahead on: sequential stats collapse
    into batched getattr_bulk windows;
  * readdir_plus — directory pages carry attrs + LOV EAs under the
    dir's PR lock: O(N/page) RPCs;
  * warm re-stat — the same client stats every entry again: everything
    is served from the DLM-covered dentry + attr caches, ZERO RPCs.

A second scenario scans a directory of files OPEN FOR WRITE (size/mtime
live on the OSTs, §6.9.1): per-file glimpses vs ONE vectored
glimpse_bulk per OST covering every file's stripe objects.

`md_scan_metrics()` feeds the `md_scan` section of BENCH_rpc.json; the
gate in benchmarks/run.py enforces: readdir-plus >= 16x cheaper than
per-entry (the ISSUE-5 acceptance bar), warm re-stat at ZERO RPCs, and
no regression vs the committed page-mode RPC count.
"""
from __future__ import annotations

from benchmarks.common import save, table
from repro.core import LustreCluster
from repro.fsio import LustreClient

N_ENTRIES = 1024
N_OPEN = 64
STRIPES = 2
DIR_PAGES = 64


def md_rpcs(c):
    """Metadata + glimpse RPCs: everything MDS-bound plus the OST
    attr/glimpse traffic a stat can cost."""
    cnt = c.stats.counters
    return (sum(n for k, n in cnt.items() if k.startswith("rpc.mds."))
            + cnt.get("rpc.ost.glimpse_bulk", 0)
            + cnt.get("rpc.ost.getattr", 0))


def all_rpcs(c):
    return sum(n for k, n in c.stats.counters.items()
               if k.startswith("rpc."))


def build(c, n, *, keep_open=0):
    fs = LustreClient(c, 0).mount()
    fs.mkdir("/scan")
    handles = []
    for i in range(n):
        fh = fs.creat(f"/scan/f{i:04d}", stripe_count=STRIPES)
        fs.write(fh, b"m" * (1024 * (1 + i % 3)))
        if i < keep_open:
            handles.append(fh)                 # size/mtime stay on OSTs
        else:
            fs.close(fh)
    return fs, handles


def md_scan_metrics() -> dict:
    out = {}
    for mode, kw in (("per_entry", {"dir_pages": 0, "statahead_max": 0}),
                     ("statahead", {"dir_pages": 0, "statahead_max": 32}),
                     ("readdir_plus", {"dir_pages": DIR_PAGES})):
        c = LustreCluster(osts=4, mdses=1, clients=2,
                          commit_interval=2048, **kw)
        build(c, N_ENTRIES)
        fs = LustreClient(c, 1).mount()        # cold scanner
        base, t0 = md_rpcs(c), c.now
        listing = fs.ls_l("/scan")
        assert len(listing) == N_ENTRIES
        out[mode] = {"cold_scan_rpcs": md_rpcs(c) - base,
                     "scan_vtime_s": round(c.now - t0, 6),
                     "entries": N_ENTRIES}
        if mode == "readdir_plus":
            base_all = all_rpcs(c)
            for name in listing:
                fs.stat("/scan/" + name)
            out["warm_restat_rpcs"] = all_rpcs(c) - base_all
    out["rpc_reduction"] = round(
        out["per_entry"]["cold_scan_rpcs"]
        / max(1, out["readdir_plus"]["cold_scan_rpcs"]), 2)
    out["statahead_reduction"] = round(
        out["per_entry"]["cold_scan_rpcs"]
        / max(1, out["statahead"]["cold_scan_rpcs"]), 2)

    # ---- batched glimpse: scanning files under write
    glimpse = {}
    for gmode, pages in (("per_file", 0), ("batched", DIR_PAGES)):
        c = LustreCluster(osts=4, mdses=1, clients=2,
                          commit_interval=2048, dir_pages=pages,
                          statahead_max=0)
        w, handles = build(c, N_OPEN, keep_open=N_OPEN)
        fs = LustreClient(c, 1).mount()
        cnt = c.stats.counters
        base = cnt.get("rpc.ost.glimpse_bulk", 0) \
            + cnt.get("rpc.ost.getattr", 0)
        listing = fs.ls_l("/scan")
        glimpse[f"{gmode}_rpcs"] = (cnt.get("rpc.ost.glimpse_bulk", 0)
                                    + cnt.get("rpc.ost.getattr", 0)) - base
        # correctness: live (unflushed) writer sizes observed
        assert listing["f0000"]["size"] == handles[0].max_written
        assert sum(o.dirty_bytes for o in w.lov.oscs) > 0
    glimpse["files"] = N_OPEN
    glimpse["reduction"] = round(glimpse["per_file_rpcs"]
                                 / max(1, glimpse["batched_rpcs"]), 2)
    out["glimpse"] = glimpse
    return out


def run() -> dict:
    out = md_scan_metrics()
    rows = [[m, out[m]["cold_scan_rpcs"],
             f"{out[m]['scan_vtime_s']:.4f}"]
            for m in ("per_entry", "statahead", "readdir_plus")]
    rows.append(["warm re-stat", out["warm_restat_rpcs"], "-"])
    table(f"ls -l scan of a {N_ENTRIES}-entry striped dir "
          f"({STRIPES} stripes)",
          ["mode", "md+glimpse RPCs", "vtime s"], rows)
    g = out["glimpse"]
    table(f"stat of {g['files']} files under write (glimpse RPCs)",
          ["mode", "OST RPCs"],
          [["per-file", g["per_file_rpcs"]],
           ["batched per OST", g["batched_rpcs"]]])
    save("mdscan", out)
    assert out["rpc_reduction"] >= 16.0, out["rpc_reduction"]
    assert out["warm_restat_rpcs"] == 0
    assert g["batched_rpcs"] <= 4 * 2          # <= per-OST, not per-file
    return out


if __name__ == "__main__":
    run()
