"""Extreme-scale mixed-personality traffic harness (ISSUE-7, ch. 35).

Drives a cluster with ``SCALE_CLIENTS`` (>= 1000) simultaneously-active
clients in four personalities, each tagged by jobid so the monitoring
plane can attribute everything it sees:

  * ``stream`` — bulk writers: chunked writes to a private file, one
    fsync barrier (the grant pipeline's customer);
  * ``scan``   — metadata readers walking a shared directory (readdir-
    plus + attr cache + batched glimpse);
  * ``churn``  — small-file create/write/setattr/unlink cycles in a
    private directory (the reint pipeline's customer);
  * ``noisy``  — ONE noisy neighbor that explodes its op rate mid-run
    (the anomaly detector's quarry).

Every round runs all clients from the same virtual instant
(``sim.parallel``), so NRS queueing and link busy-time produce a real
per-jobid latency distribution; a :class:`ClusterMonitor` snapshot after
each round merges per-target histograms into cluster-wide per-jobid
p50/p95/p99.

The documented scaling cliff: **grant exhaustion**.  Per-client grant is
``free/(2 * exports)`` (ch. 10.12), so growing the client count from 64
to SCALE_CLIENTS collapses the write-back window under the streamers'
chunk size and cached writes degrade to synchronous write-through — OST
write RPCs per streamer multiply.  ``scale_metrics()`` measures the
cliff, per-jobid p99s, the noisy-neighbor fairness ratio (p99 with the
noisy client active vs the quiet control), and monitoring overhead
(collector RPCs / workload RPCs); ``benchmarks/run.py`` gates all four
as the ``scale`` section of BENCH_rpc.json.

The ISSUE-9 rerun replays the SAME noisy workload under the fair NRS
policies instead of FIFO: ``wfq`` (``by_jobid=True`` — every jobid an
equal share of each OST/MDS service) must cut at least one victim
jobid's p99 materially without making any jobid worse, and ``tbf``
(a jobid rule pinning the noisy job's shared token bucket to
``TBF_NOISY_RATE``) must visibly throttle the aggressor while the
normal jobids stay inside the PR-7 fairness cap.  Both land in the
``scale.fairness_nrs`` section of BENCH_rpc.json and are gated there.
"""
from __future__ import annotations

from benchmarks.common import save, table
from repro.core import LustreCluster
from repro.fsio import LustreClient
from repro.tools.monitor import ChangelogAnomalyDetector

SCALE_CLIENTS = 1024          # >= 1000 mixed-personality clients
CONTROL_CLIENTS = 64          # small-N control for the grant cliff
OST_CAPACITY = 64 << 20       # small on purpose: free/(2N) is the cliff
CHUNK = 64 << 10              # streamer write chunk
SHARED_FILES = 64             # scanner working set
ROUNDS = 2
PERSONALITIES = ("stream", "scan", "churn")
TBF_NOISY_RATE = 1000.0       # req/s bucket shared by ALL noisy clients

_cache: dict | None = None


def _personality(i: int, noisy: bool) -> str:
    if noisy and i == 1:
        return "noisy"
    return PERSONALITIES[i % len(PERSONALITIES)]


def _client_round(fs, i: int, job: str, rnd: int):
    """One client's script for one round (runs inside sim.parallel)."""
    home = f"/work/c{i}"
    if job == "stream":
        if rnd == 0:
            fs.handles = getattr(fs, "handles", {})
            # spread explicitly: each client's private RR counter would
            # otherwise pile every first file onto OST 0
            fs.handles[i] = fs.creat(f"{home}/big", stripe_offset=i % 4)
        fh = fs.handles[i]
        for k in range(2):
            fs.write(fh, b"s" * CHUNK, offset=(rnd * 2 + k) * CHUNK)
        if rnd == ROUNDS - 1:
            fs.fsync(fh)
            fs.close(fh)
    elif job == "scan":
        if rnd == 0:
            fs.readdir("/shared")
        base = (i * 7 + rnd * 8) % SHARED_FILES
        for k in range(8):
            fs.stat(f"/shared/s{(base + k) % SHARED_FILES}")
    elif job == "churn":
        path = f"{home}/r{rnd}"
        fh = fs.creat(path)
        fs.write(fh, b"c" * 4096)
        fs.close(fh)
        fs.setattr(path, mode=0o644)
        if rnd > 0:
            fs.unlink(f"{home}/r{rnd - 1}")
    elif job == "noisy":
        # round 0 establishes a modest baseline window; later rounds are
        # the spike the changelog anomaly detector must flag
        burst = 3 if rnd == 0 else 30
        for k in range(burst):
            path = f"{home}/n{rnd}_{k}"
            fh = fs.creat(path, stripe_offset=k % 4)
            fs.write(fh, b"n" * CHUNK)
            fs.close(fh)


def _workload_rpcs(c) -> int:
    return sum(n for k, n in c.stats.counters.items()
               if k.startswith("rpc.") and not k.endswith(".mon_collect")
               and k not in ("rpc.timeout", "rpc.replay",
                             "rpc.reply_cache_hit"))


def _run(n_clients: int, noisy: bool,
         nrs: tuple[str, dict] | None = None) -> dict:
    c = LustreCluster(osts=4, mdses=1, clients=n_clients,
                      ost_capacity=OST_CAPACITY, commit_interval=4096)
    if nrs is not None:
        # install the fair policy on EVERY service the personalities hit:
        # the noisy neighbor hammers both the OSTs (64 KiB writes) and
        # the MDS (create/close storms), so OST-only QoS would just move
        # the pile-up to the metadata queue
        policy, params = nrs
        for t in c.ost_targets + c.mds_targets:
            t.service.set_policy(policy, **params)
    setup = LustreClient(c).mount()
    setup.mkdir("/work")
    setup.mkdir("/shared")
    for j in range(SHARED_FILES):
        fh = setup.creat(f"/shared/s{j}")
        setup.close(fh)
    for i in range(n_clients):
        setup.mkdir(f"/work/c{i}")

    clients = []
    for i in range(n_clients):
        fs = LustreClient(c, i).mount()
        fs.set_jobid(_personality(i, noisy))
        clients.append(fs)

    mon = c.monitor()
    det = ChangelogAnomalyDetector(c, mon) if noisy else None
    base_rpcs = _workload_rpcs(c)
    t0 = c.now
    anomalies = []
    for rnd in range(ROUNDS):
        c.sim.parallel([
            (lambda fs=fs, i=i, r=rnd:
             _client_round(fs, i, fs.rpc.jobid, r))
            for i, fs in enumerate(clients)])
        snap = mon.collect()
        if det is not None:
            anomalies.extend(det.poll())
    snap = mon.collect()
    assert not snap["partial"], snap["stale"]

    cnt = c.stats.counters
    mon_rpcs = (cnt.get("rpc.mds.mon_collect", 0)
                + cnt.get("rpc.ost.mon_collect", 0))
    work_rpcs = _workload_rpcs(c) - base_rpcs
    return {
        "clients": n_clients,
        "nrs": nrs[0] if nrs else "fifo",
        "vtime_s": round(c.now - t0, 6),
        "jobs": {j: {k: s[k] for k in
                     ("count", "p50_s", "p95_s", "p99_s", "mean_s")}
                 for j, s in snap["cluster"]["by_jobid"].items()},
        "grant": {
            # the MARGINAL client's slice: min over live exports — this is
            # what free/(2N) does to the last client through the door
            "min_client_grant":
                c.ost_targets[0].exports and min(
                    e.data.get("grant", 0)
                    for e in c.ost_targets[0].exports.values()) or 0,
            "granted_total": snap["cluster"]["grant"]["granted_total"],
            "shrunk_bytes": snap["cluster"]["grant"]["shrunk_bytes"],
            "shrink_rpcs": cnt.get("rpc.ost.grant_shrink", 0),
        },
        "write_rpcs_per_client":
            round(cnt.get("rpc.ost.write", 0) / n_clients, 3),
        "overhead": {
            "monitor_rpcs": mon_rpcs,
            "workload_rpcs": work_rpcs,
            "ratio": round(mon_rpcs / max(1, work_rpcs), 6),
        },
        "anomalies": anomalies,
        "spans": snap["cluster"]["spans"],
    }


def scale_metrics(use_cache: bool = True) -> dict:
    """The BENCH_rpc.json `scale` section (one execution per process)."""
    global _cache
    if use_cache and _cache is not None:
        return _cache
    control = _run(CONTROL_CLIENTS, noisy=False)
    quiet = _run(SCALE_CLIENTS, noisy=False)
    noisy = _run(SCALE_CLIENTS, noisy=True)
    # the ISSUE-9 rerun: same noisy workload, but the services run a
    # fair NRS policy instead of FIFO — WFQ gives every jobid an equal
    # share of each service, TBF pins the noisy job's shared bucket to
    # a hard request rate (the "throttle this job, whoever runs it"
    # production knob)
    noisy_wfq = _run(SCALE_CLIENTS, noisy=True,
                     nrs=("wfq", {"by_jobid": True}))
    # default rate is effectively unlimited: ONLY the noisy job's shared
    # bucket bites (1000 req/s vs the sim's microsecond RPC cadence)
    noisy_tbf = _run(SCALE_CLIENTS, noisy=True,
                     nrs=("tbf", {"rate": 1e9,
                                  "rules": {"noisy": TBF_NOISY_RATE}}))

    # fairness: how much the noisy neighbor inflates the p99 of each
    # NORMAL jobid vs the quiet control at the same scale
    def _fairness(run: dict) -> dict:
        ratios = {}
        for j in PERSONALITIES:
            q = quiet["jobs"].get(j, {}).get("p99_s", 0.0)
            n = run["jobs"].get(j, {}).get("p99_s", 0.0)
            ratios[j] = round(n / q, 3) if q else 0.0
        return {"nrs": run["nrs"], "per_jobid_p99_ratio": ratios,
                "max_ratio": max(ratios.values() or [0.0])}

    def _speedup_vs_fifo(run: dict) -> dict:
        """Per-jobid p99 improvement of a fair-policy noisy run over the
        FIFO noisy run (same workload, same scale): > 1.0 is better."""
        sp = {}
        for j in PERSONALITIES:
            f = noisy["jobs"].get(j, {}).get("p99_s", 0.0)
            n = run["jobs"].get(j, {}).get("p99_s", 0.0)
            sp[j] = round(f / n, 3) if n else 0.0
        return sp

    fair_fifo = _fairness(noisy)
    fairness = fair_fifo["per_jobid_p99_ratio"]
    wfq_speedup = _speedup_vs_fifo(noisy_wfq)
    tbf_speedup = _speedup_vs_fifo(noisy_tbf)
    out = {
        "clients": SCALE_CLIENTS,
        "control": control,
        "quiet": quiet,
        "noisy": noisy,
        "fairness": {"per_jobid_p99_ratio": fairness,
                     "max_ratio": fair_fifo["max_ratio"]},
        # fairness rerun under the fair policies (ISSUE-9).  WFQ's
        # per-jobid fair shares must leave no jobid worse than FIFO and
        # cut at least one victim's p99 materially; TBF's jobid-rule
        # bucket must contain the AGGRESSOR (its own mean request
        # latency inflates — the throttle bites) with the normal jobids
        # still inside the PR-7 fairness cap.
        "fairness_nrs": {
            "wfq": {**_fairness(noisy_wfq),
                    "p99_speedup_vs_fifo": wfq_speedup,
                    "best_speedup": max(wfq_speedup.values() or [0.0]),
                    "worst_speedup": min(wfq_speedup.values() or [0.0])},
            "tbf": {**_fairness(noisy_tbf),
                    "p99_speedup_vs_fifo": tbf_speedup,
                    "noisy_containment_x": round(
                        noisy_tbf["jobs"].get("noisy", {}).get("mean_s", 0.0)
                        / max(1e-12, noisy["jobs"].get("noisy", {})
                              .get("mean_s", 0.0)), 2)},
        },
        # the grant-exhaustion cliff: write RPCs per streamer multiply
        # when free/(2N) collapses below the streamers' chunk size
        "grant_cliff": {
            "control_clients": CONTROL_CLIENTS,
            "control_grant": control["grant"]["min_client_grant"],
            "scale_grant": quiet["grant"]["min_client_grant"],
            "control_write_rpcs_per_client":
                control["write_rpcs_per_client"],
            "scale_write_rpcs_per_client":
                quiet["write_rpcs_per_client"],
            "rpc_multiplier": round(
                quiet["write_rpcs_per_client"]
                / max(1e-9, control["write_rpcs_per_client"]), 2),
        },
        "overhead_ratio": noisy["overhead"]["ratio"],
        "noisy_flagged": any(a["jobid"] == "noisy"
                             for a in noisy["anomalies"]),
        "false_positives": sorted({a["jobid"] for a in noisy["anomalies"]}
                                  - {"noisy"}),
    }
    _cache = out
    return out


def run() -> dict:
    out = scale_metrics()
    nj = out["noisy"]["jobs"]
    table(f"scale harness: {SCALE_CLIENTS} clients, 4 personalities, "
          f"{ROUNDS} rounds (noisy run)",
          ["jobid", "rpcs traced", "p50 ms", "p95 ms", "p99 ms"],
          [[j, nj[j]["count"],
            round(nj[j]["p50_s"] * 1e3, 3),
            round(nj[j]["p95_s"] * 1e3, 3),
            round(nj[j]["p99_s"] * 1e3, 3)] for j in sorted(nj)])
    cliff = out["grant_cliff"]
    print(f"  grant cliff: {cliff['control_clients']} clients -> "
          f"{cliff['control_grant'] >> 10} KiB grant, "
          f"{cliff['control_write_rpcs_per_client']} write RPCs/client;"
          f" {SCALE_CLIENTS} clients -> {cliff['scale_grant'] >> 10} KiB, "
          f"{cliff['scale_write_rpcs_per_client']} RPCs/client "
          f"[{cliff['rpc_multiplier']}x]")
    print(f"  fairness (noisy/quiet p99): "
          f"{out['fairness']['per_jobid_p99_ratio']}  "
          f"monitor overhead: {out['overhead_ratio']:.4%}  "
          f"noisy flagged: {out['noisy_flagged']}")
    fnrs = out["fairness_nrs"]
    print(f"  fairness rerun: wfq p99 speedup vs fifo "
          f"{fnrs['wfq']['p99_speedup_vs_fifo']} (best "
          f"{fnrs['wfq']['best_speedup']}x), tbf noisy containment "
          f"{fnrs['tbf']['noisy_containment_x']}x at "
          f"{TBF_NOISY_RATE:g} req/s")
    save("scale", out)
    assert out["noisy_flagged"] and not out["false_positives"], \
        out["false_positives"]
    assert out["overhead_ratio"] <= 0.02, out["overhead_ratio"]
    return out


if __name__ == "__main__":
    run()
