"""Recovery costs (paper ch. 11, 29).

  (a) replay volume vs commit interval: lazier commits = faster steady
      state, more replay work after a crash;
  (b) failover latency: virtual time from OST death to the first
      successful retried I/O (timeout + reconnect on the ring);
  (c) MDS crash recovery: intent replay correctness at scale.
"""
from __future__ import annotations

from benchmarks.common import save, table, vtime
from repro.core import LustreCluster
from repro.core import ptlrpc as R
from repro.core import recovery as rec_mod
from repro.fsio import LustreClient

AT_CLIENTS = 1024             # loaded-server adaptive-timeout scenario
AT_LOAD_RATE = 400.0          # shared bucket: queue waits up to ~2.5 s
REPLAY_BACKLOG = 50           # uncommitted writes the reconnect replays

_metrics_cache: dict | None = None


def _reconnect_run(imperative: bool) -> dict:
    """First-op latency after an unnoticed server power-cycle.

    Timeout-driven: the client's next request goes unanswered, and the
    op pays timeout + reconnect + full replay inline. Imperative: the
    pinger already noticed the new boot count and recovered off the
    application's critical path, so the op is just the op."""
    c = LustreCluster(osts=1, mdses=1, clients=1, commit_interval=100000)
    rpc = c.make_client_rpc(0)
    osc = c.make_oscs(rpc, writeback=False)[0]
    oid = osc.create(0)["oid"]
    for i in range(REPLAY_BACKLOG):
        osc.write(0, oid, i * 8, b"r" * 8)
    c.fail_node("ost0")
    c.restart_node("ost0")
    if imperative:
        p = rec_mod.Pinger([osc.imp], interval=0.5)
        for _ in range(4):
            if p.tick().get(osc.imp.target_uuid):
                break
            c.sim.clock.advance(p.interval)
    else:
        # the client hears nothing about the reboot: lose its next
        # request so discovery is purely timeout-driven
        c.sim.faults.drop_next[c.ost_targets[0].node.nid] = 1
    t0 = c.now
    assert osc.read(0, oid, 0, 8) == b"r" * 8
    return {
        "first_op_s": c.now - t0,
        "replays": c.stats.counters.get("rpc.replay", 0),
        "imperative_recoveries":
            c.stats.counters.get("rpc.imperative_recovery", 0),
    }


def _at_run(adaptive: bool) -> dict:
    """1024 clients, one small write each, through one OST whose shared
    token bucket stretches queue waits past any fixed 1 s timeout."""
    c = LustreCluster(osts=1, mdses=1, clients=AT_CLIENTS,
                      commit_interval=4096,
                      adaptive_timeouts=adaptive)
    c.ost_targets[0].service.set_policy(
        "tbf", rate=1e9, burst=4.0, rules={"load": AT_LOAD_RATE})
    pairs = []
    for i in range(AT_CLIENTS):
        rpc = c.make_client_rpc(i)
        osc = c.make_oscs(rpc, writeback=False)[0]
        oid = osc.create(0)["oid"]   # per-client bucket: setup unthrottled
        rpc.jobid = "load"           # writes share ONE bucket from here
        pairs.append((osc, oid))
    failures = [0]

    def one(osc, oid):
        try:
            osc.write(0, oid, 0, b"w" * 4096)
        except (R.RpcError, R.TimeoutError_):
            failures[0] += 1
    t0 = c.now
    c.sim.parallel([lambda o=o, d=d: one(o, d) for o, d in pairs])
    cnt = c.stats.counters
    return {
        "adaptive": adaptive,
        "vtime_s": round(c.now - t0, 3),
        "spurious_timeouts": cnt.get("rpc.timeout_spurious", 0),
        "timeouts": cnt.get("rpc.timeout", 0),
        "early_replies": cnt.get("rpc.early_reply", 0),
        "early_reply_rescues": cnt.get("rpc.early_reply_rescue", 0),
        "evictions": sum(v for k, v in cnt.items()
                         if k.endswith("_eviction")),
        "failed_ops": failures[0],
    }


def recovery_metrics(use_cache: bool = True) -> dict:
    """The BENCH_rpc.json `recovery` section (one execution per process):
    imperative-vs-timeout reconnect speedup + the loaded-server adaptive
    timeout scenario with its fixed-timeout baseline."""
    global _metrics_cache
    if use_cache and _metrics_cache is not None:
        return _metrics_cache
    timeout_run = _reconnect_run(imperative=False)
    imp_run = _reconnect_run(imperative=True)
    at_on = _at_run(adaptive=True)
    at_off = _at_run(adaptive=False)
    out = {
        "imperative": {
            "timeout_driven_first_op_s":
                round(timeout_run["first_op_s"], 6),
            "imperative_first_op_s": round(imp_run["first_op_s"], 6),
            "speedup_x": round(timeout_run["first_op_s"]
                               / max(1e-9, imp_run["first_op_s"]), 2),
            "imperative_recoveries": imp_run["imperative_recoveries"],
            "replay_backlog": REPLAY_BACKLOG,
        },
        "at": {
            "clients": AT_CLIENTS,
            "spurious_with_at": at_on["spurious_timeouts"],
            "evictions_with_at": at_on["evictions"],
            "failed_ops_with_at": at_on["failed_ops"],
            "early_replies": at_on["early_replies"],
            "early_reply_rescues": at_on["early_reply_rescues"],
            "spurious_baseline": at_off["spurious_timeouts"],
            "failed_ops_baseline": at_off["failed_ops"],
        },
    }
    _metrics_cache = out
    return out


def run() -> dict:
    out = {}

    # -------------------------------------------- (a) commit interval
    rows = []
    for interval in (1, 16, 128, 100000):
        c = LustreCluster(osts=1, mdses=1, clients=1,
                          commit_interval=interval)
        rpc = c.make_client_rpc(0)
        osc = c.make_oscs(rpc, writeback=False)[0]
        oid = osc.create(0)["oid"]

        def io():
            for i in range(64):
                osc.write(0, oid, i * 32, b"y" * 32)
        _, t_io = vtime(c, io)
        c.fail_node("ost0")
        c.restart_node("ost0")
        _, t_rec = vtime(c, lambda: osc.read(0, oid, 0, 32))
        replays = c.stats.counters.get("rpc.replay", 0)
        rows.append([interval, f"{t_io*1e3:.2f}", replays,
                     f"{t_rec*1e3:.1f}"])
        out[f"interval_{interval}"] = {
            "io_ms": t_io * 1e3, "replays": replays,
            "recovery_ms": t_rec * 1e3}
    table("replay volume vs commit interval (64 writes then crash)",
          ["commit_every", "io ms", "replays", "recovery ms"], rows)

    # ------------------------------------------------ (b) failover
    c = LustreCluster(osts=4, mdses=1, clients=1, ost_failover=True,
                      commit_interval=8)
    fs = LustreClient(c).mount()
    fh = fs.creat("/f", stripe_count=4)
    fs.write(fh, b"q" * 4096)
    fs.fsync(fh)
    for t in c.ost_targets:
        t.commit()
    c.fail_node("ost1")
    _, t_fo = vtime(c, lambda: fs.read(fh, 4096, offset=0))
    out["failover_latency_s"] = t_fo
    print(f"\nOST failover: first read after node death took "
          f"{t_fo:.2f} virtual s (timeout + ring reconnect)")

    # ------------------------------------------------ (c) MDS replay
    c2 = LustreCluster(osts=1, mdses=1, clients=1, commit_interval=100000)
    fs2 = LustreClient(c2).mount()
    fids = {}
    for i in range(100):
        fh = fs2.creat(f"/file{i:03d}")
        fids[i] = fh.fid
        fs2.close(fh)
    c2.fail_node("mds0")
    c2.restart_node("mds0")
    _, t_mds = vtime(c2, lambda: fs2.stat("/file000"))
    ok = all(fs2.stat(f"/file{i:03d}")["fid"] == fids[i] for i in range(100))
    out["mds_replay"] = {"files": 100, "all_fids_stable": ok,
                         "first_op_recovery_s": t_mds,
                         "replays": c2.stats.counters.get("rpc.replay", 0)}
    print(f"MDS crash with 100 uncommitted creates: replayed "
          f"{out['mds_replay']['replays']} ops, fids stable: {ok}")

    # ------------------------------------- (d) ISSUE-10 gated metrics
    m = recovery_metrics()
    out["metrics"] = m
    imp = m["imperative"]
    print(f"imperative recovery: first op {imp['imperative_first_op_s']*1e3:.2f} ms "
          f"vs timeout-driven {imp['timeout_driven_first_op_s']*1e3:.1f} ms "
          f"[{imp['speedup_x']}x]")
    at = m["at"]
    print(f"adaptive timeouts, {at['clients']} clients on a throttled OST: "
          f"{at['early_replies']} early replies, "
          f"{at['spurious_with_at']} spurious timeouts "
          f"(fixed-timeout baseline: {at['spurious_baseline']})")
    save("recovery", out)
    return out


if __name__ == "__main__":
    run()
