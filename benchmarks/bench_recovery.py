"""Recovery costs (paper ch. 11, 29).

  (a) replay volume vs commit interval: lazier commits = faster steady
      state, more replay work after a crash;
  (b) failover latency: virtual time from OST death to the first
      successful retried I/O (timeout + reconnect on the ring);
  (c) MDS crash recovery: intent replay correctness at scale.
"""
from __future__ import annotations

from benchmarks.common import save, table, vtime
from repro.core import LustreCluster
from repro.fsio import LustreClient


def run() -> dict:
    out = {}

    # -------------------------------------------- (a) commit interval
    rows = []
    for interval in (1, 16, 128, 100000):
        c = LustreCluster(osts=1, mdses=1, clients=1,
                          commit_interval=interval)
        rpc = c.make_client_rpc(0)
        osc = c.make_oscs(rpc, writeback=False)[0]
        oid = osc.create(0)["oid"]

        def io():
            for i in range(64):
                osc.write(0, oid, i * 32, b"y" * 32)
        _, t_io = vtime(c, io)
        c.fail_node("ost0")
        c.restart_node("ost0")
        _, t_rec = vtime(c, lambda: osc.read(0, oid, 0, 32))
        replays = c.stats.counters.get("rpc.replay", 0)
        rows.append([interval, f"{t_io*1e3:.2f}", replays,
                     f"{t_rec*1e3:.1f}"])
        out[f"interval_{interval}"] = {
            "io_ms": t_io * 1e3, "replays": replays,
            "recovery_ms": t_rec * 1e3}
    table("replay volume vs commit interval (64 writes then crash)",
          ["commit_every", "io ms", "replays", "recovery ms"], rows)

    # ------------------------------------------------ (b) failover
    c = LustreCluster(osts=4, mdses=1, clients=1, ost_failover=True,
                      commit_interval=8)
    fs = LustreClient(c).mount()
    fh = fs.creat("/f", stripe_count=4)
    fs.write(fh, b"q" * 4096)
    fs.fsync(fh)
    for t in c.ost_targets:
        t.commit()
    c.fail_node("ost1")
    _, t_fo = vtime(c, lambda: fs.read(fh, 4096, offset=0))
    out["failover_latency_s"] = t_fo
    print(f"\nOST failover: first read after node death took "
          f"{t_fo:.2f} virtual s (timeout + ring reconnect)")

    # ------------------------------------------------ (c) MDS replay
    c2 = LustreCluster(osts=1, mdses=1, clients=1, commit_interval=100000)
    fs2 = LustreClient(c2).mount()
    fids = {}
    for i in range(100):
        fh = fs2.creat(f"/file{i:03d}")
        fids[i] = fh.fid
        fs2.close(fh)
    c2.fail_node("mds0")
    c2.restart_node("mds0")
    _, t_mds = vtime(c2, lambda: fs2.stat("/file000"))
    ok = all(fs2.stat(f"/file{i:03d}")["fid"] == fids[i] for i in range(100))
    out["mds_replay"] = {"files": 100, "all_fids_stable": ok,
                         "first_op_recovery_s": t_mds,
                         "replays": c2.stats.counters.get("rpc.replay", 0)}
    print(f"MDS crash with 100 uncommitted creates: replayed "
          f"{out['mds_replay']['replays']} ops, fids stable: {ok}")
    save("recovery", out)
    return out


if __name__ == "__main__":
    run()
