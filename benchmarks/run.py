"""Benchmark harness: one bench per paper table/claim.

    PYTHONPATH=src python -m benchmarks.run [--only striping,...]

Results land in results/bench/*.json; a summary prints per bench.
"""
from __future__ import annotations

import argparse
import sys
import time

BENCHES = ["striping", "intents", "dlm", "recovery", "cobd",
           "checkpoint", "parity"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    todo = args.only.split(",") if args.only else BENCHES
    failures = []
    for name in todo:
        mod = __import__(f"benchmarks.bench_{name}", fromlist=["run"])
        t0 = time.time()
        try:
            mod.run()
            print(f"[{name}] done in {time.time()-t0:.1f}s wall")
        except Exception as e:  # noqa: BLE001
            import traceback
            traceback.print_exc()
            failures.append((name, repr(e)))
    if failures:
        print("FAILURES:", failures)
        sys.exit(1)
    print(f"\nall {len(todo)} benchmarks OK")


if __name__ == "__main__":
    main()
