"""Benchmark harness: one bench per paper table/claim.

    PYTHONPATH=src python -m benchmarks.run [--only striping,...]

Results land in results/bench/*.json; a summary prints per bench.
Every run also emits BENCH_rpc.json (repo root): OST_WRITE RPC count +
wall/virtual time for a striped-write workload, seed-style one-RPC-per-
extent vs the vectored BRW pipeline — the perf trajectory tracked from
ISSUE 1 onward. The committed BENCH_rpc.json doubles as a regression
gate: exit status is non-zero if the vectored RPC count exceeds it.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

BENCHES = ["striping", "nrs", "read", "mdscan", "untar", "intents",
           "dlm", "recovery", "cobd", "checkpoint", "parity", "scale"]

RPC_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_rpc.json")


def bench_rpc() -> dict:
    """Striped-write RPC trajectory: 8 MiB over 4 stripes, written in
    64 KiB logical chunks, flushed once — legacy (vectored_brw=False,
    the seed's one-RPC-per-dirty-extent) vs the vectored BRW pipeline.

    The COMMITTED BENCH_rpc.json is the regression baseline: if this
    run's vectored OST_WRITE RPC count exceeds it, main() exits non-zero
    (the CI benchmark smoke job fails the PR)."""
    from repro.core import LustreCluster
    from repro.fsio import LustreClient

    baseline = read_baseline = md_baseline = None
    try:
        with open(RPC_JSON) as f:
            committed = json.load(f)
        baseline = committed["vectored"]["ost_write_rpcs"]
        read_baseline = committed["seq_read"]["readahead"]["ost_read_rpcs"]
    except (OSError, KeyError, ValueError, TypeError):
        committed = {}                         # no (usable) baseline yet
    try:
        md_baseline = committed["md_scan"]["readdir_plus"]["cold_scan_rpcs"]
    except (KeyError, TypeError):
        pass
    untar_baseline = None
    try:
        untar_baseline = committed["untar"]["wbc"]["reint_rpcs"]
    except (KeyError, TypeError):
        pass
    scale_baseline = None
    try:
        scale_baseline = committed["scale"]["jobs"]
    except (KeyError, TypeError):
        pass

    size, chunk = 8 << 20, 64 << 10
    out = {}
    for mode, vectored in (("seed_like", False), ("vectored", True)):
        wall0 = time.time()
        c = LustreCluster(osts=4, mdses=1, clients=1, commit_interval=512,
                          vectored_brw=vectored)
        fs = LustreClient(c).mount()
        fh = fs.creat("/rpc.bin", stripe_count=4, stripe_size=1 << 20)
        data = bytes(chunk)
        t0 = c.now
        for off in range(0, size, chunk):
            fs.write(fh, data, offset=off)
        fs.fsync(fh)
        out[mode] = {
            "ost_write_rpcs": c.stats.counters.get("rpc.ost.write", 0),
            "write_vtime_s": round(c.now - t0, 6),
            "wall_time_s": round(time.time() - wall0, 3),
            "bytes": size,
        }
        fs.close(fh)
    v, s = out["vectored"], out["seed_like"]
    out["rpc_reduction"] = round(
        s["ost_write_rpcs"] / max(1, v["ost_write_rpcs"]), 2)
    out["baseline_ost_write_rpcs"] = baseline
    # sequential-read trajectory (ISSUE 4): clean cache + readahead
    from benchmarks.bench_read import seq_read_metrics
    sr = seq_read_metrics()
    sr["baseline_ost_read_rpcs"] = read_baseline
    out["seq_read"] = sr
    # metadata-scan trajectory (ISSUE-5): readdir-plus + attr cache +
    # statahead + batched glimpse
    from benchmarks.bench_mdscan import md_scan_metrics
    ms = md_scan_metrics()
    ms["baseline_md_rpcs"] = md_baseline
    out["md_scan"] = ms
    # untar-shaped metadata burst (ISSUE-6): write-back cache + batched
    # reintegration vs one-RPC-per-op
    from benchmarks.bench_untar import N_FILES, untar_metrics
    un = untar_metrics()
    un["baseline_reint_rpcs"] = untar_baseline
    out["untar"] = un
    # monitoring-plane scale harness (ISSUE-7): 1024 mixed-personality
    # clients, per-jobid tail latency + noisy-neighbor fairness + the
    # grant-exhaustion cliff + monitor overhead, all from one run of
    # bench_scale (module-cached, so `--only scale` doesn't re-run it)
    from benchmarks.bench_scale import (PERSONALITIES, SCALE_CLIENTS,
                                        scale_metrics)
    sc_full = scale_metrics()
    sc = {
        "clients": SCALE_CLIENTS,
        "jobs": {j: sc_full["noisy"]["jobs"].get(j, {})
                 for j in PERSONALITIES + ("noisy",)},
        "fairness": sc_full["fairness"],
        "fairness_nrs": sc_full["fairness_nrs"],
        "grant_cliff": sc_full["grant_cliff"],
        "overhead_ratio": sc_full["overhead_ratio"],
        "noisy_flagged": sc_full["noisy_flagged"],
        "false_positives": sc_full["false_positives"],
        "spans": sc_full["noisy"]["spans"],
        "baseline_p99_s": scale_baseline and {
            j: scale_baseline.get(j, {}).get("p99_s")
            for j in PERSONALITIES},
    }
    out["scale"] = sc
    # raid5 / SNS (ISSUE-8): degraded-read reconstruction must stay
    # byte-identical, and a tbf_orr-throttled rebuild must hold client
    # p99 at <= 1.5x the no-rebuild baseline (the FIFO number is the
    # contrast, not a gate)
    from benchmarks.bench_parity import raid5_metrics
    r5 = raid5_metrics()
    out["raid5"] = r5
    # recovery plane (ISSUE-10): imperative reconnect must beat the
    # timeout-driven path >= 4x, and adaptive timeouts must keep a
    # 1024-client loaded-server run free of spurious timeouts and
    # evictions while the fixed-timeout baseline demonstrably suffers
    from benchmarks.bench_recovery import recovery_metrics
    rec = recovery_metrics()
    out["recovery"] = rec
    # single source of truth for the gates: main() keys its exit code off
    # these per-gate flags, and the file writes below key off the
    # combined one
    out["write_regressed"] = \
        baseline is not None and v["ost_write_rpcs"] > baseline
    sr["regressed"] = (
        (read_baseline is not None
         and sr["readahead"]["ost_read_rpcs"] > read_baseline)
        or sr["rpc_reduction"] < 4.0
        or sr["warm_reread_ost_reads"] != 0)
    ms["regressed"] = (
        (md_baseline is not None
         and ms["readdir_plus"]["cold_scan_rpcs"] > md_baseline)
        or ms["rpc_reduction"] < 16.0
        or ms["warm_restat_rpcs"] != 0)
    un["regressed"] = (
        (untar_baseline is not None
         and un["wbc"]["reint_rpcs"] > untar_baseline)
        or un["wbc"]["reint_rpcs"] > N_FILES // 8
        or un["reint_reduction"] < 8.0)
    # fairness rerun under TBF/WFQ (ISSUE-9): WFQ must leave no jobid
    # worse than the FIFO noisy run and cut at least one victim's p99
    # by >= 2x; TBF's jobid rule must throttle the aggressor >= 4x with
    # the normal jobids still inside the 4x fairness cap
    wfq, tbf = sc["fairness_nrs"]["wfq"], sc["fairness_nrs"]["tbf"]
    sc["fairness_nrs"]["regressed"] = (
        wfq["worst_speedup"] < 1.0 or wfq["best_speedup"] < 2.0
        or wfq["max_ratio"] > 4.0
        or tbf["noisy_containment_x"] < 4.0
        or tbf["max_ratio"] > 4.0)
    sc["regressed"] = (
        any(scale_baseline is not None
            and scale_baseline.get(j, {}).get("p99_s") is not None
            and sc["jobs"].get(j, {}).get("p99_s", 0.0)
            > scale_baseline[j]["p99_s"] * 1.25
            for j in PERSONALITIES)
        or sc["fairness"]["max_ratio"] > 4.0
        or sc["fairness_nrs"]["regressed"]
        or sc["overhead_ratio"] > 0.02
        or not sc["noisy_flagged"] or bool(sc["false_positives"])
        or sc["grant_cliff"]["rpc_multiplier"] < 1.2)
    r5["regressed"] = (
        not r5["clean"]["identical"]
        or not r5["degraded"]["identical"]
        or r5["throttle"]["tbf_p99_ratio"] > 1.5
        or r5["rebuild"]["layout_swaps"] < 1)
    rec["regressed"] = (
        rec["imperative"]["speedup_x"] < 4.0
        or rec["at"]["spurious_with_at"] != 0
        or rec["at"]["evictions_with_at"] != 0
        or rec["at"]["failed_ops_with_at"] != 0
        or rec["at"]["spurious_baseline"] <= 0)
    out["regressed"] = out["write_regressed"] or sr["regressed"] \
        or ms["regressed"] or un["regressed"] or sc["regressed"] \
        or r5["regressed"] or rec["regressed"]
    if not out["regressed"]:
        # a failed gate must NOT overwrite its own baseline: the second
        # run would compare against the regressed count and pass, and a
        # blind "commit the regenerated json" would ratchet the committed
        # baseline up. Only equal-or-better results become the baseline.
        with open(RPC_JSON, "w") as f:
            json.dump(out, f, indent=1)
    else:
        # keep the evidence without touching the baseline (CI uploads
        # BENCH_rpc.json — the regressed counts land next to it)
        failed_path = os.path.join(os.path.dirname(RPC_JSON),
                                   "BENCH_rpc_failed.json")
        with open(failed_path, "w") as f:
            json.dump(out, f, indent=1)
    print(f"\n== BENCH_rpc: striped 8 MiB write ==\n"
          f"  seed-like: {s['ost_write_rpcs']} OST_WRITE RPCs "
          f"({s['write_vtime_s']:.4f}s vtime)\n"
          f"  vectored:  {v['ost_write_rpcs']} OST_WRITE RPCs "
          f"({v['write_vtime_s']:.4f}s vtime)  "
          f"[{out['rpc_reduction']}x fewer]"
          + (f"  (baseline: {baseline})" if baseline is not None else ""))
    print(f"== BENCH_rpc: striped 8 MiB cold sequential read ==\n"
          f"  no readahead: {sr['no_readahead']['ost_read_rpcs']} "
          f"OST_READ RPCs\n"
          f"  readahead:    {sr['readahead']['ost_read_rpcs']} OST_READ "
          f"RPCs  [{sr['rpc_reduction']}x fewer, hit rate "
          f"{sr['readahead']['cache_hit_rate']}]\n"
          f"  warm re-read: {sr['warm_reread_ost_reads']} OST_READ RPCs"
          + (f"  (baseline: {read_baseline})"
             if read_baseline is not None else ""))
    print(f"== BENCH_rpc: ls -l scan, {ms['per_entry']['entries']}-entry "
          f"striped dir ==\n"
          f"  per-entry:    {ms['per_entry']['cold_scan_rpcs']} md+glimpse "
          f"RPCs\n"
          f"  statahead:    {ms['statahead']['cold_scan_rpcs']} RPCs  "
          f"[{ms['statahead_reduction']}x fewer]\n"
          f"  readdir-plus: {ms['readdir_plus']['cold_scan_rpcs']} RPCs  "
          f"[{ms['rpc_reduction']}x fewer]\n"
          f"  warm re-stat: {ms['warm_restat_rpcs']} RPCs; glimpse "
          f"{ms['glimpse']['per_file_rpcs']} -> "
          f"{ms['glimpse']['batched_rpcs']} RPCs batched"
          + (f"  (baseline: {md_baseline})"
             if md_baseline is not None else ""))
    print(f"== BENCH_rpc: untar burst, {un['wbc']['files']} files ==\n"
          f"  cold: {un['cold']['reint_rpcs']} reint RPCs "
          f"({un['cold']['md_rpcs']} MDS RPCs total)\n"
          f"  wbc:  {un['wbc']['reint_rpcs']} reint RPCs "
          f"({un['wbc']['md_rpcs']} MDS RPCs total)  "
          f"[{un['reint_reduction']}x fewer]"
          + (f"  (baseline: {untar_baseline})"
             if untar_baseline is not None else ""))
    th = r5["throttle"]
    print(f"== BENCH_rpc: raid5 degraded read + throttled rebuild ==\n"
          f"  degraded read: identical={r5['degraded']['identical']}  "
          f"{r5['degraded']['overhead_x']}x vtime of clean "
          f"({r5['degraded']['reconstructed_units']} units rebuilt)\n"
          f"  rebuild: {r5['rebuild']['bytes']} B onto spare at "
          f"{r5['rebuild']['throughput_MBps']} MB/s (virtual), "
          f"{r5['rebuild']['layout_swaps']} layout swap(s)\n"
          f"  app p99 during rebuild: tbf_orr {th['tbf_p99_ratio']}x "
          f"baseline (gate <= 1.5x), fifo {th['fifo_p99_ratio']}x")
    cl = sc["grant_cliff"]
    print(f"== BENCH_rpc: {sc['clients']}-client scale harness ==\n"
          f"  per-jobid p99 ms: "
          + "  ".join(f"{j}={sc['jobs'][j].get('p99_s', 0) * 1e3:g}"
                      for j in PERSONALITIES + ("noisy",)) + "\n"
          f"  fairness max {sc['fairness']['max_ratio']}x  "
          f"monitor overhead {sc['overhead_ratio']:.4%}  "
          f"noisy flagged: {sc['noisy_flagged']}\n"
          f"  fairness rerun: wfq best p99 speedup "
          f"{sc['fairness_nrs']['wfq']['best_speedup']}x (no jobid "
          f"worse), tbf noisy containment "
          f"{sc['fairness_nrs']['tbf']['noisy_containment_x']}x\n"
          f"  grant cliff: {cl['control_grant'] >> 10} KiB -> "
          f"{cl['scale_grant'] >> 10} KiB marginal grant, write RPCs/client "
          f"x{cl['rpc_multiplier']}")
    ri, ra = rec["imperative"], rec["at"]
    print(f"== BENCH_rpc: recovery plane ==\n"
          f"  imperative reconnect: first op "
          f"{ri['imperative_first_op_s'] * 1e3:.2f} ms vs timeout-driven "
          f"{ri['timeout_driven_first_op_s'] * 1e3:.1f} ms "
          f"[{ri['speedup_x']}x, gate >= 4x]\n"
          f"  adaptive timeouts @ {ra['clients']} clients: "
          f"{ra['early_replies']} early replies, "
          f"{ra['spurious_with_at']} spurious / {ra['evictions_with_at']} "
          f"evictions (gate 0), fixed-timeout baseline "
          f"{ra['spurious_baseline']} spurious (gate > 0)")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    todo = args.only.split(",") if args.only else BENCHES
    failures = []
    for name in todo:
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.bench_{name}", fromlist=["run"])
            mod.run()
            print(f"[{name}] done in {time.time()-t0:.1f}s wall")
        except Exception as e:  # noqa: BLE001
            import traceback
            traceback.print_exc()
            failures.append((name, repr(e)))
    try:
        rpc = bench_rpc()
        if rpc["vectored"]["ost_write_rpcs"] >= \
                rpc["seed_like"]["ost_write_rpcs"]:
            failures.append(("BENCH_rpc", "vectored BRW did not reduce "
                             "OST_WRITE RPC count"))
        if rpc.get("write_regressed"):
            failures.append((
                "BENCH_rpc", f"striped-write OST_WRITE RPC count "
                f"regressed: {rpc['vectored']['ost_write_rpcs']} > "
                f"committed baseline {rpc['baseline_ost_write_rpcs']}"))
        sr = rpc["seq_read"]
        if sr.get("regressed"):
            failures.append((
                "BENCH_rpc", f"sequential-read gate failed: readahead "
                f"{sr['readahead']['ost_read_rpcs']} RPCs (baseline "
                f"{sr['baseline_ost_read_rpcs']}), reduction "
                f"{sr['rpc_reduction']}x (needs >= 4x), warm re-read "
                f"{sr['warm_reread_ost_reads']} (needs 0)"))
        un = rpc["untar"]
        if un.get("regressed"):
            failures.append((
                "BENCH_rpc", f"untar gate failed: wbc burst "
                f"{un['wbc']['reint_rpcs']} reint RPCs (baseline "
                f"{un['baseline_reint_rpcs']}, cap N/8), reduction "
                f"{un['reint_reduction']}x (needs >= 8x)"))
        sc = rpc["scale"]
        if sc.get("regressed"):
            failures.append((
                "BENCH_rpc", f"scale gate failed: per-jobid p99 "
                f"{ {j: sc['jobs'][j].get('p99_s') for j in sc['jobs']} } "
                f"(baseline {sc['baseline_p99_s']}, headroom 1.25x), "
                f"fairness {sc['fairness']['max_ratio']}x (cap 4x), "
                f"fairness_nrs wfq speedup "
                f"{sc['fairness_nrs']['wfq']['p99_speedup_vs_fifo']} "
                f"(worst >= 1x, best >= 2x) / tbf containment "
                f"{sc['fairness_nrs']['tbf']['noisy_containment_x']}x "
                f"(floor 4x), "
                f"overhead {sc['overhead_ratio']} (cap 0.02), noisy "
                f"flagged {sc['noisy_flagged']} (false positives "
                f"{sc['false_positives']}), grant-cliff multiplier "
                f"{sc['grant_cliff']['rpc_multiplier']} (floor 1.2)"))
        r5 = rpc["raid5"]
        if r5.get("regressed"):
            failures.append((
                "BENCH_rpc", f"raid5 gate failed: degraded identical "
                f"{r5['degraded']['identical']}, tbf p99 ratio "
                f"{r5['throttle']['tbf_p99_ratio']} (cap 1.5), layout "
                f"swaps {r5['rebuild']['layout_swaps']} (floor 1)"))
        rec = rpc["recovery"]
        if rec.get("regressed"):
            failures.append((
                "BENCH_rpc", f"recovery gate failed: imperative "
                f"speedup {rec['imperative']['speedup_x']}x (floor 4x), "
                f"AT spurious {rec['at']['spurious_with_at']} / "
                f"evictions {rec['at']['evictions_with_at']} / failed "
                f"ops {rec['at']['failed_ops_with_at']} (all must be 0 "
                f"at {rec['at']['clients']} clients), fixed-timeout "
                f"baseline spurious {rec['at']['spurious_baseline']} "
                f"(must be > 0)"))
        ms = rpc["md_scan"]
        if ms.get("regressed"):
            failures.append((
                "BENCH_rpc", f"md_scan gate failed: readdir-plus "
                f"{ms['readdir_plus']['cold_scan_rpcs']} RPCs (baseline "
                f"{ms['baseline_md_rpcs']}), reduction "
                f"{ms['rpc_reduction']}x (needs >= 16x), warm re-stat "
                f"{ms['warm_restat_rpcs']} (needs 0)"))
    except Exception as e:  # noqa: BLE001
        import traceback
        traceback.print_exc()
        failures.append(("BENCH_rpc", repr(e)))
    if failures:
        print("FAILURES:", failures)
        sys.exit(1)
    print(f"\nall {len(todo)} benchmarks OK (+ BENCH_rpc.json)")


if __name__ == "__main__":
    main()
