"""Striping throughput vs stripe_count (paper ch. 10.4).

The paper's claim: striping files over N OSTs multiplies single-file
bandwidth by ~N until the client link saturates. We write + read an 8 MiB
file at stripe_count 1/2/4/8 on an 8-OST cluster and report virtual-time
bandwidth.
"""
from __future__ import annotations

from benchmarks.common import save, table, vtime
from repro.core import LustreCluster
from repro.fsio import LustreClient

SIZE = 8 << 20
CHUNK = 1 << 20


def run() -> dict:
    rows = []
    out = {}
    for cnt in (1, 2, 4, 8):
        c = LustreCluster(osts=8, mdses=1, clients=2, commit_interval=256)
        fs = LustreClient(c).mount()
        fh = fs.creat("/bench.bin", stripe_count=cnt, stripe_size=1 << 20)
        data = bytes(CHUNK)

        def write():
            for off in range(0, SIZE, CHUNK):
                fs.write(fh, data, offset=off)
            fs.fsync(fh)
        _, tw = vtime(c, write)
        fs.close(fh)

        # COLD second client: this measures the stripe fan-out bandwidth
        # off the OSTs — the writer's own clean cache would serve the
        # re-read with zero RPCs (that path is bench_read's subject)
        fs2 = LustreClient(c, 1).mount()
        fh2 = fs2.open("/bench.bin")
        # one whole-file read: the LOV fans the stripe reads out in parallel
        _, tr = vtime(c, lambda: fs2.read(fh2, SIZE, offset=0))
        fs2.close(fh2)
        wbw = SIZE / tw / 1e6
        rbw = SIZE / tr / 1e6
        out[cnt] = {"write_MBps": round(wbw, 1), "read_MBps": round(rbw, 1),
                    "write_s": tw, "read_s": tr}
        rows.append([cnt, f"{wbw:.0f}", f"{rbw:.0f}",
                     f"{wbw / out[1]['write_MBps']:.2f}x" if 1 in out
                     else "1.00x"])
    table("striping throughput vs stripe_count (8 MiB file, qswnal)",
          ["stripes", "write MB/s", "read MB/s", "scaling"], rows)
    save("striping", out)
    return out


if __name__ == "__main__":
    run()
