"""Shared benchmark utilities: virtual-time measurement + result I/O."""
from __future__ import annotations

import json
import os
import time


RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "bench")


def save(name: str, payload: dict):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def vtime(cluster, fn):
    """Run fn, return (result, virtual seconds elapsed)."""
    t0 = cluster.now
    out = fn()
    return out, cluster.now - t0


def table(title: str, headers: list[str], rows: list[list]):
    print(f"\n== {title} ==")
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows))
              for i, h in enumerate(headers)]
    print("  " + "  ".join(str(h).ljust(w) for h, w in zip(headers, widths)))
    for r in rows:
        print("  " + "  ".join(str(v).ljust(w) for v, w in zip(r, widths)))
