"""Checkpoint save/restore bandwidth (the framework's flagship workload).

Sweeps parallel writer count and stripe count for a 16 MiB model state;
reports virtual-time bandwidth + the parity-coding overhead (ch. 15).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import save, table, vtime
from repro.ckpt import CheckpointManager
from repro.core import LustreCluster
from repro.fsio import LustreClient


def state(n_leaves=16, leaf_kb=1024):
    rng = np.random.default_rng(0)
    return {f"layer{i:02d}": rng.standard_normal(
        leaf_kb * 256).astype(np.float32) for i in range(n_leaves)}


def run() -> dict:
    out = {}
    tree = state()
    total = sum(v.nbytes for v in tree.values())
    rows = []
    for writers, stripes, parity in [(1, 1, False), (1, 4, False),
                                     (2, 4, False), (4, 4, False),
                                     (4, 8, False), (4, 4, True)]:
        c = LustreCluster(osts=8, mdses=1, clients=max(writers, 1),
                          commit_interval=512)
        ws = [LustreClient(c, i).mount() for i in range(writers)]
        cm = CheckpointManager(ws, stripe_count=stripes,
                               stripe_size=1 << 20, parity=parity)
        _, t_save = vtime(c, lambda: cm.save(1, tree))
        _, t_rest = vtime(c, lambda: cm.restore(1))
        key = f"w{writers}_s{stripes}{'_p' if parity else ''}"
        out[key] = {"writers": writers, "stripes": stripes,
                    "parity": parity,
                    "save_MBps": round(total / t_save / 1e6, 1),
                    "restore_MBps": round(total / t_rest / 1e6, 1)}
        rows.append([writers, stripes, parity,
                     f"{out[key]['save_MBps']:.0f}",
                     f"{out[key]['restore_MBps']:.0f}"])
    table("checkpoint bandwidth (16 MiB state, 8 OSTs)",
          ["writers", "stripes", "parity", "save MB/s", "restore MB/s"],
          rows)
    save("checkpoint", out)
    return out


if __name__ == "__main__":
    run()
