"""Untar-shaped metadata burst: the write-back cache trajectory (ch. 17).

Workload: what `tar -x` does to a filesystem — a small directory tree,
then a burst of file creates each followed by a data write, a close and
a mode-fixing setattr. Modes:

  * cold — wbc_auto off: every create is an open intent, every chmod a
    reint, every close an MDS close (the seed shape: one-ish RPC per
    metadata op);
  * wbc  — wbc_auto on: the first metadata write under the tree enters
    write-back mode (§6.5.2), ops apply to the local shadow, and the
    final sync reintegrates everything in `wbc_batch`-sized reint_batch
    RPCs (§6.5.3, the InterMezzo property §2.4).

`untar_metrics()` feeds the `untar` section of BENCH_rpc.json; the gate
in benchmarks/run.py enforces: the WBC burst issues <= N/8 MDS reint
RPCs, >= 8x fewer reint RPCs than cold (the ISSUE-6 acceptance bar),
and no regression vs the committed WBC reint-RPC count.
"""
from __future__ import annotations

from benchmarks.common import save, table
from repro.core import LustreCluster
from repro.fsio import LustreClient

N_DIRS = 10
N_FILES = 1000
FILE_BYTES = 512


def reint_rpcs(c) -> int:
    """MDS namespace-update RPCs: single reints + WBC batch flushes."""
    cnt = c.stats.counters
    return cnt.get("rpc.mds.reint", 0) + cnt.get("rpc.mds.reint_batch", 0)


def md_rpcs(c) -> int:
    return sum(n for k, n in c.stats.counters.items()
               if k.startswith("rpc.mds."))


def untar(fs):
    fs.mkdir("/untar")
    for d in range(N_DIRS):
        fs.mkdir(f"/untar/d{d}")
    data = b"t" * FILE_BYTES
    for i in range(N_FILES):
        path = f"/untar/d{i % N_DIRS}/f{i:04d}"
        fh = fs.creat(path)
        fs.write(fh, data)
        fs.close(fh)
        fs.setattr(path, mode=0o644)         # tar fixes the mode up
    fs.sync()                                # tar exits: barrier
    fs.disable_wbc()


def untar_metrics() -> dict:
    out = {}
    for mode, auto in (("cold", False), ("wbc", True)):
        c = LustreCluster(osts=1, mdses=1, clients=1,
                          commit_interval=8192, wbc_auto=auto)
        fs = LustreClient(c).mount()
        r0, m0, t0 = reint_rpcs(c), md_rpcs(c), c.now
        untar(fs)
        out[mode] = {
            "reint_rpcs": reint_rpcs(c) - r0,
            "md_rpcs": md_rpcs(c) - m0,
            "vtime_s": round(c.now - t0, 6),
            "files": N_FILES,
            "dirs": N_DIRS,
        }
        if auto:
            cnt = c.stats.counters
            out[mode]["wbc_grants"] = cnt.get("wbc.granted", 0)
            out[mode]["flushes"] = cnt.get("wbc.flush", 0)
            out[mode]["local_updates"] = cnt.get("wbc.local_update", 0)
    out["reint_reduction"] = round(
        out["cold"]["reint_rpcs"] / max(1, out["wbc"]["reint_rpcs"]), 2)
    out["md_reduction"] = round(
        out["cold"]["md_rpcs"] / max(1, out["wbc"]["md_rpcs"]), 2)
    return out


def run() -> dict:
    out = untar_metrics()
    table(f"untar burst: {N_DIRS} dirs + {N_FILES} creates + setattrs",
          ["mode", "reint RPCs", "all MDS RPCs", "vtime s"],
          [[m, out[m]["reint_rpcs"], out[m]["md_rpcs"],
            f"{out[m]['vtime_s']:.4f}"] for m in ("cold", "wbc")])
    save("untar", out)
    assert out["wbc"]["reint_rpcs"] <= N_FILES // 8, out["wbc"]
    assert out["reint_reduction"] >= 8.0, out["reint_reduction"]
    return out


if __name__ == "__main__":
    run()
