"""NRS policies under multi-client contention (ISSUE 1).

Two scenarios on a single shared OST:
  * fairness — a heavy client bursts 32 writes while a light client needs
    one; CRR keeps the light client's latency flat while FIFO makes it
    wait behind the whole backlog;
  * TBF QoS — a rate rule throttles one tenant to `rate` requests/sec
    while the other tenant runs at full speed.
"""
from __future__ import annotations

from benchmarks.common import save, table, vtime
from repro.core import LustreCluster

SVC_COST = 2e-3          # make the OST CPU the bottleneck, not the links


def _osc(c, idx):
    return c.make_oscs(c.make_client_rpc(idx), writeback=False)[0]


def fairness(policy: str) -> dict:
    c = LustreCluster(osts=1, mdses=1, clients=2, commit_interval=256,
                      nrs_policy=policy)
    c.ost_targets[0].service.cpu_cost = SVC_COST
    heavy, light = _osc(c, 0), _osc(c, 1)
    h_oid = heavy.create(0)["oid"]
    l_oid = light.create(0)["oid"]
    out = {}

    def l_one():
        t0 = c.now
        light.write(0, l_oid, 0, b"l" * 64)
        out["light_latency_ms"] = (c.now - t0) * 1e3
    t0 = c.now
    c.sim.parallel(
        [(lambda i=i: heavy.write(0, h_oid, i * 64, b"h" * 64))
         for i in range(32)] + [l_one])
    out["makespan_ms"] = (c.now - t0) * 1e3
    return out


def tbf() -> dict:
    c = LustreCluster(osts=1, mdses=1, clients=2, commit_interval=256)
    slow, fast = _osc(c, 0), _osc(c, 1)
    c.lctl("nrs", "OST0000", "tbf",
           {"rate": 1e9, "burst": 1.0, "rules": {slow.rpc.uuid: 100.0}})
    s_oid = slow.create(0)["oid"]
    f_oid = fast.create(0)["oid"]
    n = 50

    def run(osc, oid):
        for i in range(n):
            osc.write(0, oid, i * 64, b"x" * 64)
    _, t_fast = vtime(c, lambda: run(fast, f_oid))
    _, t_slow = vtime(c, lambda: run(slow, s_oid))
    return {"rate_limit_rps": 100.0,
            "throttled_rps": round(n / t_slow, 1),
            "unthrottled_rps": round(n / t_fast, 1),
            "throttled_s": t_slow, "unthrottled_s": t_fast}


def run() -> dict:
    fair = {p: fairness(p) for p in ("fifo", "crr", "orr")}
    qos = tbf()
    rows = [[p, f"{v['light_latency_ms']:.1f}", f"{v['makespan_ms']:.1f}"]
            for p, v in fair.items()]
    table("light-client latency vs heavy 32-write burst (1 OST)",
          ["policy", "light lat ms", "makespan ms"], rows)
    table("TBF QoS: 100 req/s rule on one tenant",
          ["tenant", "req/s"],
          [["throttled", qos["throttled_rps"]],
           ["unthrottled", qos["unthrottled_rps"]]])
    out = {"fairness": fair, "tbf": qos}
    save("nrs", out)
    assert fair["crr"]["light_latency_ms"] < \
        fair["fifo"]["light_latency_ms"] / 3
    assert qos["throttled_rps"] <= 110.0
    return out


if __name__ == "__main__":
    run()
