"""DLM behaviour under I/O patterns (paper ch. 7).

  (a) extent-growth policy: sequential writes take ONE lock RPC (the grant
      grows to cover the object) vs exact-extent locking (1 RPC per write);
  (b) shared-read scaling: N clients take PR locks concurrently (compatible
      modes — no callbacks); then one writer arrives and every reader gets
      a blocking AST;
  (c) lock-cache hit ratio under random vs sequential access.
"""
from __future__ import annotations

from benchmarks.common import save, table, vtime
from repro.core import LustreCluster

N_IO = 128


def run() -> dict:
    out = {}

    # ------------------------------------------------- (a) extent policy
    c = LustreCluster(osts=1, mdses=1, clients=1, commit_interval=512)
    rpc = c.make_client_rpc(0)
    osc = c.make_oscs(rpc, writeback=False)[0]
    oid = osc.create(0)["oid"]
    r0 = c.stats.counters.get("rpc.ost.ldlm_enqueue", 0)

    def seq_io():
        for i in range(N_IO):
            osc.write(0, oid, i * 64, b"x" * 64)
    _, t_grow = vtime(c, seq_io)
    grow_rpcs = c.stats.counters["rpc.ost.ldlm_enqueue"] - r0

    # exact-extent: defeat growth by bypassing the cache every time
    oid2 = osc.create(0)["oid"]
    r0 = c.stats.counters.get("rpc.ost.ldlm_enqueue", 0)

    def exact_io():
        for i in range(N_IO):
            osc.locks.enqueue(("ext", 0, oid2), "PW",
                              (i * 64, (i + 1) * 64), use_cache=False)
            osc.write(0, oid2, i * 64, b"x" * 64, lock=False)
    _, t_exact = vtime(c, exact_io)
    exact_rpcs = c.stats.counters["rpc.ost.ldlm_enqueue"] - r0
    out["extent_policy"] = {
        "grown_lock_rpcs": grow_rpcs, "exact_lock_rpcs": exact_rpcs,
        "grown_s": t_grow, "exact_s": t_exact,
        "rpc_reduction": f"{exact_rpcs}x -> {grow_rpcs}x"}

    # ----------------------------------------------- (b) readers+writer
    c2 = LustreCluster(osts=1, mdses=1, clients=8, commit_interval=512)
    oscs = [c2.make_oscs(c2.make_client_rpc(i), writeback=False)[0]
            for i in range(8)]
    oid = oscs[0].create(0)["oid"]
    oscs[0].write(0, oid, 0, b"d" * 4096)
    for o in oscs:
        o.read(0, oid, 0, 4096)
    asts_before = c2.stats.counters.get("dlm.blocking_ast", 0)
    oscs[0].write(0, oid, 0, b"w" * 16)        # writer revokes all readers
    asts = c2.stats.counters.get("dlm.blocking_ast", 0) - asts_before
    out["read_share_write_revoke"] = {"readers": 8, "blocking_asts": asts}

    # ------------------------------------------------- (c) cache hits
    c3 = LustreCluster(osts=1, mdses=1, clients=1, commit_interval=512)
    osc3 = c3.make_oscs(c3.make_client_rpc(0), writeback=False)[0]
    oid = osc3.create(0)["oid"]
    osc3.write(0, oid, 0, b"z" * (64 * N_IO))
    h0 = c3.stats.counters.get("dlm.client_match", 0)
    for i in range(N_IO):
        osc3.read(0, oid, (i * 7919) % (63 * N_IO), 1)   # random-ish
    hits = c3.stats.counters["dlm.client_match"] - h0
    out["cache"] = {"random_reads": N_IO, "lock_cache_hits": hits,
                    "hit_rate": round(hits / N_IO, 3)}

    table("DLM (ch. 7)", ["metric", "value"], [
        ["sequential-write lock RPCs (grown extents)", grow_rpcs],
        ["sequential-write lock RPCs (exact extents)", exact_rpcs],
        ["blocking ASTs to revoke 8 readers", asts],
        ["lock-cache hit rate (random reads)", out["cache"]["hit_rate"]],
    ])
    save("dlm", out)
    return out


if __name__ == "__main__":
    run()
