"""Collaborative-cache read scaling (paper ch. 5.5, 16).

The paper's claim: "a read cache shared between a subset of the client
systems ... enabling enormous scalability benefits for mostly read-only
situations" — the cluster-boot workload. N clients read the same 4 MiB
file; we sweep the number of caching OSTs (0 = every read hits the target
OST) and report aggregate virtual-time throughput + target-OST byte load.
"""
from __future__ import annotations

from benchmarks.common import save, table, vtime
from repro.core import LustreCluster
from repro.core import cobd as cobd_mod
from repro.fsio import LustreClient

FILE = 4 << 20
N_CLIENTS = 8


def run() -> dict:
    out = {}
    rows = []
    for n_caches in (0, 1, 2, 4):
        c = LustreCluster(osts=1, mdses=1,
                          clients=N_CLIENTS + n_caches,
                          commit_interval=512)
        writer = LustreClient(c, 0).mount()
        fh = writer.creat("/boot.img", stripe_count=1)
        writer.write(fh, bytes(1 << 16) * 64)
        writer.close(fh)
        c.stats.reset()
        for k in range(n_caches):
            cobd_mod.make_caching_node(
                c, f"client{N_CLIENTS + k}", c.ost_targets[0],
                f"COBD{k:02d}")
        readers = [LustreClient(c, i).mount() for i in range(N_CLIENTS)]
        handles = [r.open("/boot.img") for r in readers]

        def read_all():
            # all clients read the whole file "simultaneously"
            c.sim.parallel([
                (lambda r=r, h=h: r.read(h, FILE, offset=0))
                for r, h in zip(readers, handles)])
        _, t = vtime(c, read_all)
        agg = N_CLIENTS * FILE / t / 1e6
        ost_bytes = c.stats.bytes.get("ost.read", 0)
        cobd_bytes = c.stats.bytes.get("cobd.served", 0)
        out[n_caches] = {
            "aggregate_MBps": round(agg, 1), "virtual_s": t,
            "target_ost_MB": round(ost_bytes / 1e6, 2),
            "cobd_served_MB": round(cobd_bytes / 1e6, 2),
            "referrals": c.stats.counters.get("ost.referral", 0)}
        rows.append([n_caches, f"{agg:.0f}",
                     f"{ost_bytes/1e6:.1f}", f"{cobd_bytes/1e6:.1f}",
                     out[n_caches]["referrals"]])
    base = out[0]["aggregate_MBps"]
    for r, k in zip(rows, (0, 1, 2, 4)):
        r.append(f"{out[k]['aggregate_MBps']/base:.2f}x")
    table(f"COBD read scaling: {N_CLIENTS} clients x 4 MiB",
          ["caches", "agg MB/s", "OST MB", "COBD MB", "referrals",
           "scaling"], rows)
    save("cobd", out)
    return out


if __name__ == "__main__":
    run()
