"""Parity kernel roofline placement (ch. 15 / kernels/parity.py).

The XOR kernel is pure VPU lane work: for K data stripes it reads K*N
bytes, writes N, and performs (K-1)*N/4 int32 XOR ops — arithmetic
intensity (K-1)/((K+1)*4) ops/byte, firmly memory-bound on TPU v5e
(819 GB/s HBM). We report the analytic roofline numbers per K and verify
kernel == oracle on large blocks (interpret mode, correctness only —
wall-clock here is CPU interpret overhead, not the TPU number).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import save, table
from repro.kernels import parity, ref
from repro.launch.mesh import HBM_BW

N = 1 << 20            # 4 MiB of int32 lanes per stripe


def run() -> dict:
    out = {}
    rows = []
    rng = np.random.default_rng(0)
    for K in (2, 4, 8, 16):
        blocks = jnp.asarray(rng.integers(-2**31, 2**31, size=(K, N),
                                          dtype=np.int32))
        p = parity.xor_parity(blocks, block=1 << 14, interpret=True)
        assert (np.asarray(p) == np.asarray(
            ref.xor_parity_ref(blocks))).all()
        bytes_moved = (K + 1) * N * 4
        t_tpu = bytes_moved / HBM_BW
        gbps = K * N * 4 / t_tpu / 1e9     # effective data-stripe rate
        ai = (K - 1) / ((K + 1) * 4)
        out[K] = {"stripes": K, "bytes_moved": bytes_moved,
                  "tpu_roofline_s": t_tpu,
                  "effective_GBps": round(gbps, 1),
                  "arith_intensity_ops_per_byte": round(ai, 4),
                  "bound": "memory"}
        rows.append([K, f"{bytes_moved >> 20} MiB", f"{t_tpu*1e6:.0f} us",
                     f"{gbps:.0f}", f"{ai:.3f}"])
    table("XOR parity kernel: analytic TPU v5e roofline (verified vs ref)",
          ["K stripes", "HBM traffic", "roofline t", "eff GB/s",
           "ops/byte"], rows)
    save("parity", out)
    return out


if __name__ == "__main__":
    run()
