"""Parity kernel roofline placement (ch. 15 / kernels/parity.py).

The XOR kernel is pure VPU lane work: for K data stripes it reads K*N
bytes, writes N, and performs (K-1)*N/4 int32 XOR ops — arithmetic
intensity (K-1)/((K+1)*4) ops/byte, firmly memory-bound on TPU v5e
(819 GB/s HBM). We report the analytic roofline numbers per K and verify
kernel == oracle on large blocks (interpret mode, correctness only —
wall-clock here is CPU interpret overhead, not the TPU number).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import save, table
from repro.kernels import parity, ref
from repro.launch.mesh import HBM_BW

N = 1 << 20            # 4 MiB of int32 lanes per stripe

_r5_cache: dict = {}


def _payload(n: int, seed: int = 5) -> bytes:
    rng = np.random.default_rng(seed)
    return rng.integers(1, 256, n, dtype=np.uint8).tobytes()


def raid5_metrics() -> dict:
    """End-to-end raid5 section (ISSUE-8) for BENCH_rpc.json:

      * degraded-read overhead vs a clean cold read (byte-identical
        reconstruction from surviving stripes + the Pallas parity
        kernel), admin-deactivated dead OST so the number is the
        reconstruction cost, not the timeout-discovery walk;
      * rebuild throughput regenerating the dead OST's objects onto the
        spare (cold maintenance client via lctl);
      * client p99 while a rebuild runs concurrently — no-rebuild
        baseline vs rebuild under the two-level tbf_orr throttle vs an
        unthrottled FIFO rebuild.

    Module-cached so `--only parity` and the BENCH_rpc gate share one
    run."""
    if _r5_cache:
        return _r5_cache
    from repro.core import LustreCluster
    from repro.core.metrics import merge_jobid_histograms
    from repro.fsio import LustreClient

    out: dict = {}
    size, ssz = 768 << 10, 64 << 10
    data = _payload(size)
    c = LustreCluster(osts=4, mdses=1, clients=3, spare_osts=1,
                      commit_interval=256)
    fs = LustreClient(c, 0).mount()
    fh = fs.creat("/f", stripe_count=3, stripe_size=ssz,
                  stripe_offset=0, pattern="raid5")
    fs.write(fh, data, offset=0)
    fs.close(fh)
    for t in c.ost_targets:
        t.commit()

    def cold_read(idx, degraded):
        r = LustreClient(c, idx).mount()
        if degraded:
            r.deactivate_ost("OST0001")
        rpc0 = c.stats.counters.get("rpc.ost.read", 0)
        t0 = c.now
        f = r.open("/f")
        got = r.read(f, size, offset=0)
        r.close(f)
        return {"identical": got == data,
                "vtime_s": round(c.now - t0, 6),
                "ost_read_rpcs":
                    c.stats.counters.get("rpc.ost.read", 0) - rpc0}

    out["clean"] = cold_read(1, degraded=False)
    c.fail_node("ost1")
    out["degraded"] = cold_read(2, degraded=True)
    out["degraded"]["overhead_x"] = round(
        out["degraded"]["vtime_s"] / max(1e-9, out["clean"]["vtime_s"]), 2)
    out["degraded"]["reconstructed_units"] = \
        c.stats.counters.get("lov.reconstruct_unit", 0)

    # rebuild throughput: fresh maintenance client (cold caches) so the
    # reconstruction reads really cross the wire
    t0 = c.now
    rep = c.lctl("rebuild", "OST0001", c.spare_uuids[0])
    rb_vt = c.now - t0
    out["rebuild"] = {
        "files": rep["rebuilt"], "bytes": rep["bytes"],
        "layout_swaps": rep["swapped"],
        "vtime_s": round(rb_vt, 6),
        "throughput_MBps": round(rep["bytes"] / max(1e-9, rb_vt) / 1e6, 2),
    }

    # --- client p99 with a concurrent rebuild: baseline / tbf / fifo ---
    def p99_run(mode: str) -> float:
        cc = LustreCluster(osts=4, mdses=1, clients=3, spare_osts=1,
                           commit_interval=256)
        for t in cc.ost_targets + cc.spare_targets:
            t.service.cpu_cost = 2e-3        # OST service is the choke
        w = LustreClient(cc, 0).mount()
        w.mkdir("/r5")
        fdata = _payload(192 << 10, seed=6)
        for i in range(8):
            f = w.creat(f"/r5/f{i}", stripe_count=3,
                        stripe_size=16 << 10, stripe_offset=0,
                        pattern="raid5")
            w.write(f, fdata, offset=0)
            w.close(f)
        for t in cc.ost_targets:
            t.commit()
        if mode == "tbf":
            cc.lctl("rebuild_throttle", 200.0, 2.0)
        cc.fail_node("ost1")
        app = LustreClient(cc, 1).mount()
        app.set_jobid("app")
        af = app.creat("/app.bin", stripe_count=2, stripe_size=16 << 10,
                       stripe_offset=2)       # lives on the live OSTs
        maint = LustreClient(cc, 2).mount()
        chunk = _payload(4 << 10, seed=7)
        nonlocal_off = [0]

        def app_burst():
            # small write + fsync per op: every op is a real wire RPC (a
            # re-read loop would be served from the clean cache and
            # measure nothing)
            for _ in range(6):
                app.write(af, chunk, offset=nonlocal_off[0])
                app.fsync(af)
                nonlocal_off[0] += len(chunk)

        def rebuild_step():
            # one file per round (the batch-paced rebuild): each burst
            # contends with a live slice of rebuild traffic instead of
            # replaying entirely before/after it
            maint.rebuild_ost("OST0001", cc.spare_uuids[0], limit=1)

        # rebuild first in thunk order: virtual-clock parallel replays
        # thunks from one instant, and the service busy chains a thunk
        # observes are those already laid down — the app must observe
        # the rebuild's occupancy, not the reverse
        for _ in range(8):
            thunks = ([rebuild_step] if mode != "none" else []) \
                + [app_burst]
            cc.sim.parallel(thunks)
        hist = merge_jobid_histograms(
            [cc.sim.metrics.target_summary(t.uuid)
             for t in cc.ost_targets + cc.spare_targets])
        return hist["app"]["p99_s"]

    base, tbf, fifo = p99_run("none"), p99_run("tbf"), p99_run("fifo")
    out["throttle"] = {
        "baseline_p99_s": base, "tbf_p99_s": tbf, "fifo_p99_s": fifo,
        "tbf_p99_ratio": round(tbf / max(1e-9, base), 3),
        "fifo_p99_ratio": round(fifo / max(1e-9, base), 3),
    }
    _r5_cache.update(out)
    return out


def run() -> dict:
    out = {}
    rows = []
    rng = np.random.default_rng(0)
    for K in (2, 4, 8, 16):
        blocks = jnp.asarray(rng.integers(-2**31, 2**31, size=(K, N),
                                          dtype=np.int32))
        p = parity.xor_parity(blocks, block=1 << 14, interpret=True)
        assert (np.asarray(p) == np.asarray(
            ref.xor_parity_ref(blocks))).all()
        bytes_moved = (K + 1) * N * 4
        t_tpu = bytes_moved / HBM_BW
        gbps = K * N * 4 / t_tpu / 1e9     # effective data-stripe rate
        ai = (K - 1) / ((K + 1) * 4)
        out[K] = {"stripes": K, "bytes_moved": bytes_moved,
                  "tpu_roofline_s": t_tpu,
                  "effective_GBps": round(gbps, 1),
                  "arith_intensity_ops_per_byte": round(ai, 4),
                  "bound": "memory"}
        rows.append([K, f"{bytes_moved >> 20} MiB", f"{t_tpu*1e6:.0f} us",
                     f"{gbps:.0f}", f"{ai:.3f}"])
    table("XOR parity kernel: analytic TPU v5e roofline (verified vs ref)",
          ["K stripes", "HBM traffic", "roofline t", "eff GB/s",
           "ops/byte"], rows)
    r5 = raid5_metrics()
    out["raid5"] = r5
    table("raid5 end-to-end (ISSUE-8)",
          ["metric", "value"],
          [["degraded read identical", r5["degraded"]["identical"]],
           ["degraded overhead", f"{r5['degraded']['overhead_x']}x"],
           ["rebuild MB/s (virtual)", r5["rebuild"]["throughput_MBps"]],
           ["app p99 ratio (tbf)", r5["throttle"]["tbf_p99_ratio"]],
           ["app p99 ratio (fifo)", r5["throttle"]["fifo_p99_ratio"]]])
    save("parity", out)
    return out


if __name__ == "__main__":
    run()
