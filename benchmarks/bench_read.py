"""Sequential-read RPC trajectory: OSC clean cache + readahead (ISSUE 4).

Workload: a writer lays down an 8 MiB file striped over 4 OSTs, a COLD
second client then reads it sequentially in 64 KiB chunks. Three passes:

  * no_readahead — clean cache on, readahead off: every chunk is a miss
    (one vectored OST_READ each);
  * readahead    — the per-handle sequential detector batches the misses
    into ~1 MiB vectored windows (one OST_READ per stripe object per
    window);
  * warm re-read — the same client reads the file again: everything is
    lock-covered cache, ZERO OST RPCs.

`seq_read_metrics()` feeds the `seq_read` section of BENCH_rpc.json
(the regression gate in benchmarks/run.py): readahead must stay >= 4x
cheaper than the no-readahead cold pass, the warm re-read must stay at
zero OST_READs, and the readahead RPC count must not regress vs the
committed baseline.
"""
from __future__ import annotations

from benchmarks.common import save, table
from repro.core import LustreCluster
from repro.fsio import LustreClient

SIZE = 8 << 20
CHUNK = 64 << 10
STRIPES = 4


def _ost_reads(c):
    return c.stats.counters.get("rpc.ost.read", 0)


def _ost_rpcs(c):
    return sum(n for k, n in c.stats.counters.items()
               if k.startswith("rpc.ost."))


def seq_read_metrics() -> dict:
    out = {}
    for mode, ra_pages in (("no_readahead", 0), ("readahead", 256)):
        c = LustreCluster(osts=4, mdses=1, clients=2, commit_interval=512,
                          readahead_pages=ra_pages)
        w = LustreClient(c, 0).mount()
        fh = w.creat("/read.bin", stripe_count=STRIPES,
                     stripe_size=1 << 20)
        w.write(fh, bytes(CHUNK) * (SIZE // CHUNK))
        w.fsync(fh)
        r = LustreClient(c, 1).mount()            # cold client cache
        fh2 = r.open("/read.bin")
        base_reads, t0 = _ost_reads(c), c.now
        for _ in range(SIZE // CHUNK):
            r.read(fh2, CHUNK)
        hits = c.stats.counters.get("osc.cache_hit", 0)
        misses = c.stats.counters.get("osc.cache_miss", 0)
        out[mode] = {
            "ost_read_rpcs": _ost_reads(c) - base_reads,
            "read_vtime_s": round(c.now - t0, 6),
            "cache_hit_rate": round(hits / (hits + misses), 4)
            if hits + misses else 0.0,
            "bytes": SIZE,
        }
        if mode == "readahead":
            # warm pass: the whole file is lock-covered clean cache
            base_reads, base_all = _ost_reads(c), _ost_rpcs(c)
            fh2.pos = 0
            for _ in range(SIZE // CHUNK):
                r.read(fh2, CHUNK)
            out["warm_reread_ost_reads"] = _ost_reads(c) - base_reads
            out["warm_reread_ost_rpcs"] = _ost_rpcs(c) - base_all
    n, ra = out["no_readahead"], out["readahead"]
    out["rpc_reduction"] = round(
        n["ost_read_rpcs"] / max(1, ra["ost_read_rpcs"]), 2)
    return out


def run() -> dict:
    out = seq_read_metrics()
    rows = [[m, out[m]["ost_read_rpcs"], out[m]["cache_hit_rate"],
             f"{out[m]['read_vtime_s']:.4f}"]
            for m in ("no_readahead", "readahead")]
    rows.append(["warm re-read", out["warm_reread_ost_reads"], 1.0, "-"])
    table(f"sequential read, {SIZE >> 20} MiB / {CHUNK >> 10} KiB chunks "
          f"({STRIPES} stripes)",
          ["mode", "OST_READ RPCs", "hit rate", "vtime s"], rows)
    save("read", out)
    assert out["rpc_reduction"] >= 4.0, out["rpc_reduction"]
    assert out["warm_reread_ost_reads"] == 0
    assert out["warm_reread_ost_rpcs"] == 0
    return out


if __name__ == "__main__":
    run()
