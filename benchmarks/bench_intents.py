"""Intent locks vs 2-RPC metadata + WBC batching (paper ch. 7.5, 17).

Measures RPC counts + virtual latency for:
  (a) stat of an uncached file: intent getattr_lock = 1 RPC vs the
      classic lookup-then-getattr = 2 RPCs;
  (b) create-heavy burst: client-server mode (1 intent RPC per create) vs
      metadata write-back caching (0 RPCs, one reint_batch at flush).
"""
from __future__ import annotations

from benchmarks.common import save, table, vtime
from repro.core import LustreCluster
from repro.core.mds import ROOT_FID
from repro.fsio import LustreClient

N = 200


def run() -> dict:
    out = {}

    # ---------------------------------------------------------- (a) stat
    c = LustreCluster(osts=1, mdses=1, clients=1, commit_interval=256)
    fs = LustreClient(c).mount()
    for i in range(N):
        fs.creat(f"/f{i:04d}")
    mdc = fs.lmv.mdcs[0]

    def stat_intent():
        for i in range(N):
            mdc.getattr_lock(ROOT_FID, f"f{i:04d}")
    r0 = c.stats.counters.get("rpc.mds.ldlm_enqueue", 0)
    _, t_intent = vtime(c, stat_intent)
    n_intent = c.stats.counters["rpc.mds.ldlm_enqueue"] - r0

    def stat_2rpc():
        for i in range(N):
            # classic: lookup RPC (enqueue, no data) + getattr RPC
            lk, d = mdc.getattr_lock(ROOT_FID, f"f{i:04d}")
            mdc.getattr(tuple(d["attrs"]["fid"]))
    # invalidate lock caches so lookups go to the wire again
    mdc.locks.cancel_all()
    r0 = sum(v for k, v in c.stats.counters.items()
             if k.startswith("rpc.mds."))
    _, t_2rpc = vtime(c, stat_2rpc)
    n_2rpc = sum(v for k, v in c.stats.counters.items()
                 if k.startswith("rpc.mds.")) - r0
    out["stat"] = {"intent_rpcs": n_intent, "two_rpcs": n_2rpc,
                   "intent_s": t_intent, "two_rpc_s": t_2rpc,
                   "latency_ratio": round(t_2rpc / t_intent, 2)}

    # -------------------------------------------------------- (b) create
    c2 = LustreCluster(osts=1, mdses=1, clients=1, commit_interval=256)
    fs2 = LustreClient(c2).mount()
    fs2.mkdir("/cs")

    def create_cs():
        for i in range(N):
            fs2.lmv.open(fs2.resolve("/cs"), f"n{i}", flags="cw")
    r0 = sum(v for k, v in c2.stats.counters.items()
             if k.startswith("rpc.mds."))
    _, t_cs = vtime(c2, create_cs)
    n_cs = sum(v for k, v in c2.stats.counters.items()
               if k.startswith("rpc.mds.")) - r0

    fs2.mkdir("/wb")
    assert fs2.enable_wbc("/wb")
    root = fs2.resolve("/wb")

    def create_wb():
        for i in range(N):
            fs2.wbc.create(root, f"n{i}")
        fs2.wbc.flush()
    r0 = sum(v for k, v in c2.stats.counters.items()
             if k.startswith("rpc.mds."))
    _, t_wb = vtime(c2, create_wb)
    n_wb = sum(v for k, v in c2.stats.counters.items()
               if k.startswith("rpc.mds.")) - r0
    fs2.disable_wbc()
    out["create"] = {"client_server_rpcs": n_cs, "wbc_rpcs": n_wb,
                     "cs_s": t_cs, "wbc_s": t_wb,
                     "speedup": round(t_cs / max(t_wb, 1e-9), 1)}

    table(f"metadata: {N} ops (ch. 7.5 intents / ch. 17 WBC)",
          ["workload", "RPCs", "virtual s", "vs baseline"],
          [["stat (intent)", n_intent, f"{t_intent:.4f}", "1.0x"],
           ["stat (lookup+getattr)", n_2rpc, f"{t_2rpc:.4f}",
            f"{t_2rpc/t_intent:.1f}x slower"],
           ["create (client-server)", n_cs, f"{t_cs:.4f}", "1.0x"],
           ["create (write-back)", n_wb, f"{t_wb:.4f}",
            f"{t_cs/max(t_wb,1e-9):.1f}x faster"]])
    save("intents", out)
    return out


if __name__ == "__main__":
    run()
