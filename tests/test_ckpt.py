"""Checkpointing + data pipeline over the Lustre substrate."""
import numpy as np
import pytest

from repro.ckpt import CheckpointManager
from repro.core import LustreCluster
from repro.data import TokenDataset, TokenPipeline
from repro.fsio import LustreClient


def mk(osts=4, clients=2, parity=True, **kw):
    c = LustreCluster(osts=osts, mdses=1, clients=clients,
                      commit_interval=kw.pop("commit_interval", 32))
    writers = [LustreClient(c, i % clients).mount() for i in range(clients)]
    cm = CheckpointManager(writers, stripe_count=min(3, osts),
                           stripe_size=4096, parity=parity, **kw)
    return c, writers, cm


def tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"a": {"w": rng.standard_normal((32, 48)).astype(np.float32),
                  "b": rng.standard_normal(48).astype(np.float32)},
            "c": rng.integers(0, 100, 17).astype(np.int32)}


def test_save_restore_roundtrip():
    c, w, cm = mk()
    t = tree()
    cm.save(10, t)
    got, m = cm.restore()
    assert m["step"] == 10
    assert (got["a.w"] == t["a"]["w"]).all()
    assert (got["a.b"] == t["a"]["b"]).all()
    assert (got["c"] == t["c"]).all()
    assert got["c"].dtype == np.int32


def test_latest_picks_max_complete():
    c, w, cm = mk()
    cm.save(1, tree(1))
    cm.save(5, tree(5))
    cm.save(3, tree(3))
    assert cm.latest() == 5
    got, _ = cm.restore(3)
    assert (got["c"] == tree(3)["c"]).all()


def test_manifest_is_commit_record():
    """A step dir without MANIFEST (writer died mid-save) is invisible to
    restore and removed by cleanup."""
    c, w, cm = mk()
    cm.save(1, tree())
    fs = w[0]
    fs.mkdir_p("/ckpt/step_00000009")
    fh = fs.creat("/ckpt/step_00000009/partial.bin")
    fs.write(fh, b"junk" * 100)
    fs.close(fh)
    assert cm.latest() == 1
    removed = cm.cleanup_incomplete()
    assert removed == ["step_00000009"]
    assert not fs.exists("/ckpt/step_00000009")


def test_parity_reconstructs_lost_stripe():
    c, w, cm = mk()
    t = tree()
    cm.save(2, t)
    fs = w[0]
    ea = fs.lmv.getattr(fs.resolve("/ckpt/step_00000002/a.w.bin"),
                        want_ea=True)["ea"]["lov"]
    victim = ea["objects"][2]
    tgt = next(x for x in c.ost_targets if x.uuid == victim["ost"])
    tgt.obd.objects.pop((victim["group"], victim["oid"]))
    got, _ = cm.restore(2)
    assert (got["a.w"] == t["a"]["w"]).all()
    assert c.stats.counters["ckpt.stripe_reconstructed"] == 1


def test_no_parity_fails_on_lost_stripe():
    c, w, cm = mk(parity=False)
    cm.save(2, tree())
    fs = w[0]
    ea = fs.lmv.getattr(fs.resolve("/ckpt/step_00000002/a.w.bin"),
                        want_ea=True)["ea"]["lov"]
    victim = ea["objects"][0]
    tgt = next(x for x in c.ost_targets if x.uuid == victim["ost"])
    tgt.obd.objects.pop((victim["group"], victim["oid"]))
    # the writers' lock-covered clean caches would (correctly!) mask the
    # lost object — drop the locks so the restore reads cold
    for fs_ in w:
        for osc in fs_.lov.oscs:
            osc.locks.cancel_all()
    with pytest.raises(Exception):
        cm.restore(2)


def test_retain_deletes_old():
    c, w, cm = mk()
    for s in (1, 2, 3, 4, 5):
        cm.save(s, {"x": np.ones(4, np.float32)})
    cm.retain(2)
    assert cm.steps() == [4, 5]


def test_checkpoint_survives_ost_crash_during_save():
    """OST crashes mid-save: replay makes the save still complete."""
    c, w, cm = mk(commit_interval=10_000)
    t = tree()
    # crash an OST partway through by hooking the clock... simplest: save,
    # crash, then verify restore works because clients replay.
    cm.save(7, t)
    c.fail_node("ost1")
    c.restart_node("ost1")
    got, _ = cm.restore(7)
    assert (got["a.w"] == t["a"]["w"]).all()


# ------------------------------------------------------------- pipeline

def test_pipeline_deterministic_and_disjoint():
    c = LustreCluster(osts=4, mdses=1, clients=1, commit_interval=64)
    fs = LustreClient(c).mount()
    ds = TokenDataset(fs, vocab=500, seq_len=32, n_seqs=128,
                      stripe_count=4).build()
    pipes = [TokenPipeline(fs, ds, dp_rank=i, dp_size=4, batch_per_rank=4)
             for i in range(4)]
    seen = []
    for p in pipes:
        idx = p.indices_for(3)
        assert (p.batch_at(3) == p.batch_at(3)).all()
        seen.append(set(idx.tolist()))
    allidx = set().union(*seen)
    assert len(allidx) == sum(len(s) for s in seen)   # disjoint shards


def test_pipeline_epoch_reshuffles():
    c = LustreCluster(osts=2, mdses=1, clients=1, commit_interval=64)
    fs = LustreClient(c).mount()
    ds = TokenDataset(fs, vocab=500, seq_len=16, n_seqs=64).build()
    p = TokenPipeline(fs, ds, dp_rank=0, dp_size=1, batch_per_rank=8)
    e0 = [tuple(p.indices_for(s)) for s in range(p.per_epoch)]
    e1 = [tuple(p.indices_for(s + p.per_epoch)) for s in range(p.per_epoch)]
    assert sorted(sum(e0, ())) == sorted(sum(e1, ()))  # same coverage
    assert e0 != e1                                    # different order


def test_pipeline_tokens_match_dataset_bytes():
    c = LustreCluster(osts=2, mdses=1, clients=1, commit_interval=64)
    fs = LustreClient(c).mount()
    ds = TokenDataset(fs, vocab=500, seq_len=16, n_seqs=64, seed=3).build()
    p = TokenPipeline(fs, ds, dp_rank=0, dp_size=1, batch_per_rank=4)
    rng = np.random.default_rng(3)
    all_tokens = rng.integers(0, 500, size=(64, 16), dtype=np.int32)
    batch = p.batch_at(0)
    idx = p.indices_for(0)
    assert (batch == all_tokens[idx]).all()
