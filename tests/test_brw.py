"""Vectored BRW pipeline: niobuf coalescing, flow control, single-txn
server apply (ISSUE 1 tentpole, paper §4.5.6 + ch. 23.4)."""
import pytest

from repro.core import LustreCluster
from repro.core import lov as LV


def mk(**kw):
    c = LustreCluster(osts=4, mdses=1, clients=2, commit_interval=256, **kw)
    rpc = c.make_client_rpc(0)
    return c, rpc


def writes(c):
    return c.stats.counters.get("rpc.ost.write", 0)


def reads(c):
    return c.stats.counters.get("rpc.ost.read", 0)


# ------------------------------------------------------------ coalescing

def test_adjacent_dirty_extents_flush_as_one_rpc():
    c, rpc = mk()
    osc = c.make_oscs(rpc)[0]
    oid = osc.create(0)["oid"]
    for i in range(8):
        osc.write(0, oid, i * 4096, bytes([i]) * 4096)
    assert writes(c) == 0                      # all cached
    base = writes(c)
    osc.flush()
    assert writes(c) - base == 1               # ONE vectored OST_WRITE
    assert osc.read(0, oid, 0, 8 * 4096) == b"".join(
        bytes([i]) * 4096 for i in range(8))


def test_disjoint_extents_ride_one_rpc_as_niobufs():
    c, rpc = mk()
    osc = c.make_oscs(rpc)[0]
    oid = osc.create(0)["oid"]
    osc.write(0, oid, 0, b"a" * 100)
    osc.write(0, oid, 10_000, b"b" * 100)      # hole between extents
    osc.flush()
    assert writes(c) == 1
    assert c.stats.counters["osc.brw_write_niobufs"] == 2
    assert c.stats.counters["ost.brw_write_niobufs"] == 2
    assert osc.read(0, oid, 0, 100) == b"a" * 100
    assert osc.read(0, oid, 10_000, 100) == b"b" * 100
    assert osc.read(0, oid, 5_000, 10) == b"\0" * 10   # hole reads zeros


def test_overlapping_writes_merge_newest_wins():
    c, rpc = mk()
    osc = c.make_oscs(rpc)[0]
    oid = osc.create(0)["oid"]
    osc.write(0, oid, 0, b"x" * 100)
    osc.write(0, oid, 50, b"y" * 100)          # overlaps the tail
    assert len([d for d in osc.dirty if d.oid == oid]) == 1   # coalesced
    osc.flush()
    assert writes(c) == 1
    assert osc.read(0, oid, 0, 150) == b"x" * 50 + b"y" * 100


def test_max_pages_per_rpc_splits_vectors():
    c, rpc = mk()
    osc = c.make_oscs(rpc, max_pages_per_rpc=2)[0]
    oid = osc.create(0)["oid"]
    osc.write(0, oid, 0, b"z" * (8 * 4096))    # 8 pages, 2 per RPC
    osc.flush()
    assert writes(c) == 4


def test_max_rpcs_in_flight_windows_dispatch():
    c, rpc = mk()
    osc = c.make_oscs(rpc, max_pages_per_rpc=1, max_rpcs_in_flight=2)[0]
    oid = osc.create(0)["oid"]
    osc.write(0, oid, 0, b"w" * (6 * 4096))
    osc.flush()
    assert writes(c) == 6                      # correctness under windowing
    assert osc.read(0, oid, 0, 6 * 4096) == b"w" * (6 * 4096)


def test_legacy_mode_matches_seed_rpc_counts():
    c, rpc = mk(vectored_brw=False)
    osc = c.make_oscs(rpc)[0]
    oid = osc.create(0)["oid"]
    for i in range(8):
        osc.write(0, oid, i * 4096, bytes([i]) * 4096)
    osc.flush()
    assert writes(c) == 8                      # one RPC per dirty extent


# --------------------------------------------------------- server side

def test_niobuf_vector_is_one_transaction():
    c, rpc = mk()
    osc = c.make_oscs(rpc)[0]
    t = c.ost_targets[0]
    oid = osc.create(0)["oid"]
    osc.write(0, oid, 0, b"a" * 64)
    osc.write(0, oid, 1000, b"b" * 64)
    osc.write(0, oid, 2000, b"c" * 64)
    before = t.transno
    rl0 = len(osc.imp.replay_list)
    osc.flush()
    assert t.transno == before + 1             # single transno for 3 niobufs
    assert len(osc.imp.replay_list) == rl0 + 1   # single reply retained


def test_writev_crash_rolls_back_whole_vector():
    c, rpc = mk()
    osc = c.make_oscs(rpc)[0]
    t = c.ost_targets[0]
    oid = osc.create(0)["oid"]
    osc.write(0, oid, 0, b"base" * 16)
    osc.flush()
    t.commit()                                 # persist the base state
    osc.write(0, oid, 8, b"X" * 8)
    osc.write(0, oid, 200, b"Y" * 8)           # grows the object
    osc.flush()
    size_before = t.obd.getattr(0, oid)["size"]
    assert size_before == 208
    t.crash()                                  # lose the uncommitted vector
    a = t.obd.getattr(0, oid)
    assert a["size"] == 64                     # growth undone
    assert t.obd.read(0, oid, 0, 64) == b"base" * 16


# ------------------------------------------------------------- striped

def test_lov_write_is_one_vectored_rpc_per_stripe():
    c, rpc = mk()
    lov = c.make_lov(rpc)
    lsm = lov.create(stripe_count=4, stripe_size=1 << 16)
    data = bytes(range(256)) * 1024            # 256 KiB = 4 runs of 64 KiB
    lov.write(lsm, 0, data)
    lov.flush()
    assert writes(c) == 4                      # one OST_WRITE per stripe
    assert lov.read(lsm, 0, len(data)) == data


def test_lov_read_vectored_per_stripe():
    c, rpc = mk()
    lov = c.make_lov(rpc)
    lsm = lov.create(stripe_count=2, stripe_size=1 << 12)
    data = bytes(range(256)) * 64              # 16 KiB = 4 runs of 4 KiB
    lov.write(lsm, 0, data)
    lov.flush()
    base = reads(c)
    fresh = LV.Lov(c.make_oscs(c.make_client_rpc(1)))   # cold client cache
    assert fresh.read(lsm, 0, len(data)) == data
    # 2 stripe objects, 2 runs each -> 2 vectored OST_READs, not 4
    assert reads(c) - base == 2


def test_zero_length_io_is_a_noop():
    c, rpc = mk()
    lov = c.make_lov(rpc)
    lsm = lov.create(stripe_count=2, stripe_size=4096)
    before = dict(c.stats.counters)
    assert lov.write(lsm, 0, b"") == 0
    assert lov.read(lsm, 0, 0) == b""
    assert c.stats.counters.get("rpc.ost.write", 0) == \
        before.get("rpc.ost.write", 0)


def test_failed_flush_keeps_dirty_data():
    """A flush that fails (ENOSPC) must NOT discard the cached extents."""
    c = LustreCluster(osts=1, mdses=1, clients=2, commit_interval=256,
                      ost_capacity=8192)
    a = c.make_oscs(c.make_client_rpc(0))[0]
    oid = a.create(0)["oid"]
    a.write(0, oid, 0, b"g" * 512)             # cached under A's grant
    assert a.dirty_bytes == 512
    b = c.make_oscs(c.make_client_rpc(1), writeback=False)[0]
    b_oid = b.create(0)["oid"]
    b.write(0, b_oid, 0, b"f" * 8000)          # B fills the device
    with pytest.raises(Exception):
        a.flush()                              # ENOSPC at the server
    assert a.dirty_bytes == 512                # data survives the failure
    assert a.read(0, oid, 0, 512) == b"g" * 512   # served from cache


def test_write_through_flushes_stale_cache_first():
    """A write-through to a range with older cached data must not let the
    stale extent overwrite it on a later flush."""
    c, rpc = mk()
    osc = c.make_oscs(rpc)[0]
    oid = osc.create(0)["oid"]
    osc.write(0, oid, 0, b"AAAA")              # cached
    osc.grant = 1                              # next write won't fit grant
    osc.writev(0, oid, [(0, b"BBBB")])         # write-through, newer data
    osc.flush()                                # must NOT resurrect AAAA
    assert c.ost_targets[0].obd.read(0, oid, 0, 4) == b"BBBB"
    assert osc.read(0, oid, 0, 4) == b"BBBB"


def test_writev_respects_legacy_mode():
    c, rpc = mk(vectored_brw=False)
    osc = c.make_oscs(rpc, writeback=False)[0]
    oid = osc.create(0)["oid"]
    osc.writev(0, oid, [(0, b"a" * 64), (1000, b"b" * 64)])
    assert writes(c) == 2                      # one legacy RPC per run
    assert c.stats.counters.get("osc.brw_write_rpc", 0) == 0
    assert osc.read(0, oid, 0, 64) == b"a" * 64
