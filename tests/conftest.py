import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import pytest  # noqa: E402

from repro.core import LustreCluster  # noqa: E402
from repro.core import sanitize  # noqa: E402
from repro.fsio import LustreClient  # noqa: E402


@pytest.fixture(autouse=True)
def _sanitizer_guard():
    """Fail any test that produced runtime-sanitizer violations (no-op
    unless SIM_SANITIZE=1 or the test used sanitize.forced()).  Tests
    that stage violations on purpose wrap them in sanitize.capture()."""
    before = len(sanitize.state.violations)
    yield
    new = sanitize.state.violations[before:]
    assert not new, "runtime sanitizer violations:\n" + "\n".join(
        v.render() for v in new)


@pytest.fixture
def cluster():
    return LustreCluster(osts=4, mdses=2, clients=3, ost_failover=True,
                         commit_interval=16)


@pytest.fixture
def fs(cluster):
    return LustreClient(cluster).mount()


@pytest.fixture
def small_cluster():
    return LustreCluster(osts=2, mdses=1, clients=2, commit_interval=8)
