import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import pytest  # noqa: E402

from repro.core import LustreCluster  # noqa: E402
from repro.fsio import LustreClient  # noqa: E402


@pytest.fixture
def cluster():
    return LustreCluster(osts=4, mdses=2, clients=3, ost_failover=True,
                         commit_interval=16)


@pytest.fixture
def fs(cluster):
    return LustreClient(cluster).mount()


@pytest.fixture
def small_cluster():
    return LustreCluster(osts=2, mdses=1, clients=2, commit_interval=8)
