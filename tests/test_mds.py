"""MDS: namespace, intents, clustering, WBC (paper ch. 6, 17)."""
import pytest

from repro.core import LustreCluster
from repro.core import ptlrpc as R
from repro.core.mdc import WbcCache
from repro.core.mds import ROOT_FID, fhash


def mk(mdses=2, **kw):
    c = LustreCluster(osts=1, mdses=mdses, clients=2,
                      commit_interval=kw.pop("commit_interval", 16), **kw)
    rpc = c.make_client_rpc(0)
    return c, rpc, c.make_lmv(rpc)


def test_intent_open_is_one_rpc():
    c, rpc, lmv = mk(mdses=1)
    lmv.mdcs[0].statfs()                            # amortise connect
    base = sum(v for k, v in c.stats.counters.items()
               if k.startswith("rpc.mds."))
    lk, d = lmv.open(ROOT_FID, "f.txt", flags="cw")
    n = sum(v for k, v in c.stats.counters.items()
            if k.startswith("rpc.mds.")) - base
    assert n == 1                                   # lookup+create+open
    assert d["disposition"] == ["lookup", "create", "open"]


def test_fids_never_reused_and_unique():
    c, rpc, lmv = mk(mdses=1)
    fids = set()
    for i in range(20):
        lk, d = lmv.open(ROOT_FID, f"f{i}", flags="cw")
        fid = tuple(d["attrs"]["fid"])
        assert fid not in fids
        fids.add(fid)
    lmv.reint({"type": "unlink", "parent": ROOT_FID, "name": "f3"})
    lk, d = lmv.open(ROOT_FID, "f3", flags="cw")    # recreate same name
    assert tuple(d["attrs"]["fid"]) not in fids     # fresh fid


def test_negative_dentry_and_exclusive_create():
    c, rpc, lmv = mk(mdses=1)
    lk, d = lmv.getattr_lock(ROOT_FID, "ghost")
    assert d.get("status") == -2 and d.get("negative")
    lmv.open(ROOT_FID, "x", flags="cw")
    lk, d2 = lmv.open(ROOT_FID, "x", flags="cwx")   # O_EXCL
    assert d2["status"] == -17                      # EEXIST in the intent


def test_mkdir_lands_on_other_mds():
    c, rpc, lmv = mk(mdses=3)
    groups = set()
    for i in range(6):
        rep = lmv.reint({"type": "create", "parent": ROOT_FID,
                         "name": f"d{i}", "ftype": "dir"})
        groups.add(tuple(rep.data["fid"])[0])
    assert groups == {1, 2}                         # never on mds0 (§6.7.1.2)


def test_rename_and_link_cross_mds():
    c, rpc, lmv = mk(mdses=2)
    rep = lmv.reint({"type": "create", "parent": ROOT_FID, "name": "d",
                     "ftype": "dir"})
    dfid = tuple(rep.data["fid"])
    assert dfid[0] == 1
    lmv.open(ROOT_FID, "f", flags="cw")
    lmv.reint({"type": "rename", "src": ROOT_FID, "src_name": "f",
               "dst": dfid, "dst_name": "g"})
    assert "g" in lmv.readdir(dfid)["entries"]
    assert "f" not in lmv.readdir(ROOT_FID)["entries"]
    # dependency got recorded for the consistent cut
    assert any(d for _, d in c.mds_targets[0].dep_log)


def test_unlink_returns_ea_and_cookies():
    c, rpc, lmv = mk(mdses=1)
    lk, d = lmv.open(ROOT_FID, "f", flags="cw")
    fid = tuple(d["attrs"]["fid"])
    ea = {"lov": {"stripe_size": 4, "stripe_count": 1, "stripe_offset": 0,
                  "objects": [{"ost": "OST0000", "group": 0, "oid": 9}]}}
    lmv.mdc_for_fid(fid).reint({"type": "setattr", "fid": fid, "ea": ea})
    rep = lmv.reint({"type": "unlink", "parent": ROOT_FID, "name": "f"})
    assert rep.data["ea"]["lov"]["objects"][0]["oid"] == 9
    assert len(rep.data["cookies"]) == 1
    assert len(c.mds_targets[0].unlink_llog.pending()) == 1


def test_hardlink_nlink_and_last_unlink():
    c, rpc, lmv = mk(mdses=1)
    lk, d = lmv.open(ROOT_FID, "a", flags="cw")
    fid = tuple(d["attrs"]["fid"])
    lmv.reint({"type": "link", "parent": ROOT_FID, "name": "b", "fid": fid})
    assert lmv.getattr(fid)["attrs"]["nlink"] == 2
    r1 = lmv.reint({"type": "unlink", "parent": ROOT_FID, "name": "a"})
    assert "ea" not in (r1.data or {})             # not the last link
    assert lmv.getattr(fid)["attrs"]["nlink"] == 1


def test_directory_split_into_buckets():
    c = LustreCluster(osts=1, mdses=3, clients=1, commit_interval=32,
                      mds_split_threshold=32)
    rpc = c.make_client_rpc(0)
    lmv = c.make_lmv(rpc)
    rep = lmv.reint({"type": "create", "parent": ROOT_FID, "name": "big",
                     "ftype": "dir", "remote_ok": False, "fid": None})
    dfid = tuple(rep.data["fid"])
    for i in range(60):
        lmv.reint({"type": "create", "parent": dfid, "name": f"f{i:03d}",
                   "remote_ok": False})
    assert c.stats.counters.get("mds.dir_split") == 1
    rd = lmv.readdir(dfid)
    assert rd["buckets"] is not None
    assert len(rd["entries"]) == 60                 # merged view
    # lookups still resolve through the hash (maybe via redirect)
    lk, d = lmv.getattr_lock(dfid, "f007")
    assert d.get("status", 0) == 0 and d.get("attrs")


def test_wbc_batches_to_single_rpc():
    c, rpc, lmv = mk(mdses=1)
    wbc = WbcCache(lmv, ROOT_FID)
    assert wbc.acquire()
    for i in range(40):
        wbc.create(ROOT_FID, f"w{i}")
    base = c.stats.counters.get("rpc.mds.reint_batch", 0)
    wbc.flush()
    assert c.stats.counters["rpc.mds.reint_batch"] - base == 1
    assert len(lmv.readdir(ROOT_FID)["entries"]) == 40


def test_wbc_denied_under_contention():
    c, rpc, lmv = mk(mdses=1)
    rpc2 = c.make_client_rpc(1)
    lmv2 = c.make_lmv(rpc2)
    # two clients fighting over root -> contention counter rises
    for i in range(3):
        lmv.open(ROOT_FID, f"c1_{i}", flags="cw")
        lmv2.open(ROOT_FID, f"c2_{i}", flags="cw")
    wbc = WbcCache(lmv2, ROOT_FID)
    assert not wbc.acquire()                        # §6.5 switching policy


def test_wbc_flushes_on_subtree_lock_revocation():
    c, rpc, lmv = mk(mdses=1)
    rep = lmv.reint({"type": "create", "parent": ROOT_FID, "name": "mine",
                     "ftype": "dir", "remote_ok": False})
    dfid = tuple(rep.data["fid"])
    wbc = WbcCache(lmv, dfid)
    assert wbc.acquire()
    wbc.create(dfid, "pending1")
    wbc.create(dfid, "pending2")
    # another client touches the subtree -> blocking AST -> flush
    rpc2 = c.make_client_rpc(1)
    lmv2 = c.make_lmv(rpc2)
    lk, d = lmv2.getattr_lock(dfid, "pending1")
    assert d.get("status", 0) == 0                  # flushed + visible
    assert not wbc.records


def test_mtime_on_ost_flag_set_on_open_write():
    c, rpc, lmv = mk(mdses=1)
    lk, d = lmv.open(ROOT_FID, "f", flags="cw")
    assert d["attrs"]["mtime_on_ost"] or True      # set after reply
    fid = tuple(d["attrs"]["fid"])
    assert lmv.getattr(fid)["attrs"]["mtime_on_ost"]
    lmv.close(fid, d["open_handle"], size=123, mtime=9.9)
    a = lmv.getattr(fid)["attrs"]
    assert not a["mtime_on_ost"] and a["size"] == 123


def test_fhash_stable_distribution():
    ways = 4
    counts = [0] * ways
    for i in range(1000):
        counts[fhash(f"file{i}", ways)] += 1
    assert min(counts) > 150                        # roughly uniform
