"""Client filesystem integration (paper ch. 9, 28)."""
import pytest

from repro.core import LustreCluster
from repro.core import cobd as cobd_mod
from repro.fsio import FsError, LustreClient


def test_basic_file_lifecycle(fs):
    fs.mkdir_p("/a/b/c")
    fh = fs.creat("/a/b/c/f.bin", stripe_count=2, stripe_size=512)
    fs.write(fh, b"0123456789" * 100)
    fs.close(fh)
    st = fs.stat("/a/b/c/f.bin")
    assert st["size"] == 1000 and st["stripe_count"] == 2
    fh = fs.open("/a/b/c/f.bin")
    assert fs.read(fh, 1000) == b"0123456789" * 100
    assert fs.read(fh, 10) == b""                 # EOF
    fs.close(fh)
    fs.unlink("/a/b/c/f.bin")
    assert not fs.exists("/a/b/c/f.bin")


def test_enoent_and_eexist(fs):
    with pytest.raises(FsError):
        fs.open("/nope")
    fs.creat("/dup")
    with pytest.raises(FsError) as ei:
        fs.creat("/dup")
    assert ei.value.errno == -17


def test_sparse_write_and_read(fs):
    fh = fs.creat("/sparse", stripe_count=3, stripe_size=128)
    fs.write(fh, b"end", offset=1000)
    fs.close(fh)
    assert fs.stat("/sparse")["size"] == 1003
    fh = fs.open("/sparse")
    data = fs.read(fh, 1003)
    assert data[:1000] == b"\0" * 1000 and data[1000:] == b"end"


def test_symlink_resolution_and_loop(fs):
    fs.mkdir("/t")
    fh = fs.creat("/t/real")
    fs.write(fh, b"hello")
    fs.close(fh)
    fs.symlink("/t/real", "/t/lnk")
    fs.symlink("/t/lnk", "/t/lnk2")
    assert fs.stat("/t/lnk2")["size"] == 5
    fs.symlink("/t/loopA", "/t/loopB")
    fs.symlink("/t/loopB", "/t/loopA")
    with pytest.raises(FsError):
        fs.stat("/t/loopA")


def test_rename_across_directories(fs):
    fs.mkdir("/src")
    fs.mkdir("/dst")
    fs.creat("/src/f")
    fs.rename("/src/f", "/dst/g")
    assert "g" in fs.readdir("/dst")
    assert "f" not in fs.readdir("/src")


def test_cross_client_coherency(cluster):
    fs1 = LustreClient(cluster, 0).mount()
    fs2 = LustreClient(cluster, 1).mount()
    fh = fs1.creat("/shared.txt")
    fs1.write(fh, b"v1")
    fs1.close(fh)
    assert fs2.stat("/shared.txt")["size"] == 2
    # client 2 removes it; client 1's cached dentry must go stale
    fs1.stat("/shared.txt")                       # populate dcache
    fs2.unlink("/shared.txt")
    assert not fs1.exists("/shared.txt")


def test_concurrent_rw_sees_writeback_data(cluster):
    """Reader triggers blocking AST that flushes the writer's cache."""
    w = LustreClient(cluster, 0).mount()
    r = LustreClient(cluster, 1).mount()
    fh = w.creat("/wb.bin", stripe_count=1)
    w.write(fh, b"dirty-cached-data")
    # NOT closed, NOT synced: data sits in w's writeback cache
    fh2 = r.open("/wb.bin")
    assert r.read(fh2, 17) == b"dirty-cached-data"
    r.close(fh2)
    w.close(fh)


def test_stat_size_from_ost_while_open(cluster):
    """§6.9.1: while a writer holds the file open, size/mtime come from
    the OSTs, not the MDS copy."""
    w = LustreClient(cluster, 0).mount()
    r = LustreClient(cluster, 1).mount()
    fh = w.creat("/grow.bin", stripe_count=2)
    w.write(fh, b"x" * 500)
    w.fsync(fh)
    st = r.stat("/grow.bin")
    assert st["size"] == 500 and st["mtime_on_ost"]
    w.close(fh)
    st = r.stat("/grow.bin")
    assert st["size"] == 500 and not st["mtime_on_ost"]


def test_readdir_and_mkdir_p(fs):
    fs.mkdir_p("/x/y/z")
    fs.creat("/x/y/z/1")
    fs.creat("/x/y/z/2")
    assert sorted(fs.readdir("/x/y/z")) == ["1", "2"]
    assert fs.readdir("/x") == {"y": fs.resolve("/x/y")}


def test_statfs_capacity(fs):
    s = fs.statfs()
    assert s["capacity"] > 0 and s["free"] <= s["capacity"]


def test_wbc_mode_speeds_metadata_burst(cluster):
    fs = LustreClient(cluster, 0).mount()
    fs.mkdir("/burst")
    assert fs.enable_wbc("/burst")
    base = cluster.stats.counters.get("rpc.mds.reint", 0)
    root = fs.resolve("/burst")
    for i in range(30):
        fs.wbc.create(root, f"f{i}")
    burst_rpcs = cluster.stats.counters.get("rpc.mds.reint", 0) - base
    fs.disable_wbc()
    assert burst_rpcs == 0                        # all local
    assert len(fs.readdir("/burst")) == 30


def test_read_through_collaborative_cache(cluster):
    fs = LustreClient(cluster, 0).mount()
    fh = fs.creat("/hot.bin", stripe_count=1, stripe_offset=1)
    fs.write(fh, bytes(range(256)) * 32)
    fs.close(fh)
    cobd, _ = cobd_mod.make_caching_node(cluster, "client1",
                                         cluster.ost_targets[1], "COBD-t")
    r = LustreClient(cluster, 2).mount()
    fh = r.open("/hot.bin")
    assert r.read(fh, 8192) == bytes(range(256)) * 32
    assert cluster.stats.counters.get("ost.referral", 0) >= 1
    assert cluster.stats.bytes.get("cobd.served", 0) >= 8192
