"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels import flash_attention as fa
from repro.kernels import parity as par


def rand(key, shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)
    return x.astype(dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("B,H,Hkv,S,D", [
    (1, 1, 1, 128, 64),       # MHA
    (2, 4, 2, 128, 64),       # GQA 2:1
    (1, 8, 1, 256, 64),       # MQA
    (1, 4, 4, 64, 128),       # head_dim 128
    (2, 2, 2, 192, 32),       # non-pow2 seq (block 64)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes_dtypes(B, H, Hkv, S, D, dtype):
    q = rand(0, (B, H, S, D), dtype)
    k = rand(1, (B, Hkv, S, D), dtype)
    v = rand(2, (B, Hkv, S, D), dtype)
    out = fa.flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                             interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    err = np.abs(out.astype(jnp.float32) - want.astype(jnp.float32)).max()
    assert err < TOL[dtype], (err, dtype)


@pytest.mark.parametrize("window", [32, 64, 100])
def test_flash_attention_sliding_window(window):
    q = rand(0, (1, 2, 256, 64), jnp.float32)
    k = rand(1, (1, 2, 256, 64), jnp.float32)
    v = rand(2, (1, 2, 256, 64), jnp.float32)
    out = fa.flash_attention(q, k, v, causal=True, window=window,
                             block_q=64, block_k=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    assert np.abs(out - want).max() < 2e-5


def test_flash_attention_noncausal():
    q = rand(0, (1, 2, 128, 64), jnp.float32)
    k = rand(1, (1, 2, 128, 64), jnp.float32)
    v = rand(2, (1, 2, 128, 64), jnp.float32)
    out = fa.flash_attention(q, k, v, causal=False, block_q=64,
                             block_k=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=False)
    assert np.abs(out - want).max() < 2e-5


def test_flash_attention_block_shape_independence():
    """Output must not depend on the BlockSpec tiling."""
    q = rand(0, (1, 2, 256, 64), jnp.float32)
    k = rand(1, (1, 1, 256, 64), jnp.float32)
    v = rand(2, (1, 1, 256, 64), jnp.float32)
    outs = [fa.flash_attention(q, k, v, block_q=bq, block_k=bk,
                               interpret=True)
            for bq, bk in [(64, 64), (128, 128), (64, 128), (256, 64)]]
    for o in outs[1:]:
        assert np.abs(o - outs[0]).max() < 1e-5


@pytest.mark.parametrize("K,N,block", [
    (2, 1024, 256), (5, 4096, 4096), (9, 512, 128), (3, 8192, 1024),
])
def test_xor_parity_sweep(K, N, block):
    rng = np.random.default_rng(K * N)
    blocks = jnp.asarray(
        rng.integers(-2**31, 2**31, size=(K, N), dtype=np.int32))
    p = par.xor_parity(blocks, block=block, interpret=True)
    assert (np.asarray(p) == np.asarray(ref.xor_parity_ref(blocks))).all()
    # reconstruct each possible missing row
    for miss in range(K):
        surv = jnp.concatenate([blocks[:miss], blocks[miss + 1:]], 0)
        rec = par.reconstruct(surv, p, block=block, interpret=True)
        assert (np.asarray(rec) == np.asarray(blocks[miss])).all()


def test_parity_bytes_roundtrip_unequal_tails():
    rng = np.random.default_rng(7)
    chunks = [rng.bytes(1000), rng.bytes(737), rng.bytes(1024)]
    p = ops.parity_bytes(chunks)
    assert len(p) == 1024
    pad = [c.ljust(1024, b"\0") for c in chunks]
    back = ops.reconstruct_bytes(pad[1:], p, 1000)
    assert back == pad[0][:1000]


def test_xor_parity_linearity_property():
    """XOR(a) ^ XOR(b) == XOR(a ^ b) — the algebra the erasure code
    relies on."""
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.integers(-2**31, 2**31, (4, 512), dtype=np.int32))
    b = jnp.asarray(rng.integers(-2**31, 2**31, (4, 512), dtype=np.int32))
    pa = par.xor_parity(a, interpret=True)
    pb = par.xor_parity(b, interpret=True)
    pab = par.xor_parity(jnp.bitwise_xor(a, b), interpret=True)
    assert (np.asarray(jnp.bitwise_xor(pa, pb)) == np.asarray(pab)).all()


@pytest.mark.parametrize("K,N,block", [
    (3, 1000, 256),     # ragged tail: 1000 % 256 != 0
    (4, 37, 64),        # whole array smaller than one block
    (2, 513, 512),      # one lane past a block boundary
    (5, 4100, 1024),    # big block, small spill
])
def test_xor_parity_ragged_tail(K, N, block):
    """ISSUE-8: the kernel wrapper zero-pads lane counts that are not a
    multiple of the grid block instead of asserting, and the pad lanes
    never leak into the returned parity."""
    rng = np.random.default_rng(K + N + block)
    blocks = jnp.asarray(
        rng.integers(-2**31, 2**31, size=(K, N), dtype=np.int32))
    p = par.xor_parity(blocks, block=block, interpret=True)
    assert p.shape == (N,)
    assert (np.asarray(p) == np.asarray(ref.xor_parity_ref(blocks))).all()
    for miss in range(K):
        surv = jnp.concatenate([blocks[:miss], blocks[miss + 1:]], 0)
        rec = par.reconstruct(surv, p, block=block, interpret=True)
        assert (np.asarray(rec) == np.asarray(blocks[miss])).all()


def test_parity_bytes_odd_sizes_roundtrip():
    """Byte-level marshalling on sizes that are neither lane- nor
    block-aligned (the raid5 tail-unit case)."""
    rng = np.random.default_rng(11)
    for sizes in [(1, 1), (3, 7, 5), (255, 255, 255), (1023, 1, 509)]:
        chunks = [rng.bytes(s) for s in sizes]
        n = max(sizes)
        p = ops.parity_bytes(chunks)
        assert len(p) == n
        pad = [c.ljust(n, b"\0") for c in chunks]
        for miss in range(len(chunks)):
            surv = [pad[j] for j in range(len(chunks)) if j != miss]
            back = ops.reconstruct_bytes(surv, p, sizes[miss])
            assert back == chunks[miss], sizes
