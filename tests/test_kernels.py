"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels import flash_attention as fa
from repro.kernels import parity as par


def rand(key, shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)
    return x.astype(dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("B,H,Hkv,S,D", [
    (1, 1, 1, 128, 64),       # MHA
    (2, 4, 2, 128, 64),       # GQA 2:1
    (1, 8, 1, 256, 64),       # MQA
    (1, 4, 4, 64, 128),       # head_dim 128
    (2, 2, 2, 192, 32),       # non-pow2 seq (block 64)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes_dtypes(B, H, Hkv, S, D, dtype):
    q = rand(0, (B, H, S, D), dtype)
    k = rand(1, (B, Hkv, S, D), dtype)
    v = rand(2, (B, Hkv, S, D), dtype)
    out = fa.flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                             interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    err = np.abs(out.astype(jnp.float32) - want.astype(jnp.float32)).max()
    assert err < TOL[dtype], (err, dtype)


@pytest.mark.parametrize("window", [32, 64, 100])
def test_flash_attention_sliding_window(window):
    q = rand(0, (1, 2, 256, 64), jnp.float32)
    k = rand(1, (1, 2, 256, 64), jnp.float32)
    v = rand(2, (1, 2, 256, 64), jnp.float32)
    out = fa.flash_attention(q, k, v, causal=True, window=window,
                             block_q=64, block_k=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    assert np.abs(out - want).max() < 2e-5


def test_flash_attention_noncausal():
    q = rand(0, (1, 2, 128, 64), jnp.float32)
    k = rand(1, (1, 2, 128, 64), jnp.float32)
    v = rand(2, (1, 2, 128, 64), jnp.float32)
    out = fa.flash_attention(q, k, v, causal=False, block_q=64,
                             block_k=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=False)
    assert np.abs(out - want).max() < 2e-5


def test_flash_attention_block_shape_independence():
    """Output must not depend on the BlockSpec tiling."""
    q = rand(0, (1, 2, 256, 64), jnp.float32)
    k = rand(1, (1, 1, 256, 64), jnp.float32)
    v = rand(2, (1, 1, 256, 64), jnp.float32)
    outs = [fa.flash_attention(q, k, v, block_q=bq, block_k=bk,
                               interpret=True)
            for bq, bk in [(64, 64), (128, 128), (64, 128), (256, 64)]]
    for o in outs[1:]:
        assert np.abs(o - outs[0]).max() < 1e-5


@pytest.mark.parametrize("K,N,block", [
    (2, 1024, 256), (5, 4096, 4096), (9, 512, 128), (3, 8192, 1024),
])
def test_xor_parity_sweep(K, N, block):
    rng = np.random.default_rng(K * N)
    blocks = jnp.asarray(
        rng.integers(-2**31, 2**31, size=(K, N), dtype=np.int32))
    p = par.xor_parity(blocks, block=block, interpret=True)
    assert (np.asarray(p) == np.asarray(ref.xor_parity_ref(blocks))).all()
    # reconstruct each possible missing row
    for miss in range(K):
        surv = jnp.concatenate([blocks[:miss], blocks[miss + 1:]], 0)
        rec = par.reconstruct(surv, p, block=block, interpret=True)
        assert (np.asarray(rec) == np.asarray(blocks[miss])).all()


def test_parity_bytes_roundtrip_unequal_tails():
    rng = np.random.default_rng(7)
    chunks = [rng.bytes(1000), rng.bytes(737), rng.bytes(1024)]
    p = ops.parity_bytes(chunks)
    assert len(p) == 1024
    pad = [c.ljust(1024, b"\0") for c in chunks]
    back = ops.reconstruct_bytes(pad[1:], p, 1000)
    assert back == pad[0][:1000]


def test_xor_parity_linearity_property():
    """XOR(a) ^ XOR(b) == XOR(a ^ b) — the algebra the erasure code
    relies on."""
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.integers(-2**31, 2**31, (4, 512), dtype=np.int32))
    b = jnp.asarray(rng.integers(-2**31, 2**31, (4, 512), dtype=np.int32))
    pa = par.xor_parity(a, interpret=True)
    pb = par.xor_parity(b, interpret=True)
    pab = par.xor_parity(jnp.bitwise_xor(a, b), interpret=True)
    assert (np.asarray(jnp.bitwise_xor(pa, pb)) == np.asarray(pab)).all()
