"""Minimal stand-in for `hypothesis` when it isn't installed.

The real library is preferred (see requirements-dev.txt); this shim keeps
the property-based tests *runnable* in bare environments by sampling a
fixed number of pseudo-random examples from the same strategy expressions.
Only the strategy surface the test-suite uses is implemented: integers,
binary, lists, tuples, sampled_from, dictionaries, fixed_dictionaries.
No shrinking, no database — a deterministic seed keeps failures
reproducible.
"""
from __future__ import annotations

import functools
import inspect
import random

_SEED = 0xC0FFEE
_DEFAULT_EXAMPLES = 20


class _Strategy:
    def __init__(self, gen):
        self.gen = gen


class strategies:  # noqa: N801 — mimics `hypothesis.strategies` module
    @staticmethod
    def integers(min_value=0, max_value=1 << 16):
        return _Strategy(lambda r: r.randint(min_value, max_value))

    @staticmethod
    def binary(min_size=0, max_size=64):
        return _Strategy(lambda r: bytes(
            r.getrandbits(8) for _ in range(r.randint(min_size, max_size))))

    @staticmethod
    def lists(elements, min_size=0, max_size=None, unique=False):
        cap = 8 if max_size is None else max_size

        def gen(r):
            out = [elements.gen(r) for _ in range(r.randint(min_size, cap))]
            if unique:
                seen, uniq = set(), []
                for v in out:
                    if v not in seen:
                        seen.add(v)
                        uniq.append(v)
                out = uniq
            return out
        return _Strategy(gen)

    @staticmethod
    def tuples(*elems):
        return _Strategy(lambda r: tuple(e.gen(r) for e in elems))

    @staticmethod
    def sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda r: r.choice(seq))

    @staticmethod
    def dictionaries(keys, values, min_size=0, max_size=None):
        cap = 8 if max_size is None else max_size

        def gen(r):
            out = {}
            for _ in range(r.randint(min_size, cap)):
                out[keys.gen(r)] = values.gen(r)
            return out
        return _Strategy(gen)

    @staticmethod
    def fixed_dictionaries(mapping):
        return _Strategy(
            lambda r: {k: v.gen(r) for k, v in mapping.items()})


def settings(**kw):
    """Decorator: records max_examples on the @given wrapper below it."""
    def deco(fn):
        setattr(fn, "_shim_settings", kw)
        return fn
    return deco


def given(*strats):
    def deco(fn):
        @functools.wraps(fn)
        def run(*args, **kwargs):
            conf = getattr(run, "_shim_settings", {})
            n = min(conf.get("max_examples", _DEFAULT_EXAMPLES), 30)
            rng = random.Random(_SEED)
            for _ in range(n):
                fn(*args, *[s.gen(rng) for s in strats], **kwargs)
        # hide the generated parameters from pytest's fixture resolution:
        # the trailing len(strats) params are filled by the strategies
        params = list(inspect.signature(fn).parameters.values())
        run.__signature__ = inspect.Signature(params[:-len(strats)])
        del run.__wrapped__              # keep pytest off the original sig
        run.hypothesis_shim = True
        return run
    return deco
