"""Monitoring plane: snapshot tree, degradation, anomalies, grants."""
from repro.core import LustreCluster
from repro.fsio import LustreClient
from repro.tools.monitor import ChangelogAnomalyDetector


def _workload(c, n_dirs=4, data=b"w" * (128 << 10)):
    fs = LustreClient(c).mount()
    for i in range(n_dirs):
        fs.mkdir(f"/d{i}")
    fh = fs.creat("/d0/f", stripe_count=2)
    fs.write(fh, data)
    fs.fsync(fh)
    fs.close(fh)
    fs.stat("/d0/f")
    return fs


# -------------------------------------------------------- snapshot tree

def test_snapshot_tree_covers_every_target_with_all_sections():
    c = LustreCluster(osts=2, mdses=2, clients=1, commit_interval=64)
    _workload(c)
    snap = c.lctl("mon_snapshot")
    assert not snap["partial"] and snap["stale"] == []
    want = {t.uuid for t in c.mds_targets + c.ost_targets}
    assert set(snap["targets"]) == want
    for uuid, leaf in snap["targets"].items():
        assert not leaf["stale"]
        for section in ("nrs", "counters", "latency"):
            assert section in leaf, (uuid, section)
        assert leaf["latency"]["spans"] >= 0
    for t in c.ost_targets:
        leaf = snap["targets"][t.uuid]
        assert {"space", "grant", "locks"} <= set(leaf)
    for t in c.mds_targets:
        leaf = snap["targets"][t.uuid]
        assert {"namespace", "locks", "changelog"} <= set(leaf)


def test_cluster_rollups_sum_leaves_and_merge_histograms():
    c = LustreCluster(osts=2, mdses=1, clients=1, commit_interval=64,
                      ost_capacity=1 << 30)
    _workload(c)
    snap = c.lctl("mon_snapshot")
    cl = snap["cluster"]
    # space: exactly the sum of the OST leaves (capacity is per-OST)
    assert cl["space"]["capacity"] == 2 * (1 << 30)
    assert 0 < cl["space"]["free"] <= cl["space"]["capacity"]
    # spans: sum over leaves; per-jobid quantiles come from merged
    # buckets, so cluster count == sum of leaf counts for that jobid
    assert cl["spans"] == sum(leaf["latency"]["spans"]
                              for leaf in snap["targets"].values())
    leafsum = sum(leaf["latency"]["by_jobid"].get("(none)", {})
                  .get("count", 0) for leaf in snap["targets"].values())
    assert cl["by_jobid"]["(none)"]["count"] == leafsum > 0
    # counters roll up the per-node attribution (satellite a)
    assert cl["counters"].get("rpc.mds.reint_batch",
                              cl["counters"].get("rpc.mds.reint", 0)) > 0
    # monitoring overhead is measured (the <=2% bound is a *scale*
    # property, gated in bench_scale where workload RPCs dwarf it)
    assert snap["overhead"]["ratio"] > 0
    assert snap["overhead"]["snapshot_rpcs"] == len(snap["targets"])


def test_partitioned_target_degrades_to_partial_snapshot():
    """A dead OST must cost the collector a bounded timeout, mark that
    leaf stale, and keep totals over fresh leaves only — never a hang,
    never a silently-wrong total."""
    c = LustreCluster(osts=2, mdses=1, clients=1, commit_interval=64,
                      ost_capacity=1 << 30)
    fs = _workload(c)
    full = c.lctl("mon_snapshot")
    assert not full["partial"]
    c.fail_node("ost1")
    snap = c.lctl("mon_snapshot")
    assert snap["partial"] and snap["stale"] == ["OST0001"]
    assert snap["targets"]["OST0001"] == {"uuid": "OST0001", "stale": True}
    # fresh-only totals: one OST's capacity, not a stale guess of two
    assert snap["cluster"]["space"]["capacity"] == 1 << 30
    assert c.stats.counters["mon.snapshot_partial"] == 1
    c.restart_node("ost1")
    # real IO (not a cached stat) so the data client reconnects and the
    # target's recovery window closes
    fh = fs.open("/d0/f", "w")
    fs.write(fh, b"again" * 1024)
    fs.fsync(fh)
    fs.close(fh)
    healed = c.lctl("mon_snapshot")
    assert not healed["partial"]
    assert healed["cluster"]["space"]["capacity"] == 2 * (1 << 30)


def test_mon_collect_failpoint_crashes_target_never_wrong_total():
    """Satellite (c): a collector crashed *on the target* mid-collect
    degrades exactly like a partition — partial snapshot, stale leaf —
    and the next round heals through normal reconnect."""
    c = LustreCluster(osts=1, mdses=1, clients=1, commit_interval=64)
    fs = _workload(c)
    c.lctl("set_param", "fail_loc", "mon.collect")
    snap = c.lctl("mon_snapshot")
    assert c.sim.fail.fired == 1
    assert snap["partial"] and len(snap["stale"]) == 1
    fs.statfs()           # workload client reconnects; recovery ends
    healed = c.lctl("mon_snapshot")
    assert not healed["partial"]
    assert c.stats.counters["mon.snapshot"] >= 2


def test_procfs_exposes_metrics_and_monitor_state():
    c = LustreCluster(osts=1, mdses=1, clients=1)
    _workload(c)
    c.lctl("mon_snapshot")
    proc = c.procfs()
    assert proc["metrics"]["spans"] > 0
    assert proc["monitor"]["snapshots"] == 1
    assert proc["monitor"]["partial"] is False
    for t in c.ost_targets + c.mds_targets:
        entry = proc["targets"][t.uuid] if "targets" in proc else None
        if entry is None:
            break
        assert "latency" in entry and "counters" in entry


# ------------------------------------------------------ grant shrinkage

def test_grant_shrink_returns_idle_grant_to_connect_target():
    c = LustreCluster(osts=1, mdses=1, clients=1, commit_interval=64)
    fs = LustreClient(c).mount()
    fh = fs.creat("/big", stripe_count=1)
    fs.write(fh, b"g" * (4 << 20))       # outruns the 2 MiB initial grant
    fs.fsync(fh)
    fs.close(fh)
    osc = fs.lov.oscs[0]
    keep = osc.imp.connect_data["grant"]
    # write replies re-granted GRANT_CHUNK slices; the post-flush shrink
    # returned the idle surplus down to the connect-time target
    assert osc.grant <= keep
    assert c.stats.counters["rpc.ost.grant_shrink"] >= 1
    assert c.stats.counters["ost.grant_shrunk_bytes"] > 0
    exp = next(iter(c.ost_targets[0].exports.values()))
    assert exp.data["grant"] == osc.grant


def test_grant_shrink_failpoint_degrades_to_drop_and_stays_idempotent():
    c = LustreCluster(osts=1, mdses=1, clients=1, commit_interval=64)
    fs = LustreClient(c).mount()
    fh = fs.creat("/big", stripe_count=1)
    fs.write(fh, b"g" * (4 << 20))
    c.lctl("set_param", "fail_loc", "osc.grant_shrink", 1, "drop")
    fs.fsync(fh)                         # shrink RPC lost; flush succeeds
    fs.close(fh)
    assert c.sim.fail.fired == 1
    osc = fs.lov.oscs[0]
    keep = osc.imp.connect_data["grant"]
    # the next idle flush retries the (absolute-target, idempotent) shrink
    osc.flush()
    assert osc.grant <= keep
    exp = next(iter(c.ost_targets[0].exports.values()))
    assert exp.data["grant"] == osc.grant


# ----------------------------------------------------- anomaly detector

def test_anomaly_detector_flags_noisy_jobid_only():
    """Satellite (b): per-jobid op-rate spike vs rolling baseline —
    the noisy neighbor is flagged, steady jobids are not, and the
    baseline only absorbs a window after it was judged."""
    c = LustreCluster(osts=1, mdses=1, clients=2, commit_interval=64)
    steady = LustreClient(c).mount()
    noisy = LustreClient(c, 1).mount()
    steady.set_jobid("steady")
    noisy.set_jobid("noisy")
    det = ChangelogAnomalyDetector(c, spike_factor=4.0, min_ops=16)

    def window(n_steady, n_noisy, tag):
        for i in range(n_steady):
            steady.mkdir(f"/s_{tag}_{i}")
        for i in range(n_noisy):
            noisy.mkdir(f"/n_{tag}_{i}")
        return det.poll()

    assert window(6, 6, "w0") == []      # first window IS the baseline
    assert window(6, 6, "w1") == []      # steady state: nothing flagged
    flagged = window(6, 60, "w2")        # the spike
    assert [a["jobid"] for a in flagged] == ["noisy"]
    assert flagged[0]["ops"] >= 60
    assert c.stats.counters["mon.anomaly"] == 1
    # spike absorbed into the EWMA only after judgement: a *sustained*
    # plateau stops being "anomalous" as the baseline catches up
    again = window(6, 60, "w3")
    assert [a["jobid"] for a in again] in ([], ["noisy"])
    det.close()
    for t in c.mds_targets:
        assert not t.changelog.users
