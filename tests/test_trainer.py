"""Trainer integration: fault tolerance, resume, determinism."""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import LustreCluster
from repro.models.config import RunConfig
from repro.train.trainer import Trainer, TrainerConfig
from repro.train.serve import BatchedServer, Request


def mkcfg(steps=6, every=3):
    return TrainerConfig(
        model=get_smoke_config("qwen3-4b"),
        rc=RunConfig(seq_len=32, global_batch=4, kind="train",
                     attn_impl="ref"),
        n_steps=steps, ckpt_every=every, dataset_seqs=128, n_writers=2,
        parity=False)


def test_train_checkpoints_and_resumes_exactly():
    cluster = LustreCluster(osts=2, mdses=1, clients=2, commit_interval=64)
    cfg = mkcfg()
    tr = Trainer(cluster, cfg)
    tr.run(6)
    assert tr.ckpt.steps() == [3, 6]
    ref_params = jax.tree.map(np.asarray, tr.params)
    tr2 = Trainer.resume(cluster, cfg)
    assert tr2.step == 6
    for a, b in zip(jax.tree.leaves(ref_params),
                    jax.tree.leaves(tr2.params)):
        assert np.allclose(np.asarray(a), np.asarray(b))


def test_training_continues_through_ost_failure():
    cluster = LustreCluster(osts=3, mdses=1, clients=2, ost_failover=True,
                            commit_interval=64)
    cfg = mkcfg(steps=6, every=2)
    tr = Trainer(cluster, cfg)
    metrics = tr.run(6, fail_at={3: lambda c: c.fail_node("ost1")})
    assert len(metrics) == 6
    assert all(np.isfinite(m["loss"]) for m in metrics)
    assert tr.ckpt.steps()[-1] == 6


def test_resume_then_training_is_deterministic():
    """Two trainers resumed from the same checkpoint produce identical
    losses (deterministic pipeline + ckpt restore)."""
    cluster = LustreCluster(osts=2, mdses=1, clients=2, commit_interval=64)
    cfg = mkcfg(steps=4, every=2)
    Trainer(cluster, cfg).run(4)
    a = Trainer.resume(cluster, cfg)
    b = Trainer.resume(cluster, cfg)
    ma = a.run(2)
    mb = b.run(2)
    assert [m["loss"] for m in ma] == [m["loss"] for m in mb]


def test_serve_generates_deterministic():
    cfg = get_smoke_config("yi-9b")
    from repro.models import layers as L, registry
    params = L.tree_init(registry.param_defs(cfg), jax.random.PRNGKey(0))
    srv = BatchedServer(cfg, params, max_seq=32)
    reqs = [Request(1, [5, 6, 7], max_new=4), Request(2, [9], max_new=4)]
    out = srv.generate(reqs)
    assert all(len(r.out) == 4 for r in out)
    srv2 = BatchedServer(cfg, params, max_seq=32)
    out2 = srv2.generate([Request(1, [5, 6, 7], max_new=4),
                          Request(2, [9], max_new=4)])
    assert [r.out for r in out] == [r.out for r in out2]
