"""Metadata write-back cache: shadow semantics, batched exactly-once
reintegration, crash loss/replay bounds (ch. 17, §6.5)."""
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # bare env: sampled fallback
    from _hyposhim import given, settings, strategies as st

from repro.core import LustreCluster
from repro.core.mds import ROOT_FID, S_IFREG
from repro.fsio import FsError, LustreClient
from repro.tools.audit import ChangelogAuditor


# ------------------------------------------------- callback hygiene

def test_flush_cb_restored_after_enable_disable_cycles():
    """release() must put back the ORIGINAL dlm flush_cb: a wrapper per
    enable/disable cycle used to pile up, each flushing a dead cache."""
    c = LustreCluster(osts=1, mdses=1, clients=1, commit_interval=16)
    fs = LustreClient(c).mount()
    fs.mkdir("/w")
    mdc = fs.lmv.mdc_for_fid(fs.resolve("/w"))
    orig = mdc.locks.flush_cb
    for cycle in range(2):
        assert fs.enable_wbc("/w")
        assert mdc.locks.flush_cb is not orig      # wrapper installed
        fs.mkdir(f"/w/c{cycle}")
        fs.disable_wbc()
        assert mdc.locks.flush_cb is orig, f"cycle {cycle}"
    assert set(fs.readdir("/w")) == {"c0", "c1"}


# --------------------------------------------- batch atomicity (MDS)

def test_reint_batch_eexist_mid_batch_leaves_no_half_applied_state():
    """A failing record contributes only its -errno status: the records
    around it land, its own partial effects are unwound, and the dup's
    pinned fid never materialises as an inode."""
    c = LustreCluster(osts=1, mdses=1, clients=1, commit_interval=16)
    fs = LustreClient(c).mount()
    mdc = fs.lmv.mdc_for_fid(ROOT_FID)
    fids = mdc.prealloc_fids(3)

    def mk(name, fid):
        return {"type": "create", "parent": ROOT_FID, "name": name,
                "fid": fid, "ftype": S_IFREG, "mode": 0o644,
                "remote_ok": False}

    rep = mdc.reint_batch([mk("a", fids[0]), mk("a", fids[1]),
                           mk("b", fids[2])])
    assert [r["status"] for r in rep.data["results"]] == [0, -17, 0]
    mds = c.mds_targets[0]
    names = list(fs.readdir("/"))
    assert names.count("a") == 1 and names.count("b") == 1
    assert tuple(fids[0]) in mds.inodes          # first create won
    assert tuple(fids[1]) not in mds.inodes      # dup fully unwound
    assert fs.stat("/a")["type"] == "file"
    assert fs.stat("/b")["type"] == "file"


# --------------------------------------------------- property stream

_OPS = st.lists(st.tuples(st.integers(0, 5), st.integers(0, 3),
                          st.integers(0, 7)),
                min_size=1, max_size=40)


@settings(max_examples=15, deadline=None)
@given(_OPS)
def test_wbc_random_op_stream_converges(ops):
    """Random create/mkdir/setattr/unlink streams — with forced
    mid-stream flushes and AST-triggered flushes from a second client —
    leave shadow ≡ post-flush namespace and changelog mirror ≡ ground
    truth."""
    c = LustreCluster(osts=1, mdses=1, clients=2, commit_interval=8,
                      wbc_batch=4)
    fs = LustreClient(c).mount()
    fs2 = LustreClient(c, 1).mount()
    aud = ChangelogAuditor(fs2)
    fs.mkdir("/w")
    assert fs.enable_wbc("/w")
    model = {"/w": {}}                   # dir path -> {name: ftype}
    dirs = ["/w"]
    for kind, di, ni in ops:
        d = dirs[di % len(dirs)]
        name = f"n{ni % 6}"
        path = d + "/" + name
        ent = model[d].get(name)
        if kind == 0:                                   # create file
            if ent is None:
                fs.close(fs.creat(path))
                model[d][name] = "file"
            else:
                with pytest.raises(FsError):
                    fs.creat(path)
        elif kind == 1:                                 # mkdir
            if ent is None:
                fs.mkdir(path)
                model[d][name] = "dir"
                model[path] = {}
                dirs.append(path)
            else:
                with pytest.raises(FsError):
                    fs.mkdir(path)
        elif kind == 2 and ent is not None:             # setattr
            fs.setattr(path, mode=0o700 + ni % 8)
        elif kind == 3:                                 # unlink/rmdir
            if ent == "file":
                fs.unlink(path)
                del model[d][name]
            elif ent == "dir":
                if model[path]:
                    with pytest.raises(FsError):
                        fs.rmdir(path)
                else:
                    fs.rmdir(path)
                    del model[d][name]
                    del model[path]
                    dirs.remove(path)
        elif kind == 4:                                 # forced flush
            fs.sync()
        elif kind == 5:                                 # AST flush
            fs2.readdir("/w")
    fs.disable_wbc()                     # final barrier
    for d in dirs:                       # namespace ≡ model, both views
        assert set(fs.readdir(d)) == set(model[d]), d
        assert set(fs2.readdir(d)) == set(model[d]), d
        for name, t in model[d].items():
            assert fs2.stat(d + "/" + name)["type"] == t
    aud.tail()
    report = aud.verify()
    assert report["ok"], report["mismatches"]


# -------------------------------------------------- crash semantics

def test_client_crash_loses_exactly_the_unflushed_tail():
    """Eviction semantics: flushed records are durable, the unflushed
    tail dies with the client, and the changelog mirror still matches
    the surviving namespace."""
    c = LustreCluster(osts=1, mdses=1, clients=2, commit_interval=4)
    fs = LustreClient(c).mount()
    fs2 = LustreClient(c, 1).mount()
    aud = ChangelogAuditor(fs2)
    fs.mkdir("/w")
    assert fs.enable_wbc("/w")
    for i in range(4):
        fs.mkdir(f"/w/keep{i}")
    fs.sync()                            # durable prefix
    for i in range(3):
        fs.mkdir(f"/w/lost{i}")
    w = fs.wbc
    assert len(w.records) == 3
    # the client dies: its subtree lock is evicted without the flush
    # callback ever running (the revoke-cb path with nobody home)
    w._deactivate(lost=True)
    fs.wbc = None
    assert c.stats.counters["wbc.lost_records"] == 3
    assert set(fs2.readdir("/w")) == {f"keep{i}" for i in range(4)}
    aud.tail()
    report = aud.verify()
    assert report["ok"], report["mismatches"]


def test_mds_crash_mid_batch_never_double_applies():
    """Crash the MDS on the 3rd record of a reint_batch: the whole batch
    rolls back, client replay re-applies it exactly once — every entry
    present once, changelog exactly-once, mirror ≡ namespace."""
    c = LustreCluster(osts=1, mdses=1, clients=2, commit_interval=3)
    fs = LustreClient(c).mount()
    fs2 = LustreClient(c, 1).mount()
    aud = ChangelogAuditor(fs2)
    fs.mkdir("/w")
    assert fs.enable_wbc("/w")
    for i in range(6):
        fs.mkdir(f"/w/d{i}")
    c.lctl("set_param", "fail_loc", "mds.reint_batch", 3)
    fs.sync()                            # flush -> crash -> heal
    c.lctl("set_param", "fail_loc", "")
    assert c.sim.fail.hits.get("mds.reint_batch", 0) >= 1
    fs.disable_wbc()
    names = fs2.readdir("/w")
    assert sorted(names) == [f"d{i}" for i in range(6)]
    aud.tail()
    report = aud.verify()
    assert report["ok"], report["mismatches"]
    keys = [(r["mdt"], r["idx"]) for r in aud.feed]
    assert len(keys) == len(set(keys))   # no record delivered twice
