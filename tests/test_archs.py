"""Per-architecture smoke tests: reduced configs, one train/decode step on
CPU, asserting output shapes + no NaNs (full configs only via dry-run)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.launch.cells import LONG_OK, cells
from repro.models import layers as L
from repro.models import registry
from repro.models.config import RunConfig, SHAPES
from repro.train import steps as steps_mod
from repro.launch.mesh import make_host_mesh


RC = RunConfig(seq_len=32, global_batch=4, kind="train", attn_impl="ref",
               num_microbatches=1)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_loss(arch):
    cfg = get_smoke_config(arch)
    params = L.tree_init(registry.param_defs(cfg), jax.random.PRNGKey(0))
    batch = steps_mod.make_batch(cfg, RC, jax.random.PRNGKey(1))
    x, prefix_len, cache, _, aux = registry.forward(cfg, params, batch, RC)
    B, S = batch["tokens"].shape
    assert x.shape == (B, S + prefix_len, cfg.d_model)
    assert not np.isnan(np.asarray(x, np.float32)).any()
    loss = steps_mod.loss_fn(cfg, params, batch, RC)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    mesh = make_host_mesh()
    bundle = steps_mod.build_train_step(cfg, RC, mesh)
    params, opt = bundle.init(jax.random.PRNGKey(0))
    l0 = np.asarray(jax.tree.leaves(params)[0])   # before donation
    batch = steps_mod.make_batch(cfg, RC, jax.random.PRNGKey(1))
    p2, o2, m = bundle.fn(params, opt, batch)
    assert np.isfinite(m["loss"]) and np.isfinite(m["grad_norm"])
    assert int(o2["step"]) == 1
    # params actually changed
    l1 = np.asarray(jax.tree.leaves(p2)[0])
    assert not np.allclose(l0, l1)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = get_smoke_config(arch)
    rc = RunConfig(seq_len=64, global_batch=2, kind="decode",
                   attn_impl="ref", param_dtype="float32")
    params = L.tree_init(registry.param_defs(cfg), jax.random.PRNGKey(0))
    cdt = jnp.dtype(rc.compute_dtype)
    spec = registry.init_cache(cfg, 2, 64, cdt)
    cache = jax.tree.map(
        lambda s: jnp.zeros(s[0], s[1]), spec,
        is_leaf=lambda x: isinstance(x, tuple) and isinstance(x[0], tuple))
    tok = jnp.ones((2, 1), jnp.int32)
    logits, cache2 = registry.decode(cfg, params, cache, tok,
                                     jnp.asarray(3, jnp.int32), rc)
    assert logits.shape == (2, 1, cfg.vocab)
    assert not np.isnan(np.asarray(logits, np.float32)).any()
    # cache got written somewhere
    changed = any(
        not np.array_equal(np.asarray(a, np.float32),
                           np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(cache2)))
    assert changed


def test_decode_matches_forward_incrementally():
    """Prefill-forward logits at position t == decoding tokens one by one
    (transformer family)."""
    cfg = get_smoke_config("qwen3-4b")
    rc = RunConfig(seq_len=16, global_batch=2, kind="train",
                   attn_impl="ref", compute_dtype="float32",
                   param_dtype="float32", remat="none")
    params = L.tree_init(registry.param_defs(cfg), jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(7), (2, 8), 0, cfg.vocab)
    x, _, _, _, _ = registry.forward(cfg, params, {"tokens": toks}, rc)
    full_logits = registry.unembed(cfg, params, x, rc)
    spec = registry.init_cache(cfg, 2, 16, jnp.float32)
    cache = jax.tree.map(
        lambda s: jnp.zeros(s[0], s[1]), spec,
        is_leaf=lambda x: isinstance(x, tuple) and isinstance(x[0], tuple))
    errs = []
    for t in range(8):
        lg, cache = registry.decode(cfg, params, cache, toks[:, t:t + 1],
                                    jnp.asarray(t, jnp.int32), rc)
        errs.append(np.abs(np.asarray(lg[:, 0]) -
                           np.asarray(full_logits[:, t])).max())
    assert max(errs) < 1e-3, errs


def test_rwkv_state_decode_matches_scan():
    """RWKV: sequential scan == one-token decode chain (state carried)."""
    cfg = get_smoke_config("rwkv6-3b")
    rc = RunConfig(seq_len=8, global_batch=1, kind="train",
                   attn_impl="ref", compute_dtype="float32",
                   param_dtype="float32", remat="none")
    params = L.tree_init(registry.param_defs(cfg), jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, 8), 0, cfg.vocab)
    x, _, _, _, _ = registry.forward(cfg, params, {"tokens": toks}, rc)
    full_logits = registry.unembed(cfg, params, x, rc)
    spec = registry.init_cache(cfg, 1, 8, jnp.float32)
    cache = jax.tree.map(
        lambda s: jnp.zeros(s[0], s[1]), spec,
        is_leaf=lambda x: isinstance(x, tuple) and isinstance(x[0], tuple))
    for t in range(8):
        lg, cache = registry.decode(cfg, params, cache, toks[:, t:t + 1],
                                    jnp.asarray(t, jnp.int32), rc)
        err = np.abs(np.asarray(lg[:, 0]) -
                     np.asarray(full_logits[:, t])).max()
        assert err < 1e-3, (t, err)


def test_microbatch_accumulation_matches_full_batch():
    cfg = get_smoke_config("yi-9b")
    mesh = make_host_mesh()
    rc1 = RunConfig(seq_len=32, global_batch=8, kind="train",
                    attn_impl="ref", num_microbatches=1, remat="none")
    rc2 = RunConfig(seq_len=32, global_batch=8, kind="train",
                    attn_impl="ref", num_microbatches=2, remat="none")
    b1 = steps_mod.build_train_step(cfg, rc1, mesh)
    b2 = steps_mod.build_train_step(cfg, rc2, mesh)
    p1, o1 = b1.init(jax.random.PRNGKey(0))
    p2, o2 = b2.init(jax.random.PRNGKey(0))
    batch = steps_mod.make_batch(cfg, rc1, jax.random.PRNGKey(1))
    batch2 = {k: v.reshape(2, 4, *v.shape[1:]) for k, v in batch.items()}
    _, _, m1 = b1.fn(p1, o1, batch)
    _, _, m2 = b2.fn(p2, o2, batch2)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4


def test_chunked_ce_matches_dense():
    cfg = get_smoke_config("yi-9b")
    rc_a = RunConfig(seq_len=32, global_batch=2, kind="train",
                     attn_impl="ref", remat="none")
    rc_b = RunConfig(seq_len=32, global_batch=2, kind="train",
                     attn_impl="ref", remat="none", chunked_ce=8)
    params = L.tree_init(registry.param_defs(cfg), jax.random.PRNGKey(0))
    batch = steps_mod.make_batch(cfg, rc_a, jax.random.PRNGKey(1))
    la = float(steps_mod.loss_fn(cfg, params, batch, rc_a))
    lb = float(steps_mod.loss_fn(cfg, params, batch, rc_b))
    assert abs(la - lb) < 1e-4


def test_chunked_attention_matches_ref():
    cfg = get_smoke_config("qwen2-7b")
    params = L.tree_init(registry.param_defs(cfg), jax.random.PRNGKey(0))
    rc_ref = RunConfig(seq_len=64, global_batch=2, kind="train",
                       attn_impl="ref", compute_dtype="float32",
                       remat="none")
    rc_ch = RunConfig(seq_len=64, global_batch=2, kind="train",
                      attn_impl="chunked", attn_chunk=16,
                      compute_dtype="float32", remat="none")
    batch = steps_mod.make_batch(cfg, rc_ref, jax.random.PRNGKey(1))
    la = float(steps_mod.loss_fn(cfg, params, batch, rc_ref))
    lb = float(steps_mod.loss_fn(cfg, params, batch, rc_ch))
    assert abs(la - lb) < 1e-4


def test_cells_cover_40_assignments():
    all_cells = list(cells(include_skipped=True))
    assert len(all_cells) == 40
    runnable = list(cells())
    skipped = 40 - len(runnable)
    # long_500k runs only for the sub-quadratic families
    assert skipped == len(ARCHS) - len(LONG_OK)
    for arch in ARCHS:
        assert get_config(arch).name == arch
