"""DLM-covered OSC clean read cache + readahead (ISSUE-4 tentpole).

Covers the acceptance criteria:
  * a sequential re-read of a cached striped file issues ZERO OST_READ
    RPCs (and, via LVB-served getattr, zero RPCs at all);
  * a 2-client write-after-read scenario proves blocking-AST
    invalidation — the reader sees the new data, never a stale cache;
  * eviction/cancel/disconnect paths invalidate too;
  * the seek-aware BRW cost model charges scattered niobuf vectors more
    than contiguous ones.
"""
import pytest

from repro.core import LustreCluster
from repro.core import dlm as D
from repro.core import ptlrpc as R
from repro.fsio import LustreClient


def mk(**kw):
    kw.setdefault("osts", 4)
    kw.setdefault("mdses", 1)
    kw.setdefault("clients", 3)
    kw.setdefault("commit_interval", 256)
    return LustreCluster(**kw)


def reads(c):
    return c.stats.counters.get("rpc.ost.read", 0)


def rpcs(c):
    """Every OST-bound RPC (read, getattr, enqueue, ...)."""
    return sum(n for k, n in c.stats.counters.items()
               if k.startswith("rpc.ost."))


# --------------------------------------------------------- osc-level cache

def test_reread_served_from_clean_cache_zero_rpcs():
    c = mk()
    osc = c.make_oscs(c.make_client_rpc(0))[0]
    oid = osc.create(0)["oid"]
    osc.write(0, oid, 0, b"x" * 8192)
    osc.flush()
    assert osc.read(0, oid, 0, 8192) == b"x" * 8192   # promoted at flush
    base = reads(c)
    for _ in range(4):
        assert osc.read(0, oid, 0, 8192) == b"x" * 8192
        assert osc.read(0, oid, 100, 50) == b"x" * 50
    assert reads(c) == base                    # all hits, zero OST_READs
    assert c.stats.counters["osc.cache_hit"] >= 8


def test_cold_read_populates_cache():
    c = mk()
    w = c.make_oscs(c.make_client_rpc(0), writeback=False)[0]
    oid = w.create(0)["oid"]
    w.write(0, oid, 0, bytes(range(256)) * 16)         # 4 KiB
    r = c.make_oscs(c.make_client_rpc(1))[0]
    assert r.read(0, oid, 0, 4096) == bytes(range(256)) * 16
    base = reads(c)
    assert r.read(0, oid, 1024, 512) == (bytes(range(256)) * 16)[1024:1536]
    assert reads(c) == base                    # sub-range hit, no RPC
    assert c.stats.counters["osc.cache_miss"] >= 1
    assert c.stats.counters["osc.cache_hit"] >= 1


def test_blocking_ast_drops_clean_pages():
    """ISSUE-4 bugfix: revocation must invalidate CLEAN pages, not just
    flush dirty ones — without it a second client's write leaves the
    first client's cache permanently stale."""
    c = mk()
    a = c.make_oscs(c.make_client_rpc(0))[0]
    b = c.make_oscs(c.make_client_rpc(1))[0]
    oid = a.create(0)["oid"]
    a.write(0, oid, 0, b"old-old-")
    a.flush()
    assert a.read(0, oid, 0, 8) == b"old-old-"         # cached clean
    assert a.clean_bytes > 0
    b.write(0, oid, 0, b"new-new-")                    # AST revokes a's lock
    b.flush()
    assert a.clean_bytes == 0                          # pages invalidated
    assert a.read(0, oid, 0, 8) == b"new-new-"         # never stale
    assert c.stats.counters["osc.cache_invalidate"] >= 1


def test_cancel_invalidates_clean_pages():
    c = mk()
    osc = c.make_oscs(c.make_client_rpc(0))[0]
    oid = osc.create(0)["oid"]
    osc.write(0, oid, 0, b"d" * 4096)
    osc.flush()
    assert osc.read(0, oid, 0, 4096) == b"d" * 4096
    assert osc.clean_bytes > 0
    osc.locks.cancel_all()
    assert osc.clean_bytes == 0                # cancel dropped the pages
    base = reads(c)
    assert osc.read(0, oid, 0, 4096) == b"d" * 4096
    assert reads(c) == base + 1                # re-fetched from the OST


def test_eviction_drops_locks_dirty_and_clean_state():
    """ISSUE-4 satellite: after rpc.evicted_reconnect the OSC must not
    keep locks, dirty extents, clean pages, or the grant."""
    c = LustreCluster(osts=1, mdses=1, clients=2, commit_interval=8)
    a = c.make_oscs(c.make_client_rpc(0))[0]
    b = c.make_oscs(c.make_client_rpc(1), writeback=False)[0]
    oid = a.create(0)["oid"]
    a.write(0, oid, 0, b"doomed-dirty")        # cached under a PW lock
    a.read(0, oid, 0, 4)                       # and some clean state
    assert a.dirty_bytes > 0 and a.locks.locks
    # a goes silent; b's conflicting lock evicts it server-side (§7.4)
    c.sim.faults.down_nids.add(a.rpc.nid)
    b.lock(0, oid, "PW", (0, 100))
    assert c.stats.counters["dlm.evictions"] == 1
    c.sim.faults.down_nids.discard(a.rpc.nid)  # a comes back...
    assert a.statfs()["capacity"] > 0          # -107 -> reconnect cycle
    assert c.stats.counters["rpc.evicted_reconnect"] >= 1
    assert a.dirty_bytes == 0 and a.dirty == []     # dirty data LOST
    assert a.clean_bytes == 0 and not a.locks.locks
    assert c.stats.counters["osc.evicted"] >= 1


def test_lru_budget_bounds_cache():
    c = mk(max_cached_mb=1)                    # 1 MiB budget via cluster knob
    osc = c.make_oscs(c.make_client_rpc(0))[0]
    oid = osc.create(0)["oid"]
    chunk = 256 << 10
    for i in range(8):                         # 2 MiB through a 1 MiB cache
        osc.write(0, oid, i * chunk, bytes([i]) * chunk)
        osc.flush()
    assert osc.clean_bytes <= 1 << 20
    assert c.stats.counters["osc.cache_lru_evict"] >= 1
    # unevicted tail still hits; evicted head re-fetches, both correct
    assert osc.read(0, oid, 7 * chunk, chunk) == bytes([7]) * chunk
    assert osc.read(0, oid, 0, chunk) == bytes([0]) * chunk


def test_max_cached_mb_zero_disables_cache():
    c = mk()
    osc = c.make_oscs(c.make_client_rpc(0), max_cached_mb=0)[0]
    oid = osc.create(0)["oid"]
    osc.write(0, oid, 0, b"z" * 4096)
    osc.flush()
    base = reads(c)
    osc.read(0, oid, 0, 4096)
    osc.read(0, oid, 0, 4096)
    assert reads(c) == base + 2                # every read pays an RPC
    assert osc.clean_bytes == 0


# ----------------------------------------------------- fsio acceptance

def test_sequential_reread_of_striped_file_zero_ost_reads():
    """Acceptance: sequential re-read of a cached striped file = 0
    OST_READ RPCs (the warm path is zero OST RPCs of ANY kind: size
    checks ride the cached locks' LVBs)."""
    c = mk()
    fs = LustreClient(c).mount()
    fh = fs.creat("/seq.bin", stripe_count=4, stripe_size=1 << 18)
    data = bytes(range(256)) * 4096            # 1 MiB over 4 stripes
    fs.write(fh, data)
    fs.fsync(fh)
    chunk = 64 << 10
    out = b"".join(fs.read(fh, chunk, offset=off)
                   for off in range(0, len(data), chunk))
    assert out == data                         # cold pass populates
    base_reads, base_all = reads(c), rpcs(c)
    out = b"".join(fs.read(fh, chunk, offset=off)
                   for off in range(0, len(data), chunk))
    assert out == data
    assert reads(c) == base_reads              # ZERO OST_READ RPCs
    assert rpcs(c) == base_all                 # and zero OST RPCs at all


def test_readahead_cuts_cold_read_rpcs_4x():
    """Acceptance: readahead cuts the cold sequential-read RPC count by
    >= 4x vs readahead disabled."""
    def cold_rpcs(ra_pages):
        c = mk(readahead_pages=ra_pages)
        w = LustreClient(c, 0).mount()
        fh = w.creat("/ra.bin", stripe_count=4, stripe_size=1 << 20)
        data = b"R" * (4 << 20)
        w.write(fh, data)
        w.fsync(fh)
        r = LustreClient(c, 1).mount()         # cold client cache
        fh2 = r.open("/ra.bin")
        base = reads(c)
        chunk = 64 << 10
        out = b"".join(r.read(fh2, chunk) for _ in range(len(data) // chunk))
        assert out == data
        return reads(c) - base
    no_ra = cold_rpcs(0)
    with_ra = cold_rpcs(256)
    assert with_ra * 4 <= no_ra, (no_ra, with_ra)


def test_readahead_fans_out_one_vectored_read_per_stripe():
    """A readahead window spanning stripe objects is fetched as ONE
    vectored OST_READ per stripe object."""
    c = mk(readahead_pages=256)                # 1 MiB window
    w = LustreClient(c, 0).mount()
    fh = w.creat("/fan.bin", stripe_count=4, stripe_size=1 << 16)  # 64 KiB
    data = b"F" * (1 << 20)
    w.write(fh, data)
    w.fsync(fh)
    r = LustreClient(c, 1).mount()
    fh2 = r.open("/fan.bin")
    base = reads(c)
    r.read(fh2, 4096)                          # sequential start at 0
    # miss (<=1 RPC) + a window striped over 4 objects: the window fetch
    # costs at most one vectored OST_READ per stripe object
    assert c.stats.counters["lov.readahead"] >= 1
    assert reads(c) - base <= 1 + 4
    assert fh2.ra_pos > 4096                   # window fetched ahead
    # read the WHOLE file in 4 KiB chunks: 256 chunk reads collapse into
    # a handful of vectored window fetches (<= 4 RPCs each), everything
    # else is served from the clean cache
    while fh2.pos < len(data):
        r.read(fh2, 4096)
    assert reads(c) - base <= 32               # vs 256 without readahead
    assert c.stats.counters["osc.cache_hit"] >= 200


def test_seek_resets_readahead_window():
    c = mk(readahead_pages=16)
    fs = LustreClient(c).mount()
    fh = fs.creat("/rand.bin", stripe_count=1)
    fs.write(fh, b"r" * (1 << 20))
    fs.fsync(fh)
    fs.read(fh, 4096, offset=0)
    assert fh.ra_window > 0
    fs.read(fh, 4096, offset=512 << 10)        # seek: detector resets
    assert fh.ra_window == 0


def test_backward_seek_rescan_readahead_still_batches():
    """A backward seek must also reset the fetch horizon (ra_pos): after
    invalidation, re-scanning an already-read range has to readahead
    again, not degrade to one RPC per chunk."""
    c = mk(readahead_pages=256)
    w = LustreClient(c, 0).mount()
    fh = w.creat("/scan.bin", stripe_count=4, stripe_size=1 << 20)
    data = b"1" * (2 << 20)
    w.write(fh, data)
    w.fsync(fh)
    r = LustreClient(c, 1).mount()
    fh2 = r.open("/scan.bin")
    while fh2.pos < len(data):                 # full sequential pass
        r.read(fh2, 64 << 10)
    w.write(fh, b"2" * len(data), offset=0)    # invalidates r's cache
    w.fsync(fh)
    base = reads(c)
    out = b"".join(r.read(fh2, 64 << 10, offset=off)
                   for off in range(0, len(data), 64 << 10))
    assert out == b"2" * len(data)
    assert reads(c) - base <= 12, reads(c) - base   # batched, not 32x 1-RPC


def test_write_after_read_two_clients_never_stale():
    """Acceptance: reader caches a striped file; a second client
    overwrites it; the reader sees the new data (AST invalidation), never
    the stale cache."""
    c = mk()
    r = LustreClient(c, 0).mount()
    w = LustreClient(c, 1).mount()
    fh_w = w.creat("/shared.bin", stripe_count=4, stripe_size=1 << 16)
    v1 = b"1" * (512 << 10)
    w.write(fh_w, v1)
    w.fsync(fh_w)
    fh_r = r.open("/shared.bin")
    assert r.read(fh_r, len(v1), offset=0) == v1       # cached
    assert r.read(fh_r, len(v1), offset=0) == v1       # warm hit
    v2 = b"2" * (512 << 10)
    w.write(fh_w, v2, offset=0)                # revokes r's PR locks
    w.fsync(fh_w)
    assert r.read(fh_r, len(v2), offset=0) == v2       # sees NEW data
    # and the writer's dirty-cache variant: don't even flush
    v3 = b"3" * (512 << 10)
    w.write(fh_w, v3, offset=0)                # sits dirty under PW
    assert r.read(fh_r, len(v3), offset=0) == v3       # AST flushed + fresh
    w.close(fh_w)
    r.close(fh_r)


def test_mds_eviction_purges_dentry_cache():
    """Satellite: eviction by the MDS drops cached dentries + their
    locks (not just the replay queue)."""
    c = LustreCluster(osts=1, mdses=1, clients=1, commit_interval=8)
    fs = LustreClient(c).mount()
    fs.mkdir("/d")
    fs.creat("/d/f")
    fs.stat("/d/f")                            # populate dcache
    assert fs.dcache
    mds = c.mds_targets[0]
    mds.evicted.add(fs.rpc.uuid)               # server-side eviction
    mds.ldlm.evict_client(fs.rpc.uuid)
    # the client only learns of the eviction when it next talks to the
    # MDS (a warm stat is served from the attr/dentry caches with zero
    # RPCs since ISSUE-5) — force one RPC, then everything purges
    fs.mkdir("/d2")                            # -107 -> reconnect + purge
    assert c.stats.counters["fs.evicted_invalidate"] >= 1
    assert not fs.attr_cache
    assert fs.stat("/d/f")["type"] == "file"   # re-fetched, still correct
    assert c.stats.counters["rpc.evicted_reconnect"] >= 1


# ------------------------------------------------ covers() regression

def test_cached_cr_lock_does_not_satisfy_pr():
    """ISSUE-4 satellite: Lock.covers had a dead if/pass branch; the real
    mode-strength check must refuse CR-for-PR."""
    cr = D.Lock(1, ("ext", 0, 1), "CR", (0, 1000), "c", "n", granted=True)
    assert not cr.covers("PR", (0, 10))
    assert not cr.covers("PW", (0, 10))
    assert cr.covers("CR", (0, 10))
    assert cr.covers("NL", (0, 10))


def test_mode_strength_matches_vms_matrix():
    for held in D.MODES:
        for req in D.MODES:
            if D.mode_covers(held, req):
                # holding `held` must protect at least as much as `req`
                for other in D.MODES:
                    assert D._C[held][other] <= D._C[req][other], \
                        (held, req, other)
    assert D.mode_covers("PW", "PR") and D.mode_covers("EX", "PW")
    assert not D.mode_covers("PR", "PW") and not D.mode_covers("NL", "CR")


# ---------------------------------------------- seek-aware BRW costs

def test_scattered_niobufs_cost_more_than_contiguous():
    c = mk()
    svc = c.ost_targets[0].service
    pg = 4096
    contig = R.Request(opcode="write", body={"niobufs": [
        {"offset": i * pg, "data": b"x" * pg} for i in range(8)]})
    scattered = R.Request(opcode="write", body={"niobufs": [
        {"offset": i * 10 * pg, "data": b"x" * pg} for i in range(8)]})
    c_cost = svc.request_cost(contig)
    s_cost = svc.request_cost(scattered)
    assert s_cost > c_cost
    # 8 seeks vs 1 seek, same pages
    assert abs((s_cost - c_cost) - 7 * svc.seek_cost) < 1e-12


def test_contiguous_runs_charge_one_seek_plus_pages():
    c = mk()
    svc = c.ost_targets[0].service
    pg = 4096
    req = R.Request(opcode="read", body={"niobufs": [
        {"offset": 0, "length": pg}, {"offset": pg, "length": pg},
        {"offset": 2 * pg, "length": 2 * pg}]})
    assert abs(svc.request_cost(req)
               - (svc.cpu_cost + svc.seek_cost + 4 * svc.page_cost)) < 1e-12


def test_non_bulk_request_costs_cpu_only():
    c = mk()
    svc = c.ost_targets[0].service
    req = R.Request(opcode="getattr", body={"group": 0, "oid": 1})
    assert svc.request_cost(req) == svc.cpu_cost


def test_nrs_sees_scatter_cost():
    """End-to-end: the seek count lands in the stats the NRS/benchmarks
    read."""
    c = mk()
    osc = c.make_oscs(c.make_client_rpc(0))[0]
    oid = osc.create(0)["oid"]
    for i in range(4):
        osc.write(0, oid, i * 40960, b"s" * 4096)      # scattered runs
    osc.flush()
    assert c.stats.counters["nrs.seeks"] >= 4


# ------------------------------------------------------------- procfs

def test_cache_stats_in_procfs():
    c = mk()
    fs = LustreClient(c).mount()
    fh = fs.creat("/p.bin", stripe_count=1)
    fs.write(fh, b"p" * 8192)
    fs.fsync(fh)
    fs.read(fh, 8192, offset=0)
    fs.read(fh, 8192, offset=0)
    p = c.procfs()
    cc = p["client_cache"]
    assert cc["hits"] >= 1
    assert 0.0 <= cc["hit_rate"] <= 1.0
    assert "osc.cache_hit" in p["counters"]
