"""LOV striping + RAID1 (paper ch. 10, 15, 20)."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # bare env: sampled fallback
    from _hyposhim import given, settings, strategies as st

from repro.core import LustreCluster
from repro.core import lov as LV


def mk(osts=4, policy="round_robin"):
    c = LustreCluster(osts=osts, mdses=1, clients=1, commit_interval=32)
    rpc = c.make_client_rpc(0)
    lov = c.make_lov(rpc, policy=policy)
    return c, lov


def test_chunks_mapping_round_trip():
    lsm = LV.StripeMd(stripe_size=100, stripe_count=3, stripe_offset=0,
                      objects=[])
    runs = LV._chunks(lsm, 0, 1000)
    # every logical byte covered exactly once
    covered = sorted((lpos, lpos + ln) for _, _, ln, lpos in runs)
    pos = 0
    for a, b in covered:
        assert a == pos
        pos = b
    assert pos == 1000
    # stripe index round-robins
    assert [r[0] for r in runs[:4]] == [0, 1, 2, 0]


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 4), st.integers(16, 257),
       st.lists(st.tuples(st.integers(0, 2000),
                          st.binary(min_size=1, max_size=513)),
                min_size=1, max_size=8))
def test_striped_write_read_random_extents(cnt, ssz, writes):
    """Property: arbitrary overlapping striped writes == a flat buffer."""
    c, lov = mk()
    lsm = lov.create(stripe_count=cnt, stripe_size=ssz)
    shadow = bytearray()
    for off, data in writes:
        lov.write(lsm, off, data)
        if off + len(data) > len(shadow):
            shadow.extend(b"\0" * (off + len(data) - len(shadow)))
        shadow[off:off + len(data)] = data
    lov.flush()
    assert lov.getattr(lsm)["size"] == len(shadow)
    assert lov.read(lsm, 0, len(shadow)) == bytes(shadow)
    # random sub-extent
    if len(shadow) > 3:
        a, b = len(shadow) // 3, 2 * len(shadow) // 3
        assert lov.read(lsm, a, b - a) == bytes(shadow[a:b])


def test_logical_size_formula():
    lsm = LV.StripeMd(stripe_size=10, stripe_count=3, stripe_offset=0,
                      objects=[])
    # obj0 has 2 full stripes (20B): last byte at logical ((1)*3+0)*10+9=39
    assert LV.logical_size(lsm, [20, 0, 0]) == 40
    assert LV.logical_size(lsm, [10, 5, 0]) == 15
    assert LV.logical_size(lsm, [0, 0, 0]) == 0


def test_punch_truncates_per_object():
    c, lov = mk()
    lsm = lov.create(stripe_count=4, stripe_size=16)
    lov.write(lsm, 0, bytes(range(256)))
    lov.flush()
    lov.punch(lsm, 100)
    assert lov.getattr(lsm)["size"] == 100
    assert lov.read(lsm, 0, 100) == bytes(range(100))


def test_parallel_stripes_overlap_in_virtual_time():
    """N stripes on N OSTs must take ~1/N the time of 1 stripe on 1 OST."""
    c1, lov1 = mk(osts=1)
    c4, lov4 = mk(osts=4)
    data = bytes(1024) * 512                     # 512 KiB
    lsm1 = lov1.create(stripe_count=1, stripe_size=1 << 16)
    t0 = c1.now
    lov1.write(lsm1, 0, data)
    lov1.oscs[0].flush()
    t1 = c1.now - t0
    lsm4 = lov4.create(stripe_count=4, stripe_size=1 << 16)
    t0 = c4.now
    lov4.write(lsm4, 0, data)
    lov4.flush()
    t4 = c4.now - t0
    assert t4 < t1 / 2                           # real parallel speedup


def test_free_space_policy_prefers_empty_ost():
    c, lov = mk(policy="free_space")
    # fill OST0 substantially
    big = lov.create(stripe_count=1, stripe_offset=0)
    lov.write(big, 0, b"x" * (1 << 20))
    lov.flush()
    lsm = lov.create(stripe_count=1)
    assert lsm.stripe_offset != 0


def test_stripe_offset_pins_allocation():
    c, lov = mk()
    lsm = lov.create(stripe_count=2, stripe_offset=2)
    assert lsm.objects[0]["ost"] == "OST0002"
    assert lsm.objects[1]["ost"] == "OST0003"


def test_raid1_mirror_write_and_failover_read():
    c = LustreCluster(osts=2, mdses=1, clients=1, commit_interval=4)
    rpc = c.make_client_rpc(0)
    a, b = c.make_oscs(rpc, writeback=False)
    r = LV.Raid1(a, b)
    oid = r.create()
    r.write(oid, 0, b"mirrored")
    for t in c.ost_targets:
        t.commit()
    c.fail_node("ost0")
    assert r.read(oid, 0, 8) == b"mirrored"
    assert c.stats.counters["raid1.failover_read"] == 1


def test_raid1_degraded_write_and_resync():
    c = LustreCluster(osts=2, mdses=1, clients=1, commit_interval=4)
    rpc = c.make_client_rpc(0)
    a, b = c.make_oscs(rpc, writeback=False)
    r = LV.Raid1(a, b)
    oid = r.create()
    r.write(oid, 0, b"00000000")
    for t in c.ost_targets:
        t.commit()
    c.fail_node("ost1")
    r.write(oid, 0, b"11111111")              # degraded: only mirror A
    assert c.stats.counters["raid1.degraded_write"] == 1
    c.restart_node("ost1")
    assert r.resync() == 1
    assert b.read(0, oid, 0, 8) == b"11111111"


# ------------------------------------------------- ISSUE-1 edge cases

def test_chunks_zero_length_emits_no_runs():
    lsm = LV.StripeMd(stripe_size=100, stripe_count=3, stripe_offset=0,
                      objects=[])
    assert LV._chunks(lsm, 0, 0) == []
    assert LV._chunks(lsm, 250, 0) == []
    assert LV._chunks(lsm, 10, -5) == []      # defensive: negative length


def test_chunks_boundary_end_has_no_empty_run():
    lsm = LV.StripeMd(stripe_size=100, stripe_count=3, stripe_offset=0,
                      objects=[])
    for off, ln in ((0, 100), (50, 50), (0, 300), (100, 200), (299, 1)):
        runs = LV._chunks(lsm, off, ln)
        assert all(r[2] > 0 for r in runs), (off, ln, runs)
        assert sum(r[2] for r in runs) == ln


def test_chunks_single_stripe_runs_merge():
    """stripe_count=1: object-contiguous runs coalesce into one niobuf."""
    lsm = LV.StripeMd(stripe_size=100, stripe_count=1, stripe_offset=0,
                      objects=[])
    assert LV._chunks(lsm, 0, 250) == [(0, 0, 250, 0)]


def test_chunks_degenerate_geometry():
    bad = LV.StripeMd(stripe_size=0, stripe_count=0, stripe_offset=0,
                      objects=[])
    assert LV._chunks(bad, 0, 100) == []      # no divide-by-zero


def test_logical_size_exact_boundary():
    lsm = LV.StripeMd(stripe_size=100, stripe_count=3, stripe_offset=0,
                      objects=[])
    # object 0 holding exactly 2 full stripes -> logical bytes 0-99+300-399
    assert LV.logical_size(lsm, [200, 0, 0]) == 400
    assert LV.logical_size(lsm, [100, 100, 100]) == 300
    assert LV.logical_size(lsm, []) == 0
    # stray object sizes beyond stripe_count are ignored
    assert LV.logical_size(lsm, [0, 0, 0, 500]) == 0


def test_zero_length_write_read_end_to_end():
    c, lov = mk()
    lsm = lov.create(stripe_count=2, stripe_size=4096)
    assert lov.write(lsm, 0, b"") == 0
    assert lov.read(lsm, 0, 0) == b""
    assert lov.getattr(lsm)["size"] == 0


def test_boundary_write_then_read_round_trip():
    c, lov = mk()
    lsm = lov.create(stripe_count=2, stripe_size=4096)
    data = bytes(range(256)) * 32             # exactly 2 stripes
    assert lov.write(lsm, 0, data) == len(data)
    lov.flush()
    assert lov.getattr(lsm)["size"] == len(data)
    assert lov.read(lsm, 0, len(data)) == data


# ------------------------------------------------- ISSUE-8: raid5 / SNS

from repro.core import ptlrpc as R  # noqa: E402


def mk5(osts=3, spares=0, clients=2):
    c = LustreCluster(osts=osts, mdses=1, clients=clients,
                      commit_interval=32, spare_osts=spares)
    rpc = c.make_client_rpc(0)
    lov = c.make_lov(rpc)
    return c, lov


def _r5_payload(n, seed=1):
    rng = np.random.default_rng(seed)
    return rng.integers(1, 256, n, dtype=np.uint8).tobytes()  # non-zero


def test_r5_parity_rotation_geometry():
    lsm = LV.StripeMd(stripe_size=10, stripe_count=2, stripe_offset=0,
                      objects=[], pattern="raid5")
    # n=3 slots; the parity slot walks right-to-left one slot per round
    assert [LV._r5_parity_slot(lsm, r) for r in range(6)] == \
        [2, 1, 0, 2, 1, 0]
    # in every round the data units occupy exactly the non-parity slots
    for r in range(6):
        p = LV._r5_parity_slot(lsm, r)
        slots = [LV._r5_slot(lsm, r, i) for i in range(2)]
        assert sorted(slots + [p]) == [0, 1, 2]


def test_r5_logical_size_witnesses():
    lsm = LV.StripeMd(stripe_size=10, stripe_count=2, stripe_offset=0,
                      objects=[], pattern="raid5")
    # 25 logical bytes: slot sizes are [15, 15, 10] (parity unit length
    # mirrors data unit 0's extent in each round)
    assert LV._r5_logical_size(lsm, [15, 15, 10]) == 25
    # a parity-only witness still pins the size (unit 0's extent)
    assert LV._r5_logical_size(lsm, [0, 0, 10]) == 10
    assert LV._r5_logical_size(lsm, [0, 0, 0]) == 0
    assert LV._r5_logical_size(lsm, [None, 15, 10]) == 25  # dead slot


def test_raid5_round_trip_odd_size_and_rmw():
    c, lov = mk5()
    lsm = lov.create(stripe_count=2, stripe_size=512, stripe_offset=0,
                     pattern="raid5")
    assert lsm.pattern == "raid5" and len(lsm.objects) == 3
    data = _r5_payload(5037)                  # ragged tail unit
    lov.write(lsm, 0, data)
    assert lov.read(lsm, 0, len(data)) == data
    assert lov.getattr(lsm)["size"] == len(data)
    # read-modify-write strictly inside one unit + spanning a round
    patch = b"\xaa" * 700
    lov.write(lsm, 300, patch)
    want = data[:300] + patch + data[1000:]
    assert lov.read(lsm, 0, len(want)) == want
    assert lov.getattr(lsm)["size"] == len(want)


def test_raid5_ea_round_trip_preserves_pattern():
    c, lov = mk5()
    lsm = lov.create(stripe_count=2, stripe_size=256, pattern="raid5")
    back = LV.StripeMd.from_ea(lsm.to_ea())
    assert back.pattern == "raid5"
    assert back.objects == lsm.objects
    # pre-raid5 EAs (no pattern key) still decode as raid0
    ea = lsm.to_ea()
    ea.pop("pattern", None)
    assert LV.StripeMd.from_ea(ea).pattern == "raid0"


def test_raid5_degraded_read_is_byte_identical():
    c, lov = mk5()
    lsm = lov.create(stripe_count=2, stripe_size=512, stripe_offset=0,
                     pattern="raid5")
    data = _r5_payload(5037)
    lov.write(lsm, 0, data)
    for t in c.ost_targets:
        t.commit()
    dead = lsm.objects[1]["ost"]
    c.fail_node("ost" + str(int(dead[3:])))
    # a COLD client must reconstruct from surviving stripes + parity
    # (the writer's own clean cache would serve the bytes without RPCs)
    cold = c.make_lov(c.make_client_rpc(1))
    assert cold.read(lsm, 0, len(data)) == data
    assert c.stats.counters["lov.degraded_read"] >= 1
    assert c.stats.counters["lov.reconstruct_unit"] >= 1
    # size survives the dead slot too
    assert cold.getattr(lsm)["size"] == len(data)


def test_raid5_degraded_write_and_parity_update():
    c, lov = mk5()
    lsm = lov.create(stripe_count=2, stripe_size=256, stripe_offset=0,
                     pattern="raid5")
    data = _r5_payload(2048, seed=3)
    lov.write(lsm, 0, data)
    for t in c.ost_targets:
        t.commit()
    dead = lsm.objects[0]["ost"]
    c.fail_node("ost" + str(int(dead[3:])))
    patch = _r5_payload(512, seed=4)
    lov.write(lsm, 0, patch)                  # slot 0 dead: parity absorbs
    assert c.stats.counters["lov.degraded_write"] >= 1
    want = patch + data[512:]
    cold = c.make_lov(c.make_client_rpc(1))
    assert cold.read(lsm, 0, len(want)) == want


def test_raid5_second_failure_is_an_error_not_garbage():
    c, lov = mk5(osts=4)
    lsm = lov.create(stripe_count=3, stripe_size=256, stripe_offset=0,
                     pattern="raid5")
    lov.write(lsm, 0, _r5_payload(3000))
    c.fail_node("ost0")
    c.fail_node("ost1")
    with pytest.raises(R.RpcError):
        c.make_lov(c.make_client_rpc(1)).read(lsm, 0, 3000)


def test_raid5_rebuild_onto_spare_and_layout_swap():
    c, lov = mk5(spares=1)
    lsm = lov.create(stripe_count=2, stripe_size=256, stripe_offset=0,
                     pattern="raid5")
    data = _r5_payload(3333, seed=7)
    lov.write(lsm, 0, data)
    for t in c.ost_targets:
        t.commit()
    dead = lsm.objects[1]["ost"]
    c.fail_node("ost" + str(int(dead[3:])))
    spare_uuid = c.spare_uuids[0]
    new = lov.rebuild_object(lsm, dead, lov.by_uuid[spare_uuid])
    assert new.objects[1]["ost"] == spare_uuid
    assert [o["ost"] for o in new.objects[::2]] == \
        [o["ost"] for o in lsm.objects[::2]]  # live slots untouched
    assert c.stats.counters["lov.rebuild_object"] == 1
    assert c.stats.counters["lov.rebuild_bytes"] > 0
    # the rebuilt layout serves reads with the dead OST still down
    cold = c.make_lov(c.make_client_rpc(1))
    assert cold.read(new, 0, len(data)) == data
    # and now survives a SECOND (different) OST failing
    other = new.objects[0]["ost"]
    c.fail_node("ost" + str(int(other[3:])))
    cold2 = c.make_lov(c.make_client_rpc(1))
    assert cold2.read(new, 0, len(data)) == data


def test_raid5_punch_recomputes_tail_parity():
    c, lov = mk5()
    lsm = lov.create(stripe_count=2, stripe_size=256, stripe_offset=0,
                     pattern="raid5")
    data = _r5_payload(2048, seed=9)
    lov.write(lsm, 0, data)
    lov.punch(lsm, 700)                       # mid-unit truncate
    assert lov.getattr(lsm)["size"] == 700
    for t in c.ost_targets:
        t.commit()
    # parity of the truncated tail round must cover the new content:
    # fail a data OST and reconstruct through the truncation point
    dead = lsm.objects[0]["ost"]
    c.fail_node("ost" + str(int(dead[3:])))
    cold = c.make_lov(c.make_client_rpc(1))
    assert cold.read(lsm, 0, 700) == data[:700]


# --------------------------------------- ISSUE-8: RAID1 stale-data fixes

def _mk_raid1():
    c = LustreCluster(osts=2, mdses=1, clients=2, commit_interval=4)
    rpc = c.make_client_rpc(0)
    a, b = c.make_oscs(rpc, writeback=False)
    r = LV.Raid1(a, b)
    oid = r.create()
    return c, r, a, b, oid


def test_raid1_resync_primary_side_stale():
    """Regression (ISSUE-8 satellite 1): when the PRIMARY missed the
    write, resync must copy b->a — the old primary-first read replayed
    a's stale bytes over the up-to-date secondary."""
    c, r, a, b, oid = _mk_raid1()
    r.write(oid, 0, b"00000000")
    for t in c.ost_targets:
        t.commit()
    c.fail_node("ost0")                       # primary down
    r.write(oid, 0, b"11111111")              # only mirror B took it
    assert c.stats.counters["raid1.degraded_write"] == 1
    assert r.dirty_log[-1][3] == "a"          # the STALE side is recorded
    c.restart_node("ost0")
    assert r.resync() == 1
    assert a.read(0, oid, 0, 8) == b"11111111"   # healed, not clobbered
    assert b.read(0, oid, 0, 8) == b"11111111"


def test_raid1_read_heals_stale_primary_before_serving():
    c, r, a, b, oid = _mk_raid1()
    r.write(oid, 0, b"00000000")
    for t in c.ost_targets:
        t.commit()
    c.fail_node("ost0")
    r.write(oid, 0, b"11111111")
    c.restart_node("ost0")
    assert r.read(oid, 0, 8) == b"11111111"   # not a's stale zeros
    assert c.stats.counters["raid1.heal_on_read"] == 1
    assert not r.dirty_log
    assert a.read(0, oid, 0, 8) == b"11111111"


def test_raid1_failover_read_never_serves_stale_secondary():
    """Regression (satellite 2): secondary missed a write (dropped
    OST_WRITE), then the primary dies — failover must NOT hand out the
    secondary's stale bytes; -5 beats silently wrong data."""
    c, r, a, b, oid = _mk_raid1()
    r.write(oid, 0, b"fresh000")
    for t in c.ost_targets:
        t.commit()
    b_nid = c.ost_targets[1].node.nid
    c.sim.faults.drop_next[b_nid] += 1000     # OST_WRITE (+ resends) lost
    r.write(oid, 0, b"fresh111")
    c.sim.faults.drop_next[b_nid] = 0
    assert r.dirty_log[-1][3] == "b"
    c.fail_node("ost0")                       # up-to-date mirror dies
    with pytest.raises(R.RpcError):
        r.read(oid, 0, 8)
    assert c.stats.counters["raid1.stale_read_avoided"] >= 1
    c.restart_node("ost0")
    assert r.read(oid, 0, 8) == b"fresh111"   # served from the good side
    assert r.resync() == 1                    # and b can heal now
    assert b.read(0, oid, 0, 8) == b"fresh111"


def test_raid1_hedged_read_uses_loser_result_no_reissue():
    """Regression (satellite 4): when the race winner FAILED, the old
    code re-issued a full read() — a third RPC and a second chance to
    hit the slow path. The loser already ran; its bytes are used as-is."""
    c, r, a, b, oid = _mk_raid1()
    r.write(oid, 0, b"hedgedat")
    for t in c.ost_targets:
        t.commit()
    # cold reader client: mirror A administratively dead (fails fast,
    # wins the race with an error), mirror B must serve over the wire
    rpc2 = c.make_client_rpc(1)
    a2, b2 = c.make_oscs(rpc2, writeback=False)
    r2 = LV.Raid1(a2, b2)
    a2.set_active(False)
    before = c.stats.counters.get("rpc.ost.read", 0)
    assert r2.read_hedged(oid, 0, 8) == b"hedgedat"
    assert c.stats.counters["raid1.hedge_loser_used"] == 1
    assert c.stats.counters.get("rpc.ost.read", 0) - before == 1


def test_raid1_hedged_read_takes_dirty_aware_path():
    c, r, a, b, oid = _mk_raid1()
    r.write(oid, 0, b"00000000")
    for t in c.ost_targets:
        t.commit()
    c.fail_node("ost1")
    r.write(oid, 0, b"22222222")              # b is stale now
    c.restart_node("ost1")
    assert r.read_hedged(oid, 0, 8) == b"22222222"   # never b's zeros
    assert r.resync() == 1
    assert b.read(0, oid, 0, 8) == b"22222222"
