"""LOV striping + RAID1 (paper ch. 10, 15, 20)."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # bare env: sampled fallback
    from _hyposhim import given, settings, strategies as st

from repro.core import LustreCluster
from repro.core import lov as LV


def mk(osts=4, policy="round_robin"):
    c = LustreCluster(osts=osts, mdses=1, clients=1, commit_interval=32)
    rpc = c.make_client_rpc(0)
    lov = c.make_lov(rpc, policy=policy)
    return c, lov


def test_chunks_mapping_round_trip():
    lsm = LV.StripeMd(stripe_size=100, stripe_count=3, stripe_offset=0,
                      objects=[])
    runs = LV._chunks(lsm, 0, 1000)
    # every logical byte covered exactly once
    covered = sorted((lpos, lpos + ln) for _, _, ln, lpos in runs)
    pos = 0
    for a, b in covered:
        assert a == pos
        pos = b
    assert pos == 1000
    # stripe index round-robins
    assert [r[0] for r in runs[:4]] == [0, 1, 2, 0]


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 4), st.integers(16, 257),
       st.lists(st.tuples(st.integers(0, 2000),
                          st.binary(min_size=1, max_size=513)),
                min_size=1, max_size=8))
def test_striped_write_read_random_extents(cnt, ssz, writes):
    """Property: arbitrary overlapping striped writes == a flat buffer."""
    c, lov = mk()
    lsm = lov.create(stripe_count=cnt, stripe_size=ssz)
    shadow = bytearray()
    for off, data in writes:
        lov.write(lsm, off, data)
        if off + len(data) > len(shadow):
            shadow.extend(b"\0" * (off + len(data) - len(shadow)))
        shadow[off:off + len(data)] = data
    lov.flush()
    assert lov.getattr(lsm)["size"] == len(shadow)
    assert lov.read(lsm, 0, len(shadow)) == bytes(shadow)
    # random sub-extent
    if len(shadow) > 3:
        a, b = len(shadow) // 3, 2 * len(shadow) // 3
        assert lov.read(lsm, a, b - a) == bytes(shadow[a:b])


def test_logical_size_formula():
    lsm = LV.StripeMd(stripe_size=10, stripe_count=3, stripe_offset=0,
                      objects=[])
    # obj0 has 2 full stripes (20B): last byte at logical ((1)*3+0)*10+9=39
    assert LV.logical_size(lsm, [20, 0, 0]) == 40
    assert LV.logical_size(lsm, [10, 5, 0]) == 15
    assert LV.logical_size(lsm, [0, 0, 0]) == 0


def test_punch_truncates_per_object():
    c, lov = mk()
    lsm = lov.create(stripe_count=4, stripe_size=16)
    lov.write(lsm, 0, bytes(range(256)))
    lov.flush()
    lov.punch(lsm, 100)
    assert lov.getattr(lsm)["size"] == 100
    assert lov.read(lsm, 0, 100) == bytes(range(100))


def test_parallel_stripes_overlap_in_virtual_time():
    """N stripes on N OSTs must take ~1/N the time of 1 stripe on 1 OST."""
    c1, lov1 = mk(osts=1)
    c4, lov4 = mk(osts=4)
    data = bytes(1024) * 512                     # 512 KiB
    lsm1 = lov1.create(stripe_count=1, stripe_size=1 << 16)
    t0 = c1.now
    lov1.write(lsm1, 0, data)
    lov1.oscs[0].flush()
    t1 = c1.now - t0
    lsm4 = lov4.create(stripe_count=4, stripe_size=1 << 16)
    t0 = c4.now
    lov4.write(lsm4, 0, data)
    lov4.flush()
    t4 = c4.now - t0
    assert t4 < t1 / 2                           # real parallel speedup


def test_free_space_policy_prefers_empty_ost():
    c, lov = mk(policy="free_space")
    # fill OST0 substantially
    big = lov.create(stripe_count=1, stripe_offset=0)
    lov.write(big, 0, b"x" * (1 << 20))
    lov.flush()
    lsm = lov.create(stripe_count=1)
    assert lsm.stripe_offset != 0


def test_stripe_offset_pins_allocation():
    c, lov = mk()
    lsm = lov.create(stripe_count=2, stripe_offset=2)
    assert lsm.objects[0]["ost"] == "OST0002"
    assert lsm.objects[1]["ost"] == "OST0003"


def test_raid1_mirror_write_and_failover_read():
    c = LustreCluster(osts=2, mdses=1, clients=1, commit_interval=4)
    rpc = c.make_client_rpc(0)
    a, b = c.make_oscs(rpc, writeback=False)
    r = LV.Raid1(a, b)
    oid = r.create()
    r.write(oid, 0, b"mirrored")
    for t in c.ost_targets:
        t.commit()
    c.fail_node("ost0")
    assert r.read(oid, 0, 8) == b"mirrored"
    assert c.stats.counters["raid1.failover_read"] == 1


def test_raid1_degraded_write_and_resync():
    c = LustreCluster(osts=2, mdses=1, clients=1, commit_interval=4)
    rpc = c.make_client_rpc(0)
    a, b = c.make_oscs(rpc, writeback=False)
    r = LV.Raid1(a, b)
    oid = r.create()
    r.write(oid, 0, b"00000000")
    for t in c.ost_targets:
        t.commit()
    c.fail_node("ost1")
    r.write(oid, 0, b"11111111")              # degraded: only mirror A
    assert c.stats.counters["raid1.degraded_write"] == 1
    c.restart_node("ost1")
    assert r.resync() == 1
    assert b.read(0, oid, 0, 8) == b"11111111"


# ------------------------------------------------- ISSUE-1 edge cases

def test_chunks_zero_length_emits_no_runs():
    lsm = LV.StripeMd(stripe_size=100, stripe_count=3, stripe_offset=0,
                      objects=[])
    assert LV._chunks(lsm, 0, 0) == []
    assert LV._chunks(lsm, 250, 0) == []
    assert LV._chunks(lsm, 10, -5) == []      # defensive: negative length


def test_chunks_boundary_end_has_no_empty_run():
    lsm = LV.StripeMd(stripe_size=100, stripe_count=3, stripe_offset=0,
                      objects=[])
    for off, ln in ((0, 100), (50, 50), (0, 300), (100, 200), (299, 1)):
        runs = LV._chunks(lsm, off, ln)
        assert all(r[2] > 0 for r in runs), (off, ln, runs)
        assert sum(r[2] for r in runs) == ln


def test_chunks_single_stripe_runs_merge():
    """stripe_count=1: object-contiguous runs coalesce into one niobuf."""
    lsm = LV.StripeMd(stripe_size=100, stripe_count=1, stripe_offset=0,
                      objects=[])
    assert LV._chunks(lsm, 0, 250) == [(0, 0, 250, 0)]


def test_chunks_degenerate_geometry():
    bad = LV.StripeMd(stripe_size=0, stripe_count=0, stripe_offset=0,
                      objects=[])
    assert LV._chunks(bad, 0, 100) == []      # no divide-by-zero


def test_logical_size_exact_boundary():
    lsm = LV.StripeMd(stripe_size=100, stripe_count=3, stripe_offset=0,
                      objects=[])
    # object 0 holding exactly 2 full stripes -> logical bytes 0-99+300-399
    assert LV.logical_size(lsm, [200, 0, 0]) == 400
    assert LV.logical_size(lsm, [100, 100, 100]) == 300
    assert LV.logical_size(lsm, []) == 0
    # stray object sizes beyond stripe_count are ignored
    assert LV.logical_size(lsm, [0, 0, 0, 500]) == 0


def test_zero_length_write_read_end_to_end():
    c, lov = mk()
    lsm = lov.create(stripe_count=2, stripe_size=4096)
    assert lov.write(lsm, 0, b"") == 0
    assert lov.read(lsm, 0, 0) == b""
    assert lov.getattr(lsm)["size"] == 0


def test_boundary_write_then_read_round_trip():
    c, lov = mk()
    lsm = lov.create(stripe_count=2, stripe_size=4096)
    data = bytes(range(256)) * 32             # exactly 2 stripes
    assert lov.write(lsm, 0, data) == len(data)
    lov.flush()
    assert lov.getattr(lsm)["size"] == len(data)
    assert lov.read(lsm, 0, len(data)) == data
