"""Runtime spot-checks for the replay-idempotence matrix claims
(tests/replay_matrix.py) and regression tests for the handlers the lint
replay-coverage rule flagged as unprotected.

The changelog_register/deregister/clear fix: those ops mutate durable
consumer state but used to reply without a transno, so a resend after a
lost reply minted a SECOND consumer id (whose stale bookmark pins the
changelog until idle-GC) or failed a succeeded deregister with -ENOENT.
They now commit in-handler and reply transno-bearing, so the reply
cache absorbs resends like every other update op.
"""
import pytest

from repro.core import LustreCluster
from repro.fsio import LustreClient

from replay_matrix import REPLAY_MATRIX


def mk():
    cluster = LustreCluster(osts=1, mdses=1, clients=1, commit_interval=8)
    fs = LustreClient(cluster).mount()
    return cluster, fs


def drop_next_reply(cluster, imp):
    """Arm the fault plan to eat the next server->client message (the
    reply of the next request), forcing timeout -> reconnect -> resend."""
    cluster.sim.faults.drop_next[imp.client.nid] += 1


# ------------------------------------------------ changelog exactly-once fix

def test_resent_changelog_register_mints_one_consumer():
    cluster, fs = mk()
    mds = cluster.mds_targets[0]
    mdc = fs.lmv.mdcs[0]
    drop_next_reply(cluster, mdc.imp)
    uid = fs.changelog_register()
    assert cluster.sim.stats.counters["rpc.timeout"] >= 1   # resend happened
    assert uid in mds.changelog.users
    assert len(mds.changelog.users) == 1                    # no duplicate


def test_resent_changelog_deregister_replies_from_cache():
    cluster, fs = mk()
    mds = cluster.mds_targets[0]
    mdc = fs.lmv.mdcs[0]
    uid = fs.changelog_register()
    drop_next_reply(cluster, mdc.imp)
    mdc.changelog_deregister(uid)       # must NOT raise -2 on the resend
    assert uid not in mds.changelog.users


def test_resent_changelog_clear_is_exactly_once():
    cluster, fs = mk()
    mdc = fs.lmv.mdcs[0]
    uid = fs.changelog_register()
    fs.mkdir("/a")
    fs.mkdir("/b")
    fs.sync()
    recs = fs.changelog_read(uid)
    assert recs
    drop_next_reply(cluster, mdc.imp)
    fs.changelog_clear(uid, recs[-1]["idx"])
    assert fs.changelog_read(uid) == []


# --------------------------------------------------- matrix claims, runtime

def test_ldlm_cancel_of_unknown_lock_is_ok(cluster):
    fs = LustreClient(cluster).mount()
    fh = fs.creat("/f")
    fs.write(fh, b"x" * 32)
    osc = fs.lov.oscs[0]
    lk = next(iter(osc.locks.locks.values()))
    osc.locks.cancel(lk)
    # a resent/duplicate cancel for the same (now unknown) handle
    osc.imp.request("ldlm_cancel", {"handle": lk.handle})


def test_orphan_cleanup_second_pass_is_noop():
    cluster, fs = mk()
    ost = cluster.ost_targets[0]
    osc = fs.lov.oscs[0]
    out1 = osc.imp.request("orphan_cleanup", {"group": 0,
                                              "last_used": 0}).data
    out2 = osc.imp.request("orphan_cleanup", {"group": 0,
                                              "last_used": 0}).data
    assert out2.get("destroyed", 0) == 0 or out2 == out1


def test_grant_shrink_resend_converges():
    cluster, fs = mk()
    ost = cluster.ost_targets[0]
    osc = fs.lov.oscs[0]
    fh = fs.creat("/f")
    fs.write(fh, b"x" * 16)
    fs.sync()                              # connect + consume some grant
    exp = ost.exports[osc.imp.client.uuid]
    start = exp.data.get("grant", 0)
    assert start > 0
    keep = start // 2
    r1 = osc.imp.request("grant_shrink", {"keep": keep}).data["grant"]
    r2 = osc.imp.request("grant_shrink", {"keep": keep}).data["grant"]
    assert r1 == r2 == keep


def test_rollback_to_same_cut_twice_is_idempotent():
    cluster, fs = mk()
    mds = cluster.mds_targets[0]
    fs.mkdir("/d1")
    fs.mkdir("/d2")
    cut = mds.transno
    fs.mkdir("/d3")
    mdc = fs.lmv.mdcs[0]
    mdc.imp.request("rollback_to", {"transno": cut})
    assert not fs.exists("/d3") and fs.exists("/d2")
    mdc.imp.request("rollback_to", {"transno": cut})    # second: no-op
    assert fs.exists("/d2") and fs.exists("/d1")


# ------------------------------------------------------- matrix hygiene

def test_matrix_mechanisms_are_descriptive():
    for cls, ops in REPLAY_MATRIX.items():
        for op, mech in ops.items():
            assert isinstance(mech, str) and len(mech) > 10, (cls, op)


def test_matrix_has_no_transno_bearing_entries():
    """Reply-cache-covered ops must NOT be in the matrix (the lint rule
    flags stale entries; this is the fast in-repo half of that check)."""
    for covered in ("create", "mkdir", "unlink", "setattr", "write",
                    "punch", "destroy"):
        for cls, ops in REPLAY_MATRIX.items():
            assert covered not in ops, (cls, covered)
