"""lustre-lint: seeded-violation tests for every rule class, plus the
shipped-tree-is-clean gate the CI lint job enforces.

Each seeded tree lives under ``<tmp>/repro/core/`` so the collector
picks it up; we drive the real CLI entry point (``main``) so exit codes
match what CI sees.
"""
import json
from pathlib import Path

import pytest

from repro.tools.lint.__main__ import main
from repro.tools.lint import run_lint, write_inventory

REPO = Path(__file__).resolve().parents[1]
SRC = REPO / "src"


def seed(tmp_path: Path, source: str, name: str = "bad.py") -> Path:
    """Plant a module inside a scan-eligible repro/core/ tree."""
    core = tmp_path / "repro" / "core"
    core.mkdir(parents=True, exist_ok=True)
    (core / name).write_text(source)
    return tmp_path


def lint_tree(tree: Path, *, matrix=None, baseline=None, fresh_inventory=True):
    """Run the analyzer over a seeded tree with its own inventory so the
    fail-sweep rule compares against a same-tree snapshot (tests that
    want a *stale* inventory pass fresh_inventory=False)."""
    inv = tree / "fail_sites.json"
    if fresh_inventory:
        first = run_lint([tree], inventory_path=inv, matrix_path=matrix,
                         baseline_path=baseline)
        write_inventory(first.inventory, inv)
    return run_lint([tree], inventory_path=inv, matrix_path=matrix,
                    baseline_path=baseline)


def rules_of(res):
    return sorted({f.rule for f in res.failures})


# ------------------------------------------------------------ rule seeds

TXN_SCOPE_BAD = """
class MdsTarget:
    def op_evil_setattr(self, req):
        self.inodes[req.body["fid"]].mode = req.body["mode"]
        return R.Reply(data={"ok": True}, transno=9)
"""

EMIT_OUTSIDE_TXN = """
class MdsTarget:
    def op_evil_note(self, req):
        self.changelog.emit("CREATE", fid=req.body["fid"])
        return R.Reply(data={})
"""

EMIT_NO_RETRACT = """
class MdsTarget:
    def op_evil_note(self, req):
        rec = self.changelog.emit("CREATE", fid=req.body["fid"])
        transno = self.txn(lambda: None)
        rep = R.Reply(data={})
        rep.transno = transno
        return rep
"""

UNREGISTERED_FAIL_SITE = """
from repro.core import fail as fail_mod

class OstTarget:
    def op_evil_write(self, req):
        fail_mod.maybe_fail("ost.bogus.checkpoint")
        return R.Reply(data={})
"""

DEAD_FAIL_SITE = """
def _register():
    register_site("ost.dead.site", "registered but never checked")
"""

UNCOVERED_REPLAY_OP = """
class MdsTarget:
    def __init__(self):
        self.ops = {}
        self.ops["mystery"] = self.op_mystery

    def op_mystery(self, req):
        self.counter += 1        # mutates state, no transno, no matrix
        return R.Reply(data={"n": self.counter})
"""

RPC_UNDER_LOCK = """
class LdlmNamespace:
    def op_evil_enqueue(self, req):
        res = self.resource(req.body["res"])
        res.granted.append(req.body["handle"])
        peer = self.imports[req.body["peer"]]
        peer.request("ldlm_notify", {"res": req.body["res"]})
        return R.Reply(data={})
"""


def test_seeded_txn_scope_violation(tmp_path):
    res = lint_tree(seed(tmp_path, TXN_SCOPE_BAD))
    assert "txn-scope" in rules_of(res)


def test_seeded_emit_outside_txn(tmp_path):
    res = lint_tree(seed(tmp_path, EMIT_OUTSIDE_TXN))
    assert "emit-in-txn" in rules_of(res)
    assert any("discards" in f.message for f in res.failures)


def test_seeded_emit_without_retract_undo(tmp_path):
    res = lint_tree(seed(tmp_path, EMIT_NO_RETRACT))
    assert "emit-in-txn" in rules_of(res)
    assert any("retract" in f.message for f in res.failures)


def test_seeded_unregistered_fail_site(tmp_path):
    res = lint_tree(seed(tmp_path, UNREGISTERED_FAIL_SITE))
    assert "fail-site" in rules_of(res)
    assert any("not registered" in f.message for f in res.failures)


def test_seeded_dead_fail_site(tmp_path):
    res = lint_tree(seed(tmp_path, DEAD_FAIL_SITE))
    assert any("dead site" in f.message for f in res.failures)


def test_seeded_unswept_site_stale_inventory(tmp_path):
    """A new fail site added without --write-inventory drifts out of the
    crash sweep; the fail-sweep rule catches exactly that."""
    tree = seed(tmp_path, """
from repro.core import fail as fail_mod
register_site("ost.first.site", "v1")

class OstTarget:
    def op_x(self, req):
        fail_mod.maybe_fail("ost.first.site")
""")
    inv = tree / "fail_sites.json"
    first = run_lint([tree], inventory_path=inv)
    write_inventory(first.inventory, inv)
    # grow the tree: a second registered+checked site, inventory unchanged
    seed(tmp_path, """
from repro.core import fail as fail_mod
register_site("ost.first.site", "v1")
register_site("ost.second.site", "added later")

class OstTarget:
    def op_x(self, req):
        fail_mod.maybe_fail("ost.first.site")
        fail_mod.maybe_fail("ost.second.site")
""")
    res = run_lint([tree], inventory_path=inv)
    assert "fail-sweep" in rules_of(res)
    assert any("unswept" in f.message for f in res.failures)


def test_missing_inventory_is_a_finding(tmp_path):
    res = lint_tree(seed(tmp_path, UNREGISTERED_FAIL_SITE.replace(
        "ost.bogus.checkpoint", "ost.x")), fresh_inventory=False)
    assert any(f.rule == "fail-sweep" and "no site inventory" in f.message
               for f in res.failures)


def test_seeded_uncovered_replay_op(tmp_path):
    res = lint_tree(seed(tmp_path, UNCOVERED_REPLAY_OP))
    assert "replay-coverage" in rules_of(res)


def test_replay_matrix_covers_seeded_op(tmp_path):
    matrix = tmp_path / "replay_matrix.py"
    matrix.write_text(
        "REPLAY_MATRIX = {'MdsTarget': {'mystery': 'idempotent: test'}}\n")
    res = lint_tree(seed(tmp_path, UNCOVERED_REPLAY_OP), matrix=matrix)
    assert "replay-coverage" not in rules_of(res)


def test_stale_matrix_entry_flagged(tmp_path):
    matrix = tmp_path / "replay_matrix.py"
    matrix.write_text(
        "REPLAY_MATRIX = {'MdsTarget': {'vanished_op': 'whatever'}}\n")
    res = lint_tree(seed(tmp_path, UNCOVERED_REPLAY_OP), matrix=matrix)
    assert any("stale entry" in f.message for f in res.failures)


def test_transno_bearing_op_needs_no_matrix_entry(tmp_path):
    covered = UNCOVERED_REPLAY_OP.replace(
        'return R.Reply(data={"n": self.counter})',
        'return R.Reply(data={"n": self.counter}, transno=self.txn(u))')
    res = lint_tree(seed(tmp_path, covered))
    assert "replay-coverage" not in rules_of(res)


def test_seeded_rpc_under_lock(tmp_path):
    res = lint_tree(seed(tmp_path, RPC_UNDER_LOCK))
    assert "rpc-under-lock" in rules_of(res)


def test_rpc_under_lock_annotation_clears(tmp_path):
    annotated = RPC_UNDER_LOCK.replace(
        'peer.request("ldlm_notify"',
        '# lint: rpc-under-lock(holder yields, cannot cycle)\n'
        '        peer.request("ldlm_notify"')
    res = lint_tree(seed(tmp_path, annotated))
    assert "rpc-under-lock" not in rules_of(res)


def test_suppression_comment_clears_finding(tmp_path):
    suppressed = TXN_SCOPE_BAD.replace(
        "return R.Reply(",
        "# lint: ok(txn-scope: test fixture)\n        return R.Reply(")
    res = lint_tree(seed(tmp_path, suppressed))
    assert "txn-scope" not in rules_of(res)
    assert res.suppressed >= 1


def test_baseline_file_downgrades_finding(tmp_path):
    base = tmp_path / "baseline.json"
    base.write_text(json.dumps({"known_issues": [
        {"rule": "txn-scope", "path": "repro/core/bad.py",
         "symbol": "MdsTarget.op_evil_setattr"}]}))
    res = lint_tree(seed(tmp_path, TXN_SCOPE_BAD), baseline=base)
    assert "txn-scope" not in rules_of(res)
    assert res.baselined >= 1


# ------------------------------------------------------- CLI + shipped tree

def test_cli_exit_codes(tmp_path):
    tree = seed(tmp_path, TXN_SCOPE_BAD)
    inv = tree / "fail_sites.json"
    base = tree / "baseline.json"
    base.write_text('{"known_issues": []}')
    argv = [str(tree), "--inventory", str(inv), "--baseline", str(base)]
    assert main(argv + ["--write-inventory"]) == 1      # seeded violation
    (tree / "repro" / "core" / "bad.py").write_text("x = 1\n")
    assert main(argv + ["--write-inventory"]) == 0      # clean again


def test_shipped_tree_is_clean():
    """The gate the CI lint job runs: zero unsuppressed findings over
    the real src/ tree with the committed inventory and matrix."""
    assert main([str(SRC)]) == 0


def test_inventory_matches_shipped_tree():
    """The committed fail_sites.json is exactly what the analyzer would
    regenerate — sweep coverage cannot silently drift."""
    res = run_lint([SRC])
    committed = json.loads(
        (SRC / "repro" / "tools" / "lint" / "fail_sites.json").read_text())
    assert res.inventory == committed


def test_inventory_flavors_and_sides():
    committed = json.loads(
        (SRC / "repro" / "tools" / "lint" / "fail_sites.json").read_text())
    sites = committed["sites"]
    assert len(sites) >= 20
    # spot-check known semantics the sweep relies on
    assert sites["mds.txn"]["flavor"] == "deferred"
    assert sites["mds.commit.before"]["flavor"] == "immediate"
    assert sites["dlm.blocking_ast"]["flavor"] == "check"
    assert sites["osc.flush"]["side"] == "client"
    assert sites["ptlrpc.mds.request_in"]["side"] == "server"
    for name, info in sites.items():
        assert info["callsites"], f"site {name} has no callsites"
