"""DLM: modes, extents, ASTs, intents, group locks (paper ch. 7, 27)."""
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # bare env: sampled fallback
    from _hyposhim import given, settings, strategies as st

from repro.core import LustreCluster
from repro.core import dlm as D


# ----------------------------------------------------------- pure matrix

def test_compat_matrix_is_vms():
    # spot checks from the paper's semantics
    assert D._C["PR"]["PR"] and not D._C["PR"]["PW"]
    assert D._C["CR"]["PW"] and not D._C["EX"]["CR"]
    assert all(D._C["NL"][m] for m in ("NL", "CR", "CW", "PR", "PW", "EX"))


@given(st.sampled_from(D.MODES), st.sampled_from(D.MODES))
def test_compat_symmetric(a, b):
    """The VMS compatibility relation is symmetric."""
    assert D._C[a][b] == D._C[b][a]


@given(st.integers(0, 1000), st.integers(1, 100),
       st.integers(0, 1000), st.integers(1, 100))
def test_overlap_symmetric_and_correct(s1, l1, s2, l2):
    a, b = (s1, s1 + l1), (s2, s2 + l2)
    assert D.overlaps(a, b) == D.overlaps(b, a)
    assert D.overlaps(a, b) == (max(s1, s2) < min(s1 + l1, s2 + l2))


# ------------------------------------------------------------ live locks

def mk():
    c = LustreCluster(osts=1, mdses=1, clients=3, commit_interval=8)
    rpcs = [c.make_client_rpc(i) for i in range(3)]
    oscs = [c.make_oscs(r, writeback=False)[0] for r in rpcs]
    return c, oscs


def test_extent_lock_grows_to_whole_object_when_uncontended():
    c, (o1, o2, o3) = mk()
    oid = o1.create(0)["oid"]
    lk, _ = o1.lock(0, oid, "PW", (0, 100))
    assert lk.extent == (0, D.MAX_EXT)       # §7.5 largest-possible grant


def test_extent_growth_bounded_by_other_locks():
    c, (o1, o2, o3) = mk()
    oid = o1.create(0)["oid"]
    o1.lock(0, oid, "PW", (0, 100))
    lk, _ = o2.lock(0, oid, "PW", (1000, 1100))
    # o1's PW got the whole object, so the AST shrank... o1 cancels; but
    # enqueue order here: o2's request revokes o1's lock entirely.
    assert lk.granted


def test_sequential_io_single_lock_rpc():
    c, (o1, _, _) = mk()
    oid = o1.create(0)["oid"]
    base = c.stats.counters.get("rpc.ost.ldlm_enqueue", 0)
    for i in range(16):
        o1.write(0, oid, i * 10, b"0123456789")
    n = c.stats.counters.get("rpc.ost.ldlm_enqueue", 0) - base
    assert n == 1                             # grown extent covers the rest
    assert c.stats.counters["dlm.client_match"] >= 15


def test_blocking_ast_revokes_and_flushes():
    c = LustreCluster(osts=1, mdses=1, clients=2, commit_interval=8)
    r1, r2 = (c.make_client_rpc(i) for i in range(2))
    w = c.make_oscs(r1, writeback=True)[0]   # write-back caching client
    rdr = c.make_oscs(r2, writeback=False)[0]
    oid = w.create(0)["oid"]
    w.write(0, oid, 0, b"cached!!")          # sits dirty under a PW lock
    assert w.dirty_bytes == 8
    data = rdr.read(0, oid, 0, 8)            # conflicting PR -> AST -> flush
    assert data == b"cached!!"
    assert w.dirty_bytes == 0
    assert c.stats.counters["dlm.blocking_ast"] >= 1


def test_group_locks_share_gid():
    c, (o1, o2, o3) = mk()
    oid = o1.create(0)["oid"]
    o1.write(0, oid, 0, b"aaaa", gid=7)
    o2.write(0, oid, 4, b"bbbb", gid=7)      # same group: no revocation
    assert c.stats.counters.get("dlm.blocking_ast", 0) == 0
    o3.read(0, oid, 0, 8)                    # different mode: ASTs fire
    assert c.stats.counters["dlm.blocking_ast"] >= 1


def test_lvb_carries_size(cluster):
    rpc = cluster.make_client_rpc(0)
    osc = cluster.make_oscs(rpc, writeback=False)[0]
    oid = osc.create(0)["oid"]
    osc.write(0, oid, 0, b"x" * 777)
    osc.locks.cancel_all()
    lk, lvb = osc.lock(0, oid, "PR", (0, 10))
    assert lvb["size"] == 777                 # §7.7 lock value block


def test_dead_client_evicted_on_ast_timeout():
    c = LustreCluster(osts=1, mdses=1, clients=2, commit_interval=8)
    r1, r2 = (c.make_client_rpc(i) for i in range(2))
    o1 = c.make_oscs(r1, writeback=False)[0]
    o2 = c.make_oscs(r2, writeback=False)[0]
    oid = o1.create(0)["oid"]
    o1.lock(0, oid, "PW", (0, 100))
    c.sim.faults.down_nids.add(r1.nid)        # client 1 dies holding PW
    lk, _ = o2.lock(0, oid, "PW", (0, 100))   # AST times out -> evict
    assert lk is not None and lk.granted
    assert c.stats.counters["dlm.evictions"] == 1


def test_lock_match_covers_weaker_modes():
    lk = D.Lock(1, ("ext", 0, 2), "PW", (0, 1000), "c", "n", granted=True)
    assert lk.covers("PR", (10, 20))
    assert lk.covers("PW", (0, 1000))
    assert not lk.covers("EX", (0, 10))
    assert not lk.covers("PR", (500, 2000))   # extent not contained
