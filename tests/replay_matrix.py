"""Replay-idempotence test matrix (consumed by `python -m repro.tools.lint`).

Every op registered in a handler table must be exactly-once under the
recovery protocol.  Ops whose replies carry a transno are
*reply-cache-covered*: the server journals the reply in the export's
last_rcvd slot, resends are answered from the cache and replays are
pruned at the committed cut — the lint pass verifies this statically and
skips them here.  Every op that does NOT bear a transno must appear
below with its idempotence mechanism; the lint `replay-coverage` rule
fails the build when a new op is registered without either.

Keys are the registering class (as the analyzer sees the AST), values
map op name -> mechanism.  `tests/test_replay_matrix.py` spot-checks the
non-obvious claims at runtime.
"""

READ_ONLY = "read-only: no server state changes, any re-execution is safe"
IDEMPOTENT_CONVERGE = ("idempotent: re-execution converges to the same "
                       "state (absolute targets / removal of absentees is "
                       "a no-op)")
SESSION = ("session handshake: connect/disconnect carry their own "
           "generation numbers; re-execution renegotiates, never corrupts")

REPLAY_MATRIX = {
    # ------------------------------------------------------- base target
    "Target": {
        "connect": SESSION,
        "disconnect": SESSION,
        "ping": READ_ONLY,
        "mon_collect": READ_ONLY,
        "recovery_close": "idempotent recovery verb: closing an already-"
                          "closed window is a no-op (VBR admits late "
                          "replays either way)",
    },
    # --------------------------------------------------------------- OST
    "OstTarget": {
        "connect": SESSION + " (grant re-derived from export state)",
        "disconnect": SESSION,
        "ping": READ_ONLY,
        "getattr": READ_ONLY,
        "read": READ_ONLY,
        "glimpse_bulk": READ_ONLY,
        "statfs": READ_ONLY,
        "list_objects": READ_ONLY,
        "sync": "idempotent: commit of an already-committed journal is "
                "a no-op",
        "llog_cancel": IDEMPOTENT_CONVERGE,
        "orphan_cleanup": "idempotent: destroys only objects above "
                          "last_used that still exist; a second pass "
                          "finds nothing",
        "grant_shrink": "idempotent: shrinks to an absolute 'keep' "
                        "target, so a resent shrink converges",
    },
    # --------------------------------------------------------------- MDS
    "MdsTarget": {
        "getattr": READ_ONLY,
        "getattr_bulk": READ_ONLY,
        "readdir": READ_ONLY,
        "statfs": READ_ONLY,
        "bucket_lookup": READ_ONLY,
        "dir_nonempty": READ_ONLY,
        "dep_records": READ_ONLY,
        "wbc_request": "read-only: a cache-grant decision; state changes "
                       "only when the client enqueues the subtree lock",
        "changelog_read": "read-only for the stream: the consumer "
                          "bookmark moves only via changelog_clear",
        "reint": "dispatcher: replies carry the dispatched _reint_* "
                 "handler's transno, so the batch rides the reply cache",
        "prealloc_fids": "idempotent-by-design: a lost range is leaked, "
                         "never reused (real FID sequence semantics)",
        "llog_cancel": IDEMPOTENT_CONVERGE,
        "revoke_dir_locks": "idempotent: revoking already-revoked client "
                            "locks is a no-op",
        "sync_commit": "idempotent: commit of an already-committed "
                       "journal is a no-op",
        "peer_rebooted": "idempotent: reconnect nudge; a second nudge "
                         "finds the import already FULL",
        "rollback_to": "idempotent recovery verb: undoing past the same "
                       "cut twice finds nothing left above it",
        "prune_history": "idempotent recovery verb: filtering retained "
                         "history to the same cut converges",
    },
    # --------------------------------------------------------------- DLM
    "LdlmNamespace": {
        "ldlm_cancel": "idempotent: cancel of an unknown lock handle "
                       "returns success (the holder already lost it)",
        "ldlm_locks_for": READ_ONLY,
    },
    "LockCallbackTarget": {
        "blocking_ast": "idempotent: an AST for a handle the client "
                        "already dropped answers 'unknown' and the "
                        "server reaps the stale lock",
        "glimpse_ast": READ_ONLY,
    },
    # -------------------------------------------------------------- COBD
    "CachingOst": {
        "read": READ_ONLY + " (cache population is not client-visible "
                            "state)",
    },
}
