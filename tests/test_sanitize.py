"""Runtime sanitizer (core.sanitize): lockdep ABBA detection and the
request-boundary invariants, staged deliberately under capture()."""
from repro.core import LustreCluster
from repro.core import sanitize
from repro.core.sim import Stats
from repro.fsio import LustreClient


def two_client_cluster():
    cluster = LustreCluster(osts=1, mdses=1, clients=2, commit_interval=8)
    c1 = LustreClient(cluster, 0).mount()
    c2 = LustreClient(cluster, 1).mount()
    return cluster, c1, c2


# ----------------------------------------------------------------- lockdep

def test_lockdep_reports_abba_across_two_clients():
    """The satellite case: client 1 takes A then B, client 2 takes B
    then A, through real file writes (PW extent enqueues).  The lock
    graph must close the cycle and report it."""
    with sanitize.forced():
        cluster, c1, c2 = two_client_cluster()
        fa = c1.creat("/fa")
        fb = c2.creat("/fb")
        c1.write(fa, b"a" * 64)            # c1 holds A (= fa's object)
        c2.write(fb, b"b" * 64)            # c2 holds B
        with sanitize.capture() as caught:
            # c1 wants B while holding A: conflicting enqueue -> edge A->B
            fb1 = c1.open("/fb", "w")
            c1.write(fb1, b"A" * 64)
            # c2 re-takes B (holds nothing conflicting), then wants A
            # while holding B: edge B->A closes the cycle
            c2.write(fb, b"B" * 64)
            fa2 = c2.open("/fa", "w")
            c2.write(fa2, b"B" * 64)
        assert any(v.kind == "lockdep-abba" for v in caught), \
            sanitize.state.lockdep_report()
        assert sanitize.state.cycles
        report = sanitize.state.lockdep_report()
        assert "cycle" in report and "held" in report


def test_lockdep_clean_on_ordered_access():
    """Same two clients, same two files, but BOTH take A before B: no
    cycle, no violation — the guard fixture in conftest enforces the
    empty-violation half automatically."""
    with sanitize.forced():
        cluster, c1, c2 = two_client_cluster()
        fa = c1.creat("/fa")
        fb = c1.creat("/fb")
        c1.write(fa, b"a" * 64)
        fb1 = c1.open("/fb", "w")
        c1.write(fb1, b"a" * 64)
        fa2 = c2.open("/fa", "w")
        c2.write(fa2, b"b" * 64)
        fb2 = c2.open("/fb", "w")
        c2.write(fb2, b"b" * 64)
        assert not sanitize.state.cycles


def test_glimpse_enqueue_orders_nothing():
    """A glimpse enqueue never waits (the server answers with the merged
    LVB), so it must not create lock-order edges."""
    with sanitize.forced():
        cluster, c1, c2 = two_client_cluster()
        fa = c1.creat("/fa")
        c1.write(fa, b"a" * 128)
        edges_before = sum(len(v) for v in sanitize.state.edges.values())
        c2.stat("/fa")                     # size via glimpse of c1's lock
        edges_after = sum(len(v) for v in sanitize.state.edges.values())
        assert edges_after == edges_before


# ------------------------------------------------------------- exactly-once

def test_exactly_once_flags_duplicate_execution():
    with sanitize.forced():
        st = sanitize.state
        st.on_new_sim()
        with sanitize.capture() as caught:
            st.note_execute("mds0", "c0", 17, 5)
            st.note_execute("mds0", "c0", 17, 9)
        assert any(v.kind == "exactly-once" for v in caught)


def test_exactly_once_allows_replay_after_crash():
    with sanitize.forced():
        st = sanitize.state
        st.on_new_sim()
        with sanitize.capture() as caught:
            st.note_execute("mds0", "c0", 17, 5)
            st.note_crash("mds0", 3)       # transno 5 was uncommitted
            st.note_execute("mds0", "c0", 17, 5)
        assert not caught


def test_exactly_once_quiet_through_real_crash_replay():
    """Drive a real crash/replay cycle: the note_crash pruning must keep
    legitimate replay out of the violation log (guard fixture asserts)."""
    with sanitize.forced():
        cluster = LustreCluster(osts=1, mdses=1, clients=1,
                                commit_interval=1 << 9)
        fs = LustreClient(cluster).mount()
        for i in range(6):
            fs.mkdir(f"/d{i}")
        mds_node = cluster.mds_targets[0].node.name
        cluster.fail_node(mds_node)
        cluster.restart_node(mds_node)
        for i in range(6):                 # replay + new work
            assert fs.exists(f"/d{i}")
        fs.mkdir("/after")
        assert not sanitize.state.violations


# ------------------------------------------------------ boundary invariants

def test_grant_conservation_catches_negative_grant():
    with sanitize.forced():
        cluster = LustreCluster(osts=1, mdses=1, clients=1)
        fs = LustreClient(cluster).mount()
        fh = fs.creat("/f")
        fs.write(fh, b"x" * 64)
        fs.sync()
        ost = cluster.ost_targets[0]
        exp = next(iter(ost.exports.values()))
        exp.data["grant"] = -1
        with sanitize.capture() as caught:
            cluster.lctl("mon_snapshot")   # real RPC -> boundary check
        assert any(v.kind == "grant" and "negative" in v.detail
                   for v in caught)
        exp.data["grant"] = 0              # repair for the guard fixture


def test_grant_conservation_catches_overcommit():
    with sanitize.forced():
        cluster = LustreCluster(osts=1, mdses=1, clients=1)
        fs = LustreClient(cluster).mount()
        fh = fs.creat("/f")
        fs.write(fh, b"x" * 64)
        fs.sync()
        ost = cluster.ost_targets[0]
        exp = next(iter(ost.exports.values()))
        saved = exp.data.get("grant", 0)
        exp.data["grant"] = ost.obd.statfs()["capacity"] + 1
        with sanitize.capture() as caught:
            cluster.lctl("mon_snapshot")
        assert any(v.kind == "grant" and "capacity" in v.detail
                   for v in caught)
        exp.data["grant"] = saved


def test_counter_partition_check():
    with sanitize.forced():
        st = sanitize.state
        stats = Stats()
        stats.count("x.ok", 3, node="n1")          # node 3 <= global 3
        with sanitize.capture() as caught:
            st.check_counter_partition(stats)
        assert not caught
        stats.node_counters["n2"]["x.ok"] = 7      # nodes 10 > global 3
        with sanitize.capture() as caught:
            st.check_counter_partition(stats)
        assert any(v.kind == "counters" for v in caught)


# ------------------------------------------------------------------ procfs

def test_procfs_sanitizer_rollup():
    with sanitize.forced():
        cluster = LustreCluster(osts=2, mdses=1, clients=2)
        fs = LustreClient(cluster).mount()
        fh = fs.creat("/f", stripe_count=2)
        fs.write(fh, b"y" * 256)
        fs.sync()
        roll = cluster.procfs()["sanitizer"]
        assert roll["enabled"] is True
        assert roll["checks"].get("grant.boundary", 0) > 0
        assert roll["checks"].get("exactly_once.execute", 0) > 0
        assert roll["checks"].get("counters.partition", 0) > 0
        assert roll["violations"] == len(sanitize.state.violations)
        assert cluster.lctl("get_param", "sanitizer.enabled") is True


def test_sanitizer_disabled_is_inert():
    with sanitize.forced(False):
        before = dict(sanitize.state.checks)   # cumulative across tests
        cluster = LustreCluster(osts=1, mdses=1, clients=1)
        fs = LustreClient(cluster).mount()
        fh = fs.creat("/f")
        fs.write(fh, b"z" * 64)
        fs.sync()
        roll = cluster.procfs()["sanitizer"]
        assert roll["enabled"] is False
        assert dict(sanitize.state.checks) == before
        assert not sanitize.state.held and not sanitize.state.edges
