"""Network Request Scheduler policies (core.nrs + ptlrpc.Service).

Covers the ISSUE-1 checklist: FIFO equivalence with seed behaviour,
round-robin fairness across two clients, TBF rate limits honored, ORR
grouping by object id — plus policy accounting and runtime switching.
"""
import pytest

from repro.core import LustreCluster
from repro.core import nrs as N
from repro.core import ptlrpc as R


def mk(nrs_policy="fifo", nrs_params=None, **kw):
    c = LustreCluster(osts=1, mdses=1, clients=3, commit_interval=64,
                      nrs_policy=nrs_policy, nrs_params=nrs_params, **kw)
    return c


def osc_for(c, idx, writeback=False):
    rpc = c.make_client_rpc(idx)
    return c.make_oscs(rpc, writeback=writeback)[0]


def run_workload(c):
    osc = osc_for(c, 0)
    oid = osc.create(0)["oid"]
    for i in range(8):
        osc.write(0, oid, i * 16, bytes([i]) * 16)
    return osc.read(0, oid, 0, 128), c


# ------------------------------------------------------------------- fifo

def test_fifo_is_seed_equivalent():
    """Explicit FIFO must match the default cluster bit-for-bit: same
    data, same RPC counters, same virtual time."""
    data_a, ca = run_workload(mk())
    data_b, cb = run_workload(mk(nrs_policy="fifo"))
    assert data_a == data_b
    assert ca.stats.counters["rpc.ost.write"] == \
        cb.stats.counters["rpc.ost.write"]
    assert abs(ca.now - cb.now) < 1e-12


def test_fifo_orders_by_arrival():
    pol = N.FifoPolicy(None)
    r = R.Request(opcode="write", body={}, client_uuid="c1")
    s1 = pol.schedule(r, 0.0, 0.01)
    s2 = pol.schedule(r, 0.0, 0.01)
    s3 = pol.schedule(r, 0.05, 0.01)
    assert (s1, s2) == (0.0, 0.01)
    assert s3 == 0.05                     # idle gap: starts at arrival


# -------------------------------------------------------------------- crr

def test_crr_light_client_unaffected_by_heavy_backlog():
    """Round-robin fairness: a light client's request does not wait behind
    a heavy client's queued backlog (it does under FIFO)."""
    def light_latency(policy):
        pol = N.make_policy(policy, None)
        heavy = R.Request(opcode="write", body={"oid": 5}, client_uuid="hog")
        light = R.Request(opcode="write", body={"oid": 6}, client_uuid="tiny")
        for _ in range(32):
            pol.schedule(heavy, 0.0, 1e-3)     # 32ms backlog from one client
        return pol.schedule(light, 0.0, 1e-3)  # arrives at the same instant
    assert light_latency("fifo") >= 32e-3      # behind the whole backlog
    assert light_latency("crr") == 0.0         # own chain: starts at once


def test_crr_fairness_end_to_end():
    """Two clients hammer one OST concurrently; under CRR the light
    client's requests complete much earlier than under FIFO."""
    def run(policy):
        c = mk(nrs_policy=policy)
        c.ost_targets[0].service.cpu_cost = 2e-3   # make the OST the
        heavy = osc_for(c, 0)                       # bottleneck, not links
        light = osc_for(c, 1)
        h_oid = heavy.create(0)["oid"]
        l_oid = light.create(0)["oid"]
        done = {}

        def h_burst(i):
            heavy.write(0, h_oid, i * 8, b"h" * 8)

        def l_one():
            light.write(0, l_oid, 0, b"l" * 8)
            done["light"] = c.now
        t0 = c.now
        c.sim.parallel([(lambda i=i: h_burst(i)) for i in range(24)]
                       + [l_one])
        return done["light"] - t0
    fifo_lat = run("fifo")
    crr_lat = run("crr")
    assert crr_lat < fifo_lat / 3, (fifo_lat, crr_lat)


def test_crr_accounting_per_client():
    c = mk(nrs_policy="crr")
    a, b = osc_for(c, 0), osc_for(c, 1)
    oa, ob = a.create(0)["oid"], b.create(0)["oid"]
    for i in range(6):
        a.write(0, oa, i * 4, b"aaaa")
    b.write(0, ob, 0, b"bbbb")
    info = c.ost_targets[0].service.policy.info()
    assert info["policy"] == "crr"
    assert info["clients"] >= 2
    counts = sorted(info["per_client"].values())
    assert counts[-1] >= 6                  # heavy client's requests seen
    assert info["reqs"] == sum(counts)


# -------------------------------------------------------------------- orr

def test_orr_groups_by_object_id():
    """ORR: per-object chains — a request to a cold object is served
    immediately even while a hot object has a deep backlog, and the
    accounting shows the per-object grouping."""
    pol = N.make_policy("orr", None)
    hot = R.Request(opcode="write", body={"group": 0, "oid": 1},
                    client_uuid="c")
    cold = R.Request(opcode="read", body={"group": 0, "oid": 2},
                     client_uuid="c")
    for _ in range(16):
        pol.schedule(hot, 0.0, 1e-3)
    assert pol.schedule(cold, 0.0, 1e-3) == 0.0
    info = pol.info()
    assert info["per_object"]["0:1"] == 16
    assert info["per_object"]["0:2"] == 1
    # 16 hot in a row then 1 cold = 2 batch switches, not 17
    assert info["batch_switches"] == 2


def test_orr_end_to_end_accounting():
    c = mk(nrs_policy="orr")
    osc = osc_for(c, 0)
    o1 = osc.create(0)["oid"]
    o2 = osc.create(0)["oid"]
    for i in range(4):
        osc.write(0, o1, i * 4, b"x" * 4)
        osc.write(0, o2, i * 4, b"y" * 4)
    info = c.ost_targets[0].service.policy.info()
    assert info["per_object"][f"0:{o1}"] >= 4
    assert info["per_object"][f"0:{o2}"] >= 4


# --------------------------------------------------------------- orr_disk

def test_orr_disk_contiguous_stream_batches_without_seeks():
    """ISSUE-5 satellite (ROADMAP open item): a BRW continuing exactly
    where the object's last one ended is batched with it — the seek
    component of the seek-aware cost model is refunded, so a contiguous
    stream's chain is shorter than under plain orr."""
    seek = 2e-4
    cost = 1e-3
    disk = N.make_policy("orr_disk", None, seek_cost=seek)
    orr = N.make_policy("orr", None)

    def contig(i):
        return R.Request(opcode="write", client_uuid="c", body={
            "group": 0, "oid": 1,
            "niobufs": [{"offset": i * 4096, "data": b"x" * 4096}]})

    d_starts = [disk.schedule(contig(i), 0.0, cost) for i in range(8)]
    o_starts = [orr.schedule(contig(i), 0.0, cost) for i in range(8)]
    assert disk.seeks_saved == 7
    # the 8th request's START accumulates the 6 refunds of requests
    # 2..7 (its own refund shortens its chain END, not its start)
    assert abs((o_starts[-1] - d_starts[-1]) - 6 * seek) < 1e-12
    assert disk.info()["seeks_saved"] == 7


def test_orr_disk_scattered_stream_pays_full_seeks():
    disk = N.make_policy("orr_disk", None, seek_cost=2e-4)
    for i in [5, 1, 9, 3, 12]:                 # never contiguous
        disk.schedule(R.Request(opcode="write", client_uuid="c", body={
            "group": 0, "oid": 1,
            "niobufs": [{"offset": i * 65536, "data": b"x" * 4096}]}),
            0.0, 1e-3)
    assert disk.seeks_saved == 0


def test_orr_disk_cold_object_fairness_preserved():
    """Contiguity batching must not break ORR's fairness: a request to
    a cold object is still served immediately under a hot backlog, and
    interleaved streams keep their per-object contiguity tracking."""
    disk = N.make_policy("orr_disk", None, seek_cost=2e-4)

    def req(oid, off):
        return R.Request(opcode="write", client_uuid="c", body={
            "group": 0, "oid": oid,
            "niobufs": [{"offset": off, "data": b"x" * 4096}]})

    # interleaved: hot object 1 streams contiguously, object 2 scatters
    for i in range(6):
        disk.schedule(req(1, i * 4096), 0.0, 1e-3)
        disk.schedule(req(2, ((i * 7) % 13) * 65536), 0.0, 1e-3)
    # a brand-new object starts NOW despite both backlogs
    assert disk.schedule(req(3, 0), 0.0, 1e-3) == 0.0
    # object 1's stream stayed contiguous even though object 2's
    # requests arrived between its BRWs (batching by contiguity per
    # object, not by arrival order)
    assert disk.seeks_saved == 5


def test_orr_disk_end_to_end_seek_count():
    c = mk(nrs_policy="orr_disk",
           nrs_params={"seek_cost": 4e-5})
    osc = osc_for(c, 0)
    o1 = osc.create(0)["oid"]
    o2 = osc.create(0)["oid"]
    for i in range(6):                          # interleaved streams
        osc.write(0, o1, i * 4096, b"a" * 4096)
        osc.write(0, o2, i * 131072, b"b" * 4096)   # scattered
    info = c.ost_targets[0].service.policy.info()
    assert info["policy"] == "orr_disk"
    # o1's sequential stream was batched; o2's scattered one was not
    assert info["seeks_saved"] >= 5
    assert info["per_object"][f"0:{o1}"] >= 6
    # and the switchable-policy plumbing works end to end
    c.lctl("nrs", c.ost_targets[0].uuid, "orr_disk", {"seek_cost": 1e-4})
    assert c.ost_targets[0].service.policy.seek_cost == 1e-4


# -------------------------------------------------------------------- wfq

def test_wfq_shares_by_weight():
    """WFQ chains: with weights 3:1 and equal backlogs arriving at t=0,
    the heavy-weight client's k-th request starts ~3x earlier than the
    light one's."""
    pol = N.make_policy("wfq", None, weights={"gold": 3.0, "bronze": 1.0})
    gold = R.Request(opcode="write", body={"oid": 1}, client_uuid="gold")
    bronze = R.Request(opcode="write", body={"oid": 2}, client_uuid="bronze")
    g_starts, b_starts = [], []
    for _ in range(12):                   # interleaved: both chains active
        g_starts.append(pol.schedule(gold, 0.0, 1e-3))
        b_starts.append(pol.schedule(bronze, 0.0, 1e-3))
    # steady state: per-request spacing is cost * total_weight / own_weight
    g_gap = g_starts[6] - g_starts[5]
    b_gap = b_starts[6] - b_starts[5]
    assert abs(g_gap * 3 - b_gap) < 1e-9, (g_gap, b_gap)
    info = pol.info()
    assert info["policy"] == "wfq"
    assert info["weights"] == {"gold": 3.0, "bronze": 1.0}


def test_wfq_equal_weights_is_crr():
    """All-weights-equal WFQ degenerates to CRR exactly."""
    reqs = [R.Request(opcode="write", body={"oid": i}, client_uuid=f"c{i%3}")
            for i in range(24)]
    wfq = N.make_policy("wfq", None)
    crr = N.make_policy("crr", None)
    for r in reqs:
        assert wfq.schedule(r, 0.01 * r.body["oid"], 1e-3) == \
            crr.schedule(r, 0.01 * r.body["oid"], 1e-3)


def test_wfq_fairness_end_to_end():
    """Two clients hammer one OST; the weight-4 client finishes its batch
    well before the weight-1 client under WFQ via the lctl knob."""
    c = mk()
    c.ost_targets[0].service.cpu_cost = 2e-3
    heavy = osc_for(c, 0)
    light = osc_for(c, 1)
    c.lctl("nrs", "OST0000", "wfq",
           {"weights": {heavy.rpc.uuid: 4.0, light.rpc.uuid: 1.0}})
    h_oid = heavy.create(0)["oid"]
    l_oid = light.create(0)["oid"]
    done = {}

    def h_burst(i):
        heavy.write(0, h_oid, i * 8, b"h" * 8)
        done["heavy"] = max(done.get("heavy", 0.0), c.now)

    def l_burst(i):
        light.write(0, l_oid, i * 8, b"l" * 8)
        done["light"] = max(done.get("light", 0.0), c.now)
    t0 = c.now
    c.sim.parallel([(lambda i=i: h_burst(i)) for i in range(16)]
                   + [(lambda i=i: l_burst(i)) for i in range(16)])
    assert done["heavy"] - t0 < (done["light"] - t0) / 2, done
    pe = c.procfs()["targets"]["OST0000"]["nrs"]["per_export"]
    assert pe[heavy.rpc.uuid]["reqs"] >= 16
    # the light client queued (lower share), the heavy one barely did
    assert pe[light.rpc.uuid]["queue_wait_s"] > \
        pe[heavy.rpc.uuid]["queue_wait_s"]


def test_wfq_jobid_classes_two_jobs_one_client():
    """ISSUE-4 satellite: WFQ classes are per-JOBID — two batch jobs
    multiplexed over ONE client uuid get their own weighted fair shares
    (previously they shared one per-uuid chain)."""
    pol = N.make_policy("wfq", None, weights={"big-job": 3.0,
                                              "small-job": 1.0})
    big = R.Request(opcode="write", body={"oid": 1}, client_uuid="c0",
                    jobid="big-job")
    small = R.Request(opcode="write", body={"oid": 2}, client_uuid="c0",
                      jobid="small-job")
    b_starts, s_starts = [], []
    for _ in range(12):                   # interleaved: both classes active
        b_starts.append(pol.schedule(big, 0.0, 1e-3))
        s_starts.append(pol.schedule(small, 0.0, 1e-3))
    # steady state: spacing is cost * total_weight / own_weight per class
    b_gap = b_starts[6] - b_starts[5]
    s_gap = s_starts[6] - s_starts[5]
    assert abs(b_gap * 3 - s_gap) < 1e-9, (b_gap, s_gap)
    info = pol.info()
    assert info["per_jobid"] == {"big-job": 12, "small-job": 12}
    # untagged requests still class by client uuid
    plain = R.Request(opcode="write", body={"oid": 3}, client_uuid="c9")
    assert pol.schedule(plain, 0.0, 1e-3) == 0.0   # own fresh chain


def test_wfq_jobid_fairness_end_to_end():
    """Two jobs sharing ONE client uuid, installed via the lctl knob:
    the weight-4 job's requests are scheduled ~4x as densely as the
    weight-1 job's (their fair-queue chains advance 1:4), which per-uuid
    WFQ could not do — both jobs would share a single chain."""
    c = mk()
    c.ost_targets[0].service.cpu_cost = 2e-3
    osc = osc_for(c, 0)
    c.lctl("nrs", "OST0000", "wfq",
           {"weights": {"gold-job": 4.0, "lead-job": 1.0}})
    g_oid = osc.create(0)["oid"]
    l_oid = osc.create(0)["oid"]

    def one(job, oid, i):
        osc.rpc.jobid = job
        osc.write(0, oid, i * 8, b"j" * 8)
    thunks = []
    for i in range(12):                    # interleaved: both classes active
        thunks.append(lambda i=i: one("gold-job", g_oid, i))
        thunks.append(lambda i=i: one("lead-job", l_oid, i))
    c.sim.parallel(thunks)
    pol = c.ost_targets[0].service.policy
    # equal work, 4:1 weights -> the light job's chain stretched ~4x as far
    assert pol.chains["lead-job"] > 2.5 * pol.chains["gold-job"], pol.chains
    info = pol.info()
    assert info["per_jobid"]["gold-job"] >= 12
    assert info["per_jobid"]["lead-job"] >= 12
    assert info["by_jobid"] is False


def test_wfq_by_jobid_flag_classifies_all_tagged():
    pol = N.make_policy("wfq", None, by_jobid=True)
    a = R.Request(opcode="write", body={"oid": 1}, client_uuid="c0",
                  jobid="jA")
    b = R.Request(opcode="write", body={"oid": 2}, client_uuid="c0",
                  jobid="jB")
    pol.schedule(a, 0.0, 1e-3)
    assert pol.schedule(b, 0.0, 1e-3) == 0.0   # own chain despite same uuid
    assert pol.info()["by_jobid"] is True


def test_wfq_control_ops_not_queued():
    pol = N.make_policy("wfq", None, weights={"c": 0.001})
    busy = R.Request(opcode="write", body={"oid": 1}, client_uuid="c")
    for _ in range(8):
        pol.schedule(busy, 0.0, 1e-3)
    ping = R.Request(opcode="ping", body={}, client_uuid="c")
    assert pol.schedule(ping, 0.0, 1e-3) == 0.0


# -------------------------------------------------------------------- tbf

def test_tbf_rate_limit_honored():
    """A client limited to 100 req/s takes >= ~(n-burst)/rate virtual
    seconds for n requests; unthrottled FIFO is orders faster."""
    def elapsed(policy, params=None):
        c = mk(nrs_policy=policy, nrs_params=params)
        osc = osc_for(c, 0)
        oid = osc.create(0)["oid"]
        t0 = c.now
        for i in range(30):
            osc.write(0, oid, i * 4, b"zzzz")
        return c.now - t0
    throttled = elapsed("tbf", {"rate": 100.0, "burst": 1.0})
    free = elapsed("fifo")
    assert throttled >= 29 / 100.0 * 0.95
    assert free < throttled / 10
    # and the policy counted the throttling
    # (re-run to inspect the policy object)
    c = mk(nrs_policy="tbf", nrs_params={"rate": 100.0, "burst": 1.0})
    osc = osc_for(c, 0)
    oid = osc.create(0)["oid"]
    for i in range(10):
        osc.write(0, oid, i * 4, b"zzzz")
    info = c.ost_targets[0].service.policy.info()
    assert info["policy"] == "tbf"
    assert info["throttled"] >= 5


def test_tbf_per_client_rules():
    """rules={uuid: rate} throttles one tenant while others run free."""
    c = mk()
    slow = osc_for(c, 0)
    fast = osc_for(c, 1)
    c.lctl("nrs", "OST0000", "tbf",
           {"rate": 1e9, "burst": 1.0, "rules": {slow.rpc.uuid: 50.0}})
    s_oid = slow.create(0)["oid"]
    f_oid = fast.create(0)["oid"]
    t0 = c.now
    for i in range(10):
        fast.write(0, f_oid, i * 4, b"ffff")
    fast_dt = c.now - t0
    t0 = c.now
    for i in range(10):
        slow.write(0, s_oid, i * 4, b"ssss")
    slow_dt = c.now - t0
    assert slow_dt >= 9 / 50.0 * 0.95
    assert fast_dt < slow_dt / 20


def test_tbf_jobid_rule_shares_one_bucket():
    """A rules entry matching the request's jobid beats the client uuid:
    every client tagged with that batch job drains ONE shared bucket,
    while untagged clients run free."""
    pol = N.make_policy("tbf", None, rate=1e9, burst=1.0,
                        rules={"batch1": 10.0})
    r_a = R.Request(opcode="write", body={"oid": 1}, client_uuid="cA",
                    jobid="batch1")
    r_b = R.Request(opcode="write", body={"oid": 2}, client_uuid="cB",
                    jobid="batch1")
    r_free = R.Request(opcode="write", body={"oid": 3}, client_uuid="cC",
                       jobid="otherjob")
    pol.schedule(r_a, 0.0, 1e-6)               # spends the shared token
    s_b = pol.schedule(r_b, 0.0, 1e-6)
    assert s_b >= 0.09                         # different client, same job
    s_free = pol.schedule(r_free, 0.001, 1e-6)
    assert s_free < 0.01                       # no rule for its job: free
    info = pol.info()
    assert info["per_jobid"] == {"batch1": 2, "otherjob": 1}


def test_tbf_jobid_rule_end_to_end():
    """lctl-installed jobid rule throttles a tagged client's RPCs; the
    same tag lands in MDS changelog records (one plumbing, two
    consumers)."""
    c = mk()
    tagged = osc_for(c, 0)
    free = osc_for(c, 1)
    tagged.rpc.jobid = "nightly-scrub"
    c.lctl("nrs", "OST0000", "tbf",
           {"rate": 1e9, "burst": 1.0, "rules": {"nightly-scrub": 50.0}})
    t_oid = tagged.create(0)["oid"]
    f_oid = free.create(0)["oid"]
    t0 = c.now
    for i in range(10):
        free.write(0, f_oid, i * 4, b"ffff")
    free_dt = c.now - t0
    t0 = c.now
    for i in range(10):
        tagged.write(0, t_oid, i * 4, b"tttt")
    tagged_dt = c.now - t0
    assert tagged_dt >= 9 / 50.0 * 0.95
    assert free_dt < tagged_dt / 20
    assert c.ost_targets[0].service.policy.info()[
        "per_jobid"]["nightly-scrub"] >= 10


def test_per_export_nrs_stats_in_procfs():
    """procfs breaks NRS accounting out per client uuid (per export),
    not just as target-wide aggregates (ROADMAP item)."""
    c = mk(nrs_policy="crr")
    a, b = osc_for(c, 0), osc_for(c, 1)
    oa, ob = a.create(0)["oid"], b.create(0)["oid"]
    for i in range(6):
        a.write(0, oa, i * 4, b"aaaa")
    b.write(0, ob, 0, b"bbbb")
    pe = c.procfs()["targets"]["OST0000"]["nrs"]["per_export"]
    assert a.rpc.uuid in pe and b.rpc.uuid in pe
    assert pe[a.rpc.uuid]["reqs"] >= 6
    assert pe[b.rpc.uuid]["reqs"] >= 1
    for row in pe.values():
        assert row["queue_wait_s"] >= 0.0
        assert row["avg_queue_wait_us"] >= 0.0
    # aggregates stay consistent with the per-export rows
    nrs = c.procfs()["targets"]["OST0000"]["nrs"]
    assert nrs["reqs"] == sum(r["reqs"] for r in pe.values())


def test_tbf_never_throttles_control_ops():
    c = mk(nrs_policy="tbf", nrs_params={"rate": 1.0, "burst": 1.0})
    osc = osc_for(c, 0)
    oid = osc.create(0)["oid"]          # spends the only token
    t0 = c.now
    assert osc.imp.ping()               # ping must not wait ~1s for a token
    assert c.now - t0 < 0.5


# -------------------------------------------------------- switch + procfs

def test_policy_switch_at_runtime_and_procfs():
    c = mk()
    osc = osc_for(c, 0)
    oid = osc.create(0)["oid"]
    osc.write(0, oid, 0, b"a" * 8)
    assert c.procfs()["targets"]["OST0000"]["nrs"]["policy"] == "fifo"
    c.lctl("nrs", "OST0000", "orr")
    osc.write(0, oid, 8, b"b" * 8)
    nrs = c.procfs()["targets"]["OST0000"]["nrs"]
    assert nrs["policy"] == "orr"
    assert nrs["reqs"] >= 1             # accounting restarted with policy
    c.lctl("nrs", "OST0000", "wfq", {"weights": {osc.rpc.uuid: 2.0}})
    assert c.procfs()["targets"]["OST0000"]["nrs"]["policy"] == "wfq"
    with pytest.raises(ValueError):
        c.lctl("nrs", "OST0000", "bogus")


def test_unknown_policy_rejected():
    with pytest.raises(ValueError):
        N.make_policy("nope", None)


def test_tbf_throttled_tenant_does_not_block_others():
    """One class waiting for tokens must not head-of-line-block another
    class's requests (the service idles during a token wait)."""
    pol = N.make_policy("tbf", None,
                        rate=1e9, burst=1.0, rules={"heavy": 1.0})
    heavy = R.Request(opcode="write", body={"oid": 1}, client_uuid="heavy")
    light = R.Request(opcode="write", body={"oid": 2}, client_uuid="light")
    pol.schedule(heavy, 0.0, 1e-5)             # spends heavy's only token
    s_heavy = pol.schedule(heavy, 0.0, 1e-5)   # waits ~1s for a token
    assert s_heavy >= 0.9
    s_light = pol.schedule(light, 0.001, 1e-5)
    assert s_light < 0.01, s_light             # unaffected by heavy's wait


# -------------------------------------------- ISSUE-8: tbf_orr (two-level)

def test_tbf_orr_throttles_only_ruled_class():
    """The two-level policy (ROADMAP open item): TBF admission feeds
    orr_disk ordering. Only jobid classes named in `rules` pay tokens —
    the default rate of 0 means 'unlimited', so regular traffic rides
    the disk-ordered chains untouched."""
    pol = N.make_policy("tbf_orr", None, rules={"rebuild": 20.0},
                        burst=1.0)

    def req(jobid, oid, off):
        return R.Request(opcode="write", client_uuid="c", jobid=jobid,
                         body={"group": 0, "oid": oid,
                               "niobufs": [{"offset": off,
                                            "data": b"x" * 4096}]})

    # rebuild class: 1 token of burst, then 1/rate pacing
    s0 = pol.schedule(req("rebuild", 1, 0), 0.0, 1e-6)
    s1 = pol.schedule(req("rebuild", 1, 4096), 0.0, 1e-6)
    assert s0 == 0.0
    assert s1 >= 1 / 20.0 * 0.95
    assert pol.throttled >= 1
    # unruled traffic at the same instant: no admission delay at all
    assert pol.schedule(req("app", 2, 0), 0.0, 1e-6) == 0.0
    assert pol.schedule(req("", 3, 0), 0.0, 1e-6) == 0.0
    info = pol.info()
    assert info["policy"] == "tbf_orr"
    assert info["rules"] == {"rebuild": 20.0}


def test_tbf_orr_keeps_orr_disk_contiguity_refund():
    """Level two is the real orr_disk: an unthrottled contiguous stream
    still earns the seek refunds."""
    seek = 2e-4
    pol = N.make_policy("tbf_orr", None, seek_cost=seek,
                        rules={"rebuild": 10.0})
    for i in range(8):
        pol.schedule(R.Request(opcode="write", client_uuid="c",
                               jobid="app", body={
                                   "group": 0, "oid": 1,
                                   "niobufs": [{"offset": i * 4096,
                                                "data": b"x" * 4096}]}),
                     0.0, 1e-3)
    assert pol.seeks_saved == 7
    assert pol.info()["seeks_saved"] == 7


def test_tbf_orr_never_throttles_control_ops():
    pol = N.make_policy("tbf_orr", None, rate=1.0, burst=1.0)
    r = R.Request(opcode="ping", body={}, client_uuid="c", jobid="rebuild")
    for _ in range(16):
        assert pol.schedule(r, 0.0, 1e-6) == 0.0
    assert pol.throttled == 0


def test_tbf_orr_end_to_end_rebuild_class_yields_to_app():
    """lctl('rebuild_throttle', rate) installs tbf_orr on every OST:
    writes tagged jobid=rebuild pace at the rule's rate while untagged
    app writes from another client run at full speed."""
    c = mk()
    c.lctl("rebuild_throttle", 50.0, 1.0)
    assert c.ost_targets[0].service.policy.name == "tbf_orr"
    reb = osc_for(c, 0)
    app = osc_for(c, 1)
    reb.rpc.jobid = "rebuild"
    r_oid = reb.create(0)["oid"]
    a_oid = app.create(0)["oid"]
    t0 = c.now
    for i in range(10):
        app.write(0, a_oid, i * 4, b"aaaa")
    app_dt = c.now - t0
    t0 = c.now
    for i in range(10):
        reb.write(0, r_oid, i * 4, b"rrrr")
    reb_dt = c.now - t0
    assert reb_dt >= 9 / 50.0 * 0.95
    assert app_dt < reb_dt / 20
    assert c.ost_targets[0].service.policy.throttled >= 5
