"""Global namespace: mount-objects + automounter (paper ch. 3) + procfs."""
import pytest

from repro.core import LustreCluster
from repro.fsio import LustreClient
from repro.fsio.namespace import (Automounter, GlobalNamespace, SETUID,
                                  make_mount_object)


def two_cells():
    """Two independent clusters = two AFS-style cells."""
    home = LustreCluster(osts=2, mdses=1, clients=2, commit_interval=32)
    proj = LustreCluster(osts=2, mdses=1, clients=1, commit_interval=32)
    fs_home = LustreClient(home).mount()
    fs_proj = LustreClient(proj).mount()
    fh = fs_proj.creat("/data.bin")
    fs_proj.write(fh, b"project fileset payload")
    fs_proj.close(fh)
    fs_proj.mkdir("/sub")
    fs_proj.creat("/sub/deep.txt")
    return home, proj, fs_home, fs_proj


def test_mount_object_traversal():
    home, proj, fs_home, fs_proj = two_cells()
    amd = Automounter()
    amd.register("fileset://proj@cell2",
                 lambda: LustreClient(proj, 0).mount())
    make_mount_object(fs_home, "/mnt/proj", "fileset://proj@cell2")
    gns = GlobalNamespace(fs_home, amd)
    # traversal INTO the mount-object grafts the remote fileset
    assert gns.read_file("/mnt/proj/data.bin") == b"project fileset payload"
    assert gns.stat("/mnt/proj/sub/deep.txt")["type"] == "file"
    assert amd.mounts == 1                       # cached after first walk


def test_lookup_of_mount_object_does_not_mount():
    """§3.3: `ls -l /mnt` must not cause a mount storm."""
    home, proj, fs_home, fs_proj = two_cells()
    amd = Automounter()
    amd.register("fileset://proj@cell2",
                 lambda: LustreClient(proj, 0).mount())
    make_mount_object(fs_home, "/mnt/proj", "fileset://proj@cell2")
    gns = GlobalNamespace(fs_home, amd)
    st = gns.stat("/mnt/proj")                   # stat of the object itself
    assert st["mode"] & SETUID
    assert amd.mounts == 0                       # NOT mounted


def test_mount_object_is_ordinary_directory():
    """The paper's argument vs AFS: mount-objects are plain directories,
    manageable through the standard API (link counts stay correct)."""
    home, proj, fs_home, fs_proj = two_cells()
    make_mount_object(fs_home, "/mnt/proj", "fileset://proj@cell2")
    st = fs_home.stat("/mnt")
    assert st["nlink"] == 3                      # '.' + '..' + proj
    assert "proj" in fs_home.readdir("/mnt")
    # removable with standard ops
    fs_home.unlink("/mnt/proj/mntinfo")
    fs_home.rmdir("/mnt/proj")
    assert not fs_home.exists("/mnt/proj")


def test_unknown_fileset_errors():
    home, proj, fs_home, _ = two_cells()
    amd = Automounter()
    make_mount_object(fs_home, "/mnt/ghost", "fileset://nope")
    gns = GlobalNamespace(fs_home, amd)
    with pytest.raises(Exception):
        gns.read_file("/mnt/ghost/x")


def test_automount_expiry_remounts():
    home, proj, fs_home, fs_proj = two_cells()
    amd = Automounter()
    amd.register("fileset://proj@cell2",
                 lambda: LustreClient(proj, 0).mount())
    make_mount_object(fs_home, "/mnt/proj", "fileset://proj@cell2")
    gns = GlobalNamespace(fs_home, amd)
    gns.stat("/mnt/proj/data.bin")
    amd.expire("fileset://proj@cell2")
    gns.stat("/mnt/proj/data.bin")               # remounts transparently
    assert amd.mounts == 2


def test_procfs_tree():
    c = LustreCluster(osts=2, mdses=1, clients=1, commit_interval=8)
    fs = LustreClient(c).mount()
    fh = fs.creat("/x", stripe_count=2)
    fs.write(fh, b"y" * 100)
    fs.close(fh)
    p = c.procfs()
    assert p["targets"]["OST0000"]["kind"] == "obdfilter"
    assert p["targets"]["OST0000"]["num_objects"] == 1
    assert p["targets"]["MDS0000"]["num_inodes"] == 2   # root + /x
    assert p["targets"]["MDS0000"]["last_transno"] > 0
    assert p["counters"]["rpc.ost.write"] >= 1
