"""Network-chaos property tests (ISSUE-10).

Seeded fault schedules (drop, lossy links, delay, partitions, server
flaps, heals) run over a live mixed workload — create/rename/unlink/
write spread across 2 MDTs and a raid5 file — and every schedule must
satisfy three oracles once the final heal lands:

  1. audit mirror   — the merged changelog feed rebuilds a namespace
     mirror identical to readdir/stat ground truth, with exactly-once
     record delivery;
  2. sanitizer      — runtime invariants (grant conservation, counter
     partition, lockdep) hold through every fault;
  3. no stuck client — every client completes a fresh op after the
     heal: adaptive timeouts + VBR + the reconnect ladder guarantee
     liveness, never a wedge.

Schedules are pure functions of their integer seed, so any failure
replays deterministically. The hypothesis test widens the seed space;
the parametrized block pins the CI matrix (>= 20 seeds).
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # bare env: sampled fallback
    from _hyposhim import given, settings, strategies as st

from repro.core import LustreCluster, sanitize
from repro.core import chaos as chaos_mod
from repro.fsio import FsError, LustreClient
from repro.tools.audit import ChangelogAuditor

SERVERS = ("mds0", "mds1", "ost0", "ost1", "ost2")
N_SEEDED = 24                            # CI matrix: >= 20 seeds


def _mk():
    c = LustreCluster(osts=3, mdses=2, clients=3, commit_interval=8)
    clients = [LustreClient(c, i).mount() for i in range(3)]
    return c, clients


def _step_factory(clients):
    """Build the per-event workload step: each call issues one op from a
    rotating mix, each client taking turns. Dependent ops (rename/unlink
    of an earlier step's file) tolerate ENOENT — a fault may have cost
    that step its effect, which is exactly what the oracles then audit."""
    fs = clients[0]
    fs.mkdir("/a")                       # hashed across both MDTs
    fs.mkdir("/b")
    fh = fs.creat("/a/r5", stripe_count=2, stripe_size=256,
                  stripe_offset=0, pattern="raid5")
    payload = bytes(range(1, 201)) * 2
    fs.write(fh, payload, offset=0)
    fs.close(fh)
    n = {"i": 0}

    def step():
        i = n["i"]
        n["i"] += 1
        fsx = clients[i % len(clients)]
        op = i % 6
        if op == 0:
            try:
                fsx.mkdir(f"/a/d{i}")
            except FsError:
                pass                     # parent rolled back, replay pending
        elif op == 1:
            try:
                h = fsx.creat(f"/b/f{i}")
                fsx.write(h, b"x" * 512)
                fsx.close(h)
            except FsError:
                pass
        elif op == 2:
            try:
                fsx.rename(f"/b/f{i - 1}", f"/a/m{i}")
            except FsError:
                pass                     # source lost to an earlier fault
        elif op == 3:
            try:
                fsx.unlink(f"/a/m{i - 1}")
            except FsError:
                pass
        elif op == 4:
            # raid5 I/O stays on one owner: a parity write caches locks
            # on TWO OSTs, and a peer revoking just the data lock would
            # leave a reversed cached-hold order that global lockdep
            # rightly flags (shared-file raid5 writers need group locks)
            try:
                h = fs.open("/a/r5")
                fs.read(h, 64, offset=0)
                fs.close(h)
            except FsError:
                pass
        else:
            try:
                h = fs.open("/a/r5")
                fs.write(h, b"y" * 64, offset=64 * (i % 4))
                fs.close(h)
            except FsError:
                pass
    return step


def _run_schedule(seed: int, steps: int) -> None:
    with sanitize.forced():
        c, clients = _mk()
        aud = ChangelogAuditor(clients[0])
        step = _step_factory(clients)
        eng = chaos_mod.ChaosEngine(c, SERVERS)
        sched = chaos_mod.generate_schedule(
            seed, steps, [f.rpc.nid for f in clients], SERVERS)
        eng.run(sched, step)
        assert not eng.flapped and not c.sim.faults.drop_prob \
            and not c.sim.faults.partitions  # run() ends healed
        # oracle 3: nobody is stuck — every client performs a fresh op
        # (reconnect/replay/VBR may run inside, but it must terminate).
        # Root-level: chaos may legitimately erase /a or /b (an eviction
        # forfeits uncommitted setup ops), liveness must not depend on it
        for i, fsx in enumerate(clients):
            fsx.mkdir(f"/alive{seed}_{i}")
            assert f"alive{seed}_{i}" in fsx.readdir("/")
        # oracle 1: audit mirror == ground truth, records exactly once
        aud.tail()
        report = aud.verify()
        assert report["ok"], (seed, report["mismatches"])
        keys = [(r["mdt"], r["idx"]) for r in aud.feed]
        assert len(keys) == len(set(keys)), (seed, keys)
        # oracle 2: the sanitizer saw the whole run and stayed clean
        san = c.sim.sanitize.info()
        assert san["enabled"] and san["violations"] == 0, san


@pytest.mark.parametrize("seed", range(N_SEEDED))
def test_chaos_schedule_holds_oracles(seed):
    _run_schedule(seed, steps=12)


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=N_SEEDED, max_value=2**31 - 1))
def test_chaos_any_seed_holds_oracles(seed):
    _run_schedule(seed, steps=8)


def test_schedule_is_deterministic_and_ends_healed():
    a = chaos_mod.generate_schedule(7, 16, ["elan:client0"], SERVERS)
    b = chaos_mod.generate_schedule(7, 16, ["elan:client0"], SERVERS)
    assert a == b
    assert a[-1] == ("heal",)
    kinds = {ev[0] for ev in a}
    assert kinds <= set(chaos_mod.EVENT_KINDS)


def test_flap_suppressed_by_fail_site():
    c, clients = _mk()
    eng = chaos_mod.ChaosEngine(c, SERVERS)
    c.lctl("set_param", "fail_loc", "net.flap", 1, "drop")
    eng.apply(("flap", "ost0"))
    assert not eng.flapped               # the flap itself was lost
    assert "elan:ost0" not in c.sim.faults.down_nids
    c.lctl("set_param", "fail_loc", "")
    eng.apply(("flap", "ost0"))          # disarmed: flap proceeds
    assert eng.flapped == {"ost0"}
    eng.heal()
    assert not eng.flapped
    clients[0].mkdir("/post")            # cluster healthy again
