"""Portals layer: match entries, MDs, events, routing (paper ch. 4)."""
import pytest

from repro.core import portals as P
from repro.core import ptlrpc as R
from repro.core.sim import Simulator


def mknet():
    sim = Simulator()
    net = P.PortalsNetwork(sim)
    a = P.NI("tcp:a", "tcp", net)
    b = P.NI("tcp:b", "tcp", net)
    return sim, net, a, b


def test_put_matches_bits_and_delivers_event():
    sim, net, a, b = mknet()
    eq = P.EventQueue()
    md = P.MemoryDescriptor(length=1024, threshold=1, eq=eq)
    b.me_attach(7, match_bits=42, ignore_bits=0, md=md)
    t = a.put("tcp:b", 7, 42, {"hello": 1}, nbytes=100)
    assert t > 0 and md.buffer
    ev = eq.pop()
    assert ev.kind == P.PUT and ev.match_bits == 42
    assert ev.data == {"hello": 1}


def test_no_match_drops_packet():
    sim, net, a, b = mknet()
    md = P.MemoryDescriptor(length=1024, threshold=1)
    b.me_attach(7, match_bits=42, ignore_bits=0, md=md)
    a.put("tcp:b", 7, 43, "x", nbytes=10)     # wrong bits
    a.put("tcp:b", 9, 42, "x", nbytes=10)     # wrong portal
    assert not md.buffer
    assert sim.stats.counters["portals.no_match_drop"] == 2


def test_threshold_auto_unlink():
    sim, net, a, b = mknet()
    md = P.MemoryDescriptor(length=1024, threshold=2,
                            manage_remote_offset=True)
    b.me_attach(7, 0, P.IGNORE_ALL, md)
    a.put("tcp:b", 7, 1, "x", nbytes=4)
    a.put("tcp:b", 7, 2, "y", nbytes=4)
    assert md.unlinked
    a.put("tcp:b", 7, 3, "z", nbytes=4)
    assert len(md.buffer) == 2                # third dropped


def test_receiver_managed_offsets():
    sim, net, a, b = mknet()
    md = P.MemoryDescriptor(length=1 << 20, threshold=-1,
                            manage_remote_offset=True)
    b.me_attach(6, 0, P.IGNORE_ALL, md)
    a.put("tcp:b", 6, 1, "req1", nbytes=100)
    a.put("tcp:b", 6, 2, "req2", nbytes=50)
    offs = [o for o, _ in md.buffer]
    assert offs == [0, 100]


def test_link_bandwidth_serialises_same_link():
    sim, net, a, b = mknet()
    md = P.MemoryDescriptor(length=1 << 30, threshold=-1, eq=P.EventQueue())
    b.me_attach(6, 0, P.IGNORE_ALL, md)
    nbytes = 1 << 20
    t1 = a.put("tcp:b", 6, 1, "x", nbytes=nbytes)
    t2 = a.put("tcp:b", 6, 2, "y", nbytes=nbytes)
    # same (src,dst) link: second transfer queues after the first
    assert t2 > t1 > 0
    assert t2 - t1 >= nbytes / P.NALS["tcp"].bandwidth * 0.99 \
        if "tcp" in P.NALS else t2 > t1


def test_fault_drop_and_down_node():
    sim, net, a, b = mknet()
    md = P.MemoryDescriptor(length=1024, threshold=-1)
    b.me_attach(7, 0, P.IGNORE_ALL, md)
    sim.faults.down_nids.add("tcp:b")
    t = a.put("tcp:b", 7, 1, "x", nbytes=4)
    assert t == float("inf") and not md.buffer
    sim.faults.down_nids.clear()
    sim.faults.drop_next["tcp:b"] = 1
    assert a.put("tcp:b", 7, 1, "x", nbytes=4) == float("inf")
    assert a.put("tcp:b", 7, 1, "x", nbytes=4) < float("inf")


def test_routing_via_gateways_load_balances():
    sim = Simulator()
    net = P.PortalsNetwork(sim)
    client = P.NI("tcp:c", "tcp", net)
    gw0 = P.NI("elan:gw0", "elan", net)
    gw1 = P.NI("elan:gw1", "elan", net)
    srv = P.NI("elan:s", "elan", net)
    for n in ("elan", "tcp"):
        net.add_route(n, "elan:gw0")
        net.add_route(n, "elan:gw1")
    md = P.MemoryDescriptor(length=1 << 20, threshold=-1,
                            manage_remote_offset=True)
    srv.me_attach(6, 0, P.IGNORE_ALL, md)
    for i in range(4):
        client.put("elan:s", 6, i, "x", nbytes=8)
    assert len(md.buffer) == 4
    # disabling one gateway reroutes everything through the other
    net.set_gw("elan:gw0", up=False)
    for i in range(4):
        assert client.put("elan:s", 6, 10 + i, "x", nbytes=8) < float("inf")
    # both gateways disabled -> unreachable
    net.set_gw("elan:gw1", up=False)
    assert client.put("elan:s", 6, 99, "x", nbytes=8) == float("inf")
    assert sim.stats.counters["portals.unreachable"] == 1


def test_get_reads_remote_md():
    sim, net, a, b = mknet()
    src = P.MemoryDescriptor(length=64, threshold=-1, user_ptr=b"payload")
    b.me_attach(8, 5, 0, src)
    reply_md = P.MemoryDescriptor(length=64, threshold=1)
    a.get("tcp:b", 8, 5, nbytes=7, reply_md=reply_md)
    assert reply_md.buffer and reply_md.buffer[0][1] == b"payload"
