"""Recovery: replay, failover, orphans, consistent cut (ch. 11, 29)."""
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # bare env: sampled fallback
    from _hyposhim import given, settings, strategies as st

from repro.core import LustreCluster
from repro.core import fail as F
from repro.core import ptlrpc as R
from repro.core.mds import ROOT_FID
from repro.core.recovery import Pinger, compute_consistent_cut
from repro.fsio import LustreClient
from repro.tools.audit import ChangelogAuditor


def test_mds_crash_replays_namespace_ops():
    c = LustreCluster(osts=1, mdses=1, clients=1, commit_interval=10_000)
    fs = LustreClient(c).mount()
    fs.mkdir("/d")
    fh = fs.creat("/d/f")
    fs.write(fh, b"payload")
    fs.close(fh)
    c.fail_node("mds0")
    c.restart_node("mds0")
    st_ = fs.stat("/d/f")
    assert st_["size"] == 7
    assert c.stats.counters["rpc.replay"] >= 2


def test_unlink_llog_reshipped_after_mds_crash():
    """MDS crashed after unlink committed but before OST destroys were
    confirmed: pending llog records re-ship the destroys (§6.7.5)."""
    c = LustreCluster(osts=2, mdses=1, clients=1, commit_interval=1)
    fs = LustreClient(c).mount()
    fh = fs.creat("/f", stripe_count=2)
    fs.write(fh, b"x" * 100)
    fs.close(fh)
    mds = c.mds_targets[0]
    # unlink via MDS only — simulate the client dying before destroying
    # the objects (rep carries cookies nobody acts on)
    rep = fs.lmv.reint({"type": "unlink", "parent": ROOT_FID, "name": "f"})
    assert len(mds.unlink_llog.pending()) == 2
    objs_before = sum(len(t.obd.objects) for t in c.ost_targets)
    assert objs_before == 2
    # MDS recovery re-processes pending records -> objects destroyed
    n = mds.process_unlink_llog(mds.osts)
    assert n == 2
    assert sum(len(t.obd.objects) for t in c.ost_targets) == 0
    assert not mds.unlink_llog.pending()


def test_orphan_cleanup_unreferenced_objects():
    """Client created objects then died before writing the EA (§6.7.5)."""
    c = LustreCluster(osts=2, mdses=1, clients=1, commit_interval=4)
    fs = LustreClient(c).mount()
    fh = fs.creat("/real", stripe_count=2)       # referenced objects
    fs.write(fh, b"keep")
    fs.close(fh)
    # orphans: raw object creates with no file EA pointing at them
    fs.lov.create(stripe_count=2)
    mds = c.mds_targets[0]
    out = mds.orphan_cleanup(mds.osts, group=0)
    destroyed = sum(len(v) for v in out.values())
    assert destroyed == 2
    fh = fs.open("/real")
    assert fs.read(fh, 4) == b"keep"             # referenced data intact


def test_pinger_detects_down_targets(cluster):
    rpc = cluster.make_client_rpc(0)
    oscs = cluster.make_oscs(rpc, writeback=False)
    oscs[0].statfs()
    p = Pinger([o.imp for o in oscs])
    assert all(p.tick().values())
    cluster.fail_node("ost2")
    cluster.fail_node("ost3")                     # kill its standby too
    res = p.tick()
    assert not res["OST0002"]
    assert "OST0002" in p.down


# ------------------------------------------------------ consistent cut

def test_cut_pure_no_deps():
    states = {"a": {"committed": 5, "deps": []},
              "b": {"committed": 9, "deps": []}}
    assert compute_consistent_cut(states) == {"a": 5, "b": 9}


def test_cut_excludes_dependent_txn():
    # a's txn 5 depends on b's txn 10 which b lost (committed 9)
    states = {"a": {"committed": 5, "deps": [(5, {"b": 10})]},
              "b": {"committed": 9, "deps": []}}
    assert compute_consistent_cut(states) == {"a": 4, "b": 9}


def test_cut_bidirectional():
    # b committed the subordinate half (txn 7) of a's lost txn 6
    states = {"a": {"committed": 5, "deps": [(6, {"b": 7})]},
              "b": {"committed": 8, "deps": []}}
    cut = compute_consistent_cut(states)
    assert cut == {"a": 5, "b": 6}


def test_cut_cascades():
    states = {
        "a": {"committed": 3, "deps": [(2, {"b": 2})]},
        "b": {"committed": 1, "deps": [(1, {"c": 4})]},
        "c": {"committed": 3, "deps": []},
    }
    cut = compute_consistent_cut(states)
    # b2 excluded (b committed only 1) -> a2 excluded -> a=1
    # b1 depends on c4 excluded (c committed 3) -> b=0
    assert cut == {"a": 1, "b": 0, "c": 3}


@settings(max_examples=50, deadline=None)
@given(st.dictionaries(
    st.sampled_from(["a", "b", "c"]),
    st.fixed_dictionaries({
        "committed": st.integers(0, 10),
        "deps": st.lists(st.tuples(
            st.integers(1, 10),
            st.dictionaries(st.sampled_from(["a", "b", "c"]),
                            st.integers(1, 10), max_size=2)),
            max_size=5)}),
    min_size=1, max_size=3))
def test_cut_properties(states):
    cut = compute_consistent_cut(states)
    for u, s in states.items():
        assert 0 <= cut[u] <= s["committed"]
        # closure: any included txn's dependencies are included
        for t, deps in s["deps"]:
            included = t <= cut[u]
            for peer, pt in deps.items():
                if peer in cut:
                    if included:
                        assert pt <= cut[peer]
                    if pt <= cut[peer]:
                        assert included or t > s["committed"] or included


def test_double_mds_failure_rolls_back_consistently():
    c = LustreCluster(osts=1, mdses=2, clients=1, commit_interval=6)
    fs = LustreClient(c).mount()
    dfid = fs.mkdir("/d")
    fs.creat("/d/committed")
    for t in c.mds_targets:
        t.commit()
    fs.creat("/x")
    fs.rename("/x", "/d/x2")                     # cross-MDS, uncommitted
    c.fail_node("mds0")
    c.fail_node("mds1")
    c.restart_node("mds0")
    c.restart_node("mds1")
    rec = c.mds_recovery(LustreClient(c).mount().rpc)
    rec.rollback_after_failure()
    fresh = LustreClient(c).mount()
    d = fresh.readdir("/d")
    assert "committed" in d and "x2" not in d
    assert "x" not in fresh.readdir("/")


def test_steady_state_snapshot_prunes_history():
    c = LustreCluster(osts=1, mdses=2, clients=1, commit_interval=4)
    fs = LustreClient(c).mount()
    for i in range(8):
        fs.creat(f"/f{i}")
    for t in c.mds_targets:
        t.commit()
    rec = c.mds_recovery(fs.rpc)
    cut = rec.snapshot()
    assert cut["MDS0000"] > 0          # all activity was on mds0
    assert all(len(t.undo_history) == 0 or
               min(tr for tr, _ in t.undo_history) > cut[t.uuid]
               for t in c.mds_targets)


def test_changelog_crash_replay_exactly_once():
    """Changelog crash consistency (ISSUE-2): MDS fail + client replay
    must neither drop a committed record nor duplicate an uncommitted
    one. Uncommitted records are retracted by the crash rollback (they
    live in the reint's undo scope) and re-emitted exactly once when the
    client replays the lost transactions."""
    c = LustreCluster(osts=1, mdses=1, clients=1, commit_interval=10_000)
    fs = LustreClient(c).mount()
    mds = c.mds_targets[0]
    user = fs.changelog_register()
    fs.mkdir("/d")
    fh = fs.creat("/d/a")
    fs.write(fh, b"12345")
    fs.close(fh)
    mds.commit()                       # everything above is durable
    fs.mkdir("/d/sub")                 # uncommitted tail: will be rolled
    fh = fs.creat("/d/b")              # back by the crash, then replayed
    fs.close(fh)
    uncommitted = len(mds.undo_log)
    assert uncommitted >= 3
    c.fail_node("mds0")
    c.restart_node("mds0")
    assert fs.stat("/d/b")["type"] == "file"     # triggers replay
    assert c.stats.counters["rpc.replay"] >= uncommitted
    recs = fs.changelog_read(user)
    seen = [(r["type"], r["name"]) for r in recs]
    for expected in [("MKDIR", "d"), ("CREAT", "a"),
                     ("MKDIR", "sub"), ("CREAT", "b")]:
        assert seen.count(expected) == 1, (expected, seen)
    # per-fid CLOSE records survive/replay exactly once too
    closes = [tuple(r["fid"]) for r in recs if r["type"] == "CLOSE"]
    assert len(closes) == len(set(closes)) == 2
    idxs = [r["idx"] for r in recs]
    assert idxs == sorted(idxs) and len(set(idxs)) == len(idxs)


def test_changelog_replay_not_duplicated_by_resend():
    """A resend answered from the reply cache must not re-emit records:
    drop the reply of one reint, let the import resend, and check the
    operation appears exactly once in the stream."""
    c = LustreCluster(osts=1, mdses=1, clients=1, commit_interval=64)
    fs = LustreClient(c).mount()
    user = fs.changelog_register()
    c.lctl("drop_next", fs.rpc.nid, 1)           # lose one reply
    fs.mkdir("/once")
    assert c.stats.counters["rpc.timeout"] >= 1
    recs = fs.changelog_read(user)
    assert [(r["type"], r["name"]) for r in recs].count(("MKDIR", "once")) == 1


# ------------------------------------------------- OBD_FAIL crash sweep

def _sweep_workload(fs):
    """Mixed metadata + data workload spanning both MDTs and both OSTs:
    every registered failpoint site is reachable from here."""
    fs.mkdir("/d1")                              # remote mkdir -> MDS1
    fs.mkdir("/d2")
    fh = fs.creat("/d1/f", stripe_count=2)
    for i in range(4):
        fs.write(fh, b"x" * 64, offset=i * 64)
    fs.close(fh)
    fh = fs.creat("/top")
    fs.close(fh)
    fs.link("/d1/f", "/d2/lnk")
    fs.symlink("/d1/f", "/d2/sym")
    fs.rename("/top", "/d2/moved")               # cross-MDT rename
    fs.rename("/d1/f", "/d1/g")
    fs.unlink("/d2/lnk")
    fs.unlink("/d2/moved")
    fs.mkdir("/d1/sub")
    fs.rmdir("/d1/sub")


@pytest.mark.parametrize("site", sorted(F.SITES))
def test_crash_point_sweep(site):
    """Ch. 11 / §6.7.6 acceptance: crash a target at EVERY registered
    OBD_FAIL site (one-shot, wherever the workload or the consumer
    protocol first hits it), let the normal timeout/reconnect/replay
    machinery heal the cluster, and prove (a) the audit mirror still
    matches readdir/stat ground truth and (b) every changelog record
    was delivered exactly once."""
    c = LustreCluster(osts=2, mdses=2, clients=1, commit_interval=3)
    fs = LustreClient(c).mount()
    aud = ChangelogAuditor(fs)
    c.lctl("set_param", "fail_loc", site)        # arm (fires once)
    _sweep_workload(fs)
    aud.tail()                                   # read/clear may crash too
    c.lctl("set_param", "fail_loc", "")          # disarm leftovers
    assert c.sim.fail.hits.get(site, 0) >= 1, \
        f"site {site} never reached by the sweep workload"
    aud.tail()                                   # drain whatever was left
    report = aud.verify()
    assert report["ok"], (site, report["mismatches"])
    # exactly-once delivery: no (mdt, idx) appears twice in the feed
    keys = [(r["mdt"], r["idx"]) for r in aud.feed]
    assert len(keys) == len(set(keys)), (site, keys)
    # and nothing was silently dropped: the surviving namespace content
    # all arrived through records (mirror already proved equality), plus
    # the crash actually happened
    assert c.sim.fail.fired == 1 or site not in (c.sim.fail.hits or {})


def test_crash_sweep_sites_cover_all_layers():
    """The registry spans the layers the ISSUE names: ptlrpc service,
    MDS reint/commit, llog writes, OST transactions, changelog clear."""
    prefixes = {s.split(".")[0] for s in F.SITES}
    assert {"ptlrpc", "mds", "ost", "llog"} <= prefixes
    assert "mds.changelog.clear.applied" in F.SITES
    assert "mds.reint.before" in F.SITES and "ost.txn" in F.SITES


# ------------------------------------- journaled bookmarks / mid-clear

def test_bookmark_survives_mds_restart_mid_clear():
    """ISSUE-3 acceptance: a consumer's bookmark is journaled with the
    catalog header inside the clear's transaction — after an MDS restart
    the next read resumes at the journaled bookmark, with no re-delivery
    of cleared records."""
    c = LustreCluster(osts=1, mdses=1, clients=1, commit_interval=10_000)
    fs = LustreClient(c).mount()
    mds = c.mds_targets[0]
    user = fs.changelog_register()
    for i in range(6):
        fs.mkdir(f"/d{i}")
    recs = fs.changelog_read(user)
    mid = recs[2]["idx"]
    fs.changelog_clear(user, mid)            # ack is durable before reply
    c.fail_node("mds0")
    c.restart_node("mds0")
    assert mds.changelog.users[user] == mid  # header survived the restart
    after = fs.changelog_read(user)          # resumes AT the bookmark
    assert [r["idx"] for r in after] == [r["idx"] for r in recs[3:]]
    assert {r["name"] for r in after} == {"d3", "d4", "d5"}


def test_crash_mid_clear_rolls_back_bookmark_and_purge_atomically():
    """Crash between the clear's transaction and its commit (the
    mds.changelog.clear.applied failpoint): bookmark AND purge roll back
    together — no cleared-but-retained or purged-but-unacked split — and
    the client's resend completes the clear."""
    c = LustreCluster(osts=1, mdses=1, clients=1, commit_interval=10_000)
    fs = LustreClient(c).mount()
    mds = c.mds_targets[0]
    user = fs.changelog_register()
    for i in range(4):
        fs.mkdir(f"/d{i}")
    recs = fs.changelog_read(user)           # stabilizes the tail
    retained = len(mds.changelog.records())
    c.lctl("set_param", "fail_loc", "mds.changelog.clear.applied")
    # the clear RPC crashes the MDS mid-clear; the import times out,
    # reconnects and resends; the re-executed clear succeeds
    fs.changelog_clear(user, recs[-1]["idx"])
    assert c.sim.fail.fired == 1
    assert mds.changelog.users[user] == recs[-1]["idx"]
    assert len(mds.changelog.records()) == 0     # purge completed once
    assert mds.changelog.purged_to == recs[-1]["idx"]
    # nothing re-delivered, stream still consistent
    assert fs.changelog_read(user) == []
    fs.mkdir("/after")
    assert [r["name"] for r in fs.changelog_read(user)] == ["after"]
    assert retained == 4


# --------------------------------------- cluster-cut gated serving

def test_changelog_read_gated_at_cluster_committed_cut():
    """ISSUE-3 acceptance: changelog_read never serves a record above the
    cluster-committed consistent cut. A cross-MDT record whose peer half
    cannot be proven durable (peer down) is withheld; once the peer is
    back the read forces the halves into the cut and serves it; after
    that, rollback_after_failure can no longer retract it."""
    c = LustreCluster(osts=1, mdses=2, clients=1, commit_interval=10_000)
    fs = LustreClient(c).mount()
    mds0, mds1 = c.mds_targets
    user = fs.changelog_register(mdt=0)
    fs.mkdir("/d1")                          # coordinator MDS0, half on MDS1
    dfid = fs.resolve("/d1")
    assert dfid[0] == 1
    # peer dies before its half ever commits: the record's dependency
    # cannot be proven durable -> withheld (NOT served, NOT purged)
    c.fail_node("mds1")
    assert fs.changelog_read(user) == []
    assert len(mds0.changelog.records()) == 1    # still retained
    # peer returns; MDS0's peer import replays the lost half, the read
    # forces both journals and serves the record
    c.restart_node("mds1")
    recs = fs.changelog_read(user)
    assert [(r["type"], r["name"]) for r in recs] == [("MKDIR", "d1")]
    served_transno = mds0.changelog.records()[0].transno
    assert served_transno <= mds0.cluster_cut
    # simultaneous double failure + consistent-cut rollback: the served
    # record (and its namespace op) must survive
    c.fail_node("mds0")
    c.fail_node("mds1")
    c.restart_node("mds0")
    c.restart_node("mds1")
    rec = c.mds_recovery(LustreClient(c).mount().rpc)
    cut = rec.rollback_after_failure()
    assert cut["MDS0000"] >= served_transno
    assert [r.name for r in mds0.changelog.records()] == ["d1"]
    fresh = LustreClient(c).mount()
    assert fresh.stat("/d1")["type"] == "dir"


def test_steady_state_snapshot_advances_serving_cut():
    """MdsClusterRecovery.snapshot pushes the cluster cut to every MDS
    (via prune_history): serving trusts it without re-deriving."""
    c = LustreCluster(osts=1, mdses=2, clients=1, commit_interval=4)
    fs = LustreClient(c).mount()
    for i in range(6):
        fs.creat(f"/f{i}")
    for t in c.mds_targets:
        t.commit()
    cut = c.mds_recovery(fs.rpc).snapshot()
    for t in c.mds_targets:
        assert t.cluster_cut == cut[t.uuid]
    assert c.procfs()["targets"]["MDS0000"]["cluster_cut"] == cut["MDS0000"]


def test_gateway_failover_with_lctl():
    from repro.core import osc as osc_mod
    c = LustreCluster(osts=1, mdses=1, clients=0)
    gw0 = R.Node("gw0", "elan", c)
    gw1 = R.Node("gw1", "elan", c)
    for net in ("elan", "tcp"):
        c.network.add_route(net, gw0.nid)
        c.network.add_route(net, gw1.nid)
    cl = R.Node("tclient", "tcp", c)
    rpc = R.RpcClient(cl)
    osc = osc_mod.Osc(rpc, "OST0000", [c.ost_targets[0].node.nid],
                      writeback=False)
    oid = osc.create(0)["oid"]
    osc.write(0, oid, 0, b"via-gw")
    c.sim.faults.down_nids.add(gw0.nid)
    c.lctl("set_gw", gw0.nid, "down")
    assert osc.read(0, oid, 0, 6) == b"via-gw"
