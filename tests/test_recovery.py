"""Recovery: replay, failover, orphans, consistent cut (ch. 11, 29)."""
import json
from pathlib import Path

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # bare env: sampled fallback
    from _hyposhim import given, settings, strategies as st

from repro.core import LustreCluster
from repro.core import chaos as chaos_mod
from repro.core import fail as F
from repro.core import ptlrpc as R
from repro.core.mds import ROOT_FID
from repro.core.recovery import Pinger, compute_consistent_cut
from repro.fsio import FsError, LustreClient
from repro.tools.audit import ChangelogAuditor


def test_mds_crash_replays_namespace_ops():
    c = LustreCluster(osts=1, mdses=1, clients=1, commit_interval=10_000)
    fs = LustreClient(c).mount()
    fs.mkdir("/d")
    fh = fs.creat("/d/f")
    fs.write(fh, b"payload")
    fs.close(fh)
    c.fail_node("mds0")
    c.restart_node("mds0")
    st_ = fs.stat("/d/f")
    assert st_["size"] == 7
    assert c.stats.counters["rpc.replay"] >= 2


def test_unlink_llog_reshipped_after_mds_crash():
    """MDS crashed after unlink committed but before OST destroys were
    confirmed: pending llog records re-ship the destroys (§6.7.5)."""
    c = LustreCluster(osts=2, mdses=1, clients=1, commit_interval=1)
    fs = LustreClient(c).mount()
    fh = fs.creat("/f", stripe_count=2)
    fs.write(fh, b"x" * 100)
    fs.close(fh)
    mds = c.mds_targets[0]
    # unlink via MDS only — simulate the client dying before destroying
    # the objects (rep carries cookies nobody acts on)
    rep = fs.lmv.reint({"type": "unlink", "parent": ROOT_FID, "name": "f"})
    assert len(mds.unlink_llog.pending()) == 2
    objs_before = sum(len(t.obd.objects) for t in c.ost_targets)
    assert objs_before == 2
    # MDS recovery re-processes pending records -> objects destroyed
    n = mds.process_unlink_llog(mds.osts)
    assert n == 2
    assert sum(len(t.obd.objects) for t in c.ost_targets) == 0
    assert not mds.unlink_llog.pending()


def test_orphan_cleanup_unreferenced_objects():
    """Client created objects then died before writing the EA (§6.7.5)."""
    c = LustreCluster(osts=2, mdses=1, clients=1, commit_interval=4)
    fs = LustreClient(c).mount()
    fh = fs.creat("/real", stripe_count=2)       # referenced objects
    fs.write(fh, b"keep")
    fs.close(fh)
    # orphans: raw object creates with no file EA pointing at them
    fs.lov.create(stripe_count=2)
    mds = c.mds_targets[0]
    out = mds.orphan_cleanup(mds.osts, group=0)
    destroyed = sum(len(v) for v in out.values())
    assert destroyed == 2
    fh = fs.open("/real")
    assert fs.read(fh, 4) == b"keep"             # referenced data intact


def test_pinger_detects_down_targets(cluster):
    rpc = cluster.make_client_rpc(0)
    oscs = cluster.make_oscs(rpc, writeback=False)
    oscs[0].statfs()
    p = Pinger([o.imp for o in oscs])
    assert all(p.tick().values())
    cluster.fail_node("ost2")
    cluster.fail_node("ost3")                     # kill its standby too
    res = p.tick()
    assert not res["OST0002"]
    assert "OST0002" in p.down


# ------------------------------------------------------ consistent cut

def test_cut_pure_no_deps():
    states = {"a": {"committed": 5, "deps": []},
              "b": {"committed": 9, "deps": []}}
    assert compute_consistent_cut(states) == {"a": 5, "b": 9}


def test_cut_excludes_dependent_txn():
    # a's txn 5 depends on b's txn 10 which b lost (committed 9)
    states = {"a": {"committed": 5, "deps": [(5, {"b": 10})]},
              "b": {"committed": 9, "deps": []}}
    assert compute_consistent_cut(states) == {"a": 4, "b": 9}


def test_cut_bidirectional():
    # b committed the subordinate half (txn 7) of a's lost txn 6
    states = {"a": {"committed": 5, "deps": [(6, {"b": 7})]},
              "b": {"committed": 8, "deps": []}}
    cut = compute_consistent_cut(states)
    assert cut == {"a": 5, "b": 6}


def test_cut_cascades():
    states = {
        "a": {"committed": 3, "deps": [(2, {"b": 2})]},
        "b": {"committed": 1, "deps": [(1, {"c": 4})]},
        "c": {"committed": 3, "deps": []},
    }
    cut = compute_consistent_cut(states)
    # b2 excluded (b committed only 1) -> a2 excluded -> a=1
    # b1 depends on c4 excluded (c committed 3) -> b=0
    assert cut == {"a": 1, "b": 0, "c": 3}


@settings(max_examples=50, deadline=None)
@given(st.dictionaries(
    st.sampled_from(["a", "b", "c"]),
    st.fixed_dictionaries({
        "committed": st.integers(0, 10),
        "deps": st.lists(st.tuples(
            st.integers(1, 10),
            st.dictionaries(st.sampled_from(["a", "b", "c"]),
                            st.integers(1, 10), max_size=2)),
            max_size=5)}),
    min_size=1, max_size=3))
def test_cut_properties(states):
    cut = compute_consistent_cut(states)
    for u, s in states.items():
        assert 0 <= cut[u] <= s["committed"]
        # closure: any included txn's dependencies are included
        for t, deps in s["deps"]:
            included = t <= cut[u]
            for peer, pt in deps.items():
                if peer in cut:
                    if included:
                        assert pt <= cut[peer]
                    if pt <= cut[peer]:
                        assert included or t > s["committed"] or included


def test_double_mds_failure_rolls_back_consistently():
    c = LustreCluster(osts=1, mdses=2, clients=1, commit_interval=6)
    fs = LustreClient(c).mount()
    dfid = fs.mkdir("/d")
    fs.creat("/d/committed")
    for t in c.mds_targets:
        t.commit()
    fs.creat("/x")
    fs.rename("/x", "/d/x2")                     # cross-MDS, uncommitted
    c.fail_node("mds0")
    c.fail_node("mds1")
    c.restart_node("mds0")
    c.restart_node("mds1")
    rec = c.mds_recovery(LustreClient(c).mount().rpc)
    rec.rollback_after_failure()
    fresh = LustreClient(c).mount()
    d = fresh.readdir("/d")
    assert "committed" in d and "x2" not in d
    assert "x" not in fresh.readdir("/")


def test_steady_state_snapshot_prunes_history():
    c = LustreCluster(osts=1, mdses=2, clients=1, commit_interval=4)
    fs = LustreClient(c).mount()
    for i in range(8):
        fs.creat(f"/f{i}")
    for t in c.mds_targets:
        t.commit()
    rec = c.mds_recovery(fs.rpc)
    cut = rec.snapshot()
    assert cut["MDS0000"] > 0          # all activity was on mds0
    assert all(len(t.undo_history) == 0 or
               min(tr for tr, _ in t.undo_history) > cut[t.uuid]
               for t in c.mds_targets)


def test_changelog_crash_replay_exactly_once():
    """Changelog crash consistency (ISSUE-2): MDS fail + client replay
    must neither drop a committed record nor duplicate an uncommitted
    one. Uncommitted records are retracted by the crash rollback (they
    live in the reint's undo scope) and re-emitted exactly once when the
    client replays the lost transactions."""
    c = LustreCluster(osts=1, mdses=1, clients=1, commit_interval=10_000)
    fs = LustreClient(c).mount()
    mds = c.mds_targets[0]
    user = fs.changelog_register()
    fs.mkdir("/d")
    fh = fs.creat("/d/a")
    fs.write(fh, b"12345")
    fs.close(fh)
    mds.commit()                       # everything above is durable
    fs.mkdir("/d/sub")                 # uncommitted tail: will be rolled
    fh = fs.creat("/d/b")              # back by the crash, then replayed
    fs.close(fh)
    uncommitted = len(mds.undo_log)
    assert uncommitted >= 3
    c.fail_node("mds0")
    c.restart_node("mds0")
    assert fs.stat("/d/b")["type"] == "file"     # triggers replay
    assert c.stats.counters["rpc.replay"] >= uncommitted
    recs = fs.changelog_read(user)
    seen = [(r["type"], r["name"]) for r in recs]
    for expected in [("MKDIR", "d"), ("CREAT", "a"),
                     ("MKDIR", "sub"), ("CREAT", "b")]:
        assert seen.count(expected) == 1, (expected, seen)
    # per-fid CLOSE records survive/replay exactly once too
    closes = [tuple(r["fid"]) for r in recs if r["type"] == "CLOSE"]
    assert len(closes) == len(set(closes)) == 2
    idxs = [r["idx"] for r in recs]
    assert idxs == sorted(idxs) and len(set(idxs)) == len(idxs)


def test_changelog_replay_not_duplicated_by_resend():
    """A resend answered from the reply cache must not re-emit records:
    drop the reply of one reint, let the import resend, and check the
    operation appears exactly once in the stream."""
    c = LustreCluster(osts=1, mdses=1, clients=1, commit_interval=64)
    fs = LustreClient(c).mount()
    user = fs.changelog_register()
    c.lctl("drop_next", fs.rpc.nid, 1)           # lose one reply
    fs.mkdir("/once")
    assert c.stats.counters["rpc.timeout"] >= 1
    recs = fs.changelog_read(user)
    assert [(r["type"], r["name"]) for r in recs].count(("MKDIR", "once")) == 1


# ------------------------------------------------- OBD_FAIL crash sweep

def _sweep_workload(fs):
    """Mixed metadata + data workload spanning both MDTs and both OSTs:
    every registered failpoint site is reachable from here (the
    cross-client read drives OST extent ASTs + read-cache invalidation
    through every crash point too)."""
    fs.mkdir("/d1")                              # remote mkdir -> MDS1
    fs.mkdir("/d2")
    fh = fs.creat("/d1/f", stripe_count=2)
    for i in range(4):
        fs.write(fh, b"x" * 64, offset=i * 64)
    # a second client reads while the writer's cache is dirty: blocking
    # AST -> flush -> clean-cache promotion/invalidation under crashes
    fs2 = LustreClient(fs.cluster, 1).mount()
    fh2 = fs2.open("/d1/f")
    assert fs2.read(fh2, 256, offset=0) == b"x" * 256
    fs2.close(fh2)
    fs.close(fh)
    fh = fs.creat("/top")
    fs.close(fh)
    fs.link("/d1/f", "/d2/lnk")
    fs.symlink("/d1/f", "/d2/sym")
    fs.rename("/top", "/d2/moved")               # cross-MDT rename
    # sequential stats in readdir order drive the statahead pipeline
    # (the mds.statahead failpoint site) through every crash point
    for name in fs.readdir("/d2"):
        fs.stat("/d2/" + name)
    fs.rename("/d1/f", "/d1/g")
    fs.unlink("/d2/lnk")
    fs.unlink("/d2/moved")
    fs.mkdir("/d1/sub")
    fs.rmdir("/d1/sub")
    # metadata write-back cache: local records + a reint_batch flush
    # drive the mdc.wbc_flush / mds.reint_batch crash points
    fs.mkdir("/wb")
    if fs.enable_wbc("/wb"):
        for i in range(3):
            fs.mkdir(f"/wb/s{i}")
        fs.disable_wbc()
    # raid5/SNS (ISSUE-8): a degraded read plus an OST rebuild onto the
    # spare drive the lov.rebuild / lov.layout_swap crash points; both
    # are client-side, so "crash" degrades to an abort — the sweep then
    # proves the namespace and the file content survive the abort intact
    fh = fs.creat("/d2/r5", stripe_count=2, stripe_size=256,
                  stripe_offset=0, pattern="raid5")
    payload = bytes(range(1, 251)) * 3
    fs.write(fh, payload, offset=0)
    fs.close(fh)
    fs.cluster.fail_node("ost1")
    fsr = LustreClient(fs.cluster, 1).mount()    # cold cache: the read
    fhr = fsr.open("/d2/r5")                     # must really reconstruct
    assert fsr.read(fhr, len(payload), offset=0) == payload
    fsr.close(fhr)
    fs.rebuild_ost("OST0001", fs.cluster.spare_uuids[0])
    fs.cluster.restart_node("ost1")
    # active health plane (ISSUE-10): the pinger notices OST0001's new
    # boot count and runs imperative recovery — the ping.notify crash
    # point models the notification getting lost (timeout back-stop)
    fs.pinger.tick()
    fhr = fs.open("/d2/r5")                      # post-rebuild (or, under
    assert fs.read(fhr, len(payload), offset=0) == payload  # an aborted
    fs.close(fhr)                                # rebuild, post-restart)
    # VBR recovery window (ISSUE-10): power-cycle MDS1 and let the
    # scaled window expire — the first request after the deadline closes
    # recovery (the mds.recovery_window crash point) WITHOUT evicting
    # the stragglers that never reconnected
    c = fs.cluster
    c.fail_node("mds1")
    c.restart_node("mds1")
    t1 = c.mds_targets[1]
    if t1.recovering:
        c.sim.clock.advance(max(0.0, t1.recovery_deadline - c.sim.now)
                            + 0.01)
    fs.mkdir("/d1/postrec")                      # /d1 lives on MDS1
    # adaptive timeouts (ISSUE-10): throttle OST0000 so one request's
    # queue wait overruns its deadline — the server's early reply
    # (ptl.early_reply crash point) must extend it, no spurious timeout
    c.lctl("nrs", "OST0000", "tbf", {"rate": 0.5, "burst": 1.0})
    fh = fs.creat("/d2/slow", stripe_count=1, stripe_offset=0)
    fs.write(fh, b"q" * 32, offset=0)
    fs.close(fh)
    c.lctl("nrs", "OST0000", "fifo")
    assert c.stats.counters.get("rpc.timeout_spurious", 0) == 0
    # network chaos (ISSUE-10): one flap/heal cycle through the chaos
    # engine reaches the net.flap site (armed drop/crash = the flap
    # never happens, which must change nothing the oracles check)
    eng = chaos_mod.ChaosEngine(c, ["ost2"])
    eng.apply(("flap", "ost2"))
    eng.heal()
    # monitoring plane: one collector round over real RPCs reaches the
    # mon.collect site; a crash/partition there degrades to a PARTIAL
    # snapshot (target listed in 'stale') — never a hang and never a
    # silently-wrong total, which the sweep's healing asserts implicitly
    snap = fs.cluster.lctl("mon_snapshot")
    assert set(snap["targets"]) == {
        t.uuid for t in fs.cluster.mds_targets + fs.cluster.ost_targets}
    for uuid in snap["stale"]:
        assert snap["targets"][uuid]["stale"], uuid
    assert snap["partial"] == bool(snap["stale"])


@pytest.mark.parametrize("site", sorted(F.SITES))
def test_crash_point_sweep(site):
    """Ch. 11 / §6.7.6 acceptance: crash a target at EVERY registered
    OBD_FAIL site (one-shot, wherever the workload or the consumer
    protocol first hits it), let the normal timeout/reconnect/replay
    machinery heal the cluster, and prove (a) the audit mirror still
    matches readdir/stat ground truth and (b) every changelog record
    was delivered exactly once."""
    c = LustreCluster(osts=3, mdses=2, clients=2, commit_interval=3,
                      spare_osts=1)
    fs = LustreClient(c).mount()
    aud = ChangelogAuditor(fs)
    c.lctl("set_param", "fail_loc", site)        # arm (fires once)
    _sweep_workload(fs)
    aud.tail()                                   # read/clear may crash too
    c.lctl("set_param", "fail_loc", "")          # disarm leftovers
    assert c.sim.fail.hits.get(site, 0) >= 1, \
        f"site {site} never reached by the sweep workload"
    aud.tail()                                   # drain whatever was left
    report = aud.verify()
    assert report["ok"], (site, report["mismatches"])
    # exactly-once delivery: no (mdt, idx) appears twice in the feed
    keys = [(r["mdt"], r["idx"]) for r in aud.feed]
    assert len(keys) == len(set(keys)), (site, keys)
    # and nothing was silently dropped: the surviving namespace content
    # all arrived through records (mirror already proved equality), plus
    # the crash actually happened
    assert c.sim.fail.fired == 1 or site not in (c.sim.fail.hits or {})
    # trace exactly-once under EVERY crash site (ISSUE-7): one span per
    # client-issued BRW write and per reint_batch, no matter how many
    # resends/replays/reply-cache hits the recovery path produced
    spans_of = lambda op: sum(  # noqa: E731
        t.by_op[op].count for t in c.sim.metrics.targets.values()
        if op in t.by_op)
    assert spans_of("write") == c.stats.counters.get("osc.brw_write_rpc", 0)
    assert spans_of("reint_batch") == \
        c.stats.counters.get("wbc.flush", 0), site


def test_crash_sweep_sites_cover_all_layers():
    """The registry spans the layers the ISSUE names: ptlrpc service,
    MDS reint/commit, llog writes, OST transactions, changelog clear."""
    prefixes = {s.split(".")[0] for s in F.SITES}
    assert {"ptlrpc", "mds", "ost", "llog"} <= prefixes
    assert "mds.changelog.clear.applied" in F.SITES
    assert "mds.reint.before" in F.SITES and "ost.txn" in F.SITES


# ----------------------------------- inventory-driven (site, nth/action)
# The pair sweep parametrizes over the ANALYZER-GENERATED inventory
# (src/repro/tools/lint/fail_sites.json), not over F.SITES directly:
# the lint fail-sweep rule pins the inventory to the registry, so a new
# site cannot enter the code without entering this sweep — coverage
# can never silently drift.

_INVENTORY_PATH = Path(__file__).resolve().parents[1] / \
    "src" / "repro" / "tools" / "lint" / "fail_sites.json"
_INVENTORY = json.loads(_INVENTORY_PATH.read_text())["sites"]

# 'drop' (OBD_FAIL_*_NET: lose the in-flight message) is meaningful for
# every server-side site — the ptlrpc boundary turns immediate AND
# deferred flavors into a lost request — plus osc.flush's documented
# lost-BRW semantics.  dlm.blocking_ast is excluded here: dropping the
# AST evicts the dirty holder, whose data loss is the eviction's
# documented cost (dedicated test below), so the generic sweep's
# content-survival assertions don't apply.
_DROP_SITES = sorted(
    s for s, info in _INVENTORY.items()
    if (info["side"] == "server" and s != "dlm.blocking_ast")
    or s == "osc.flush")


def test_pair_sweep_inventory_matches_registry():
    """Drift gate: the committed inventory IS the registry (the lint CI
    job enforces the same both ways; this is the in-suite half)."""
    assert set(_INVENTORY) == set(F.SITES)


def _run_swept_workload(c, fs, site):
    """Run the sweep workload + auditor healing checks shared by every
    (site, nth/action) pair; returns the auditor report."""
    aud = ChangelogAuditor(fs)
    _sweep_workload(fs)
    aud.tail()
    c.lctl("set_param", "fail_loc", "")          # disarm leftovers
    assert c.sim.fail.hits.get(site, 0) >= 1, \
        f"site {site} never reached by the sweep workload"
    aud.tail()
    report = aud.verify()
    assert report["ok"], (site, report["mismatches"])
    keys = [(r["mdt"], r["idx"]) for r in aud.feed]
    assert len(keys) == len(set(keys)), (site, keys)
    return report


@pytest.mark.parametrize("site", sorted(_INVENTORY))
def test_crash_pair_sweep_second_hit(site):
    """(site, nth-hit) pair: crash on the SECOND hit of every site.
    The second hit typically lands inside resend/replay/recovery
    traffic — a crash there exercises recovery-of-recovery, which the
    first-hit sweep never reaches."""
    c = LustreCluster(osts=3, mdses=2, clients=2, commit_interval=3,
                      spare_osts=1)
    fs = LustreClient(c).mount()
    c.lctl("set_param", "fail_loc", site, 2)     # fire on 2nd hit
    _run_swept_workload(c, fs, site)
    if c.sim.fail.hits.get(site, 0) >= 2:
        assert c.sim.fail.fired == 1, site       # it really was the 2nd


@pytest.mark.parametrize("site", _DROP_SITES)
def test_fail_pair_sweep_drop_action(site):
    """(site, action=drop) pair: lose the in-flight message at the site
    instead of crashing — the target stays up, the client heals via
    timeout -> resend, and the reply cache keeps it exactly-once."""
    c = LustreCluster(osts=3, mdses=2, clients=2, commit_interval=3,
                      spare_osts=1)
    fs = LustreClient(c).mount()
    c.lctl("set_param", "fail_loc", site, 1, "drop")
    _run_swept_workload(c, fs, site)
    assert c.sim.fail.fired == 1, site


@pytest.mark.parametrize("site", sorted(_INVENTORY))
def test_fail_pair_sweep_delay_action(site):
    """(site, action=delay) pair: a slow-disk/slow-wire stall at every
    site must never change RESULTS, only timing."""
    c = LustreCluster(osts=3, mdses=2, clients=2, commit_interval=3,
                      spare_osts=1)
    fs = LustreClient(c).mount()
    c.lctl("set_param", "fail_loc", site, 1, "delay")
    _run_swept_workload(c, fs, site)
    assert c.sim.fail.fired == 1, site


# ------------------------------------- journaled bookmarks / mid-clear

def test_bookmark_survives_mds_restart_mid_clear():
    """ISSUE-3 acceptance: a consumer's bookmark is journaled with the
    catalog header inside the clear's transaction — after an MDS restart
    the next read resumes at the journaled bookmark, with no re-delivery
    of cleared records."""
    c = LustreCluster(osts=1, mdses=1, clients=1, commit_interval=10_000)
    fs = LustreClient(c).mount()
    mds = c.mds_targets[0]
    user = fs.changelog_register()
    for i in range(6):
        fs.mkdir(f"/d{i}")
    recs = fs.changelog_read(user)
    mid = recs[2]["idx"]
    fs.changelog_clear(user, mid)            # ack is durable before reply
    c.fail_node("mds0")
    c.restart_node("mds0")
    assert mds.changelog.users[user] == mid  # header survived the restart
    after = fs.changelog_read(user)          # resumes AT the bookmark
    assert [r["idx"] for r in after] == [r["idx"] for r in recs[3:]]
    assert {r["name"] for r in after} == {"d3", "d4", "d5"}


def test_crash_mid_clear_rolls_back_bookmark_and_purge_atomically():
    """Crash between the clear's transaction and its commit (the
    mds.changelog.clear.applied failpoint): bookmark AND purge roll back
    together — no cleared-but-retained or purged-but-unacked split — and
    the client's resend completes the clear."""
    c = LustreCluster(osts=1, mdses=1, clients=1, commit_interval=10_000)
    fs = LustreClient(c).mount()
    mds = c.mds_targets[0]
    user = fs.changelog_register()
    for i in range(4):
        fs.mkdir(f"/d{i}")
    recs = fs.changelog_read(user)           # stabilizes the tail
    retained = len(mds.changelog.records())
    c.lctl("set_param", "fail_loc", "mds.changelog.clear.applied")
    # the clear RPC crashes the MDS mid-clear; the import times out,
    # reconnects and resends; the re-executed clear succeeds
    fs.changelog_clear(user, recs[-1]["idx"])
    assert c.sim.fail.fired == 1
    assert mds.changelog.users[user] == recs[-1]["idx"]
    assert len(mds.changelog.records()) == 0     # purge completed once
    assert mds.changelog.purged_to == recs[-1]["idx"]
    # nothing re-delivered, stream still consistent
    assert fs.changelog_read(user) == []
    fs.mkdir("/after")
    assert [r["name"] for r in fs.changelog_read(user)] == ["after"]
    assert retained == 4


# --------------------------------------- cluster-cut gated serving

def test_changelog_read_gated_at_cluster_committed_cut():
    """ISSUE-3 acceptance: changelog_read never serves a record above the
    cluster-committed consistent cut. A cross-MDT record whose peer half
    cannot be proven durable (peer down) is withheld; once the peer is
    back the read forces the halves into the cut and serves it; after
    that, rollback_after_failure can no longer retract it."""
    c = LustreCluster(osts=1, mdses=2, clients=1, commit_interval=10_000)
    fs = LustreClient(c).mount()
    mds0, mds1 = c.mds_targets
    user = fs.changelog_register(mdt=0)
    fs.mkdir("/d1")                          # coordinator MDS0, half on MDS1
    dfid = fs.resolve("/d1")
    assert dfid[0] == 1
    # peer dies before its half ever commits: the record's dependency
    # cannot be proven durable -> withheld (NOT served, NOT purged)
    c.fail_node("mds1")
    assert fs.changelog_read(user) == []
    assert len(mds0.changelog.records()) == 1    # still retained
    # peer returns; MDS0's peer import replays the lost half, the read
    # forces both journals and serves the record
    c.restart_node("mds1")
    recs = fs.changelog_read(user)
    assert [(r["type"], r["name"]) for r in recs] == [("MKDIR", "d1")]
    served_transno = mds0.changelog.records()[0].transno
    assert served_transno <= mds0.cluster_cut
    # simultaneous double failure + consistent-cut rollback: the served
    # record (and its namespace op) must survive
    c.fail_node("mds0")
    c.fail_node("mds1")
    c.restart_node("mds0")
    c.restart_node("mds1")
    rec = c.mds_recovery(LustreClient(c).mount().rpc)
    cut = rec.rollback_after_failure()
    assert cut["MDS0000"] >= served_transno
    assert [r.name for r in mds0.changelog.records()] == ["d1"]
    fresh = LustreClient(c).mount()
    assert fresh.stat("/d1")["type"] == "dir"


def test_steady_state_snapshot_advances_serving_cut():
    """MdsClusterRecovery.snapshot pushes the cluster cut to every MDS
    (via prune_history): serving trusts it without re-deriving."""
    c = LustreCluster(osts=1, mdses=2, clients=1, commit_interval=4)
    fs = LustreClient(c).mount()
    for i in range(6):
        fs.creat(f"/f{i}")
    for t in c.mds_targets:
        t.commit()
    cut = c.mds_recovery(fs.rpc).snapshot()
    for t in c.mds_targets:
        assert t.cluster_cut == cut[t.uuid]
    assert c.procfs()["targets"]["MDS0000"]["cluster_cut"] == cut["MDS0000"]


# ------------------------------------ OBD_FAIL drop / delay actions

def test_fail_action_drop_blocking_ast_evicts_holder():
    """Armed with action=drop, the dlm.blocking_ast site loses the AST on
    the wire: the holder never answers and is evicted (§7.4) — and its
    next RPC triggers the full client-side eviction cleanup."""
    c = LustreCluster(osts=1, mdses=1, clients=2, commit_interval=8)
    a = c.make_oscs(c.make_client_rpc(0))[0]
    b = c.make_oscs(c.make_client_rpc(1), writeback=False)[0]
    oid = a.create(0)["oid"]
    a.write(0, oid, 0, b"dirty-doomed")        # cached under a's PW lock
    c.lctl("set_param", "fail_loc", "dlm.blocking_ast", 1, "drop")
    b.write(0, oid, 0, b"winner-data!")        # AST lost -> a evicted
    assert c.sim.fail.fired == 1
    assert c.stats.counters["dlm.evictions"] == 1
    assert b.read(0, oid, 0, 12) == b"winner-data!"
    # a comes back: -107 -> reconnect, and ALL its stale state is gone
    assert a.statfs()["capacity"] > 0
    assert a.dirty_bytes == 0 and not a.locks.locks
    assert a.read(0, oid, 0, 12) == b"winner-data!"   # never stale


def test_fail_action_delay_stalls_site():
    c = LustreCluster(osts=1, mdses=1, clients=1, commit_interval=8)
    osc = c.make_oscs(c.make_client_rpc(0))[0]
    oid = osc.create(0)["oid"]
    osc.write(0, oid, 0, b"slowpoke")
    c.lctl("set_param", "fail_delay", 0.5)
    c.lctl("set_param", "fail_loc", "osc.flush", 1, "delay")
    t0 = c.now
    osc.flush()
    assert c.now - t0 >= 0.5                   # the flush stalled
    assert c.sim.fail.fired == 1
    assert c.ost_targets[0].obd.read(0, oid, 0, 8) == b"slowpoke"


def test_fail_action_drop_osc_flush_recovers_via_resend():
    """action=drop on osc.flush loses the flush's first BRW RPC on the
    wire; the import times out, reconnects, resends — no data lost."""
    c = LustreCluster(osts=1, mdses=1, clients=1, commit_interval=8)
    osc = c.make_oscs(c.make_client_rpc(0))[0]
    oid = osc.create(0)["oid"]
    osc.write(0, oid, 0, b"must-arrive")
    c.lctl("set_param", "fail_loc", "osc.flush", 1, "drop")
    osc.flush()
    assert c.sim.fail.fired == 1
    assert c.stats.counters["rpc.timeout"] >= 1
    assert c.ost_targets[0].obd.read(0, oid, 0, 11) == b"must-arrive"


def test_fail_action_drop_server_site_resends_from_reply_cache():
    """A server-side site armed with drop behaves like OBD_FAIL_*_NET:
    the reply is lost, the target stays up, and the resend is answered
    from the reply cache — the op executes exactly once."""
    c = LustreCluster(osts=1, mdses=2, clients=1, commit_interval=64)
    fs = LustreClient(c).mount()
    user = fs.changelog_register()
    c.lctl("set_param", "fail_loc", "ptlrpc.mds.before_reply", 1, "drop")
    fs.mkdir("/dropped-reply")
    assert c.sim.fail.fired == 1
    assert c.stats.counters["fail.drop"] == 1
    assert c.stats.counters["rpc.timeout"] >= 1
    recs = [r for r in fs.changelog_read(user) if r["name"] == "dropped-reply"]
    assert len(recs) == 1                      # executed exactly once
    assert fs.stat("/dropped-reply")["type"] == "dir"


def test_fail_action_validated():
    c = LustreCluster(osts=1, mdses=1, clients=1)
    with pytest.raises(ValueError):
        c.lctl("set_param", "fail_action", "explode")
    with pytest.raises(ValueError):
        c.lctl("set_param", "fail_loc", "osc.flush", 1, "explode")


# -------------------------------- post-eviction namespace cross-check

def test_peer_eviction_crosschecks_namespace_halves():
    """ISSUE-4 satellite (ROADMAP): an MDS whose peer import is evicted
    loses its replayable cross-MDT halves — the cross-check drops the
    dangling dirents instead of leaving entries that resolve nowhere."""
    c = LustreCluster(osts=1, mdses=2, clients=1, commit_interval=10_000)
    fs = LustreClient(c).mount()
    mds0, mds1 = c.mds_targets
    fs.mkdir("/survivor")                      # inode on MDS1, entry on MDS0
    mds0.commit()
    mds1.commit()                              # survivor fully durable
    fs.mkdir("/dangling")                      # inode half NOT committed
    assert fs.resolve("/dangling")[0] == 1
    assert fs.resolve("/survivor")[0] == 1
    mds0.commit()                              # the ENTRY half is durable
    # mds1 dies losing the uncommitted inode half, and evicts mds0's
    # import while down (recovery window expiry stand-in).  mds0 is
    # partitioned across the reboot so the imperative-recovery nudge is
    # lost — otherwise it would replay the half and there is nothing to
    # cross-check
    c.fail_node("mds1")
    c.sim.faults.down_nids.add(mds0.node.nid)
    c.restart_node("mds1")
    c.sim.faults.down_nids.discard(mds0.node.nid)
    mds1.evicted.add(mds0.rpc.uuid)
    mds1.recovering = False
    # mds0's next cross-MDT op hits -107: replay queue dies, cross-check
    # runs and drops the dangling entry
    fs.mkdir("/fresh")                         # round-robins onto MDS1
    assert c.stats.counters["rpc.evicted_reconnect"] >= 1
    assert c.stats.counters["mds.peer_evicted"] >= 1
    assert c.stats.counters["mds.crosscheck_dropped"] >= 1
    names = fs.readdir("/")
    assert "dangling" not in names             # no entry resolving nowhere
    assert "survivor" in names
    for name in names:
        fs.stat("/" + name)                    # everything left resolves


# ------------------------------------- consistent-cut staleness window

def test_cut_derivation_cached_behind_staleness_window():
    """ISSUE-4 satellite: a gated-read burst pays ONE dep-vector round;
    within the staleness window new records are withheld rather than
    re-deriving per read; after the window (or a snapshot push) they
    serve."""
    c = LustreCluster(osts=1, mdses=2, clients=1, commit_interval=10_000)
    fs = LustreClient(c).mount()
    mds0 = c.mds_targets[0]
    user = fs.changelog_register(mdt=0)
    fs.mkdir("/warm")                          # cross-MDT halves + dep vector
    assert [r["name"] for r in fs.changelog_read(user)] == ["warm"]
    rounds0 = c.stats.counters.get("rpc.mds.dep_records", 0)
    # burst: new records keep arriving, reads keep coming — ONE window,
    # ONE derivation round at most
    for i in range(6):
        fs.mkdir(f"/burst{i}")
        fs.changelog_read(user)
    rounds = c.stats.counters.get("rpc.mds.dep_records", 0) - rounds0
    assert rounds <= 1, rounds                 # one dep-vector round
    # window expires -> the next read re-derives and serves everything
    c.sim.clock.advance(mds0.cut_staleness)
    names = {r["name"] for r in fs.changelog_read(user)}
    assert {f"burst{i}" for i in range(6)} <= names


def test_snapshot_push_refreshes_cut_cache():
    """A snapshot() push is fresh knowledge: gated reads trust it without
    re-deriving (zero extra dep-vector rounds)."""
    c = LustreCluster(osts=1, mdses=2, clients=1, commit_interval=10_000)
    fs = LustreClient(c).mount()
    user = fs.changelog_register(mdt=0)
    fs.mkdir("/pushed")
    for t in c.mds_targets:
        t.commit()
    c.mds_recovery(fs.rpc).snapshot()          # leader pushes the cut
    rounds0 = c.stats.counters.get("rpc.mds.dep_records", 0)
    assert [r["name"] for r in fs.changelog_read(user)] == ["pushed"]
    assert c.stats.counters.get("rpc.mds.dep_records", 0) == rounds0


def test_gateway_failover_with_lctl():
    from repro.core import osc as osc_mod
    c = LustreCluster(osts=1, mdses=1, clients=0)
    gw0 = R.Node("gw0", "elan", c)
    gw1 = R.Node("gw1", "elan", c)
    for net in ("elan", "tcp"):
        c.network.add_route(net, gw0.nid)
        c.network.add_route(net, gw1.nid)
    cl = R.Node("tclient", "tcp", c)
    rpc = R.RpcClient(cl)
    osc = osc_mod.Osc(rpc, "OST0000", [c.ost_targets[0].node.nid],
                      writeback=False)
    oid = osc.create(0)["oid"]
    osc.write(0, oid, 0, b"via-gw")
    c.sim.faults.down_nids.add(gw0.nid)
    c.lctl("set_gw", gw0.nid, "down")
    assert osc.read(0, oid, 0, 6) == b"via-gw"


# --------------------------------------- ISSUE-10: robustness plane

def test_unreachable_target_bounded_by_reconnect_backoff():
    """Reconnect-storm regression: against a black-holed server the
    client walks the failover ring with capped exponential backoff and
    gives up in BOUNDED virtual time — no unbounded flat-timeout spin."""
    c = LustreCluster(osts=1, mdses=1, clients=1)
    rpc = c.make_client_rpc(0)
    osc = c.make_oscs(rpc, writeback=False)[0]
    oid = osc.create(0)["oid"]
    c.sim.faults.down_nids.add(c.ost_targets[0].node.nid)  # black hole
    t0 = c.now
    with pytest.raises(R.TimeoutError_):
        osc.read(0, oid, 0, 1)
    assert c.now - t0 < 120.0          # virtual s: attempts * (AT + cap)
    assert c.stats.counters.get("rpc.reconnect_backoff", 0) > 0


def test_ping_detected_death_degraded_read_without_rpcs_to_dead_ost():
    """Health plane -> LOV: once the pinger marks an OST dead, a raid5
    read degrades IMMEDIATELY — reconstruction from survivors + parity,
    zero wire attempts (so zero timeouts) toward the dead target."""
    c = LustreCluster(osts=3, mdses=1, clients=1, commit_interval=1)
    fs = LustreClient(c).mount()
    fh = fs.creat("/r5", stripe_count=2, stripe_size=64,
                  stripe_offset=0, pattern="raid5")
    fs.write(fh, bytes(range(128)))    # both data units + parity
    fs.close(fh)
    c.fail_node("ost1")                # serves one of the data slots
    # a fresh mount (cold page cache) so the read must hit the wire
    rd = LustreClient(c).mount()
    assert rd.pinger.tick().get("OST0001") is False
    before = c.stats.counters.get("rpc.timeout", 0)
    h = rd.open("/r5")
    assert rd.read(h, 128, offset=0) == bytes(range(128))
    rd.close(h)
    assert c.stats.counters.get("rpc.timeout", 0) == before
    assert c.stats.counters.get("lov.degraded_read", 0) >= 1


def test_mds_vbr_partial_participation_preserves_namespace():
    """VBR on the MDS: an admin closes the recovery window early with
    ALL three clients still outstanding — nobody is evicted, and each
    client's later return triggers a version-checked delayed replay.
    The clients' uncommitted ops touch disjoint inodes (the shared tree
    skeleton is durable), so delayed replays admit in ANY arrival order
    — exactly the case VBR exists for.  Namespace == no-crash run."""
    c = LustreCluster(osts=1, mdses=1, clients=3, commit_interval=10_000)
    f0, f1, f2 = [LustreClient(c, i).mount() for i in range(3)]
    for d in ("/a", "/b", "/c"):
        f0.mkdir(d)
    c.mds_targets[0].commit()          # skeleton durable: root versions
    for fx, d in ((f0, "/a"), (f1, "/b"), (f2, "/c")):
        fh = fx.creat(d + "/x")        # uncommitted, per-client inodes
        fx.write(fh, b"payload")
        fx.close(fh)
    c.fail_node("mds0")
    c.restart_node("mds0")
    t = c.mds_targets[0]
    assert t.recovering                # window open, nobody back yet
    c.lctl("recovery_close", "MDS0000")
    assert not t.recovering            # closed early: 3 stragglers
    assert c.stats.counters.get("rpc.recovery_stragglers", 0) >= 3
    assert c.stats.counters.get("rpc.recovery_eviction", 0) == 0
    # stragglers return in REVERSE order: disjoint version chains make
    # delayed replay order-independent, every one admits on exact match
    assert f2.stat("/c/x")["size"] == 7
    assert f1.stat("/b/x")["size"] == 7
    assert f0.stat("/a/x")["size"] == 7
    assert c.stats.counters.get("rpc.vbr_admit", 0) >= 3
    assert c.stats.counters.get("rpc.vbr_eviction", 0) == 0
    names = sorted(n for n in f0.readdir("/"))
    assert names == ["a", "b", "c"]
    for fx in (f0, f1, f2):
        for d in ("/a", "/b", "/c"):
            assert fx.stat(d + "/x")["size"] == 7


def test_ost_vbr_evicts_only_genuinely_conflicting_replay():
    """VBR eviction matrix, conflict row: client1's uncommitted write
    observed client2's uncommitted version; the crash loses both and
    client2 never returns, so client1's replay pre-version references a
    version that no longer exists — THAT client is evicted, alone."""
    c = LustreCluster(osts=1, mdses=1, clients=2, commit_interval=10_000)
    rpc1 = c.make_client_rpc(0)
    rpc2 = c.make_client_rpc(1)
    osc1 = c.make_oscs(rpc1, writeback=False)[0]
    osc2 = c.make_oscs(rpc2, writeback=False)[0]
    oid = osc1.create(0)["oid"]
    c.ost_targets[0].commit()          # the object itself is durable
    osc2.write(0, oid, 0, b"base")     # uncommitted: bumps the version
    osc1.write(0, oid, 0, b"over")     # uncommitted: pre-version = osc2's
    c.fail_node("ost0")
    c.restart_node("ost0")
    # osc2 stays away; osc1 reconnects and replays "over" whose pre-op
    # version names osc2's lost transno -> genuine conflict -> evicted
    osc1.statfs()
    assert c.stats.counters.get("rpc.vbr_eviction", 0) == 1
    assert c.stats.counters.get("rpc.replay_vbr_rejected", 0) == 1
    assert c.stats.counters.get("rpc.evicted_reconnect", 0) >= 1
    # the committed create survives; osc1 keeps working post-eviction
    assert osc1.read(0, oid, 0, 4) in (b"", b"\0\0\0\0")


def test_adaptive_timeout_early_reply_rescues_throttled_server():
    """AT end-to-end on one import: a token-bucket throttle stretches
    service past the client's adaptive deadline; the server notices at
    dispatch time and extends it with an early reply — loaded != dead,
    so no timeout fires at all."""
    c = LustreCluster(osts=1, mdses=1, clients=1)
    rpc = c.make_client_rpc(0)
    osc = c.make_oscs(rpc, writeback=False)[0]
    oid = osc.create(0)["oid"]
    c.lctl("nrs", "OST0000", "tbf", {"rate": 0.4, "burst": 1.0})
    for i in range(3):                 # queue waits reach ~2.5 s >> AT
        osc.write(0, oid, i * 8, b"z" * 8)
    c.lctl("nrs", "OST0000", "fifo")
    assert c.stats.counters.get("rpc.early_reply", 0) >= 1
    assert c.stats.counters.get("rpc.timeout_spurious", 0) == 0
    assert c.stats.counters.get("rpc.timeout", 0) == 0


def test_cross_mdt_create_replay_keeps_original_transnos():
    """Replay renumbering regression: replaying a cross-MDT create makes
    a synchronous peer round-trip that calls BACK into the coordinator
    (nlink accounting) — that nested transaction must not consume the
    replay's pinned transno, and post-restart transnos live in a fresh
    boot epoch, or the second replay's version match breaks."""
    c = LustreCluster(osts=1, mdses=2, clients=1, commit_interval=10_000)
    fs = LustreClient(c).mount()
    fs.mkdir("/a")                     # remote create: dirent + peer inode
    fs.mkdir("/b")                     # version chain: pre(b) = transno(a)
    c.fail_node("mds0")
    c.restart_node("mds0")
    fs.mkdir("/c")                     # reconnect -> replay a, b -> new op
    assert sorted(fs.readdir("/")) == ["a", "b", "c"]
    assert c.stats.counters.get("rpc.replay_vbr_rejected", 0) == 0
    assert c.stats.counters.get("rpc.vbr_eviction", 0) == 0
    assert c.stats.counters.get("rpc.vbr_admit", 0) >= 1


def test_peer_reboot_nudge_replays_lost_half_from_disconn_import():
    """Imperative recovery between MDTs: mds1's import to mds0 went
    DISCONN during the outage; mds0's restart announce must still kick
    the reconnect so mds1 replays the cross-MDT half mds0 lost — no
    client traffic ever touches that import again otherwise."""
    c = LustreCluster(osts=1, mdses=2, clients=1, commit_interval=10_000)
    fs = LustreClient(c).mount()
    fs.mkdir("/a")                     # inode on mds1 (remote mkdir)
    fs.mkdir("/a/d")                   # coordinator mds1, inode on mds0
    c.fail_node("mds0")                # loses d's inode half
    try:                               # cross-MDT op while mds0 is down:
        fs.mkdir("/a/d2")              # mds1's peer import times out ->
    except (FsError, R.RpcError, R.TimeoutError_):   # DISCONN
        pass
    assert c.mds_targets[1].peers["MDS0000"].state == "DISCONN"
    c.restart_node("mds0")             # announce -> nudge -> peer replay
    assert c.mds_targets[1].peers["MDS0000"].state == "FULL"
    assert fs.stat("/a/d")["type"] == "dir"
    assert c.stats.counters.get("rpc.vbr_eviction", 0) == 0
