"""Changelog subsystem (core.changelog + MDS hooks + audit tooling).

Covers the ISSUE-2 tentpole: typed records emitted inside the reint
transaction scope, the register/read/clear consumer protocol with
min-bookmark purging, jobid tagging, the llog full-log leak fix, and the
Robinhood-style audit mirror over a 2-MDT striped namespace.
"""
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # bare env: sampled fallback
    from _hyposhim import given, settings, strategies as st

from repro.core import LustreCluster
from repro.core import changelog as CL
from repro.core import ptlrpc as R
from repro.core.llog import LlogCatalog
from repro.core.mds import ROOT_FID
from repro.fsio import FsError, LustreClient
from repro.tools.audit import ChangelogAuditor, NamespaceMirror


def mk(mdses=1, **kw):
    kw.setdefault("commit_interval", 64)
    c = LustreCluster(osts=2, mdses=mdses, clients=1, **kw)
    return c, LustreClient(c).mount()


# ----------------------------------------------------------- record types

def test_record_types_names_and_order():
    c, fs = mk()
    user = fs.changelog_register()
    fs.mkdir("/d")
    fh = fs.creat("/d/f")
    fs.write(fh, b"hello")
    fs.close(fh)
    fs.symlink("/d/f", "/d/s")
    fs.link("/d/f", "/d/f2")
    fs.rename("/d/f", "/d/g")
    dfid = fs.resolve("/d")
    gfid = fs.resolve("/d/g")
    fs.lmv.reint({"type": "setattr", "fid": gfid, "attrs": {"mode": 0o600}})
    fs.unlink("/d/f2")
    recs = fs.changelog_read(user)
    types = [r["type"] for r in recs]
    for t in (CL.CL_MKDIR, CL.CL_CREAT, CL.CL_CLOSE, CL.CL_SYMLINK,
              CL.CL_LINK, CL.CL_RENAME, CL.CL_SETATTR, CL.CL_UNLINK):
        assert t in types, (t, types)
    # indices strictly increasing, timestamps non-decreasing
    idxs = [r["idx"] for r in recs]
    assert idxs == sorted(idxs) and len(set(idxs)) == len(idxs)
    times = [r["time"] for r in recs]
    assert times == sorted(times)
    # name/fid/pfid payloads
    by_type = {r["type"]: r for r in recs}
    assert by_type[CL.CL_MKDIR]["name"] == "d"
    assert by_type[CL.CL_MKDIR]["pfid"] == ROOT_FID
    assert by_type[CL.CL_CREAT]["name"] == "f"
    assert tuple(by_type[CL.CL_CREAT]["pfid"]) == dfid
    assert by_type[CL.CL_CLOSE]["extra"]["size"] == 5
    ren = by_type[CL.CL_RENAME]
    assert (ren["extra"]["sname"], ren["name"]) == ("f", "g")
    assert tuple(ren["fid"]) == gfid
    assert by_type[CL.CL_UNLINK]["name"] == "f2"
    # every record attributes the originating client
    assert all(r["client"] == fs.rpc.uuid for r in recs)


def test_recording_gated_on_registered_consumer():
    c, fs = mk()
    fs.mkdir("/before")                # nobody listening: not recorded
    mds = c.mds_targets[0]
    info = mds.changelog.info()
    assert not info["active"] and info["users"] == {}
    assert (info["records"], info["last_idx"], info["purged_to"],
            info["plain_logs"]) == (0, 0, 0, 0)
    user = fs.changelog_register()
    assert fs.changelog_read(user) == []
    fs.mkdir("/after")
    names = [r["name"] for r in fs.changelog_read(user)]
    assert names == ["after"]


def test_failed_reint_emits_no_phantom_record():
    c, fs = mk()
    user = fs.changelog_register()
    fs.mkdir("/d")
    with pytest.raises(Exception):
        fs.mkdir("/d")                 # EEXIST
    types = [(r["type"], r["name"]) for r in fs.changelog_read(user)]
    assert types == [(CL.CL_MKDIR, "d")]


# ------------------------------------------------- consumers & bookmarks

def test_min_bookmark_across_consumers_governs_purge():
    """Doreau's model: the SLOWEST registered consumer pins the stream —
    clears by a fast consumer purge nothing until the slow one catches
    up, and reading never purges (ISSUE-2 acceptance)."""
    c, fs = mk()
    mds = c.mds_targets[0]
    fast = fs.changelog_register()
    slow = fs.changelog_register()
    for i in range(6):
        fs.mkdir(f"/d{i}")
    recs = fs.changelog_read(fast)
    total = len(recs)
    assert total == 6
    last = recs[-1]["idx"]
    # reading does not purge
    fs.changelog_read(fast)
    fs.changelog_read(slow)
    assert mds.changelog.info()["records"] == total
    # fast consumer acks everything: min bookmark still 0 -> no purge
    fs.changelog_clear(fast, last)
    assert mds.changelog.info()["records"] == total
    assert len(fs.changelog_read(slow)) == total
    # slow consumer acks half: purge exactly up to its bookmark
    mid = recs[2]["idx"]
    fs.changelog_clear(slow, mid)
    info = mds.changelog.info()
    assert info["records"] == total - 3
    assert info["purged_to"] == mid
    assert [r["idx"] for r in fs.changelog_read(slow)] == \
        [r["idx"] for r in recs[3:]]
    # slow consumer catches up: stream drains
    fs.changelog_clear(slow, last)
    assert mds.changelog.info()["records"] == 0
    # default read resumes from the consumer's own bookmark
    fs.mkdir("/new")
    assert [r["name"] for r in fs.changelog_read(slow)] == ["new"]


def test_deregister_releases_bookmark_pin():
    c, fs = mk()
    mds = c.mds_targets[0]
    aud = fs.changelog_register()
    lagger = fs.changelog_register()
    fs.mkdir("/a")
    fs.mkdir("/b")
    last = fs.changelog_read(aud)[-1]["idx"]
    fs.changelog_clear(aud, last)
    assert mds.changelog.info()["records"] == 2    # lagger pins
    fs.changelog_deregister(lagger)
    assert mds.changelog.info()["records"] == 0    # pin released
    # deregistering the LAST consumer stops recording
    fs.changelog_deregister(aud)
    fs.mkdir("/c")
    assert mds.changelog.info()["records"] == 0
    assert not mds.changelog.active


def test_lctl_and_procfs_surface_consumer_state():
    c, fs = mk()
    user = c.lctl("changelog_register", "MDS0000")
    fs.mkdir("/x")
    info = c.procfs()["targets"]["MDS0000"]["changelog"]
    assert info["active"] and user in info["users"]
    assert info["records"] == 1
    assert c.lctl("changelog_info", "MDS0000")["last_idx"] == 1
    c.lctl("changelog_deregister", "MDS0000", user)
    assert not c.procfs()["targets"]["MDS0000"]["changelog"]["active"]


# ------------------------------------------------------------------ jobid

def test_records_carry_jobid():
    c, fs = mk()
    user = fs.changelog_register()
    fs.set_jobid("train-7b@step1000")
    fs.mkdir("/ckpt")
    fh = fs.creat("/ckpt/w0")
    fs.close(fh)
    fs.set_jobid("")
    fs.unlink("/ckpt/w0")
    recs = fs.changelog_read(user)
    jobs = {(r["type"], r["name"]): r["jobid"] for r in recs}
    assert jobs[(CL.CL_MKDIR, "ckpt")] == "train-7b@step1000"
    assert jobs[(CL.CL_CREAT, "w0")] == "train-7b@step1000"
    assert jobs[(CL.CL_UNLINK, "w0")] == ""


def test_changelog_read_rejects_unknown_consumer():
    c, fs = mk()
    user = fs.changelog_register()
    fs.mkdir("/d")
    with pytest.raises(R.RpcError):
        fs.changelog_read("cl999")                # never registered
    fs.changelog_deregister(user)
    with pytest.raises(R.RpcError):
        fs.changelog_read(user)                   # gone after deregister


def test_remote_half_records_attribute_origin_client():
    """Cross-MDT halves executed over the MDS-MDS import must attribute
    the ORIGINATING client uuid/jobid, not the coordinator MDS's internal
    RpcClient."""
    c = LustreCluster(osts=1, mdses=2, clients=1, commit_interval=64)
    fs = LustreClient(c).mount()
    fs.set_jobid("jobX")
    u0 = fs.changelog_register(mdt=0)
    u1 = fs.changelog_register(mdt=1)
    fs.mkdir("/d1")                               # inode half on MDS1
    fs.rmdir("/d1")                               # rmdir half on MDS1
    remote = [r for r in fs.changelog_read(u1, mdt=1)
              if (r.get("extra") or {}).get("remote")]
    assert {r["type"] for r in remote} == {CL.CL_MKDIR, CL.CL_RMDIR}
    assert all(r["client"] == fs.rpc.uuid for r in remote), remote
    assert all(r["jobid"] == "jobX" for r in remote), remote
    # coordinator-side records agree
    coord = fs.changelog_read(u0, mdt=0)
    assert all(r["client"] == fs.rpc.uuid and r["jobid"] == "jobX"
               for r in coord)


def test_cross_mdt_rmdir_typed_and_frees_remote_inode():
    """A cross-MDT rmdir must look like a LOCAL rmdir in the stream:
    RMDIR type (not UNLINK) on both halves, last=True, and the remote
    dir inode actually freed (nlink accounting counted only the name
    link, leaking one inode per removed remote directory)."""
    c = LustreCluster(osts=1, mdses=2, clients=1, commit_interval=64)
    fs = LustreClient(c).mount()
    u0 = fs.changelog_register(mdt=0)
    mds1 = c.mds_targets[1]
    inodes_before = len(mds1.inodes)
    fs.mkdir("/d1")                               # remote inode on MDS1
    fs.rmdir("/d1")
    assert len(mds1.inodes) == inodes_before      # no leaked dir inode
    coord = {r["type"]: r for r in fs.changelog_read(u0)}
    assert CL.CL_RMDIR in coord and CL.CL_UNLINK not in coord
    assert coord[CL.CL_RMDIR]["extra"]["last"] is True
    # create/remove churn stays flat (the leaks compounded per cycle):
    # neither remote inodes nor the parent's nlink may drift
    root_nlink = fs.stat("/")["nlink"]
    for i in range(5):
        fs.mkdir(f"/x{i}")
        fs.rmdir(f"/x{i}")
    assert len(mds1.inodes) == inodes_before
    assert fs.stat("/")["nlink"] == root_nlink


def test_rename_over_unlinks_displaced_inode():
    """Rename over an existing name must unlink the displaced target:
    inode freed, data objects destroyed by the client (as in unlink),
    RENAME record carries the victim — the MDS used to leak the inode
    (and its OST objects) while the audit mirror correctly killed it."""
    c, fs = mk()
    user = fs.changelog_register()
    fh = fs.creat("/a", stripe_count=2)
    fs.write(fh, b"winner")
    fs.close(fh)
    fh = fs.creat("/b", stripe_count=2)
    fs.write(fh, b"loser-data")
    fs.close(fh)
    mds = c.mds_targets[0]
    inodes = len(mds.inodes)
    objs = sum(len(t.obd.objects) for t in c.ost_targets)
    bfid = fs.resolve("/b")
    fs.rename("/a", "/b")
    assert len(mds.inodes) == inodes - 1         # victim inode freed
    assert sum(len(t.obd.objects) for t in c.ost_targets) == objs - 2
    fh = fs.open("/b")
    assert fs.read(fh, 16) == b"winner"
    fs.close(fh)
    ren = [r for r in fs.changelog_read(user) if r["type"] == CL.CL_RENAME]
    assert tuple(ren[-1]["extra"]["victim"]) == bfid
    assert ren[-1]["extra"]["victim_last"] is True
    # hardlinked victim survives with one fewer link, and no llog cookies
    fs.link("/b", "/keep")
    fh = fs.creat("/c")
    fs.close(fh)
    inodes = len(mds.inodes)
    fs.rename("/c", "/b")
    assert len(mds.inodes) == inodes             # victim alive via /keep
    fh = fs.open("/keep")
    assert fs.read(fh, 16) == b"winner"
    fs.close(fh)


def test_rename_over_nonempty_dir_is_enotempty():
    """POSIX: rename over a non-empty directory fails with ENOTEMPTY
    (like unlink), and fails BEFORE any mutation — no half-applied
    rename, no changelog record."""
    c, fs = mk()
    user = fs.changelog_register()
    fs.mkdir("/a")
    fs.mkdir("/victim")
    fh = fs.creat("/victim/child")
    fs.close(fh)
    before = len(fs.changelog_read(user))
    with pytest.raises(R.RpcError) as ei:
        fs.rename("/a", "/victim")
    assert ei.value.status == -39
    assert fs.readdir("/victim") == {"child": fs.resolve("/victim/child")}
    assert fs.resolve("/a")                      # source untouched
    assert len(fs.changelog_read(user)) == before
    # empty dir victim IS displaceable, and its inode is freed
    fs.unlink("/victim/child")
    mds = c.mds_targets[0]
    inodes = len(mds.inodes)
    fs.rename("/a", "/victim")
    assert len(mds.inodes) == inodes - 1
    assert fs.stat("/victim")["type"] == "dir"


def test_cross_mdt_rename_over_unlinks_remote_victim():
    """Rename-over where the victim's inode lives on a peer MDT: the
    coordinator issues the two-stage remote unlink, the peer inode is
    freed, and the RENAME record names the victim."""
    c = LustreCluster(osts=1, mdses=2, clients=1, commit_interval=64)
    fs = LustreClient(c).mount()
    u0 = fs.changelog_register(mdt=0)
    fs.mkdir("/a")                               # inode on MDS1
    fs.mkdir("/b")                               # inode on MDS1
    bfid = fs.resolve("/b")
    assert bfid[0] == 1
    mds1 = c.mds_targets[1]
    inodes = len(mds1.inodes)
    fs.rename("/a", "/b")                        # coordinator is MDS0
    assert bfid not in mds1.inodes               # remote victim freed
    assert len(mds1.inodes) == inodes - 1
    ren = [r for r in fs.changelog_read(u0)
           if r["type"] == CL.CL_RENAME][-1]
    assert tuple(ren["extra"]["victim"]) == bfid
    assert ren["extra"]["victim_last"] is True
    assert fs.readdir("/") == {"b": fs.resolve("/b")}
    # the victim dir's ".." link left the destination parent too
    assert fs.stat("/")["nlink"] == 3            # root + "." + /b only


def test_cross_mdt_nonempty_dir_guards():
    """ENOTEMPTY must hold when the directory's inode is remote: the
    owning MDT refuses remote_unlink_inode for a non-empty dir, and the
    rename coordinator pre-checks the victim over getattr BEFORE
    mutating anything."""
    c = LustreCluster(osts=1, mdses=2, clients=1, commit_interval=64)
    fs = LustreClient(c).mount()
    fs.mkdir("/victim")                          # inode on MDS1
    fh = fs.creat("/victim/child")
    fs.close(fh)
    fs.mkdir("/src")
    # cross-MDT rmdir of a non-empty dir
    with pytest.raises(R.RpcError) as ei:
        fs.rmdir("/victim")
    assert ei.value.status == -39
    assert fs.exists("/victim/child")
    # cross-MDT rename over a non-empty dir: refused before any mutation
    with pytest.raises(R.RpcError) as ei:
        fs.rename("/src", "/victim")
    assert ei.value.status == -39
    assert fs.exists("/src") and fs.exists("/victim/child")
    assert sorted(fs.readdir("/")) == ["src", "victim"]
    # emptied, both succeed
    fs.unlink("/victim/child")
    fs.rename("/src", "/victim")
    assert sorted(fs.readdir("/")) == ["victim"]


def test_rename_over_with_remote_dst_parent_unlinks_victim():
    """Coordinator placement where the DESTINATION parent's inode is on
    the peer MDT (dst=None, bucket_insert path): the displaced entry
    must still be found, ENOTEMPTY-checked, and unlinked — this path
    used to silently clobber the entry and leak the victim."""
    c = LustreCluster(osts=2, mdses=2, clients=1, commit_interval=64)
    fs = LustreClient(c).mount()
    u0 = fs.changelog_register(mdt=0)
    fs.mkdir("/d1")                              # dir inode on MDS1
    fh = fs.creat("/d1/t", stripe_count=2)       # victim, inode on MDS1
    fs.write(fh, b"old")
    fs.close(fh)
    fh = fs.creat("/winner", stripe_count=2)     # inode on MDS0
    fs.write(fh, b"new!")
    fs.close(fh)
    vfid = fs.resolve("/d1/t")
    assert vfid[0] == 1
    mds1 = c.mds_targets[1]
    objs = sum(len(t.obd.objects) for t in c.ost_targets)
    fs.rename("/winner", "/d1/t")                # coordinator MDS0, dst remote
    assert vfid not in mds1.inodes               # victim inode freed on peer
    assert sum(len(t.obd.objects) for t in c.ost_targets) == objs - 2
    wfid = fs.resolve("/d1/t")
    assert wfid[0] == 0                          # the winner moved in
    assert fs.stat("/d1/t")["size"] == 4
    # the file's inode lives on MDS0 while its parent is on MDS1: open
    # follows the _intent_open remote redirect (open-by-fid second hop)
    fh = fs.open("/d1/t")
    assert fs.read(fh, 8) == b"new!"
    fs.close(fh)
    ren = [r for r in fs.changelog_read(u0)
           if r["type"] == CL.CL_RENAME][-1]
    assert tuple(ren["extra"]["victim"]) == vfid
    assert ren["extra"]["victim_last"] is True
    # same placement, non-empty dir victim: ENOTEMPTY before any mutation
    fs.mkdir("/d1/sub")
    fh = fs.creat("/d1/sub/x")
    fs.close(fh)
    fh = fs.creat("/w2")
    fs.close(fh)
    with pytest.raises(R.RpcError) as ei:
        fs.rename("/w2", "/d1/sub")
    assert ei.value.status == -39
    assert fs.exists("/w2") and fs.exists("/d1/sub/x")


def test_cross_mdt_rename_of_remote_dir_transfers_parent_nlinks():
    """Renaming a DIRECTORY whose inode lives on a peer MDT between two
    local parents must still move the '..' link: was_dir used to be
    computed only from local inode presence, so both parents' nlink
    drifted permanently."""
    c = LustreCluster(osts=1, mdses=2, clients=1, commit_interval=64)
    fs = LustreClient(c).mount()
    fs.mkdir("/src")                             # dirs on MDS1
    fs.mkdir("/d1")
    fs.mkdir("/src/mover")                       # inode back on MDS0
    assert fs.resolve("/src/mover")[0] == 0
    assert fs.stat("/src")["nlink"] == 3
    assert fs.stat("/d1")["nlink"] == 2
    fs.rename("/src/mover", "/d1/mover")
    assert fs.stat("/src")["nlink"] == 2
    assert fs.stat("/d1")["nlink"] == 3


def test_rename_dir_nlink_accounting_reaches_remote_parents():
    """Moving a directory between parents (and displacing a dir victim)
    must keep '..' nlink accounting right even when a parent or the
    moved inode lives on a peer MDT — via remote_nlink_adjust."""
    c = LustreCluster(osts=1, mdses=2, clients=1, commit_interval=64)
    fs = LustreClient(c).mount()
    fs.mkdir("/d1")                              # inode on MDS1
    fs.mkdir("/d1/old")                          # empty dir victim (MDS0)
    fs.mkdir("/x")                               # mover dir (MDS1)
    root_nl = fs.stat("/")["nlink"]
    d1_nl = fs.stat("/d1")["nlink"]
    fs.rename("/x", "/d1/old")                   # coordinator MDS0, dst
    assert fs.stat("/")["nlink"] == root_nl - 1  # remote, dir over dir
    assert fs.stat("/d1")["nlink"] == d1_nl      # -victim +mover
    assert fs.stat("/d1/old")["type"] == "dir"
    assert not fs.exists("/x")


def test_rmdir_split_directory_is_enotempty():
    """A split directory's own entries dict is empty (content lives in
    the hash buckets) — rmdir must refuse it like any non-empty dir
    instead of orphaning the buckets."""
    c = LustreCluster(osts=1, mdses=2, clients=1, commit_interval=64,
                      mds_split_threshold=4)
    fs = LustreClient(c).mount()
    fs.mkdir("/big")
    for i in range(8):                           # trigger the split
        fh = fs.creat(f"/big/f{i}")
        fs.close(fh)
    assert c.stats.counters["mds.dir_split"] >= 1
    with pytest.raises(R.RpcError) as ei:
        fs.rmdir("/big")
    assert ei.value.status == -39
    assert len(fs.readdir("/big")) == 8          # content intact
    # DRAINED split dir is removable, and its bucket inodes die with it
    for i in range(8):
        fs.unlink(f"/big/f{i}")
    inodes = sum(len(t.inodes) for t in c.mds_targets)
    n_buckets = len(c.mds_targets[1].inodes[
        fs.resolve("/big")].ea["buckets"])
    fs.rmdir("/big")
    assert not fs.exists("/big")
    # the dir inode AND every bucket inode are gone
    assert sum(len(t.inodes) for t in c.mds_targets) \
        == inodes - 1 - n_buckets


def test_unlink_rollback_restores_split_dir_entry():
    """Crash rollback of an unlink in a SPLIT directory must restore the
    entry into its hash bucket (the master entries dict is invisible
    once a dir has split) so the name stays resolvable and replayable."""
    from repro.core.mds import fhash
    c = LustreCluster(osts=1, mdses=2, clients=1, commit_interval=10_000,
                      mds_split_threshold=4)
    fs = LustreClient(c).mount()
    fs.mkdir("/big")                             # on MDS1
    for i in range(8):
        fh = fs.creat(f"/big/f{i}")
        fs.close(fh)
    mds1 = c.mds_targets[1]
    assert "buckets" in mds1.inodes[fs.resolve("/big")].ea
    for t in c.mds_targets:
        t.commit()
    # pick an entry whose bucket is LOCAL to MDS1 so the whole unlink+
    # rollback is a single-MDT affair
    name = next(n for n in (f"f{i}" for i in range(8)) if fhash(n, 2) == 0)
    fs.unlink(f"/big/{name}")                    # uncommitted
    mds1.crash()                                 # rollback, no replay
    assert fs.stat(f"/big/{name}")["type"] == "file"   # resolvable again
    assert name in fs.readdir("/big")
    fs.unlink(f"/big/{name}")                    # and unlinkable again
    assert name not in fs.readdir("/big")


def test_rmdir_with_unreachable_bucket_is_ebusy():
    """A hash bucket on an unreachable MDT cannot prove the directory is
    empty: rmdir must refuse with EBUSY instead of destroying a dir that
    may still hold entries there."""
    c = LustreCluster(osts=1, mdses=3, clients=1, commit_interval=64,
                      mds_split_threshold=4)
    fs = LustreClient(c).mount()
    fs.mkdir("/big")
    for i in range(8):
        fh = fs.creat(f"/big/f{i}")
        fs.close(fh)
    for i in range(8):
        fs.unlink(f"/big/f{i}")                  # fully drained
    c.fail_node("mds2")                          # one bucket's MDT dies
    with pytest.raises(R.RpcError) as ei:
        fs.rmdir("/big")
    assert ei.value.status == -16                # EBUSY: cannot prove empty
    assert fs.exists("/big")
    c.restart_node("mds2")
    fs.rmdir("/big")                             # provable again: removed
    assert not fs.exists("/big")


def test_rename_over_dangling_entry_is_tolerated():
    """A displaced entry whose inode is already gone (dangling dentry)
    must not abort the rename mid-mutation: the insert simply replaces
    it, transactionally."""
    c = LustreCluster(osts=1, mdses=2, clients=1, commit_interval=64)
    fs = LustreClient(c).mount()
    user = fs.changelog_register()
    fh = fs.creat("/winner")
    fs.close(fh)
    mds0 = c.mds_targets[0]
    root = mds0.inodes[ROOT_FID]
    # dangling entries: one local-group, one remote-group, neither inode
    # exists anywhere
    root.entries["ghost_l"] = (0, 9999, 1)
    root.entries["ghost_r"] = (1, 9999, 1)
    fs.rename("/winner", "/ghost_l")
    fs.rename("/ghost_l", "/ghost_r")
    assert fs.resolve("/ghost_r") == fs.resolve("/ghost_r")
    assert sorted(fs.readdir("/")) == ["ghost_r"]
    renames = [r for r in fs.changelog_read(user)
               if r["type"] == CL.CL_RENAME]
    assert len(renames) == 2                     # both fully recorded


def test_cross_mdt_link_eexist_leaves_no_stray_nlink():
    """A cross-MDT link that hits EEXIST must not leave the remote
    inode's nlink bumped (the remote_link RPC used to fire before the
    destination-name check, leaking +1 on the peer forever)."""
    c = LustreCluster(osts=1, mdses=2, clients=1, commit_interval=64)
    fs = LustreClient(c).mount()
    fs.mkdir("/d1")                              # on MDS1
    fh = fs.creat("/d1/a")                       # inode on MDS1
    fs.close(fh)
    fh = fs.creat("/x")                          # root name on MDS0
    fs.close(fh)
    afid = fs.resolve("/d1/a")
    assert afid[0] == 1
    nlink_before = c.mds_targets[1].inodes[afid].nlink
    with pytest.raises(R.RpcError):
        fs.link("/d1/a", "/x")                   # EEXIST at the root
    assert c.mds_targets[1].inodes[afid].nlink == nlink_before
    fs.unlink("/d1/a")                           # last link really frees it
    assert afid not in c.mds_targets[1].inodes


# ------------------------------------------------- rollback (no phantoms)

def test_read_stabilizes_uncommitted_records():
    """A record handed to a consumer can never be rolled back: serving
    (or purging) an uncommitted tail forces the MDS journal commit
    first, so a crash after the read keeps exactly what the consumer
    saw."""
    c, fs = mk(commit_interval=10_000)
    mds = c.mds_targets[0]
    user = fs.changelog_register()
    fs.mkdir("/d")                               # uncommitted
    assert mds.committed_transno < mds.transno
    recs = fs.changelog_read(user)
    assert [r["name"] for r in recs] == ["d"]
    assert mds.committed_transno == mds.transno  # read forced the commit
    mds.crash()                                  # nothing left to lose
    assert [r.name for r in mds.changelog.records()] == ["d"]
    assert fs.stat("/d")["type"] == "dir"
    # clear of an uncommitted tail is stabilized the same way
    fs.mkdir("/e")
    fs.changelog_clear(user, mds.changelog.last_idx)
    assert mds.committed_transno == mds.transno
    mds.crash()
    assert fs.stat("/e")["type"] == "dir"


def test_crash_rollback_retracts_uncommitted_records():
    """An aborted (crash-rolled-back) reint must leave no phantom record:
    the changelog emit lives inside the transaction undo scope."""
    c, fs = mk(commit_interval=10_000)
    mds = c.mds_targets[0]
    user = fs.changelog_register()
    fs.mkdir("/durable")
    mds.commit()
    fs.mkdir("/phantom")
    fh = fs.creat("/durable/p2")
    fs.close(fh)
    # mkdir + mkdir + creat + setattr(lov ea) + close
    assert len(mds.changelog.records()) == 5
    mds.crash()                                  # rollback, no replay
    names = [(r.cl_type, r.name) for r in mds.changelog.records()]
    assert names == [(CL.CL_MKDIR, "durable")]


# ------------------------------------------------- llog leak regression

def test_llog_drained_full_log_destroyed():
    """Regression: LlogCatalog.cancel used to keep a drained FULL plain
    log alive forever when it was the last one (the `is not logs[-1]`
    guard); a full log's index slots are consumed, so once empty it must
    be destroyed like any other drained log."""
    cat = LlogCatalog("t")
    cat.LOG_CAP = 4
    cookies = [cat.add("x", {"i": i}).cookie for i in range(4)]
    assert len(cat.logs) == 1 and cat.logs[0].full()
    assert cat.cancel(cookies) == 4
    assert cat.logs == []                        # no leaked handle
    rec = cat.add("x", {"i": 99})
    assert len(cat.logs) == 1
    assert [r.payload["i"] for r in cat.pending()] == [99]
    # partial drain of a multi-log catalog: only the drained full log dies
    cat2 = LlogCatalog("t2")
    cat2.LOG_CAP = 4
    head = [cat2.add("x", {}).cookie for _ in range(4)]
    tail = [cat2.add("x", {}).cookie for _ in range(2)]
    assert len(cat2.logs) == 2
    cat2.cancel(head)
    assert len(cat2.logs) == 1 and len(cat2.pending()) == 2
    rec2 = cat2.add("x", {})                     # current log still open
    assert len(cat2.logs) == 1 and rec2 in cat2.logs[-1].records


def test_changelog_purge_rotates_and_frees_plain_logs():
    """End to end: a long stream with a keeping-up consumer must not
    accumulate plain logs (the leak the llog fix closes)."""
    c, fs = mk()
    mds = c.mds_targets[0]
    mds.changelog.catalog.LOG_CAP = 8
    user = fs.changelog_register()
    for i in range(40):
        fs.mkdir(f"/d{i}")
        recs = fs.changelog_read(user)
        fs.changelog_clear(user, recs[-1]["idx"])
    info = mds.changelog.info()
    assert info["records"] == 0
    assert info["plain_logs"] <= 1               # no drained-log pileup


# ------------------------------------------------------ audit tool (2 MDT)

def test_audit_mirror_matches_ground_truth_across_mdts():
    """ISSUE-2 acceptance: a 2-MDT striped namespace with cross-MDT
    renames/unlinks; the auditor's mirror, rebuilt from merged changelog
    streams alone, matches client-visible readdir/stat exactly."""
    c = LustreCluster(osts=2, mdses=2, clients=1, commit_interval=32)
    fs = LustreClient(c).mount()
    aud = ChangelogAuditor(fs)
    # --- workload: root entries live on MDS0, mkdir fans out to MDS1
    fs.mkdir("/d1")
    fs.mkdir("/d2")
    assert fs.resolve("/d1")[0] == 1             # remote mkdir really hit MDS1
    fh = fs.creat("/top")
    fs.write(fh, b"abc")
    fs.close(fh)
    fh = fs.creat("/d1/a")
    fs.write(fh, b"hello")
    fs.close(fh)
    fh = fs.creat("/d1/b")
    fs.close(fh)
    fs.symlink("/d1/a", "/d2/lnk")
    fs.link("/d1/a", "/d2/a2")
    fs.rename("/top", "/d1/top2")                # cross-MDT: ROOT -> d1
    fs.rename("/d1/b", "/d2/b")
    fs.unlink("/d2/b")
    n = aud.tail()
    assert n >= 10
    report = aud.verify()
    assert report["ok"], report["mismatches"]
    assert report["entries"] >= 5
    # merged feed is time-ordered and spans both MDTs
    times = [r["time"] for r in aud.feed]
    assert times == sorted(times)
    assert {r["mdt"] for r in aud.feed} == {0, 1}
    # the auditor is the only consumer: its clear fully drains both MDTs
    for t in c.mds_targets:
        assert t.changelog.info()["records"] == 0
    # --- second round: cross-MDT unlinks + teardown, incremental tail
    fs.unlink("/d1/a")                           # still linked via /d2/a2
    fs.unlink("/d1/top2")                        # cross-MDT unlink (g0 inode)
    fs.unlink("/d2/a2")                          # last link of a
    fs.unlink("/d2/lnk")
    fs.rmdir("/d2")                              # cross-MDT rmdir
    aud.tail()
    report = aud.verify()
    assert report["ok"], report["mismatches"]
    assert fs.readdir("/d1") == {}
    # cross-MDT halves were merged, not double-applied
    assert aud.mirror.skipped_remote >= 2


def test_audit_mirror_tracks_sizes_and_hardlinks():
    c, fs = mk()
    aud = ChangelogAuditor(fs)
    fh = fs.creat("/f")
    fs.write(fh, b"x" * 1234)
    fs.close(fh)
    fs.link("/f", "/g")
    fs.unlink("/f")                              # /g keeps the inode alive
    aud.tail()
    report = aud.verify()
    assert report["ok"], report["mismatches"]
    gfid = fs.resolve("/g")
    assert aud.mirror.nodes[gfid]["size"] == 1234
    fs.unlink("/g")                              # last link
    aud.tail()
    assert gfid not in aud.mirror.nodes
    assert aud.verify()["ok"]


# ----------------------------------------------------- open-by-fid redirect

def test_open_follows_remote_inode_redirect():
    """A cross-MDT rename leaves a file whose inode lives on a different
    MDT than its parent directory; open() must follow the
    _intent_lookup-style redirect (open by fid at the owning MDT) —
    including write opens, with close routing size/mtime correctly."""
    c = LustreCluster(osts=2, mdses=2, clients=1, commit_interval=64)
    fs = LustreClient(c).mount()
    fs.mkdir("/d1")                              # dir inode on MDS1
    fh = fs.creat("/w", stripe_count=2)          # file inode on MDS0
    fs.write(fh, b"hello")
    fs.close(fh)
    fs.rename("/w", "/d1/w")                     # parent MDS1, inode MDS0
    wfid = fs.resolve("/d1/w")
    assert wfid[0] == 0 and fs.resolve("/d1")[0] == 1
    fh = fs.open("/d1/w")                        # read open: redirected
    assert fs.read(fh, 16) == b"hello"
    fs.close(fh)
    fh = fs.open("/d1/w", "w")                   # write open: redirected
    fs.write(fh, b"HELLO+MORE", offset=0)
    fs.close(fh)
    assert fs.stat("/d1/w")["size"] == 10
    fh = fs.open("/d1/w")
    assert fs.read(fh, 16) == b"HELLO+MORE"
    fs.close(fh)
    # a dangling entry still errors cleanly (ENOENT at the owning MDT)
    c.mds_targets[1].inodes[fs.resolve("/d1")].entries["ghost"] = (0, 999, 1)
    with pytest.raises(FsError) as ei:
        fs.open("/d1/ghost")
    assert ei.value.errno == -2


# ------------------------------------------------------------ changelog_gc

def test_changelog_gc_collects_idle_consumer_by_index_lag():
    """A dead consumer pins the stream forever without GC: with
    gc_max_idle_indexes set, the laggard is deregistered once its
    bookmark falls too far behind, and the purge pin releases."""
    c, fs = mk()
    mds = c.mds_targets[0]
    live = fs.changelog_register()
    dead = fs.changelog_register()               # never reads, never clears
    c.lctl("changelog_gc", "MDS0000", {"max_idle_indexes": 4})
    for i in range(4):
        fs.mkdir(f"/d{i}")
        fs.changelog_clear(live, fs.changelog_read(live)[-1]["idx"])
    assert dead in mds.changelog.users           # lag 4: not yet collected
    assert mds.changelog.info()["records"] == 4  # dead consumer pins
    fs.mkdir("/d4")                              # gc runs pre-emit: lag 4
    assert dead in mds.changelog.users
    fs.mkdir("/d5")                              # pre-emit lag 5 > 4: GC
    assert dead not in mds.changelog.users
    assert dead in mds.changelog.info()["gc"]["collected"]
    fs.changelog_clear(live, fs.changelog_read(live)[-1]["idx"])
    assert mds.changelog.info()["records"] == 0  # pin released
    # the live consumer is untouched and the stream keeps flowing
    fs.mkdir("/d6")
    assert [r["name"] for r in fs.changelog_read(live)] == ["d6"]


def test_changelog_gc_collects_idle_consumer_by_time():
    c, fs = mk()
    mds = c.mds_targets[0]
    idle = fs.changelog_register()
    fs.mkdir("/a")
    c.sim.clock.advance(100.0)                   # consumer goes silent
    collected = c.lctl("changelog_gc", "MDS0000", {"max_idle_time": 50.0})
    assert collected == [idle]
    assert not mds.changelog.users
    # recording stopped with the last consumer gone
    fs.mkdir("/b")
    assert mds.changelog.info()["records"] == 0
    info = mds.changelog.info()["gc"]
    assert info["max_idle_time"] == 50.0 and info["collected"] == [idle]


def test_changelog_gc_knobs_in_procfs():
    c, fs = mk()
    c.lctl("changelog_gc", "MDS0000",
           {"max_idle_indexes": 100, "max_idle_time": 9.0})
    gc = c.procfs()["targets"]["MDS0000"]["changelog"]["gc"]
    assert gc == {"max_idle_indexes": 100, "max_idle_time": 9.0,
                  "collected": []}


# ------------------------------------------------------- mirror bootstrap

def test_audit_bootstrap_from_populated_namespace():
    """ROADMAP item: the mirror can bootstrap from a NON-empty namespace
    (register first, initial scan, changelog catch-up) instead of
    requiring mkfs-time registration."""
    c = LustreCluster(osts=2, mdses=2, clients=1, commit_interval=32)
    fs = LustreClient(c).mount()
    # populate while NOTHING is recorded (no consumer registered)
    fs.mkdir("/pre")
    fs.mkdir("/pre/sub")                         # cross-MDT dirs
    fh = fs.creat("/pre/a", stripe_count=2)
    fs.write(fh, b"12345")
    fs.close(fh)
    fs.link("/pre/a", "/pre/b")                  # hard link pre-dates scan
    fs.symlink("/pre/a", "/pre/s")
    for t in c.mds_targets:
        assert t.changelog.info()["records"] == 0
    aud = ChangelogAuditor(fs, bootstrap=True)
    report = aud.verify()                        # scan alone matches truth
    assert report["ok"], report["mismatches"]
    afid = fs.resolve("/pre/a")
    assert aud.mirror.nodes[afid]["size"] == 5
    assert aud.mirror.nodes[afid]["links"] == {
        (fs.resolve("/pre"), "a"), (fs.resolve("/pre"), "b")}
    # post-registration activity flows in through the changelog
    fs.rename("/pre/a", "/pre/sub/a2")           # cross-MDT rename
    fs.unlink("/pre/b")
    fh = fs.creat("/pre/new")
    fs.close(fh)
    aud.tail()
    report = aud.verify()
    assert report["ok"], report["mismatches"]
    assert afid in aud.mirror.nodes              # alive via /pre/sub/a2


def test_audit_bootstrap_scan_races_with_activity():
    """Ops that land between registration and the end of the scan are
    both scanned AND recorded; catch-up application is idempotent."""
    c = LustreCluster(osts=1, mdses=1, clients=1, commit_interval=32)
    fs = LustreClient(c).mount()
    fs.mkdir("/old")
    aud = ChangelogAuditor(fs)                   # registered, no scan yet
    fs.mkdir("/raced")                           # recorded AND scan-visible
    fh = fs.creat("/raced/f")
    fs.close(fh)
    aud.bootstrap_scan()                         # scan sees /raced too
    report = aud.verify()
    assert report["ok"], report["mismatches"]
    # the raced records were applied on top without duplicating links
    rfid = fs.resolve("/raced/f")
    assert aud.mirror.nodes[rfid]["links"] == {(fs.resolve("/raced"), "f")}


# --------------------------------------------- property: random op streams

_PROP_VERBS = ["create", "mkdir", "rename", "link", "unlink", "tailclear"]


@settings(max_examples=10, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(_PROP_VERBS),
                          st.integers(0, 5), st.integers(0, 5)),
                min_size=4, max_size=28))
def test_property_random_ops_mirror_matches_and_bookmarks_monotonic(ops):
    """Property (ISSUE-3): any interleaving of create/mkdir/rename/link/
    unlink across 2 MDTs, with clears interleaved at arbitrary points,
    keeps (a) the audit mirror identical to the readdir/stat ground
    truth and (b) every consumer bookmark monotonically non-decreasing."""
    c = LustreCluster(osts=1, mdses=2, clients=1, commit_interval=16)
    fs = LustreClient(c).mount()
    aud = ChangelogAuditor(fs)
    fs.mkdir("/dA")                              # landing zones on both MDTs
    fs.mkdir("/dB")
    dirs = ["", "/dA", "/dB"]
    names = [f"n{i}" for i in range(4)]
    last_bm = {i: 0 for i in aud.users}

    def bookmarks_monotonic():
        for i, t in enumerate(c.mds_targets):
            uid = aud.users[i]
            bm = t.changelog.users[uid]
            assert bm >= last_bm[i], (i, bm, last_bm[i])
            last_bm[i] = bm

    for verb, i, j in ops:
        src = f"{dirs[i % 3]}/{names[i % 4]}"
        dst = f"{dirs[j % 3]}/{names[j % 4]}"
        try:
            if verb == "create":
                fs.close(fs.creat(src, stripe_count=1))
            elif verb == "mkdir":
                fs.mkdir(src)
            elif verb == "rename":
                fs.rename(src, dst)
            elif verb == "link":
                fs.link(src, dst)
            elif verb == "unlink":
                fs.unlink(src)
            elif verb == "tailclear":
                aud.tail()
                bookmarks_monotonic()
        except (FsError, R.RpcError):
            pass          # EEXIST/ENOENT/ENOTEMPTY... are legal outcomes
    aud.tail()
    bookmarks_monotonic()
    report = aud.verify()
    assert report["ok"], (ops, report["mismatches"])
    # exactly-once: the merged feed never repeats a (mdt, idx)
    keys = [(r["mdt"], r["idx"]) for r in aud.feed]
    assert len(keys) == len(set(keys))


def test_mirror_standalone_displacing_rename():
    """Unit-level mirror semantics: rename over an existing name kills
    the displaced node when that was its last link."""
    m = NamespaceMirror()
    m.apply({"type": "CREAT", "fid": (0, 2, 1), "pfid": ROOT_FID,
             "name": "a", "idx": 1, "time": 1.0})
    m.apply({"type": "CREAT", "fid": (0, 3, 1), "pfid": ROOT_FID,
             "name": "b", "idx": 2, "time": 2.0})
    m.apply({"type": "RENAME", "fid": (0, 2, 1), "pfid": ROOT_FID,
             "name": "b", "idx": 3, "time": 3.0,
             "extra": {"spfid": ROOT_FID, "sname": "a"}})
    assert m.children[ROOT_FID] == {"b": (0, 2, 1)}
    assert (0, 3, 1) not in m.nodes              # displaced node died
