"""Beyond-paper extensions: hedged reads, int8 checkpoints, elastic
resume across different mesh shapes, example smoke runs."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.ckpt import CheckpointManager
from repro.core import LustreCluster
from repro.core import lov as lov_mod
from repro.fsio import LustreClient

REPO = os.path.join(os.path.dirname(__file__), "..")


# ---------------------------------------------------- straggler mitigation

def test_hedged_read_beats_slow_mirror():
    c = LustreCluster(osts=2, mdses=1, clients=1, commit_interval=16)
    rpc = c.make_client_rpc(0)
    # cache off: this test measures WIRE latency of the straggler mirror
    a, b = c.make_oscs(rpc, writeback=False, max_cached_mb=0)
    r = lov_mod.Raid1(a, b)
    oid = r.create()
    r.write(oid, 0, bytes(1 << 16) * 16)            # 1 MiB mirrored
    # make mirror A a straggler: its link is busy far into the future
    slow_link = (rpc.nid, c.ost_targets[0].node.nid)
    c.network.link_busy[slow_link] = c.now + 10.0
    t0 = c.now
    data = r.read_hedged(oid, 0, 1 << 16)
    dt = c.now - t0
    assert len(data) == 1 << 16
    assert dt < 1.0                                 # did NOT wait for A
    # plain read from A would have taken >= 10 s
    t0 = c.now
    r.a.read(0, oid, 0, 1 << 16)
    assert c.now - t0 > 5.0


def test_race_returns_earliest():
    c = LustreCluster(osts=1, mdses=1, clients=1)

    def fast():
        c.sim.clock.advance(0.1)
        return "fast"

    def slow():
        c.sim.clock.advance(2.0)
        return "slow"

    idx, res = c.sim.race([slow, fast])
    assert (idx, res) == (1, "fast")
    # clock advanced by the winner only
    assert abs(c.now - 0.1) < 1e-9


# ------------------------------------------------------- int8 checkpoints

def test_quantized_checkpoint_roundtrip():
    c = LustreCluster(osts=2, mdses=1, clients=1, commit_interval=32)
    fs = [LustreClient(c).mount()]
    cm = CheckpointManager(fs, stripe_count=2, stripe_size=4096,
                           quantize="int8")
    rng = np.random.default_rng(0)
    w = (rng.standard_normal((128, 64)) * 0.02).astype(np.float32)
    ints = rng.integers(0, 100, 50).astype(np.int32)
    cm.save(1, {"w": w, "step_ids": ints})
    got, m = cm.restore(1)
    # int tensors stored exactly; float tensors within int8 block error
    assert (got["step_ids"] == ints).all()
    rel = np.abs(got["w"] - w).max() / np.abs(w).max()
    assert rel < 0.02, rel
    # compression actually happened (~4x smaller than f32)
    assert m["leaves"]["w"]["bytes"] < w.nbytes // 3


def test_quantized_vs_raw_bytes_on_wire():
    c1 = LustreCluster(osts=2, mdses=1, clients=1, commit_interval=512)
    c2 = LustreCluster(osts=2, mdses=1, clients=1, commit_interval=512)
    arr = {"w": np.random.default_rng(1).standard_normal(
        (256, 256)).astype(np.float32)}
    CheckpointManager([LustreClient(c1).mount()]).save(1, arr)
    CheckpointManager([LustreClient(c2).mount()],
                      quantize="int8").save(1, arr)
    raw = c1.stats.bytes["ost.write"]
    q = c2.stats.bytes["ost.write"]
    assert q < raw / 3


# ------------------------------------------------------- elastic resume

@pytest.mark.slow
def test_elastic_resume_across_mesh_shapes():
    """Train on a (4,2) mesh, resume on (2,4): params must match exactly
    (runs in a subprocess: device count is process-global)."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, numpy as np
        from repro.core import LustreCluster
        from repro.configs import get_smoke_config
        from repro.models.config import RunConfig
        from repro.train.trainer import Trainer, TrainerConfig

        cluster = LustreCluster(osts=2, mdses=1, clients=2,
                                commit_interval=64)
        cfg = TrainerConfig(
            model=get_smoke_config("qwen3-4b"),
            rc=RunConfig(seq_len=32, global_batch=8, kind="train",
                         attn_impl="ref"),
            n_steps=4, ckpt_every=2, dataset_seqs=64, n_writers=1,
            parity=False)
        mesh_a = jax.make_mesh((4, 2), ("data", "model"))
        tr = Trainer(cluster, cfg, mesh=mesh_a)
        tr.run(4)
        want = jax.tree.map(np.asarray, tr.params)

        mesh_b = jax.make_mesh((2, 4), ("data", "model"))   # ELASTIC
        tr2 = Trainer.resume(cluster, cfg, mesh=mesh_b)
        assert tr2.step == 4
        got = jax.tree.map(np.asarray, tr2.params)
        for a, b in zip(jax.tree.leaves(want), jax.tree.leaves(got)):
            assert np.array_equal(a, b)
        # and it can keep training on the new mesh
        tr2.run(2)
        print("ELASTIC-OK")
    """)
    out = subprocess.run(
        [sys.executable, "-c", prog], capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
        timeout=600)
    assert "ELASTIC-OK" in out.stdout, out.stderr[-2000:]


# ------------------------------------------------------- example smokes

@pytest.mark.slow
@pytest.mark.parametrize("script,expect", [
    ("quickstart.py", "virtual time elapsed"),
    ("failover_demo.py", "all six failure modes recovered"),
])
def test_examples_run(script, expect):
    out = subprocess.run(
        [sys.executable, os.path.join(REPO, "examples", script)],
        capture_output=True, text=True, timeout=600,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
        cwd=REPO)
    assert out.returncode == 0, out.stderr[-2000:]
    assert expect in out.stdout
