"""RPC tracing: histograms, span dedup, exactly-once, attribution."""
from repro.core import LustreCluster
from repro.core.metrics import (LatencyHistogram, MetricsRegistry,
                                merge_jobid_histograms)
from repro.fsio import LustreClient


# ------------------------------------------------- histogram unit tests

def test_bucket_edges():
    b = LatencyHistogram.bucket_of
    assert b(0.0) == 0
    assert b(1e-6) == 0                  # 1 us: bucket 0 covers (0, 1]
    assert b(1.5e-6) == 1                # (1, 2] us
    assert b(2e-6) == 1
    assert b(2.1e-6) == 2
    assert b(1.0) == 20                  # 1 s ~ 2^20 us
    assert b(1e16) == LatencyHistogram.MAX_BUCKET    # clamped


def test_quantile_is_bucket_upper_bound():
    h = LatencyHistogram()
    for us in (1, 1, 1, 1, 1, 1, 1, 1, 1, 1000):   # 10 samples
        h.record(us / 1e6)
    assert h.count == 10
    assert h.quantile(0.5) == 1e-6       # bucket 0 upper bound
    # the 1000us straggler sits in bucket 10 -> p99 = 2^10 us = 1024 us
    assert h.quantile(0.99) == 1024 / 1e6
    s = h.summary()
    assert s["count"] == 10 and s["max_s"] == 0.001
    assert s["p99_s"] > s["p50_s"]


def test_merge_matches_single_histogram_and_wire_form():
    samples = [1e-6, 5e-6, 3e-4, 0.01, 2.0]
    whole, a, b = (LatencyHistogram() for _ in range(3))
    for i, s in enumerate(samples):
        whole.record(s)
        (a if i % 2 == 0 else b).record(s)
    merged = LatencyHistogram()
    merged.merge(a)
    merged.merge(b.to_dict())            # wire (dict) form merges too
    assert merged.buckets == whole.buckets
    assert merged.count == whole.count
    for q in (0.5, 0.95, 0.99):
        assert merged.quantile(q) == whole.quantile(q)


def test_merge_jobid_histograms_sums_buckets_across_targets():
    reg = MetricsRegistry()
    for i in range(4):
        reg.record_span(target=f"ost{i % 2}", op="write", export="c0",
                        jobid="jobA", queue_wait=0.0, service=1e-3,
                        seeks=0, nbytes=0, trace_id=100 + i)
    merged = merge_jobid_histograms(
        [reg.target_summary("ost0"), reg.target_summary("ost1")])
    assert merged["jobA"]["count"] == 4  # quantile AFTER the merge
    assert merged["jobA"]["p99_s"] == reg.targets["ost0"].by_jobid[
        "jobA"].quantile(0.99)


def test_registry_dedups_on_trace_id():
    reg = MetricsRegistry()
    kw = dict(target="ost0", op="write", export="c0", jobid="j",
              queue_wait=0.0, service=1e-3, seeks=1, nbytes=10)
    assert reg.record_span(trace_id=7, **kw) is True
    assert reg.record_span(trace_id=7, **kw) is False
    assert reg.targets["ost0"].spans == 1
    assert reg.dup_suppressed == 1


def test_dedup_set_stays_bounded():
    reg = MetricsRegistry()
    reg.DEDUP_LIMIT = 100
    kw = dict(target="t", op="o", export="e", jobid="j", queue_wait=0.0,
              service=1e-6, seeks=0, nbytes=0)
    for t in range(1, 302):
        reg.record_span(trace_id=t, **kw)
    assert len(reg._seen) <= reg.DEDUP_LIMIT
    # recent ids (the only ones resend/replay can revisit) still dedup
    assert reg.record_span(trace_id=301, **kw) is False


# ------------------------------------------- exactly-once through ptlrpc

def _spans_of(c, op):
    return sum(t.by_op[op].count for t in c.sim.metrics.targets.values()
               if op in t.by_op)


def test_resent_request_after_dropped_reply_records_one_span():
    """Reply lost after execution: the resend is served from the reply
    cache (same xid, same trace id) — exactly one span."""
    c = LustreCluster(osts=1, mdses=1, clients=1, commit_interval=512)
    fs = LustreClient(c).mount()
    fh = fs.creat("/f")
    c.lctl("set_param", "fail_loc", "ptlrpc.ost.before_reply", 1, "drop")
    fs.write(fh, b"x" * 4096)
    fs.fsync(fh)                         # the BRW reply is dropped once
    fs.close(fh)
    assert c.stats.counters["rpc.timeout"] >= 1
    assert _spans_of(c, "write") == c.stats.counters["osc.brw_write_rpc"]


def test_request_dropped_before_execution_records_one_span():
    """Request lost before execution: only the resend executes — one
    span, and no dedup suppression needed for it."""
    c = LustreCluster(osts=1, mdses=1, clients=1, commit_interval=512)
    fs = LustreClient(c).mount()
    fh = fs.creat("/f")
    c.lctl("set_param", "fail_loc", "ptlrpc.ost.request_in", 1, "drop")
    fs.write(fh, b"y" * 4096)
    fs.fsync(fh)
    fs.close(fh)
    assert c.stats.counters["rpc.timeout"] >= 1
    assert _spans_of(c, "write") == c.stats.counters["osc.brw_write_rpc"]


def test_replayed_requests_record_one_span_each():
    """MDS crash with uncommitted transactions: replay re-executes the
    same Request objects (same trace ids) — the registry, which lives on
    the Simulator and survives the restart, suppresses the duplicates."""
    c = LustreCluster(osts=1, mdses=1, clients=1, commit_interval=10_000)
    fs = LustreClient(c).mount()
    for i in range(5):
        fs.mkdir(f"/d{i}")
    dups0 = c.sim.metrics.dup_suppressed
    c.fail_node("mds0")
    c.restart_node("mds0")
    assert fs.stat("/d4")["fid"]
    assert c.stats.counters["rpc.replay"] >= 1
    assert c.sim.metrics.dup_suppressed > dups0   # replays were delivered
    # ... and every one was suppressed: one span per client-issued batch
    assert _spans_of(c, "reint_batch") == \
        c.stats.counters.get("wbc.flush", 0)


def test_control_ops_are_not_traced():
    c = LustreCluster(osts=1, mdses=1, clients=1)
    fs = LustreClient(c).mount()
    fs.mkdir("/d")
    ops = set()
    for t in c.sim.metrics.targets.values():
        ops |= set(t.by_op)
    assert not ops & {"connect", "disconnect", "ping"}


# ----------------------------------------------- per-target attribution

def test_node_attribution_sums_to_cluster_totals():
    """Satellite (a): per-target counters partition the global ones.
    Every RPC-side counter must attribute to exactly one serving node,
    so per-node sums equal the cluster total; non-RPC keys may also be
    counted outside any service context, so per-node sums never exceed
    the global value."""
    c = LustreCluster(osts=2, mdses=2, clients=2, commit_interval=8)
    for idx in range(2):
        fs = LustreClient(c, idx).mount()
        for i in range(6):
            fs.mkdir(f"/cl{idx}_d{i}")
        fh = fs.creat(f"/cl{idx}_f", stripe_count=2)
        fs.write(fh, b"z" * (256 << 10))
        fs.fsync(fh)
        fs.close(fh)
        fs.readdir("/")
        fs.stat(f"/cl{idx}_f")
    node_keys = {k for per in c.stats.node_counters.values() for k in per}
    assert any(k.startswith("rpc.mds.") for k in node_keys)
    assert any(k.startswith("rpc.ost.") for k in node_keys)
    for key in node_keys:
        node_sum = sum(per.get(key, 0)
                       for per in c.stats.node_counters.values())
        if key.startswith("rpc."):
            assert node_sum == c.stats.counters[key], key
        else:
            assert node_sum <= c.stats.counters[key], key
    # and the per-node slices name real targets, plus the per-client
    # DLM-callback pseudo-targets (their uuid is the client's rpc uuid)
    real = {t.uuid for t in c.mds_targets + c.ost_targets}
    for uuid in c.stats.node_counters:
        assert uuid in real or uuid.startswith(("client-", "lcb:")), uuid
