"""ptlrpc: requests, recovery semantics (paper ch. 4.5-4.8, 29)."""
import pytest

from repro.core import LustreCluster
from repro.core import ptlrpc as R


def mk(commit_interval=8, **kw):
    c = LustreCluster(osts=1, mdses=1, clients=1,
                      commit_interval=commit_interval, **kw)
    rpc = c.make_client_rpc(0)
    osc = c.make_oscs(rpc, writeback=False)[0]
    return c, rpc, osc


def test_xids_increase_and_never_reuse():
    c, rpc, osc = mk()
    xs = [rpc.next_xid() for _ in range(100)]
    assert xs == sorted(set(xs))


def test_rpc_roundtrip_and_stats():
    c, rpc, osc = mk()
    out = osc.create(0)
    assert out["oid"] >= 2
    assert c.stats.counters["rpc.ost.create"] == 1


def test_request_timeout_advances_clock_and_recovers():
    c, rpc, osc = mk()
    oid = osc.create(0)["oid"]
    t0 = c.now
    c.sim.faults.drop_next[c.ost_targets[0].node.nid] = 1
    osc.write(0, oid, 0, b"x" * 10)
    # adaptive timeouts: a cold import waits out at least at_min (the
    # fixed DEFAULT_TIMEOUT only applies with AT disabled)
    assert c.now - t0 >= R.AT_MIN
    assert c.stats.counters["rpc.timeout"] == 1
    assert osc.read(0, oid, 0, 10) == b"x" * 10


def test_reply_cache_answers_resend_of_executed_update():
    c, rpc, osc = mk()
    oid = osc.create(0)["oid"]
    osc.write(0, oid, 0, b"A" * 4)
    c.sim.faults.drop_next[rpc.nid] = 1            # lose the reply
    osc.write(0, oid, 4, b"B" * 4)
    assert c.stats.counters["rpc.reply_cache_hit"] == 1
    # the write was NOT executed twice
    assert osc.read(0, oid, 0, 8) == b"AAAABBBB"


def test_crash_loses_uncommitted_replay_restores():
    c, rpc, osc = mk(commit_interval=1000)
    oid = osc.create(0)["oid"]
    osc.write(0, oid, 0, b"hello")
    t = c.ost_targets[0]
    assert t.committed_transno == 0
    c.fail_node("ost0")
    c.restart_node("ost0")
    assert osc.read(0, oid, 0, 5) == b"hello"
    assert c.stats.counters["rpc.replay"] == 2     # create + write


def test_committed_state_survives_without_replay():
    c, rpc, osc = mk(commit_interval=1)            # commit every op
    oid = osc.create(0)["oid"]
    osc.write(0, oid, 0, b"hello")
    c.fail_node("ost0")
    c.restart_node("ost0")
    assert osc.read(0, oid, 0, 5) == b"hello"
    assert c.stats.counters.get("rpc.replay", 0) == 0


def test_replay_prunes_after_commit():
    c, rpc, osc = mk(commit_interval=4)
    oid = osc.create(0)["oid"]
    for i in range(8):
        osc.write(0, oid, i, b"z")
    # everything through transno 8 committed (interval 4): list small
    assert len(osc.imp.replay_list) <= 4


def test_recovery_window_gates_new_clients():
    c = LustreCluster(osts=1, mdses=1, clients=2, commit_interval=4)
    rpc1 = c.make_client_rpc(0)
    osc1 = c.make_oscs(rpc1, writeback=False)[0]
    oid = osc1.create(0)["oid"]
    c.fail_node("ost0")
    c.restart_node("ost0")
    # client 1 reconnects (recovery completes: it's the only known client)
    assert osc1.read(0, oid, 0, 0) == b""
    assert not c.ost_targets[0].recovering


def test_vbr_no_blanket_eviction_straggler_replays_late():
    """VBR replaces the pre-VBR blanket eviction at window close: a
    straggler that misses the window is merely counted, and when it
    finally returns its replays are admitted because their pre-op
    versions still match (its objects are its own)."""
    c = LustreCluster(osts=1, mdses=1, clients=2, commit_interval=1000)
    rpc1 = c.make_client_rpc(0)
    rpc2 = c.make_client_rpc(1)
    osc1 = c.make_oscs(rpc1, writeback=False)[0]
    osc2 = c.make_oscs(rpc2, writeback=False)[0]
    osc1.create(0)
    oid2 = osc2.create(0)["oid"]
    osc2.write(0, oid2, 0, b"mine")
    c.fail_node("ost0")
    c.restart_node("ost0")
    # only client1 comes back; deadline expiry closes the window WITHOUT
    # evicting client2
    osc1.statfs()
    c.sim.clock.advance(4 * R.DEFAULT_TIMEOUT)
    osc1.statfs()
    t = c.ost_targets[0]
    assert not t.recovering
    assert c.stats.counters.get("rpc.recovery_eviction", 0) == 0
    assert c.stats.counters.get("rpc.recovery_stragglers", 0) >= 1
    assert rpc2.uuid not in t.evicted
    # delayed recovery: client2 reconnects late, replays, and its data
    # survives — the version check proves the replay still applies
    assert osc2.read(0, oid2, 0, 4) == b"mine"
    assert c.stats.counters.get("rpc.vbr_admit", 0) >= 1
    assert c.stats.counters.get("rpc.vbr_eviction", 0) == 0


def test_failover_ring_walks_nids(cluster):
    rpc = cluster.make_client_rpc(0)
    osc = cluster.make_oscs(rpc, writeback=False)[0]
    oid = osc.create(0)["oid"]
    osc.write(0, oid, 0, b"data")
    cluster.ost_targets[0].commit()
    cluster.fail_node("ost0")
    assert osc.read(0, oid, 0, 4) == b"data"
    assert osc.imp.active_nid != "elan:ost0"


def test_wire_size_estimates():
    assert R.wire_size(b"x" * 100) == 100
    assert R.wire_size({"a": 1}) > 8
    assert R.wire_size(None) == 0
