"""Metadata read-path batching (ISSUE-5 tentpole).

Covers the acceptance criteria:
  * readdir-plus: a directory scan costs O(N/page) MDS RPCs, entries
    carry attrs + LOV EAs, split-dir buckets page at THEIR MDS and
    cross-MDT inodes batch-resolve with one getattr_bulk per MDT;
  * the fid attr cache: a warm re-stat of a scanned tree is ZERO RPCs,
    and a second client's chmod/truncate/write-close invalidates via
    blocking AST (plus a hypothesis property test: random stat/setattr
    interleavings across two clients never serve stale attrs);
  * statahead: sequential stats over a plain readdir prefetch attr
    windows in batch; an armed `mds.statahead` drop degrades to correct
    synchronous stats;
  * batched glimpse: stat/scan of files under write asks each OST ONCE
    for many objects via glimpse ASTs — writers keep their PW locks and
    dirty caches.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                               # pragma: no cover
    from _hyposhim import given, settings, strategies as st

from repro.core import LustreCluster
from repro.fsio import FsError, LustreClient


def mk(**kw):
    kw.setdefault("osts", 2)
    kw.setdefault("mdses", 1)
    kw.setdefault("clients", 3)
    kw.setdefault("commit_interval", 256)
    return LustreCluster(**kw)


def mds_rpcs(c):
    return sum(n for k, n in c.stats.counters.items()
               if k.startswith("rpc.mds."))


def all_rpcs(c):
    """Every RPC of any kind (MDS, OST, DLM callbacks, ...)."""
    return sum(n for k, n in c.stats.counters.items()
               if k.startswith("rpc."))


def build_tree(c, n, *, path="/scan", close=True, stripe_count=2,
               idx=0):
    fs = LustreClient(c, idx).mount()
    fs.mkdir_p(path)
    handles = []
    for i in range(n):
        fh = fs.creat(f"{path}/f{i:04d}", stripe_count=stripe_count)
        fs.write(fh, b"x" * (512 * (1 + i % 3)))
        if close:
            fs.close(fh)
        else:
            handles.append(fh)
    return fs, handles


# ------------------------------------------------------------ readdir-plus

def test_readdir_plus_pages_and_rpc_count():
    c = mk(dir_pages=8)
    build_tree(c, 32)
    fs2 = LustreClient(c, 1).mount()
    base_pages = c.stats.counters.get("mds.intent.readdir", 0)
    base_getattr = c.stats.counters.get("rpc.mds.getattr", 0)
    listing = fs2.ls_l("/scan")
    assert len(listing) == 32
    # 32 entries / 8 per page = 4 page RPCs, not one getattr per entry
    assert c.stats.counters["mds.intent.readdir"] - base_pages == 4
    assert c.stats.counters.get("rpc.mds.getattr", 0) == base_getattr


def test_readdir_plus_attrs_match_ground_truth():
    c = mk(dir_pages=8)
    fs, _ = build_tree(c, 12)
    fs.chmod("/scan/f0003", 0o600)
    fs2 = LustreClient(c, 1).mount()
    listing = fs2.ls_l("/scan")
    truth = LustreClient(c, 2).mount()
    for name, a in listing.items():
        t = truth.stat("/scan/" + name)
        assert a["size"] == t["size"], name
        assert a["mode"] == t["mode"], name
        assert a["stripe_count"] == t["stripe_count"], name
    assert listing["f0003"]["mode"] == 0o600


def test_warm_restat_of_scanned_tree_is_zero_rpcs():
    """Acceptance: after a cold scan, re-statting every entry is served
    entirely from the DLM-covered dentry + attr caches — ZERO RPCs of
    any kind."""
    c = mk(dir_pages=16)
    build_tree(c, 48)
    fs2 = LustreClient(c, 1).mount()
    listing = fs2.ls_l("/scan")
    base = all_rpcs(c)
    for name in listing:
        st_ = fs2.stat("/scan/" + name)
        assert st_["size"] == listing[name]["size"]
    assert all_rpcs(c) == base
    assert c.stats.counters["fs.attr_hit"] >= 48


def test_walk_rides_readdir_plus_pages():
    c = mk(dir_pages=16)
    fs, _ = build_tree(c, 40)
    fs.mkdir("/scan/sub")
    fh = fs.creat("/scan/sub/inner")
    fs.close(fh)
    fs2 = LustreClient(c, 1).mount()
    base = mds_rpcs(c)
    seen = {(tuple(p), n): a for p, n, f, a in fs2.walk()}
    # 41 entries under /scan + sub's child + /scan itself
    assert len(seen) == 43
    # pages, not per-entry getattrs: far fewer MDS RPCs than entries
    assert mds_rpcs(c) - base <= 10
    # and a ground-truth spot check
    truth = fs.stat("/scan/f0000")
    got = next(a for (p, n), a in seen.items() if n == "f0000")
    assert got["size"] == truth["size"]


def test_dir_pages_zero_keeps_seed_shape():
    c = mk(dir_pages=0, statahead_max=0)
    build_tree(c, 8)
    fs2 = LustreClient(c, 1).mount()
    base = c.stats.counters.get("mds.intent.readdir", 0)
    base_enq = c.stats.counters.get("rpc.mds.ldlm_enqueue", 0)
    listing = fs2.ls_l("/scan")
    assert len(listing) == 8
    assert c.stats.counters.get("mds.intent.readdir", 0) == base
    # per-entry path: one lookup enqueue per name (the attrs then ride
    # the lookup's lock — the fid attr cache works even without pages)
    assert c.stats.counters.get("rpc.mds.ldlm_enqueue", 0) - base_enq >= 8


# ------------------------------------------------- split / cross-MDT dirs

def test_readdir_plus_split_dir_pages_per_mdt():
    c = LustreCluster(osts=2, mdses=2, clients=2, commit_interval=256,
                      mds_split_threshold=8, dir_pages=8)
    fs = LustreClient(c, 0).mount()
    fs.mkdir("/big", mode=0o755)
    names = [f"e{i:03d}" for i in range(24)]
    for n in names:
        fs.close(fs.creat(f"/big/{n}", stripe_count=1))
    assert c.stats.counters.get("mds.dir_split", 0) >= 1
    fs2 = LustreClient(c, 1).mount()
    base_getattr = c.stats.counters.get("rpc.mds.getattr", 0)
    listing = fs2.ls_l("/big")
    assert sorted(listing) == names
    # bucket pages at their MDS + batched remote resolution — never one
    # plain getattr per name
    assert c.stats.counters.get("rpc.mds.getattr", 0) - base_getattr \
        <= len(names) // 4
    truth = LustreClient(c, 0).mount()
    for n in names[:6]:
        assert listing[n]["size"] == truth.stat(f"/big/{n}")["size"]


def test_readdir_plus_cross_mdt_inodes_batch_one_bulk_per_mdt():
    """mkdir round-robins dirs onto peer MDTs (§6.7.1.2): a dir full of
    subdirs has remote-inode entries. The LMV must resolve them with
    getattr_bulk batches, not a getattr per name."""
    c = LustreCluster(osts=2, mdses=2, clients=2, commit_interval=256,
                      dir_pages=16)
    fs = LustreClient(c, 0).mount()
    fs.mkdir("/d")
    for i in range(12):
        fs.mkdir(f"/d/s{i:02d}")
    fs2 = LustreClient(c, 1).mount()
    base_bulk = c.stats.counters.get("rpc.mds.getattr_bulk", 0)
    base_getattr = c.stats.counters.get("rpc.mds.getattr", 0)
    listing = fs2.ls_l("/d")
    assert len(listing) == 12
    assert all(a["type"] == "dir" for a in listing.values())
    assert c.stats.counters.get("rpc.mds.getattr_bulk", 0) > base_bulk
    # one bulk per MDT per page, not one getattr per remote entry
    assert c.stats.counters.get("rpc.mds.getattr", 0) - base_getattr <= 2


# --------------------------------------------------- attr-cache coherency

def test_remote_chmod_invalidates_cached_attrs():
    c = mk(dir_pages=8)
    build_tree(c, 4)
    a = LustreClient(c, 1).mount()
    b = LustreClient(c, 2).mount()
    assert a.ls_l("/scan")["f0001"]["mode"] == 0o644
    assert a.stat("/scan/f0001")["mode"] == 0o644       # warm, cached
    b.chmod("/scan/f0001", 0o640)                       # AST revokes a's lock
    assert a.stat("/scan/f0001")["mode"] == 0o640       # never stale
    assert c.stats.counters["fs.attr_invalidate"] >= 1


def test_remote_truncate_invalidates_cached_attrs():
    c = mk(dir_pages=8)
    build_tree(c, 4)
    a = LustreClient(c, 1).mount()
    b = LustreClient(c, 2).mount()
    old = a.ls_l("/scan")["f0002"]["size"]
    assert a.stat("/scan/f0002")["size"] == old
    b.truncate("/scan/f0002", 7)
    assert a.stat("/scan/f0002")["size"] == 7


def test_remote_write_close_invalidates_cached_attrs():
    c = mk(dir_pages=8)
    build_tree(c, 4)
    a = LustreClient(c, 1).mount()
    b = LustreClient(c, 2).mount()
    before = a.ls_l("/scan")["f0000"]["size"]
    fh = b.open("/scan/f0000", "w")
    b.write(fh, b"y" * 4096, offset=0)
    # mtime_on_ost flipped: a's cached attrs were revoked, a live stat
    # must glimpse the OSTs and see the writer's (unflushed) data
    assert a.stat("/scan/f0000")["size"] == 4096
    b.close(fh)
    assert a.stat("/scan/f0000")["size"] == 4096 != before


@settings(max_examples=12, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 1),       # acting client
                          st.integers(0, 1),       # target file
                          st.sampled_from(["stat", "chmod", "trunc"]),
                          st.integers(0, 7)),      # op argument
                min_size=1, max_size=24))
def test_property_interleaved_stat_setattr_never_stale(ops):
    """Random stat/setattr interleavings across two clients: a stat
    NEVER returns attrs older than the last applied setattr (the DLM
    revocation makes the attr cache coherent, not merely fast)."""
    c = LustreCluster(osts=1, mdses=1, clients=2, commit_interval=64,
                      dir_pages=4)
    clients = [LustreClient(c, 0).mount(), LustreClient(c, 1).mount()]
    clients[0].mkdir("/p")
    model = {}
    for i in range(2):
        fh = clients[0].creat(f"/p/f{i}", stripe_count=1)
        clients[0].write(fh, b"z" * 64)
        clients[0].close(fh)
        model[i] = {"mode": 0o644, "size": 64}
    for cl in clients:                     # both caches warm
        cl.ls_l("/p")
    for who, tgt, op, arg in ops:
        path = f"/p/f{tgt}"
        if op == "stat":
            got = clients[who].stat(path)
            assert got["mode"] == model[tgt]["mode"], (who, tgt)
            assert got["size"] == model[tgt]["size"], (who, tgt)
        elif op == "chmod":
            mode = 0o600 + arg
            clients[who].chmod(path, mode)
            model[tgt]["mode"] = mode
        else:
            clients[who].setattr(path, size=arg * 16)
            model[tgt]["size"] = arg * 16


# ------------------------------------------------------------- statahead

def test_statahead_batches_sequential_stats():
    """dir_pages=0 (no readdir-plus): sequential stats over a plain
    readdir must still collapse into batched getattr_bulk windows."""
    c = mk(dir_pages=0, statahead_max=8)
    build_tree(c, 32)
    fs2 = LustreClient(c, 1).mount()
    names = sorted(fs2.readdir("/scan"))
    truth = {n: LustreClient(c, 2).mount().stat("/scan/" + n)["size"]
             for n in names[:3]}
    base = mds_rpcs(c)
    for n in names:
        fs2.stat("/scan/" + n)
    spent = mds_rpcs(c) - base
    # 32 per-entry stats would cost >= 64 RPCs (lookup + getattr each);
    # statahead turns the tail into ~32/8 bulk fetches
    assert spent <= 16, spent
    assert c.stats.counters["fs.statahead"] >= 3
    assert c.stats.counters["fs.attr_hit"] >= 20
    for n, size in truth.items():
        assert fs2.stat("/scan/" + n)["size"] == size


def test_statahead_random_order_does_not_prefetch():
    c = mk(dir_pages=0, statahead_max=8)
    build_tree(c, 16)
    fs2 = LustreClient(c, 1).mount()
    names = sorted(fs2.readdir("/scan"))
    for n in names[::-1][:6]:              # backwards: never sequential
        fs2.stat("/scan/" + n)
    assert c.stats.counters.get("fs.statahead", 0) == 0


def test_statahead_cross_mdt_prefetch_never_stale():
    """One-shot prefetched attrs of cross-MDT inodes must die when the
    inode changes: the owning MDT forwards a revoke_dir_locks to the
    directory's MDT (Inode.remote_pfids), which kills the dir lock the
    prefetch ran under — the next stat re-fetches."""
    c = LustreCluster(osts=2, mdses=2, clients=3, commit_interval=256,
                      dir_pages=0, statahead_max=8)
    b = LustreClient(c, 0).mount()
    b.mkdir("/d")
    for i in range(8):
        b.mkdir(f"/d/s{i}")                    # remote-MDT children
    a = LustreClient(c, 1).mount()
    names = sorted(a.readdir("/d"))
    a.stat("/d/" + names[0])
    a.stat("/d/" + names[1])                   # sequential: prefetch fires
    assert c.stats.counters.get("fs.statahead", 0) >= 1
    assert a._sa_attrs                         # one-shot entries pending
    w = LustreClient(c, 2).mount()
    w.chmod("/d/" + names[3], 0o700)           # remote-MDT setattr
    assert a.stat("/d/" + names[3])["mode"] == 0o700   # never stale
    assert c.stats.counters.get("fs.statahead_stale_dropped", 0) >= 1


def test_statahead_obd_fail_drop_degrades_to_sync_stat():
    """Satellite: an armed mds.statahead drop loses the prefetch; every
    stat falls back to a correct synchronous fetch."""
    c = mk(dir_pages=0, statahead_max=8)
    build_tree(c, 12)
    fs2 = LustreClient(c, 1).mount()
    names = sorted(fs2.readdir("/scan"))
    c.lctl("set_param", "fail_loc", "mds.statahead", 1, "drop")
    sizes = [fs2.stat("/scan/" + n)["size"] for n in names]
    assert c.stats.counters["fs.statahead_dropped"] == 1
    assert c.sim.fail.hits.get("mds.statahead", 0) >= 1
    truth = LustreClient(c, 2).mount()
    assert sizes == [truth.stat("/scan/" + n)["size"] for n in names]


# -------------------------------------------------------- batched glimpse

def test_scan_glimpses_open_files_batched_per_ost():
    """Files under write: ONE vectored glimpse RPC per OST covers every
    such file's stripe objects (vs stripe_count RPCs per file)."""
    c = mk(osts=4, dir_pages=16)
    w, handles = build_tree(c, 8, close=False, stripe_count=2)
    fs2 = LustreClient(c, 1).mount()
    base = c.stats.counters.get("rpc.ost.glimpse_bulk", 0)
    listing = fs2.ls_l("/scan")
    assert c.stats.counters["rpc.ost.glimpse_bulk"] - base <= 4  # <= #OSTs
    for i, fh in enumerate(handles):
        assert listing[f"f{i:04d}"]["size"] == fh.max_written
    # the writers' PW locks and dirty caches survived the whole scan
    assert all(o.dirty_bytes >= 0 for o in w.lov.oscs)
    assert sum(o.dirty_bytes for o in w.lov.oscs) > 0


def test_glimpse_does_not_revoke_writer_lock():
    """Satellite regression: a stat of a file under write asks the PW
    holder for its LVB via a glimpse AST — the writer's dirty cache and
    lock survive (before: the PR enqueue revoked them)."""
    c = mk()
    w = LustreClient(c, 0).mount()
    fh = w.creat("/hot.bin", stripe_count=1)
    w.write(fh, b"d" * 8192)                     # dirty, unflushed
    dirty_before = sum(o.dirty_bytes for o in w.lov.oscs)
    locks_before = sum(len(o.locks.locks) for o in w.lov.oscs)
    assert dirty_before == 8192
    r = LustreClient(c, 1).mount()
    base_bl = c.stats.counters.get("dlm.blocking_ast", 0)
    st_ = r.stat("/hot.bin")
    assert st_["size"] == 8192                   # live size via glimpse
    assert sum(o.dirty_bytes for o in w.lov.oscs) == dirty_before
    assert sum(len(o.locks.locks) for o in w.lov.oscs) == locks_before
    assert c.stats.counters["dlm.glimpse_ast"] >= 1
    assert c.stats.counters.get("dlm.blocking_ast", 0) == base_bl
    w.close(fh)
    assert r.stat("/hot.bin")["size"] == 8192


def test_osc_getattr_locked_glimpses_instead_of_revoking():
    c = mk(osts=1)
    a = c.make_oscs(c.make_client_rpc(0))[0]
    b = c.make_oscs(c.make_client_rpc(1))[0]
    oid = a.create(0)["oid"]
    a.write(0, oid, 0, b"w" * 4096)              # dirty under PW
    assert a.dirty_bytes == 4096
    got = b.getattr_locked(0, oid)
    assert got["size"] == 4096                   # writer's live size
    assert a.dirty_bytes == 4096                 # cache NOT flushed
    assert a.locks.locks                         # lock NOT revoked
    assert c.stats.counters["osc.glimpse_answered"] >= 1


def test_hard_linked_names_both_get_live_glimpse_size():
    """Two links to one file under write: the batched glimpse answer
    must land on EVERY linked name, not just the last one seen."""
    c = mk(dir_pages=16)
    w = LustreClient(c, 0).mount()
    w.mkdir("/d")
    fh = w.creat("/d/a", stripe_count=1)
    w.write(fh, b"L" * 4096)                     # dirty, open, unflushed
    w.link("/d/a", "/d/b")
    listing = LustreClient(c, 1).mount().ls_l("/d")
    assert listing["a"]["size"] == 4096
    assert listing["b"]["size"] == 4096


def test_own_update_does_not_revoke_own_dir_cache():
    """The requester is spared from the revocation storm (it fixes its
    own caches locally): creating one more file must not tear down the
    creator's cached attrs for the directory's OTHER entries."""
    c = mk(dir_pages=16)
    fs, _ = build_tree(c, 8)
    listing = fs.ls_l("/scan")
    base_ast = c.stats.counters.get("dlm.client_bl_ast", 0)
    fs.close(fs.creat("/scan/extra"))            # own create
    assert c.stats.counters.get("dlm.client_bl_ast", 0) == base_ast
    base = all_rpcs(c)
    assert fs.stat("/scan/f0003")["size"] == listing["f0003"]["size"]
    assert all_rpcs(c) == base                   # still warm
    # and the dir's own attrs were self-invalidated, not served stale
    assert fs.stat("/scan")["nentries"] == 9


def test_readdir_plus_pagination_stable_under_mutation():
    """Name-cursor paging: an unlink/create between two page RPCs must
    not skip or duplicate entries that existed for the whole scan."""
    c = mk(dir_pages=4)
    fs, _ = build_tree(c, 12)
    fs2 = LustreClient(c, 1).mount()
    dfid = fs2.resolve("/scan")
    pages = fs2.lmv.readdir_plus(dfid, 4)
    _, _, first = next(pages)                    # page 1 = f0000..f0003
    fs.unlink("/scan/f0000")                     # mutate mid-scan
    fs.close(fs.creat("/scan/f0001a"))
    seen = list(first)
    for _, _, page in pages:
        seen.extend(page)
    survivors = [f"f{i:04d}" for i in range(1, 12)]
    assert len(seen) == len(set(seen))           # no duplicates
    assert set(survivors) <= set(seen)           # nothing skipped


# ------------------------------------------------------------------ misc

def test_md_cache_rollup_in_procfs():
    c = mk(dir_pages=8)
    build_tree(c, 8)
    fs2 = LustreClient(c, 1).mount()
    fs2.ls_l("/scan")
    fs2.stat("/scan/f0001")
    mc = c.procfs()["md_cache"]
    assert mc["attr_hits"] >= 1
    assert mc["readdir_plus_pages"] >= 1


def test_readdir_plus_enoent_and_enotdir():
    c = mk(dir_pages=8)
    fs, _ = build_tree(c, 2)
    with pytest.raises(FsError):
        fs.ls_l("/nope")
    fs2 = LustreClient(c, 1).mount()
    listing = fs2.ls_l("/")
    assert "scan" in listing and listing["scan"]["type"] == "dir"
