"""OBD devices, transactions, llog, snapshots (paper ch. 5, 8)."""
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # bare env: sampled fallback
    from _hyposhim import given, settings, strategies as st

from repro.core import llog as L
from repro.core import obd as O
from repro.core.snapshot import SnapDevice


def test_filter_crud():
    d = O.FilterDevice("d", capacity=1 << 20)
    out = d.create(0)
    oid = out["oid"]
    d.write(0, oid, 0, b"hello world")
    assert d.read(0, oid, 0, 5) == b"hello"
    assert d.getattr(0, oid)["size"] == 11
    d.punch(0, oid, 5)
    assert d.getattr(0, oid)["size"] == 5
    d.destroy(0, oid)
    with pytest.raises(O.ObdError):
        d.getattr(0, oid)


def test_create_with_requested_oid_and_eexist():
    d = O.FilterDevice("d")
    d.create(0, oid=4711)                      # §5.2.3: exact-id create
    assert d.getattr(0, 4711)["size"] == 0
    with pytest.raises(O.ObdError):
        d.create(0, oid=4711)


def test_object_groups_independent():
    d = O.FilterDevice("d")
    d.create(1, oid=5)
    d.create(2, oid=5)                         # same oid, different group
    d.write(1, 5, 0, b"g1")
    d.write(2, 5, 0, b"g2")
    assert d.read(1, 5, 0, 2) == b"g1"
    assert d.read(2, 5, 0, 2) == b"g2"
    assert d.list_objects(1) == [5]


def test_enospc():
    d = O.FilterDevice("d", capacity=100)
    oid = d.create(0)["oid"]
    with pytest.raises(O.ObdError):
        d.write(0, oid, 0, b"x" * 200)


def _apply(dev: O.FilterDevice, op) -> None:
    kind, off, data = op
    if kind == 0:
        dev.write(0, 100, off, data)
    elif kind == 1:
        dev.punch(0, 100, off)
    elif kind == 2:
        dev.setattr(0, 100, tag=data.hex())
    else:
        dev.write(0, 100, off // 2, data * 2)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 200),
                          st.binary(min_size=1, max_size=64)),
                min_size=1, max_size=24),
       st.integers(0, 24))
def test_crash_rolls_back_to_committed_prefix(ops, cut):
    """Property (paper ch.11): after a crash, the device state equals the
    state produced by exactly the committed prefix of operations.
    Txn 1 is the create; op i is txn i+2."""
    cut = min(cut, len(ops) + 1)

    # device A: everything applied, then crash undoes txns > cut
    undo_log = []
    a = O.FilterDevice("a")
    a.txn_hook = lambda undo: (undo_log.append(undo), len(undo_log))[1]
    a.create(0, oid=100)
    for op in ops:
        _apply(a, op)
    for t in range(len(undo_log), cut, -1):
        undo_log[t - 1]()

    # device B: only the committed prefix ever ran. Ops may produce ZERO
    # transactions (no-op punch), so count txns exactly like A did and
    # stop once the committed budget is used.
    b = O.FilterDevice("b")
    b_txns = [0]
    b.txn_hook = lambda undo: (b_txns.__setitem__(0, b_txns[0] + 1),
                               b_txns[0])[1]
    if cut >= 1:
        b.create(0, oid=100)
        for op in ops:
            if b_txns[0] >= cut:
                break
            _apply(b, op)

    oa, ob = a.objects.get((0, 100)), b.objects.get((0, 100))
    assert (oa is None) == (ob is None)
    if oa is not None:
        assert bytes(oa.data) == bytes(ob.data)
        assert oa.attrs == ob.attrs
        assert a.used == b.used


# ------------------------------------------------------------------ llog

def test_llog_add_cancel_pending():
    cat = L.LlogCatalog("c")
    recs = [cat.add("unlink", {"oid": i}) for i in range(10)]
    assert len(cat.pending()) == 10
    cat.cancel([recs[3].cookie, recs[7].cookie])
    assert len(cat.pending()) == 8
    assert all(r.payload["oid"] not in (3, 7) for r in cat.pending())


def test_llog_catalog_rolls_plain_logs():
    cat = L.LlogCatalog("c")
    for i in range(150):
        cat.add("x", {"i": i})
    assert len(cat.logs) == 3                  # 64-cap plain logs
    cat.cancel([r.cookie for r in cat.pending()][:64])
    assert len(cat.pending()) == 86


def test_llog_process_cancels_successful():
    cat = L.LlogCatalog("c")
    for i in range(6):
        cat.add("x", {"i": i})
    n = cat.process(lambda rec: rec.payload["i"] % 2 == 0)
    assert n == 3 and len(cat.pending()) == 3


# -------------------------------------------------------------- snapshot

def test_snapshot_cow_versions():
    bot = O.FilterDevice("bot")
    cur = SnapDevice("cur", bot, 0)
    oid = cur.create(0)["oid"]
    cur.write(0, oid, 0, b"v1-data-x")
    s1 = cur.snap_add("monday", time=1e9)
    cur.write(0, oid, 0, b"v2-data-y")
    s2 = cur.snap_add("tuesday", time=2e9)
    cur.write(0, oid, 0, b"v3-data-z")
    assert cur.read(0, oid, 0, 9) == b"v3-data-z"
    assert SnapDevice("a", bot, s1).read(0, oid, 0, 9) == b"v1-data-x"
    assert SnapDevice("b", bot, s2).read(0, oid, 0, 9) == b"v2-data-y"


def test_snapshot_readonly_enforced():
    bot = O.FilterDevice("bot")
    cur = SnapDevice("cur", bot, 0)
    oid = cur.create(0)["oid"]
    cur.write(0, oid, 0, b"x")
    idx = cur.snap_add("s", time=1e9)
    ro = SnapDevice("ro", bot, idx)
    with pytest.raises(O.ObdError):
        ro.write(0, oid, 0, b"nope")
    with pytest.raises(O.ObdError):
        ro.destroy(0, oid)


def test_snapshot_restore():
    bot = O.FilterDevice("bot")
    cur = SnapDevice("cur", bot, 0)
    oid = cur.create(0)["oid"]
    cur.write(0, oid, 0, b"original!")
    idx = cur.snap_add("keep", time=1e9)
    cur.write(0, oid, 0, b"clobbered")
    cur.snap_restore(idx)
    assert cur.read(0, oid, 0, 9) == b"original!"
