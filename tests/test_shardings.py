"""Sharding resolution + HLO cost analyzer properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # bare env: sampled fallback
    from _hyposhim import given, settings, strategies as st
from jax.sharding import Mesh, PartitionSpec as P

from repro.parallel import shardings as sh
from repro.tools import hlo_cost


def mesh2(d=2, m=2):
    devs = np.array(jax.devices()[:1] * (d * m)).reshape(d, m)
    return Mesh(devs, ("data", "model"))


# resolve_spec is pure given mesh axis sizes: test the logic via a real
# 1-device mesh is impossible for >1 axes, so fabricate with repeated
# device (allowed for spec computation only).

def test_resolve_divisibility():
    m = mesh2(2, 2)
    assert sh.resolve_spec(m, ("batch", None), (4, 3)) == P("data", None)
    assert sh.resolve_spec(m, ("batch", None), (3, 3)) == P(None, None)
    assert sh.resolve_spec(m, (None, "model"), (3, 4)) == P(None, "model")
    assert sh.resolve_spec(m, (None, "model"), (3, 5)) == P(None, None)


def test_model2_fallback():
    m = mesh2(2, 2)
    # kv-heads (3) not divisible -> head_dim picks up the model axis
    spec = sh.resolve_spec(m, (None, "model", "model2"), (8, 3, 4))
    assert spec == P(None, None, "model")
    # kv-heads divisible -> head_dim stays replicated
    spec = sh.resolve_spec(m, (None, "model", "model2"), (8, 4, 4))
    assert spec == P(None, "model", None)


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(1, 64), min_size=1, max_size=4),
       st.lists(st.sampled_from(["batch", "model", "model2", None]),
                min_size=1, max_size=4))
def test_resolve_never_overshards(dims, logical):
    n = min(len(dims), len(logical))
    dims, logical = dims[:n], logical[:n]
    m = mesh2(2, 2)
    spec = sh.resolve_spec(m, logical, dims)
    sizes = {"data": 2, "model": 2, ("pod", "data"): 4}
    model_used = 0
    for dim, s in zip(dims, spec):
        if s is None:
            continue
        ax = 2 if isinstance(s, str) else 4
        assert dim % ax == 0           # sharded dims always divide
        if s == "model" or (isinstance(s, tuple) and "model" in s):
            model_used += 1
    assert model_used <= 1             # model axis claimed at most once


# ------------------------------------------------------------- hlo cost

def test_flops_counting_simple_matmul():
    a = jnp.zeros((128, 256), jnp.float32)
    b = jnp.zeros((256, 512), jnp.float32)
    compiled = jax.jit(lambda x, y: x @ y).lower(a, b).compile()
    rep = hlo_cost.analyze(compiled.as_text())
    want = 2 * 128 * 256 * 512
    assert abs(rep.flops - want) / want < 0.01


def test_flops_scan_multiplied_by_trip_count():
    w = jnp.zeros((4, 64, 64), jnp.float32)

    def f(x, w):
        def body(c, wi):
            return c @ wi, None
        out, _ = jax.lax.scan(body, x, w)
        return out

    x = jnp.zeros((64, 64), jnp.float32)
    compiled = jax.jit(f).lower(x, w).compile()
    rep = hlo_cost.analyze(compiled.as_text())
    want = 4 * 2 * 64 * 64 * 64
    assert abs(rep.flops - want) / want < 0.01
    assert rep.n_while == 1
    # XLA's own analysis undercounts the loop (this is WHY hlo_cost exists)
    xla = compiled.cost_analysis()
    if isinstance(xla, list):                 # older jax returns a list
        xla = xla[0] if xla else None
    if xla and xla.get("flops"):
        assert xla["flops"] <= rep.flops


def test_collective_bytes_counted():
    try:
        mesh = jax.make_mesh((1,), ("x",))
    except Exception:
        pytest.skip("no mesh")
    # single-device: no collectives expected
    f = jax.jit(lambda x: x * 2)
    rep = hlo_cost.analyze(f.lower(jnp.zeros((8, 8))).compile().as_text())
    assert rep.collective_bytes == 0


def test_shape_bytes_parser():
    assert hlo_cost.shape_bytes("f32[128,256]") == 128 * 256 * 4
    assert hlo_cost.shape_bytes("bf16[2,2]") == 8
    assert hlo_cost.shape_bytes("(f32[4], s32[2])") == 24
    assert hlo_cost.shape_bytes("token[]") == 0
