"""Sharded AdamW with global-norm clipping and optional INT8 error-feedback
gradient compression for the cross-pod (DCN) all-reduce.

Optimizer state inherits the parameter sharding (m, v live alongside the
param shard — ZeRO-1 style when params are TP-sharded, replicated otherwise;
the `dp_shard_states` flag additionally shards replicated m/v over the data
axis, ZeRO-style, with an all-gather at update time).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    # distributed-optimization tricks
    compress_grads: bool = False     # int8 error-feedback compression (DCN)


def init_state(params):
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def init_state_structs(param_structs):
    z = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32)
    return {
        "step": jax.ShapeDtypeStruct((), jnp.int32),
        "m": jax.tree.map(z, param_structs),
        "v": jax.tree.map(z, param_structs),
    }


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    return cfg.lr * warm


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def compress_int8(g):
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(g)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q, scale):
    return q.astype(jnp.float32) * scale


def apply_updates(cfg: AdamWConfig, params, grads, state):
    """One AdamW step. grads in fp32 (already averaged over DP)."""
    step = state["step"] + 1
    lr = _schedule(cfg, state["step"])
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, {"step": step, "m": new_m, "v": new_v}, gnorm
