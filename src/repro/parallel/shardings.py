"""Logical→physical sharding resolution for the production mesh.

Logical axis names used by model code:
  "batch"  -> data-parallel axes ("pod","data") when present
  "model"  -> tensor/expert-parallel axis ("model",)
  None     -> replicated

Resolution is divisibility-aware: a dim is only sharded if the mesh axis
product divides it (GSPMD can pad, but we keep in/out shardings exact).
"""
from __future__ import annotations

import math
from typing import Any, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return math.prod(mesh.shape[a] for a in axes)


def resolve_spec(mesh: Mesh, logical: Sequence, shape: Sequence[int]) -> P:
    """Map a logical spec (tuple of "batch"/"model"/"model2"/None per dim) to
    a PartitionSpec, dropping entries whose mesh size does not divide the dim.

    "model2" is a *fallback* model-axis slot: it shards over "model" only if
    no earlier dim claimed the model axis (used e.g. to shard KV-cache
    head_dim when n_kv_heads is not divisible by the model axis)."""
    out = []
    model_used = False
    batch_used = False
    deferred_batch2 = []
    for i, (dim, name) in enumerate(zip(shape, logical)):
        if name is None:
            out.append(None)
            continue
        if name in ("model", "model2"):
            # the model axis can be claimed by at most one dim
            if model_used:
                out.append(None)
                continue
            name = "model"
        if name == "batch2":
            # fallback slot: takes the dp axes only if no "batch" dim
            # could (e.g. decode KV caches with batch=1: the SEQUENCE dim
            # shards over "data" instead)
            deferred_batch2.append((i, dim))
            out.append(None)
            continue
        axes = dp_axes(mesh) if name == "batch" else ("model",)
        axes = tuple(a for a in axes if a in mesh.axis_names)
        if axes and dim % _axis_size(mesh, axes) == 0:
            if name == "model":
                model_used = True
            if name == "batch":
                batch_used = True
            out.append(axes if len(axes) > 1 else axes[0])
        else:
            out.append(None)
    if deferred_batch2 and not batch_used:
        axes = dp_axes(mesh)
        for i, dim in deferred_batch2:
            if axes and dim % _axis_size(mesh, axes) == 0:
                out[i] = axes if len(axes) > 1 else axes[0]
                break
    return P(*out)


def named(mesh: Mesh, logical: Sequence, shape: Sequence[int]) -> NamedSharding:
    return NamedSharding(mesh, resolve_spec(mesh, logical, shape))


def constrain(x: jax.Array, logical: Sequence) -> jax.Array:
    """with_sharding_constraint against the ambient mesh, divisibility-aware.

    Safe to call outside jit/mesh context (returns x unchanged)."""
    mesh = _ambient_mesh()
    if mesh is None or mesh.empty:
        return x
    spec = resolve_spec(mesh, logical, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _ambient_mesh() -> Mesh | None:
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is not None and not m.empty:
            # need the concrete mesh for NamedSharding; use thread-local
            pass
    except Exception:
        pass
    return _MESH[0]


# The dry-run / trainer set this before tracing so model-internal constraints
# can resolve against the right physical mesh.
_MESH: list[Mesh | None] = [None]


def set_ambient_mesh(mesh: Mesh | None) -> None:
    _MESH[0] = mesh


def get_ambient_mesh() -> Mesh | None:
    return _MESH[0]
