"""Distributed striped checkpointing over the Lustre substrate.

This is the paper's architecture doing the job it does in real ML clusters:
checkpoints live on Lustre. Design:

  * one file per pytree leaf, striped over OSTs (LOV, ch. 10); writers are
    N LustreClients (one per simulated host / dp group) writing in
    parallel — group locks (ch. 10.10) let cooperating writers share
    objects without PW ping-pong;
  * crash consistency: data files first, MANIFEST.json last (the commit
    record). restore() only trusts steps with a manifest; incomplete step
    directories are garbage (client died mid-save) and are removed by
    `cleanup_incomplete` — the client-side mirror of the MDS orphan logic;
  * erasure coding (ch. 15 adapted): optional XOR parity file per tensor,
    computed by the Pallas parity kernel; `restore` can reconstruct a
    stripe lost to a dead OST's disk;
  * elastic restore: the manifest stores shapes/dtypes; restore returns
    numpy arrays that the trainer re-shards onto whatever mesh it now has.
"""
from __future__ import annotations

import json
from typing import Any

import numpy as np

from repro.fsio.client import FsError, LustreClient
from repro.kernels import ops as kops


def _leaf_paths(tree, prefix=()):
    """Stable (path, leaf) list without jax dependency on the hot path."""
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _leaf_paths(tree[k], prefix + (str(k),))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _leaf_paths(v, prefix + (str(i),))
    else:
        yield ".".join(prefix), tree


def _unflatten(skeleton, values: dict):
    if isinstance(skeleton, dict):
        return {k: _unflatten(v, values[k]) for k, v in skeleton.items()}
    return skeleton, values


def _quant_int8(arr: np.ndarray, block: int = 256):
    """Blockwise symmetric int8: q = round(x / s), s = absmax/127 per
    block (the error-feedback-free storage variant of adamw.compress)."""
    flat = arr.astype(np.float32).ravel()
    n = len(flat)
    pad = (-n) % block
    if pad:
        flat = np.concatenate([flat, np.zeros(pad, np.float32)])
    blocks = flat.reshape(-1, block)
    scales = (np.abs(blocks).max(axis=1) / 127.0 + 1e-12).astype(np.float32)
    q = np.clip(np.round(blocks / scales[:, None]), -127, 127).astype(
        np.int8)
    return q.ravel()[:n + pad], scales, block


def _dequant_int8(data: bytes, entry: dict) -> np.ndarray:
    qm = entry["quant"]
    ns, blk = qm["n_scales"], qm["block"]
    scales = np.frombuffer(data[:ns * 4], np.float32)
    q = np.frombuffer(data[ns * 4:], np.int8).astype(np.float32)
    out = (q.reshape(-1, blk) * scales[:, None]).ravel()
    n = int(np.prod(entry["shape"]))
    return out[:n].astype(qm["orig_dtype"]).reshape(entry["shape"])


class CheckpointManager:
    def __init__(self, clients: list[LustreClient], base: str = "/ckpt",
                 *, stripe_count: int = 0, stripe_size: int = 1 << 20,
                 parity: bool = False, use_wbc: bool = True,
                 quantize: str | None = None):
        """`clients` = parallel writer hosts (>=1). parity=True adds an
        erasure stripe per tensor file. quantize="int8" stores float
        tensors as blockwise int8 + f32 scales (4x less wire/disk; lossy —
        meant for high-frequency intermediate checkpoints)."""
        self.clients = clients
        self.fs = clients[0]
        self.sim = self.fs.sim
        self.base = base.rstrip("/")
        self.stripe_count = stripe_count
        self.stripe_size = stripe_size
        self.parity = parity
        self.use_wbc = use_wbc
        self.quantize = quantize
        self.fs.mkdir_p(self.base)

    # -------------------------------------------------------------- save
    def _step_dir(self, step: int) -> str:
        return f"{self.base}/step_{step:08d}"

    def save(self, step: int, tree: Any, *, extra_meta: dict | None = None
             ) -> dict:
        """Write one checkpoint. Returns the manifest."""
        leaves = [(p, np.asarray(v)) for p, v in _leaf_paths(tree)]
        d = self._step_dir(step)
        # overwrite semantics: a re-save of the same step (two trainers
        # resumed from one checkpoint) replaces the old content
        if self.fs.exists(d):
            for f in sorted(self.fs.readdir(d)):
                try:
                    self.fs.unlink(f"{d}/{f}")
                except FsError:
                    pass
        # metadata burst: create the step dir + files under a WBC subtree
        # lock when the MDS grants one (ch. 17)
        self.fs.mkdir_p(d)
        if self.use_wbc:
            self.fs.enable_wbc(d)
        manifest = {"step": step, "leaves": {}, **(extra_meta or {})}

        def write_leaf(w_idx: int, name: str, arr: np.ndarray):
            fs = self.clients[w_idx % len(self.clients)]
            qmeta = None
            if self.quantize == "int8" and arr.dtype.kind == "f" \
                    and arr.size >= 256:
                q, scales, blk = _quant_int8(arr)
                data = scales.tobytes() + q.tobytes()
                qmeta = {"block": blk, "n_scales": len(scales),
                         "orig_dtype": str(arr.dtype)}
            else:
                data = arr.tobytes()
            fh = fs.creat(f"{d}/{name}.bin",
                          stripe_count=self.stripe_count,
                          stripe_size=self.stripe_size)
            fs.write(fh, data, gid=1 + w_idx)       # group locks (ch.10.10)
            fs.close(fh)
            entry = {"shape": list(arr.shape), "dtype": str(arr.dtype),
                     "bytes": len(data), "writer": w_idx % len(self.clients)}
            if qmeta:
                entry["quant"] = qmeta
            if self.parity and len(data) > 0:
                p = self._parity_for(fh, data)
                pfh = fs.creat(f"{d}/{name}.parity",
                               stripe_count=1,
                               stripe_offset=self._parity_ost(fh))
                fs.write(pfh, p, gid=1 + w_idx)
                fs.close(pfh)
                entry["parity"] = True
            return name, entry

        if self.use_wbc:
            self.fs.disable_wbc()      # flush the metadata batch first
        outs = self.sim.parallel([
            (lambda i=i, n=n, a=a: write_leaf(i, n, a))
            for i, (n, a) in enumerate(leaves)])
        for name, entry in outs:
            manifest["leaves"][name] = entry
        for fs in self.clients:
            fs.sync()
        # commit record LAST: a manifest present == checkpoint complete
        mdata = json.dumps(manifest).encode()
        fh = self.fs.creat(f"{d}/MANIFEST.json", stripe_count=1)
        self.fs.write(fh, mdata)
        self.fs.close(fh)
        self.fs.sync()
        for t in self.fs.cluster.ost_targets:       # durable commit point
            t.commit()
        self.sim.stats.count("ckpt.saved")
        return manifest

    def _parity_for(self, fh, data: bytes) -> bytes:
        """XOR parity across the file's stripe columns (Pallas kernel)."""
        lsm = fh.lsm
        ssz, cnt = lsm.stripe_size, lsm.stripe_count
        if cnt < 2:
            return kops.parity_bytes([data])
        cols = [data[i * ssz:(i + 1) * ssz]
                for i in range(-(-len(data) // ssz))]
        rows = [b"".join(cols[i::cnt]) for i in range(cnt)]
        rows = [r for r in rows if r]
        return kops.parity_bytes(rows)

    @staticmethod
    def _parity_ost(fh) -> int:
        """Place parity on an OST not holding any data stripe if possible."""
        lsm = fh.lsm
        return (lsm.stripe_offset + lsm.stripe_count) % max(
            1, len(fh.lsm.objects) + 1)

    # ------------------------------------------------------------ restore
    def steps(self) -> list[int]:
        try:
            names = self.fs.readdir(self.base)
        except FsError:
            return []
        out = []
        for n in names:
            if n.startswith("step_"):
                s = int(n.split("_")[1])
                if self.fs.exists(f"{self.base}/{n}/MANIFEST.json"):
                    out.append(s)
        return sorted(out)

    def latest(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, step: int | None = None) -> tuple[dict, dict]:
        """Returns ({leaf_name: np.ndarray}, manifest). Reads leaves in
        parallel across reader clients; reconstructs stripes lost to dead
        OSTs from parity when enabled."""
        if step is None:
            step = self.latest()
        if step is None:
            raise FsError(-2, "no complete checkpoint")
        d = self._step_dir(step)
        fh = self.fs.open(f"{d}/MANIFEST.json")
        manifest = json.loads(self.fs.read(fh, 1 << 24))
        self.fs.close(fh)
        names = sorted(manifest["leaves"])

        def read_leaf(i: int, name: str):
            fs = self.clients[i % len(self.clients)]
            e = manifest["leaves"][name]
            try:
                fh = fs.open(f"{d}/{name}.bin")
                data = fs.read(fh, e["bytes"])
                fs.close(fh)
                if len(data) != e["bytes"]:
                    raise FsError(-5, "short read")
            except (FsError, Exception) as ex:
                if not e.get("parity"):
                    raise
                data = self._reconstruct(fs, d, name, e)
            if e.get("quant"):
                return name, _dequant_int8(data, e)
            return name, np.frombuffer(data, e["dtype"]).reshape(e["shape"])

        outs = self.sim.parallel([
            (lambda i=i, n=n: read_leaf(i, n))
            for i, n in enumerate(names)])
        self.sim.stats.count("ckpt.restored")
        return dict(outs), manifest

    def _reconstruct(self, fs: LustreClient, d: str, name: str,
                     e: dict) -> bytes:
        """One stripe object is gone (dead OST disk): rebuild it from the
        surviving stripes + parity (ch. 15 / Pallas reconstruct)."""
        from repro.core import lov as lov_mod
        meta = fs.lmv.getattr(fs.resolve(f"{d}/{name}.bin"), want_ea=True)
        lsm = lov_mod.StripeMd.from_ea(meta["ea"]["lov"])
        ssz, cnt = lsm.stripe_size, lsm.stripe_count
        total = e["bytes"]
        rows: list[bytes | None] = []
        missing = None
        for i, o in enumerate(lsm.objects):
            try:
                osc = fs.lov.by_uuid[o["ost"]]
                sz = lov_mod.Lov._obj_size_for(lsm, i, total)
                rows.append(osc.read(o["group"], o["oid"], 0, sz))
            except Exception:
                if missing is not None:
                    raise FsError(-5, "more than one stripe lost")
                missing = i
                rows.append(None)
        pfh = fs.open(f"{d}/{name}.parity")
        par = fs.read(pfh, 1 << 30)
        fs.close(pfh)
        if missing is None:
            # file itself was readable after all
            rows_b = rows
        else:
            surv = [r for r in rows if r is not None]
            want = lov_mod.Lov._obj_size_for(lsm, missing, total)
            rec = kops.reconstruct_bytes(
                [r.ljust(len(par), b"\0") for r in surv],
                par, len(par))[:want]
            rows[missing] = rec
            rows_b = rows
            self.sim.stats.count("ckpt.stripe_reconstructed")
        # interleave stripe rows back into the logical byte stream
        out = bytearray(total)
        for i, row in enumerate(rows_b):
            for j in range(0, len(row), ssz):
                snum = (j // ssz) * cnt + i
                lpos = snum * ssz
                chunk = row[j:j + ssz]
                out[lpos:lpos + len(chunk)] = chunk[:max(0, total - lpos)]
        return bytes(out)

    # ----------------------------------------------------------- cleanup
    def cleanup_incomplete(self) -> list[str]:
        """Remove step dirs without a manifest (writer died mid-save)."""
        removed = []
        try:
            names = self.fs.readdir(self.base)
        except FsError:
            return removed
        for n in sorted(names):
            if not n.startswith("step_"):
                continue
            d = f"{self.base}/{n}"
            if self.fs.exists(f"{d}/MANIFEST.json"):
                continue
            for f in sorted(self.fs.readdir(d)):
                try:
                    self.fs.unlink(f"{d}/{f}")
                except FsError:
                    pass
            self.fs.rmdir(d)
            removed.append(n)
            self.sim.stats.count("ckpt.incomplete_removed")
        return removed

    def retain(self, keep: int = 3):
        """Delete old complete checkpoints beyond `keep`."""
        for s in self.steps()[:-keep]:
            d = self._step_dir(s)
            for f in sorted(self.fs.readdir(d)):
                self.fs.unlink(f"{d}/{f}")
            self.fs.rmdir(d)
