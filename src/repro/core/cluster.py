"""Cluster assembly + configuration management (paper ch. 13, 14, 31).

The paper drives configuration from XML/LDAP profiles through `lconf`;
here a plain dict plays the XML role and `LustreCluster` plays lconf:
it instantiates nodes, OST/MDS targets (with failover standbys), routes,
and wires MDS<->OST / MDS<->MDS imports. An `lctl()` method exposes the
admin verbs used in the paper (set_gw up/down, fail/restart node, ...).

Example config:
    {"net": "elan",
     "osts": 4, "ost_capacity": 1 << 30, "ost_failover": True,
     "mdses": 2,
     "clients": 2,
     "gateways": [("tcp", "gw0"), ...]}   # cross-net routing
"""
from __future__ import annotations

from repro.core import fail as fail_mod
from repro.core import mdc as mdc_mod
from repro.core import mds as mds_mod
from repro.core import osc as osc_mod
from repro.core import ost as ost_mod
from repro.core import lov as lov_mod
from repro.core import ptlrpc as R
from repro.core import recovery as rec_mod


class LustreCluster(R.ClusterBase):
    def __init__(self, *, osts: int = 2, mdses: int = 1, clients: int = 1,
                 net: str = "elan", ost_capacity: int = 1 << 40,
                 ost_failover: bool = False, seed: int = 0,
                 commit_interval: int = 64, mds_split_threshold: int = 0,
                 nrs_policy: str = "fifo", nrs_params: dict | None = None,
                 max_pages_per_rpc: int = osc_mod.DEFAULT_MAX_PAGES_PER_RPC,
                 max_rpcs_in_flight: int = osc_mod.DEFAULT_MAX_RPCS_IN_FLIGHT,
                 vectored_brw: bool = True,
                 max_cached_mb: int = osc_mod.DEFAULT_MAX_CACHED_MB,
                 readahead_pages: int = osc_mod.DEFAULT_READAHEAD_PAGES,
                 dir_pages: int = 64, statahead_max: int = 32,
                 wbc_auto: bool = False, wbc_batch: int = 64,
                 wbc_max_dirty: int = 1024,
                 spare_osts: int = 0, rebuild_rate: float = 0.0,
                 rebuild_burst: float = 4.0,
                 adaptive_timeouts: bool = True,
                 at_min: float = R.AT_MIN, at_max: float = R.AT_MAX,
                 ping_evict_age: float = 0.0,
                 recovery_per_client: float = 0.1,
                 recovery_window_max: float = 30.0):
        super().__init__(seed)
        self.net = net
        # recovery / health-plane knobs (ISSUE-10): adaptive_timeouts +
        # at_min/at_max are read by every Import built against this
        # cluster (per-opcode decayed-max service estimates instead of
        # the fixed DEFAULT_TIMEOUT); ping_evict_age > 0 arms the
        # server-side stale-export back-stop; recovery_per_client scales
        # each target's recovery window with its export count, capped at
        # recovery_window_max
        self.adaptive_timeouts = adaptive_timeouts
        self.at_min = at_min
        self.at_max = at_max
        self.ping_evict_age = ping_evict_age
        self.recovery_per_client = recovery_per_client
        self.recovery_window_max = recovery_window_max
        # client-side BRW pipeline + read cache knobs, handed to every
        # OSC built via make_oscs/make_lov (overridable per call);
        # readahead_pages is consumed by LustreClient's sequential-read
        # detector (0 disables readahead)
        self.max_pages_per_rpc = max_pages_per_rpc
        self.max_rpcs_in_flight = max_rpcs_in_flight
        self.vectored_brw = vectored_brw
        self.max_cached_mb = max_cached_mb
        self.readahead_pages = readahead_pages
        # metadata read-path knobs (ISSUE-5), consumed by LustreClient:
        # dir_pages = entries per readdir-plus page (0 = seed per-entry
        # scan path); statahead_max = attr-prefetch window for sequential
        # stat patterns (0 disables statahead)
        self.dir_pages = dir_pages
        self.statahead_max = statahead_max
        # metadata write-back cache knobs (ISSUE-6), consumed by
        # LustreClient: wbc_auto = enter WBC on the first metadata write
        # under a directory (the MDS §6.5.2 contention decision still
        # arbitrates); wbc_batch = records per reint_batch RPC (0 = one
        # RPC per flush); wbc_max_dirty = dirty-record cap forcing a
        # full flush (cache pressure)
        self.wbc_auto = wbc_auto
        self.wbc_batch = wbc_batch
        self.wbc_max_dirty = wbc_max_dirty
        # raid5 rebuild knobs (ISSUE-8): spare_osts = extra OST targets
        # excluded from stripe allocation, available as rebuild targets
        # (lctl("rebuild", dead, spare)); rebuild_rate > 0 installs the
        # two-level tbf_orr NRS policy on every OST with a
        # {"rebuild": rate} rule, throttling rebuild BRWs req/s while
        # leaving client classes unlimited (and disk-ordered)
        self.spare_osts = spare_osts
        self.rebuild_rate = rebuild_rate
        self.rebuild_burst = rebuild_burst
        self.ost_targets: list[ost_mod.OstTarget] = []
        self.spare_targets: list[ost_mod.OstTarget] = []
        self.mds_targets: list[mds_mod.MdsTarget] = []
        self.client_nodes: list[R.Node] = []

        # --- OST nodes (optionally paired for failover: shared storage,
        # standby node imports the same target on failure — ch. 13.8)
        for i in range(osts + spare_osts):
            node = R.Node(f"ost{i}", net, self)
            t = ost_mod.OstTarget(f"OST{i:04d}", node, ost_capacity)
            t.commit_interval = commit_interval
            if rebuild_rate > 0:
                t.service.set_policy("tbf_orr",
                                     rules={"rebuild": rebuild_rate},
                                     burst=rebuild_burst)
            elif nrs_policy != "fifo" or nrs_params:
                t.service.set_policy(nrs_policy, **(nrs_params or {}))
            (self.ost_targets if i < osts
             else self.spare_targets).append(t)
        self.spare_uuids = [t.uuid for t in self.spare_targets]
        self.ost_nids = {}
        for i, t in enumerate(self.ost_targets):
            ring = [t.node.nid]
            if ost_failover:
                # nearest left neighbour hosts the standby (§6.7.6.4)
                ring.append(self.ost_targets[(i + 1) % osts].node.nid)
            self.ost_nids[t.uuid] = ring
        for t in self.spare_targets:
            self.ost_nids[t.uuid] = [t.node.nid]

        # --- MDS cluster
        for i in range(mdses):
            node = R.Node(f"mds{i}", net, self)
            t = mds_mod.MdsTarget(f"MDS{i:04d}", node, inode_group=i)
            t.commit_interval = commit_interval
            if mds_split_threshold:
                t.SPLIT_THRESHOLD = mds_split_threshold
            self.mds_targets.append(t)
        self.mds_nids = {t.uuid: [t.node.nid] for t in self.mds_targets}
        for t in self.mds_targets:
            for u in self.mds_targets:
                if u is not t:
                    t.connect_peer(u.uuid, [u.node.nid])
            for o in self.ost_targets + self.spare_targets:
                t.connect_ost(o.uuid, self.ost_nids[o.uuid])

        # --- failover standby wiring: a restarted OST target can be
        # reached at the standby nid because the standby node also serves
        # the target object (shared-storage assumption).
        if ost_failover:
            for i, t in enumerate(self.ost_targets):
                standby = self.ost_targets[(i + 1) % osts].node
                standby.targets[t.uuid] = t

        # --- client nodes
        for i in range(clients):
            self.client_nodes.append(R.Node(f"client{i}", net, self))

        for t in (self.ost_targets + self.spare_targets
                  + self.mds_targets):
            t.at_enabled = adaptive_timeouts
            t.ping_evict_age = ping_evict_age
            t.recovery_per_client = recovery_per_client
            t.recovery_window_max = recovery_window_max

    # ------------------------------------------------------------ builders
    def make_client_rpc(self, idx: int = 0) -> R.RpcClient:
        return R.RpcClient(self.client_nodes[idx])

    def make_oscs(self, rpc: R.RpcClient, writeback=True, *,
                  spares: bool = False, **osc_kw):
        osc_kw.setdefault("max_pages_per_rpc", self.max_pages_per_rpc)
        osc_kw.setdefault("max_rpcs_in_flight", self.max_rpcs_in_flight)
        osc_kw.setdefault("vectored_brw", self.vectored_brw)
        osc_kw.setdefault("max_cached_mb", self.max_cached_mb)
        return [osc_mod.Osc(rpc, t.uuid, self.ost_nids[t.uuid],
                            writeback=writeback, **osc_kw)
                for t in (self.spare_targets if spares
                          else self.ost_targets)]

    def make_lov(self, rpc: R.RpcClient, policy: str = "round_robin",
                 group: int = 0, writeback=True, **osc_kw) -> lov_mod.Lov:
        return lov_mod.Lov(self.make_oscs(rpc, writeback, **osc_kw),
                           group=group, policy=policy,
                           spares=self.make_oscs(rpc, writeback,
                                                 spares=True, **osc_kw))

    def target(self, uuid: str):
        for t in self.ost_targets + self.spare_targets + self.mds_targets:
            if t.uuid == uuid:
                return t
        raise KeyError(uuid)

    def make_lmv(self, rpc: R.RpcClient) -> mdc_mod.Lmv:
        return mdc_mod.Lmv([
            mdc_mod.Mdc(rpc, t.uuid, self.mds_nids[t.uuid])
            for t in self.mds_targets])

    def mds_recovery(self, rpc: R.RpcClient) -> rec_mod.MdsClusterRecovery:
        return rec_mod.MdsClusterRecovery(rpc, self.mds_nids)

    def monitor(self, **kw):
        """The cluster's MELT-style collector (repro.tools.monitor),
        created on first use; `lctl("mon_snapshot")` is the admin verb."""
        if getattr(self, "_monitor", None) is None:
            from repro.tools.monitor import ClusterMonitor
            self._monitor = ClusterMonitor(self, **kw)
        return self._monitor

    # ---------------------------------------------------------------- ops
    def fail_node(self, name: str):
        self.nodes[name].fail()

    def restart_node(self, name: str):
        self.nodes[name].restart()

    def lctl(self, verb: str, *args):
        if verb == "set_gw":
            nid, state = args
            self.network.set_gw(nid, state == "up")
        elif verb == "fail":
            self.fail_node(args[0])
        elif verb == "restart":
            self.restart_node(args[0])
        elif verb == "drop_next":
            self.sim.faults.drop_next[args[0]] += int(args[1])
        elif verb == "nrs":
            # lctl("nrs", target_uuid, policy_name[, params_dict])
            uuid, policy = args[0], args[1]
            params = args[2] if len(args) > 2 else {}
            self.target(uuid).service.set_policy(policy, **params)
        elif verb == "changelog_register":
            # lctl("changelog_register", mds_uuid) -> consumer id
            t = self.target(args[0])
            uid = t.changelog.register()
            t.commit()          # the id handed out survives restart
            return uid
        elif verb == "changelog_deregister":
            # lctl("changelog_deregister", mds_uuid, consumer_id)
            t = self.target(args[0])
            t.changelog.deregister(args[1])
            t.commit()      # durable: a crash must not resurrect the pin
        elif verb == "changelog_info":
            # lctl("changelog_info", mds_uuid) -> consumer/record state
            return self.target(args[0]).changelog.info()
        elif verb == "changelog_gc":
            # lctl("changelog_gc", mds_uuid[, {"max_idle_indexes": n,
            #                                  "max_idle_time": s}])
            # sets the idle-consumer GC knobs (None disables one) and
            # runs a collection pass; returns the ids collected now
            t = self.target(args[0])
            cl = t.changelog
            if len(args) > 1:
                knobs = args[1]
                if "max_idle_indexes" in knobs:
                    cl.gc_max_idle_indexes = knobs["max_idle_indexes"]
                if "max_idle_time" in knobs:
                    cl.gc_max_idle_time = knobs["max_idle_time"]
            collected = cl.gc()
            if collected:
                t.commit()  # durable: a crash must not resurrect the pins
            return collected
        elif verb == "set_param":
            # lctl("set_param", "fail_loc", site[, nth[, action]]) arms an
            # OBD_FAIL failpoint (one-shot, fires on the nth hit); ""
            # disarms. action: crash (default) | drop | delay.
            # lctl("set_param", "fail_val", n) adjusts the hit count;
            # "fail_action"/"fail_delay" adjust the action knobs.
            if args[0] == "fail_loc":
                self.sim.fail.arm(args[1],
                                  args[2] if len(args) > 2 else None,
                                  args[3] if len(args) > 3 else None)
            elif args[0] == "fail_val":
                self.sim.fail.val = max(1, int(args[1]))
            elif args[0] == "fail_action":
                if args[1] not in fail_mod.ACTIONS:
                    raise ValueError(args[1])
                self.sim.fail.action = args[1]
            elif args[0] == "fail_delay":
                self.sim.fail.delay_s = float(args[1])
            elif args[0] in ("adaptive_timeouts", "at_min", "at_max",
                             "ping_evict_age", "recovery_per_client",
                             "recovery_window_max"):
                # health-plane knobs: cluster attr feeds new Imports;
                # server-side ones are pushed to live targets too
                val = (bool(args[1]) if args[0] == "adaptive_timeouts"
                       else float(args[1]))
                setattr(self, args[0], val)
                if args[0] != "at_min" and args[0] != "at_max":
                    attr = ("at_enabled"
                            if args[0] == "adaptive_timeouts" else args[0])
                    for t in (self.ost_targets + self.spare_targets
                              + self.mds_targets):
                        setattr(t, attr, val)
            else:
                raise ValueError(args[0])
        elif verb == "rebuild":
            # lctl("rebuild", dead_ost_uuid, spare_ost_uuid[, jobid])
            # walks the namespace with a maintenance client and rebuilds
            # every raid5 file referencing the dead OST onto the spare
            # (ISSUE-8); returns the rebuild report dict
            dead, spare = args[0], args[1]
            jobid = args[2] if len(args) > 2 else "rebuild"
            # local import: fsio sits above core in the layer stack, so a
            # module-level import here would be circular
            from repro.fsio.client import LustreClient
            maint = LustreClient(self, node_idx=0)
            return maint.rebuild_ost(dead, spare, jobid=jobid)
        elif verb == "rebuild_throttle":
            # lctl("rebuild_throttle", rate[, burst]) installs the
            # two-level tbf_orr policy on every OST service, limiting the
            # "rebuild" jobid class to `rate` RPCs/s while other traffic
            # rides the orr_disk ordering unthrottled
            rate = float(args[0])
            burst = float(args[1]) if len(args) > 1 else self.rebuild_burst
            for t in self.ost_targets + self.spare_targets:
                t.service.set_policy("tbf_orr", rules={"rebuild": rate},
                                     burst=burst)
        elif verb == "recovery_close":
            # lctl("recovery_close", target_uuid) — admin closes the
            # recovery window early instead of waiting out the deadline
            # (VBR makes that safe: stragglers replay late, §ISSUE-10).
            # mirror the RPC boundary's OBD_FAIL semantics: an armed
            # mds.recovery_window crash powers the target off here too
            t = self.target(args[0])
            try:
                t.close_recovery()
            except fail_mod.FailLocDrop:
                self.sim.stats.count("fail.drop")
            except fail_mod.FailLocHit:
                self.sim.stats.count("fail.crash")
                t.crash()
                t.restart()
        elif verb == "evict_client":
            # lctl("evict_client", target_uuid, client_uuid)
            self.target(args[0]).evict_client(args[1], reason="admin")
        elif verb == "mon_snapshot":
            # lctl("mon_snapshot") -> one cluster-wide aggregation round
            # over real RPCs (partial + 'stale' list when targets are
            # down); the snapshot tree is also the "monitor" procfs leaf
            return self.monitor().collect()
        elif verb == "get_param":
            # lctl("get_param", "wbc") -> one procfs section; dotted
            # paths walk into it ("wbc.flushes", "client_cache.hit_rate")
            node = self.procfs()
            for part in args[0].split("."):
                node = node[part]
            return node
        else:
            raise ValueError(verb)

    def _sanitizer_rollup(self) -> dict:
        san = self.sim.sanitize
        if san.enabled:
            # reading procfs is a natural audit point: run the final
            # counter-partition check before reporting
            san.check_counter_partition(self.sim.stats)
        return san.info()

    def procfs(self) -> dict:
        """lprocfs-style introspection tree (paper ch. 35): per-target
        state + cluster counters, as /proc/fs/lustre would expose."""
        cnt = self.sim.stats.counters
        hits, misses = cnt.get("osc.cache_hit", 0), cnt.get("osc.cache_miss", 0)
        out = {"counters": dict(cnt),
               "bytes": dict(self.sim.stats.bytes),
               "fail": self.sim.fail.info(),
               # runtime sanitizer rollup (checks run / violations /
               # captured-by-tests); a final counter-partition audit
               # runs here so the leaf is never stale
               "sanitizer": self._sanitizer_rollup(),
               # client read-cache rollup (ISSUE-4): the per-event
               # counters (osc.cache_*) live in "counters" too
               "client_cache": {
                   "hits": hits, "misses": misses,
                   "hit_rate": round(hits / (hits + misses), 4)
                   if hits + misses else 0.0,
                   "invalidations": cnt.get("osc.cache_invalidate", 0),
                   "lru_evictions": cnt.get("osc.cache_lru_evict", 0),
                   "readaheads": cnt.get("lov.readahead", 0),
               },
               # metadata read-path rollup (ISSUE-5): attr cache +
               # statahead + readdir-plus + batched glimpse
               "md_cache": {
                   "attr_hits": cnt.get("fs.attr_hit", 0),
                   "attr_misses": cnt.get("fs.attr_miss", 0),
                   "statahead": cnt.get("fs.statahead", 0),
                   "statahead_hits": cnt.get("fs.statahead_hit", 0),
                   "statahead_dropped": cnt.get("fs.statahead_dropped", 0),
                   "readdir_plus_pages": cnt.get("mds.intent.readdir", 0),
                   "glimpse_bulk_rpcs": cnt.get("rpc.ost.glimpse_bulk", 0),
                   "neg_hits": cnt.get("fs.neg_hit", 0),
               },
               # metadata write-back cache rollup (ISSUE-6): grant
               # decisions, local (RPC-free) updates, the flush pipeline
               # and its batch-size distribution, and how often an
               # unrepresentable op forced a flush-and-go-synchronous
               "wbc": {
                   "grants": cnt.get("wbc.granted", 0),
                   "denials": cnt.get("wbc.denied", 0),
                   "local_updates": cnt.get("wbc.local_update", 0),
                   "flushes": cnt.get("wbc.flush", 0),
                   "flushed_records": cnt.get("wbc.flushed_records", 0),
                   "batch_hist": {
                       k.rsplit(".", 1)[1]: v for k, v in sorted(
                           cnt.items(),
                           key=lambda kv: (len(kv[0]), kv[0]))
                       if k.startswith("wbc.batch_hist.")},
                   "fallback_sync": cnt.get("wbc.fallback_sync", 0),
                   "lost_records": cnt.get("wbc.lost_records", 0),
                   "reint_errors": cnt.get("wbc.reint_errors", 0),
               },
               # raid5/SNS rollup (ISSUE-8): degraded service, parity
               # reconstruction volume, and rebuild progress
               "raid": {
                   "degraded_reads": cnt.get("lov.degraded_read", 0),
                   "degraded_read_bytes": cnt.get("lov.degraded_read_bytes", 0),
                   "degraded_writes": cnt.get("lov.degraded_write", 0),
                   "reconstructed_units": cnt.get("lov.reconstruct_unit", 0),
                   "reconstructed_bytes": cnt.get("lov.reconstruct_bytes", 0),
                   "parity_writes": cnt.get("lov.parity_write", 0),
                   "parity_bytes": cnt.get("lov.parity_bytes", 0),
                   "rebuilt_objects": cnt.get("lov.rebuild_object", 0),
                   "rebuilt_bytes": cnt.get("lov.rebuild_bytes", 0),
                   "layout_swaps": cnt.get("lov.layout_swap", 0),
                   "rebuilds_aborted": cnt.get("lov.rebuild_aborted", 0),
                   "ost_deactivations": cnt.get("lov.ost_inactive", 0),
               },
               # recovery / health plane rollup (ISSUE-10): adaptive
               # timeouts, early replies, VBR admission decisions, and
               # the pinger's imperative-recovery + eviction activity
               "recovery": {
                   "early_replies": cnt.get("rpc.early_reply", 0),
                   "early_reply_rescues":
                       cnt.get("rpc.early_reply_rescue", 0),
                   "timeouts": cnt.get("rpc.timeout", 0),
                   "spurious_timeouts": cnt.get("rpc.timeout_spurious", 0),
                   "reconnect_backoffs":
                       cnt.get("rpc.reconnect_backoff", 0),
                   "imperative_recoveries":
                       cnt.get("rpc.imperative_recovery", 0),
                   "vbr_admits": cnt.get("rpc.vbr_admit", 0),
                   "vbr_evictions": cnt.get("rpc.vbr_eviction", 0),
                   "recovery_stragglers":
                       cnt.get("rpc.recovery_stragglers", 0),
                   "ping_evictions": cnt.get("rpc.ping_eviction", 0),
               },
               # monitoring plane (ISSUE-7): span registry roll-up + the
               # collector's last-snapshot summary; per-target per-node
               # counters appear under targets.<uuid>.counters below
               "metrics": self.sim.metrics.info(),
               "monitor": (self._monitor.info()
                           if getattr(self, "_monitor", None) else
                           {"snapshots": 0}),
               "targets": {}}
        for t in self.ost_targets + self.spare_targets:
            out["targets"][t.uuid] = {
                "kind": "obdfilter", "nid": t.node.nid,
                "spare": t in self.spare_targets,
                "boot_count": t.boot_count,
                "last_transno": t.transno,
                "last_committed": t.committed_transno,
                "recovering": t.recovering,
                "num_exports": len(t.exports),
                "kbytesfree": t.obd.statfs()["free"] >> 10,
                "num_objects": len(t.obd.objects),
                "locks": sum(len(r.granted)
                             for r in t.ldlm.resources.values()),
                "nrs": t.service.policy.info(),
                "counters": dict(
                    self.sim.stats.node_counters.get(t.uuid, {})),
                "latency": self.sim.metrics.target_summary(t.uuid),
            }
        for t in self.mds_targets:
            out["targets"][t.uuid] = {
                "kind": "mds", "nid": t.node.nid,
                "boot_count": t.boot_count,
                "last_transno": t.transno,
                "last_committed": t.committed_transno,
                "recovering": t.recovering,
                "num_exports": len(t.exports),
                "num_inodes": len(t.inodes),
                "pending_unlink_llog": len(t.unlink_llog.pending()),
                "locks": sum(len(r.granted)
                             for r in t.ldlm.resources.values()),
                "nrs": t.service.policy.info(),
                "changelog": t.changelog.info(),
                "cluster_cut": t.cluster_cut,
                "counters": dict(
                    self.sim.stats.node_counters.get(t.uuid, {})),
                "latency": self.sim.metrics.target_summary(t.uuid),
            }
        return out

    # ------------------------------------------------------------- stats
    @property
    def stats(self):
        return self.sim.stats

    @property
    def now(self):
        return self.sim.now
