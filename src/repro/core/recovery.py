"""Recovery coordination (paper ch. 11, 29, §6.7.6).

  * Pinger: periodic health checks of critical targets + gateways
    (§4.4.2.5 'the lustre pinger is going to be checking the health of
    critical nodes anyway ... provides the back-stop').
  * Failover rings (§6.7.6.4): each target has an ordered nid list; the
    import walks it on reconnect (implemented in ptlrpc.Import) — here we
    provide the ring construction.
  * Consistent-cut snapshot for multi-MDS failures (§6.7.6.3): the leader
    collects last-committed transnos + dependency vectors and converges on
    a cut that could have been reached by full execution of client
    requests; MDSes roll back (undo records) past the cut.

The cut is also the changelog's cluster durability horizon: each MDS
tracks the highest cut it has been told about (or has derived itself by
running `compute_consistent_cut` over peer `dep_records`, see
`mds._gate_at_cluster_cut`) and `changelog_read` never serves a record
above it — so `rollback_after_failure` can never retract a record a
consumer has already seen. The steady-state `snapshot()` below pushes
the cut to every MDS through `prune_history`, advancing that horizon
without the serving path having to re-derive it.
"""
from __future__ import annotations

from typing import Iterable

from repro.core import ptlrpc as R


class Pinger:
    """Client-side pinger over a set of imports (§4.4.2.5).

    Beyond the health back-stop, the pinger is the client half of the
    active health plane (ISSUE-10): a down→up transition on an import
    marks the OST active again in the LOV (and vice versa), and the
    ping itself notices a target's new boot count — imperative recovery,
    so the client reconnects/replays long before any request timeout.
    """

    def __init__(self, imports: Iterable[R.Import], interval: float = 0.5,
                 lov=None, on_down=None, on_up=None):
        self.imports = list(imports)
        self.interval = interval
        self.lov = lov
        self.on_down = on_down
        self.on_up = on_up
        self.down: set = set()

    def _mark(self, uuid: str, alive: bool) -> None:
        if alive:
            if uuid in self.down:
                self.down.discard(uuid)
                if self.lov is not None and uuid in self.lov.by_uuid:
                    self.lov.set_active(uuid, True)
                if self.on_up:
                    self.on_up(uuid)
        else:
            if uuid not in self.down:
                self.down.add(uuid)
                if self.lov is not None and uuid in self.lov.by_uuid:
                    self.lov.set_active(uuid, False)
                if self.on_down:
                    self.on_down(uuid)

    def tick(self) -> dict:
        """Ping everything once; returns {target_uuid: alive}."""
        out = {}
        for imp in self.imports:
            alive = imp.ping()
            out[imp.target_uuid] = alive
            self._mark(imp.target_uuid, alive)
        return out


def failover_ring(targets: list) -> dict[str, list[str]]:
    """§6.7.6.4: organize servers in a ring; the nearest working left
    neighbour is the failover node. Returns target_uuid -> nid list."""
    nids = {}
    n = len(targets)
    for i, t in enumerate(targets):
        ring = [targets[(i + k) % n].node.nid for k in range(n)]
        nids[t.uuid] = ring
    return nids


# ------------------------------------------------------- consistent cut

def compute_consistent_cut(states: dict[str, dict]) -> dict[str, int]:
    """§6.7.6.3 leader algorithm.

    `states[uuid] = {"committed": int, "deps": [(transno, {peer: pt})]}`.
    Start each cut at the last committed transno; while any included
    transaction depends on an excluded peer transaction, exclude it too.
    The sequence is strictly decreasing, hence converges.
    """
    cut = {u: s["committed"] for u, s in states.items()}
    changed = True
    while changed:
        changed = False
        for u, s in states.items():
            for transno, deps in s["deps"]:
                for peer, pt in deps.items():
                    if peer not in cut:
                        continue
                    # a multi-node transaction is in the snapshot on ALL
                    # nodes or on NONE (a half-rename is not "a state that
                    # could have been reached through full execution of
                    # requests")
                    if transno <= cut[u] and pt > cut[peer]:
                        cut[u] = min(cut[u], transno - 1)
                        changed = True
                    elif pt <= cut[peer] and transno > cut[u]:
                        cut[peer] = min(cut[peer], pt - 1)
                        changed = True
    return cut


class MdsClusterRecovery:
    """Leader-driven snapshot/rollback across the MDS cluster."""

    def __init__(self, rpc: R.RpcClient, mds_nids: dict[str, list[str]]):
        self.rpc = rpc
        self.imports = {u: rpc.import_target(u, nids, "mds")
                        for u, nids in mds_nids.items()}

    def collect(self) -> dict[str, dict]:
        out = {}
        for u, imp in self.imports.items():
            try:
                out[u] = imp.request("dep_records", {}).data
            except (R.TimeoutError_, R.RpcError):
                pass
        return out

    def snapshot(self) -> dict[str, int]:
        """Steady-state: advance the cluster-committed cut and let MDSes
        prune their retained undo history ('records can be canceled when
        the cluster as a whole has committed'). Each MDS also adopts the
        cut as its changelog serving horizon (`MdsTarget.cluster_cut`)."""
        cut = compute_consistent_cut(self.collect())
        for u, transno in cut.items():
            self.imports[u].request("prune_history", {"transno": transno})
        return cut

    def rollback_after_failure(self) -> dict[str, int]:
        """After simultaneous MDS failures: roll every surviving/restarted
        MDS back to a consistent cut; clients then drop replay requests
        older than the cut and replay the rest."""
        states = self.collect()
        cut = compute_consistent_cut(states)
        for u, transno in cut.items():
            self.imports[u].request("rollback_to", {"transno": transno})
        return cut
