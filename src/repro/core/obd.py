"""Object-Based Devices: class driver + direct drivers (paper ch. 5, 25).

The OBD *class driver* keeps a registry of attached devices by name/UUID
(the paper's `obdcontrol attach/setup` flow). Devices expose the object API:

    create destroy getattr setattr read write punch statfs sync

*Direct* drivers manage persistent storage (here: in-memory object store
with transactional undo, standing in for the ext2/filter backends).
*Logical* drivers (LOV striping, SNAP snapshots, COBD caching) stack on
other OBD devices through the same API — the paper's key structural idea.

Object ids: (group, oid) per the NSIC object-group extension the paper
argues for (§5.2.3) — snapshots and recovery both exploit groups. `create`
accepts a *requested* oid (§5.2.3: needed to migrate filesystems by moving
objects); the drive errors if it exists.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Optional

from repro.core import llog as llog_mod


class ObdError(Exception):
    def __init__(self, errno: int, msg: str = ""):
        super().__init__(f"obd error {errno}: {msg}")
        self.errno = errno


# ------------------------------------------------------------ class driver

class ObdClassDriver:
    """Device registry (one per cluster)."""

    def __init__(self):
        self.devices: dict[str, "ObdDevice"] = {}
        self.types: dict[str, type] = {}

    def register_type(self, name: str, cls: type):
        self.types[name] = cls

    def attach(self, type_name: str, name: str, *args, **kw) -> "ObdDevice":
        dev = self.types[type_name](name, *args, **kw)
        self.devices[name] = dev
        return dev

    def get(self, name: str) -> "ObdDevice":
        return self.devices[name]


class ObdDevice:
    """Abstract object device (method table of §25.2)."""

    obd_type = "abstract"

    def __init__(self, name: str):
        self.name = name

    # object API — direct/logical drivers override
    def create(self, group: int, oid: int | None = None, **attrs): ...
    def destroy(self, group: int, oid: int): ...
    def getattr(self, group: int, oid: int) -> dict: ...
    def setattr(self, group: int, oid: int, **attrs): ...
    def read(self, group: int, oid: int, offset: int, length: int) -> bytes: ...
    def write(self, group: int, oid: int, offset: int, data: bytes): ...
    def punch(self, group: int, oid: int, size: int): ...
    def statfs(self) -> dict: ...
    def sync(self): ...
    def list_objects(self, group: int) -> list: ...


# ------------------------------------------------------------------ filter

@dataclasses.dataclass
class StorageObject:
    oid: int
    group: int
    data: bytearray = dataclasses.field(default_factory=bytearray)
    attrs: dict = dataclasses.field(default_factory=dict)
    mtime: float = 0.0

    @property
    def size(self) -> int:
        return len(self.data)


class FilterDevice(ObdDevice):
    """Direct driver: the `obdfilter` stand-in. The OST's block allocation
    happens *here*, on the server — the paper's distributed-allocation
    insight (§2.2).

    Transactions: every update registers an undo closure with the owning
    target (set via `txn_hook`) so an OST crash rolls back to the last
    commit; clients then replay (ch. 11/29)."""

    obd_type = "filter"

    def __init__(self, name: str, capacity: int = 1 << 40):
        super().__init__(name)
        self.objects: dict[tuple[int, int], StorageObject] = {}
        self.capacity = capacity
        self.used = 0
        self._oid_seq = itertools.count(2)
        self.txn_hook = None             # set by OST: records undo closures
        self.llogs: dict[str, llog_mod.LlogCatalog] = {}

    def _txn(self, undo):
        if self.txn_hook:
            return self.txn_hook(undo)
        return 0

    def llog(self, name: str) -> llog_mod.LlogCatalog:
        cat = self.llogs.get(name)
        if cat is None:
            cat = self.llogs[name] = llog_mod.LlogCatalog(
                f"{self.name}:{name}")
        return cat

    # ----------------------------------------------------------- obd api
    def create(self, group: int, oid: int | None = None, **attrs):
        if oid is None:
            oid = next(self._oid_seq)
        key = (group, oid)
        if key in self.objects:
            raise ObdError(17, f"object {key} exists")      # EEXIST
        obj = StorageObject(oid=oid, group=group, attrs=dict(attrs))
        self.objects[key] = obj
        transno = self._txn(lambda: self.objects.pop(key, None))
        return {"group": group, "oid": oid, "transno": transno}

    def destroy(self, group: int, oid: int):
        key = (group, oid)
        obj = self.objects.pop(key, None)
        if obj is None:
            raise ObdError(2, f"no object {key}")            # ENOENT
        self.used -= obj.size
        sz = obj.size

        def undo():
            self.objects[key] = obj
            self.used += sz
        return {"transno": self._txn(undo)}

    def _get(self, group: int, oid: int) -> StorageObject:
        obj = self.objects.get((group, oid))
        if obj is None:
            raise ObdError(2, f"no object {(group, oid)}")
        return obj

    def getattr(self, group: int, oid: int) -> dict:
        obj = self._get(group, oid)
        return {"size": obj.size, "mtime": obj.mtime,
                "blocks": (obj.size + 4095) // 4096, **obj.attrs}

    def setattr(self, group: int, oid: int, **attrs):
        obj = self._get(group, oid)
        old = dict(obj.attrs)
        old_mtime = obj.mtime
        if "mtime" in attrs:
            obj.mtime = attrs.pop("mtime")
        obj.attrs.update(attrs)

        def undo():
            obj.attrs = old
            obj.mtime = old_mtime
        return {"transno": self._txn(undo)}

    def read(self, group: int, oid: int, offset: int, length: int) -> bytes:
        obj = self._get(group, oid)
        return bytes(obj.data[offset:offset + length])

    def write(self, group: int, oid: int, offset: int, data: bytes,
              mtime: float = 0.0):
        obj = self._get(group, oid)
        end = offset + len(data)
        if end - obj.size > self.capacity - self.used:
            raise ObdError(28, "no space")                   # ENOSPC
        old_len = obj.size
        overlap = bytes(obj.data[offset:min(end, old_len)])
        old_mtime = obj.mtime
        if end > old_len:
            self.used += end - old_len
            obj.data.extend(b"\0" * (end - old_len))
        obj.data[offset:end] = data
        obj.mtime = max(obj.mtime, mtime)
        grew = max(0, end - old_len)

        def undo():
            if grew:
                del obj.data[old_len:]
                self.used -= grew
            obj.data[offset:offset + len(overlap)] = overlap
            obj.mtime = old_mtime
        return {"transno": self._txn(undo), "size": obj.size}

    def writev(self, group: int, oid: int, iov: list, mtime: float = 0.0):
        """Apply a whole niobuf vector [(offset, data), ...] as ONE
        transaction (§4.5.6: bulk moves vectors of niobufs; the OST's BRW
        handler commits them under a single transno / single undo record).
        """
        obj = self._get(group, oid)
        old_len = obj.size
        max_end = max((off + len(d) for off, d in iov), default=old_len)
        if max_end - old_len > self.capacity - self.used:
            raise ObdError(28, "no space")                   # ENOSPC
        undos = []
        for off, data in iov:
            end = off + len(data)
            overlap = bytes(obj.data[off:min(end, obj.size)])
            if end > obj.size:
                self.used += end - obj.size
                obj.data.extend(b"\0" * (end - obj.size))
            obj.data[off:end] = data
            undos.append((off, overlap))
        grew = obj.size - old_len
        old_mtime = obj.mtime
        obj.mtime = max(obj.mtime, mtime)

        def undo():
            for off, overlap in reversed(undos):
                obj.data[off:off + len(overlap)] = overlap
            if grew:
                del obj.data[old_len:]
                self.used -= grew
            obj.mtime = old_mtime
        return {"transno": self._txn(undo), "size": obj.size}

    def punch(self, group: int, oid: int, size: int):
        """Truncate to `size`."""
        obj = self._get(group, oid)
        if size >= obj.size:
            return {"transno": 0}
        cut = bytes(obj.data[size:])
        del obj.data[size:]
        self.used -= len(cut)

        def undo():
            obj.data.extend(cut)
            self.used += len(cut)
        return {"transno": self._txn(undo)}

    def statfs(self) -> dict:
        return {"capacity": self.capacity, "used": self.used,
                "free": self.capacity - self.used,
                "objects": len(self.objects)}

    def sync(self):
        pass

    def list_objects(self, group: int) -> list:
        return sorted(o for g, o in self.objects if g == group)
