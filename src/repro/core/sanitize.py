"""Runtime protocol sanitizer: DLM lockdep + request-boundary invariants.

Enabled with ``SIM_SANITIZE=1`` (evaluated whenever a Simulator is
built, so one pytest run flips the whole suite), or force-enabled from
a test via :func:`forced`.  The hooks are no-ops when disabled — one
attribute check per event.

What it watches:

* **lockdep** — a lock-dependency graph built from *real* enqueue order
  across every client and MDS-MDS import.  An edge ``A -> B`` is
  recorded only when an owner that HOLDS ``A`` issues an enqueue for
  ``B`` that actually conflicts with another holder (true wait-for
  semantics: cached-but-compatible grants order nothing).  A cycle in
  that graph is an ABBA deadlock the synchronous simulator would never
  itself hang on — exactly why it needs a sanitizer.
* **exactly-once** — every transno-bearing handler execution is recorded
  per ``(target, client, xid)``; executing the same xid twice while the
  first execution's transaction survived (committed, or not yet crashed
  away) means the reply cache / replay barrier leaked a duplicate.
  ``Target.crash`` prunes executions above the committed cut: their
  replay is legitimate re-execution.
* **grant conservation** — at every OST request boundary: no export with
  negative grant, and the sum of outstanding grants never exceeds the
  backend capacity.
* **counter partition** — periodically (and whenever procfs asks): for
  every counter key, the per-node attributions must sum to at most the
  cluster-wide total (attribution can under-count — client-side counts
  carry no node — but must never over-count).

Violations are recorded, not raised, so one broken invariant cannot
cascade into unrelated test failures; the autouse pytest fixture in
``tests/conftest.py`` fails any test that produced new ones.  Tests
that *construct* violations on purpose wrap the scenario in
:func:`capture`.
"""
from __future__ import annotations

import dataclasses
import os
from collections import defaultdict
from contextlib import contextmanager

ENV_VAR = "SIM_SANITIZE"


def env_enabled() -> bool:
    return os.environ.get(ENV_VAR, "") not in ("", "0")


@dataclasses.dataclass
class Violation:
    kind: str          # "lockdep-abba" | "exactly-once" | "grant" | "counters"
    detail: str
    chain: list = dataclasses.field(default_factory=list)

    def render(self) -> str:
        out = f"[{self.kind}] {self.detail}"
        for hop in self.chain:
            out += f"\n    {hop}"
        return out


class SanitizerState:
    """Module-global sanitizer state (mirrors ``fail.state``): per-sim
    graphs reset with every Simulator, violation log accumulates so the
    per-test fixture can diff it."""

    def __init__(self):
        self.forced: bool | None = None     # tests override the env
        self.enabled = env_enabled()
        self.checks: defaultdict = defaultdict(int)
        self.suppressed = 0                 # violations eaten by capture()
        self.violations: list[Violation] = []
        self._capturing: list | None = None
        self._new_sim()

    # ------------------------------------------------------------ lifecycle
    def _new_sim(self):
        # owner uuid -> {(target_uuid, res_name): refcount}
        self.held: defaultdict = defaultdict(lambda: defaultdict(int))
        # lock-order edges A -> {B}; evidence remembers one witness each
        self.edges: defaultdict = defaultdict(set)
        self.evidence: dict = {}
        self.cycles: list[list] = []
        self._cycle_keys: set = set()
        # target_uuid -> {(client_uuid, xid): transno}
        self.executed: defaultdict = defaultdict(dict)
        self._boundaries = 0

    def on_new_sim(self):
        """Called from Simulator.__init__: fresh cluster, fresh graphs
        (client uuids repeat across clusters — stale held-state would
        fabricate edges)."""
        self.enabled = self.forced if self.forced is not None \
            else env_enabled()
        self._new_sim()

    # ------------------------------------------------------------ reporting
    def _violate(self, kind: str, detail: str, chain: list | None = None):
        v = Violation(kind, detail, chain or [])
        if self._capturing is not None:
            self.suppressed += 1
            self._capturing.append(v)
        else:
            self.violations.append(v)

    def info(self) -> dict:
        """procfs 'sanitizer' rollup."""
        return {
            "enabled": self.enabled,
            "checks": dict(self.checks),
            "violations": len(self.violations),
            "captured": self.suppressed,
            "lockdep": {
                "edges": sum(len(v) for v in self.edges.values()),
                "held_owners": sum(1 for h in self.held.values() if h),
                "cycles": len(self.cycles),
            },
        }

    # -------------------------------------------------------------- lockdep
    def note_granted(self, owner: str, key: tuple):
        if not self.enabled:
            return
        self.held[owner][key] += 1

    def note_released(self, owner: str, key: tuple):
        if not self.enabled:
            return
        h = self.held[owner]
        if h.get(key, 0) <= 1:
            h.pop(key, None)
        else:
            h[key] -= 1

    def note_enqueue(self, owner: str, key: tuple, conflicted: bool):
        """Server-side enqueue observation.  Only a CONFLICTING enqueue
        orders locks: the owner is now waiting on `key`'s holders while
        everything in its held set stays pinned."""
        if not self.enabled or not conflicted:
            return
        self.checks["lockdep.enqueue"] += 1
        for held_key in list(self.held.get(owner, ())):
            if held_key == key:
                continue
            new_edge = key not in self.edges[held_key]
            self.edges[held_key].add(key)
            self.evidence.setdefault((held_key, key), owner)
            if new_edge:
                self._check_cycle(held_key, key)

    def _check_cycle(self, src: tuple, dst: tuple):
        """Adding src->dst: a path dst ->* src closes a cycle."""
        path = self._find_path(dst, src)
        if path is None:
            return
        cycle = [src] + path            # src -> dst -> ... -> src
        sig = frozenset(cycle)
        if sig in self._cycle_keys:
            return
        self._cycle_keys.add(sig)
        self.cycles.append(cycle)
        chain = []
        for a, b in zip(cycle, cycle[1:]):
            who = self.evidence.get((a, b), "?")
            chain.append(f"{who} held {_fmt(a)} while waiting for {_fmt(b)}")
        self._violate(
            "lockdep-abba",
            f"lock-order cycle over {len(cycle) - 1} resource(s)", chain)

    def _find_path(self, src: tuple, dst: tuple):
        stack = [(src, [src])]
        seen = {src}
        while stack:
            node, path = stack.pop()
            if node == dst:
                return path
            for nxt in self.edges.get(node, ()):
                if nxt not in seen:
                    seen.add(nxt)
                    stack.append((nxt, path + [nxt]))
        return None

    def lockdep_report(self) -> str:
        """Human-readable report (see core/README.md for how to read it)."""
        lines = [f"lockdep: {len(self.cycles)} cycle(s), "
                 f"{sum(len(v) for v in self.edges.values())} edge(s)"]
        for cycle in self.cycles:
            lines.append("  cycle: " + " -> ".join(_fmt(k) for k in cycle))
            for a, b in zip(cycle, cycle[1:]):
                who = self.evidence.get((a, b), "?")
                lines.append(f"    {who}: held {_fmt(a)}, wanted {_fmt(b)}")
        return "\n".join(lines)

    # --------------------------------------------------------- exactly-once
    def note_execute(self, target_uuid: str, client_uuid: str, xid: int,
                     transno: int):
        if not self.enabled:
            return
        self.checks["exactly_once.execute"] += 1
        slot = self.executed[target_uuid]
        prev = slot.get((client_uuid, xid))
        if prev is not None:
            self._violate(
                "exactly-once",
                f"{target_uuid} re-executed xid {xid} from {client_uuid} "
                f"(first run transno {prev} survived the crash cut, second "
                f"run got transno {transno}) — reply cache / replay "
                f"barrier leaked a duplicate execution")
        slot[(client_uuid, xid)] = transno

    def note_crash(self, target_uuid: str, committed_transno: int):
        """Uncommitted executions died with the journal: replaying them
        is the protocol working, not a duplicate."""
        if not self.enabled:
            return
        slot = self.executed[target_uuid]
        for k in [k for k, t in slot.items() if t > committed_transno]:
            del slot[k]

    # ---------------------------------------------------- boundary invariants
    def request_boundary(self, target):
        """Runs in Node._request_in's finally, after every served RPC."""
        if not self.enabled:
            return
        self._boundaries += 1
        obd = getattr(target, "obd", None)
        if obd is not None and target.exports:
            self.checks["grant.boundary"] += 1
            total = 0
            for uuid, exp in target.exports.items():
                g = exp.data.get("grant", 0)
                total += g
                if g < 0:
                    self._violate("grant",
                                  f"{target.uuid}: export {uuid} holds "
                                  f"negative grant {g}")
            cap = obd.statfs()["capacity"]
            if total > cap:
                self._violate("grant",
                              f"{target.uuid}: outstanding grant {total} "
                              f"exceeds capacity {cap} — grants are no "
                              f"longer conserved")
        if self._boundaries % 256 == 0:
            self.check_counter_partition(target.sim.stats)

    def check_counter_partition(self, stats):
        self.checks["counters.partition"] += 1
        sums: defaultdict = defaultdict(int)
        for per_node in stats.node_counters.values():
            for key, n in per_node.items():
                sums[key] += n
        for key, n in sums.items():
            total = stats.counters.get(key, 0)
            if n > total:
                self._violate(
                    "counters",
                    f"per-node counters for {key!r} sum to {n} but the "
                    f"cluster total is {total} — node attribution "
                    f"double-counted")


state = SanitizerState()


def _fmt(key: tuple) -> str:
    target_uuid, res = key
    return f"{target_uuid}:{res}"


# ------------------------------------------------------------- test helpers

@contextmanager
def forced(on: bool = True):
    """Force the sanitizer on (or off) regardless of SIM_SANITIZE; new
    Simulators built inside the scope inherit the forced setting."""
    prev_forced, prev_enabled = state.forced, state.enabled
    state.forced = on
    state.enabled = on
    try:
        yield state
    finally:
        state.forced, state.enabled = prev_forced, prev_enabled


@contextmanager
def capture():
    """Route violations produced inside the scope into the yielded list
    instead of the global log — for tests that stage violations on
    purpose (the autouse guard fixture stays green)."""
    prev = state._capturing
    state._capturing = caught = []
    try:
        yield caught
    finally:
        state._capturing = prev
