"""Lustre logging API — llog (paper ch. 8).

Write-ahead *intent* logs with catalogs and a cross-node cancellation
protocol. Used by:
  * MDS unlink -> OST object destroy (orphan recovery): the MDS logs an
    "unlink" record per data object; the OST cancels the cookie once the
    destroy is committed; after a crash, uncancelled records are re-shipped
    (ch. 8.4, §6.7.5);
  * size/mtime recovery (ch. 8.10);
  * configuration logs (ch. 8.9).

Records live in the owning target's persistent state and participate in its
transaction/undo machinery via the caller.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable

from repro.core import fail as fail_mod


_cookie_seq = itertools.count(1)


@dataclasses.dataclass
class LlogRecord:
    idx: int
    rec_type: str
    payload: dict
    cookie: int = dataclasses.field(default_factory=lambda: next(_cookie_seq))
    cancelled: bool = False


class LlogHandle:
    """One plain log (a special object on the backing store)."""

    def __init__(self, logid: str, cap: int | None = None):
        self.logid = logid
        self.cap = cap
        self.records: list[LlogRecord] = []
        self.added = 0               # index slots ever consumed (cancelling
        self._idx = itertools.count(1)   # a record does not free its slot)

    def add(self, rec_type: str, payload: dict) -> LlogRecord:
        rec = LlogRecord(next(self._idx), rec_type, payload)
        self.records.append(rec)
        self.added += 1
        return rec

    def full(self) -> bool:
        return self.cap is not None and self.added >= self.cap

    def cancel(self, cookies) -> int:
        """Cancel by cookie set; full logs get destroyed by the catalog."""
        cs = set(cookies)
        n = 0
        for r in self.records:
            if r.cookie in cs and not r.cancelled:
                r.cancelled = True
                n += 1
        self.records = [r for r in self.records if not r.cancelled]
        return n

    def pending(self) -> list[LlogRecord]:
        return [r for r in self.records if not r.cancelled]

    def empty(self) -> bool:
        return not self.records


class LlogCatalog:
    """Catalog of llog handles (ch. 8.3: catalog + plain logs)."""

    LOG_CAP = 64                      # records per plain log

    def __init__(self, name: str):
        self.name = name
        self.logs: list[LlogHandle] = []
        self._seq = itertools.count(1)

    def _current(self) -> LlogHandle:
        if not self.logs or self.logs[-1].full():
            self.logs.append(LlogHandle(f"{self.name}-{next(self._seq)}",
                                        cap=self.LOG_CAP))
        return self.logs[-1]

    def add(self, rec_type: str, payload: dict) -> LlogRecord:
        rec = self._current().add(rec_type, payload)
        # deferred crash site: the induced crash lands at the owning
        # target's request boundary — journal atomicity means a crash can
        # never expose half the transaction this write belongs to
        fail_mod.note("llog.catalog.add")
        return rec

    def restore(self, recs) -> None:
        """Undo of a cancel (transaction rollback): re-insert previously
        cancelled records with their original cookies/payloads. Appended
        to the current plain log — readers that need index order must
        sort (the changelog does)."""
        for rec in recs:
            rec.cancelled = False
            lg = self._current()
            lg.records.append(rec)
            lg.added += 1

    def cancel(self, cookies) -> int:
        # deferred crash site: cancellation is part of the surrounding
        # transaction (destroy / changelog clear) — a crash lands at the
        # owning target's request boundary and the undo log re-inserts
        # the records, which are then re-shipped and re-cancelled
        fail_mod.note("llog.cancel")
        n = 0
        for lg in list(self.logs):
            n += lg.cancel(cookies)
            # destroy drained logs. A FULL log is dead even when it is the
            # current (last) one: its index slots are consumed, so the next
            # add() rotates to a fresh log anyway — keeping it alive leaked
            # one plain-log object per drained catalog tail.
            if lg.empty() and (lg.full() or lg is not self.logs[-1]):
                self.logs.remove(lg)
        return n

    def pending(self) -> list[LlogRecord]:
        return [r for lg in self.logs for r in lg.pending()]

    def process(self, cb: Callable[[LlogRecord], bool]) -> int:
        """Run `cb` over pending records; records for which cb returns True
        are cancelled (llog_process + cancel, ch. 8.7). Returns #cancelled."""
        done = []
        for rec in self.pending():
            if cb(rec):
                done.append(rec.cookie)
        return self.cancel(done)
