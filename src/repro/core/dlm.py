"""Distributed Lock Manager (paper ch. 7 and 27).

Faithful pieces:
  * six lock modes EX PW PR CW CR NL (+ Lustre's group locks, ch. 10.10)
    with the VMS compatibility matrix;
  * resources keyed by (type, id) holding granted/waiting queues;
  * *extent* policy: the server grants the **largest possible extent** that
    does not conflict with other granted/waiting locks (§7.5);
  * *intent* policy: the enqueue carries an operation; the server executes
    it while granting (one RPC for lookup+lock+op) (§7.5, §6.2.2);
  * blocking + completion ASTs as real (reverse) RPCs to lock holders;
    holders flush/cancel; unresponsive holders are **evicted** (§7.4);
  * lock value blocks carrying size/mtime/version (§7.7);
  * client-side lock cache with `match` (no RPC when a compatible cached
    lock covers the extent).
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import defaultdict
from typing import Any, Callable, Optional

from repro.core import fail as fail_mod
from repro.core import ptlrpc as R
from repro.core import sanitize

MAX_EXT = (1 << 64) - 1
WHOLE = (0, MAX_EXT)

MODES = ("EX", "PW", "PR", "CW", "CR", "NL", "GR")

# row = held, col = requested : True = compatible (VMS matrix, §7.3)
_C = {
    "NL": {"NL": 1, "CR": 1, "CW": 1, "PR": 1, "PW": 1, "EX": 1, "GR": 1},
    "CR": {"NL": 1, "CR": 1, "CW": 1, "PR": 1, "PW": 1, "EX": 0, "GR": 0},
    "CW": {"NL": 1, "CR": 1, "CW": 1, "PR": 0, "PW": 0, "EX": 0, "GR": 0},
    "PR": {"NL": 1, "CR": 1, "CW": 0, "PR": 1, "PW": 0, "EX": 0, "GR": 0},
    "PW": {"NL": 1, "CR": 1, "CW": 0, "PR": 0, "PW": 0, "EX": 0, "GR": 0},
    "EX": {"NL": 1, "CR": 0, "CW": 0, "PR": 0, "PW": 0, "EX": 0, "GR": 0},
    "GR": {"NL": 1, "CR": 0, "CW": 0, "PR": 0, "PW": 0, "EX": 0, "GR": 1},
}


def compatible(held: "Lock", req_mode: str, req_gid: int = 0) -> bool:
    ok = bool(_C[held.mode][req_mode])
    if held.mode == "GR" and req_mode == "GR":
        return held.gid == req_gid          # group locks share a gid
    return ok


def mode_covers(held: str, req: str) -> bool:
    """A cached lock of mode `held` satisfies a request for mode `req`
    iff `held` is at least as strong: everything incompatible with `req`
    must also be incompatible with `held` (so holding it grants at least
    the protection the requester asked for). Derived straight from the
    VMS matrix — a cached CR lock does NOT satisfy a PR request."""
    return all(_C[held][x] <= _C[req][x] for x in MODES)


def overlaps(a: tuple | None, b: tuple | None) -> bool:
    if a is None or b is None:
        return True                          # plain locks conflict wholly
    return a[0] < b[1] and b[0] < a[1]


_handle_seq = itertools.count(1)


@dataclasses.dataclass
class Lock:
    handle: int
    res_name: tuple
    mode: str
    extent: tuple | None                    # (start, end) end-exclusive
    client_uuid: str
    client_nid: str
    gid: int = 0
    granted: bool = False
    lvb: dict = dataclasses.field(default_factory=dict)
    # client-side:
    refcount: int = 0
    dirty: bool = False                     # pages under this lock to flush

    def covers(self, mode: str, extent: tuple | None) -> bool:
        if not mode_covers(self.mode, mode):
            return False
        if extent is None or self.extent is None:
            return True
        return self.extent[0] <= extent[0] and extent[1] <= self.extent[1]


class Resource:
    def __init__(self, name: tuple):
        self.name = name
        self.granted: list[Lock] = []
        self.waiting: list[Lock] = []
        self.lvb: dict = {}                  # size/mtime/version block
        self.version = 0

    def conflicting(self, mode: str, extent: tuple | None, gid: int,
                    exclude_client: str | None = None) -> list[Lock]:
        out = []
        for lk in self.granted:
            if exclude_client and lk.client_uuid == exclude_client:
                continue
            if not compatible(lk, mode, gid) and overlaps(lk.extent, extent):
                out.append(lk)
        return out


class LdlmNamespace:
    """Server-side lock namespace, embedded in an OST/MDS target.

    The owning target registers our ops on itself and provides an RpcClient
    for reverse (AST) RPCs.
    """

    def __init__(self, target: R.Target, rpc_client: R.RpcClient,
                 intent_policy: Callable | None = None,
                 lvb_update: Callable | None = None):
        self.target = target
        self.sim = target.sim
        self.rpc = rpc_client
        self.resources: dict[tuple, Resource] = {}
        self.intent_policy = intent_policy
        self.lvb_update = lvb_update        # res -> fills res.lvb
        self.conflict_cb = None             # res_name -> None (contention)
        self._cb_imports: dict[str, R.Import] = {}
        t = target
        t.ops["ldlm_enqueue"] = self.op_enqueue
        t.ops["ldlm_cancel"] = self.op_cancel
        t.ops["ldlm_locks_for"] = self.op_locks_for

    # ------------------------------------------------------------- state
    def resource(self, name) -> Resource:
        name = tuple(name)
        res = self.resources.get(name)
        if res is None:
            res = self.resources[name] = Resource(name)
        return res

    def holders(self, name, mode: str = "PR") -> list[Lock]:
        """Clients holding >= mode locks (used by the COBD referral)."""
        res = self.resources.get(tuple(name))
        if not res:
            return []
        return [lk for lk in res.granted if lk.covers(mode, None) or
                lk.mode == mode]

    # -------------------------------------------------------------- RPC
    def _cb_import(self, client_uuid: str, client_nid: str) -> R.Import:
        imp = self._cb_imports.get(client_uuid)
        if imp is None:
            imp = self.rpc.import_target(f"lcb:{client_uuid}",
                                         [client_nid], "ldlm_cb")
            self._cb_imports[client_uuid] = imp
        return imp

    def _glimpse_ast(self, lk: Lock) -> dict | None:
        """Ask the holder for its CURRENT lock value block without
        revoking the lock (§7.7 glimpse): the writer keeps its PW lock
        and its write-back cache, the server learns the live size/mtime.
        Returns None when the holder is unreachable or knows nothing —
        the caller falls back to the on-disk attributes."""
        self.sim.stats.count("dlm.glimpse_ast")
        imp = self._cb_import(lk.client_uuid, lk.client_nid)
        try:
            rep = imp.request("glimpse_ast",
                              {"handle": lk.handle,
                               "res": list(lk.res_name)},
                              no_recover=True)
            d = rep.data or {}
            return None if d.get("unknown") else d
        except (R.TimeoutError_, R.RpcError):
            self.sim.stats.count("dlm.glimpse_timeout")
            return None

    def glimpse_lvb(self, name, base: dict | None = None) -> dict:
        """Current LVB for a resource: on-disk state merged with what
        PW/EX/GR holders report over glimpse ASTs. This is how a stat of
        a file under write learns the live size WITHOUT killing the
        writer's cache (before: a PR enqueue revoked the PW lock).
        `base` lets a caller that already read the disk attributes seed
        the LVB instead of paying a second backend read."""
        res = self.resource(tuple(name))
        if base is not None:
            lvb = dict(base)
        else:
            if self.lvb_update:
                self.lvb_update(res)
            lvb = dict(res.lvb)
        for lk in list(res.granted):
            if lk.mode in ("PW", "EX", "GR"):
                d = self._glimpse_ast(lk)
                if d and "size" in d:
                    lvb["size"] = max(lvb.get("size", 0), d["size"])
                    lvb["mtime"] = max(lvb.get("mtime", 0.0),
                                       d.get("mtime", 0.0))
        return lvb

    def _blocking_ast(self, lk: Lock) -> bool:
        """Ask the holder to drop `lk`. Returns False if the holder is
        unreachable (-> eviction)."""
        self.sim.stats.count("dlm.blocking_ast")
        act = fail_mod.state.check("dlm.blocking_ast")
        if act == "drop":
            # the AST is lost on the wire: the holder never answers and
            # is treated exactly like a dead client (§7.4 -> eviction)
            return False
        if act == "crash":
            # mid-revocation server crash, deferred to the request
            # boundary of the target serving the triggering enqueue
            fail_mod.state.defer("dlm.blocking_ast")
        imp = self._cb_import(lk.client_uuid, lk.client_nid)
        try:
            rep = imp.request("blocking_ast",
                              {"handle": lk.handle,
                               "res": list(lk.res_name)},
                              no_recover=True)
            if (rep.data or {}).get("unknown"):
                # holder lost the lock state: reap it server-side
                res = self.resources.get(lk.res_name)
                if res and lk in res.granted:
                    res.granted.remove(lk)
                self.sim.stats.count("dlm.stale_lock_reaped")
            return True
        except (R.TimeoutError_, R.RpcError):
            return False

    def evict_client(self, client_uuid: str):
        """Drop every lock of a dead client (§7.4 AST timeout -> evict)."""
        self.sim.stats.count("dlm.evictions")
        self.target.evicted.add(client_uuid)
        for res in self.resources.values():
            res.granted = [l for l in res.granted
                           if l.client_uuid != client_uuid]
            res.waiting = [l for l in res.waiting
                           if l.client_uuid != client_uuid]

    # ------------------------------------------------- extent grant policy
    def _grow_extent(self, res: Resource, lk: Lock) -> tuple | None:
        """§7.5: grant the *largest* extent containing the request that does
        not overlap any extent of a conflicting granted/waiting lock."""
        if lk.extent is None:
            return None
        lo, hi = 0, MAX_EXT
        for other in res.granted + res.waiting:
            if other is lk or other.client_uuid == lk.client_uuid:
                continue
            if compatible(other, lk.mode, lk.gid):
                continue
            if other.extent is None:
                return lk.extent              # plain conflict: no growth
            os_, oe = other.extent
            if oe <= lk.extent[0]:
                lo = max(lo, oe)
            elif os_ >= lk.extent[1]:
                hi = min(hi, os_)
        return (lo, hi)

    # ----------------------------------------------------------- enqueue
    def op_enqueue(self, req: R.Request) -> R.Reply:
        b = req.body
        name = tuple(b["res"])
        mode = b["mode"]
        extent = tuple(b["extent"]) if b.get("extent") else None
        gid = b.get("gid", 0)
        res = self.resource(name)

        # conflict resolution FIRST: Lustre strictly orders "locks are
        # acquired before the associated data is used" (§6.2.3) — the
        # intent below must see post-revocation state (WBC holders flush
        # on the blocking AST before the lookup runs).
        lk = Lock(next(_handle_seq), name, mode, extent,
                  req.client_uuid, b.get("client_nid", ""), gid=gid)
        res.waiting.append(lk)
        conf = res.conflicting(mode, extent, gid,
                               exclude_client=req.client_uuid)
        # lockdep: a CONFLICTING enqueue orders everything the requester
        # already holds before this resource (glimpse enqueues never
        # wait — they are answered with the merged LVB below)
        sanitize.state.note_enqueue(
            req.client_uuid, (self.target.uuid, name),
            bool(conf) and not b.get("glimpse"))
        if b.get("glimpse") and conf:
            # glimpse enqueue (§7.7): the requester only wants the LVB —
            # do NOT revoke the conflicting holders; ask them for their
            # value blocks instead and answer without granting
            res.waiting.remove(lk)
            self.sim.stats.count("dlm.glimpse_served")
            return R.Reply(data={"handle": 0, "granted": False,
                                 "intent": None,
                                 # lint: rpc-under-lock(glimpse ASTs never
                                 # revoke and holders answer from their own
                                 # ldlm_cb service, so no wait cycle forms)
                                 "lvb": self.glimpse_lvb(name),
                                 "version": res.version})
        if conf and self.conflict_cb:
            self.conflict_cb(name)
        for other in list(conf):
            # lint: rpc-under-lock(revocation protocol: the blocking AST
            # goes to a DIFFERENT client's ldlm_cb service and the holder
            # yields rather than acquires, so this wait cannot cycle)
            ok = self._blocking_ast(other)
            if not ok:
                self.evict_client(other.client_uuid)
        # after ASTs, holders have cancelled (synchronously); re-check
        conf = res.conflicting(mode, extent, gid,
                               exclude_client=req.client_uuid)
        if conf:
            # still conflicting (another same-arrival waiter) — in the
            # synchronous model this cannot block forever; deny politely.
            res.waiting.remove(lk)
            return R.Reply(status=-11)

        intent_data = None
        if b.get("intent") and self.intent_policy:
            # intent policy: execute the op server-side while granting
            # (it may veto the lock entirely, e.g. highly-contended res).
            intent_data, grant = self.intent_policy(req, res)
            if not grant:
                res.waiting.remove(lk)
                rep = R.Reply(data={"handle": 0, "granted": False,
                                    "intent": intent_data,
                                    "lvb": dict(res.lvb)})
                if isinstance(intent_data, dict) and \
                        intent_data.get("_transno"):
                    rep.transno = intent_data["_transno"]
                return rep

        lk.extent = self._grow_extent(res, lk)
        res.waiting.remove(lk)
        lk.granted = True
        res.granted.append(lk)
        if self.lvb_update:
            self.lvb_update(res)
        self.sim.stats.count("dlm.granted")
        rep = R.Reply(data={"handle": lk.handle, "granted": True,
                            "mode": mode, "extent": lk.extent,
                            "intent": intent_data, "lvb": dict(res.lvb),
                            "version": res.version})
        if isinstance(intent_data, dict) and intent_data.get("_transno"):
            rep.transno = intent_data["_transno"]   # replayable intent op
        return rep

    def op_cancel(self, req: R.Request) -> R.Reply:
        h = req.body["handle"]
        for res in self.resources.values():
            for lk in res.granted:
                if lk.handle == h:
                    res.granted.remove(lk)
                    self.sim.stats.count("dlm.cancel")
                    return R.Reply()
        return R.Reply()                     # cancel of unknown lock: ok

    def op_locks_for(self, req: R.Request) -> R.Reply:
        """Referral support: who holds `mode` locks overlapping extent?"""
        res = self.resources.get(tuple(req.body["res"]))
        mode = req.body.get("mode", "PR")
        extent = tuple(req.body["extent"]) if req.body.get("extent") else None
        out = []
        if res:
            for lk in res.granted:
                if lk.mode == mode and overlaps(lk.extent, extent):
                    out.append({"client_uuid": lk.client_uuid,
                                "client_nid": lk.client_nid,
                                "extent": lk.extent})
        return R.Reply(data=out)

    def bump_version(self, name, **lvb):
        res = self.resource(name)
        res.version += 1
        res.lvb.update(lvb)


# ---------------------------------------------------------------- client

class LockCallbackTarget(R.Target):
    """Per-RpcClient pseudo-target receiving ASTs (reverse RPCs). One
    client uuid holds locks in MANY namespaces (each OST + each MDS), so
    this dispatcher routes by lock handle to the owning LockClient."""

    svc_kind = "ldlm_cb"

    def __init__(self, rpc_uuid: str, node: R.Node):
        super().__init__(f"lcb:{rpc_uuid}", node)
        self.clients: list["LockClient"] = []
        self.ops["blocking_ast"] = self.op_blocking_ast
        self.ops["glimpse_ast"] = self.op_glimpse_ast

    def op_glimpse_ast(self, req: R.Request) -> R.Reply:
        h = req.body["handle"]
        for lc in self.clients:
            if h in lc.locks:
                return R.Reply(data=lc.on_glimpse_ast(h))
        return R.Reply(data={"unknown": True})

    def op_blocking_ast(self, req: R.Request) -> R.Reply:
        h = req.body["handle"]
        for lc in self.clients:
            if h in lc.locks:
                lc.on_blocking_ast(h, tuple(req.body["res"]))
                return R.Reply()
        # no LockClient knows this handle: the lock state was lost on this
        # client — tell the server to reap it (implicit cancel)
        return R.Reply(data={"unknown": True})


class LockClient:
    """Client lock cache for one remote namespace (one OST or MDS).

    `flush_cb(lock)` is provided by the data layer (page-cache writeback
    before a PW lock is surrendered). `revoke_cbs` fire whenever a lock
    leaves the cache for ANY reason (blocking AST, cancel, eviction) —
    clean cached pages are valid exactly while a lock covers them
    (§7.4/§7.6), so the data layer invalidates them here."""

    def __init__(self, rpc: R.RpcClient, server_import: R.Import,
                 flush_cb: Callable[["Lock"], None] | None = None):
        self.rpc = rpc
        self.imp = server_import
        self.sim = rpc.sim
        self.flush_cb = flush_cb
        # glimpse_cb(lock) -> {"size","mtime"}: the data layer reports its
        # CURRENT value block (dirty cache included) without dropping the
        # lock when the server glimpses it (§7.7)
        self.glimpse_cb: Callable[["Lock"], dict] | None = None
        self.revoke_cbs: list[Callable[["Lock"], None]] = []
        self.locks: dict[int, Lock] = {}
        self.by_res: defaultdict = defaultdict(list)
        node = rpc.node
        key = f"lcb:{rpc.uuid}"
        cbt = node.targets.get(key)
        if cbt is None:
            cbt = LockCallbackTarget(rpc.uuid, node)
        cbt.clients.append(self)

    # -------------------------------------------------------------- match
    def match(self, res_name, mode: str, extent=None) -> Lock | None:
        for lk in self.by_res.get(tuple(res_name), ()):
            if lk.covers(mode, extent):
                self.sim.stats.count("dlm.client_match")
                return lk
        return None

    # ------------------------------------------------------------ enqueue
    def enqueue(self, res_name, mode: str, extent=None, *, gid: int = 0,
                intent: dict | None = None, use_cache: bool = True,
                glimpse: bool = False, fixup=None):
        """Returns (lock | None, intent_data, lvb). With `glimpse` the
        server answers a conflicting enqueue with the holders' merged
        LVB instead of revoking them (lock comes back None)."""
        if use_cache and not intent:
            lk = self.match(res_name, mode, extent)
            if lk is not None:
                return lk, None, dict(lk.lvb)
        body = {"res": list(res_name), "mode": mode,
                "extent": list(extent) if extent else None,
                "gid": gid, "client_nid": self.rpc.nid, "intent": intent,
                "glimpse": glimpse}
        rep = self.imp.request("ldlm_enqueue", body, fixup=fixup)
        d = rep.data
        if not d["granted"]:
            return None, d.get("intent"), d.get("lvb", {})
        lk = Lock(d["handle"], tuple(res_name), mode,
                  tuple(d["extent"]) if d.get("extent") else None,
                  self.rpc.uuid, self.rpc.nid, gid=gid, granted=True,
                  lvb=d.get("lvb", {}))
        self.locks[lk.handle] = lk
        self.by_res[lk.res_name].append(lk)
        sanitize.state.note_granted(self.rpc.uuid,
                                    (self.imp.target_uuid, lk.res_name))
        return lk, d.get("intent"), d.get("lvb", {})

    def _forget(self, lk: Lock):
        """Drop a lock from the cache + notify the data layer: pages the
        lock covered are no longer protected."""
        self.locks.pop(lk.handle, None)
        if lk in self.by_res.get(lk.res_name, ()):
            self.by_res[lk.res_name].remove(lk)
        sanitize.state.note_released(self.rpc.uuid,
                                     (self.imp.target_uuid, lk.res_name))
        for cb in self.revoke_cbs:
            cb(lk)

    def cancel(self, lk: Lock):
        if self.flush_cb and lk.dirty:
            self.flush_cb(lk)
            lk.dirty = False
        self._forget(lk)
        try:
            self.imp.request("ldlm_cancel", {"handle": lk.handle})
        except (R.TimeoutError_, R.RpcError):
            pass

    def cancel_all(self):
        for lk in list(self.locks.values()):
            self.cancel(lk)

    def drop_all(self):
        """Local-only teardown (server evicted us: it already dropped our
        locks, so no cancel RPCs): every covered page is invalidated."""
        for lk in list(self.locks.values()):
            lk.dirty = False
            self._forget(lk)
        self.by_res.clear()

    # --------------------------------------------------------------- ASTs
    def on_glimpse_ast(self, handle: int) -> dict:
        """Server asks for our current LVB: answer WITHOUT flushing or
        dropping anything — that is the whole point of the glimpse."""
        lk = self.locks.get(handle)
        self.sim.stats.count("dlm.client_glimpse_ast")
        if lk is None:
            return {"unknown": True}
        if self.glimpse_cb is not None:
            return self.glimpse_cb(lk) or {}
        return dict(lk.lvb)

    def on_blocking_ast(self, handle: int, res_name: tuple):
        lk = self.locks.get(handle)
        self.sim.stats.count("dlm.client_bl_ast")
        if lk is None:
            return
        if self.flush_cb and lk.dirty:
            self.flush_cb(lk)
            lk.dirty = False
        # revocation drops CLEAN pages too (revoke_cbs inside _forget):
        # the writer about to be granted will change data under this
        # lock, so serving the old pages later would be stale (§7.4)
        self._forget(lk)
        # lock cancel goes back to the server as its own RPC
        try:
            self.imp.request("ldlm_cancel", {"handle": handle})
        except (R.TimeoutError_, R.RpcError):
            pass
