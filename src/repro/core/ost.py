"""Object Storage Target (paper ch. 2.2, 5, 10.12, 23.4).

An OST wraps a direct OBD device (FilterDevice) behind the OST network
protocol, embeds a DLM namespace for *extent* locks on its objects, manages
client *grants* (space pre-allocated to clients so they can write back
cached dirty data without ENOSPC surprises, ch. 10.12), and hosts the
*referral* module that redirects reads to collaborative caches (§5.5.2).

Bulk data rides on the request's `bulk_nbytes` (timing) + the reply `bulk`
field (payload) — the niobuf vector of §4.5.6.
"""
from __future__ import annotations

from typing import Optional

from repro.core import dlm as dlm_mod
from repro.core import obd as obd_mod
from repro.core import ptlrpc as R

INITIAL_GRANT = 2 << 20        # 2 MB on connect
GRANT_CHUNK = 8 << 20


class OstTarget(R.Target):
    svc_kind = "ost"

    def __init__(self, uuid: str, node: R.Node, capacity: int = 1 << 40):
        super().__init__(uuid, node)
        self.obd = obd_mod.FilterDevice(f"{uuid}-filter", capacity)
        self.obd.txn_hook = self.txn
        self.rpc = R.RpcClient(node)
        self.ldlm = dlm_mod.LdlmNamespace(
            self, self.rpc, lvb_update=self._lvb_update)
        # referral/policy module (§5.5.2): caching OST uuid -> nid
        self.caching_osts: dict[str, str] = {}
        self.referral_rr = 0
        # per-jobid I/O byte attribution, {jobid: {"read": n, "write": n}}:
        # server-side ground truth for "how fast is the rebuild job
        # actually moving" vs the client jobs sharing this spindle
        self.jobid_bytes: dict[str, dict] = {}
        ops = self.ops
        ops["connect"] = self.op_connect
        ops["disconnect"] = self.op_disconnect
        ops["ping"] = self.op_ping
        ops["create"] = self.op_create
        ops["destroy"] = self.op_destroy
        ops["getattr"] = self.op_getattr
        ops["setattr"] = self.op_setattr
        ops["read"] = self.op_read
        ops["write"] = self.op_write
        ops["punch"] = self.op_punch
        ops["glimpse_bulk"] = self.op_glimpse_bulk
        ops["statfs"] = self.op_statfs
        ops["sync"] = self.op_sync
        ops["list_objects"] = self.op_list_objects
        ops["llog_cancel"] = self.op_llog_cancel
        ops["orphan_cleanup"] = self.op_orphan_cleanup
        ops["grant_shrink"] = self.op_grant_shrink

    # ---------------------------------------------------- VBR (ISSUE-10)
    def vbr_keys_for(self, req: R.Request) -> list:
        """Every object mutation versions its (group, oid).  `create` is
        deliberately untracked: a pinned-oid replay either finds its
        object alive (idempotent) or rebirths it — no older mutation can
        conflict with an object's own birth."""
        if req.opcode in ("write", "setattr", "punch", "destroy"):
            b = req.body
            if b.get("oid") is not None:
                return [("obj", b["group"], b["oid"])]
        return []

    # ------------------------------------------------------------- locks
    def _lvb_update(self, res: dlm_mod.Resource):
        if res.name[0] != "ext":
            return
        _, group, oid = res.name
        try:
            attrs = self.obd.getattr(group, oid)
            res.lvb.update(size=attrs["size"], mtime=attrs["mtime"])
        except obd_mod.ObdError:
            pass

    # ------------------------------------------------------------ grants
    def _grant_for(self, exp: R.Export, want: int) -> int:
        free = self.obd.statfs()["free"]
        cur = exp.data.get("grant", 0)
        add = max(0, min(want, free // max(1, 2 * len(self.exports)) - cur))
        exp.data["grant"] = cur + add
        return exp.data["grant"]

    def op_connect(self, req: R.Request) -> R.Reply:
        rep = super().op_connect(req)
        exp = self.exports[req.client_uuid]
        rep.data["grant"] = self._grant_for(exp, INITIAL_GRANT)
        return rep

    def op_grant_shrink(self, req: R.Request) -> R.Reply:
        """Client returns idle grant down to an absolute `keep` target
        (idempotent: a resent shrink converges to the same number).
        Grant bookkeeping is volatile export state — no transno."""
        exp = self.exports[req.client_uuid]
        keep = max(0, int(req.body.get("keep", 0)))
        cur = exp.data.get("grant", 0)
        if cur > keep:
            self.sim.stats.count("ost.grant_shrunk_bytes", cur - keep)
            exp.data["grant"] = keep
        return R.Reply(data={"grant": exp.data.get("grant", 0)})

    # ---------------------------------------------------------- monitor
    def mon_stats(self) -> dict:
        sf = self.obd.statfs()
        return {
            "space": {"capacity": sf["capacity"], "free": sf["free"],
                      "objects": len(self.obd.objects)},
            "grant": {
                "granted_total": sum(e.data.get("grant", 0)
                                     for e in self.exports.values()),
                "shrunk_bytes": self.sim.stats.node_counters
                                .get(self.uuid, {})
                                .get("ost.grant_shrunk_bytes", 0),
            },
            "locks": {
                "resources": len(self.ldlm.resources),
                "granted": sum(len(r.granted)
                               for r in self.ldlm.resources.values()),
                "waiting": sum(len(r.waiting)
                               for r in self.ldlm.resources.values()),
            },
            "jobid_bytes": {j: dict(v)
                            for j, v in self.jobid_bytes.items()},
        }

    def _note_jobid_io(self, req: R.Request, kind: str, nbytes: int):
        jobid = getattr(req, "jobid", "") or ""
        if not jobid or not nbytes:
            return
        slot = self.jobid_bytes.setdefault(jobid, {"read": 0, "write": 0})
        slot[kind] += nbytes

    # ----------------------------------------------------------- obd ops
    def _wrap(self, fn, *a, **kw):
        try:
            return fn(*a, **kw)
        except obd_mod.ObdError as e:
            raise R.RpcError(-e.errno, str(e))

    def op_create(self, req: R.Request) -> R.Reply:
        b = req.body
        if req.replay and b.get("oid") is not None:
            # replayed create of an object that survived: idempotent
            try:
                self.obd.getattr(b["group"], b["oid"])
                return R.Reply(data={"group": b["group"], "oid": b["oid"]},
                               transno=self.transno)
            except obd_mod.ObdError:
                pass
        out = self._wrap(self.obd.create, b["group"], b.get("oid"),
                         **b.get("attrs", {}))
        return R.Reply(data=out, transno=out["transno"])

    def op_destroy(self, req: R.Request) -> R.Reply:
        b = req.body
        try:
            out = self.obd.destroy(b["group"], b["oid"])
        except obd_mod.ObdError:
            return R.Reply(data={"transno": 0})     # idempotent for replay
        # cancel llog cookie shipped with the destroy (ch. 8.4)
        if b.get("cookie"):
            self.obd.llog("unlink-client").cancel([b["cookie"]])
        return R.Reply(data=out, transno=out["transno"])

    def op_getattr(self, req: R.Request) -> R.Reply:
        b = req.body
        return R.Reply(data=self._wrap(self.obd.getattr, b["group"], b["oid"]))

    def op_glimpse_bulk(self, req: R.Request) -> R.Reply:
        """Vectored glimpse (§7.7): ONE RPC answers size/mtime for MANY
        objects of this OST — a striped-directory scan ships one of
        these per OST instead of one getattr per stripe object. Each
        object's LVB merges disk state with what PW holders report over
        glimpse ASTs, so writers keep their locks and caches."""
        out = []
        for g, o in req.body["objects"]:
            try:
                a = self.obd.getattr(g, o)
            except obd_mod.ObdError:
                out.append(None)
                continue
            lvb = self.ldlm.glimpse_lvb(
                ("ext", g, o), base={"size": a["size"],
                                     "mtime": a["mtime"]})
            out.append({"size": lvb.get("size", 0),
                        "mtime": lvb.get("mtime", 0.0)})
        self.sim.stats.count("ost.glimpse_objects", len(out))
        return R.Reply(data={"attrs": out}, bulk_nbytes=R.wire_size(out))

    def op_setattr(self, req: R.Request) -> R.Reply:
        b = req.body
        out = self._wrap(self.obd.setattr, b["group"], b["oid"],
                         **b.get("attrs", {}))
        return R.Reply(data=out, transno=out["transno"])

    def _maybe_refer(self, req: R.Request, group: int, oid: int,
                     ext: tuple) -> R.Reply | None:
        """Referral module: redirect to a collaborative cache when some
        caching OST holds a PR lock covering the extent (§5.5.2), or --
        cache-population policy -- round-robin when none does. Reads
        FROM a COBD (populating its cache) are never re-referred."""
        b = req.body
        if not self.caching_osts or b.get("no_referral") \
                or b.get("_from_cobd"):
            return None
        holders = self.ldlm.resources.get(("ext", group, oid))
        cached = []
        if holders:
            for lk in holders.granted:
                if (lk.client_uuid in self.caching_osts
                        and lk.mode == "PR"
                        and dlm_mod.overlaps(lk.extent, ext)):
                    cached.append(lk.client_uuid)
        if cached:
            pick = cached[self.referral_rr % len(cached)]
        else:
            pick = list(self.caching_osts)[
                self.referral_rr % len(self.caching_osts)]
        self.referral_rr += 1
        self.sim.stats.count("ost.referral")
        return R.Reply(data={"referral": {
            "uuid": pick, "nid": self.caching_osts[pick]}})

    def op_read(self, req: R.Request) -> R.Reply:
        b = req.body
        group, oid = b["group"], b["oid"]
        if "niobufs" in b:
            # vectored BRW read: one reply carries the whole niobuf vector
            nio = b["niobufs"]
            span = (min(n["offset"] for n in nio),
                    max(n["offset"] + n["length"] for n in nio))
            ref = self._maybe_refer(req, group, oid, span)
            if ref is not None:
                return ref
            chunks = [self._wrap(self.obd.read, group, oid,
                                 n["offset"], n["length"]) for n in nio]
            total = sum(len(c) for c in chunks)
            self.sim.stats.add_bytes("ost.read", total)
            self._note_jobid_io(req, "read", total)
            self.sim.stats.count("ost.brw_read_niobufs", len(nio))
            return R.Reply(data={"len": total, "niobufs": len(nio)},
                           bulk=chunks, bulk_nbytes=total)
        ref = self._maybe_refer(req, group, oid,
                                (b["offset"], b["offset"] + b["length"]))
        if ref is not None:
            return ref
        data = self._wrap(self.obd.read, group, oid, b["offset"], b["length"])
        self.sim.stats.add_bytes("ost.read", len(data))
        self._note_jobid_io(req, "read", len(data))
        return R.Reply(data={"len": len(data)}, bulk=data,
                       bulk_nbytes=len(data))

    def op_write(self, req: R.Request) -> R.Reply:
        b = req.body
        if "niobufs" in b:
            # vectored BRW write: apply the whole niobuf vector in ONE
            # backend transaction and answer with a single reply
            iov = [(n["offset"], n["data"]) for n in b["niobufs"]]
            out = self._wrap(self.obd.writev, b["group"], b["oid"], iov,
                             b.get("mtime", self.sim.now))
            total = sum(len(d) for _, d in iov)
            self.sim.stats.count("ost.brw_write_niobufs", len(iov))
        else:
            data = b["data"]
            out = self._wrap(self.obd.write, b["group"], b["oid"],
                             b["offset"], data,
                             b.get("mtime", self.sim.now))
            total = len(data)
        self.sim.stats.add_bytes("ost.write", total)
        self._note_jobid_io(req, "write", total)
        exp = self.exports[req.client_uuid]
        exp.data["grant"] = max(0, exp.data.get("grant", 0) - total)
        self.ldlm.bump_version(("ext", b["group"], b["oid"]), size=out["size"])
        return R.Reply(data={"size": out["size"],
                             "grant": self._grant_for(exp, GRANT_CHUNK)},
                       transno=out["transno"])

    def op_punch(self, req: R.Request) -> R.Reply:
        b = req.body
        out = self._wrap(self.obd.punch, b["group"], b["oid"], b["size"])
        return R.Reply(data=out, transno=out.get("transno", 0))

    def op_statfs(self, req: R.Request) -> R.Reply:
        return R.Reply(data=self.obd.statfs())

    def op_sync(self, req: R.Request) -> R.Reply:
        self.commit()
        return R.Reply(data={"last_committed": self.committed_transno})

    def op_list_objects(self, req: R.Request) -> R.Reply:
        return R.Reply(data=self.obd.list_objects(req.body["group"]))

    def op_llog_cancel(self, req: R.Request) -> R.Reply:
        n = self.obd.llog(req.body["catalog"]).cancel(req.body["cookies"])
        return R.Reply(data={"cancelled": n})

    def op_orphan_cleanup(self, req: R.Request) -> R.Reply:
        """MDS-driven orphan deletion after MDS recovery (§6.7.5): destroy
        objects in `group` above `last_used` oid that no file references."""
        b = req.body
        doomed = [oid for oid in self.obd.list_objects(b["group"])
                  if oid > b["last_used"] and oid not in set(b.get("keep", ()))]
        for oid in doomed:
            self.obd.destroy(b["group"], oid)
        self.sim.stats.count("ost.orphans_destroyed", len(doomed))
        return R.Reply(data={"destroyed": doomed})

    # --------------------------------------------------------- lifecycle
    def register_caching_ost(self, uuid: str, nid: str):
        self.caching_osts[uuid] = nid
