"""ptlrpc: request processing over Portals (paper ch. 4.5-4.8, 22, 23, 29).

Concepts kept from the paper:
  * static portal assignment per protocol (OST_REQUEST_PORTAL=6, ...);
  * per-connection increasing xids; replies matched on xid bits;
  * bulk transfer via logical niobufs (vectors of extents) moved on the bulk
    portals, driven by the server (`ptlrpc_bulk_get` for writes / `_put` for
    reads);
  * targets / exports / imports / services (§4.6): an export is server-side
    per-client state (last_rcvd slot, reply cache); an import is the client
    stub with a failover nid list;
  * transactions: every update gets a transno; the server retains an *undo
    record* until commit (commits are lazy — `commit_interval` ops — so a
    crash loses the tail, which clients recover by REPLAY);
  * recovery (§6.6, ch. 11/29): timeout -> disconnect -> reconnect (possibly
    to a failover nid) -> replay committed-but-lost transnos in order ->
    resend unreplied requests; the server answers resends of executed
    requests from the reply cache keyed (client_uuid, xid).

Portal / NRS layering (ch. 22-23 + the NRS refactor):

    client Import.request()                 server Node
      |  PUT on REQUEST_PORTALS[kind]        |  pre-posted MD, EQ handler
      v                                      v
    portals.transmit  ------------------>  Node._request_in(ev)
                                             |  target lookup (body._target)
                                             v
                                           Service.process(req, arrival)
                                             |  NRS policy picks the virtual
                                             |  start (fifo/crr/orr/tbf,
                                             |  see core.nrs) + accounting
                                             v
                                           Target.handle(req)  -> Reply
                                             |
      reply MD matched on xid  <-----------  PUT on REPLY_PORTALS[kind]

The Service sits between the Portals event and the Target handler table:
every target owns one (`target.service`), its policy is switchable at
runtime (`service.set_policy("tbf", rate=100)` or `lctl("nrs", ...)`),
and bulk-heavy requests (niobuf vectors from the OSC's BRW path) are
charged a per-niobuf service cost so scheduling sees their true weight.
"""
from __future__ import annotations

import dataclasses
import itertools
import zlib
from collections import defaultdict
from typing import Any, Callable, Optional

from repro.core import fail as fail_mod
from repro.core import nrs as nrs_mod
from repro.core import portals as P
from repro.core import sanitize
from repro.core.sim import Simulator

# --------------------------------------------------------------- portals
# Static portal index assignment (paper §4.5.1).
OSC_REPLY_PORTAL = 4
OSC_BULK_PORTAL = 5
OST_REQUEST_PORTAL = 6
OST_BULK_PORTAL = 8
MDC_REPLY_PORTAL = 10
MDS_REQUEST_PORTAL = 12
MDS_BULK_PORTAL = 13
LDLM_CB_REQUEST_PORTAL = 15   # server -> client ASTs
LDLM_CB_REPLY_PORTAL = 16
LDLM_REQUEST_PORTAL = 17
LDLM_REPLY_PORTAL = 18
PING_PORTAL = 23

PAGE_SIZE = 4096               # BRW page granularity (cost model + OSC)

REQUEST_PORTALS = {"ost": OST_REQUEST_PORTAL, "mds": MDS_REQUEST_PORTAL,
                   "ldlm": LDLM_REQUEST_PORTAL, "ping": PING_PORTAL,
                   "ldlm_cb": LDLM_CB_REQUEST_PORTAL}
REPLY_PORTALS = {"ost": OSC_REPLY_PORTAL, "mds": MDC_REPLY_PORTAL,
                 "ldlm": LDLM_REPLY_PORTAL, "ping": OSC_REPLY_PORTAL,
                 "ldlm_cb": LDLM_CB_REPLY_PORTAL}

DEFAULT_TIMEOUT = 1.0      # virtual seconds ("obd_timeout")

# ------------------------------------------------- adaptive timeouts (AT)
# Lustre 1.8 adaptive timeouts (ch. 11): the client keeps a per-(import,
# opcode) service-time history (a decayed max) and times out at
# estimate * (1 + margin) clamped to [at_min, at_max] instead of the one
# flat obd_timeout.  The server side of the bargain is the EARLY REPLY:
# when the NRS queue means a request will finish after the client's
# shipped deadline, the service extends that deadline (`early_until` on
# the reply) so a merely-loaded server is not mistaken for a dead one.
AT_MIN = 0.5               # floor: never flakier than this
AT_MAX = 10.0              # ceiling: a dead server is still detected
AT_DECAY = 0.9             # history decay per observation (decayed max)
AT_MARGIN = 0.25           # client slack factor over the estimate
EARLY_REPLY_MARGIN = 0.25  # server slack granted past actual completion
BACKOFF_BASE = 0.05        # reconnect backoff: base * 2^attempt ...
BACKOFF_MAX = 1.0          # ... capped here (virtual seconds)
TRANSNO_EPOCH = 1 << 20    # per-boot transno epoch (VBR monotonicity)


class AdaptiveTimeout:
    """Per-import AT state: opcode -> decayed-max service estimate."""

    def __init__(self, at_min: float = AT_MIN, at_max: float = AT_MAX,
                 enabled: bool = True):
        self.at_min = at_min
        self.at_max = at_max
        self.enabled = enabled
        self.est: dict[str, float] = {}

    def observe(self, opcode: str, rtt: float):
        cur = self.est.get(opcode, 0.0)
        self.est[opcode] = max(rtt, cur * AT_DECAY)

    def timeout_for(self, opcode: str) -> float:
        est = self.est.get(opcode, 0.0)
        return min(self.at_max,
                   max(self.at_min, est * (1.0 + AT_MARGIN)))

    def info(self) -> dict:
        return {"at_min": self.at_min, "at_max": self.at_max,
                "enabled": self.enabled,
                "estimates": {k: round(v, 6)
                              for k, v in sorted(self.est.items())}}


def wire_size(obj: Any) -> int:
    """Rough on-the-wire size of a request/reply payload."""
    if obj is None:
        return 0
    if isinstance(obj, (bytes, bytearray, memoryview)):
        return len(obj)
    if isinstance(obj, str):
        return len(obj)
    if isinstance(obj, (int, float, bool)):
        return 8
    if isinstance(obj, dict):
        return 16 + sum(wire_size(k) + wire_size(v) for k, v in obj.items())
    if isinstance(obj, (list, tuple, set)):
        return 16 + sum(wire_size(v) for v in obj)
    if dataclasses.is_dataclass(obj):
        return 16 + sum(wire_size(getattr(obj, f.name))
                        for f in dataclasses.fields(obj))
    return 32


# --------------------------------------------------------------- messages

@dataclasses.dataclass
class Request:
    opcode: str
    body: dict
    xid: int = 0
    client_uuid: str = ""
    boot_count: int = 0          # client boot count (epoch)
    conn_generation: int = 0
    replay: bool = False
    bulk_nbytes: int = 0         # niobuf vector total (timing)
    transno: int = 0             # assigned by server on updates
    sent_at: float = 0.0         # client send instant (AT: the server
                                 # derives request transit from it)
    deadline: float = 0.0        # client's absolute give-up time; the
                                 # server grants an early reply when its
                                 # own completion estimate overruns it
                                 # (0 = pre-AT client, never early-reply)
    jobid: str = ""              # batch-job tag: TBF NRS classification +
                                 # changelog attribution (one plumbing,
                                 # two consumers)
    trace_id: int = 0            # span id (core.metrics): assigned ONCE at
                                 # construction, stable across resend /
                                 # replay / reply-cache retries so the
                                 # registry can dedup to exactly one span


_trace_seq = itertools.count(1)   # cluster-wide span ids (0 = untraced)


@dataclasses.dataclass
class Reply:
    status: int = 0              # 0 ok, else -errno
    data: Any = None
    transno: int = 0
    last_committed: int = 0
    bulk: Any = None             # payload moved on the bulk portal
    bulk_nbytes: int = 0
    early_until: float = 0.0     # AT early reply: server-extended client
                                 # deadline (0 = no extension granted)
    pre_versions: Any = None     # VBR: [(key, version)] observed by this
                                 # update pre-op; the client pins them
                                 # into the retained request so a replay
                                 # can prove it still applies (§29 + VBR)


class RpcError(Exception):
    def __init__(self, status: int, msg: str = ""):
        super().__init__(f"rpc error {status} {msg}")
        self.status = status


class TimeoutError_(Exception):
    pass


# ----------------------------------------------------------------- export

@dataclasses.dataclass
class Export:
    """Server-resident per-client state (§4.6.5). `last_rcvd` slot + reply
    cache survive server restart (they are journalled with the transaction
    they belong to — we keep the committed prefix only)."""
    client_uuid: str
    client_nid: str
    conn_generation: int = 1
    boot_count: int = 0
    last_xid: int = 0
    # committed reply cache: xid -> Reply (persistent)
    reply_cache: dict = dataclasses.field(default_factory=dict)
    # uncommitted portion (lost on crash)
    volatile_replies: dict = dataclasses.field(default_factory=dict)
    data: dict = dataclasses.field(default_factory=dict)  # per-svc (opens..)
    last_ping: float = 0.0       # any RPC refreshes it; the server-side
                                 # pinger back-stop evicts exports whose
                                 # age exceeds ping_evict_age (§4.4.2.5)


# ---------------------------------------------------------------- service

class Service:
    """Request-processing service for one target (ch. 22-23).

    The seed's ad-hoc service loop (portals event -> handler, strictly in
    arrival order) is extracted here and given a pluggable Network Request
    Scheduler: the policy decides the virtual instant the service thread
    picks a request up, then the handler runs and the reply departs no
    earlier than start + service cost.  Costs model per-request CPU plus
    per-niobuf overhead so vectored BRW requests are weighted fairly.
    """

    def __init__(self, target: "Target", policy: str = "fifo",
                 cpu_cost: float = 5e-6, seek_cost: float = 4e-5,
                 page_cost: float = 5e-7, **params):
        self.target = target
        self.sim = target.sim
        self.cpu_cost = cpu_cost
        self.seek_cost = seek_cost     # per discontiguous niobuf run
        self.page_cost = page_cost     # per 4 KiB page transferred
        self.policy: nrs_mod.NrsPolicy = nrs_mod.make_policy(
            policy, self.sim, **params)

    def set_policy(self, name: str, **params):
        """Switch the NRS policy at runtime (lctl nrs ...); accounting
        restarts with the new policy."""
        self.policy = nrs_mod.make_policy(name, self.sim, **params)
        return self.policy

    @staticmethod
    def _nio_len(n: dict) -> int:
        d = n.get("data")
        return len(d) if d is not None else n.get("length", 0)

    def cost_parts(self, req: Request) -> tuple[float, int, int]:
        """Seek-aware scatter/gather service cost (§4.5.6): a *contiguous*
        run of niobufs is one disk seek plus per-page transfer, every
        discontiguity charges another seek — so NRS scheduling (and the
        benchmarks) see a scattered vector's true weight, not a flat
        per-niobuf constant. Returns (cost, seeks, payload_bytes) so the
        span recorded for this request carries its true disk weight."""
        nio = req.body.get("niobufs")
        if not isinstance(nio, (list, tuple)) or not nio:
            if "data" in req.body or "length" in req.body:
                # legacy single-extent BRW: one run
                ln = self._nio_len(req.body)
                pages = max(1, (ln + PAGE_SIZE - 1) // PAGE_SIZE)
                return (self.cpu_cost + self.seek_cost +
                        self.page_cost * pages, 1, ln)
            return self.cpu_cost, 0, 0
        runs, pages, nbytes, prev_end = 0, 0, 0, None
        for n in sorted(nio, key=lambda n: n.get("offset", 0)):
            ln = self._nio_len(n)
            nbytes += ln
            pages += max(1, (ln + PAGE_SIZE - 1) // PAGE_SIZE)
            off = n.get("offset", 0)
            if prev_end is None or off != prev_end:
                runs += 1              # discontiguity: the head seeks
            prev_end = off + ln
        self.sim.stats.count("nrs.seeks", runs)
        return (self.cpu_cost + self.seek_cost * runs +
                self.page_cost * pages, runs, nbytes)

    def request_cost(self, req: Request) -> float:
        return self.cost_parts(req)[0]

    def process(self, req: Request, arrival: float) -> Reply:
        cost, seeks, nio_bytes = self.cost_parts(req)
        start = self.policy.schedule(req, arrival, cost)
        self.sim.clock.advance_to(start)
        reply = self.target.handle(req)
        # the reply departs no earlier than the scheduled completion
        # (handlers issuing nested RPCs may already be later than this)
        self.sim.clock.advance_to(start + cost)
        if req.deadline and self.target.at_enabled \
                and self.sim.now + EARLY_REPLY_MARGIN > req.deadline:
            # AT early reply (ch. 11): queueing/service overran (or is
            # about to overrun) the client's deadline — extend it past
            # our completion plus the observed request transit, so the
            # reply's symmetric trip home still lands inside the grant
            fail_mod.maybe_fail("ptl.early_reply")
            net = max(0.0, arrival - req.sent_at) if req.sent_at else 0.0
            reply.early_until = max(reply.early_until,
                                    self.sim.now + net
                                    + EARLY_REPLY_MARGIN)
            self.sim.stats.count("rpc.early_reply")
        if req.trace_id and req.opcode not in nrs_mod.CONTROL_OPS \
                and reply.status not in (-11, -108, -107):
            # one span per traced RPC (ch. 35 observability): the registry
            # dedups on trace_id, so resends / replays / reply-cache-served
            # retries of this request never produce a second sample; the
            # excluded statuses are recovery gates the client retries
            # through — the span belongs to the attempt that executes
            self.sim.metrics.record_span(
                target=self.target.uuid, op=req.opcode,
                export=req.client_uuid, jobid=req.jobid,
                queue_wait=start - arrival, service=cost, seeks=seeks,
                nbytes=nio_bytes + req.bulk_nbytes + reply.bulk_nbytes,
                trace_id=req.trace_id)
        return reply


# ----------------------------------------------------------------- target

class Target:
    """A service target: handler table + transaction/undo machinery.

    Subclasses (OST, MDS, DLM namespace holder) register ops in self.ops and
    call `self.txn(undo_fn)` inside update handlers.
    """

    svc_kind = "ost"             # request portal selector

    def __init__(self, uuid: str, node: "Node"):
        self.uuid = uuid
        self.node = node
        self.sim = node.sim
        self.ops: dict[str, Callable] = {}
        self.exports: dict[str, Export] = {}
        self.transno = 0
        self.committed_transno = 0
        self.undo_log: list[tuple[int, Callable]] = []
        self.commit_interval = 64          # ops between lazy commits
        self._ops_since_commit = 0
        self.boot_count = 1
        self.recovering = False
        self.recovery_deadline = 0.0
        self._recov_pending: set = set()
        self.commit_callbacks: list[Callable[[int], None]] = []
        self.evicted: set = set()
        # ---- recovery-robustness knobs (ISSUE-10) ----
        self.at_enabled = True             # server grants early replies
        self.recovery_per_client = 0.1     # window scales with exports
        self.recovery_window_max = 30.0
        self.ping_evict_age = 0.0          # 0 = server pinger backstop off
        self._next_stale_scan = 0.0
        # VBR (§29 + Lustre 1.8 version-based recovery): object key ->
        # mutation history as a list of transnos (last entry = current
        # version). Histories are pruned with the journal: a crash drops
        # entries above committed_transno, a consistent-cut rollback
        # drops entries above the cut.
        self.versions: dict[Any, list[int]] = {}
        self._replay_tno = 0               # replay reuses its original
                                           # transno (keeps the version
                                           # namespace crash-aligned)
        self.service = Service(self)
        self.ops["connect"] = self.op_connect
        self.ops["disconnect"] = self.op_disconnect
        self.ops["ping"] = self.op_ping
        self.ops["mon_collect"] = self.op_mon_collect
        self.ops["recovery_close"] = self.op_recovery_close
        node.register_target(self)

    # ------------------------------------------------------------- wiring
    def export_for(self, client_uuid: str, client_nid: str) -> Export:
        exp = self.exports.get(client_uuid)
        if exp is None:
            exp = Export(client_uuid, client_nid)
            self.exports[client_uuid] = exp
        return exp

    # -------------------------------------------------------------- txns
    def txn(self, undo: Callable[[], None]) -> int:
        """Open+record a transaction; returns its transno."""
        if self._replay_tno:
            # replay reuses the original transno (§29.2): VBR pre-op
            # versions reference transnos, so re-execution must not
            # renumber history or the next replay's match breaks.  The
            # counter itself never regresses: post-restart transnos live
            # in a fresh boot epoch above every number the crash lost
            tno = self._replay_tno
            self._replay_tno = 0           # only the op's first txn
            self.transno = max(self.transno, tno)
        else:
            self.transno += 1
            tno = self.transno
        self.undo_log.append((tno, undo))
        # deferred crash site ({mds,ost}.txn): the induced crash lands at
        # this target's request boundary — transaction atomicity
        fail_mod.note(f"{self.svc_kind}.txn")
        self._ops_since_commit += 1
        if self._ops_since_commit >= self.commit_interval:
            self.commit()
        return tno

    def commit(self):
        """Flush journal: everything up to `transno` becomes persistent."""
        fail_mod.maybe_fail(f"{self.svc_kind}.commit.before")
        self.committed_transno = self.transno
        self.undo_log.clear()
        self._ops_since_commit = 0
        for exp in self.exports.values():
            exp.reply_cache.update(exp.volatile_replies)
            exp.volatile_replies.clear()
            # bound the cache: a client only ever resends its last window
            if len(exp.reply_cache) > 512:
                for k in sorted(exp.reply_cache)[:-256]:
                    del exp.reply_cache[k]
        for cb in self.commit_callbacks:
            cb(self.committed_transno)
        self.sim.stats.count(f"{self.uuid}.commit")
        # "commit durable, reply lost": deferred to the request boundary,
        # AFTER the reply landed in the journaled reply cache — real
        # Lustre writes the last_rcvd reply slot inside the transaction,
        # so a resend after this crash is answered from the cache
        fail_mod.note(f"{self.svc_kind}.commit.after")

    def crash(self):
        """Lose uncommitted state: run undo records in reverse (§6.7.6.3
        'metadata undo log records')."""
        for transno, undo in reversed(self.undo_log):
            undo()
        # executions above the cut died with the journal: their replay
        # is legitimate re-execution, not an exactly-once violation
        sanitize.state.note_crash(self.uuid, self.committed_transno)
        self.transno = self.committed_transno
        self.undo_log.clear()
        self._ops_since_commit = 0
        self.vbr_prune(self.committed_transno)
        for exp in self.exports.values():
            exp.volatile_replies.clear()

    def restart(self):
        self.boot_count += 1
        # VBR keys versions by transno, so transnos must stay monotone
        # ACROSS reboots: a post-restart op reusing a number the crash
        # lost would collide with pinned replay transnos and poison the
        # version store (false conflicts on late replay).  Real servers
        # keep a per-boot epoch in the transno high bits; jump epochs
        self.transno = (self.transno // TRANSNO_EPOCH + 1) * TRANSNO_EPOCH
        # all live connections died with the node: clients must reconnect
        # (stale-generation requests bounce with -108 below)
        for exp in self.exports.values():
            exp.conn_generation += 1
        if self.exports:
            self.recovering = True
            self._recov_pending = set(self.exports)
            # window scaled to the client count (ch. 11): every export
            # needs a chance to reconnect+replay, but VBR means missing
            # the window is survivable, so the cap stays tight
            window = min(self.recovery_window_max,
                         2 * DEFAULT_TIMEOUT
                         + self.recovery_per_client * len(self.exports))
            self.recovery_deadline = self.sim.now + window
        self.on_restart()

    def on_restart(self):
        pass

    def finish_recovery(self):
        self.recovering = False

    def close_recovery(self):
        """Close the recovery window (§29.3 + VBR).  Unlike the pre-VBR
        scheme, stragglers are NOT blanket-evicted here: a client that
        reconnects after the close gets its replays version-checked like
        anyone else (delayed recovery) and is only evicted if a replay
        genuinely conflicts with the gap it left."""
        if not self.recovering:
            return
        if self.svc_kind == "mds":
            fail_mod.maybe_fail("mds.recovery_window")
        if self._recov_pending:
            self.sim.stats.count("rpc.recovery_stragglers",
                                 len(self._recov_pending))
        self._recov_pending = set()
        self.finish_recovery()

    def op_recovery_close(self, req: Request) -> Reply:
        """lctl abort_recovery analogue: the consistent-cut machinery (or
        an admin) closes the window early once every returning client has
        replayed — new requests unblock without waiting out the clock."""
        self.close_recovery()
        return Reply(data={"recovering": self.recovering})

    # ------------------------------------------------------ VBR versions
    def vbr_keys_for(self, req: Request) -> list:
        """Subclass hook: the object keys this update mutates (inode fids
        on the MDS, (group, oid) objects on the OST). Empty = the op is
        not version-tracked."""
        return []

    def version_of(self, key) -> int:
        hist = self.versions.get(key)
        return hist[-1] if hist else 0

    def vbr_prune(self, cut: int):
        """Drop version history above `cut` (crash / consistent-cut
        rollback): those mutations were undone with the journal tail."""
        if not self.versions:
            return
        for key in list(self.versions):
            hist = [t for t in self.versions[key] if t <= cut]
            if hist:
                self.versions[key] = hist
            else:
                del self.versions[key]

    def _vbr_admit(self, req: Request, exp: Export) -> Optional[Reply]:
        """Version-based replay admission: the replay shipped the pre-op
        versions it observed; if any tracked object has moved past them
        (a straggler's lost mutation was undone, or a later mutation
        already re-applied), re-executing would corrupt — evict THIS
        client, not every straggler."""
        vbr = req.body.get("_vbr")
        if not vbr:
            return None                    # pre-VBR request: admit as-is
        for key, ver in vbr:
            have = self.version_of(key)
            if have != ver:
                self.sim.stats.count("rpc.vbr_eviction")
                self.evict_client(req.client_uuid, reason="vbr",
                                  counted=True)
                return Reply(status=-107)
        self.sim.stats.count("rpc.vbr_admit")
        return None

    # --------------------------------------------------------- evictions
    def evict_client(self, uuid: str, reason: str = "admin",
                     counted: bool = False):
        """Evict one export, reclaiming what the server granted it: DLM
        locks through the existing ldlm eviction path, OST grant by
        zeroing the export's share."""
        if uuid in self.evicted or uuid not in self.exports:
            return
        if not counted:
            self.sim.stats.count(f"rpc.{reason}_eviction")
        self.evicted.add(uuid)
        exp = self.exports.get(uuid)
        if exp is not None:
            exp.data.pop("grant", None)
        ldlm = getattr(self, "ldlm", None)
        if ldlm is not None:
            ldlm.evict_client(uuid)
        self._recov_pending.discard(uuid)

    def _maybe_evict_stale(self, requester: str):
        """Server-side pinger back-stop (§4.4.2.5): exports whose last
        ping is older than ping_evict_age are dead — reclaim their locks
        and grant so the living stop waiting on them."""
        age = self.ping_evict_age
        if not age or self.sim.now < self._next_stale_scan:
            return
        self._next_stale_scan = self.sim.now + age / 4
        for uuid, exp in list(self.exports.items()):
            if uuid == requester or uuid in self.evicted:
                continue
            if exp.last_ping and self.sim.now - exp.last_ping > age:
                self.evict_client(uuid, reason="ping")

    # ------------------------------------------------------------ handler
    def handle(self, req: Request) -> Reply:
        st = self.sim.stats
        st.count(f"rpc.{self.svc_kind}.{req.opcode}")
        exp = self.export_for(req.client_uuid, "")
        exp.last_ping = self.sim.now       # any RPC is proof of life
        self._maybe_evict_stale(req.client_uuid)
        if req.client_uuid in self.evicted and req.opcode != "connect":
            return Reply(status=-107)      # ENOTCONN: evicted
        if (req.opcode not in ("connect", "disconnect", "ping")
                and not req.replay
                and req.conn_generation != exp.conn_generation):
            # connection died with a server reboot: force reconnect+replay
            return Reply(status=-108)
        # resend of an already-executed request? answer from reply cache.
        cached = exp.reply_cache.get(req.xid, exp.volatile_replies.get(req.xid))
        if cached is not None and not req.replay:
            st.count("rpc.reply_cache_hit")
            return cached
        if self.recovering and self.sim.now >= self.recovery_deadline:
            # window expired: close it — VBR version checks (not blanket
            # eviction) decide the fate of stragglers' later replays
            self.close_recovery()
        if self.recovering and req.opcode not in (
                "connect", "replay", "disconnect",
                "recovery_close") and not req.replay:
            # new requests are gated until the recovery window closes;
            # the reply tells the client how long is left so it backs
            # off sensibly instead of burning reconnect attempts
            return Reply(status=-11, data={
                "recovery_left": max(0.0, self.recovery_deadline
                                     - self.sim.now)})  # EAGAIN
        if req.replay:
            rej = self._vbr_admit(req, exp)
            if rej is not None:
                return rej
        fn = self.ops.get(req.opcode)
        if fn is None:
            return Reply(status=-38)       # ENOSYS
        keys = self.vbr_keys_for(req)
        pre = [(k, self.version_of(k)) for k in keys] if keys else None
        # the transno pin is scoped to THIS request: a replayed handler
        # may round-trip to a peer that synchronously calls back into us
        # (e.g. remote_nlink_adjust on a replayed create's parent), and
        # that nested txn must NOT consume the outer replay's number
        prev_pin = self._replay_tno
        self._replay_tno = req.transno if req.replay else 0
        try:
            reply = fn(req)
        except RpcError as e:
            reply = Reply(status=e.status)
        finally:
            self._replay_tno = prev_pin
        reply.last_committed = self.committed_transno
        if reply.transno:                   # update op: cache for resends
            if keys:
                for k in keys:
                    self.versions.setdefault(k, []).append(reply.transno)
                reply.pre_versions = pre
            sanitize.state.note_execute(self.uuid, req.client_uuid,
                                        req.xid, reply.transno)
            exp.volatile_replies[req.xid] = reply
            if reply.transno <= self.committed_transno:
                exp.reply_cache[req.xid] = reply
        exp.last_xid = max(exp.last_xid, req.xid)
        return reply

    # ------------------------------------------------- std ops: connect
    def op_connect(self, req: Request) -> Reply:
        exp = self.export_for(req.client_uuid, req.body.get("nid", ""))
        exp.conn_generation += 1
        exp.boot_count = req.boot_count
        self.evicted.discard(req.client_uuid)
        if self.recovering:
            self._recov_pending.discard(req.client_uuid)
            if not self._recov_pending \
                    or self.sim.now >= self.recovery_deadline:
                # every known client is back (or window expired): open
                # up. Stragglers are NOT evicted — VBR version checks
                # judge their replays if they ever return (§29.3 + VBR).
                self.close_recovery()
        return Reply(data={
            "boot_count": self.boot_count,
            "conn_generation": exp.conn_generation,
            "last_committed": self.committed_transno,
            "recovering": self.recovering,
        })

    def op_disconnect(self, req: Request) -> Reply:
        self.exports.pop(req.client_uuid, None)
        return Reply()

    def op_ping(self, req: Request) -> Reply:
        return Reply(data={"boot_count": self.boot_count})

    # ------------------------------------------------- std ops: monitor
    def mon_stats(self) -> dict:
        """Subclass hook: target-kind-specific sections of the monitoring
        snapshot (OST: grants/space, MDS: changelog/inodes, both: locks)."""
        return {}

    def op_mon_collect(self, req: Request) -> Reply:
        """One target's leaf of the cluster monitoring tree.  The reply
        payload is charged to the wire like any other (wire_size of the
        whole tree), so monitoring is a *cost-bearing* consumer the
        overhead gate can measure, not free introspection."""
        fail_mod.maybe_fail("mon.collect")
        data = {
            "uuid": self.uuid, "kind": self.svc_kind,
            "nid": self.node.nid, "boot_count": self.boot_count,
            "last_transno": self.transno,
            "last_committed": self.committed_transno,
            "recovering": self.recovering,
            "num_exports": len(self.exports),
            "nrs": self.service.policy.info(),
            "counters": dict(self.sim.stats.node_counters.get(self.uuid, {})),
            "latency": self.sim.metrics.target_summary(
                self.uuid, max_exports=req.body.get("max_exports", 32)),
        }
        data.update(self.mon_stats())
        return Reply(data=data)


# ------------------------------------------------------------------- node

class Node:
    """One machine: an NI + the targets and clients living on it."""

    def __init__(self, name: str, net: str, cluster: "ClusterBase"):
        self.name = name
        self.nid = f"{net}:{name}"
        self.cluster = cluster
        self.sim = cluster.sim
        self.ni = P.NI(self.nid, net, cluster.network)
        self.targets: dict[str, Target] = {}
        self.boot_count = 1
        cluster.nodes[self.name] = self
        self._post_request_buffers()

    def _post_request_buffers(self):
        """Pre-posted request buffers w/ receiver-managed offsets (§4.5.5).
        One MD per request portal; the EQ handler dispatches to targets."""
        for portal in set(REQUEST_PORTALS.values()) | {
                LDLM_CB_REQUEST_PORTAL}:
            eq = P.EventQueue(handler=self._request_in)
            md = P.MemoryDescriptor(length=1 << 30, threshold=-1,
                                    manage_remote_offset=True, eq=eq,
                                    user_ptr=portal)
            self.ni.me_attach(portal, 0, P.IGNORE_ALL, md)

    # --------------------------------------------------------- server in
    def _request_in(self, ev: P.Event):
        # service time starts at request arrival (the reply transmit below
        # then departs no earlier than this).
        self.sim.clock.advance_to(ev.arrival_time)
        req, reply_nid, reply_portal = ev.data
        target_uuid = req.body.get("_target", "")
        target = self.targets.get(target_uuid)
        if target is None:
            reply = Reply(status=-19)      # ENODEV
        else:
            fail = self.sim.fail
            fail.enter_service(target)
            # stats attribution context: every counter bumped while this
            # target serves the request lands in its per-node namespace
            # (nested server->server RPCs push the inner target on top)
            self.sim.stats.node_stack.append(target.uuid)
            try:
                fail.maybe_fail(f"ptlrpc.{target.svc_kind}.request_in")
                reply = target.service.process(req, ev.arrival_time)
                fail.maybe_fail(f"ptlrpc.{target.svc_kind}.before_reply")
                fail.raise_if_pending(target)
            except fail_mod.FailLocDrop:
                # OBD_FAIL_*_NET-style action: the in-flight request is
                # lost on the wire — target stays up, no reply goes out,
                # the client recovers via timeout -> resend
                self.sim.stats.count("fail.drop")
                return
            except fail_mod.FailLocHit:
                # the armed OBD_FAIL site powers the serving target off at
                # this exact point: uncommitted state dies through the
                # undo log, the in-flight request is dropped (no reply) —
                # the client recovers via timeout -> reconnect -> replay
                self.sim.stats.count("fail.crash")
                target.crash()
                target.restart()
                return
            finally:
                self.sim.stats.node_stack.pop()
                fail.exit_service(target)
                # request-boundary invariants: grant conservation +
                # (periodically) counter-partition, see core/sanitize.py
                sanitize.state.request_boundary(target)
        # reply PUT matched on xid (paper §4.5.2)
        nbytes = wire_size(reply) + reply.bulk_nbytes
        self.ni.put(reply_nid, reply_portal, req.xid, reply, nbytes)

    def register_target(self, t: Target):
        self.targets[t.uuid] = t

    # ----------------------------------------------------------- up/down
    def fail(self):
        """Power the node off: drop traffic + lose uncommitted state of
        the targets THIS node serves (standby registrations of targets
        primary-served elsewhere keep their journals — shared storage).
        A served target immediately "restarts" (possibly on its standby
        node): new boot count -> clients detect the reboot and replay."""
        self.sim.faults.down_nids.add(self.nid)
        for t in self.targets.values():
            if t.node is self:
                t.crash()
                t.restart()

    def restart(self):
        self.sim.faults.down_nids.discard(self.nid)
        self.boot_count += 1
        # the targets already restarted at fail() time, but the node was
        # unreachable then: re-run their announce hooks now so peers get
        # the imperative-recovery nudge (the pinger's job in real Lustre)
        for t in self.targets.values():
            if t.node is self:
                t.on_restart()


class ClusterBase:
    """Holds the simulator + network; subclassed by core.cluster."""

    def __init__(self, seed: int = 0):
        self.sim = Simulator(seed)
        self.network = P.PortalsNetwork(self.sim)
        self.nodes: dict[str, Node] = {}


# ----------------------------------------------------------------- import

class Import:
    """Client-side stub for one target (§4.6.8) with recovery.

    `nids` is the failover list (primary first). Requests flow through
    `self.request()`; on timeout the import disconnects, pings/reconnects
    (walking the failover ring), replays and resends, then retries.
    """

    def __init__(self, client: "RpcClient", target_uuid: str,
                 nids: list[str], svc_kind: str):
        self.client = client
        self.target_uuid = target_uuid
        self.nids = list(nids)
        self.active_nid = nids[0]
        self.svc_kind = svc_kind
        self.sim = client.sim
        self.state = "NEW"                 # NEW|FULL|DISCONN|REPLAY
        self.server_boot_count = 0
        self.last_committed = 0
        self.replay_list: list[Request] = []   # sent, uncommitted updates
        self.inflight: Request | None = None
        self.timeout = DEFAULT_TIMEOUT     # fixed fallback (AT disabled)
        self.max_reconnects = 8
        cl = getattr(client.node, "cluster", None)
        self.at = AdaptiveTimeout(
            at_min=getattr(cl, "at_min", AT_MIN),
            at_max=getattr(cl, "at_max", AT_MAX),
            enabled=getattr(cl, "adaptive_timeouts", True))
        self.backoff_base = BACKOFF_BASE
        self.backoff_max = BACKOFF_MAX
        self.generation = 0
        self.connect_data: dict = {}
        # eviction observers: upper layers (OSC page cache, LockClient,
        # dentry cache, MDS peer cross-check) register here — after a
        # -107 every piece of state the server granted this import is
        # void and MUST be dropped, not just the replay queue
        self.evict_cbs: list[Callable[[], None]] = []
        # `lctl --device deactivate` analogue: an administratively-inactive
        # import fails fast with -19 (ENODEV) instead of paying the full
        # reconnect walk on every touch — the LOV marks a dead OST inactive
        # so raid5 degraded paths and the rebuilder skip it cheaply
        self.deactivated = False

    # ------------------------------------------------------------ wiring
    @property
    def request_portal(self) -> int:
        return REQUEST_PORTALS[self.svc_kind]

    @property
    def reply_portal(self) -> int:
        return REPLY_PORTALS[self.svc_kind]

    # --------------------------------------------------------------- rpc
    def rpc_timeout(self, opcode: str) -> float:
        """Per-op timeout: the AT estimate when adaptive, else fixed."""
        if self.at.enabled:
            return self.at.timeout_for(opcode)
        return self.timeout

    def _backoff(self, attempt: int):
        """Capped exponential backoff with deterministic jitter between
        reconnect attempts — N clients losing the same server no longer
        hammer it in lockstep, and the schedule is reproducible."""
        base = min(self.backoff_max, self.backoff_base * (2 ** attempt))
        h = zlib.crc32(f"{self.client.uuid}:{self.target_uuid}:"
                       f"{attempt}".encode())
        # jitter in [0.5, 1.0) * base, derived from stable identifiers
        self.sim.clock.advance(base * (0.5 + (h % 1024) / 2048.0))
        self.sim.stats.count("rpc.reconnect_backoff")

    def _send_once(self, req: Request,
                   timeout: float | None = None) -> Reply | None:
        """One wire attempt. None = timeout/drop.

        AT semantics (ch. 11): the request carries an absolute deadline;
        a reply that lands after it is a SPURIOUS TIMEOUT — dropped here
        exactly as if the wire ate it (the resend is answered from the
        reply cache) — unless the server granted an early reply
        extending the deadline past the arrival."""
        if timeout is None:
            timeout = self.rpc_timeout(req.opcode)
        t0 = self.sim.now
        req.sent_at = t0
        req.deadline = t0 + timeout
        eq = P.EventQueue()
        md = P.MemoryDescriptor(length=1 << 22, threshold=1, eq=eq)
        self.client.ni.me_attach(self.reply_portal, req.xid, 0, md)
        nbytes = wire_size(req) + req.bulk_nbytes
        t_arr = self.client.ni.put(self.active_nid, self.request_portal,
                                   req.xid, (req, self.client.nid,
                                             self.reply_portal), nbytes)
        if t_arr == float("inf") or not md.buffer:
            # request or reply lost: wait out the timeout (§4.4.2.3)
            self.sim.clock.advance(timeout)
            md.unlinked = True             # unlink ME/MD after timeout
            self.sim.stats.count("rpc.timeout")
            return None
        ev = eq.pop()
        _, reply = md.buffer[0]
        arrival = ev.arrival_time
        if arrival > req.deadline + 1e-12 \
                and reply.early_until + 1e-12 < arrival:
            # the reply exists but the client already gave up at the
            # deadline and no early reply extended it: a spurious
            # timeout — the loaded-server failure mode AT exists to kill
            md.unlinked = True
            self.sim.clock.advance_to(max(self.sim.now, req.deadline))
            self.sim.stats.count("rpc.timeout")
            self.sim.stats.count("rpc.timeout_spurious")
            return None
        if arrival > req.deadline + 1e-12:
            self.sim.stats.count("rpc.early_reply_rescue")
        self.sim.clock.advance_to(arrival)
        if self.at.enabled:
            self.at.observe(req.opcode, arrival - t0)
        return reply

    def request(self, opcode: str, body: dict, *, bulk_nbytes: int = 0,
                no_recover: bool = False, fixup=None) -> Reply:
        """Send a request with full recovery semantics; raises RpcError on
        application errors, TimeoutError_ if the target stays unreachable."""
        if self.deactivated:
            raise RpcError(-19, f"{self.target_uuid} deactivated")
        if self.state in ("NEW", "DISCONN"):
            self._connect_cycle()
        req = Request(opcode=opcode, body=dict(body, _target=self.target_uuid),
                      xid=self.client.next_xid(), client_uuid=self.client.uuid,
                      boot_count=self.client.boot_count,
                      conn_generation=self.generation,
                      bulk_nbytes=bulk_nbytes, jobid=self.client.jobid,
                      trace_id=next(_trace_seq))
        attempt = 0
        eagain_waited = 0.0
        while attempt < self.max_reconnects:
            reply = self._send_once(req)
            if reply is None:
                if no_recover:
                    raise TimeoutError_(f"{self.target_uuid} unreachable")
                attempt += 1
                self.state = "DISCONN"
                self._backoff(attempt - 1)
                self._connect_cycle()      # may replay + walk failover ring
                continue
            if reply.status == -11:        # EAGAIN: server in recovery
                # wait out what the server says is left of its window
                # (client-count-scaled windows outlive any fixed retry
                # budget); a separate time budget bounds the spin
                left = 0.5
                if isinstance(reply.data, dict):
                    left = max(0.05, min(0.5,
                                         reply.data.get(
                                             "recovery_left", 0.5)))
                eagain_waited += left
                if eagain_waited > 4 * 60.0:
                    raise TimeoutError_(
                        f"{self.target_uuid} stuck in recovery")
                self.sim.clock.advance(left)
                continue
            if reply.status == -108:       # stale connection: server reboot
                attempt += 1
                self.state = "DISCONN"
                self._connect_cycle()
                req.body["_target"] = self.target_uuid
                req.conn_generation = self.generation
                continue
            if reply.status == -107:       # evicted: state is gone — drop
                # replay queue, reconnect fresh, retry (client-visible data
                # loss is the eviction's documented cost)
                attempt += 1
                self.sim.stats.count("rpc.evicted_reconnect")
                self.replay_list.clear()
                self.state = "DISCONN"
                self.server_boot_count = 0
                self._connect_cycle()
                req.conn_generation = self.generation
                # server-granted state died with the export: locks, dirty
                # extents, clean pages, dentries — observers drop it all
                # (and the MDS peer cross-check repairs namespace halves)
                for cb in list(self.evict_cbs):
                    cb()
                continue
            self._note_reply(req, reply)
            if reply.status:
                raise RpcError(reply.status, opcode)
            if fixup is not None:
                # let the caller pin server-assigned ids (oid/fid) into the
                # retained request so REPLAY recreates identical objects
                # (the paper's create-with-requested-id, §5.2.3)
                fixup(req, reply)
            return reply
        raise TimeoutError_(f"{self.target_uuid} unreachable")

    def _note_reply(self, req: Request, reply: Reply):
        self.last_committed = max(self.last_committed, reply.last_committed)
        if reply.transno:
            req.transno = reply.transno
            if reply.pre_versions is not None:
                # VBR: retain the observed pre-op versions with the
                # request — a later replay ships them as its proof that
                # re-execution still applies to the same state
                req.body["_vbr"] = reply.pre_versions
            self.replay_list.append(req)
        # prune replay list: server committed these (§29: last_committed)
        self.replay_list = [r for r in self.replay_list
                            if r.transno > self.last_committed]

    # ---------------------------------------------------------- recovery
    def _connect_cycle(self, max_attempts: int | None = None):
        """Reconnect, walking the failover nid ring with capped
        exponential backoff between attempts (no more N flat timeout
        spins in lockstep); on a server reboot, replay
        committed-but-lost transactions then mark FULL."""
        last_err = None
        n = self.max_reconnects if max_attempts is None else max_attempts
        for attempt in range(n):
            if attempt:
                self._backoff(attempt - 1)
            nid = self.nids[attempt % len(self.nids)]
            self.active_nid = nid
            creq = Request(opcode="connect",
                           body={"_target": self.target_uuid,
                                 "nid": self.client.nid},
                           xid=self.client.next_xid(),
                           client_uuid=self.client.uuid,
                           boot_count=self.client.boot_count)
            reply = self._send_once(creq)
            if reply is None or reply.status:
                last_err = reply
                continue
            self.generation = reply.data["conn_generation"]
            self.connect_data = dict(reply.data)
            new_boot = reply.data["boot_count"]
            rebooted = (self.server_boot_count
                        and new_boot != self.server_boot_count)
            self.server_boot_count = new_boot
            if rebooted:
                self.sim.stats.count("rpc.server_reboot_detected")
                self._replay(reply.data["last_committed"])
            self.state = "FULL"
            return
        self.state = "DISCONN"
        raise TimeoutError_(
            f"connect {self.target_uuid} failed: {last_err}")

    def _replay(self, server_last_committed: int):
        """Replay transactions the server lost, oldest first (§29.2)."""
        self.state = "REPLAY"
        todo = sorted((r for r in self.replay_list
                       if r.transno > server_last_committed),
                      key=lambda r: r.transno)
        self.replay_list = []
        evicted = False
        for req in todo:
            req.replay = True
            req.conn_generation = self.generation
            self.sim.stats.count("rpc.replay")
            reply = self._send_once(req)
            if reply is None:
                # server vanished mid-replay: keep for the next cycle
                self.replay_list.append(req)
            elif reply.status == -107:
                # VBR conflict: a straggler's gap invalidated this
                # replay — the whole import's server-side state is gone,
                # stop replaying and let the next request's -107 path
                # run the full eviction cleanup (evict_cbs etc.)
                self.sim.stats.count("rpc.replay_vbr_rejected")
                self.replay_list.clear()
                evicted = True
                break
            elif reply.transno:
                req.transno = reply.transno
                self.replay_list.append(req)
        self.state = "FULL"
        return not evicted

    def ping(self) -> bool:
        """Health probe (§4.4.2.5).  Works even on a deactivated import —
        the pinger is precisely how a dead target's RETURN gets noticed —
        and never walks the full reconnect ladder (one probe per tick).
        A reply carrying a new server boot count triggers IMPERATIVE
        RECOVERY: reconnect + replay right now, instead of discovering
        the reboot via the next request's timeout."""
        if self.state != "FULL":
            if fail_mod.state.check("ping.notify") in ("drop", "crash"):
                return False       # notification lost: stay down a tick
            prev_boot = self.server_boot_count
            try:
                self._connect_cycle(max_attempts=1)
            except TimeoutError_:
                return False
            if prev_boot and self.server_boot_count != prev_boot:
                # the pinger (not a timed-out request) found the reboot
                self.sim.stats.count("rpc.imperative_recovery")
            return True
        req = Request(opcode="ping",
                      body={"_target": self.target_uuid},
                      xid=self.client.next_xid(),
                      client_uuid=self.client.uuid,
                      boot_count=self.client.boot_count,
                      conn_generation=self.generation)
        reply = self._send_once(req)
        if reply is None or reply.status:
            self.state = "DISCONN"
            return False
        boot = (reply.data or {}).get("boot_count", 0)
        if boot and self.server_boot_count \
                and boot != self.server_boot_count:
            act = fail_mod.state.check("ping.notify")
            if act in ("drop", "crash"):
                # notification lost: the client falls back to the
                # timeout-driven path on its next real request
                return True
            self.sim.stats.count("rpc.imperative_recovery")
            self.state = "DISCONN"
            try:
                self._connect_cycle()
            except TimeoutError_:
                return False
        return True


class RpcClient:
    """Client networking context: uuid + NI + xid sequence (§4.6.7)."""

    _uuid_seq = itertools.count()

    def __init__(self, node: Node):
        self.node = node
        self.ni = node.ni
        self.nid = node.nid
        self.network = node.cluster.network
        self.sim = node.sim
        self.uuid = f"client-{node.name}-{next(self._uuid_seq)}"
        self.jobid = ""              # stamped into every Request (the
                                     # JOBENV tag of real Lustre clients)
        self.boot_count = 1
        self._xid = itertools.count(1)
        self.imports: dict[str, Import] = {}

    def next_xid(self) -> int:
        # unique per client; never reused, even across recovery (§4.4.2.3)
        return next(self._xid)

    def import_target(self, target_uuid: str, nids: list[str],
                      svc_kind: str) -> Import:
        imp = Import(self, target_uuid, nids, svc_kind)
        self.imports[target_uuid] = imp
        return imp
