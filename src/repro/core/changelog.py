"""Per-MDT changelog: a persistent stream of metadata activity.

Layered on the llog machinery (paper ch. 8) exactly like the unlink log:
every namespace update the MDS executes appends one typed record to a
per-MDT `LlogCatalog` *inside the same transaction/undo scope as the
operation itself* — a crashed (rolled-back) reint retracts its record, a
replayed reint re-emits it, so consumers see each committed operation
exactly once.

The consumer model follows Doreau's *Distributed Lustre activity
tracking* (arXiv:1505.02656) and the Robinhood policy engine it feeds:

  * recording is active only while at least one consumer is registered
    (``changelog_register`` -> "cl1", "cl2", ...);
  * each consumer owns a persistent *bookmark* — the highest record index
    it has acknowledged via ``changelog_clear``;
  * records are purged from the catalog only past the MINIMUM bookmark
    across all registered consumers: a slow auditor pins the stream, a
    fast one never destroys data someone else still needs;
  * ``changelog_read(user, since_idx)`` returns retained records above an
    index, so multiple independent consumers (HSM, audit, mirror) tail
    the same stream;
  * a record handed to a consumer must be durable: the MDS commits its
    journal before serving (or purging) an uncommitted tail, so a
    single-MDT crash can never roll back a record a consumer has seen.
    One documented exception remains: the multi-MDT consistent-cut
    rollback (recovery.py §6.7.6.3) undoes *committed* cross-MDT
    transactions whose peer half was lost, retracting their records —
    a consumer that read past the cluster-committed cut must rescan
    (ROADMAP follow-up; real DNE changelogs share this exposure).

Records carry (fid, parent fid, name, timestamp, client uuid, jobid) so
audit tooling (arXiv:2302.14824) can answer "who did what, where, when,
and for which batch job" — the jobid is the same tag the TBF NRS policy
classifies on (core.nrs), threaded through `ptlrpc.Request`.
"""
from __future__ import annotations

import dataclasses
import itertools

from repro.core import llog as llog_mod

# Record types (the CL_* subset our MDS emits).
CL_CREAT = "CREAT"        # regular file create
CL_MKDIR = "MKDIR"
CL_SYMLINK = "SYMLINK"
CL_UNLINK = "UNLINK"
CL_RMDIR = "RMDIR"
CL_RENAME = "RENAME"
CL_LINK = "LINK"
CL_SETATTR = "SETATTR"
CL_CLOSE = "CLOSE"

CL_TYPES = (CL_CREAT, CL_MKDIR, CL_SYMLINK, CL_UNLINK, CL_RMDIR,
            CL_RENAME, CL_LINK, CL_SETATTR, CL_CLOSE)


@dataclasses.dataclass
class ChangelogRecord:
    idx: int                  # per-MDT, strictly increasing (gaps allowed:
                              # a rolled-back record's index is not reused)
    cl_type: str
    fid: tuple | None         # inode the operation applied to
    pfid: tuple | None        # parent directory (name-bearing ops)
    name: str                 # entry name under pfid ("" for inode ops)
    time: float               # virtual timestamp (merge key across MDTs)
    client: str               # originating client uuid
    jobid: str                # batch-job tag (see core.nrs TBF rules)
    extra: dict = dataclasses.field(default_factory=dict)
    transno: int = 0          # owning transaction (server-internal: the
                              # MDS commits it before serving the record)

    def to_wire(self) -> dict:
        d = {"idx": self.idx, "type": self.cl_type, "fid": self.fid,
             "pfid": self.pfid, "name": self.name, "time": self.time,
             "client": self.client, "jobid": self.jobid}
        if self.extra:
            d["extra"] = dict(self.extra)
        return d


class Changelog:
    """One MDT's changelog catalog + consumer bookkeeping."""

    def __init__(self, owner_uuid: str):
        self.owner_uuid = owner_uuid
        self.catalog = llog_mod.LlogCatalog(f"{owner_uuid}-changelog")
        self.users: dict[str, int] = {}      # consumer id -> bookmark idx
        self._user_seq = itertools.count(1)
        self._idx = itertools.count(1)
        self.last_idx = 0
        self.purged_to = 0
        self._cookies: dict[int, int] = {}   # record idx -> llog cookie

    # --------------------------------------------------------- consumers
    @property
    def active(self) -> bool:
        """Recording is on only while someone is listening (the register
        RPC is what 'turns on' the changelog, as in real Lustre)."""
        return bool(self.users)

    def register(self) -> str:
        uid = f"cl{next(self._user_seq)}"
        # a new consumer can read everything still retained
        self.users[uid] = self.purged_to
        return uid

    def deregister(self, uid: str):
        if uid not in self.users:
            raise KeyError(uid)
        del self.users[uid]
        self._purge()

    # ------------------------------------------------------------ record
    def emit(self, cl_type: str, fid, *, pfid=None, name: str = "",
             time: float = 0.0, client: str = "", jobid: str = "",
             transno: int = 0, **extra) -> ChangelogRecord | None:
        """Append one record; returns None while no consumer is
        registered. The CALLER's transaction undo must call `retract`
        on the returned record so aborted operations leave no trace."""
        if not self.users:
            return None
        idx = next(self._idx)
        self.last_idx = idx
        rec = ChangelogRecord(idx, cl_type,
                              tuple(fid) if fid is not None else None,
                              tuple(pfid) if pfid is not None else None,
                              name, time, client, jobid, dict(extra),
                              transno)
        lrec = self.catalog.add("changelog", {"rec": rec})
        self._cookies[idx] = lrec.cookie
        return rec

    def retract(self, rec: ChangelogRecord | None):
        """Transaction rollback: remove an uncommitted record (no-op if it
        was already purged by a consumer that read past it)."""
        if rec is None:
            return
        cookie = self._cookies.pop(rec.idx, None)
        if cookie is not None:
            self.catalog.cancel([cookie])

    # ------------------------------------------------------------- read
    def records(self) -> list[ChangelogRecord]:
        # already idx-ordered: records only ever append to the current
        # plain log, and cancellation never reorders survivors
        return [r.payload["rec"] for r in self.catalog.pending()]

    def read(self, since_idx: int = 0, count: int = 0) \
            -> list[ChangelogRecord]:
        recs = [r for r in self.records() if r.idx > since_idx]
        return recs[:count] if count else recs

    def clear(self, uid: str, up_to: int):
        """Acknowledge records up to `up_to` for one consumer; physically
        purge only past the minimum bookmark across ALL consumers."""
        if uid not in self.users:
            raise KeyError(uid)
        self.users[uid] = max(self.users[uid], min(up_to, self.last_idx))
        self._purge()

    def _purge(self):
        keep_after = min(self.users.values()) if self.users else self.last_idx
        doomed = []
        for rec in self.records():
            if rec.idx <= keep_after:
                cookie = self._cookies.pop(rec.idx, None)
                if cookie is not None:
                    doomed.append(cookie)
        if doomed:
            self.catalog.cancel(doomed)
        self.purged_to = max(self.purged_to, keep_after)

    # ------------------------------------------------------------ procfs
    def info(self) -> dict:
        return {"active": self.active,
                "users": dict(self.users),
                "records": len(self.catalog.pending()),
                "last_idx": self.last_idx,
                "purged_to": self.purged_to,
                "plain_logs": len(self.catalog.logs)}
