"""Per-MDT changelog: a persistent stream of metadata activity.

Layered on the llog machinery (paper ch. 8) exactly like the unlink log:
every namespace update the MDS executes appends one typed record to a
per-MDT `LlogCatalog` *inside the same transaction/undo scope as the
operation itself* — a crashed (rolled-back) reint retracts its record, a
replayed reint re-emits it, so consumers see each committed operation
exactly once.

The consumer model follows Doreau's *Distributed Lustre activity
tracking* (arXiv:1505.02656) and the Robinhood policy engine it feeds:

  * recording is active only while at least one consumer is registered
    (``changelog_register`` -> "cl1", "cl2", ...);
  * each consumer owns a persistent *bookmark* — the highest record index
    it has acknowledged via ``changelog_clear``.  Bookmarks are
    **journaled with the catalog header**: register/clear/deregister run
    as transactions whose undo restores the previous header state, so a
    crash mid-clear rolls bookmark AND purge back together (never one
    without the other), and a committed clear survives MDS restart —
    the consumer resumes at its journaled bookmark with no re-delivery
    of cleared records;
  * records are purged from the catalog only past the MINIMUM bookmark
    across all registered consumers: a slow auditor pins the stream, a
    fast one never destroys data someone else still needs;
  * **changelog_gc**: a consumer that stays idle past a configurable
    record lag (``gc_max_idle_indexes``) or virtual-time lag
    (``gc_max_idle_time``) is garbage-collected — deregistered by the
    MDS — so a dead consumer cannot pin the stream forever (real Lustre
    grew the same knobs);
  * ``changelog_read(user, since_idx)`` returns retained records above an
    index, so multiple independent consumers (HSM, audit, mirror) tail
    the same stream;
  * a record handed to a consumer must be durable — not just locally
    (journal commit before serving an uncommitted tail) but *cluster*
    durable: the MDS serves only records at or below the cluster-committed
    consistent cut (mds._gate_at_cluster_cut), so not even a multi-MDT
    consistent-cut rollback (recovery.py §6.7.6.3) can retract a record
    a consumer has seen.

Records carry (fid, parent fid, name, timestamp, client uuid, jobid) so
audit tooling (arXiv:2302.14824) can answer "who did what, where, when,
and for which batch job" — the jobid is the same tag the TBF NRS policy
classifies on (core.nrs), threaded through `ptlrpc.Request`.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Optional

from repro.core import fail as fail_mod
from repro.core import llog as llog_mod

# Record types (the CL_* subset our MDS emits).
CL_CREAT = "CREAT"        # regular file create
CL_MKDIR = "MKDIR"
CL_SYMLINK = "SYMLINK"
CL_UNLINK = "UNLINK"
CL_RMDIR = "RMDIR"
CL_RENAME = "RENAME"
CL_LINK = "LINK"
CL_SETATTR = "SETATTR"
CL_CLOSE = "CLOSE"

CL_TYPES = (CL_CREAT, CL_MKDIR, CL_SYMLINK, CL_UNLINK, CL_RMDIR,
            CL_RENAME, CL_LINK, CL_SETATTR, CL_CLOSE)


@dataclasses.dataclass
class ChangelogRecord:
    idx: int                  # per-MDT, strictly increasing (gaps allowed:
                              # a rolled-back record's index is not reused)
    cl_type: str
    fid: tuple | None         # inode the operation applied to
    pfid: tuple | None        # parent directory (name-bearing ops)
    name: str                 # entry name under pfid ("" for inode ops)
    time: float               # virtual timestamp (merge key across MDTs)
    client: str               # originating client uuid
    jobid: str                # batch-job tag (see core.nrs TBF rules)
    extra: dict = dataclasses.field(default_factory=dict)
    transno: int = 0          # owning transaction (server-internal: the
                              # MDS commits it before serving the record)

    def to_wire(self) -> dict:
        d = {"idx": self.idx, "type": self.cl_type, "fid": self.fid,
             "pfid": self.pfid, "name": self.name, "time": self.time,
             "client": self.client, "jobid": self.jobid}
        if self.extra:
            d["extra"] = dict(self.extra)
        return d


class Changelog:
    """One MDT's changelog catalog + journaled consumer header.

    `txn` is the owning target's transaction hook (undo registration):
    consumer-header updates (register/clear/deregister) go through it so
    they are crash-atomic with the purge they imply. `now` supplies the
    virtual time used for per-consumer idle tracking (changelog_gc).
    """

    def __init__(self, owner_uuid: str,
                 txn: Optional[Callable] = None,
                 now: Optional[Callable[[], float]] = None):
        self.owner_uuid = owner_uuid
        self.catalog = llog_mod.LlogCatalog(f"{owner_uuid}-changelog")
        self.users: dict[str, int] = {}      # consumer id -> bookmark idx
        self.user_time: dict[str, float] = {}    # id -> last activity
        self._user_seq = itertools.count(1)
        self._idx = itertools.count(1)
        self.last_idx = 0
        self.purged_to = 0
        self._cookies: dict[int, int] = {}   # record idx -> llog cookie
        self._txn = txn or (lambda undo: 0)
        self._now = now or (lambda: 0.0)
        # changelog_gc knobs (None = off); surfaced through lctl/procfs
        self.gc_max_idle_indexes: int | None = None
        self.gc_max_idle_time: float | None = None
        self.gc_collected: list[str] = []

    # --------------------------------------------------------- consumers
    @property
    def active(self) -> bool:
        """Recording is on only while someone is listening (the register
        RPC is what 'turns on' the changelog, as in real Lustre)."""
        return bool(self.users)

    def touch(self, uid: str):
        self.user_time[uid] = self._now()

    def register(self) -> str:
        uid = f"cl{next(self._user_seq)}"
        # a new consumer can read everything still retained; the header
        # update is a transaction so a crash before commit forgets the
        # consumer instead of resurrecting half of one
        self.users[uid] = self.purged_to
        self.touch(uid)

        def undo():
            self.users.pop(uid, None)
            self.user_time.pop(uid, None)
        self._txn(undo)
        return uid

    def deregister(self, uid: str):
        if uid not in self.users:
            raise KeyError(uid)
        bookmark = self.users.pop(uid)
        last_t = self.user_time.pop(uid, 0.0)
        restore_purge = self._purge()

        def undo():
            restore_purge()
            self.users[uid] = bookmark
            self.user_time[uid] = last_t
        self._txn(undo)

    # -------------------------------------------------------------- gc
    def maybe_gc(self):
        """Run the idle sweep iff any knob is set. Callers that stamp an
        owning transno into the next record must run this BEFORE
        computing it — each collected consumer's deregister is its own
        header transaction and consumes a transno."""
        if self.gc_max_idle_indexes is not None \
                or self.gc_max_idle_time is not None:
            self.gc()

    def gc(self) -> list[str]:
        """Garbage-collect idle consumers: a bookmark lagging more than
        `gc_max_idle_indexes` records behind the head, or a consumer
        silent for longer than `gc_max_idle_time` virtual seconds, is
        deregistered (its pin on the stream released). Returns the ids
        collected by this pass."""
        now = self._now()
        doomed = []
        for uid, bookmark in self.users.items():
            if (self.gc_max_idle_indexes is not None
                    and self.last_idx - bookmark > self.gc_max_idle_indexes):
                doomed.append(uid)
            elif (self.gc_max_idle_time is not None
                    and now - self.user_time.get(uid, 0.0)
                    > self.gc_max_idle_time):
                doomed.append(uid)
        for uid in doomed:
            self.deregister(uid)
            # the collected-ids bookkeeping rolls back with the
            # deregister: a crash must not report a still-registered
            # consumer as collected
            self.gc_collected.append(uid)

            def undo(uid=uid):
                if uid in self.gc_collected:
                    self.gc_collected.remove(uid)
            self._txn(undo)
        return doomed

    # ------------------------------------------------------------ record
    def emit(self, cl_type: str, fid, *, pfid=None, name: str = "",
             time: float = 0.0, client: str = "", jobid: str = "",
             transno: int = 0, **extra) -> ChangelogRecord | None:
        """Append one record; returns None while no consumer is
        registered. The CALLER's transaction undo must call `retract`
        on the returned record so aborted operations leave no trace.
        (The caller also runs `maybe_gc` first — see mds._cl — so the
        record's owning transno is computed after any GC transactions.)"""
        if not self.users:
            return None
        idx = next(self._idx)
        self.last_idx = idx
        rec = ChangelogRecord(idx, cl_type,
                              tuple(fid) if fid is not None else None,
                              tuple(pfid) if pfid is not None else None,
                              name, time, client, jobid, dict(extra),
                              transno)
        lrec = self.catalog.add("changelog", {"rec": rec})
        self._cookies[idx] = lrec.cookie
        fail_mod.note("mds.changelog.emit")
        return rec

    def retract(self, rec: ChangelogRecord | None):
        """Transaction rollback: remove an uncommitted record (no-op if it
        was already purged by a consumer that read past it)."""
        if rec is None:
            return
        cookie = self._cookies.pop(rec.idx, None)
        if cookie is not None:
            self.catalog.cancel([cookie])

    # ------------------------------------------------------------- read
    def records(self) -> list[ChangelogRecord]:
        # sorted by idx: appends keep order naturally, but a rolled-back
        # purge restores its records at the catalog tail
        return sorted((r.payload["rec"] for r in self.catalog.pending()),
                      key=lambda r: r.idx)

    def read(self, since_idx: int = 0, count: int = 0) \
            -> list[ChangelogRecord]:
        recs = [r for r in self.records() if r.idx > since_idx]
        return recs[:count] if count else recs

    def clear(self, uid: str, up_to: int):
        """Acknowledge records up to `up_to` for one consumer; physically
        purge only past the minimum bookmark across ALL consumers. The
        bookmark update and the purge are ONE transaction: its undo
        restores both, so a crash before the journal commit can never
        advance the bookmark while resurrecting the records (or vice
        versa)."""
        if uid not in self.users:
            raise KeyError(uid)
        old = self.users[uid]
        self.users[uid] = max(old, min(up_to, self.last_idx))
        self.touch(uid)
        restore_purge = self._purge()

        def undo():
            restore_purge()
            self.users[uid] = old
        self._txn(undo)

    def _purge(self) -> Callable[[], None]:
        """Purge past the min bookmark; returns the restore closure the
        caller journals as (part of) its transaction undo."""
        keep_after = min(self.users.values()) if self.users else self.last_idx
        doomed = [lrec for lrec in self.catalog.pending()
                  if lrec.payload["rec"].idx <= keep_after]
        removed_cookies = {}
        for lrec in doomed:
            idx = lrec.payload["rec"].idx
            removed_cookies[idx] = self._cookies.pop(idx, None)
        if doomed:
            self.catalog.cancel([lrec.cookie for lrec in doomed])
        old_purged = self.purged_to
        self.purged_to = max(self.purged_to, keep_after)

        def restore():
            self.purged_to = old_purged
            if doomed:
                self.catalog.restore(doomed)
            self._cookies.update({i: c for i, c in removed_cookies.items()
                                  if c is not None})
        return restore

    # ------------------------------------------------------------ procfs
    def info(self) -> dict:
        return {"active": self.active,
                "users": dict(self.users),
                "records": len(self.catalog.pending()),
                "last_idx": self.last_idx,
                "purged_to": self.purged_to,
                "plain_logs": len(self.catalog.logs),
                "gc": {"max_idle_indexes": self.gc_max_idle_indexes,
                       "max_idle_time": self.gc_max_idle_time,
                       "collected": list(self.gc_collected)}}
