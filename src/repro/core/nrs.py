"""Network Request Scheduler (NRS): pluggable per-target request ordering.

The paper's service loops (ch. 22-23) drain each request queue strictly
FIFO.  At scale that lets one aggressive client starve everyone sharing an
OST, so production Lustre grew an NRS framework between the request-in
event and the handler.  This module reproduces that layer for our
synchronous simulator.

Because the cluster runs synchronously with an analytic virtual clock,
policies do not physically reorder a queue; they decide *when in virtual
time* the service picks each request up.  `schedule(req, arrival, cost)`
returns the virtual start instant and advances the policy's internal
chains:

  * ``fifo`` — one busy chain: start = max(arrival, busy_until).  Exactly
    the seed service-loop behaviour.
  * ``crr``  — client round-robin via start-time fair queueing: one chain
    per client, each charged cost x n_active (every active client gets a
    1/n share), so a light client's latency is independent of a heavy
    client's backlog.
  * ``orr``  — object round-robin: the same fair chains keyed by
    (group, oid), modelling per-object batched ordering (disk-friendly
    grouping; requests to a cold object never wait behind a hot one).
  * ``orr_disk`` — disk-locality ORR: the ``orr`` chains plus a
    contiguity-aware charge — a BRW continuing exactly where the
    object's last one ended is batched with it (the seek component of
    the seek-aware cost model is refunded), so queues batch by on-disk
    contiguity, not just by object.
  * ``wfq``  — weighted fair queueing: the CRR chains with per-export
    weights (a weight-3 client gets 3x the share of a weight-1 client
    under contention); installed with
    ``lctl("nrs", uuid, "wfq", {"weights": {...}})``.
  * ``tbf``  — token bucket filter QoS: per-class buckets (class = the
    request's jobid when a ``rules`` entry matches it, else the client
    uuid) delay a request's start until a token is available, enforcing
    requests/sec rate limits per tenant or per batch job.
  * ``tbf_orr`` — two-level composition: TBF admission (rate limits for
    classes named in ``rules``; everyone else unlimited) feeding the
    ``orr_disk`` ordering — QoS and disk locality compose, which is how
    raid5 OST rebuild traffic is throttled without starving clients.

Every policy keeps request accounting (per-client and per-object counts,
total queue wait) exposed through ``info()`` — the substrate for the
fairness/observability work Brim et al. and Doreau motivate — surfaced in
``LustreCluster.procfs()["targets"][uuid]["nrs"]``.
"""
from __future__ import annotations

from collections import defaultdict

# Control-plane ops are never throttled or fair-queued: delaying a
# connect/ping turns QoS into a recovery hazard.
CONTROL_OPS = {"connect", "disconnect", "ping"}


class NrsPolicy:
    """Base policy: accounting + the FIFO busy chain helpers."""

    name = "fifo"

    def __init__(self, sim, **params):
        self.sim = sim
        self.params = dict(params)
        self.busy_until = 0.0
        self.n_reqs = 0
        self.total_wait = 0.0
        self.per_client = defaultdict(int)
        self.per_client_wait = defaultdict(float)
        self.per_jobid = defaultdict(int)
        self.per_object = defaultdict(int)

    # ------------------------------------------------------------ schedule
    def schedule(self, req, arrival: float, cost: float) -> float:
        """Return the virtual-time start for `req` arriving at `arrival`
        whose handler occupies the service for `cost` seconds."""
        raise NotImplementedError

    # ---------------------------------------------------------- accounting
    def _account(self, req, arrival: float, start: float):
        self.n_reqs += 1
        wait = max(0.0, start - arrival)
        self.total_wait += wait
        self.per_client[req.client_uuid] += 1
        self.per_client_wait[req.client_uuid] += wait
        jobid = getattr(req, "jobid", "")
        if jobid:
            self.per_jobid[jobid] += 1
        oid = req.body.get("oid")
        if oid is not None:
            self.per_object[(req.body.get("group", 0), oid)] += 1

    def info(self) -> dict:
        return {
            "policy": self.name,
            "reqs": self.n_reqs,
            "clients": len(self.per_client),
            "objects": len(self.per_object),
            "total_queue_wait_s": round(self.total_wait, 6),
            "avg_queue_wait_us": round(
                1e6 * self.total_wait / self.n_reqs, 3) if self.n_reqs else 0.0,
            "per_client": dict(self.per_client),
            # per-export breakdown (procfs: one row per client uuid)
            "per_export": {
                u: {"reqs": n,
                    "queue_wait_s": round(self.per_client_wait[u], 6),
                    "avg_queue_wait_us": round(
                        1e6 * self.per_client_wait[u] / n, 3)}
                for u, n in self.per_client.items()},
            "per_jobid": dict(self.per_jobid),
        }


class FifoPolicy(NrsPolicy):
    """Strict arrival order — the seed's implicit policy."""

    name = "fifo"

    def schedule(self, req, arrival, cost):
        start = max(arrival, self.busy_until)
        self.busy_until = start + cost
        self._account(req, arrival, start)
        return start


class RoundRobinPolicy(NrsPolicy):
    """Client round-robin (CRR): start-time fair queueing across clients.

    Each class keeps its own busy chain; a request starts at
    max(arrival, own chain) and extends the chain by cost x n_active, so
    n concurrently active classes each see ~1/n of the service rate and
    none waits behind another's backlog.
    """

    name = "crr"

    def __init__(self, sim, **params):
        super().__init__(sim, **params)
        self.chains: dict = {}

    def classify(self, req):
        return req.client_uuid

    def _stretch(self, active: set, key) -> float:
        """Chain-extension multiplier — the class's inverse service
        share among the currently active classes. CRR: everyone equal."""
        return float(len(active))

    def schedule(self, req, arrival, cost):
        if req.opcode in CONTROL_OPS:
            self._account(req, arrival, arrival)
            return arrival
        key = self.classify(req)
        # chains still running at this arrival are the active sharers
        active = {k for k, t in self.chains.items() if t > arrival}
        active.add(key)
        start = max(arrival, self.chains.get(key, 0.0))
        self.chains[key] = start + cost * self._stretch(active, key)
        self.busy_until = max(self.busy_until, self.chains[key])
        self._account(req, arrival, start)
        return start


class OrrPolicy(RoundRobinPolicy):
    """Object round-robin (ORR): fair chains keyed by (group, oid), so
    requests batch per object; a cold object is served immediately even
    while a hot object has a deep backlog."""

    name = "orr"

    def __init__(self, sim, **params):
        super().__init__(sim, **params)
        self._last_key = None
        self.batch_switches = 0

    def classify(self, req):
        oid = req.body.get("oid")
        if oid is None:
            return ("client", req.client_uuid)
        key = ("obj", req.body.get("group", 0), oid)
        if key != self._last_key:
            self.batch_switches += 1
            self._last_key = key
        return key

    def info(self):
        out = super().info()
        out["batch_switches"] = self.batch_switches
        out["per_object"] = {f"{g}:{o}": n
                             for (g, o), n in self.per_object.items()}
        return out


class OrrDiskPolicy(OrrPolicy):
    """Disk-locality ORR: the per-object fair chains of ``orr`` plus a
    contiguity-aware charge consuming the seek-aware cost model (the
    ROADMAP follow-up to the ISSUE-4 cost rework).

    ``Service.request_cost`` charges every BRW one head seek per
    discontiguous run. When a queued BRW *continues exactly where the
    object's previously scheduled BRW ended*, the head is already there:
    this policy batches the two — the chain is extended by the transfer
    cost only, the seek component is refunded. A discontiguous request
    (or one against a different object) pays the full seek-inclusive
    cost, so streams are batched by on-disk contiguity, not merely by
    object identity. ``info()["seeks_saved"]`` counts the refunds.

    params:
      seek_cost — the refund per batched contiguous continuation; keep it
                  equal to the Service's seek_cost (default 4e-5 s).
    """

    name = "orr_disk"

    def __init__(self, sim, seek_cost: float = 4e-5, **params):
        super().__init__(sim, **params)
        self.seek_cost = float(seek_cost)
        self._next_off: dict = {}      # object key -> expected next offset
        self.seeks_saved = 0

    @staticmethod
    def _span(req) -> tuple | None:
        """(start, end) of the request's on-disk footprint, if any."""
        b = req.body
        nio = b.get("niobufs")
        if isinstance(nio, (list, tuple)) and nio:
            def ln(n):
                d = n.get("data")
                return len(d) if d is not None else n.get("length", 0)
            return (min(n.get("offset", 0) for n in nio),
                    max(n.get("offset", 0) + ln(n) for n in nio))
        if "offset" in b and ("data" in b or "length" in b):
            ln = len(b["data"]) if b.get("data") is not None \
                else b.get("length", 0)
            return (b["offset"], b["offset"] + ln)
        return None

    def schedule(self, req, arrival, cost):
        if req.opcode not in CONTROL_OPS:
            key = self.classify(req)
            span = self._span(req)
            if span is not None:
                if self._next_off.get(key) == span[0]:
                    # contiguous continuation: batched with the previous
                    # BRW — no head seek between them
                    cost = max(0.0, cost - self.seek_cost)
                    self.seeks_saved += 1
                self._next_off[key] = span[1]
        return super().schedule(req, arrival, cost)

    def info(self):
        out = super().info()
        out["seeks_saved"] = self.seeks_saved
        out["seek_cost"] = self.seek_cost
        return out


class WfqPolicy(RoundRobinPolicy):
    """Weighted fair queueing (WFQ): CRR generalized with per-export
    weights.

    The CRR chains with a weighted stretch: a request extends its class
    chain by ``cost * total_active_weight / own_weight``, so n
    concurrently active classes share the service rate in proportion to
    their weights (CRR is the all-weights-equal special case). Installed
    per target with ``lctl("nrs", uuid, "wfq", {"weights":
    {client_uuid: w}, "default_weight": 1.0})``.

    params:
      weights        — {class: weight}; a class is a jobid or client uuid
      default_weight — weight for classes without an entry (default 1.0)
      by_jobid       — classify EVERY tagged request by its jobid, not
                       just those with a weights entry (default False)
    """

    name = "wfq"

    def __init__(self, sim, weights: dict | None = None,
                 default_weight: float = 1.0, by_jobid: bool = False,
                 **params):
        super().__init__(sim, **params)
        self.weights = {k: float(v) for k, v in (weights or {}).items()}
        self.default_weight = float(default_weight)
        self.by_jobid = bool(by_jobid)

    def classify(self, req):
        """WFQ classes are per-JOBID when the request carries one and
        either a weights entry names that jobid or ``by_jobid`` is set:
        two batch jobs multiplexed over ONE client uuid get their own
        fair shares, and one job spread over many clients drains a
        single weighted class (mirroring the TBF jobid-rule semantics)."""
        jobid = getattr(req, "jobid", "")
        if jobid and (self.by_jobid or jobid in self.weights):
            return jobid
        return req.client_uuid

    def weight_for(self, key) -> float:
        return max(1e-9, self.weights.get(key, self.default_weight))

    def _stretch(self, active, key):
        return sum(self.weight_for(k) for k in active) \
            / self.weight_for(key)

    def info(self):
        out = super().info()
        out["weights"] = dict(self.weights)
        out["default_weight"] = self.default_weight
        out["by_jobid"] = self.by_jobid
        return out


class TbfPolicy(NrsPolicy):
    """Token Bucket Filter QoS: rate-limit request starts per class.

    params:
      rate  — default tokens/sec for every class (1 token per request)
      burst — bucket depth (allows short bursts at line rate)
      rules — {class: rate} overrides, matched against the request's
              jobid first, then its client uuid. A jobid rule makes every
              client running under that batch-job tag share ONE bucket
              (the production "throttle this job, whoever runs it" knob).
    """

    name = "tbf"

    def __init__(self, sim, rate: float = 1000.0, burst: float = 4.0,
                 rules: dict | None = None, **params):
        super().__init__(sim, **params)
        self.rate = float(rate)
        self.burst = float(burst)
        self.rules = dict(rules or {})
        # class -> (tokens, last_update_time)
        self.buckets: dict = {}
        self.throttled = 0

    def rate_for(self, key) -> float:
        return float(self.rules.get(key, self.rate))

    def classify(self, req):
        """TBF class: a matching jobid rule wins over the client uuid, so
        all clients of one batch job drain a single shared bucket."""
        jobid = getattr(req, "jobid", "")
        if jobid and jobid in self.rules:
            return jobid
        return req.client_uuid

    def schedule(self, req, arrival, cost):
        if req.opcode in CONTROL_OPS:
            self._account(req, arrival, arrival)
            return arrival
        key = self.classify(req)
        rate = max(1e-9, self.rate_for(key))
        tokens, last = self.buckets.get(key, (self.burst, arrival))
        # refill up to the arrival instant (clock may rewind between
        # parallel thunks — never refill backwards)
        now = max(arrival, last)
        tokens = min(self.burst, tokens + (now - last) * rate)
        if tokens >= 1.0:
            token_ready = now
        else:
            token_ready = now + (1.0 - tokens) / rate
            self.throttled += 1
        svc_free = max(arrival, self.busy_until)
        start = max(svc_free, token_ready)
        # spend the token at `start` (refill any wait time first)
        tokens = min(self.burst, tokens + (start - now) * rate) - 1.0
        self.buckets[key] = (tokens, start)
        # the busy chain advances by service occupancy only: while a
        # throttled class idles waiting for tokens, other classes run —
        # one tenant's rate limit must not head-of-line-block the rest
        self.busy_until = svc_free + cost
        self._account(req, arrival, start)
        return start

    def info(self):
        out = super().info()
        out["rate"] = self.rate
        out["burst"] = self.burst
        out["rules"] = dict(self.rules)
        out["throttled"] = self.throttled
        return out


class TbfOrrPolicy(OrrDiskPolicy):
    """Two-level policy (the ROADMAP'd composition): TBF rate limits
    OVER orr_disk ordering, so QoS and disk locality compose instead of
    being either/or.

    Level 1 (admission): a token bucket per QoS class (jobid-rule first,
    else client uuid — the TBF semantics) delays the request's effective
    arrival until a token is free.  Level 2 (ordering): the admitted
    request then takes the ordinary ``orr_disk`` path — per-object fair
    chains with the contiguous-continuation seek refund.

    This is what OST rebuild wants: the rebuilder runs under a
    ``rules={"rebuild": r}`` bucket so its reconstruction BRWs trickle
    in at r req/s and client p99 holds, while WITHIN its trickle the
    requests still batch by object and disk contiguity (a throttled
    rebuild that also seeks randomly would waste its whole budget).

    params:
      rate  — default tokens/sec per class; 0 = unlimited (default —
              only classes named in ``rules`` are throttled)
      burst — bucket depth (default 4)
      rules — {class: rate} overrides, jobid first then client uuid
      seek_cost — forwarded to orr_disk
    """

    name = "tbf_orr"

    def __init__(self, sim, rate: float = 0.0, burst: float = 4.0,
                 rules: dict | None = None, **params):
        super().__init__(sim, **params)
        self.rate = float(rate)
        self.burst = float(burst)
        self.rules = dict(rules or {})
        self.buckets: dict = {}        # class -> (tokens, last_update)
        self.throttled = 0
        # ORR chain keys whose traffic is token-limited: they YIELD in
        # the fair-share stretch (see _stretch)
        self._throttled_keys: set = set()

    def rate_for(self, key) -> float:
        return float(self.rules.get(key, self.rate))

    def tbf_classify(self, req):
        jobid = getattr(req, "jobid", "")
        if jobid and jobid in self.rules:
            return jobid
        return req.client_uuid

    def _admit(self, req, arrival: float) -> float:
        """Token release instant for the request's QoS class."""
        key = self.tbf_classify(req)
        rate = self.rate_for(key)
        if rate <= 0:
            return arrival             # unlimited class
        tokens, last = self.buckets.get(key, (self.burst, arrival))
        now = max(arrival, last)       # clock may rewind between thunks
        tokens = min(self.burst, tokens + (now - last) * rate)
        if tokens >= 1.0:
            ready = now
        else:
            ready = now + (1.0 - tokens) / rate
            self.throttled += 1
        tokens = min(self.burst, tokens + (ready - now) * rate) - 1.0
        self.buckets[key] = (tokens, ready)
        return ready

    def _stretch(self, active, key):
        """Throttled classes yield: the token bucket IS their service
        allocation, so their paced chains must not also count as fair-
        share members — otherwise a rebuild spread over many objects
        would claim one share per object ON TOP of its rate cap and
        unthrottled clients would see 1/n service during the whole
        rebuild window (the exact starvation the composition exists to
        prevent). A throttled class itself still shares with everything
        active; unthrottled classes share only with each other."""
        if key in self._throttled_keys:
            return float(len(active))
        return float(max(1, sum(1 for k in active
                                if k not in self._throttled_keys)))

    def schedule(self, req, arrival, cost):
        if req.opcode in CONTROL_OPS:
            self._account(req, arrival, arrival)
            return arrival
        if self.rate_for(self.tbf_classify(req)) > 0:
            # mirror of classify()'s key, without its batch accounting
            oid = req.body.get("oid")
            self._throttled_keys.add(
                ("client", req.client_uuid) if oid is None
                else ("obj", req.body.get("group", 0), oid))
        # admission first, ordering second: the orr_disk chains see the
        # token-release instant as the arrival
        return super().schedule(req, max(arrival, self._admit(req, arrival)),
                                cost)

    def info(self):
        out = super().info()
        out["rate"] = self.rate
        out["burst"] = self.burst
        out["rules"] = dict(self.rules)
        out["throttled"] = self.throttled
        return out


POLICIES = {p.name: p for p in
            (FifoPolicy, RoundRobinPolicy, OrrPolicy, OrrDiskPolicy,
             WfqPolicy, TbfPolicy, TbfOrrPolicy)}


def make_policy(name: str, sim, **params) -> NrsPolicy:
    cls = POLICIES.get(name)
    if cls is None:
        raise ValueError(f"unknown NRS policy {name!r} "
                         f"(have: {sorted(POLICIES)})")
    return cls(sim, **params)
