"""Object Storage Client (paper §2.2, ch. 25) with write-back page cache.

The OSC exposes the same OBD API as a direct device but ships each call to
an OST. It owns:
  * a LockClient on the OST's DLM namespace (extent locks; reads take PR,
    writes PW; the server grows extents per §7.5 so sequential I/O takes
    ONE lock RPC per object, which our benchmarks measure);
  * a write-back cache of dirty extents flushed on lock revocation, grant
    exhaustion, or explicit sync (ch. 28.5);
  * the client half of the grant protocol (ch. 10.12);
  * the vectored BRW engine (§4.5.6): adjacent/overlapping dirty extents
    are coalesced, flushes ship *niobuf vectors* (many extents per
    OST_WRITE RPC) bounded by `max_pages_per_rpc`, and RPC dispatch is
    flow-controlled by `max_rpcs_in_flight`;
  * referral handling: reads bounced to a collaborative cache follow the
    referral to the caching OST (§5.5);
  * a CLEAN read cache (§7.4-§7.7): extents fetched by reads (and dirty
    extents promoted at flush) stay cached, LRU-bounded by
    `max_cached_mb`, and are served with ZERO RPCs for as long as a
    cached PR/PW lock covers them. Lock revocation (blocking AST),
    cancel, and eviction invalidate the covered pages — cached data is
    valid exactly while the lock protocol says it is.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Optional

from repro.core import dlm as dlm_mod
from repro.core import fail as fail_mod
from repro.core import ptlrpc as R

PAGE_SIZE = 4096
DEFAULT_MAX_PAGES_PER_RPC = 1024      # 4 MiB per BRW RPC
DEFAULT_MAX_RPCS_IN_FLIGHT = 8
DEFAULT_MAX_CACHED_MB = 64            # clean read-cache budget per OSC
DEFAULT_READAHEAD_PAGES = 256         # 1 MiB sequential readahead window


def _pages(nbytes: int) -> int:
    return max(1, (nbytes + PAGE_SIZE - 1) // PAGE_SIZE)


@dataclasses.dataclass
class DirtyExtent:
    group: int
    oid: int
    offset: int
    data: bytes
    mtime: float

    @property
    def end(self) -> int:
        return self.offset + len(self.data)


@dataclasses.dataclass
class CleanExtent:
    """A lock-covered cached extent of clean data (read or written-back).
    Validity is NOT stored here: it is re-checked against the client lock
    cache on every hit (the pages are usable exactly while a cached PR/PW
    lock covers them)."""
    group: int
    oid: int
    offset: int
    data: bytes
    atime: float                       # LRU clock

    @property
    def end(self) -> int:
        return self.offset + len(self.data)


class Osc:
    def __init__(self, rpc: R.RpcClient, target_uuid: str, nids: list[str],
                 *, writeback: bool = True,
                 max_pages_per_rpc: int = DEFAULT_MAX_PAGES_PER_RPC,
                 max_rpcs_in_flight: int = DEFAULT_MAX_RPCS_IN_FLIGHT,
                 vectored_brw: bool = True,
                 max_cached_mb: int = DEFAULT_MAX_CACHED_MB):
        self.rpc = rpc
        self.sim = rpc.sim
        self.uuid = target_uuid
        self.imp = rpc.import_target(target_uuid, nids, "ost")
        self.locks = dlm_mod.LockClient(rpc, self.imp, flush_cb=self._flush_lock)
        self.locks.revoke_cbs.append(self._on_lock_revoked)
        self.locks.glimpse_cb = self._on_glimpse
        self.imp.evict_cbs.append(self._on_evicted)
        self.writeback = writeback
        self.max_pages_per_rpc = max(1, max_pages_per_rpc)
        self.max_rpcs_in_flight = max(1, max_rpcs_in_flight)
        self.vectored_brw = vectored_brw
        self.dirty: list[DirtyExtent] = []
        self.dirty_bytes = 0
        # clean read cache: per-object sorted disjoint extents, global
        # LRU byte budget (max_cached_mb)
        self.clean: dict[tuple, list[CleanExtent]] = defaultdict(list)
        self.clean_bytes = 0
        self.max_cached_bytes = max(0, max_cached_mb) << 20
        # size/mtime known-under-lock (LVB, §7.7): valid while a cached
        # whole-object PR/PW lock is held
        self._sizes: dict[tuple, int] = {}
        self._mtimes: dict[tuple, float] = {}
        self.grant = 0
        self._cobd_imports: dict[str, R.Import] = {}
        self.read_cache_cb = None       # COBD hook: populate peer cache

    # ------------------------------------------------------------- locks
    def _res(self, group, oid):
        return ("ext", group, oid)

    def lock(self, group, oid, mode, extent=None, gid: int = 0,
             glimpse: bool = False):
        lk, _, lvb = self.locks.enqueue(self._res(group, oid), mode,
                                        extent or dlm_mod.WHOLE, gid=gid,
                                        glimpse=glimpse)
        if lk is not None and lk.covers("PR", dlm_mod.WHOLE) \
                and "size" in lvb:
            # whole-object PR/PW lock: the LVB size/mtime stay current
            # (nobody else can write) modulo our own tracked writes
            key = (group, oid)
            self._sizes.setdefault(key, lvb["size"])
            self._mtimes.setdefault(key, lvb.get("mtime", 0.0))
        return lk, lvb

    def _flush_lock(self, lk: dlm_mod.Lock):
        """Blocking AST on a PW lock: write back dirty extents under it."""
        _, group, oid = lk.res_name
        self.flush(group, oid)

    def _on_glimpse(self, lk: dlm_mod.Lock) -> dict:
        """Glimpse AST: report the live size/mtime this client knows —
        tracked lock-cached size plus dirty write-back extents — WITHOUT
        flushing or surrendering the lock (§7.7)."""
        if lk.res_name[0] != "ext":
            return {}
        _, group, oid = lk.res_name
        key = (group, oid)
        size = self._sizes.get(key, 0)
        mtime = self._mtimes.get(key, 0.0)
        for d in self.dirty:
            if (d.group, d.oid) == key:
                size = max(size, d.end)
                mtime = max(mtime, d.mtime)
        self.sim.stats.count("osc.glimpse_answered")
        return {"size": size, "mtime": mtime}

    def _on_lock_revoked(self, lk: dlm_mod.Lock):
        """A lock left the cache (AST / cancel / eviction): every clean
        page it covered is no longer protected — drop them, plus the
        LVB-derived size (§7.4: flush AND invalidate on revocation)."""
        if lk.res_name[0] != "ext":
            return
        _, group, oid = lk.res_name
        self._invalidate_clean(group, oid, lk.extent)
        self._sizes.pop((group, oid), None)
        self._mtimes.pop((group, oid), None)

    def _on_evicted(self):
        """The OST evicted us (-107): locks, grant, dirty data and clean
        pages are all void. Dirty bytes are LOST — the documented cost of
        eviction (§7.4)."""
        self.sim.stats.count("osc.evicted")
        if self.dirty_bytes:
            self.sim.stats.count("osc.evicted_dirty_lost_bytes",
                                 self.dirty_bytes)
        self.dirty.clear()
        self.dirty_bytes = 0
        self.clean.clear()
        self.clean_bytes = 0
        self._sizes.clear()
        self._mtimes.clear()
        self.grant = 0
        self.locks.drop_all()

    # ------------------------------------------------------------- admin
    @property
    def active(self) -> bool:
        return not self.imp.deactivated

    def set_active(self, on: bool):
        """`lctl --device <osc> activate|deactivate` analogue. While
        inactive every RPC through this OSC fails fast with -19 (ENODEV)
        instead of paying the reconnect walk; the LOV's raid5 paths key
        degraded service off exactly that."""
        self.imp.deactivated = not on

    # --------------------------------------------------------------- api
    def create(self, group: int, oid: int | None = None, **attrs) -> dict:
        def fixup(req, rep):
            req.body["oid"] = rep.data["oid"]
        rep = self.imp.request("create", {"group": group, "oid": oid,
                                          "attrs": attrs}, fixup=fixup)
        return rep.data

    def destroy(self, group: int, oid: int, cookie: int | None = None):
        return self.imp.request("destroy", {"group": group, "oid": oid,
                                            "cookie": cookie}).data

    def getattr(self, group: int, oid: int) -> dict:
        return self.imp.request("getattr", {"group": group, "oid": oid}).data

    def glimpse_bulk(self, items: list) -> list:
        """ONE vectored glimpse RPC for many objects of this OST:
        items = [(group, oid), ...] -> [{"size", "mtime"} | None, ...].
        Writers holding PW locks answer glimpse ASTs server-side; their
        caches survive (unlike the PR-enqueue revocation path)."""
        rep = self.imp.request("glimpse_bulk",
                               {"objects": [list(i) for i in items]})
        self.sim.stats.count("osc.glimpse_bulk")
        return rep.data["attrs"]

    def setattr(self, group: int, oid: int, **attrs):
        return self.imp.request(
            "setattr", {"group": group, "oid": oid, "attrs": attrs}).data

    def punch(self, group: int, oid: int, size: int):
        self._drop_dirty_beyond(group, oid, size)
        self._invalidate_clean(group, oid, (size, dlm_mod.MAX_EXT))
        key = (group, oid)
        if key in self._sizes:
            self._sizes[key] = min(self._sizes[key], size)
        return self.imp.request(
            "punch", {"group": group, "oid": oid, "size": size}).data

    def statfs(self) -> dict:
        return self.imp.request("statfs", {}).data

    def sync(self):
        self.flush()
        return self.imp.request("sync", {}).data

    def list_objects(self, group: int) -> list:
        return self.imp.request("list_objects", {"group": group}).data

    # --------------------------------------------------------------- I/O
    def _ensure_grant(self):
        if self.grant == 0:
            self.grant = self.imp.connect_data.get("grant", 0)

    def write(self, group: int, oid: int, offset: int, data: bytes,
              *, lock: bool = True, gid: int = 0):
        if not data:
            return {"cached": False, "size": None}
        if lock:
            self.lock(group, oid, "GR" if gid else "PW",
                      (offset, offset + len(data)), gid=gid)
        self._ensure_grant()
        if self.writeback and len(data) <= self.grant:
            # cached write consumes grant; flushed lazily (ch. 10.12)
            self.grant -= len(data)
            self._note_write(group, oid, offset, len(data))
            self._cache_dirty(group, oid, offset, data)
            for lk in self.locks.by_res.get(self._res(group, oid), ()):
                lk.dirty = True
            self.sim.stats.count("osc.cached_write")
            return {"cached": True}
        # write-through: older cached extents of this object must land
        # FIRST or a later flush would overwrite this newer data
        self.flush(group, oid)
        # AFTER the flush: it promotes the older extents to clean pages,
        # which this newer write supersedes
        self._note_write(group, oid, offset, len(data))
        return self._write_through(
            DirtyExtent(group, oid, offset, bytes(data), self.sim.now))

    def writev(self, group: int, oid: int, iov: list, *, lock: bool = True,
               gid: int = 0):
        """Vectored write: iov = [(offset, data), ...] for ONE object.
        Takes a single lock spanning the runs, then either caches the runs
        (write-back) or ships them as coalesced BRW niobuf vectors."""
        iov = [(off, d) for off, d in iov if d]
        if not iov:
            return {"cached": False}
        total = sum(len(d) for _, d in iov)
        if lock:
            span = (min(off for off, _ in iov),
                    max(off + len(d) for off, d in iov))
            self.lock(group, oid, "GR" if gid else "PW", span, gid=gid)
        self._ensure_grant()
        if self.writeback and total <= self.grant:
            self.grant -= total
            for off, d in iov:
                self._note_write(group, oid, off, len(d))
                self._cache_dirty(group, oid, off, d)
            for lk in self.locks.by_res.get(self._res(group, oid), ()):
                lk.dirty = True
            self.sim.stats.count("osc.cached_write", len(iov))
            return {"cached": True}
        # write-through (see write()): flush older cached data first —
        # the flush promotes them to clean, which these newer runs
        # supersede (_note_write after it)
        self.flush(group, oid)
        for off, d in iov:
            self._note_write(group, oid, off, len(d))
        now = self.sim.now
        exts = [DirtyExtent(group, oid, off, bytes(d), now) for off, d in iov]
        if not self.vectored_brw:
            outs = self.sim.parallel([
                (lambda dd=d: self._write_through(dd)) for d in exts])
            return outs[-1] if outs else {"cached": False}
        outs = self._send_vectors(self._build_vectors(exts))
        return outs[-1] if outs else {"cached": False}

    # ------------------------------------------------------- dirty cache
    def _cache_dirty(self, group: int, oid: int, offset: int, data: bytes):
        """Insert a dirty extent, coalescing with overlapping/adjacent
        extents of the same object (new data wins over old) so the cache
        stays normalized: per-object extents are sorted and disjoint."""
        if not self.vectored_brw:
            self.dirty.append(DirtyExtent(group, oid, offset, bytes(data),
                                          self.sim.now))
            self.dirty_bytes += len(data)
            return
        end = offset + len(data)
        touch = [d for d in self.dirty
                 if (d.group, d.oid) == (group, oid)
                 and d.offset <= end and offset <= d.end]
        if not touch:
            merged = DirtyExtent(group, oid, offset, bytes(data),
                                 self.sim.now)
        else:
            lo = min(offset, min(d.offset for d in touch))
            hi = max(end, max(d.end for d in touch))
            buf = bytearray(hi - lo)
            # lay old extents in temporal (list) order, newest write last
            for d in touch:
                buf[d.offset - lo:d.end - lo] = d.data
                self.dirty.remove(d)
                self.dirty_bytes -= len(d.data)
            buf[offset - lo:end - lo] = data
            merged = DirtyExtent(group, oid, lo, bytes(buf), self.sim.now)
            self.sim.stats.count("osc.extents_coalesced", len(touch))
        self.dirty.append(merged)
        self.dirty_bytes += len(merged.data)

    # ------------------------------------------------------- clean cache
    def _note_write(self, group: int, oid: int, offset: int, nbytes: int):
        """A write supersedes any clean pages it overlaps and grows the
        lock-cached size."""
        if nbytes <= 0:
            return
        self._invalidate_clean(group, oid, (offset, offset + nbytes))
        key = (group, oid)
        if key in self._sizes:
            self._sizes[key] = max(self._sizes[key], offset + nbytes)
            self._mtimes[key] = max(self._mtimes.get(key, 0.0),
                                    self.sim.now)

    def _clean_insert(self, group: int, oid: int, offset: int,
                      data: bytes):
        """Cache a clean extent, coalescing with overlapping/adjacent
        cached extents (new data wins), then enforce the LRU byte budget."""
        if not data or not self.max_cached_bytes:
            return
        key = (group, oid)
        end = offset + len(data)
        exts = self.clean[key]
        touch = [e for e in exts if e.offset <= end and offset <= e.end]
        if not touch:
            merged = CleanExtent(group, oid, offset, bytes(data),
                                 self.sim.now)
        else:
            lo = min(offset, min(e.offset for e in touch))
            hi = max(end, max(e.end for e in touch))
            buf = bytearray(hi - lo)
            for e in touch:
                buf[e.offset - lo:e.end - lo] = e.data
                exts.remove(e)
                self.clean_bytes -= len(e.data)
            buf[offset - lo:end - lo] = data
            merged = CleanExtent(group, oid, lo, bytes(buf), self.sim.now)
        exts.append(merged)
        exts.sort(key=lambda e: e.offset)
        self.clean_bytes += len(merged.data)
        self._clean_shrink()

    def _clean_shrink(self):
        """LRU-evict whole extents until the cache fits max_cached_mb."""
        while self.clean_bytes > self.max_cached_bytes:
            victim = min((e for exts in self.clean.values() for e in exts),
                         key=lambda e: e.atime)
            vkey = (victim.group, victim.oid)
            self.clean[vkey].remove(victim)
            if not self.clean[vkey]:
                del self.clean[vkey]
            self.clean_bytes -= len(victim.data)
            self.sim.stats.count("osc.cache_lru_evict")

    def _clean_read(self, group: int, oid: int, offset: int,
                    length: int) -> bytes | None:
        """Serve from the clean cache iff a cached PR/PW lock covers the
        extent (the §7.4 validity rule) — zero RPCs on a hit."""
        exts = self.clean.get((group, oid))
        if not exts:
            return None
        end = offset + length
        for e in exts:
            if e.offset <= offset and end <= e.end:
                if self.locks.match(self._res(group, oid), "PR",
                                    (offset, end)) is None:
                    # no covering lock: the pages are unprotected — a
                    # revocation should already have dropped them, but
                    # never serve unguarded data (count + drop)
                    self.sim.stats.count("osc.cache_uncovered")
                    self._invalidate_clean(group, oid, (e.offset, e.end))
                    return None
                e.atime = self.sim.now
                self.sim.stats.count("osc.cache_hit")
                self.sim.stats.count("osc.cache_hit_bytes", length)
                o = offset - e.offset
                return e.data[o:o + length]
        return None

    def _invalidate_clean(self, group: int, oid: int,
                          extent: tuple | None = None):
        """Drop clean pages overlapping `extent` (None = whole object)."""
        key = (group, oid)
        exts = self.clean.get(key)
        if not exts:
            return
        lo, hi = extent if extent is not None else (0, dlm_mod.MAX_EXT)
        keep = []
        for e in exts:
            if e.offset < hi and lo < e.end:
                self.clean_bytes -= len(e.data)
                self.sim.stats.count("osc.cache_invalidate")
            else:
                keep.append(e)
        if keep:
            self.clean[key] = keep
        else:
            self.clean.pop(key, None)

    # ------------------------------------------------------- BRW engine
    def _pack(self, items: list, nbytes_of) -> list[list]:
        """Pack items (pre-sorted by offset) into batches whose combined
        page count stays within max_pages_per_rpc."""
        batches, vec, pages = [], [], 0
        for it in items:
            npg = _pages(nbytes_of(it))
            if vec and pages + npg > self.max_pages_per_rpc:
                batches.append(vec)
                vec, pages = [], 0
            vec.append(it)
            pages += npg
        if vec:
            batches.append(vec)
        return batches

    def _build_vectors(self, extents: list[DirtyExtent]) -> list[tuple]:
        """Group extents by object and pack them, sorted by offset, into
        niobuf vectors of at most max_pages_per_rpc pages each.
        Returns [(group, oid, [DirtyExtent, ...]), ...]."""
        max_bytes = self.max_pages_per_rpc * PAGE_SIZE
        by_obj: dict[tuple, list[DirtyExtent]] = defaultdict(list)
        for d in extents:
            # an extent larger than one RPC's page budget is sliced first
            for cut in range(0, len(d.data), max_bytes):
                by_obj[(d.group, d.oid)].append(
                    DirtyExtent(d.group, d.oid, d.offset + cut,
                                d.data[cut:cut + max_bytes], d.mtime))
        rpcs = []
        for (g, o), exts in by_obj.items():
            for vec in self._pack(sorted(exts, key=lambda d: d.offset),
                                  lambda d: len(d.data)):
                rpcs.append((g, o, vec))
        return rpcs

    def _brw_write(self, group: int, oid: int, vec: list[DirtyExtent]) -> dict:
        # bulk bytes ride in the body niobufs: wire_size counts them once;
        # no extra bulk_nbytes or we double-charge the link
        rep = self.imp.request(
            "write", {"group": group, "oid": oid,
                      "niobufs": [{"offset": d.offset, "data": d.data}
                                  for d in vec],
                      "mtime": max(d.mtime for d in vec)})
        self.grant = rep.data.get("grant", self.grant)
        self._note_written_size(group, oid, rep.data)
        self.sim.stats.count("osc.brw_write_rpc")
        self.sim.stats.count("osc.brw_write_niobufs", len(vec))
        return rep.data

    def _note_written_size(self, group: int, oid: int, rep_data: dict):
        """Write replies carry the post-write object size: keep the
        lock-cached size current so getattr_locked stays RPC-free."""
        key = (group, oid)
        if key in self._sizes and isinstance(rep_data, dict) \
                and "size" in rep_data:
            self._sizes[key] = max(self._sizes[key], rep_data["size"])

    def _send_vectors(self, rpcs: list[tuple]) -> list:
        """Dispatch BRW RPCs with at most max_rpcs_in_flight concurrent."""
        outs = []
        for i in range(0, len(rpcs), self.max_rpcs_in_flight):
            window = rpcs[i:i + self.max_rpcs_in_flight]
            outs.extend(self.sim.parallel(
                [(lambda r=r: self._brw_write(*r)) for r in window]))
        return outs

    def _write_through(self, d: DirtyExtent) -> dict:
        if self.vectored_brw:
            outs = self._send_vectors(self._build_vectors([d]))
            return outs[-1]
        # legacy (seed) path: one RPC per extent, data in the body
        rep = self.imp.request(
            "write", {"group": d.group, "oid": d.oid, "offset": d.offset,
                      "data": d.data, "mtime": d.mtime})
        self.grant = rep.data.get("grant", self.grant)
        self._note_written_size(d.group, d.oid, rep.data)
        return rep.data

    def flush(self, group=None, oid=None):
        """Write back dirty extents (all, or one object's), coalesced into
        vectored BRW RPCs under in-flight flow control. Flushed pages are
        not thrown away: they stay cached as CLEAN extents, still covered
        by the PW lock the write took."""
        todo = [d for d in self.dirty
                if group is None or (d.group, d.oid) == (group, oid)]
        if not todo:
            if group is None:
                # idle full flush (e.g. close after a blocking AST already
                # wrote everything back): still the moment to return grant
                self._maybe_shrink_grant()
            return 0
        act = fail_mod.state.check("osc.flush")
        if act == "delay":
            pass                       # check() already stalled the clock
        elif act in ("drop", "crash"):
            # client-side site: the flush's first BRW RPC is lost on the
            # wire (OBD_FAIL_*_NET); the import recovers via timeout ->
            # reconnect -> resend, so the flush still completes
            self.sim.faults.drop_next[self.imp.active_nid] += 1
        if self.vectored_brw:
            self._send_vectors(self._build_vectors(todo))
        else:
            self.sim.parallel([
                (lambda dd=d: self._write_through(dd)) for d in todo])
        # drop from the cache only once the writes went out: a failed
        # flush (ENOSPC, unreachable target) must not discard dirty data
        for d in todo:
            self.dirty.remove(d)
            self.dirty_bytes -= len(d.data)
            self._clean_insert(d.group, d.oid, d.offset, d.data)
        if group is None:
            # full flush = the write burst is over: return idle grant so
            # the OST can redistribute it (ch. 10.12 grant shrinking —
            # at thousands of clients the per-export slice is the scarce
            # resource, see benchmarks/bench_scale.py)
            self._maybe_shrink_grant()
        return len(todo)

    def _maybe_shrink_grant(self):
        """Give back grant above the connect-time watermark once no dirty
        data needs it. The RPC carries the absolute `keep` target, so a
        resend after a drop/crash is idempotent (shrinking to 2 MB twice
        is shrinking to 2 MB)."""
        keep = self.imp.connect_data.get("grant", 0)
        if self.dirty or keep <= 0 or self.grant <= keep:
            return
        act = fail_mod.state.check("osc.grant_shrink")
        if act in ("drop", "crash"):
            # client-side site: the shrink RPC is lost on the wire; the
            # import recovers via timeout -> reconnect -> resend
            self.sim.faults.drop_next[self.imp.active_nid] += 1
        try:
            rep = self.imp.request("grant_shrink", {"keep": keep})
        except (R.TimeoutError_, R.RpcError):
            return                     # best-effort: grant is a hint
        self.grant = min(self.grant, rep.data.get("grant", keep))
        self.sim.stats.count("osc.grant_shrink", node=self.rpc.uuid)

    def _drop_dirty_beyond(self, group, oid, size):
        for d in list(self.dirty):
            if (d.group, d.oid) == (group, oid) and d.offset >= size:
                self.dirty.remove(d)
                self.dirty_bytes -= len(d.data)

    # --------------------------------------------------------------- read
    def _cached_read(self, group, oid, offset, length) -> bytes | None:
        for d in self.dirty:
            if (d.group, d.oid) == (group, oid) and d.offset <= offset and \
                    offset + length <= d.end:
                o = offset - d.offset
                return d.data[o:o + length]
        return None

    def read(self, group: int, oid: int, offset: int, length: int,
             *, lock: bool = True, from_cobd: str | None = None) -> bytes:
        # serve from own dirty cache when fully covered
        hit = self._cached_read(group, oid, offset, length)
        if hit is not None:
            return hit
        # then from the clean cache, if a cached lock still covers it
        hit = self._clean_read(group, oid, offset, length)
        if hit is not None:
            return hit
        self.sim.stats.count("osc.cache_miss")
        self.flush(group, oid)             # partial overlap: write back first
        if lock:
            self.lock(group, oid, "PR", (offset, offset + length))
        body = {"group": group, "oid": oid, "offset": offset,
                "length": length}
        if from_cobd:
            body["_from_cobd"] = from_cobd
        rep = self.imp.request("read", body)
        if rep.data and "referral" in (rep.data or {}):
            ref = rep.data["referral"]
            self.sim.stats.count("osc.followed_referral")
            data = self._read_via(ref, group, oid, offset, length)
        else:
            data = rep.bulk
        if self.locks.match(self._res(group, oid), "PR",
                            (offset, offset + len(data or b""))):
            self._clean_insert(group, oid, offset, data)
        return data

    def readv(self, group: int, oid: int, iov: list,
              *, lock: bool = True) -> list[bytes]:
        """Vectored read: iov = [(offset, length), ...] for ONE object.
        One lock spanning the runs; uncached runs travel as niobuf vectors
        in as few OST_READ RPCs as max_pages_per_rpc allows; replies are
        merged with cache hits positionally."""
        iov = list(iov)
        if not iov:
            return []
        if not self.vectored_brw:
            return [self.read(group, oid, off, ln, lock=lock)
                    for off, ln in iov]
        out: list[Optional[bytes]] = [None] * len(iov)
        miss: list[tuple[int, int, int]] = []      # (iov_idx, offset, length)
        for i, (off, ln) in enumerate(iov):
            hit = self._cached_read(group, oid, off, ln)
            if hit is None:
                hit = self._clean_read(group, oid, off, ln)
            if hit is not None:
                out[i] = hit
            else:
                self.sim.stats.count("osc.cache_miss")
                miss.append((i, off, ln))
        if not miss:
            return out                       # fully served from cache
        self.flush(group, oid)               # partial overlap: write back
        span = (min(off for _, off, _ in miss),
                max(off + ln for _, off, ln in miss))
        if lock:
            self.lock(group, oid, "PR", span)
        # pack misses into vectors bounded by max_pages_per_rpc
        batches = self._pack(sorted(miss, key=lambda m: m[1]),
                             lambda m: m[2])

        def one(batch):
            rep = self.imp.request(
                "read", {"group": group, "oid": oid,
                         "niobufs": [{"offset": off, "length": ln}
                                     for _, off, ln in batch]})
            if rep.data and "referral" in (rep.data or {}):
                # collaborative-cache referral: fall back to per-run reads
                # (they follow the referral chain)
                self.sim.stats.count("osc.followed_referral")
                return [self.read(group, oid, off, ln, lock=False)
                        for _, off, ln in batch]
            self.sim.stats.count("osc.brw_read_rpc")
            return rep.bulk
        covered = bool(lock) or self.locks.match(
            self._res(group, oid), "PR", span) is not None
        for i in range(0, len(batches), self.max_rpcs_in_flight):
            window = batches[i:i + self.max_rpcs_in_flight]
            chunk_lists = self.sim.parallel(
                [(lambda b=b: one(b)) for b in window])
            for batch, chunks in zip(window, chunk_lists):
                for (idx, off, _), chunk in zip(batch, chunks):
                    out[idx] = chunk
                    if covered:
                        self._clean_insert(group, oid, off, chunk)
        return out

    def getattr_locked(self, group: int, oid: int) -> dict:
        """size/mtime under a PR lock. While a cached whole-object PR/PW
        lock is held nobody else can change the object, so the grant-time
        LVB (§7.7) plus our own tracked writes IS the current size — zero
        RPCs on the warm path. The cold enqueue is a GLIMPSE enqueue: a
        conflicting writer is ASKED for its LVB via a glimpse AST instead
        of revoked, so a stat of a file under write no longer kills the
        writer's write-back cache (the ROADMAP'd 'glimpse ASTs proper')."""
        key = (group, oid)
        if key not in self._sizes or self.locks.match(
                self._res(group, oid), "PR", dlm_mod.WHOLE) is None:
            lk, lvb = self.lock(group, oid, "PR", glimpse=True)
            if lk is None and "size" in lvb:
                # writer active: the server merged the holders' glimpse
                # answers into the LVB — use it, cache nothing (no lock)
                self.sim.stats.count("osc.glimpse_stat")
                return {"size": lvb["size"], "mtime": lvb.get("mtime", 0.0)}
            if not (lk is not None and lk.covers("PR", dlm_mod.WHOLE)
                    and key in self._sizes):
                # contended object (lock not grown to whole): fall back
                a = self.getattr(group, oid)
                return {"size": a["size"], "mtime": a["mtime"]}
        else:
            self.sim.stats.count("osc.getattr_cached")
        size = self._sizes[key]
        mtime = self._mtimes.get(key, 0.0)
        for d in self.dirty:
            if (d.group, d.oid) == key:
                size = max(size, d.end)
                mtime = max(mtime, d.mtime)
        return {"size": size, "mtime": mtime}

    def _read_via(self, ref: dict, group, oid, offset, length) -> bytes:
        imp = self._cobd_imports.get(ref["uuid"])
        if imp is None:
            imp = self.rpc.import_target(ref["uuid"], [ref["nid"]], "ost")
            self._cobd_imports[ref["uuid"]] = imp
        rep = imp.request("read", {"group": group, "oid": oid,
                                   "offset": offset, "length": length,
                                   "no_referral": True})
        return rep.bulk

    # ---------------------------------------------------------- recovery
    def on_connect_data(self, data: dict):
        self.grant = data.get("grant", 0)
