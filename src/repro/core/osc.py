"""Object Storage Client (paper §2.2, ch. 25) with write-back page cache.

The OSC exposes the same OBD API as a direct device but ships each call to
an OST. It owns:
  * a LockClient on the OST's DLM namespace (extent locks; reads take PR,
    writes PW; the server grows extents per §7.5 so sequential I/O takes
    ONE lock RPC per object, which our benchmarks measure);
  * a write-back cache of dirty extents flushed on lock revocation, grant
    exhaustion, or explicit sync (ch. 28.5);
  * the client half of the grant protocol (ch. 10.12);
  * referral handling: reads bounced to a collaborative cache follow the
    referral to the caching OST (§5.5).
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Optional

from repro.core import dlm as dlm_mod
from repro.core import ptlrpc as R


@dataclasses.dataclass
class DirtyExtent:
    group: int
    oid: int
    offset: int
    data: bytes
    mtime: float


class Osc:
    def __init__(self, rpc: R.RpcClient, target_uuid: str, nids: list[str],
                 *, writeback: bool = True):
        self.rpc = rpc
        self.sim = rpc.sim
        self.uuid = target_uuid
        self.imp = rpc.import_target(target_uuid, nids, "ost")
        self.locks = dlm_mod.LockClient(rpc, self.imp, flush_cb=self._flush_lock)
        self.writeback = writeback
        self.dirty: list[DirtyExtent] = []
        self.dirty_bytes = 0
        self.grant = 0
        self._cobd_imports: dict[str, R.Import] = {}
        self.read_cache_cb = None       # COBD hook: populate peer cache

    # ------------------------------------------------------------- locks
    def _res(self, group, oid):
        return ("ext", group, oid)

    def lock(self, group, oid, mode, extent=None, gid: int = 0):
        lk, _, lvb = self.locks.enqueue(self._res(group, oid), mode,
                                        extent or dlm_mod.WHOLE, gid=gid)
        return lk, lvb

    def _flush_lock(self, lk: dlm_mod.Lock):
        """Blocking AST on a PW lock: write back dirty extents under it."""
        _, group, oid = lk.res_name
        mine = [d for d in self.dirty if (d.group, d.oid) == (group, oid)]
        for d in mine:
            self._write_through(d)
            self.dirty.remove(d)
            self.dirty_bytes -= len(d.data)

    # --------------------------------------------------------------- api
    def create(self, group: int, oid: int | None = None, **attrs) -> dict:
        def fixup(req, rep):
            req.body["oid"] = rep.data["oid"]
        rep = self.imp.request("create", {"group": group, "oid": oid,
                                          "attrs": attrs}, fixup=fixup)
        return rep.data

    def destroy(self, group: int, oid: int, cookie: int | None = None):
        return self.imp.request("destroy", {"group": group, "oid": oid,
                                            "cookie": cookie}).data

    def getattr(self, group: int, oid: int) -> dict:
        return self.imp.request("getattr", {"group": group, "oid": oid}).data

    def setattr(self, group: int, oid: int, **attrs):
        return self.imp.request(
            "setattr", {"group": group, "oid": oid, "attrs": attrs}).data

    def punch(self, group: int, oid: int, size: int):
        self._drop_dirty_beyond(group, oid, size)
        return self.imp.request(
            "punch", {"group": group, "oid": oid, "size": size}).data

    def statfs(self) -> dict:
        return self.imp.request("statfs", {}).data

    def sync(self):
        self.flush()
        return self.imp.request("sync", {}).data

    def list_objects(self, group: int) -> list:
        return self.imp.request("list_objects", {"group": group}).data

    # --------------------------------------------------------------- I/O
    def _ensure_grant(self):
        if self.grant == 0:
            self.grant = self.imp.connect_data.get("grant", 0)

    def write(self, group: int, oid: int, offset: int, data: bytes,
              *, lock: bool = True, gid: int = 0):
        if lock:
            self.lock(group, oid, "GR" if gid else "PW",
                      (offset, offset + len(data)), gid=gid)
        self._ensure_grant()
        if self.writeback and len(data) <= self.grant:
            # cached write consumes grant; flushed lazily (ch. 10.12)
            self.grant -= len(data)
            self.dirty.append(DirtyExtent(group, oid, offset, bytes(data),
                                          self.sim.now))
            self.dirty_bytes += len(data)
            for lk in self.locks.by_res.get(self._res(group, oid), ()):
                lk.dirty = True
            self.sim.stats.count("osc.cached_write")
            return {"cached": True}
        return self._write_through(
            DirtyExtent(group, oid, offset, bytes(data), self.sim.now))

    def _write_through(self, d: DirtyExtent) -> dict:
        # bulk bytes already ride in the body ("data"): wire_size counts
        # them once; no extra bulk_nbytes or we double-charge the link
        rep = self.imp.request(
            "write", {"group": d.group, "oid": d.oid, "offset": d.offset,
                      "data": d.data, "mtime": d.mtime})
        self.grant = rep.data.get("grant", self.grant)
        return rep.data

    def flush(self, group=None, oid=None):
        """Write back dirty extents (all, or one object's)."""
        todo = [d for d in self.dirty
                if group is None or (d.group, d.oid) == (group, oid)]
        if not todo:
            return 0
        self.sim.parallel([
            (lambda dd=d: self._write_through(dd)) for d in todo])
        for d in todo:
            self.dirty.remove(d)
            self.dirty_bytes -= len(d.data)
        return len(todo)

    def _drop_dirty_beyond(self, group, oid, size):
        for d in list(self.dirty):
            if (d.group, d.oid) == (group, oid) and d.offset >= size:
                self.dirty.remove(d)
                self.dirty_bytes -= len(d.data)

    def read(self, group: int, oid: int, offset: int, length: int,
             *, lock: bool = True, from_cobd: str | None = None) -> bytes:
        # serve from own dirty cache when fully covered
        for d in self.dirty:
            if (d.group, d.oid) == (group, oid) and d.offset <= offset and \
                    offset + length <= d.offset + len(d.data):
                o = offset - d.offset
                return d.data[o:o + length]
        self.flush(group, oid)             # partial overlap: write back first
        if lock:
            self.lock(group, oid, "PR", (offset, offset + length))
        body = {"group": group, "oid": oid, "offset": offset,
                "length": length}
        if from_cobd:
            body["_from_cobd"] = from_cobd
        rep = self.imp.request("read", body)
        if rep.data and "referral" in (rep.data or {}):
            ref = rep.data["referral"]
            self.sim.stats.count("osc.followed_referral")
            return self._read_via(ref, group, oid, offset, length)
        return rep.bulk

    def _read_via(self, ref: dict, group, oid, offset, length) -> bytes:
        imp = self._cobd_imports.get(ref["uuid"])
        if imp is None:
            imp = self.rpc.import_target(ref["uuid"], [ref["nid"]], "ost")
            self._cobd_imports[ref["uuid"]] = imp
        rep = imp.request("read", {"group": group, "oid": oid,
                                   "offset": offset, "length": length,
                                   "no_referral": True})
        return rep.bulk

    # ---------------------------------------------------------- recovery
    def on_connect_data(self, data: dict):
        self.grant = data.get("grant", 0)
