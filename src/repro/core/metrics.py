"""RPC tracing + latency metrics (ORNL MELT-style monitoring plane).

Every traced RPC produces exactly one *span* on the target that executed
it: (op, export uuid, jobid, queue wait, service time, seeks, bytes).
Spans land in per-target :class:`TargetMetrics` — log2-bucketed latency
histograms keyed three ways (by op, by export, by jobid) so the
aggregation tree (`repro.tools.monitor`) can answer "p99 for jobid X
across the cluster" by *merging buckets*, never by shipping raw samples.

Exactly-once: the trace id is assigned when the client constructs the
Request and never changes across resends, replays, or reply-cache-served
retries (ptlrpc reuses the same Request object through recovery).  The
registry dedups on trace id, so a span is recorded the first time a
target *finishes executing* the request and every later arrival of the
same id is suppressed (`dup_suppressed` counts them).  The registry
lives on the Simulator — it survives target crash/restart, which is what
makes replay-after-crash count once, not twice.

All times are **virtual-clock** seconds; histogram buckets are log2-
spaced microseconds (bucket i covers (2^(i-1), 2^i] µs), which keeps a
histogram ~50 ints wide no matter how many samples it absorbs.
"""
from __future__ import annotations

import math


class LatencyHistogram:
    """Log2-bucketed latency histogram (microsecond buckets).

    Mergeable: cluster-wide quantiles come from summing per-target
    bucket arrays. Quantiles are reported as the bucket's upper bound —
    deterministic and safe (never understates a latency).
    """

    __slots__ = ("buckets", "count", "total_s", "max_s")

    MAX_BUCKET = 63                     # 2^63 us ~ 292k years: plenty

    def __init__(self):
        self.buckets: dict[int, int] = {}
        self.count = 0
        self.total_s = 0.0
        self.max_s = 0.0

    @staticmethod
    def bucket_of(seconds: float) -> int:
        us = seconds * 1e6
        if us <= 1.0:
            return 0
        return min(LatencyHistogram.MAX_BUCKET,
                   max(0, math.ceil(math.log2(us))))

    def record(self, seconds: float):
        b = self.bucket_of(seconds)
        self.buckets[b] = self.buckets.get(b, 0) + 1
        self.count += 1
        self.total_s += seconds
        if seconds > self.max_s:
            self.max_s = seconds

    def merge(self, other: "LatencyHistogram | dict"):
        """Absorb another histogram (object or its to_dict() form)."""
        if isinstance(other, LatencyHistogram):
            buckets, cnt = other.buckets, other.count
            tot, mx = other.total_s, other.max_s
        else:
            buckets = {int(k): v for k, v in other.get("buckets", {}).items()}
            cnt = other.get("count", sum(buckets.values()))
            tot = other.get("total_s", 0.0)
            mx = other.get("max_s", 0.0)
        for b, n in buckets.items():
            self.buckets[b] = self.buckets.get(b, 0) + n
        self.count += cnt
        self.total_s += tot
        if mx > self.max_s:
            self.max_s = mx

    def quantile(self, q: float) -> float:
        """Latency (seconds) at quantile q: upper bound of the bucket
        holding the q-th sample."""
        if not self.count:
            return 0.0
        rank = max(1, math.ceil(q * self.count))
        seen = 0
        for b in sorted(self.buckets):
            seen += self.buckets[b]
            if seen >= rank:
                return (2.0 ** b) / 1e6
        return self.max_s

    def summary(self) -> dict:
        return {"count": self.count,
                "mean_s": round(self.total_s / self.count, 9)
                if self.count else 0.0,
                "max_s": round(self.max_s, 9),
                "p50_s": round(self.quantile(0.50), 9),
                "p95_s": round(self.quantile(0.95), 9),
                "p99_s": round(self.quantile(0.99), 9)}

    def to_dict(self) -> dict:
        """Wire form: what mon_collect ships so the collector can merge."""
        return {"buckets": {str(b): n for b, n in sorted(self.buckets.items())},
                "count": self.count,
                "total_s": round(self.total_s, 9),
                "max_s": round(self.max_s, 9)}


class TargetMetrics:
    """Per-target span sink: latency histograms keyed by op / export /
    jobid plus scalar roll-ups (queue wait, service time, seeks, bytes)."""

    def __init__(self, uuid: str):
        self.uuid = uuid
        self.by_op: dict[str, LatencyHistogram] = {}
        self.by_export: dict[str, LatencyHistogram] = {}
        self.by_jobid: dict[str, LatencyHistogram] = {}
        self.spans = 0
        self.queue_wait_s = 0.0
        self.service_s = 0.0
        self.seeks = 0
        self.nbytes = 0

    def record(self, op: str, export: str, jobid: str,
               queue_wait: float, service: float, seeks: int, nbytes: int):
        latency = queue_wait + service
        for table, key in ((self.by_op, op), (self.by_export, export),
                           (self.by_jobid, jobid or "(none)")):
            h = table.get(key)
            if h is None:
                h = table[key] = LatencyHistogram()
            h.record(latency)
        self.spans += 1
        self.queue_wait_s += queue_wait
        self.service_s += service
        self.seeks += seeks
        self.nbytes += nbytes

    def summary(self, max_exports: int = 32) -> dict:
        """Snapshot-tree form. by_jobid ships raw buckets (the collector
        merges them across targets for cluster-wide quantiles); by_export
        is capped to the busiest `max_exports` so a thousand-client
        target reports a bounded tree, not a megabyte of leaves."""
        exports = sorted(self.by_export.items(),
                         key=lambda kv: (-kv[1].count, kv[0]))
        return {
            "spans": self.spans,
            "queue_wait_s": round(self.queue_wait_s, 9),
            "service_s": round(self.service_s, 9),
            "seeks": self.seeks,
            "bytes": self.nbytes,
            "by_op": {k: h.summary() for k, h in sorted(self.by_op.items())},
            "by_jobid": {k: dict(h.summary(), **h.to_dict())
                         for k, h in sorted(self.by_jobid.items())},
            "by_export": {k: h.summary() for k, h in exports[:max_exports]},
            "exports_omitted": max(0, len(exports) - max_exports),
        }


class MetricsRegistry:
    """Simulator-wide span registry: per-target sinks + trace-id dedup.

    Dedup state is bounded: trace ids are monotonically increasing, and
    resend/replay only ever revisit *recent* ids (a client's in-flight
    window), so pruning the oldest half at `DEDUP_LIMIT` is safe.
    """

    DEDUP_LIMIT = 200_000

    def __init__(self):
        self.targets: dict[str, TargetMetrics] = {}
        self.dup_suppressed = 0
        self._seen: set[int] = set()
        self._seen_max = 0

    def record_span(self, target: str, op: str, export: str, jobid: str,
                    queue_wait: float, service: float, seeks: int,
                    nbytes: int, trace_id: int) -> bool:
        """Record one span; returns False (and counts it) for a duplicate
        delivery of an already-recorded trace id."""
        if trace_id in self._seen:
            self.dup_suppressed += 1
            return False
        self._seen.add(trace_id)
        if trace_id > self._seen_max:
            self._seen_max = trace_id
        if len(self._seen) > self.DEDUP_LIMIT:
            cut = self._seen_max - self.DEDUP_LIMIT // 2
            self._seen = {t for t in self._seen if t >= cut}
        tm = self.targets.get(target)
        if tm is None:
            tm = self.targets[target] = TargetMetrics(target)
        tm.record(op, export, jobid, queue_wait, service, seeks, nbytes)
        return True

    def target_summary(self, uuid: str, max_exports: int = 32) -> dict:
        tm = self.targets.get(uuid)
        if tm is None:
            return TargetMetrics(uuid).summary(max_exports)
        return tm.summary(max_exports)

    def info(self) -> dict:
        return {"targets": len(self.targets),
                "spans": sum(t.spans for t in self.targets.values()),
                "dup_suppressed": self.dup_suppressed}


def merge_jobid_histograms(target_summaries: list[dict]) -> dict:
    """Cluster-wide per-jobid latency: merge the by_jobid bucket arrays
    of many target summaries into one histogram per jobid and return
    {jobid: summary}. This is the MELT aggregation step — quantiles are
    computed AFTER the merge, never averaged across targets."""
    merged: dict[str, LatencyHistogram] = {}
    for ts in target_summaries:
        for jobid, h in (ts.get("by_jobid") or {}).items():
            m = merged.get(jobid)
            if m is None:
                m = merged[jobid] = LatencyHistogram()
            m.merge(h)
    return {jobid: h.summary() for jobid, h in sorted(merged.items())}
