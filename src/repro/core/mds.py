"""Lustre Metadata Service (paper ch. 6, 26).

Namespace model (§6.2): inodes keyed by *fid* = (inode_group, ino, gen) —
fids are never reused and uniquely identify an inode. Elements are
(parent_fid, name, fid) triples. File inodes hold NO data, only the LOV
stripe descriptor in an extended attribute (§2.2, §10.2).

Implemented:
  * intent handling (§6.2.2/§7.5): lookup/getattr/open/create execute inside
    the DLM enqueue — one RPC;
  * reintegration ops mds_reint_{create,unlink,rename,link,setattr} (§6.4.2)
    with transactional undo records;
  * unlink returns the LOV EA + llog cookies so the *client* destroys the
    data objects; OSTs confirm with llog_cancel once their destroy commits
    (ch. 8.4); pending records re-shipped after MDS recovery (§6.7.5);
  * clustered MDS (§6.7): each MDS owns an inode group; mkdir round-robins
    new directories onto other MDSes; large directories *split* into hash
    buckets on peer MDSes (master inode EA lists bucket fids); cross-MDS
    rename/link/unlink via MDS-MDS RPCs with *dependency tracking* feeding
    the consistent-cut snapshot (§6.7.6.3, implemented in recovery.py);
  * metadata write-back-cache grants: a client may be granted a subtree
    lock + a preallocated fid range and reintegrate batched update records
    later (ch. 17, §6.5);
  * open files tracked per-export so failed clients' orphans get cleaned;
  * per-MDT changelog (core.changelog): every reint/close/remote op emits
    a typed record inside its transaction undo scope; consumers register/
    read/clear over ptlrpc (changelog_* ops) with min-bookmark purging.
"""
from __future__ import annotations

import bisect
import dataclasses
import itertools
from typing import Any, Optional

from repro.core import changelog as cl_mod
from repro.core import dlm as dlm_mod
from repro.core import fail as fail_mod
from repro.core import llog as llog_mod
from repro.core import ptlrpc as R
from repro.core import recovery as rec_mod

ROOT_FID = (0, 1, 1)

S_IFDIR, S_IFREG, S_IFLNK = "dir", "file", "symlink"


@dataclasses.dataclass
class Inode:
    fid: tuple
    ftype: str
    mode: int = 0o644
    uid: int = 0
    gid: int = 0
    nlink: int = 1
    mtime: float = 0.0
    size: int = 0
    ea: dict = dataclasses.field(default_factory=dict)
    entries: dict = dataclasses.field(default_factory=dict)  # dirs
    symlink: str = ""
    # mtime/size delegated to OSTs while a writer has the file open (§6.9.1)
    mtime_on_ost: bool = False
    # LOCAL directory fids this inode is (or was) linked under: the dir
    # PR locks covering clients' cached copies of our attributes (dentry
    # + attr cache). setattr/close revoke these. Add-only — a stale
    # entry costs a spurious revocation, never a stale cache.
    pfids: set = dataclasses.field(default_factory=set)
    # (peer_uuid, dir_fid) pairs for directories a PEER MDT owns that
    # link this inode (cross-MDT mkdir/create halves): an attr change
    # here forwards a revoke_dir_locks to the peer so clients scanning
    # THAT directory drop their (one-shot) copies of our attrs too.
    remote_pfids: set = dataclasses.field(default_factory=set)

    def attrs(self) -> dict:
        return {"fid": self.fid, "type": self.ftype, "mode": self.mode,
                "uid": self.uid, "gid": self.gid, "nlink": self.nlink,
                "mtime": self.mtime, "size": self.size,
                "mtime_on_ost": self.mtime_on_ost,
                "nentries": len(self.entries) if self.ftype == S_IFDIR
                else None,
                "has_buckets": "buckets" in self.ea}


def _cl_create_type(ftype: str) -> str:
    return {S_IFDIR: cl_mod.CL_MKDIR,
            S_IFLNK: cl_mod.CL_SYMLINK}.get(ftype, cl_mod.CL_CREAT)


def _pin_remote_fid(req, rep):
    """MDS-MDS create fixup: pin the peer-assigned fid into the retained
    request so REPLAY after a peer crash recreates the SAME inode (the
    create-with-requested-id rule, §5.2.3 — without it a replayed
    remote_mkdir mints a fresh fid the coordinator's entry never finds)."""
    if (rep.data or {}).get("fid"):
        req.body["fid"] = tuple(rep.data["fid"])


def fhash(name: str, n: int) -> int:
    """Stable directory-bucket hash."""
    h = 2166136261
    for ch in name.encode():
        h = ((h ^ ch) * 16777619) & 0xFFFFFFFF
    return h % n


class MdsTarget(R.Target):
    svc_kind = "mds"

    SPLIT_THRESHOLD = 1 << 30         # entries before a dir splits (set low
                                      # in tests; effectively off by default)
    SPLIT_WAYS = 4

    def __init__(self, uuid: str, node: R.Node, inode_group: int,
                 peers: dict | None = None):
        super().__init__(uuid, node)
        self.inode_group = inode_group
        self.inodes: dict[tuple, Inode] = {}
        self._ino_seq = itertools.count(2)
        self.rpc = R.RpcClient(node)
        self.ldlm = dlm_mod.LdlmNamespace(self, self.rpc,
                                          intent_policy=self.intent_policy)
        self.ldlm.conflict_cb = self._note_contention
        self.peers: dict[str, R.Import] = {}      # peer mds uuid -> import
        self.peer_nids: dict[str, list] = peers or {}
        self.unlink_llog = llog_mod.LlogCatalog(f"{uuid}-unlink")
        # consumer bookmarks are journaled with the catalog header: the
        # register/clear/deregister header updates run through this MDT's
        # transaction machinery (crash-atomic with the purge they imply)
        self.changelog = cl_mod.Changelog(uuid, txn=self.txn,
                                          now=lambda: self.sim.now)
        # highest transno of THIS mds known to be inside the CLUSTER-wide
        # committed consistent cut (§6.7.6.3): changelog_read never serves
        # a record above it, so a multi-MDT rollback cannot retract a
        # record a consumer has already seen
        self.cluster_cut = 0
        # cut-derivation cache: deriving the cut costs O(peers) RPCs, so
        # the serving path re-derives at most once per `cut_staleness`
        # virtual seconds; a local commit invalidates (new records may
        # now enter the cut), a snapshot()/prune_history push refreshes
        self.cut_staleness = 0.05
        self._cut_checked_at: float | None = None
        self.commit_callbacks.append(self._cut_cache_invalidate)
        # dependency records for the consistent cut (§6.7.6.3):
        # [(own_transno, {peer_uuid: peer_transno})]
        self.dep_log: list[tuple[int, dict]] = []
        self.undo_history: list[tuple[int, Any]] = []   # kept past commit
        # batch-collection mode (op_reint_batch): while set, txn_meta
        # accumulates (undo, deps) here instead of opening transactions,
        # so the whole batch lands as ONE undo-scoped transaction
        self._batch_txn: dict | None = None
        self.contention: dict[tuple, int] = {}    # fid -> recent conflicts
        self.osts: dict[str, R.Import] = {}       # for orphan cleanup
        if inode_group == 0:
            root = Inode(ROOT_FID, S_IFDIR, mode=0o755, nlink=2)
            self.inodes[ROOT_FID] = root
        ops = self.ops
        ops["getattr"] = self.op_getattr
        ops["getattr_bulk"] = self.op_getattr_bulk
        ops["readdir"] = self.op_readdir
        ops["reint"] = self.op_reint
        ops["reint_batch"] = self.op_reint_batch
        ops["close"] = self.op_close
        ops["statfs"] = self.op_statfs
        ops["wbc_request"] = self.op_wbc_request
        ops["prealloc_fids"] = self.op_prealloc_fids
        ops["llog_cancel"] = self.op_llog_cancel
        ops["bucket_insert"] = self.op_bucket_insert
        ops["bucket_lookup"] = self.op_bucket_lookup
        ops["bucket_remove"] = self.op_bucket_remove
        ops["remote_mkdir"] = self.op_remote_mkdir
        ops["remote_create"] = self.op_remote_create
        ops["remote_link"] = self.op_remote_link
        ops["remote_unlink_inode"] = self.op_remote_unlink_inode
        ops["dir_nonempty"] = self.op_dir_nonempty
        ops["remote_nlink_adjust"] = self.op_remote_nlink_adjust
        ops["revoke_dir_locks"] = self.op_revoke_dir_locks
        ops["dep_records"] = self.op_dep_records
        ops["rollback_to"] = self.op_rollback_to
        ops["prune_history"] = self.op_prune_history
        ops["sync_commit"] = self.op_sync_commit
        ops["peer_rebooted"] = self.op_peer_rebooted
        ops["changelog_register"] = self.op_changelog_register
        ops["changelog_deregister"] = self.op_changelog_deregister
        ops["changelog_read"] = self.op_changelog_read
        ops["changelog_clear"] = self.op_changelog_clear

    # ------------------------------------------------------------- wiring
    def connect_peer(self, uuid: str, nids: list[str]):
        self.peer_nids[uuid] = nids

    def _peer(self, uuid: str) -> R.Import:
        imp = self.peers.get(uuid)
        if imp is None:
            imp = self.rpc.import_target(uuid, self.peer_nids[uuid], "mds")
            # a peer evicting this import loses our replayable cross-MDT
            # halves: cross-check the namespace halves right away
            imp.evict_cbs.append(lambda u=uuid: self._peer_evicted(u))
            self.peers[uuid] = imp
        return imp

    def _peer_evicted(self, peer_uuid: str):
        """Our MDS-MDS import got evicted (-107): the replay queue died
        with it, so cross-MDT halves this side already applied may now
        dangle (entry here, inode lost over there). Run the ROADMAP'd
        post-eviction namespace cross-check against that peer."""
        self.sim.stats.count("mds.peer_evicted")
        self.namespace_crosscheck(peer_uuid)

    def namespace_crosscheck(self, peer_uuid: str) -> int:
        """Verify every dirent pointing at an inode the peer owns still
        resolves there; drop dangling entries (the state a lost replay
        queue leaves behind). An unreachable peer proves nothing — those
        entries are kept. Returns the number of entries dropped."""
        dropped = 0
        imp = self._peer(peer_uuid)
        for ino in list(self.inodes.values()):
            if ino.ftype != S_IFDIR:
                continue
            for name, fid in list(ino.entries.items()):
                fid = tuple(fid)
                if fid[0] == self.inode_group or fid in self.inodes:
                    continue
                if self._peer_for_group(fid[0]) != peer_uuid:
                    continue
                try:
                    imp.request("getattr", {"fid": fid}, no_recover=True)
                except R.RpcError as e:
                    if e.status == -2:       # the peer half is gone
                        ino.entries.pop(name, None)
                        dropped += 1
                except R.TimeoutError_:
                    pass                     # unreachable: keep the entry
        if dropped:
            self.sim.stats.count("mds.crosscheck_dropped", dropped)
        return dropped

    def connect_ost(self, uuid: str, nids: list[str]):
        self.osts[uuid] = self.rpc.import_target(uuid, nids, "ost")

    def on_restart(self):
        """A restarted MDS announces itself to its peers (the pinger's
        job in real Lustre, §4.4.2.5 — a synchronous stand-in here): each
        peer reconnects its MDS-MDS import, detects the reboot, and
        replays the cross-MDT halves this target lost, inside the
        recovery window. A target restarting while its node is still
        powered off (fail_node) cannot announce — peers learn of the
        reboot on next contact (-108) instead."""
        if self.node.nid in self.sim.faults.down_nids:
            return
        for uuid in self.peer_nids:
            try:
                self._peer(uuid).request("peer_rebooted",
                                         {"peer": self.uuid},
                                         no_recover=True)
            except (R.RpcError, R.TimeoutError_):
                pass

    def op_peer_rebooted(self, req: R.Request) -> R.Reply:
        """Peer notification: our import to `peer` is stale — reconnect
        now (detecting the reboot) so our half-transactions replay into
        its recovery window instead of waiting for the next cross-MDT
        operation to stumble over -108."""
        imp = self.peers.get(req.body.get("peer", ""))
        if imp is not None:
            # a FULL import must drop its now-stale connection first; a
            # DISCONN one (we noticed the outage mid-flap and nothing
            # retried since) just needs the reconnect kick
            if imp.state == "FULL":
                imp.state = "DISCONN"
            try:
                imp._connect_cycle()       # detects reboot -> replays
            except R.TimeoutError_:
                pass
        # a peer reboot changes what the cut can prove: drop the cached
        # derivation so the next gated read re-derives immediately
        self._cut_checked_at = None
        return R.Reply()

    # --------------------------------------------------------------- fids
    def new_fid(self) -> tuple:
        ino = next(self._ino_seq)
        return (self.inode_group, ino, 1)

    def _get(self, fid) -> Inode:
        ino = self.inodes.get(tuple(fid))
        if ino is None:
            raise R.RpcError(-2, f"no inode {fid}")      # ENOENT
        return ino

    # --------------------------------------------------------- changelog
    def _cl(self, req: Optional[R.Request], cl_type: str, fid, *,
            pfid=None, name: str = "", **extra):
        """Emit one changelog record attributed to the requesting client.
        Returns the record (or None while no consumer is registered) —
        the caller's transaction undo MUST retract it so an aborted or
        rolled-back reint leaves no phantom record. For MDS-MDS halves of
        cross-MDT ops the coordinator forwards the real originator in the
        request body (origin_client/origin_jobid); otherwise the requester
        IS the originator. Every emit site opens its transaction right
        after emitting, so the owning transno is the next one."""
        # the idle-consumer sweep runs BEFORE the owning transno below is
        # computed: a collected consumer's deregister is its own header
        # transaction and would otherwise skew transno + 1
        self.changelog.maybe_gc()
        client = jobid = ""
        if req is not None:
            client = req.body.get("origin_client", req.client_uuid)
            jobid = req.body.get("origin_jobid", req.jobid)
        return self.changelog.emit(
            cl_type, fid, pfid=pfid, name=name, time=self.sim.now,
            client=client, jobid=jobid, transno=self.transno + 1, **extra)

    def _cl_origin(self, req: Optional[R.Request]) -> dict:
        """Origin fields a coordinator forwards with MDS-MDS requests so
        the peer's record half attributes the real client, not the
        internal MDS RpcClient."""
        if req is None:
            return {}
        return {"origin_client": req.body.get("origin_client",
                                              req.client_uuid),
                "origin_jobid": req.body.get("origin_jobid", req.jobid)}

    def _cl_stabilize(self, recs):
        """A record handed to a consumer (or purged on its behalf) must
        be durable first — commit the journal if any of `recs` is still
        in the uncommitted tail, so nothing a consumer has seen can be
        rolled back by a crash."""
        if any(r.transno > self.committed_transno for r in recs):
            self.commit()

    # ------------------------------------------ cluster-cut record gating
    def _collect_dep_states(self) -> dict:
        """Own + peer (committed, dep-vector) states for the consistent-cut
        computation. An unreachable peer contributes committed=0: its
        halves cannot be proven durable, so nothing depending on them is
        served until it returns."""
        states = {self.uuid: {"committed": self.committed_transno,
                              "deps": [(t, dict(d))
                                       for t, d in self.dep_log]}}
        self._last_collect_ok = True
        for uuid in self.peer_nids:
            try:
                states[uuid] = self._peer(uuid).request(
                    "dep_records", {}, no_recover=True).data
            except (R.RpcError, R.TimeoutError_):
                states[uuid] = {"committed": 0, "deps": []}
                self._last_collect_ok = False
        return states

    def _advance_cluster_cut(self, need: int):
        """Try to move the cluster-committed cut past transno `need`:
        compute the cut over everyone's dep records; if `need` is still
        excluded (some dependency's peer half uncommitted), ask the peers
        to flush their journals and recompute. The cut only advances —
        commits are durable, so a transno once inside it stays inside."""
        for attempt in range(2):
            states = self._collect_dep_states()
            cut = rec_mod.compute_consistent_cut(states).get(self.uuid, 0)
            if cut >= need or attempt:
                break
            for uuid in self.peer_nids:       # force the blocking halves out
                try:
                    self._peer(uuid).request("sync_commit", {},
                                             no_recover=True)
                except (R.RpcError, R.TimeoutError_):
                    pass
        self.cluster_cut = max(self.cluster_cut, cut)
        # cache only a FULL round: with a peer unreachable nothing was
        # proven — the next read must retry, not trust a stale failure
        self._cut_checked_at = self.sim.now \
            if getattr(self, "_last_collect_ok", True) else None

    def _cut_cache_invalidate(self, committed: int | None = None):
        self._cut_checked_at = None

    def _cut_stale(self) -> bool:
        return self._cut_checked_at is None or \
            self.sim.now - self._cut_checked_at >= self.cut_staleness

    def _gate_at_cluster_cut(self, recs):
        """Serve only records at or below the CLUSTER-committed consistent
        cut (§6.7.6.3): local commit protects against single-MDT crashes,
        the cut protects against the multi-MDT rollback retracting a
        committed cross-MDT record a consumer already read. Records above
        the cut are withheld until it advances (they stay retained).

        The O(peers) dep-vector round runs at most once per
        `cut_staleness` window: a burst of gated reads pays ONE round,
        records above the cached cut are simply withheld until the window
        expires (or a commit/snapshot invalidates the cache)."""
        if not recs:
            return recs
        if not self.peer_nids:
            self._cl_stabilize(recs)      # single MDT: the commit IS the cut
            return recs
        hi = max(r.transno for r in recs)
        if hi > self.cluster_cut and self._cut_stale():
            if hi > self.committed_transno:
                # our own tail must be durable before it can enter the cut
                self.commit()
            self._advance_cluster_cut(hi)
        served = [r for r in recs if r.transno <= self.cluster_cut]
        self._cl_stabilize(served)        # no-op: cut <= committed
        return served

    def op_sync_commit(self, req: R.Request) -> R.Reply:
        """Peer-requested journal flush (a serving MDS forcing the peer
        halves of cross-MDT transactions into the consistent cut)."""
        self.commit()
        return R.Reply(data={"committed": self.committed_transno})

    def op_changelog_register(self, req: R.Request) -> R.Reply:
        uid = self.changelog.register()
        # the id handed back must survive a restart: commit the header txn
        self.commit()
        # transno-bearing so the reply cache absorbs resends: a register
        # whose reply was lost must NOT mint a second consumer (whose
        # stale bookmark would pin the stream until idle GC)
        return R.Reply(data={"id": uid, "last_idx": self.changelog.last_idx},
                       transno=self.transno)

    def op_changelog_deregister(self, req: R.Request) -> R.Reply:
        try:
            self.changelog.deregister(req.body["id"])
        except KeyError:
            raise R.RpcError(-2, req.body.get("id", ""))
        # like register/clear: the ack must be durable, or a crash would
        # resurrect the consumer (whose stale bookmark pins the stream)
        self.commit()
        # reply-cache-covered: a resent deregister must be answered from
        # the cache, not re-executed into a spurious -ENOENT
        return R.Reply(transno=self.transno)

    def op_changelog_read(self, req: R.Request) -> R.Reply:
        b = req.body
        if b.get("id") not in self.changelog.users:
            raise R.RpcError(-2, b.get("id", ""))
        self.changelog.touch(b["id"])
        since = b.get("since_idx")
        if since is None:
            # default: everything the consumer has not cleared yet
            since = self.changelog.users[b["id"]]
        recs = self._gate_at_cluster_cut(
            self.changelog.read(since, b.get("count", 0)))
        # record payload moves like a bulk readdir page
        wire = [r.to_wire() for r in recs]
        return R.Reply(data={"records": wire,
                             "last_idx": self.changelog.last_idx},
                       bulk_nbytes=R.wire_size(wire))

    def op_changelog_clear(self, req: R.Request) -> R.Reply:
        uid = req.body.get("id")
        if uid not in self.changelog.users:
            raise R.RpcError(-22, uid or "")
        fail_mod.maybe_fail("mds.changelog.clear")
        up_to = req.body["up_to"]
        # purging is destructive: anything acked must be durable first —
        # locally AND inside the cluster cut (an ack above the cut is
        # clamped down; the consumer can only have seen served records)
        acked = [r for r in self.changelog.records() if r.idx <= up_to]
        served = self._gate_at_cluster_cut(acked)
        if len(served) < len(acked):
            up_to = max((r.idx for r in served),
                        default=self.changelog.users[uid])
        self.changelog.clear(uid, up_to)
        fail_mod.maybe_fail("mds.changelog.clear.applied")
        # journal the bookmark with the clear's transaction: the ack the
        # consumer receives is durable across MDS restart (no re-delivery
        # of cleared records after recovery)
        self.commit()
        # reply-cache-covered like every other update op
        return R.Reply(data={"purged_to": self.changelog.purged_to,
                             "records": len(self.changelog.catalog.pending())},
                       transno=self.transno)

    # ---------------------------------------------------- txn w/ history
    def crash(self):
        super().crash()
        # the rolled-back tail's retained-undo/dependency entries are
        # dead — their undos already ran, and REPLAY will reuse their
        # transnos with fresh closures; keeping both would double-undo
        # on a later consistent-cut rollback
        self.undo_history = [(t, u) for t, u in self.undo_history
                             if t <= self.committed_transno]
        self.dep_log = [(t, d) for t, d in self.dep_log
                        if t <= self.committed_transno]

    def txn_meta(self, undo, deps: dict | None = None) -> int:
        """A metadata transaction: normal undo (crash rollback) + retained
        undo history + dependency record for the consistent cut.

        In batch-collection mode (op_reint_batch) nothing is opened:
        the (undo, deps) pair is parked on the batch and the would-be
        batch transno is returned — `self.transno` does not advance, so
        every changelog emit in the batch stamps the SAME transno."""
        if self._batch_txn is not None:
            self._batch_txn["undos"].append(undo)
            if deps:
                bd = self._batch_txn["deps"]
                for peer, t in deps.items():
                    bd[peer] = max(bd.get(peer, 0), t)
            return self.transno + 1
        transno = self.txn(undo)
        self.undo_history.append((transno, undo))
        if deps:
            self.dep_log.append((transno, dict(deps)))
        if len(self.undo_history) > 4096:
            self.undo_history = self.undo_history[-2048:]
        return transno

    # ------------------------------------------------------------ intents
    def intent_policy(self, req: R.Request, res) -> tuple[dict, bool]:
        """DLM intent execution (§7.5): run the operation while granting.
        Returns (intent_data, grant_lock)."""
        it = req.body["intent"]
        op = it["op"]
        self.sim.stats.count(f"mds.intent.{op}")
        if op == "lookup" or op == "getattr":
            data = self._intent_lookup(it)
            return data, data.get("status", 0) == 0
        if op == "readdir":
            data = self._intent_readdir(it)
            return data, data.get("status", 0) == 0
        if op == "open":
            data = self._intent_open(it, req)
            return data, data.get("status", 0) == 0 and not it.get("no_lock")
        if op == "wbc":
            granted = self._wbc_decision(tuple(it["fid"]))
            return {"wbc_granted": granted}, granted
        return {"status": -38}, False

    def _intent_lookup(self, it) -> dict:
        parent = self.inodes.get(tuple(it["parent"]))
        if parent is None:
            return {"status": -2}
        name = it["name"]
        if "buckets" in parent.ea:
            b = parent.ea["buckets"]
            bfid = b[fhash(name, len(b))]
            if tuple(bfid)[0] != self.inode_group:
                return {"status": 0, "redirect": bfid}
            parent = self._get(bfid)
        fid = parent.entries.get(name)
        if fid is None:
            # negative dentry: cacheable non-existence (§6.2.1)
            return {"status": -2, "negative": True}
        inode = self.inodes.get(tuple(fid))
        if inode is None:
            return {"status": 0, "fid": fid, "remote": True}
        d = {"status": 0, "attrs": inode.attrs()}
        if it.get("want_ea"):
            d["ea"] = dict(inode.ea)
        return d

    def _intent_readdir(self, it) -> dict:
        """readdir-plus (ISSUE-5): ONE page of directory entries, each
        carrying the entry's attributes (+ EA with the LOV stripe
        descriptor) when its inode lives on THIS MDT, served under the
        directory's PR lock the enqueue grants. Entries whose inode a
        peer MDT owns are flagged `remote` — the LMV batch-resolves them
        with ONE getattr_bulk per owning MDT, not one RPC per name. A
        split directory returns its bucket fids; the LMV pages each
        bucket at ITS MDS the same way (one page per MDT)."""
        inode = self.inodes.get(tuple(it["fid"]))
        if inode is None:
            return {"status": -2}
        if inode.ftype != S_IFDIR:
            return {"status": -20}                      # ENOTDIR
        page = max(1, int(it.get("page_size") or 64))
        names = sorted(inode.entries)
        # name cursor, not a numeric index: a create/unlink between two
        # page RPCs must not shift later pages (an index cursor would
        # skip or duplicate entries that existed for the whole scan)
        after = it.get("after")
        if after is not None:
            names = names[bisect.bisect_right(names, after):]
        entries = {}
        for name in names[:page]:
            fid = tuple(inode.entries[name])
            child = self.inodes.get(fid)
            e = {"fid": fid}
            if child is None:
                e["remote"] = True
            else:
                child.pfids.add(inode.fid)
                e["attrs"] = child.attrs()
                if it.get("want_ea"):
                    e["ea"] = dict(child.ea)
            entries[name] = e
        d = {"status": 0, "entries": entries,
             "next": names[page - 1] if len(names) > page else None,
             "buckets": inode.ea.get("buckets")}
        self.sim.stats.count("mds.readdir_plus_entries", len(entries))
        return d

    def op_getattr_bulk(self, req: R.Request) -> R.Reply:
        """Batched getattr: attrs (+EA) for MANY fids in ONE RPC — the
        statahead prefetch and the LMV's cross-MDT readdir-plus merge
        ride on this instead of a getattr per name. Unknown fids answer
        None (the caller falls back per entry)."""
        out = []
        for f in req.body["fids"]:
            ino = self.inodes.get(tuple(f))
            if ino is None:
                out.append(None)
                continue
            d = {"attrs": ino.attrs()}
            if req.body.get("want_ea"):
                d["ea"] = dict(ino.ea)
            out.append(d)
        self.sim.stats.count("mds.getattr_bulk_fids", len(out))
        return R.Reply(data={"attrs": out}, bulk_nbytes=R.wire_size(out))

    def _intent_open(self, it, req: R.Request) -> dict:
        """open_namei work: lookup [+create] + open (§6.4.3). Returns the
        `disposition` bitmap of which phases ran. An entry whose inode a
        peer MDT owns (the state a cross-MDT rename leaves behind) gets
        the `_intent_lookup`-style remote redirect: the LMV re-issues the
        open BY FID at the owning MDT (`by_fid`)."""
        flags = it.get("flags", "")
        if it.get("by_fid"):
            # redirected second hop: open the inode this MDT owns directly
            disp = ["open"]
            inode = self.inodes.get(tuple(it["fid"]))
            if inode is None:
                return {"status": -2, "disposition": disp}
            return self._open_tail(inode, flags, req, disp,
                                   created=False, transno=0)
        disp = ["lookup"]
        parent = self._get(it["parent"])
        name = it["name"]
        fid = parent.entries.get(name)
        if fid is None and "buckets" in parent.ea:
            b = parent.ea["buckets"]
            bfid = b[fhash(name, len(b))]
            bucket = self.inodes.get(tuple(bfid))
            if bucket is not None:
                fid = bucket.entries.get(name)
        created = False
        if fid is None:
            if "c" not in flags:
                return {"status": -2, "disposition": disp}
            disp.append("create")
            # the create changes the parent's OWN attrs (nentries) too:
            # revoke the locks covering cached copies of them as well
            self._revoke_client_locks(parent.fid, *parent.pfids,
                                      exclude=self._requester(req))
            fid = tuple(it["fid"]) if it.get("fid") else self.new_fid()
            inode = Inode(fid, S_IFREG, mode=it.get("mode", 0o644),
                          mtime=self.sim.now)
            self.inodes[fid] = inode
            self._dir_insert(parent, name, fid,
                             exclude=self._requester(req))
            created = True
            clrec = self._cl(req, cl_mod.CL_CREAT, fid, pfid=parent.fid,
                             name=name, mode=inode.mode)

            def undo():
                self._dir_remove_raw(parent, name)
                self.inodes.pop(fid, None)
                self.changelog.retract(clrec)
            transno = self.txn_meta(undo)
        else:
            if "x" in flags and "c" in flags:
                return {"status": -17, "disposition": disp}   # EEXIST
            fid = tuple(fid)
            if fid not in self.inodes and fid[0] != self.inode_group:
                # inode half lives on a peer MDT (cross-MDT rename
                # residue): redirect, exactly as _intent_lookup does
                return {"status": 0, "disposition": disp,
                        "remote": True, "fid": fid}
            transno = 0
        inode = self._get(fid)
        return self._open_tail(inode, flags, req, disp, created, transno)

    def _open_tail(self, inode: Inode, flags: str, req: R.Request,
                   disp: list, created: bool, transno: int) -> dict:
        """The open phase shared by the local and by-fid (redirected)
        paths: symlink short-circuit, per-export open handle, mtime
        delegation to the OSTs while open for write."""
        disp = disp + ["open"] if disp[-1] != "open" else disp
        if inode.ftype == S_IFLNK:
            return {"status": 0, "disposition": disp, "symlink": inode.symlink,
                    "attrs": inode.attrs()}
        exp = self.exports[req.client_uuid]
        handle = len(exp.data.setdefault("opens", {})) + 1
        exp.data["opens"][handle] = inode.fid
        if "w" in flags and inode.ftype == S_IFREG \
                and not inode.mtime_on_ost:
            # OSTs own mtime/size while open-write — clients caching the
            # old attrs (mtime_on_ost=False) would skip the OST glimpse
            # and serve a frozen size: revoke their covering dir locks
            self._revoke_client_locks(*inode.pfids,
                                      exclude=self._requester(req))
            self._revoke_remote_pfids(inode, req)
            inode.mtime_on_ost = True
        return {"status": 0, "disposition": disp, "created": created,
                "attrs": inode.attrs(), "ea": dict(inode.ea),
                "open_handle": handle, "_transno": transno}

    def _revoke_client_locks(self, *fids, exclude: str | None = None):
        """§6.4.2: the MDS takes a write lock on the parent directories (in
        fid order) before a namespace update — here that means revoking
        client PR locks (blocking ASTs) so cached dentries invalidate.

        `exclude` spares the REQUESTING client's own locks: it made the
        change and fixes its own caches locally (fsio drops the touched
        dentry/attr entries), so ASTing it back would only burn an RPC
        round trip per operation and tear down its whole-directory cache
        for nothing (the double-AST-per-create problem)."""
        for fid in sorted(set(tuple(f) for f in fids)):
            res = self.ldlm.resources.get(("fid", *fid))
            if not res:
                continue
            for lk in list(res.granted):
                if exclude is not None and lk.client_uuid == exclude:
                    continue
                if lk.mode in ("PR", "EX", "PW", "CW"):
                    ok = self.ldlm._blocking_ast(lk)
                    if not ok:
                        self.ldlm.evict_client(lk.client_uuid)
            self._note_contention(("fid", *fid))

    @staticmethod
    def _requester(req) -> str | None:
        """Client uuid to spare from cache revocation: the direct
        requester maintains its own caches after its own operation.
        This includes a WBC reint_batch — revoking the flusher's own
        subtree EX lock would tear down the write-back cache on its
        FIRST background flush; the client invalidates its pre-WBC
        dentry/attr entries itself when it applies a shadow update."""
        if req is None:
            return None
        return req.client_uuid

    def _note_contention(self, res_name: tuple):
        """Lock-callback traffic feeds the WBC switching policy (§6.5.2)."""
        if res_name and res_name[0] == "fid":
            fid = tuple(res_name[1:])
            self.contention[fid] = self.contention.get(fid, 0) + 1

    # ---------------------------------------------------------- wbc grant
    def _wbc_decision(self, fid: tuple) -> bool:
        """§6.5: default to a subtree (write-back) lock unless the resource
        saw recent lock-callback traffic."""
        return self.contention.get(fid, 0) < 2

    def op_wbc_request(self, req: R.Request) -> R.Reply:
        fid = tuple(req.body["fid"])
        ok = self._wbc_decision(fid)
        return R.Reply(data={"granted": ok})

    def op_prealloc_fids(self, req: R.Request) -> R.Reply:
        n = req.body.get("count", 64)
        fids = [self.new_fid() for _ in range(n)]
        return R.Reply(data={"fids": fids})

    # -------------------------------------------------------------- plain
    def op_getattr(self, req: R.Request) -> R.Reply:
        inode = self._get(req.body["fid"])
        d = {"attrs": inode.attrs()}
        if req.body.get("want_ea"):
            d["ea"] = dict(inode.ea)
        if inode.ftype == S_IFLNK:
            d["symlink"] = inode.symlink
        return R.Reply(data=d)

    def op_readdir(self, req: R.Request) -> R.Reply:
        inode = self._get(req.body["fid"])
        if inode.ftype != S_IFDIR:
            raise R.RpcError(-20)           # ENOTDIR
        entries = dict(inode.entries)
        nbytes = sum(len(k) + 24 for k in entries)
        # split dir: the LMV iterates the buckets client-side (§6.7.3);
        # bucket fids on THIS mds could be merged here, but uniform
        # client-side iteration keeps the protocol single-shaped.
        return R.Reply(data={"entries": entries, "buckets":
                             inode.ea.get("buckets")}, bulk_nbytes=nbytes)

    def op_statfs(self, req: R.Request) -> R.Reply:
        return R.Reply(data={"inodes": len(self.inodes),
                             "group": self.inode_group})

    # ---------------------------------------------------------- monitor
    def mon_stats(self) -> dict:
        return {
            "namespace": {"inodes": len(self.inodes),
                          "inode_group": self.inode_group,
                          "pending_unlink_llog":
                              len(self.unlink_llog.pending())},
            "locks": {
                "resources": len(self.ldlm.resources),
                "granted": sum(len(r.granted)
                               for r in self.ldlm.resources.values()),
                "waiting": sum(len(r.waiting)
                               for r in self.ldlm.resources.values()),
            },
            "changelog": self.changelog.info(),
            "cluster_cut": self.cluster_cut,
        }

    def op_close(self, req: R.Request) -> R.Reply:
        exp = self.exports[req.client_uuid]
        fid = exp.data.get("opens", {}).pop(req.body.get("handle"), None)
        if fid is None and req.body.get("fid"):
            # replay after server restart: open-handle table was volatile,
            # the request carries the fid (§29: open replay)
            fid = tuple(req.body["fid"])
            if fid not in self.inodes:
                fid = None
        b = req.body
        if fid is not None and (b.get("size") is not None
                                or b.get("mtime") is not None):
            inode = self._get(fid)
            # size/mtime land on the MDS (and mtime_on_ost flips off):
            # cached attrs under the parents' dir locks are stale now
            self._revoke_client_locks(*inode.pfids,
                                      exclude=self._requester(req))
            self._revoke_remote_pfids(inode, req)
            old = (inode.size, inode.mtime, inode.mtime_on_ost)
            if b.get("size") is not None:
                inode.size = b["size"]
            if b.get("mtime") is not None:
                inode.mtime = max(inode.mtime, b["mtime"])
            inode.mtime_on_ost = False
            clrec = self._cl(req, cl_mod.CL_CLOSE, fid,
                             size=inode.size, mtime=inode.mtime)

            def undo():
                inode.size, inode.mtime, inode.mtime_on_ost = old
                self.changelog.retract(clrec)
            return R.Reply(transno=self.txn_meta(undo))
        return R.Reply()

    # ---------------------------------------------------- VBR (ISSUE-10)
    @staticmethod
    def _vbr_rec_keys(r: dict) -> list:
        """The inodes one reint record mutates: the parent dir(s) whose
        entry set changes and the target inode whose attrs change."""
        keys = []
        for f in ("parent", "fid", "src", "dst"):
            v = r.get(f)
            if v is not None:
                k = ("ino",) + tuple(v)
                if k not in keys:
                    keys.append(k)
        return keys

    def vbr_keys_for(self, req: R.Request) -> list:
        op = req.opcode
        if op == "reint":
            return self._vbr_rec_keys(req.body.get("rec") or {})
        if op == "reint_batch":
            keys: list = []
            seen: set = set()
            for r in req.body.get("records", ()):
                for k in self._vbr_rec_keys(r):
                    if k not in seen:
                        seen.add(k)
                        keys.append(k)
            return keys
        if op == "close":
            b = req.body
            if b.get("size") is None and b.get("mtime") is None:
                return []                  # attr-less close: no txn
            exp = self.exports.get(req.client_uuid)
            fid = None
            if exp is not None:
                fid = exp.data.get("opens", {}).get(b.get("handle"))
            if fid is None and b.get("fid"):
                fid = tuple(b["fid"])
            return [("ino",) + tuple(fid)] if fid is not None else []
        return []

    # ----------------------------------------------------- reintegration
    def op_reint(self, req: R.Request) -> R.Reply:
        fail_mod.maybe_fail("mds.reint.before")
        r = req.body["rec"]
        fn = getattr(self, f"_reint_{r['type']}", None)
        if fn is None:
            raise R.RpcError(-38, r["type"])
        self.sim.stats.count(f"mds.reint.{r['type']}")
        return fn(r, req)

    def op_reint_batch(self, req: R.Request) -> R.Reply:
        """WBC flush: apply update records in order as ONE undo-scoped
        transaction (ch. 17, §6.5.3) with per-record status.

        Batch-collection mode diverts every record's txn_meta into an
        accumulator (transno frozen), so all changelog emits stamp the
        single batch transno; one real txn_meta at the end installs a
        composite undo running the records' undos in reverse. The reply
        carries that transno, so the batch rides the ordinary reply
        cache + replay machinery: a resend is answered from the cache, an
        MDS crash rolls the whole batch back and client replay re-applies
        it exactly once. A record that fails (e.g. EEXIST) contributes
        only its -errno status — its partial effects (none today: every
        handler checks before mutating) are unwound record-locally."""
        out = []
        self._batch_txn = {"undos": [], "deps": {}}
        try:
            for r in req.body["records"]:
                fail_mod.maybe_fail("mds.reint_batch")
                fn = getattr(self, f"_reint_{r['type']}", None)
                if fn is None:
                    out.append({"status": -38, "data": None})
                    continue
                self.sim.stats.count(f"mds.reint.{r['type']}")
                n0 = len(self._batch_txn["undos"])
                try:
                    rep = fn(r, req)
                    out.append({"status": rep.status, "data": rep.data})
                except R.RpcError as e:
                    # record-local rollback: a failing record must not
                    # leave half-applied state inside the batch
                    for u in reversed(self._batch_txn["undos"][n0:]):
                        u()
                    del self._batch_txn["undos"][n0:]
                    out.append({"status": e.status, "data": None})
        except BaseException:
            # induced crash (FailLocHit) or bug mid-batch: no transaction
            # was opened yet, so the target's undo_log knows nothing of
            # the applied records — unwind them here before propagating
            for u in reversed(self._batch_txn["undos"]):
                u()
            self._batch_txn = None
            raise
        bt, self._batch_txn = self._batch_txn, None
        if not bt["undos"]:
            return R.Reply(data={"results": out})

        def undo_batch():
            for u in reversed(bt["undos"]):
                u()
        transno = self.txn_meta(undo_batch, bt["deps"] or None)
        return R.Reply(data={"results": out}, transno=transno)

    def _dir_insert(self, parent: Inode, name: str, fid: tuple,
                    is_dir: bool = False, exclude: str | None = None):
        child = self.inodes.get(tuple(fid))
        if child is not None:
            # the master dir's PR lock covers clients' cached attrs of
            # this child (readdir-plus / statahead): remember it so a
            # later setattr/close revokes that lock
            child.pfids.add(parent.fid)
        if "buckets" in parent.ea:
            b = parent.ea["buckets"]
            bfid = tuple(b[fhash(name, len(b))])
            if bfid[0] == self.inode_group:
                self._get(bfid).entries[name] = fid
                if child is not None:
                    child.pfids.add(bfid)       # bucket lock covers too
                self._revoke_client_locks(bfid, exclude=exclude)
            else:
                peer = self._peer_for_group(bfid[0])
                rep = self._peer(peer).request(
                    "bucket_insert", {"bucket": bfid, "name": name,
                                      "fid": fid, "exclude": exclude})
                # cross-MDS dependency: our txn depends on the peer's
                self._last_deps = {peer: rep.transno}
            parent.entries.pop(name, None)
        else:
            parent.entries[name] = fid
            if len(parent.entries) > self.SPLIT_THRESHOLD and self.peer_nids:
                self._split_dir(parent)
        if is_dir:
            parent.nlink += 1

    def _dir_remove_raw(self, parent: Inode, name: str,
                        exclude: str | None = None):
        if "buckets" in parent.ea:
            b = parent.ea["buckets"]
            bfid = tuple(b[fhash(name, len(b))])
            if bfid[0] == self.inode_group:
                self._get(bfid).entries.pop(name, None)
                self._revoke_client_locks(bfid, exclude=exclude)
            else:
                peer = self._peer_for_group(bfid[0])
                rep = self._peer(peer).request(
                    "bucket_remove", {"bucket": bfid, "name": name,
                                      "exclude": exclude})
                self._last_deps = {peer: rep.transno}
        else:
            parent.entries.pop(name, None)

    def _lookup_entry(self, parent: Inode, name: str):
        if "buckets" in parent.ea:
            b = parent.ea["buckets"]
            bfid = tuple(b[fhash(name, len(b))])
            if bfid[0] == self.inode_group:
                return self._get(bfid).entries.get(name)
            peer = self._peer_for_group(bfid[0])
            rep = self._peer(peer).request(
                "bucket_lookup", {"bucket": bfid, "name": name})
            f = rep.data.get("fid")
            return tuple(f) if f else None
        f = parent.entries.get(name)
        return tuple(f) if f else None

    def _peer_for_group(self, group: int) -> str:
        for uuid in self.peer_nids:
            if uuid.endswith(str(group)) or f"-{group}" in uuid:
                return uuid
        return list(self.peer_nids)[group % max(1, len(self.peer_nids))]

    # --- create family
    def _reint_create(self, r, req) -> R.Reply:
        parent = self._get(r["parent"])
        name = r["name"]
        # parent.fid: the dentries/attrs cached under the dir's lock;
        # parent.pfids: the parent's OWN cached attrs (nlink/nentries
        # change with this create) under ITS parents' locks
        self._revoke_client_locks(parent.fid, *parent.pfids,
                                  exclude=self._requester(req))
        if self._lookup_entry(parent, name) is not None:
            raise R.RpcError(-17, name)
        ftype = r.get("ftype", S_IFREG)
        self._last_deps = None
        if ftype == S_IFDIR and self.peer_nids and not r.get("fid") \
                and r.get("remote_ok", True):
            return self._mkdir_remote(parent, name, r, req)
        fid = tuple(r["fid"]) if r.get("fid") else self.new_fid()
        if fid[0] != self.inode_group:
            # replay of a remote-MDS create: re-create the pinned fid on
            # its owning peer (idempotent there), then re-insert locally
            peer = self._peer_for_group(fid[0])
            rep = self._peer(peer).request(
                "remote_mkdir" if ftype == S_IFDIR else "remote_create",
                {"mode": r.get("mode", 0o644), "fid": fid,
                 "ftype": ftype, "pfid": parent.fid,
                 "pfid_owner": self.uuid, **self._cl_origin(req)})
            self._dir_insert(parent, name, fid, is_dir=ftype == S_IFDIR,
                             exclude=self._requester(req))
            deps = {peer: rep.transno} if rep.transno else None
            clrec = self._cl(req, _cl_create_type(ftype), fid,
                             pfid=parent.fid, name=name)

            def undo_remote():
                self._dir_remove_raw(parent, name)
                if ftype == S_IFDIR:
                    parent.nlink -= 1
                self.changelog.retract(clrec)
            return R.Reply(data={"fid": fid},
                           transno=self.txn_meta(undo_remote, deps))
        inode = Inode(fid, ftype, mode=r.get("mode", 0o644),
                      mtime=self.sim.now,
                      nlink=2 if ftype == S_IFDIR else 1)
        if ftype == S_IFLNK:
            inode.symlink = r.get("target", "")
        if r.get("ea"):
            inode.ea.update(r["ea"])
        self.inodes[fid] = inode
        self._dir_insert(parent, name, fid, is_dir=ftype == S_IFDIR,
                         exclude=self._requester(req))
        deps = self._last_deps
        clrec = self._cl(req, _cl_create_type(ftype), fid, pfid=parent.fid,
                         name=name, mode=inode.mode)

        def undo():
            self._dir_remove_raw(parent, name)
            self.inodes.pop(fid, None)
            if ftype == S_IFDIR:
                parent.nlink -= 1
            self.changelog.retract(clrec)
        transno = self.txn_meta(undo, deps)
        self.ldlm.bump_version(("fid", *parent.fid))
        return R.Reply(data={"fid": fid}, transno=transno)

    def _mkdir_remote(self, parent: Inode, name: str, r,
                      req: Optional[R.Request] = None) -> R.Reply:
        """§6.7.1.2: 'mkdir always creates the new directory on another
        MDS'. Two-node transaction with a dependency record."""
        peer = sorted(self.peer_nids)[
            len(parent.entries) % len(self.peer_nids)]
        rep = self._peer(peer).request(
            "remote_mkdir", {"mode": r.get("mode", 0o755),
                             "pfid": parent.fid, "pfid_owner": self.uuid,
                             **self._cl_origin(req)},
            fixup=_pin_remote_fid)
        fid = tuple(rep.data["fid"])
        self._dir_insert(parent, name, fid, is_dir=True,
                         exclude=self._requester(req))
        deps = {peer: rep.transno}
        # the COORDINATOR (namespace side) logs the name-bearing record;
        # the peer logged only an inode-half record (remote=True)
        clrec = self._cl(req, cl_mod.CL_MKDIR, fid, pfid=parent.fid,
                         name=name)

        def undo():
            self._dir_remove_raw(parent, name)
            parent.nlink -= 1
            self.changelog.retract(clrec)
        transno = self.txn_meta(undo, deps)
        return R.Reply(data={"fid": fid, "remote": True}, transno=transno)

    def op_remote_mkdir(self, req: R.Request) -> R.Reply:
        fid = tuple(req.body["fid"]) if req.body.get("fid") else \
            self.new_fid()
        if fid in self.inodes:                  # idempotent replay
            return R.Reply(data={"fid": fid})
        ftype = req.body.get("ftype", S_IFDIR)
        inode = Inode(fid, ftype, mode=req.body.get("mode", 0o755),
                      nlink=2 if ftype == S_IFDIR else 1,
                      mtime=self.sim.now)
        if req.body.get("pfid"):
            # the coordinator's directory links us: attr changes here
            # must reach ITS clients' caches (revocation forwarding)
            inode.remote_pfids.add((req.body["pfid_owner"],
                                    tuple(req.body["pfid"])))
        self.inodes[fid] = inode
        # inode half of a cross-MDT create: nameless, flagged remote so
        # namespace consumers (audit mirror) don't double-apply it
        clrec = self._cl(req, _cl_create_type(ftype), fid, remote=True)

        def undo():
            self.inodes.pop(fid, None)
            self.changelog.retract(clrec)
        return R.Reply(data={"fid": fid}, transno=self.txn_meta(undo))

    op_remote_create = op_remote_mkdir

    # --- unlink family
    def _dir_nonempty(self, inode: Inode) -> bool:
        """THE 'directory still has content' predicate (ENOTEMPTY source
        of truth, shared by unlink / remote unlink / rename-over): own
        entries, or any entry in a hash bucket — local buckets read
        directly, remote ones via getattr (nentries)."""
        if inode.entries:
            return True
        for bfid in inode.ea.get("buckets", []):
            bfid = tuple(bfid)
            if bfid[0] == self.inode_group:
                b = self.inodes.get(bfid)
                if b is not None and b.entries:
                    return True
            else:
                try:
                    a = self._peer(self._peer_for_group(bfid[0])).request(
                        "getattr", {"fid": bfid}).data["attrs"]
                except R.RpcError as e:
                    if e.status == -2:
                        continue       # bucket inode gone: nothing there
                    raise R.RpcError(-16, "bucket unreachable")  # EBUSY
                except R.TimeoutError_:
                    # an unreachable bucket cannot prove emptiness —
                    # refusing (EBUSY) beats destroying live entries
                    raise R.RpcError(-16, "bucket unreachable")
                if a["nentries"]:
                    return True
        return False

    def op_dir_nonempty(self, req: R.Request) -> R.Reply:
        """Read-only: authoritative emptiness answer for a directory this
        MDT owns (cross-MDT rename-over prechecks ask here)."""
        inode = self.inodes.get(tuple(req.body["fid"]))
        if inode is None:
            return R.Reply(data={"exists": False, "nonempty": False})
        return R.Reply(data={
            "exists": True,
            "nonempty": inode.ftype == S_IFDIR
            and self._dir_nonempty(inode)})

    def op_revoke_dir_locks(self, req: R.Request) -> R.Reply:
        """Peer-forwarded attr revocation: a cross-MDT child of a dir
        THIS MDT owns changed its attrs over there — revoke the dir's
        client PR locks so no scan cache serves the old copy."""
        self._revoke_client_locks(tuple(req.body["fid"]),
                                  exclude=req.body.get("exclude") or None)
        return R.Reply()

    def _revoke_remote_pfids(self, inode: Inode,
                             req: Optional[R.Request] = None):
        """Forward the attr revocation to every peer-owned directory
        linking this inode (best effort: an unreachable peer's clients
        re-fetch when their locks lapse; its namespace half is already
        withheld from the consistent cut anyway)."""
        for owner, pfid in list(inode.remote_pfids):
            try:
                self._peer(owner).request(
                    "revoke_dir_locks",
                    {"fid": tuple(pfid),
                     "exclude": self._requester(req)},
                    no_recover=True)
            except (R.RpcError, R.TimeoutError_):
                self.sim.stats.count("mds.remote_revoke_skipped")

    def op_remote_nlink_adjust(self, req: R.Request) -> R.Reply:
        """'..'-link accounting half of a cross-MDT rename: the
        coordinator moved/removed a subdirectory of a dir THIS MDT
        owns."""
        inode = self._get(req.body["fid"])
        delta = int(req.body["delta"])
        self._revoke_client_locks(*inode.pfids)   # cached nlink is stale
        inode.nlink += delta

        def undo():
            inode.nlink -= delta
        return R.Reply(transno=self.txn_meta(undo))

    def _remote_nlink(self, fid: tuple, delta: int, deps: dict):
        """Best-effort '..' accounting on a peer-owned parent dir; the
        peer half joins the consistent cut via `deps`. A peer failure
        leaves an nlink drift rather than aborting the caller's
        already-applied rename."""
        peer = self._peer_for_group(fid[0])
        try:
            rep = self._peer(peer).request(
                "remote_nlink_adjust", {"fid": fid, "delta": delta})
            deps[peer] = max(deps.get(peer, 0), rep.transno)
        except (R.RpcError, R.TimeoutError_):
            self.sim.stats.count("mds.remote_nlink_skipped")

    def _victim_empty_or_raise(self, vfid: tuple, name: str):
        """Rename-over guard: the displaced target must be an empty
        directory (or a non-directory) — POSIX ENOTEMPTY, checked BEFORE
        any mutation, asking the victim's MDT when its inode is remote.
        Must be at least as strict as op_remote_unlink_inode so the
        post-mutation victim unlink can never be refused."""
        inode = self.inodes.get(vfid)
        if inode is not None:
            if inode.ftype == S_IFDIR and self._dir_nonempty(inode):
                raise R.RpcError(-39, name)
            return
        if vfid[0] == self.inode_group:
            return                     # locally owned but gone: stale entry
        try:
            d = self._peer(self._peer_for_group(vfid[0])).request(
                "dir_nonempty", {"fid": vfid}).data
        except R.RpcError as e:
            if e.status == -2:
                return                 # victim inode already gone
            raise                      # EBUSY etc: cannot prove empty
        except R.TimeoutError_:
            # nothing has mutated yet: refusing is safe, clobbering a
            # possibly non-empty dir is not
            raise R.RpcError(-16, name)
        if d["nonempty"]:
            raise R.RpcError(-39, name)

    def _drop_last_link(self, inode: Inode, data: dict,
                        req: Optional[R.Request] = None,
                        deps: dict | None = None):
        """Last link gone: drop the inode — a (drained) split dir dies
        with its hash buckets — and log one orphan-recovery llog record
        per data object (§6.7.5); `data` gains the ea + cookies the
        CLIENT needs to destroy the objects (§6.4.2, ch. 8.4). Shared by
        unlink, remote unlink, and rename-over. Returns (removed_inode,
        cookies, dropped_buckets) for `_undo_drop`."""
        removed = self.inodes.pop(inode.fid)
        cookies = []
        buckets = []
        if inode.ftype == S_IFDIR:
            for bfid in inode.ea.get("buckets", []):
                bfid = tuple(bfid)
                if bfid[0] == self.inode_group:
                    b = self.inodes.pop(bfid, None)
                    if b is not None:
                        buckets.append(b)
                else:
                    bpeer = self._peer_for_group(bfid[0])
                    try:
                        brep = self._peer(bpeer).request(
                            "remote_unlink_inode",
                            {"fid": bfid, **self._cl_origin(req)})
                        if deps is not None:
                            deps[bpeer] = max(deps.get(bpeer, 0),
                                              brep.transno)
                    except (R.RpcError, R.TimeoutError_):
                        pass           # bucket survives for orphan cleanup
        if "lov" in inode.ea:
            for o in inode.ea["lov"]["objects"]:
                # lint: ok(emit-in-txn: cookies are cancelled by
                # _undo_drop, which every caller registers in its txn undo)
                rec = self.unlink_llog.add("unlink", {
                    "ost": o["ost"], "group": o["group"], "oid": o["oid"]})
                cookies.append(rec.cookie)
            data["ea"] = dict(inode.ea)
            data["cookies"] = cookies
        return removed, cookies, buckets

    def _undo_drop(self, removed: Inode, cookies: list, buckets: list):
        """Transaction rollback half of _drop_last_link (local state
        only: peer halves are the consistent cut's job)."""
        self.inodes[removed.fid] = removed
        self.unlink_llog.cancel(cookies)
        for b in buckets:
            self.inodes[b.fid] = b

    def _reint_unlink(self, r, req) -> R.Reply:
        parent = self._get(r["parent"])
        name = r["name"]
        self._revoke_client_locks(parent.fid, *parent.pfids,
                                  exclude=self._requester(req))
        fid = self._lookup_entry(parent, name)
        if fid is None:
            raise R.RpcError(-2, name)
        inode = self.inodes.get(fid)
        self._last_deps = None
        if inode is None:
            # inode lives on a peer MDS (§6.7.5 two-stage unlink)
            peer = self._peer_for_group(fid[0])
            rep = self._peer(peer).request(
                "remote_unlink_inode",
                {"fid": fid, **self._cl_origin(req)})
            self._dir_remove_raw(parent, name,
                                 exclude=self._requester(req))
            deps = dict(self._last_deps or {})
            deps[peer] = rep.transno
            remote_was_dir = rep.data.get("ftype") == S_IFDIR
            if remote_was_dir:
                # mirror the local path: the removed subdir's ".." link
                parent.nlink -= 1
            clrec = self._cl(req, cl_mod.CL_RMDIR if remote_was_dir
                             else cl_mod.CL_UNLINK, fid, pfid=parent.fid,
                             name=name, last=rep.data.get("last", False))

            def undo():
                # via _dir_insert: a split parent keeps entries in its
                # hash buckets, never in the master entries dict
                self._dir_insert(parent, name, fid)
                if remote_was_dir:
                    parent.nlink += 1
                self.changelog.retract(clrec)
            return R.Reply(data=rep.data,
                           transno=self.txn_meta(undo, deps))
        if inode.ftype == S_IFDIR and self._dir_nonempty(inode):
            raise R.RpcError(-39, "not empty")           # ENOTEMPTY
        was_dir = inode.ftype == S_IFDIR
        inode.nlink -= 2 if was_dir else 1
        self._dir_remove_raw(parent, name, exclude=self._requester(req))
        if was_dir:
            parent.nlink -= 1
        data = {"fid": fid}
        cookies = []
        removed = None
        dropped_buckets = []
        deps = dict(self._last_deps or {})
        if inode.nlink <= 0:
            removed, cookies, dropped_buckets = \
                self._drop_last_link(inode, data, req, deps)
        clrec = self._cl(req, cl_mod.CL_RMDIR if was_dir
                         else cl_mod.CL_UNLINK, fid, pfid=parent.fid,
                         name=name, last=removed is not None)

        def undo():
            if removed is not None:
                self._undo_drop(removed, cookies, dropped_buckets)
            removed_inode = self.inodes[fid]
            removed_inode.nlink += 2 if was_dir else 1
            # via _dir_insert: a split parent keeps entries in its hash
            # buckets, never in the master entries dict
            self._dir_insert(parent, name, fid)
            if was_dir:
                parent.nlink += 1
            self.changelog.retract(clrec)
        transno = self.txn_meta(undo, deps or None)
        self.ldlm.bump_version(("fid", *parent.fid))
        return R.Reply(data=data, transno=transno)

    def op_remote_unlink_inode(self, req: R.Request) -> R.Reply:
        fid = tuple(req.body["fid"])
        inode = self.inodes.get(fid)
        if inode is None:
            # idempotent replay (mirrors op_remote_mkdir): our inode half
            # already committed before the coordinator's crash rolled ITS
            # dirent half back — report the inode gone so the replayed
            # coordinator can finish that half. The ftype is unknowable
            # here, so a replayed cross-MDT rmdir leaves the parent's
            # nlink one high — the drift lfsck-class repair tolerates.
            self.sim.stats.count("mds.remote_unlink_replay")
            return R.Reply(data={"fid": fid, "ftype": None, "last": False})
        self._revoke_client_locks(*inode.pfids)   # cached nlink is stale
        was_dir = inode.ftype == S_IFDIR
        # authoritative ENOTEMPTY: the coordinator cannot see a remote
        # directory's entries, so ITS owner refuses here (before the
        # coordinator has mutated anything — this RPC goes first)
        if was_dir and self._dir_nonempty(inode):
            raise R.RpcError(-39, "not empty")
        # a directory loses both its name link and its own "." link —
        # decrementing by 1 left every cross-MDT-removed dir inode alive
        # forever (and published last=False for its final removal)
        inode.nlink -= 2 if was_dir else 1
        data = {"fid": fid, "ftype": inode.ftype}
        removed = None
        cookies = []
        dropped_buckets = []
        if inode.nlink <= 0:
            removed, cookies, dropped_buckets = \
                self._drop_last_link(inode, data, req)
        data["last"] = removed is not None
        # inode half of a cross-MDT unlink (§6.7.5 two-stage): nameless
        clrec = self._cl(req, cl_mod.CL_RMDIR if was_dir
                         else cl_mod.CL_UNLINK, fid, remote=True,
                         last=removed is not None)

        def undo():
            if removed is not None:
                self._undo_drop(removed, cookies, dropped_buckets)
            self.inodes[fid].nlink += 2 if was_dir else 1
            self.changelog.retract(clrec)
        return R.Reply(data=data, transno=self.txn_meta(undo))

    # --- rename / link / setattr
    def _reint_rename(self, r, req) -> R.Reply:
        """Rename, possibly across MDS nodes (§6.7.5 'the most interesting
        of all: three nodes'). The coordinator (chosen by the client per
        fid order, §6.7.1.4) performs remote lookup/remove/insert RPCs on
        peers and records the dependencies for the consistent cut. Local
        undo restores local state; cross-node atomicity is the cut's job."""
        src_fid, dst_fid = tuple(r["src"]), tuple(r["dst"])
        self._revoke_client_locks(
            src_fid, dst_fid,
            *getattr(self.inodes.get(src_fid), "pfids", ()),
            *getattr(self.inodes.get(dst_fid), "pfids", ()),
            exclude=self._requester(req))
        src = self.inodes.get(src_fid)
        dst = self.inodes.get(dst_fid)
        # --- read-only lookups first: the source entry and the entry the
        # rename will displace, wherever their parents live — ENOENT and
        # ENOTEMPTY (rename over a non-empty dir, as unlink refuses it)
        # are decided BEFORE anything mutates; rename onto itself is a
        # no-op victim-wise
        if src is not None:
            fid = self._lookup_entry(src, r["src_name"])
        else:
            speer = self._peer_for_group(src_fid[0])
            f = self._peer(speer).request(
                "bucket_lookup", {"bucket": src_fid,
                                  "name": r["src_name"]}).data.get("fid")
            fid = tuple(f) if f else None
        if fid is None:
            raise R.RpcError(-2, r["src_name"])
        if dst is not None:
            displaced = self._lookup_entry(dst, r["dst_name"])
        else:
            dpeer = self._peer_for_group(dst_fid[0])
            f = self._peer(dpeer).request(
                "bucket_lookup", {"bucket": dst_fid,
                                  "name": r["dst_name"]}).data.get("fid")
            displaced = tuple(f) if f else None
        if displaced is not None and tuple(displaced) == fid:
            displaced = None
        if displaced is not None:
            self._victim_empty_or_raise(tuple(displaced), r["dst_name"])
        deps = {}
        self._last_deps = None
        # --- source side: remove
        if src is not None:
            self._dir_remove_raw(src, r["src_name"],
                                 exclude=self._requester(req))
            if self._last_deps:
                deps.update(self._last_deps)
        else:
            rep = self._peer(speer).request(
                "bucket_remove", {"bucket": src_fid, "name": r["src_name"]})
            deps[speer] = rep.transno
        # --- destination side: insert
        self._last_deps = None
        if dst is not None:
            self._dir_insert(dst, r["dst_name"], fid,
                             exclude=self._requester(req))
            if self._last_deps:
                deps.update(self._last_deps)
        else:
            rep = self._peer(dpeer).request(
                "bucket_insert", {"bucket": dst_fid, "name": r["dst_name"],
                                  "fid": fid})
            deps[dpeer] = max(deps.get(dpeer, 0), rep.transno)
        inode = self.inodes.get(fid)
        if inode is not None:
            was_dir = inode.ftype == S_IFDIR
        elif fid[0] != self.inode_group:
            # the moved inode lives on a peer MDT: its type still decides
            # the parents' ".." nlink transfer below (peer failure here
            # must not abort — both namespace halves are applied already)
            try:
                was_dir = self._peer(self._peer_for_group(fid[0])).request(
                    "getattr", {"fid": fid}).data["attrs"]["type"] \
                    == S_IFDIR
            except (R.RpcError, R.TimeoutError_):
                was_dir = False
        else:
            was_dir = False
        # '..' transfer between the parents, reaching peer-owned ones
        # over remote_nlink_adjust (their halves join the consistent cut)
        transfer = was_dir and src_fid != dst_fid
        if transfer:
            if src is not None:
                src.nlink -= 1
            else:
                self._remote_nlink(src_fid, -1, deps)
            if dst is not None:
                dst.nlink += 1
            else:
                self._remote_nlink(dst_fid, +1, deps)
        # --- displaced victim: rename-over unlinks the old target (its
        # inode used to leak here with a dangling nlink, disagreeing
        # with any link-accounting consumer of the changelog)
        victim = self.inodes.get(tuple(displaced)) if displaced else None
        victim_was_dir = victim is not None and victim.ftype == S_IFDIR
        vremoved = None
        vcookies = []
        vbuckets = []
        vextra = {}
        data = {"fid": fid}
        v_dst_dec = False
        if displaced is not None and victim is None \
                and tuple(displaced)[0] != self.inode_group:
            # victim inode lives on a peer MDT: two-stage unlink of its
            # inode half (§6.7.5), like the remote branch of unlink.
            # (A displaced LOCAL-group fid with no inode is a dangling
            # entry — nothing to unlink, the insert already replaced it.)
            vpeer = self._peer_for_group(tuple(displaced)[0])
            try:
                vrep = self._peer(vpeer).request(
                    "remote_unlink_inode",
                    {"fid": displaced, **self._cl_origin(req)})
            except (R.RpcError, R.TimeoutError_) as e:
                # the namespace halves are already applied; aborting here
                # would leave a half-rename OUTSIDE any transaction. A
                # dangling entry (-2) has nothing to unlink; any other
                # peer failure leaves the victim inode alive on its MDT
                # for orphan cleanup — the rename itself stays atomic
                if not (isinstance(e, R.RpcError) and e.status == -2):
                    self.sim.stats.count("mds.rename_victim_skipped")
                vrep = None
            if vrep is not None:
                deps[vpeer] = max(deps.get(vpeer, 0), vrep.transno)
                for k in ("ea", "cookies"):
                    if k in vrep.data:
                        data[k] = vrep.data[k]
                if vrep.data.get("ftype") == S_IFDIR \
                        and vrep.data.get("last"):
                    # the victim dir's ".." link leaves the dst parent
                    if dst is not None:
                        dst.nlink -= 1
                        v_dst_dec = True
                    else:
                        self._remote_nlink(dst_fid, -1, deps)
                vextra = {"victim": tuple(displaced),
                          "victim_last": vrep.data.get("last", False)}
        elif victim is not None:
            victim.nlink -= 2 if victim_was_dir else 1
            if victim.nlink <= 0:
                vremoved, vcookies, vbuckets = \
                    self._drop_last_link(victim, data, req, deps)
                if victim_was_dir:
                    if dst is not None:
                        dst.nlink -= 1         # its ".." link
                    else:
                        self._remote_nlink(dst_fid, -1, deps)
            vextra = {"victim": victim.fid,
                      "victim_last": vremoved is not None}
        clrec = self._cl(req, cl_mod.CL_RENAME, fid, pfid=dst_fid,
                         name=r["dst_name"], spfid=src_fid,
                         sname=r["src_name"], **vextra)

        def undo():
            if v_dst_dec:
                dst.nlink += 1
            if victim is not None:
                if vremoved is not None:
                    self._undo_drop(vremoved, vcookies, vbuckets)
                    if victim_was_dir and dst is not None:
                        dst.nlink += 1
                self.inodes[victim.fid].nlink += 2 if victim_was_dir else 1
            if dst is not None:
                self._dir_remove_raw(dst, r["dst_name"])
                if displaced is not None:
                    # via _dir_insert: a split dst keeps its entries in
                    # hash buckets, never in the master entries dict
                    self._dir_insert(dst, r["dst_name"], displaced)
            if src is not None:
                self._dir_insert(src, r["src_name"], fid)
            if transfer:
                if src is not None:
                    src.nlink += 1
                if dst is not None:
                    dst.nlink -= 1
            self.changelog.retract(clrec)
        transno = self.txn_meta(undo, deps or None)
        for pf in {src_fid, dst_fid}:
            self.ldlm.bump_version(("fid", *pf))
        return R.Reply(data=data, transno=transno)

    def _reint_link(self, r, req) -> R.Reply:
        fid = tuple(r["fid"])
        parent = self._get(r["parent"])
        self._revoke_client_locks(parent.fid, *parent.pfids,
                                  exclude=self._requester(req))
        # EEXIST check BEFORE any nlink bump: the remote_link RPC commits
        # on the peer in its own transaction, so raising after it used to
        # leak a permanent +1 on the remote inode's nlink
        if self._lookup_entry(parent, r["name"]) is not None:
            raise R.RpcError(-17, r["name"])
        inode = self.inodes.get(fid)
        self._last_deps = None
        deps = {}
        if inode is None:
            peer = self._peer_for_group(fid[0])
            rep = self._peer(peer).request("remote_link", {"fid": fid})
            deps[peer] = rep.transno
        else:
            inode.nlink += 1
        self._dir_insert(parent, r["name"], fid,
                         exclude=self._requester(req))
        if self._last_deps:
            deps.update(self._last_deps)
        clrec = self._cl(req, cl_mod.CL_LINK, fid, pfid=parent.fid,
                         name=r["name"])

        def undo():
            self._dir_remove_raw(parent, r["name"])
            if inode is not None:
                inode.nlink -= 1
            self.changelog.retract(clrec)
        return R.Reply(data={"fid": fid},
                       transno=self.txn_meta(undo, deps or None))

    def op_remote_link(self, req: R.Request) -> R.Reply:
        inode = self._get(req.body["fid"])
        self._revoke_client_locks(*inode.pfids)   # cached nlink is stale
        inode.nlink += 1

        def undo():
            inode.nlink -= 1
        return R.Reply(transno=self.txn_meta(undo))

    def _reint_setattr(self, r, req) -> R.Reply:
        inode = self._get(r["fid"])
        # attribute update: clients may cache this inode's attrs under
        # the PR locks of the directories it is linked in (readdir-plus
        # / statahead) — revoke them so no stale attr is ever served
        # (the requester drops its own copy locally)
        self._revoke_client_locks(*inode.pfids,
                                  exclude=self._requester(req))
        self._revoke_remote_pfids(inode, req)
        old = (dict(inode.ea), inode.mode, inode.uid, inode.gid,
               inode.mtime, inode.size)
        a = r.get("attrs", {})
        if "ea" in r:
            inode.ea.update(r["ea"])
        inode.mode = a.get("mode", inode.mode)
        inode.uid = a.get("uid", inode.uid)
        inode.gid = a.get("gid", inode.gid)
        inode.mtime = a.get("mtime", inode.mtime)
        if "size" in a:
            inode.size = a["size"]
        clrec = self._cl(req, cl_mod.CL_SETATTR, inode.fid, attrs=dict(a),
                         ea_keys=sorted(r["ea"]) if r.get("ea") else [])

        def undo():
            (inode.ea, inode.mode, inode.uid, inode.gid, inode.mtime,
             inode.size) = ({**old[0]}, *old[1:])
            self.changelog.retract(clrec)
        return R.Reply(data={"attrs": inode.attrs()},
                       transno=self.txn_meta(undo))

    # ---------------------------------------------------- directory split
    def _split_dir(self, parent: Inode):
        """§6.7.3: fan a large directory out into hash buckets on peer
        MDSes (and locally)."""
        peers = sorted(self.peer_nids)
        ways = min(self.SPLIT_WAYS, len(peers) + 1)
        buckets = []
        for i in range(ways):
            if i == 0:
                bfid = self.new_fid()
                self.inodes[bfid] = Inode(bfid, S_IFDIR, nlink=2)
            else:
                peer = peers[(i - 1) % len(peers)]
                rep = self._peer(peer).request("remote_mkdir", {},
                                               fixup=_pin_remote_fid)
                bfid = tuple(rep.data["fid"])
            buckets.append(bfid)
        entries = dict(parent.entries)
        parent.entries.clear()
        parent.ea["buckets"] = buckets
        for name, fid in entries.items():
            bfid = tuple(buckets[fhash(name, ways)])
            if bfid[0] == self.inode_group:
                self._get(bfid).entries[name] = fid
            else:
                peer = self._peer_for_group(bfid[0])
                self._peer(peer).request(
                    "bucket_insert", {"bucket": bfid, "name": name,
                                      "fid": fid})
        self.sim.stats.count("mds.dir_split")

    def op_bucket_insert(self, req: R.Request) -> R.Reply:
        bucket = self._get(req.body["bucket"])
        name = req.body["name"]
        fid = tuple(req.body["fid"])
        bucket.entries[name] = fid
        child = self.inodes.get(fid)
        if child is not None:
            child.pfids.add(bucket.fid)
        # readdir-plus pages of this bucket were served under ITS PR
        # lock; the originating client (forwarded by the coordinator)
        # fixes its own caches, like every other requester
        self._revoke_client_locks(bucket.fid,
                                  exclude=req.body.get("exclude"))

        def undo():
            bucket.entries.pop(name, None)
        return R.Reply(transno=self.txn_meta(undo))

    def op_bucket_lookup(self, req: R.Request) -> R.Reply:
        bucket = self._get(req.body["bucket"])
        return R.Reply(data={"fid": bucket.entries.get(req.body["name"])})

    def op_bucket_remove(self, req: R.Request) -> R.Reply:
        bucket = self._get(req.body["bucket"])
        name = req.body["name"]
        fid = bucket.entries.pop(name, None)
        self._revoke_client_locks(bucket.fid,
                                  exclude=req.body.get("exclude"))

        def undo():
            if fid is not None:
                bucket.entries[name] = fid
        return R.Reply(data={"fid": fid}, transno=self.txn_meta(undo))

    # -------------------------------------------------- llog / recovery
    def op_llog_cancel(self, req: R.Request) -> R.Reply:
        n = self.unlink_llog.cancel(req.body["cookies"])
        return R.Reply(data={"cancelled": n})

    def process_unlink_llog(self, ost_imports: dict[str, R.Import]) -> int:
        """After MDS recovery: re-ship destroys for uncancelled unlink
        records (§6.7.5). Idempotent on the OST."""
        def ship(rec: llog_mod.LlogRecord) -> bool:
            imp = ost_imports.get(rec.payload["ost"])
            if imp is None:
                return False
            try:
                imp.request("destroy", {"group": rec.payload["group"],
                                        "oid": rec.payload["oid"],
                                        "cookie": rec.cookie})
                return True
            except (R.RpcError, R.TimeoutError_):
                return False
        return self.unlink_llog.process(ship)

    def orphan_cleanup(self, lov_targets: dict[str, R.Import],
                       group: int) -> dict:
        """§6.7.5 second half: destroy OST objects no file references
        (client died between object create and EA setattr)."""
        keep: dict[str, set] = {u: set() for u in lov_targets}
        for inode in self.inodes.values():
            lsm = inode.ea.get("lov")
            if lsm:
                for o in lsm["objects"]:
                    if o["ost"] in keep and o["group"] == group:
                        keep[o["ost"]].add(o["oid"])
        out = {}
        for uuid, imp in lov_targets.items():
            objs = imp.request("list_objects", {"group": group}).data
            doomed = [o for o in objs if o not in keep[uuid]]
            for oid in doomed:
                imp.request("destroy", {"group": group, "oid": oid})
            out[uuid] = doomed
        return out

    # ------------------------------------------- consistent cut support
    def op_dep_records(self, req: R.Request) -> R.Reply:
        return R.Reply(data={
            "committed": self.committed_transno,
            "deps": [(t, d) for t, d in self.dep_log]})

    def op_rollback_to(self, req: R.Request) -> R.Reply:
        """Undo all retained transactions with transno > cut (§6.7.6.3)."""
        cut = req.body["transno"]
        undone = 0
        for transno, undo in sorted(self.undo_history, reverse=True,
                                    key=lambda t: t[0]):
            if transno > cut:
                undo()
                undone += 1
        self.undo_history = [(t, u) for t, u in self.undo_history
                             if t <= cut]
        self.dep_log = [(t, d) for t, d in self.dep_log if t <= cut]
        self.transno = min(self.transno, cut)
        self.committed_transno = min(self.committed_transno, cut)
        self.cluster_cut = min(self.cluster_cut, cut)
        self.vbr_prune(cut)               # version history follows the cut
        self._cut_checked_at = None       # the world changed: re-derive
        return R.Reply(data={"undone": undone})

    def op_prune_history(self, req: R.Request) -> R.Reply:
        cut = req.body["transno"]
        self.undo_history = [(t, u) for t, u in self.undo_history if t > cut]
        self.dep_log = [(t, d) for t, d in self.dep_log if t > cut]
        # the leader proved everything <= cut cluster-committed (§6.7.6.3
        # steady state): changelog serving can trust it without re-deriving
        # — the push also refreshes the derivation cache
        self.cluster_cut = max(self.cluster_cut, cut)
        self._cut_checked_at = self.sim.now
        return R.Reply()
