"""Core Lustre architecture simulation (the paper's contribution).

Layers (bottom up, mirroring Part 1 of the paper):
    sim        — virtual clock / link model / fault injection
    portals    — message passing: portals, match entries, MDs, events (ch.4)
    ptlrpc     — request processing: xids, exports/imports, bulk,
                 transactions + replay/resend recovery (ch.4, 22, 23, 29)
    nrs        — network request scheduler: pluggable per-target request
                 ordering policies (fifo/crr/orr/tbf) + accounting
    dlm        — distributed lock manager: 6 modes, extents, intents, ASTs
                 (ch.7, 27)
    obd        — object devices: class driver + filter direct driver (ch.5)
    llog       — logging API: catalogs, cookies, cancellation (ch.8)
    changelog  — per-MDT metadata activity streams on llog: typed records,
                 consumer bookmarks, jobid tagging (ch.8 + audit tooling)
    ost / osc  — object storage target/client, grants, referral (ch.2, 10)
    lov        — striping + RAID1 redundant OSTs (ch.10, 15, 20)
    mds / mdc  — metadata service: fids, intents, reintegration, clustered
                 directories, WBC (ch.6, 17, 26)
    cobd       — collaborative read cache (ch.5.5, 16)
    snapshot   — snapshot logical driver, COW redirectors (ch.5.4)
    recovery   — pinger, failover rings, consistent-cut snapshot (ch.11, 29)
    cluster    — configuration management / assembly (ch.13, 14, 31)
"""
from repro.core.cluster import LustreCluster  # noqa: F401
