"""Snapshot logical OBD driver (paper §5.4).

A case study in logical object drivers: the snap device stacks on a direct
device whose volume holds *direct* objects and *redirector* objects. The
volume is characterised by snapshot times T1 < ... < Tk; attaching with
snapshot index S=0 gives the writable primary, S>0 a read-only clone.

COW per §5.4.1: the first write to an object after a snapshot time freezes
the current data into a new direct object and repoints the redirector slots
for the snapshots it belongs to.
"""
from __future__ import annotations

from repro.core import obd as obd_mod


class SnapDevice(obd_mod.ObdDevice):
    obd_type = "snap"

    def __init__(self, name: str, bottom: obd_mod.FilterDevice,
                 snap_index: int = 0):
        super().__init__(name)
        self.bottom = bottom
        self.snap_index = snap_index
        # shared table on the bottom device so all attached snap devices
        # of one volume agree (the paper stores it in volume metadata)
        tbl = getattr(bottom, "_snap_table", None)
        if tbl is None:
            tbl = bottom._snap_table = {"times": [], "names": {}}
        self.table = tbl

    # ----------------------------------------------------------- admin
    def snap_add(self, name: str, time: float) -> int:
        """`snap add` — times may be 'written to current time' (§5.4)."""
        self.table["times"].append(time)
        idx = len(self.table["times"])
        self.table["names"][idx] = name
        return idx

    def snap_list(self):
        return [{"index": 0, "name": "current"}] + [
            {"index": i + 1, "name": self.table["names"].get(i + 1, ""),
             "time": t} for i, t in enumerate(self.table["times"])]

    def snap_del(self, index: int):
        """Remove a snapshot: drop redirector pointers via an iterator."""
        for (g, o), obj in list(self.bottom.objects.items()):
            redir = obj.attrs.get("snap_redirect")
            if redir and redir.get(index):
                tgt = redir.pop(index)
                if tgt and tgt not in redir.values() and tgt != obj.oid:
                    still = any(v == tgt for v in redir.values())
                    if not still:
                        try:
                            self.bottom.destroy(g, tgt)
                        except obd_mod.ObdError:
                            pass
        self.table["names"].pop(index, None)

    def snap_restore(self, index: int):
        """Roll the primary back to snapshot `index` (snap restore)."""
        for (g, o), obj in list(self.bottom.objects.items()):
            redir = obj.attrs.get("snap_redirect")
            if not redir:
                continue
            tgt = redir.get(index)
            if tgt:
                data = self.bottom.read(g, tgt, 0,
                                        self.bottom.getattr(g, tgt)["size"])
                cur = redir.get(0)
                if cur:
                    self.bottom.punch(g, cur, 0)
                    self.bottom.write(g, cur, 0, data)
                else:
                    obj.data = bytearray(data)

    # -------------------------------------------------------- redirection
    def _slot_for_read(self, obj) -> int | None:
        """Which direct object serves reads for this snap index (§5.4.1)."""
        redir = obj.attrs.get("snap_redirect")
        if redir is None:
            return None                      # direct object
        if self.snap_index == 0:
            return redir.get(0)
        # snapshot read: exact slot, else the object was not modified
        # since that snapshot -> current data (slot 0) is still correct
        return redir.get(self.snap_index, redir.get(0))

    def _cow(self, group: int, oid: int):
        """First write after a snapshot time: freeze current data."""
        obj = self.bottom._get(group, oid)
        times = self.table["times"]
        if not times:
            return
        t = obj.mtime
        k = len(times)
        # snapshots whose time >= mtime still reference the current data
        needs = [i + 1 for i, st in enumerate(times)
                 if st >= t and (obj.attrs.get("snap_redirect", {})
                                 .get(i + 1) is None)]
        if not needs:
            return
        redir = obj.attrs.setdefault("snap_redirect", {})
        cur = redir.get(0, oid)
        cur_obj = self.bottom._get(group, cur)
        frozen = self.bottom.create(group)["oid"]
        self.bottom.write(group, frozen, 0, bytes(cur_obj.data))
        self.bottom.setattr(group, frozen, snap_frozen=True)
        for i in needs:
            redir[i] = frozen
        if 0 not in redir:
            # turn `oid` into a redirector: its data moves to a new direct
            # object N; pointer 0 -> N (§5.4.1)
            n = self.bottom.create(group)["oid"]
            self.bottom.write(group, n, 0, bytes(cur_obj.data))
            redir[0] = n

    # ------------------------------------------------------------ obd api
    def _ro(self):
        if self.snap_index != 0:
            raise obd_mod.ObdError(30, "read-only snapshot")   # EROFS

    def create(self, group, oid=None, **attrs):
        self._ro()
        return self.bottom.create(group, oid, **attrs)

    def destroy(self, group, oid):
        self._ro()
        obj = self.bottom._get(group, oid)
        redir = obj.attrs.get("snap_redirect")
        if redir:
            # object still referenced by snapshots: just null the 0 slot
            tgt = redir.pop(0, None)
            if tgt and tgt != oid:
                self.bottom.destroy(group, tgt)
            return {"transno": 0}
        return self.bottom.destroy(group, oid)

    def getattr(self, group, oid):
        obj = self.bottom._get(group, oid)
        slot = self._slot_for_read(obj)
        if slot is None or slot == oid:
            return self.bottom.getattr(group, oid)
        a = self.bottom.getattr(group, slot)
        if self.snap_index == 0:
            a["mtime"] = obj.mtime
        return a

    def setattr(self, group, oid, **attrs):
        self._ro()
        self._cow(group, oid)
        return self.bottom.setattr(group, oid, **attrs)

    def read(self, group, oid, offset, length):
        obj = self.bottom._get(group, oid)
        slot = self._slot_for_read(obj)
        if slot is None or slot == oid:
            return self.bottom.read(group, oid, offset, length)
        return self.bottom.read(group, slot, offset, length)

    def write(self, group, oid, offset, data, **kw):
        self._ro()
        self._cow(group, oid)
        obj = self.bottom._get(group, oid)
        redir = obj.attrs.get("snap_redirect")
        tgt = redir[0] if redir and 0 in redir else oid
        out = self.bottom.write(group, tgt, offset, data, **kw)
        obj.mtime = max(obj.mtime, kw.get("mtime", 0.0)) or obj.mtime
        return out

    def punch(self, group, oid, size):
        self._ro()
        self._cow(group, oid)
        obj = self.bottom._get(group, oid)
        redir = obj.attrs.get("snap_redirect")
        tgt = redir[0] if redir and 0 in redir else oid
        return self.bottom.punch(group, tgt, size)

    def statfs(self):
        return self.bottom.statfs()

    def list_objects(self, group):
        return [o for o in self.bottom.list_objects(group)
                if not self.bottom._get(group, o).attrs.get("snap_frozen")]
