"""Seeded network-chaos harness (ISSUE-10).

Lustre's recovery machinery (adaptive timeouts, VBR, the pinger health
plane) exists because real fabrics drop, delay, and partition traffic
at the worst possible moments. This module generates deterministic
fault schedules over the simulator's analytic network model so tests
can subject a live workload to that weather and assert the durability
oracles afterwards. Six primitives:

  drop       lose the next N messages addressed to one nid
  lossy      probabilistic loss on one (src, dst) link or "*"
  delay      extra per-hop latency on one link or "*"
  partition  sever one node pair bidirectionally
  flap       power-cycle a server node (down until the next heal)
  heal       clear every injected fault and restart flapped servers

A schedule is a pure function of its integer seed (`random.Random`), so
any failing seed replays identically under the deterministic clock. The
`net.flap` fail site gates the flap primitive: arming it with drop or
crash suppresses the power-cycle, which is how the crash-point sweep
proves a *missing* flap changes nothing it shouldn't.
"""
from __future__ import annotations

import random
from typing import Iterable

from repro.core import fail as fail_mod

EVENT_KINDS = ("drop", "lossy", "delay", "partition", "flap", "heal")

# chaos stays inside the envelope the recovery machinery is built for:
# loss below the retry horizon, delays below at_max, short partitions
MAX_DROP_BURST = 3
MAX_LOSS_PROB = 0.2
MAX_EXTRA_DELAY = 0.5


def generate_schedule(seed: int, steps: int, client_nids: Iterable[str],
                      server_names: Iterable[str], *,
                      heal_every: int = 4) -> list[tuple]:
    """Derive `steps` chaos events from `seed`. Every `heal_every`-th
    event is a forced heal so no schedule strands the cluster in a
    permanently-faulted state (the final event is always a heal, added
    by the runner if the schedule doesn't end with one)."""
    rng = random.Random(seed)
    clients = list(client_nids)
    servers = list(server_names)
    nids = clients + [f"elan:{s}" for s in servers]
    out: list[tuple] = []
    for i in range(steps):
        if heal_every and i % heal_every == heal_every - 1:
            out.append(("heal",))
            continue
        kind = rng.choice(("drop", "lossy", "delay", "partition", "flap"))
        if kind == "drop":
            out.append(("drop", rng.choice(nids),
                        rng.randint(1, MAX_DROP_BURST)))
        elif kind == "lossy":
            link = ("*" if rng.random() < 0.3
                    else (rng.choice(nids), rng.choice(nids)))
            out.append(("lossy", link,
                        round(rng.uniform(0.05, MAX_LOSS_PROB), 3)))
        elif kind == "delay":
            link = ("*" if rng.random() < 0.3
                    else (rng.choice(nids), rng.choice(nids)))
            out.append(("delay", link,
                        round(rng.uniform(0.05, MAX_EXTRA_DELAY), 3)))
        elif kind == "partition":
            a, b = rng.sample(nids, 2)
            out.append(("partition", a, b))
        else:
            out.append(("flap", rng.choice(servers)))
    if not out or out[-1][0] != "heal":
        out.append(("heal",))
    return out


class ChaosEngine:
    """Applies schedule events to a cluster, one per workload step."""

    def __init__(self, cluster, server_names: Iterable[str]):
        self.cluster = cluster
        self.sim = cluster.sim
        self.servers = list(server_names)
        self.flapped: set = set()         # names currently down via flap

    def apply(self, ev: tuple) -> None:
        kind = ev[0]
        f = self.sim.faults
        if kind == "drop":
            f.drop_next[ev[1]] += ev[2]
        elif kind == "lossy":
            f.drop_prob[ev[1]] = ev[2]
        elif kind == "delay":
            f.link_delay[ev[1]] = ev[2]
        elif kind == "partition":
            f.partitions.add(frozenset((ev[1], ev[2])))
        elif kind == "flap":
            if fail_mod.state.check("net.flap") in ("drop", "crash"):
                return                    # the flap itself is suppressed
            name = ev[1]
            if name not in self.flapped:
                self.cluster.fail_node(name)
                self.flapped.add(name)
        elif kind == "heal":
            self.heal()
        else:
            raise ValueError(f"unknown chaos event {kind!r}")
        self.sim.stats.count(f"chaos.{kind}")

    def heal(self) -> None:
        """Clear injected faults and power flapped servers back on —
        the state every schedule ends in before oracles run."""
        self.sim.faults.heal()
        for name in sorted(self.flapped):
            self.cluster.restart_node(name)
        self.flapped.clear()

    def run(self, schedule: list[tuple], step) -> int:
        """Interleave: one event, one workload step (a zero-arg callable
        that may raise RpcError/TimeoutError_ — chaos makes those legal).
        Ends healed. Returns how many steps raised."""
        from repro.core import ptlrpc as R
        failures = 0
        for ev in schedule:
            self.apply(ev)
            try:
                step()
            except (R.RpcError, R.TimeoutError_):
                failures += 1
        self.heal()
        return failures
