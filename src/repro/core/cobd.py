"""Collaborative cache — COBD + caching OST (paper §5.5, ch. 16).

A caching node runs a COBD (page cache of object extents, kept coherent by
PR extent locks on the *target* OST) fronted by a caching-OST service so
peer clients can read from it. The target OST's referral module (in ost.py)
redirects client reads to caching OSTs that hold covering PR locks; on a
miss the COBD populates itself through its own OSC (taking the PR lock the
referral logic later relies on).

"This can result in an unprecedented improvement in scalability for reads"
— bench_cobd.py measures exactly this claim (cluster-boot workload).
"""
from __future__ import annotations

from collections import defaultdict

from repro.core import osc as osc_mod
from repro.core import ptlrpc as R


class CachingOst(R.Target):
    """The OST-protocol service a caching node exports (§5.5.1: 'lock
    requests are still made to the target OST, so we disable lock granting
    at the caching OST — it simply services the read request')."""

    svc_kind = "ost"

    def __init__(self, uuid: str, node: R.Node, cobd: "Cobd"):
        super().__init__(uuid, node)
        self.cobd = cobd
        self.ops["read"] = self.op_read

    def op_read(self, req: R.Request) -> R.Reply:
        b = req.body
        data = self.cobd.read(b["group"], b["oid"], b["offset"], b["length"])
        self.sim.stats.add_bytes("cobd.served", len(data))
        return R.Reply(data={"len": len(data)}, bulk=data,
                       bulk_nbytes=len(data))


class Cobd:
    """Caching OBD: read-through page cache over an OSC (§5.5.1).

    Cached extents are covered by PR locks taken on the target OST; a
    blocking AST (writer appeared) invalidates the pages under the lock —
    exactly the paper's coherency story. Memory pressure is modelled with
    a byte budget + LRU."""

    PAGE = 4096

    def __init__(self, name: str, target_osc: osc_mod.Osc,
                 budget: int = 64 << 20):
        self.name = name
        self.osc = target_osc
        self.sim = target_osc.sim
        self.budget = budget
        self.used = 0
        # (group, oid) -> {page_index: bytes}
        self.pages: dict[tuple, dict[int, bytes]] = defaultdict(dict)
        self.lru: list[tuple] = []
        # invalidate on lock revocation. revoke_cbs (not flush_cb): the
        # COBD's locks are clean PR locks — the old flush_cb hook only
        # fired for DIRTY locks, so revocation never actually dropped the
        # cached pages (a writer left this cache permanently stale).
        def cb(lock):
            if lock.res_name[0] == "ext":
                self._invalidate(lock.res_name[1], lock.res_name[2])
        self.osc.locks.revoke_cbs.append(cb)

    # ------------------------------------------------------------- cache
    def _invalidate(self, group, oid):
        dropped = self.pages.pop((group, oid), None)
        if dropped:
            self.used -= sum(len(v) for v in dropped.values())
            self.sim.stats.count("cobd.invalidate")

    def _evict_until(self, need: int):
        while self.used + need > self.budget and self.lru:
            key = self.lru.pop(0)
            self._invalidate(*key)

    def read(self, group: int, oid: int, offset: int, length: int) -> bytes:
        key = (group, oid)
        pgs = self.pages[key]
        first, last = offset // self.PAGE, (offset + length - 1) // self.PAGE
        missing = [i for i in range(first, last + 1) if i not in pgs]
        if missing:
            self.sim.stats.count("cobd.miss")
            # populate through the standard OSC (takes the PR lock the
            # target OST's referral module will see; §5.5.2)
            start = missing[0] * self.PAGE
            end = (missing[-1] + 1) * self.PAGE
            data = self.osc.read(group, oid, start, end - start,
                                 from_cobd=self.name)
            self._evict_until(len(data))
            for i in range(missing[0], missing[-1] + 1):
                o = (i - missing[0]) * self.PAGE
                pg = data[o:o + self.PAGE]
                if pg:
                    pgs[i] = pg
                    self.used += len(pg)
            if key in self.lru:
                self.lru.remove(key)
            self.lru.append(key)
        else:
            self.sim.stats.count("cobd.hit")
        buf = bytearray()
        for i in range(first, last + 1):
            buf += pgs.get(i, b"")
        s = offset - first * self.PAGE
        return bytes(buf[s:s + length])


def make_caching_node(cluster, node_name: str, ost_target, uuid: str):
    """Wire a caching node: COBD + caching-OST service + referral
    registration on the target OST."""
    node = cluster.nodes[node_name]
    rpc = R.RpcClient(node)
    osc = osc_mod.Osc(rpc, ost_target.uuid,
                      [ost_target.node.nid], writeback=False)
    cobd = Cobd(uuid, osc)
    cost = CachingOst(uuid, node, cobd)
    ost_target.register_caching_ost(uuid, node.nid)
    return cobd, cost
