"""Metadata client (MDC), clustered-MDS router (LMV), and the client
metadata write-back cache (paper §6.7.1.1, ch. 17, ch. 26).

The LMV is deliberately thin (§6.7.1.1: "the client part of the
implementation is very trivial"): it picks the MDC by
  (1) the inode group of the fid in the request,
  (2) the name hash + bucket EA for split directories,
  (3) fid order for rename coordination (§6.7.1.4).

The write-back cache (ch. 17) holds a subtree lock + preallocated fids;
updates apply to a local shadow namespace and are recorded as reintegration
records, flushed as batched `reint_batch` RPCs — in the background on
batch-size/age/pressure thresholds, and as a barrier on fsync/close/
release or a blocking AST on the subtree lock.
"""
from __future__ import annotations

from typing import Any, Optional

from repro.core import dlm as dlm_mod
from repro.core import fail as fail_mod
from repro.core import mds as mds_mod
from repro.core import ptlrpc as R


class Mdc:
    """Client stub for ONE MDS target."""

    def __init__(self, rpc: R.RpcClient, target_uuid: str, nids: list[str]):
        self.rpc = rpc
        self.sim = rpc.sim
        self.uuid = target_uuid
        self.imp = rpc.import_target(target_uuid, nids, "mds")
        self.locks = dlm_mod.LockClient(rpc, self.imp)

    # -------------------------------------------------------- intent ops
    def enqueue_intent(self, res_fid, mode: str, intent: dict):
        """mdc_enqueue (§6.2.2): lock + operation in one RPC."""
        def fixup(req, rep):
            d = (rep.data or {}).get("intent") or {}
            attrs = d.get("attrs")
            if d.get("created") and attrs:
                # pin the assigned fid so replay recreates the same inode
                req.body["intent"]["fid"] = tuple(attrs["fid"])
        lk, data, lvb = self.locks.enqueue(
            ("fid", *tuple(res_fid)), mode, None, intent=intent,
            use_cache=False, fixup=fixup)
        return lk, (data or {})

    def getattr_lock(self, parent_fid, name: str, want_ea: bool = False):
        return self.enqueue_intent(
            parent_fid, "PR", {"op": "lookup", "parent": tuple(parent_fid),
                               "name": name, "want_ea": want_ea})

    def open(self, parent_fid, name: str, flags: str = "r",
             mode: int = 0o644):
        return self.enqueue_intent(
            parent_fid, "PR", {"op": "open", "parent": tuple(parent_fid),
                               "name": name, "flags": flags, "mode": mode})

    def readdir_plus(self, fid, page_size: int, after: str | None = None,
                     want_ea: bool = True):
        """ONE readdir-plus page (entries + per-entry attrs/EA) under
        the directory's PR lock (ISSUE-5). `after` is a NAME cursor (the
        last name of the previous page) so pagination stays stable under
        concurrent creates/unlinks. Returns (lock, data)."""
        return self.enqueue_intent(
            fid, "PR", {"op": "readdir", "fid": tuple(fid),
                        "page_size": page_size, "after": after,
                        "want_ea": want_ea})

    # --------------------------------------------------------- plain ops
    def getattr(self, fid, want_ea: bool = False) -> dict:
        return self.imp.request("getattr", {"fid": tuple(fid),
                                            "want_ea": want_ea}).data

    def getattr_bulk(self, fids: list, want_ea: bool = False) -> list:
        """Batched getattr: ONE RPC, attrs (+EA) per fid (None for
        unknown fids) — the statahead / readdir-plus merge primitive."""
        return self.imp.request(
            "getattr_bulk", {"fids": [tuple(f) for f in fids],
                             "want_ea": want_ea}).data["attrs"]

    def readdir(self, fid) -> dict:
        return self.imp.request("readdir", {"fid": tuple(fid)}).data

    def reint(self, rec: dict) -> R.Reply:
        def fixup(req, rep):
            # pin the server-assigned fid so REPLAY recreates the same
            # inode (even when it was created on a peer MDS)
            if rec["type"] == "create" and (rep.data or {}).get("fid"):
                req.body["rec"]["fid"] = tuple(rep.data["fid"])
        return self.imp.request("reint", {"rec": rec}, fixup=fixup)

    def reint_batch(self, records: list) -> R.Reply:
        def fixup(req, rep):
            # pin server-assigned fids per record so REPLAY re-creates
            # the same inodes (WBC records normally carry preallocated
            # fids already — this covers records without one)
            results = (rep.data or {}).get("results") or []
            for r, res in zip(req.body["records"], results):
                d = res.get("data") or {}
                if r.get("type") == "create" and not r.get("fid") \
                        and d.get("fid"):
                    r["fid"] = tuple(d["fid"])
        return self.imp.request("reint_batch", {"records": records},
                                fixup=fixup)

    def close(self, handle: int, size=None, mtime=None,
              fid=None) -> R.Reply:
        return self.imp.request("close", {"handle": handle, "size": size,
                                          "mtime": mtime,
                                          "fid": tuple(fid) if fid else None})

    def statfs(self) -> dict:
        return self.imp.request("statfs", {}).data

    def prealloc_fids(self, count: int = 64) -> list:
        return [tuple(f) for f in
                self.imp.request("prealloc_fids",
                                 {"count": count}).data["fids"]]

    # -------------------------------------------------- changelog consumer
    def changelog_register(self) -> str:
        """Register as a changelog consumer; returns the consumer id."""
        return self.imp.request("changelog_register", {}).data["id"]

    def changelog_deregister(self, user: str):
        self.imp.request("changelog_deregister", {"id": user})

    def changelog_read(self, user: str, since_idx: int | None = None,
                       count: int = 0) -> list[dict]:
        """Fetch retained records above `since_idx` (default: the
        consumer's bookmark). Does NOT advance the bookmark — that is
        `changelog_clear`'s job, after the consumer persisted them."""
        return self.imp.request(
            "changelog_read", {"id": user, "since_idx": since_idx,
                               "count": count}).data["records"]

    def changelog_clear(self, user: str, up_to: int) -> dict:
        """Acknowledge records <= up_to; the MDT purges only past the
        minimum bookmark across all registered consumers."""
        return self.imp.request(
            "changelog_clear", {"id": user, "up_to": up_to}).data


class Lmv:
    """Logical Metadata Volume: routes ops across the MDS cluster
    (§6.7.1.1). mdcs[i] serves inode group i."""

    def __init__(self, mdcs: list[Mdc]):
        self.mdcs = mdcs
        self.sim = mdcs[0].sim

    def mdc_for_fid(self, fid) -> Mdc:
        return self.mdcs[tuple(fid)[0] % len(self.mdcs)]

    def mdc_for_rename(self, src_fid, dst_fid) -> Mdc:
        """§6.7.1.4: coordinate at the highest-order resource so the lock
        ordering sequence starts correctly."""
        first = min(tuple(src_fid), tuple(dst_fid))
        return self.mdc_for_fid(first)

    # ------------------------------------------------------- routed ops
    def getattr(self, fid, want_ea=False):
        return self.mdc_for_fid(fid).getattr(fid, want_ea)

    def getattr_lock(self, parent_fid, name, want_ea=False):
        mdc = self.mdc_for_fid(parent_fid)
        lk, data = mdc.getattr_lock(parent_fid, name, want_ea)
        if data.get("redirect"):
            # split directory: retry at the bucket's MDS (§6.7.3)
            bfid = tuple(data["redirect"])
            mdc = self.mdc_for_fid(bfid)
            lk, data = mdc.enqueue_intent(
                bfid, "PR", {"op": "lookup", "parent": bfid,
                             "name": name, "want_ea": want_ea})
        data["_granted_by"] = self.mdcs.index(mdc)
        if data.get("remote") and data.get("fid"):
            # entry's inode lives on a peer MDS (directly, or behind the
            # bucket redirect): 2nd RPC for attributes (the §6.7.3
            # 'worst case 3 RPCs' path). The lock is on the lookup-side
            # namespace, so these attrs are NOT covered by it — flag
            # them so the client attr cache skips them.
            fid = tuple(data["fid"])
            d2 = self.mdc_for_fid(fid).getattr(fid, want_ea)
            d2["status"] = 0
            d2["_remote"] = True
            d2["_granted_by"] = self.mdcs.index(mdc)
            return lk, d2
        return lk, data

    def open(self, parent_fid, name, flags="r", mode=0o644):
        lk, data = self.mdc_for_fid(parent_fid).open(parent_fid, name,
                                                     flags, mode)
        if data.get("remote") and data.get("fid"):
            # the entry's inode lives on a peer MDT (cross-MDT rename
            # residue): re-issue the open BY FID at the owning MDT —
            # the same 2-RPC worst case as the lookup redirect (§6.7.3)
            fid = tuple(data["fid"])
            return self.mdc_for_fid(fid).enqueue_intent(
                fid, "PR", {"op": "open", "by_fid": True, "fid": fid,
                            "flags": flags, "mode": mode})
        return lk, data

    def readdir_plus(self, fid, page_size: int, want_ea: bool = True):
        """readdir-plus page generator (ISSUE-5): yields (mdc, lock,
        entries) pages — entries = {name: {"fid", "attrs"?, "ea"?,
        "remote"?}} — walking the master directory and then every
        split-dir hash bucket AT ITS OWN MDS (one page-RPC per MDT, each
        under that MDT's dir/bucket PR lock). Entries whose inode a peer
        MDT owns are batch-resolved with ONE getattr_bulk per owning MDT
        per page (their attrs stay flagged `remote`: no covering lock)."""
        todo = [tuple(fid)]
        master = True
        while todo:
            dfid = todo.pop(0)
            mdc = self.mdc_for_fid(dfid)
            after = None
            while True:
                lk, data = mdc.readdir_plus(dfid, page_size, after,
                                            want_ea)
                st = data.get("status", 0)
                if st:
                    raise R.RpcError(st, str(dfid))
                if master and data.get("buckets"):
                    todo.extend(tuple(b) for b in data["buckets"])
                page = data["entries"]
                remote: dict = {}
                for name, e in page.items():
                    if e.get("remote"):
                        remote.setdefault(self.mdc_for_fid(e["fid"]),
                                          []).append(name)
                for rmdc, names in remote.items():
                    outs = rmdc.getattr_bulk(
                        [page[n]["fid"] for n in names], want_ea)
                    for n, a in zip(names, outs):
                        if a:
                            page[n].update(a)
                yield mdc, lk, page
                if data.get("next") is None:
                    break
                after = data["next"]
            master = False

    def readdir(self, fid):
        """Client-side bucket iteration for split directories (§6.7.3)."""
        out = self.mdc_for_fid(fid).readdir(fid)
        if out.get("buckets"):
            entries = dict(out["entries"])
            for bfid in out["buckets"]:
                bfid = tuple(bfid)
                b = self.mdc_for_fid(bfid).readdir(bfid)
                entries.update(b["entries"])
            out = dict(out, entries=entries)
        return out

    def reint(self, rec: dict):
        key = {"create": "parent", "unlink": "parent", "link": "parent",
               "setattr": "fid"}.get(rec["type"])
        if rec["type"] == "rename":
            mdc = self.mdc_for_rename(rec["src"], rec["dst"])
        else:
            mdc = self.mdc_for_fid(rec[key])
        return mdc.reint(rec)

    def close(self, fid, handle, size=None, mtime=None):
        return self.mdc_for_fid(fid).close(handle, size, mtime, fid=fid)

    def statfs(self):
        return [m.statfs() for m in self.mdcs]


# -------------------------------------------------------------------- WBC

_GONE = object()          # shadow negative entry: name is known absent


class WbcCache:
    """Metadata write-back cache for one directory subtree (ch. 17).

    Holds an EX subtree lock + preallocated fids; namespace updates below
    the root apply to a local shadow and append reintegration records
    (the InterMezzo property, §2.4), shipped later as `reint_batch` RPCs.
    Flush triggers: `release()`, a blocking AST on the subtree lock
    (§17.2), an fsync/close barrier from the VFS layer — and, when the
    thresholds are armed, background flushes on batch size (`batch`
    records ship as one RPC, the tail stays dirty), total dirty records
    (`max_dirty`: cache pressure, everything ships) or age of the oldest
    record (`max_age`). A multi-batch flush keeps up to `max_rpcs`
    batches in flight (§17.1 reintegration pipelining).

    The shadow keeps a COMPLETE listing for every directory it owns —
    shadow-born directories by construction, pre-existing ones seeded
    with one readdir on first touch — so lookups, readdirs and negative
    lookups (`GONE`) under the subtree cost zero RPCs while the EX lock
    holds them coherent.
    """

    GONE = _GONE

    def __init__(self, lmv: Lmv, root_fid: tuple, *, batch: int = 0,
                 max_dirty: int = 0, max_age: float = 0.0,
                 max_rpcs: int = 8):
        self.lmv = lmv
        self.root_fid = tuple(root_fid)
        self.mdc = lmv.mdc_for_fid(root_fid)
        self.sim = lmv.sim
        self.batch = batch             # background flush unit (0 = off)
        self.max_dirty = max_dirty     # dirty-record cap (0 = off)
        self.max_age = max_age         # oldest-record age cap (0 = off)
        self.max_rpcs = max(1, max_rpcs)
        self.records: list[dict] = []
        self.fids: list[tuple] = []
        self.shadow: dict[tuple, dict] = {}    # dir fid -> {name: fid}
        self.shadow_attrs: dict[tuple, dict] = {}   # shadow-born inodes
        self.complete: set[tuple] = set()      # dirs with full listings
        self.gone: set[tuple] = set()          # (pfid, name) known absent
        self.known: set[tuple] = set()         # fids inside the subtree
        self.lock: dlm_mod.Lock | None = None
        self.active = False
        self.first_dirty_t: float | None = None
        # fsio sinks: destroy_cb consumes unlink reply data (ea+cookies)
        # so flushed unlinks still destroy their OST objects
        self.destroy_cb = None
        self._orig_cb: Any = None
        self._cb_installed = False
        self._revoke_cb = None

    # ------------------------------------------------------------ grant
    def acquire(self) -> bool:
        lk, data = self.mdc.enqueue_intent(
            self.root_fid, "EX", {"op": "wbc", "fid": self.root_fid})
        if not (data or {}).get("wbc_granted"):
            self.sim.stats.count("wbc.denied")
            return False
        self.lock = lk
        self.active = True
        self.known.add(self.root_fid)
        self.fids = self.mdc.prealloc_fids(128)
        self.sim.stats.count("wbc.granted")
        # flush when the subtree lock is revoked; remember the ORIGINAL
        # callback so release() can restore it (a wrapper per
        # enable/disable cycle used to pile up here, each flushing a
        # dead cache)
        self._orig_cb = self.mdc.locks.flush_cb
        self._cb_installed = True

        def cb(lock):
            if self.lock is not None and lock.handle == self.lock.handle:
                self.flush()
            elif self._orig_cb:
                self._orig_cb(lock)
        self.mdc.locks.flush_cb = cb
        # the lock leaving the cache for ANY reason (AST, eviction)
        # deactivates the cache: the shadow is only coherent under it
        def rcb(lock):
            if self.lock is not None and lock.handle == self.lock.handle:
                self._deactivate(lost=True)
        self._revoke_cb = rcb
        self.mdc.locks.revoke_cbs.append(rcb)
        if lk is not None:
            lk.dirty = True
        return True

    def _deactivate(self, lost: bool = False):
        """The subtree lock is gone (or being released): the shadow is no
        longer coherent. With `lost`, pending records die with the lock —
        eviction semantics: exactly the unflushed tail is lost."""
        if lost and self.records:
            self.sim.stats.count("wbc.lost_records", len(self.records))
            self.records = []
        self.first_dirty_t = None
        self.active = False
        self.lock = None
        self.shadow.clear()
        self.shadow_attrs.clear()
        self.complete.clear()
        self.gone.clear()
        self.known.clear()

    def _fid(self) -> tuple:
        if not self.fids:
            self.fids = self.mdc.prealloc_fids(128)
        return self.fids.pop(0)

    def in_subtree(self, fid: tuple) -> bool:
        return tuple(fid) == self.root_fid or tuple(fid) in self.known

    # ----------------------------------------------------- shadow reads
    def _ensure_listing(self, pfid: tuple) -> bool:
        """Make the shadow's listing of `pfid` complete. Shadow-born dirs
        are complete by construction; a pre-existing dir is seeded with
        ONE readdir under the subtree EX lock (amortised over every later
        lookup/readdir below it). Returns False when the shadow cannot
        own the dir (split into buckets, outside the subtree)."""
        p = tuple(pfid)
        if p in self.complete:
            return True
        if p in self.shadow_attrs:                 # shadow-born
            self.shadow.setdefault(p, {})
            self.complete.add(p)
            return True
        if not self.in_subtree(p):
            return False
        try:
            out = self.lmv.readdir(p)
        except R.RpcError:
            return False
        if out.get("buckets"):
            return False                           # split dir: too big
        listing = self.shadow.setdefault(p, {})
        for name, fid in out["entries"].items():
            if (p, name) in self.gone:
                continue                           # locally unlinked
            listing.setdefault(name, tuple(fid))   # local updates win
            self.known.add(tuple(fid))
        self.gone = {g for g in self.gone if g[0] != p}
        self.complete.add(p)
        self.sim.stats.count("wbc.seed")
        return True

    def lookup(self, parent_fid, name):
        """Shadow lookup: a fid, GONE (known absent — the shadow's
        negative entry), or None (the shadow does not know)."""
        p = tuple(parent_fid)
        if (p, name) in self.gone:
            return _GONE
        ent = self.shadow.get(p, {}).get(name)
        if ent is not None:
            return ent
        return _GONE if p in self.complete else None

    def child(self, parent_fid, name):
        """Resolve one component under the WBC. Returns (handled, fid):
        handled=False falls through to the MDS; handled=True with
        fid=None is an authoritative ENOENT answered locally."""
        p = tuple(parent_fid)
        if not self.active or not self.in_subtree(p):
            return False, None
        hit = self.lookup(p, name)
        if hit is _GONE:
            return True, None
        if hit is not None:
            return True, hit
        if not self._ensure_listing(p):
            return False, None
        hit = self.lookup(p, name)
        return True, None if hit is _GONE else hit

    def listing(self, pfid) -> dict | None:
        """Complete {name: fid} view of a shadow-owned directory."""
        if not self._ensure_listing(pfid):
            return None
        return dict(self.shadow.get(tuple(pfid), {}))

    def attrs(self, fid) -> dict | None:
        return self.shadow_attrs.get(tuple(fid))

    # --------------------------------------------------------- local ops
    def create(self, parent_fid, name, ftype=mds_mod.S_IFREG,
               mode=0o644, ea=None, target="") -> tuple:
        """Local create: zero RPCs (the InterMezzo property, §2.4)."""
        fid = self._fid()
        p = tuple(parent_fid)
        rec = {"type": "create", "parent": p, "name": name,
               "fid": fid, "ftype": ftype, "mode": mode, "remote_ok": False}
        if ea:
            rec["ea"] = ea
        if target:
            rec["target"] = target
        self.records.append(rec)
        self.shadow.setdefault(p, {})[name] = fid
        self.gone.discard((p, name))
        self.shadow_attrs[fid] = {"fid": fid, "type": ftype, "mode": mode,
                                  "nlink": 2 if ftype == mds_mod.S_IFDIR
                                  else 1,
                                  "mtime": self.sim.now, "size": 0,
                                  "mtime_on_ost": False}
        if ea:
            self.shadow_attrs[fid]["ea"] = dict(ea)
        if target:
            self.shadow_attrs[fid]["symlink"] = target
        self.known.add(fid)
        if ftype == mds_mod.S_IFDIR:
            # born in the cache: its listing is complete by construction
            self.shadow.setdefault(fid, {})
            self.complete.add(fid)
        self._note_dirty()
        return fid

    def setattr(self, fid, attrs=None, ea=None):
        rec = {"type": "setattr", "fid": tuple(fid), "attrs": attrs or {}}
        if ea:
            rec["ea"] = ea
        self.records.append(rec)
        sa = self.shadow_attrs.get(tuple(fid))
        if sa is not None:
            sa.update(attrs or {})
            if ea:
                sa.setdefault("ea", {}).update(ea)
        self._note_dirty()

    def unlink(self, parent_fid, name):
        p = tuple(parent_fid)
        self.records.append({"type": "unlink", "parent": p, "name": name})
        fid = self.shadow.get(p, {}).pop(name, None)
        if fid is not None:
            self.shadow_attrs.pop(tuple(fid), None)
            self.shadow.pop(tuple(fid), None)
            self.complete.discard(tuple(fid))
            self.known.discard(tuple(fid))
        if p not in self.complete:
            # incomplete listing: remember the negative entry explicitly
            self.gone.add((p, name))
        self._note_dirty()

    def forget(self, pfid):
        """Drop the shadow's claim on one directory (a synchronous op
        slipped past the shadow): the next access re-seeds it."""
        p = tuple(pfid)
        self.shadow.pop(p, None)
        self.complete.discard(p)
        self.gone = {g for g in self.gone if g[0] != p}

    # -------------------------------------------------------------- flush
    def _note_dirty(self):
        self.sim.stats.count("wbc.local_update")
        if self.first_dirty_t is None:
            self.first_dirty_t = self.sim.now
        if self.max_dirty and len(self.records) >= self.max_dirty:
            self.sim.stats.count("wbc.flush_pressure")
            self.flush()
        elif self.batch and len(self.records) >= self.batch:
            self.sim.stats.count("wbc.flush_batch")
            self._flush_n(self.batch)
        elif self.max_age and self.sim.now - self.first_dirty_t \
                >= self.max_age:
            self.sim.stats.count("wbc.flush_age")
            self.flush()

    def flush(self) -> int:
        """Barrier: reintegrate EVERY pending record (fsync/close/
        release/AST all funnel here)."""
        return self._flush_n(len(self.records))

    def _flush_n(self, n: int) -> int:
        """Ship the oldest `n` records, split into `batch`-sized
        reint_batch RPCs, up to `max_rpcs` in flight per wave. Records
        apply in order: batches within a wave arrive (and are serviced)
        in issue order at the one owning MDS."""
        if n <= 0 or not self.records:
            return 0
        recs, self.records = self.records[:n], self.records[n:]
        if not self.records:
            self.first_dirty_t = None
        act = fail_mod.state.check("mdc.wbc_flush")
        if act in ("drop", "crash"):
            # client-side site (crash degrades to drop, like osc.flush):
            # the first batch RPC is lost on the wire; the import
            # recovers by timeout -> reconnect -> resend
            self.sim.faults.drop_next[self.mdc.imp.active_nid] += 1
        bs = self.batch or len(recs)
        batches = [recs[i:i + bs] for i in range(0, len(recs), bs)]
        for i in range(0, len(batches), self.max_rpcs):
            wave = batches[i:i + self.max_rpcs]
            if len(wave) == 1:
                reps = [self.mdc.reint_batch(wave[0])]
            else:
                reps = self.sim.parallel(
                    [(lambda b=b: self.mdc.reint_batch(b))
                     for b in wave])
            for b, rep in zip(wave, reps):
                self._flush_done(b, rep)
        return len(recs)

    def _flush_done(self, batch: list, rep: R.Reply):
        st = self.sim.stats
        st.count("wbc.flush")
        st.count("wbc.flushed_records", len(batch))
        size = len(batch)
        st.count(f"wbc.batch_hist.{1 << max(0, size - 1).bit_length()}")
        for r, res in zip(batch, (rep.data or {}).get("results") or []):
            if res.get("status"):
                st.count("wbc.reint_errors")
                continue
            d = res.get("data") or {}
            if r["type"] == "unlink" and d.get("ea") and self.destroy_cb:
                # the flushed unlink dropped the last link: destroy the
                # OST objects with the returned EA + llog cookies
                self.destroy_cb(d)

    def release(self):
        self.flush()
        if self.lock is not None:
            self.lock.dirty = False
            self.mdc.locks.cancel(self.lock)   # fires _deactivate via rcb
        # restore the pre-acquire callbacks (no wrapper stacking)
        if self._cb_installed:
            self.mdc.locks.flush_cb = self._orig_cb
            self._cb_installed = False
            self._orig_cb = None
        if self._revoke_cb is not None:
            try:
                self.mdc.locks.revoke_cbs.remove(self._revoke_cb)
            except ValueError:
                pass
            self._revoke_cb = None
        self._deactivate()
