"""Metadata client (MDC), clustered-MDS router (LMV), and the client
metadata write-back cache (paper §6.7.1.1, ch. 17, ch. 26).

The LMV is deliberately thin (§6.7.1.1: "the client part of the
implementation is very trivial"): it picks the MDC by
  (1) the inode group of the fid in the request,
  (2) the name hash + bucket EA for split directories,
  (3) fid order for rename coordination (§6.7.1.4).

The write-back cache (ch. 17) holds a subtree lock + preallocated fids;
updates apply to a local shadow namespace and are recorded as reintegration
records, flushed as ONE `reint_batch` RPC (on sync, cache pressure, or a
blocking AST on the subtree lock).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Optional

from repro.core import dlm as dlm_mod
from repro.core import mds as mds_mod
from repro.core import ptlrpc as R


class Mdc:
    """Client stub for ONE MDS target."""

    def __init__(self, rpc: R.RpcClient, target_uuid: str, nids: list[str]):
        self.rpc = rpc
        self.sim = rpc.sim
        self.uuid = target_uuid
        self.imp = rpc.import_target(target_uuid, nids, "mds")
        self.locks = dlm_mod.LockClient(rpc, self.imp)

    # -------------------------------------------------------- intent ops
    def enqueue_intent(self, res_fid, mode: str, intent: dict):
        """mdc_enqueue (§6.2.2): lock + operation in one RPC."""
        def fixup(req, rep):
            d = (rep.data or {}).get("intent") or {}
            attrs = d.get("attrs")
            if d.get("created") and attrs:
                # pin the assigned fid so replay recreates the same inode
                req.body["intent"]["fid"] = tuple(attrs["fid"])
        lk, data, lvb = self.locks.enqueue(
            ("fid", *tuple(res_fid)), mode, None, intent=intent,
            use_cache=False, fixup=fixup)
        return lk, (data or {})

    def getattr_lock(self, parent_fid, name: str, want_ea: bool = False):
        return self.enqueue_intent(
            parent_fid, "PR", {"op": "lookup", "parent": tuple(parent_fid),
                               "name": name, "want_ea": want_ea})

    def open(self, parent_fid, name: str, flags: str = "r",
             mode: int = 0o644):
        return self.enqueue_intent(
            parent_fid, "PR", {"op": "open", "parent": tuple(parent_fid),
                               "name": name, "flags": flags, "mode": mode})

    def readdir_plus(self, fid, page_size: int, after: str | None = None,
                     want_ea: bool = True):
        """ONE readdir-plus page (entries + per-entry attrs/EA) under
        the directory's PR lock (ISSUE-5). `after` is a NAME cursor (the
        last name of the previous page) so pagination stays stable under
        concurrent creates/unlinks. Returns (lock, data)."""
        return self.enqueue_intent(
            fid, "PR", {"op": "readdir", "fid": tuple(fid),
                        "page_size": page_size, "after": after,
                        "want_ea": want_ea})

    # --------------------------------------------------------- plain ops
    def getattr(self, fid, want_ea: bool = False) -> dict:
        return self.imp.request("getattr", {"fid": tuple(fid),
                                            "want_ea": want_ea}).data

    def getattr_bulk(self, fids: list, want_ea: bool = False) -> list:
        """Batched getattr: ONE RPC, attrs (+EA) per fid (None for
        unknown fids) — the statahead / readdir-plus merge primitive."""
        return self.imp.request(
            "getattr_bulk", {"fids": [tuple(f) for f in fids],
                             "want_ea": want_ea}).data["attrs"]

    def readdir(self, fid) -> dict:
        return self.imp.request("readdir", {"fid": tuple(fid)}).data

    def reint(self, rec: dict) -> R.Reply:
        def fixup(req, rep):
            # pin the server-assigned fid so REPLAY recreates the same
            # inode (even when it was created on a peer MDS)
            if rec["type"] == "create" and (rep.data or {}).get("fid"):
                req.body["rec"]["fid"] = tuple(rep.data["fid"])
        return self.imp.request("reint", {"rec": rec}, fixup=fixup)

    def reint_batch(self, records: list) -> R.Reply:
        return self.imp.request("reint_batch", {"records": records})

    def close(self, handle: int, size=None, mtime=None,
              fid=None) -> R.Reply:
        return self.imp.request("close", {"handle": handle, "size": size,
                                          "mtime": mtime,
                                          "fid": tuple(fid) if fid else None})

    def statfs(self) -> dict:
        return self.imp.request("statfs", {}).data

    def prealloc_fids(self, count: int = 64) -> list:
        return [tuple(f) for f in
                self.imp.request("prealloc_fids",
                                 {"count": count}).data["fids"]]

    # -------------------------------------------------- changelog consumer
    def changelog_register(self) -> str:
        """Register as a changelog consumer; returns the consumer id."""
        return self.imp.request("changelog_register", {}).data["id"]

    def changelog_deregister(self, user: str):
        self.imp.request("changelog_deregister", {"id": user})

    def changelog_read(self, user: str, since_idx: int | None = None,
                       count: int = 0) -> list[dict]:
        """Fetch retained records above `since_idx` (default: the
        consumer's bookmark). Does NOT advance the bookmark — that is
        `changelog_clear`'s job, after the consumer persisted them."""
        return self.imp.request(
            "changelog_read", {"id": user, "since_idx": since_idx,
                               "count": count}).data["records"]

    def changelog_clear(self, user: str, up_to: int) -> dict:
        """Acknowledge records <= up_to; the MDT purges only past the
        minimum bookmark across all registered consumers."""
        return self.imp.request(
            "changelog_clear", {"id": user, "up_to": up_to}).data


class Lmv:
    """Logical Metadata Volume: routes ops across the MDS cluster
    (§6.7.1.1). mdcs[i] serves inode group i."""

    def __init__(self, mdcs: list[Mdc]):
        self.mdcs = mdcs
        self.sim = mdcs[0].sim

    def mdc_for_fid(self, fid) -> Mdc:
        return self.mdcs[tuple(fid)[0] % len(self.mdcs)]

    def mdc_for_rename(self, src_fid, dst_fid) -> Mdc:
        """§6.7.1.4: coordinate at the highest-order resource so the lock
        ordering sequence starts correctly."""
        first = min(tuple(src_fid), tuple(dst_fid))
        return self.mdc_for_fid(first)

    # ------------------------------------------------------- routed ops
    def getattr(self, fid, want_ea=False):
        return self.mdc_for_fid(fid).getattr(fid, want_ea)

    def getattr_lock(self, parent_fid, name, want_ea=False):
        mdc = self.mdc_for_fid(parent_fid)
        lk, data = mdc.getattr_lock(parent_fid, name, want_ea)
        if data.get("redirect"):
            # split directory: retry at the bucket's MDS (§6.7.3)
            bfid = tuple(data["redirect"])
            mdc = self.mdc_for_fid(bfid)
            lk, data = mdc.enqueue_intent(
                bfid, "PR", {"op": "lookup", "parent": bfid,
                             "name": name, "want_ea": want_ea})
        data["_granted_by"] = self.mdcs.index(mdc)
        if data.get("remote") and data.get("fid"):
            # entry's inode lives on a peer MDS (directly, or behind the
            # bucket redirect): 2nd RPC for attributes (the §6.7.3
            # 'worst case 3 RPCs' path). The lock is on the lookup-side
            # namespace, so these attrs are NOT covered by it — flag
            # them so the client attr cache skips them.
            fid = tuple(data["fid"])
            d2 = self.mdc_for_fid(fid).getattr(fid, want_ea)
            d2["status"] = 0
            d2["_remote"] = True
            d2["_granted_by"] = self.mdcs.index(mdc)
            return lk, d2
        return lk, data

    def open(self, parent_fid, name, flags="r", mode=0o644):
        lk, data = self.mdc_for_fid(parent_fid).open(parent_fid, name,
                                                     flags, mode)
        if data.get("remote") and data.get("fid"):
            # the entry's inode lives on a peer MDT (cross-MDT rename
            # residue): re-issue the open BY FID at the owning MDT —
            # the same 2-RPC worst case as the lookup redirect (§6.7.3)
            fid = tuple(data["fid"])
            return self.mdc_for_fid(fid).enqueue_intent(
                fid, "PR", {"op": "open", "by_fid": True, "fid": fid,
                            "flags": flags, "mode": mode})
        return lk, data

    def readdir_plus(self, fid, page_size: int, want_ea: bool = True):
        """readdir-plus page generator (ISSUE-5): yields (mdc, lock,
        entries) pages — entries = {name: {"fid", "attrs"?, "ea"?,
        "remote"?}} — walking the master directory and then every
        split-dir hash bucket AT ITS OWN MDS (one page-RPC per MDT, each
        under that MDT's dir/bucket PR lock). Entries whose inode a peer
        MDT owns are batch-resolved with ONE getattr_bulk per owning MDT
        per page (their attrs stay flagged `remote`: no covering lock)."""
        todo = [tuple(fid)]
        master = True
        while todo:
            dfid = todo.pop(0)
            mdc = self.mdc_for_fid(dfid)
            after = None
            while True:
                lk, data = mdc.readdir_plus(dfid, page_size, after,
                                            want_ea)
                st = data.get("status", 0)
                if st:
                    raise R.RpcError(st, str(dfid))
                if master and data.get("buckets"):
                    todo.extend(tuple(b) for b in data["buckets"])
                page = data["entries"]
                remote: dict = {}
                for name, e in page.items():
                    if e.get("remote"):
                        remote.setdefault(self.mdc_for_fid(e["fid"]),
                                          []).append(name)
                for rmdc, names in remote.items():
                    outs = rmdc.getattr_bulk(
                        [page[n]["fid"] for n in names], want_ea)
                    for n, a in zip(names, outs):
                        if a:
                            page[n].update(a)
                yield mdc, lk, page
                if data.get("next") is None:
                    break
                after = data["next"]
            master = False

    def readdir(self, fid):
        """Client-side bucket iteration for split directories (§6.7.3)."""
        out = self.mdc_for_fid(fid).readdir(fid)
        if out.get("buckets"):
            entries = dict(out["entries"])
            for bfid in out["buckets"]:
                bfid = tuple(bfid)
                b = self.mdc_for_fid(bfid).readdir(bfid)
                entries.update(b["entries"])
            out = dict(out, entries=entries)
        return out

    def reint(self, rec: dict):
        key = {"create": "parent", "unlink": "parent", "link": "parent",
               "setattr": "fid"}.get(rec["type"])
        if rec["type"] == "rename":
            mdc = self.mdc_for_rename(rec["src"], rec["dst"])
        else:
            mdc = self.mdc_for_fid(rec[key])
        return mdc.reint(rec)

    def close(self, fid, handle, size=None, mtime=None):
        return self.mdc_for_fid(fid).close(handle, size, mtime, fid=fid)

    def statfs(self):
        return [m.statfs() for m in self.mdcs]


# -------------------------------------------------------------------- WBC

@dataclasses.dataclass
class WbcRecord:
    rec: dict          # a reint record, replayed verbatim at flush


class WbcCache:
    """Metadata write-back cache for one directory subtree (ch. 17).

    Holds an EX subtree lock; `mkdir/create/...` below the root apply to a
    local shadow and append records. `flush()` reintegrates in ONE RPC.
    A blocking AST on the subtree lock triggers flush + drop (§17.2).
    """

    def __init__(self, lmv: Lmv, root_fid: tuple):
        self.lmv = lmv
        self.root_fid = tuple(root_fid)
        self.mdc = lmv.mdc_for_fid(root_fid)
        self.sim = lmv.sim
        self.records: list[dict] = []
        self.fids: list[tuple] = []
        self.shadow: dict[tuple, dict] = {}    # fid -> {name: fid} created
        self.shadow_attrs: dict[tuple, dict] = {}
        self.lock: dlm_mod.Lock | None = None
        self.active = False

    # ------------------------------------------------------------ grant
    def acquire(self) -> bool:
        lk, data = self.mdc.enqueue_intent(
            self.root_fid, "EX", {"op": "wbc", "fid": self.root_fid})
        if not (data or {}).get("wbc_granted"):
            self.sim.stats.count("wbc.denied")
            return False
        self.lock = lk
        self.active = True
        self.fids = self.mdc.prealloc_fids(128)
        self.sim.stats.count("wbc.granted")
        # flush when the subtree lock is revoked
        orig_cb = self.mdc.locks.flush_cb

        def cb(lock):
            if self.lock is not None and lock.handle == self.lock.handle:
                self.flush()
            if orig_cb:
                orig_cb(lock)
        self.mdc.locks.flush_cb = cb
        if lk is not None:
            lk.dirty = True
        return True

    def _fid(self) -> tuple:
        if not self.fids:
            self.fids = self.mdc.prealloc_fids(128)
        return self.fids.pop(0)

    def in_subtree(self, fid: tuple) -> bool:
        return tuple(fid) == self.root_fid or tuple(fid) in self.shadow_attrs

    # --------------------------------------------------------- local ops
    def create(self, parent_fid, name, ftype=mds_mod.S_IFREG,
               mode=0o644, ea=None, target="") -> tuple:
        """Local create: zero RPCs (the InterMezzo property, §2.4)."""
        fid = self._fid()
        rec = {"type": "create", "parent": tuple(parent_fid), "name": name,
               "fid": fid, "ftype": ftype, "mode": mode, "remote_ok": False}
        if ea:
            rec["ea"] = ea
        if target:
            rec["target"] = target
        self.records.append(rec)
        self.shadow.setdefault(tuple(parent_fid), {})[name] = fid
        self.shadow_attrs[fid] = {"fid": fid, "type": ftype, "mode": mode,
                                  "nlink": 2 if ftype == "dir" else 1,
                                  "mtime": self.sim.now, "size": 0}
        self.sim.stats.count("wbc.local_update")
        return fid

    def setattr(self, fid, attrs=None, ea=None):
        rec = {"type": "setattr", "fid": tuple(fid), "attrs": attrs or {}}
        if ea:
            rec["ea"] = ea
        self.records.append(rec)
        if tuple(fid) in self.shadow_attrs:
            self.shadow_attrs[tuple(fid)].update(attrs or {})
        self.sim.stats.count("wbc.local_update")

    def unlink(self, parent_fid, name):
        self.records.append({"type": "unlink", "parent": tuple(parent_fid),
                             "name": name})
        self.shadow.get(tuple(parent_fid), {}).pop(name, None)
        self.sim.stats.count("wbc.local_update")

    def lookup(self, parent_fid, name):
        return self.shadow.get(tuple(parent_fid), {}).get(name)

    # -------------------------------------------------------------- flush
    def flush(self) -> int:
        """Reintegrate: ship ALL records in one batched RPC (§17.1)."""
        if not self.records:
            return 0
        recs, self.records = self.records, []
        self.mdc.reint_batch(recs)
        self.sim.stats.count("wbc.flush")
        return len(recs)

    def release(self):
        self.flush()
        if self.lock is not None:
            self.lock.dirty = False
            self.mdc.locks.cancel(self.lock)
            self.lock = None
        self.active = False
