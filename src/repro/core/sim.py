"""Simulation substrate: virtual clock, link model, fault injection, stats.

The whole Lustre cluster runs in-process and synchronously (handlers are
plain Python calls), while *time* is modelled analytically: every message
occupies its (src, dst) link for latency + bytes/bandwidth, and callers that
wait for N parallel completions advance the clock to max(completion times).
This gives deterministic, reproducible performance numbers for the
benchmarks (striping scaling, COBD read scaling, recovery time) without
threads.
"""
from __future__ import annotations

import dataclasses
import random
from collections import defaultdict


class Clock:
    """Virtual time in seconds."""

    def __init__(self):
        self.now = 0.0

    def advance_to(self, t: float) -> None:
        if t > self.now:
            self.now = t

    def advance(self, dt: float) -> None:
        self.now += dt


@dataclasses.dataclass
class LinkSpec:
    """One network type (NAL). Default numbers ~ GigE (socknal)."""
    latency: float = 50e-6          # per-message latency (s)
    bandwidth: float = 1e9          # bytes/s
    small_msg_cost: float = 5e-6    # per-message CPU/serialisation cost


# NAL presets from the paper's world: TCP (socknal), Quadrics Elan (qswnal).
NALS = {
    "socknal": LinkSpec(latency=50e-6, bandwidth=110e6),
    "qswnal": LinkSpec(latency=5e-6, bandwidth=340e6),
    "ibnal": LinkSpec(latency=7e-6, bandwidth=900e6),
    "lonal": LinkSpec(latency=1e-6, bandwidth=4e9),     # loopback
}


class FaultPlan:
    """Mutable fault-injection state consulted on every delivery."""

    def __init__(self, seed: int = 0):
        self.rng = random.Random(seed)
        self.down_nids: set = set()          # dead nodes (drop all traffic)
        self.drop_prob: dict = {}            # (src,dst) or "*" -> prob
        self.partitions: set = set()         # frozenset({a, b}) cut pairs
        self.drop_next: defaultdict = defaultdict(int)  # nid -> count
        # per-link latency model (chaos harness): extra seconds added to
        # every hop on (src,dst), or "*" for the whole fabric — a slow
        # WAN link / congested switch, distinct from dropping traffic
        self.link_delay: dict = {}

    def should_drop(self, src, dst) -> bool:
        if src in self.down_nids or dst in self.down_nids:
            return True
        if frozenset((src, dst)) in self.partitions:
            return True
        if self.drop_next[dst] > 0:
            self.drop_next[dst] -= 1
            return True
        p = self.drop_prob.get((src, dst), self.drop_prob.get("*", 0.0))
        return p > 0 and self.rng.random() < p

    def extra_latency(self, src, dst) -> float:
        if not self.link_delay:
            return 0.0
        return self.link_delay.get((src, dst),
                                   self.link_delay.get("*", 0.0))

    def heal(self):
        """Clear every injected network fault (chaos `heal` event);
        down_nids is owned by Node.fail/restart and is left alone."""
        self.drop_prob.clear()
        self.partitions.clear()
        self.drop_next.clear()
        self.link_delay.clear()


class Stats:
    """Cluster-wide counters; benchmarks read these.

    Counters are ALSO namespaced by node uuid so the monitoring plane
    reports per-target numbers that sum to the cluster totals.
    Attribution is contextual: ``ptlrpc.Node._request_in`` pushes the
    serving target's uuid onto ``node_stack`` for the duration of the
    handler, so every count made while serving target X lands in X's
    namespace automatically (nested server->server RPCs re-attribute
    correctly because the inner target pushes on top).  Code running
    outside any service context (client-side caches) may pass an
    explicit ``node=`` fallback; counts with neither stay global-only.
    """

    def __init__(self):
        self.counters = defaultdict(int)
        self.bytes = defaultdict(int)
        self.node_counters = defaultdict(lambda: defaultdict(int))
        self.node_bytes = defaultdict(lambda: defaultdict(int))
        self.node_stack: list[str] = []   # serving-target uuid context

    def _node(self, fallback):
        return self.node_stack[-1] if self.node_stack else fallback

    def count(self, key: str, n: int = 1, node: str | None = None):
        self.counters[key] += n
        owner = self._node(node)
        if owner is not None:
            self.node_counters[owner][key] += n

    def add_bytes(self, key: str, n: int, node: str | None = None):
        self.bytes[key] += n
        owner = self._node(node)
        if owner is not None:
            self.node_bytes[owner][key] += n

    def snapshot(self) -> dict:
        return {"counters": dict(self.counters), "bytes": dict(self.bytes)}

    def node_snapshot(self, node: str) -> dict:
        return {"counters": dict(self.node_counters.get(node, {})),
                "bytes": dict(self.node_bytes.get(node, {}))}

    def reset(self):
        self.counters.clear()
        self.bytes.clear()
        self.node_counters.clear()
        self.node_bytes.clear()
        self.node_stack.clear()


class Simulator:
    """Shared context handed to every node: clock + faults + stats."""

    def __init__(self, seed: int = 0):
        self.clock = Clock()
        self.faults = FaultPlan(seed)
        self.stats = Stats()
        # RPC span registry (core.metrics): trace-id dedup lives HERE so
        # exactly-once accounting survives target crash/restart
        from repro.core.metrics import MetricsRegistry
        self.metrics = MetricsRegistry()
        # OBD_FAIL failpoints are node-global (like obd_fail_loc); a fresh
        # simulator starts disarmed so clusters are isolated (core.fail)
        from repro.core import fail as fail_mod
        self.fail = fail_mod.state
        self.fail.reset()
        self.fail.sim = self       # 'delay' actions advance this clock
        # runtime sanitizer (SIM_SANITIZE=1): like fail.state it is
        # module-global; per-sim graphs reset here so clusters are
        # isolated (client uuids repeat across clusters)
        from repro.core import sanitize
        self.sanitize = sanitize.state
        sanitize.state.on_new_sim()

    @property
    def now(self) -> float:
        return self.clock.now

    def race(self, thunks):
        """Hedged execution: run all thunks from the same virtual instant
        and advance the clock to the FIRST completion (straggler
        mitigation — the loser's link stays busy, as in real hedging).
        Returns (winner_index, winner_result)."""
        t0 = self.clock.now
        results, ends = [], []
        for th in thunks:
            self.clock.now = t0
            results.append(th())
            ends.append(self.clock.now)
        best = min(range(len(ends)), key=lambda i: ends[i])
        self.clock.now = ends[best]
        self.stats.count("sim.hedged_race")
        return best, results[best]

    def parallel(self, thunks):
        """Run thunks as concurrent activities starting at the same virtual
        instant; the clock ends at the max completion time. Per-link busy
        times still serialise messages that share a link, so e.g. N stripe
        writes to N different OSTs overlap while N writes to ONE OST queue.
        """
        t0 = self.clock.now
        ends, results = [], []
        for th in thunks:
            self.clock.now = t0
            results.append(th())
            ends.append(self.clock.now)
        self.clock.now = max(ends) if ends else t0
        return results
