"""OBD_FAIL-style failpoint registry (crash-point testing, ch. 11).

Real Lustre proves its recovery claims with ``OBD_FAIL_CHECK(id)`` sites
compiled into every interesting code path and a global ``fail_loc``
(set via ``lctl set_param fail_loc=...``) that arms exactly one of them;
the recovery test matrix then crashes a target at *every* site and
asserts the cluster heals.  This module reproduces that machinery for
the simulator:

  * **Sites** are registered by name at import time (``register_site``);
    ``SITES`` is the authoritative map the crash-point sweep in
    ``tests/test_recovery.py`` parametrizes over.
  * ``fail_loc`` / ``fail_val`` are armed via
    ``cluster.lctl("set_param", "fail_loc", site[, nth])``:
    the site triggers on its ``nth`` hit (default: first), once
    (OBD_FAIL_ONCE semantics), then disarms itself.
  * A triggered site raises :class:`FailLocHit`.  ``ptlrpc.Node``
    catches it at the request boundary and powers the serving target
    off at that exact point: uncommitted state is lost through the undo
    log, the in-flight request is dropped (no reply), and the client
    recovers through the normal timeout -> reconnect -> replay path.

Two site flavours:

  * ``maybe_fail(site)`` — *immediate*: raises right at the call site.
    Placed only where the target's state is transaction-consistent
    (request boundaries, reint entry, commit edges), because the crash
    rollback can only undo *registered* transactions.
  * ``note(site)`` — *deferred*: arms a pending crash that
    ``raise_if_pending(owner)`` fires at the owning target's next
    request boundary.  Used for sites *inside* a mutation (llog writes,
    changelog emits, backend transactions): a journaled filesystem
    cannot expose half a transaction after a crash, so the induced
    crash lands at the transaction boundary — the llog write is what
    arms it, the whole uncommitted transaction is what dies.

Like real Lustre's ``obd_fail_loc`` the armed state is node-global
(module-global here); every fresh :class:`repro.core.sim.Simulator`
resets it so clusters are isolated from one another.

Contract: the crash/restart handling lives at the ptlrpc request
boundary, so arm sites only for RPC-driven flows. A site hit OUTSIDE
any request context (e.g. arming ``mds.txn`` and then mutating a target
directly through ``lctl`` verbs) raises :class:`FailLocHit` straight
into the caller — deliberate, so a mis-armed test fails loudly instead
of silently skipping the crash — but nothing rolls the target back.
"""
from __future__ import annotations

from collections import defaultdict

# --------------------------------------------------------------- registry

SITES: dict[str, str] = {}       # site name -> description


def register_site(name: str, desc: str) -> str:
    SITES[name] = desc
    return name


class FailLocHit(Exception):
    """An armed failpoint fired: the caller's target must crash here."""

    def __init__(self, site: str):
        super().__init__(f"fail_loc hit: {site}")
        self.site = site


class FailLocDrop(Exception):
    """An armed failpoint fired with the 'drop' action: the in-flight
    request is lost on the wire (OBD_FAIL_*_NET semantics) — the target
    stays up, no reply is sent, the client recovers by timeout+resend."""

    def __init__(self, site: str):
        super().__init__(f"fail_loc drop: {site}")
        self.site = site


ACTIONS = ("crash", "drop", "delay")


class FailState:
    """The armed failpoint (one at a time, like obd_fail_loc).

    Besides the classic crash, a site can be armed with an *action*:

      * ``crash`` — power the serving target off at the site (default);
      * ``drop``  — lose the in-flight message instead (OBD_FAIL_*_NET):
        server sites drop the request/reply, the DLM blocking-AST site
        loses the AST (holder presumed dead -> evicted), the client-side
        ``osc.flush`` site loses the flush's first BRW RPC on the wire;
      * ``delay`` — stall the site for ``fail_delay`` virtual seconds
        (slow disk / slow wire), then continue normally.
    """

    def __init__(self):
        self.loc = ""                    # armed site name ("" = disarmed)
        self.val = 1                     # trigger on the val-th hit
        self.action = "crash"            # what a triggered site does
        self.delay_s = 0.25              # 'delay' action stall (virtual s)
        self.sim = None                  # owning Simulator (delay needs it)
        self.hits = defaultdict(int)     # site -> times checked while armed
        self.fired = 0                   # total failures induced
        # deferred-crash bookkeeping: the innermost target currently
        # processing a request (see ptlrpc.Node._request_in) owns any
        # pending crash armed by a note() inside its handler.
        self.service_stack: list = []
        self.pending: dict = {}          # owner id -> (site, action)

    # ------------------------------------------------------------- control
    def arm(self, loc: str, val: int | None = None,
            action: str | None = None):
        """Arm `loc`; `val` = fire on the val-th hit. Like real Lustre,
        fail_val and fail_loc are order-independent: arming without an
        explicit val/action keeps whatever was set before."""
        if loc and loc not in SITES:
            raise ValueError(f"unknown fail site {loc!r} "
                             f"(have: {sorted(SITES)})")
        self.loc = loc
        if val is not None:
            self.val = max(1, int(val))
        if action is not None:
            if action not in ACTIONS:
                raise ValueError(f"unknown fail action {action!r} "
                                 f"(have: {ACTIONS})")
            self.action = action

    def disarm(self):
        self.loc = ""

    def reset(self):
        self.disarm()
        self.val = 1
        self.action = "crash"
        self.delay_s = 0.25
        self.hits.clear()
        self.fired = 0
        self.service_stack.clear()
        self.pending.clear()

    # -------------------------------------------------------------- checks
    def _triggered(self, site: str) -> bool:
        if site != self.loc:
            return False
        self.hits[site] += 1
        if self.hits[site] < self.val:
            return False
        self.disarm()                    # OBD_FAIL_ONCE: one shot
        self.fired += 1
        return True

    def _delay(self):
        if self.sim is not None:
            self.sim.clock.advance(self.delay_s)

    def maybe_fail(self, site: str):
        """Immediate site: act right here (crash raises at a
        transaction-consistent point; drop loses the in-flight request;
        delay stalls and continues)."""
        if not self._triggered(site):
            return
        if self.action == "delay":
            self._delay()
        elif self.action == "drop":
            raise FailLocDrop(site)
        else:
            raise FailLocHit(site)

    def note(self, site: str):
        """Deferred site: the crash/drop lands at the owning target's
        request boundary (transaction atomicity — see module docstring);
        a delay stalls immediately (it breaks no atomicity)."""
        if not self._triggered(site):
            return
        if self.action == "delay":
            self._delay()
        elif self.service_stack:
            self.pending[id(self.service_stack[-1])] = (site, self.action)
        else:                            # no request context: fail now
            raise FailLocHit(site)

    def check(self, site: str) -> str | None:
        """Self-interpreting site: returns the armed action if `site`
        triggers (handling 'delay' in place), else None. Call sites with
        their own drop/crash semantics (dlm.blocking_ast, osc.flush)
        dispatch on the result."""
        if not self._triggered(site):
            return None
        if self.action == "delay":
            self._delay()
        return self.action

    def defer(self, site: str):
        """Arm a pending crash for the innermost serving target (used by
        check() call sites that want crash-at-request-boundary)."""
        if self.service_stack:
            self.pending[id(self.service_stack[-1])] = (site, "crash")
        else:
            raise FailLocHit(site)

    # ----------------------------------------------- request-boundary hooks
    def enter_service(self, owner):
        self.service_stack.append(owner)

    def exit_service(self, owner):
        if self.service_stack and self.service_stack[-1] is owner:
            self.service_stack.pop()

    def raise_if_pending(self, owner):
        hit = self.pending.pop(id(owner), None)
        if hit is not None:
            site, action = hit
            if action == "drop":
                raise FailLocDrop(site)
            raise FailLocHit(site)

    def info(self) -> dict:
        return {"fail_loc": self.loc, "fail_val": self.val,
                "fail_action": self.action, "fail_delay": self.delay_s,
                "fired": self.fired, "hits": dict(self.hits)}


# One node-global armed state, exactly like obd_fail_loc; Simulator's
# constructor calls reset() so each cluster starts disarmed.
state = FailState()

maybe_fail = state.maybe_fail
note = state.note


# ---------------------------------------------------- the registered sites
# ptlrpc request boundaries (crash before executing / before replying):
register_site("ptlrpc.mds.request_in",
              "MDS request received, nothing executed yet")
register_site("ptlrpc.ost.request_in",
              "OST request received, nothing executed yet")
register_site("ptlrpc.mds.before_reply",
              "MDS handler done (txns registered), reply not sent")
register_site("ptlrpc.ost.before_reply",
              "OST handler done (txns registered), reply not sent")
# MDS reint / commit path:
register_site("mds.reint.before", "reint dispatched, before any mutation")
register_site("mds.commit.before", "MDS journal flush about to start")
register_site("mds.commit.after",
              "MDS journal flush durable, reply lost (deferred)")
register_site("mds.txn", "inside an MDS metadata transaction (deferred)")
# OST transactions / commit:
register_site("ost.commit.before", "OST journal flush about to start")
register_site("ost.commit.after",
              "OST journal flush durable, reply lost (deferred)")
register_site("ost.txn", "inside an OST backend transaction (deferred)")
# llog / changelog writes:
register_site("llog.catalog.add", "llog record appended (deferred)")
register_site("mds.changelog.emit", "changelog record emitted (deferred)")
register_site("mds.changelog.clear",
              "changelog_clear dispatched, before bookmark/purge")
register_site("mds.changelog.clear.applied",
              "bookmark+purge transaction applied, not yet committed")
# DLM blocking-AST path / OSC write-back flush (ISSUE-4):
register_site("dlm.blocking_ast",
              "server about to send a blocking AST to a lock holder "
              "(drop: AST lost -> holder evicted; crash: deferred to the "
              "triggering request's boundary)")
register_site("osc.flush",
              "client write-back flush about to ship its BRW vectors "
              "(client-side site: crash degrades to drop — the flush's "
              "first RPC is lost on the wire and the import recovers by "
              "timeout -> reconnect -> resend)")
# Statahead prefetch (ISSUE-5):
register_site("mds.statahead",
              "client statahead about to ship its batched getattr_bulk/"
              "glimpse prefetch (client-side site: crash degrades to "
              "drop — the prefetch is abandoned and every stat falls "
              "back to a correct synchronous fetch)")
# Metadata writeback cache reintegration (ISSUE-6):
register_site("mdc.wbc_flush",
              "client WBC about to ship a reint_batch flush "
              "(client-side site: crash degrades to drop — the batch "
              "RPC is lost on the wire and the import recovers by "
              "timeout -> reconnect -> resend, so the flush still "
              "completes; the unsent tail stays cached)")
register_site("mds.reint_batch",
              "inside op_reint_batch, before applying the next record "
              "(the batch is ONE undo-scoped transaction: a crash here "
              "unwinds every already-applied record and client replay "
              "re-applies the batch exactly once)")
# Monitoring plane + grant/llog maintenance (ISSUE-7):
register_site("mon.collect",
              "target about to assemble its mon_collect leaf (crash/"
              "drop: the collector's single-attempt RPC times out and "
              "the snapshot degrades to a PARTIAL one with this target "
              "marked stale — never a hang, never a wrong total)")
register_site("llog.cancel",
              "llog catalog cancelling cookies (deferred: the crash "
              "lands at the owning target's request boundary, the whole "
              "uncommitted cancel transaction dies and the records are "
              "re-shipped/re-cancelled after recovery — cancel is "
              "idempotent)")
register_site("osc.grant_shrink",
              "client about to return idle grant to the OST (client-"
              "side site: crash degrades to drop — the shrink RPC is "
              "lost on the wire and the import recovers by timeout -> "
              "reconnect -> resend; the absolute 'keep' target makes "
              "the retry idempotent)")
# raid5 OST rebuild (ISSUE-8):
register_site("lov.rebuild",
              "rebuilder about to reconstruct one file's dead-slot "
              "object onto the spare (client-side site: crash degrades "
              "to abort — the rebuild stops mid-namespace-walk; no "
              "layout was touched yet, every file it skipped still "
              "serves degraded reads from parity and a rerun finishes "
              "the job)")
# recovery-robustness plane (ISSUE-10):
register_site("ptl.early_reply",
              "service about to grant an adaptive-timeout early reply "
              "extending the client's deadline (drop: the reply — and "
              "the extension riding on it — is lost on the wire, the "
              "client declares a spurious timeout and heals by resend "
              "-> reply cache; crash: the target dies after executing "
              "but before replying, the client reconnects and replays)")
register_site("mds.recovery_window",
              "MDS about to close its recovery window (VBR: stragglers "
              "are NOT blanket-evicted — a late replay is admitted iff "
              "its pre-op versions still match; crash here restarts "
              "recovery from the journal, drop loses the close and the "
              "window closes again at the next trigger)")
register_site("ping.notify",
              "pinger noticed a target reboot and is about to trigger "
              "imperative recovery (self-interpreting: drop/crash lose "
              "the notification — the client falls back to the timeout-"
              "driven reconnect path, strictly slower but safe)")
register_site("net.flap",
              "chaos harness about to power-cycle a server node (self-"
              "interpreting: drop/crash suppress the flap — the "
              "schedule skips the event and the workload proceeds on a "
              "healthy fabric)")
register_site("lov.layout_swap",
              "rebuilder about to commit a rebuilt file's new StripeMd "
              "to the MDS EA (client-side site: crash degrades to "
              "abort BEFORE the setattr — the old layout stays intact "
              "and degraded-readable, the spare object is merely "
              "orphaned; readers never observe a torn layout)")
