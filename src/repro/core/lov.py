"""LOV: Logical Object Volume — RAID0 striping over OSTs (paper ch. 10, 20)
and RAID1 mirroring (ch. 15 Redundant Object Storage Targets).

A file's stripe metadata (`lsm`: stripe_size / stripe_count / stripe_offset
+ per-stripe object ids) is stored by the MDS in the file inode's extended
attribute — the LOV only interprets it (§10.2). I/O maps logical extents to
per-object extents and issues the per-OST OSC calls in parallel (the
concurrency the paper's striping exists to exploit).

QOS allocation policy (ch. 20): round-robin or free-space weighted choice
of the starting OST / stripe set.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Optional

from repro.core import osc as osc_mod
from repro.core import ptlrpc as R


@dataclasses.dataclass
class StripeMd:
    """lsm — lives in the MDS inode EA ("lov" key)."""
    stripe_size: int
    stripe_count: int
    stripe_offset: int
    objects: list            # [{"ost": uuid, "group": g, "oid": o}, ...]

    def to_ea(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_ea(cls, ea: dict) -> "StripeMd":
        return cls(**ea)


def _chunks(lsm: StripeMd, offset: int, length: int):
    """Split a logical extent into (stripe_idx, obj_offset, length, lpos)
    runs.  Zero-length I/O yields no runs, every emitted run has length
    > 0 (extents ending exactly on a stripe boundary never produce an
    empty trailing run), and object-contiguous runs of the same stripe
    (stripe_count == 1) are merged so they coalesce into one niobuf."""
    ssz, cnt = lsm.stripe_size, lsm.stripe_count
    if length <= 0 or ssz <= 0 or cnt <= 0:
        return []
    out = []
    pos = offset
    end = offset + length
    while pos < end:
        snum = pos // ssz
        sidx = snum % cnt
        in_off = pos % ssz
        run = min(ssz - in_off, end - pos)
        obj_off = (snum // cnt) * ssz + in_off
        prev = out[-1] if out else None
        if (prev is not None and prev[0] == sidx
                and prev[1] + prev[2] == obj_off
                and prev[3] + prev[2] == pos):
            # same object, contiguous on both axes: extend the run
            out[-1] = (sidx, prev[1], prev[2] + run, prev[3])
        else:
            out.append((sidx, obj_off, run, pos))
        pos += run
    return out


def logical_size(lsm: StripeMd, obj_sizes: list[int]) -> int:
    """File size from per-object sizes (§10: size management)."""
    ssz, cnt = lsm.stripe_size, lsm.stripe_count
    best = 0
    for i, s in enumerate(obj_sizes):
        if s <= 0 or i >= cnt:
            continue
        last = s - 1
        logical_last = ((last // ssz) * cnt + i) * ssz + (last % ssz)
        best = max(best, logical_last + 1)
    return best


class Lov:
    """Stripes over an ordered list of OSCs (one per OST)."""

    DEFAULT_STRIPE_SIZE = 1 << 20

    def __init__(self, oscs: list[osc_mod.Osc], group: int = 0,
                 policy: str = "round_robin"):
        self.oscs = oscs
        self.by_uuid = {o.uuid: o for o in oscs}
        self.group = group
        self.policy = policy
        self._rr = itertools.count()
        self.sim = oscs[0].sim if oscs else None

    # ---------------------------------------------------------- allocate
    def _pick_offset(self, stripe_count: int) -> int:
        if self.policy == "free_space":
            frees = [(o.statfs()["free"], i) for i, o in enumerate(self.oscs)]
            return max(frees)[1]
        return next(self._rr) % len(self.oscs)

    def create(self, *, stripe_count: int = 0, stripe_size: int = 0,
               stripe_offset: int = -1, group: int | None = None,
               oids: list | None = None) -> StripeMd:
        """Allocate stripe objects (one `create` per OST, in parallel).
        `oids` pins object ids (checkpoint restore / replay)."""
        cnt = stripe_count or 1
        cnt = min(cnt, len(self.oscs))
        ssz = stripe_size or self.DEFAULT_STRIPE_SIZE
        off = stripe_offset if stripe_offset >= 0 else self._pick_offset(cnt)
        grp = self.group if group is None else group
        idxs = [(off + i) % len(self.oscs) for i in range(cnt)]

        def mk(i, k):
            osc = self.oscs[k]
            oid = oids[i] if oids else None
            out = osc.create(grp, oid)
            return {"ost": osc.uuid, "group": grp, "oid": out["oid"]}

        objs = self.sim.parallel(
            [(lambda i=i, k=k: mk(i, k)) for i, k in enumerate(idxs)])
        return StripeMd(ssz, cnt, off, objs)

    # --------------------------------------------------------------- I/O
    def _osc(self, lsm: StripeMd, sidx: int) -> osc_mod.Osc:
        return self.by_uuid[lsm.objects[sidx]["ost"]]

    def write(self, lsm: StripeMd, offset: int, data: bytes,
              gid: int = 0) -> int:
        """Striped write: logical runs are grouped per stripe object and
        dispatched concurrently as ONE vectored call per object (the OSC
        coalesces them into BRW niobuf vectors)."""
        runs = _chunks(lsm, offset, len(data))
        if not runs:
            return 0
        by_stripe: dict[int, list] = {}
        for sidx, obj_off, ln, lpos in runs:
            by_stripe.setdefault(sidx, []).append(
                (obj_off, data[lpos - offset:lpos - offset + ln]))

        def wr(sidx, iov):
            o = lsm.objects[sidx]
            self._osc(lsm, sidx).writev(o["group"], o["oid"], iov, gid=gid)

        self.sim.parallel([(lambda s=s, v=v: wr(s, v))
                           for s, v in by_stripe.items()])
        return len(data)

    def read(self, lsm: StripeMd, offset: int, length: int) -> bytes:
        """Striped read: one vectored OST_READ per stripe object, issued
        concurrently; partial results are merged by logical position."""
        runs = _chunks(lsm, offset, length)
        if not runs:
            return b""
        by_stripe: dict[int, list] = {}
        for sidx, obj_off, ln, lpos in runs:
            by_stripe.setdefault(sidx, []).append((obj_off, ln, lpos))

        def rd(sidx, iov):
            o = lsm.objects[sidx]
            chunks = self._osc(lsm, sidx).readv(
                o["group"], o["oid"], [(off, ln) for off, ln, _ in iov])
            return [(lpos, chunk)
                    for (_, _, lpos), chunk in zip(iov, chunks)]

        parts = self.sim.parallel([(lambda s=s, v=v: rd(s, v))
                                   for s, v in by_stripe.items()])
        buf = bytearray(length)
        for group in parts:
            for lpos, chunk in group:
                buf[lpos - offset:lpos - offset + len(chunk)] = chunk
        return bytes(buf)

    def getattr(self, lsm: StripeMd) -> dict:
        outs = self.sim.parallel([
            (lambda o=o: self.by_uuid[o["ost"]].getattr(o["group"], o["oid"]))
            for o in lsm.objects])
        return {"size": logical_size(lsm, [a["size"] for a in outs]),
                "mtime": max((a["mtime"] for a in outs), default=0.0),
                "blocks": sum(a["blocks"] for a in outs)}

    def glimpse(self, lsm: StripeMd) -> dict:
        """size/mtime of ONE file via glimpse (§7.7): per-OST vectored
        glimpse_bulk RPCs; writers holding PW locks are asked for their
        LVBs, never revoked — correct even against unflushed write-back
        caches (plain getattr reads disk and misses them)."""
        return self.glimpse_files({0: lsm})[0]

    def glimpse_files(self, lsms: dict) -> dict:
        """Batched glimpse across MANY files: every file's stripe objects
        are grouped per OST and fetched with ONE vectored glimpse_bulk
        RPC per OST (a striped-directory scan pays #OSTs RPCs, not
        #files x #stripes). lsms: key -> StripeMd; returns key ->
        {"size", "mtime"} (logical size recombined per file)."""
        by_ost: dict[str, list] = {}
        for key, lsm in lsms.items():
            for i, o in enumerate(lsm.objects):
                by_ost.setdefault(o["ost"], []).append(
                    (key, i, o["group"], o["oid"]))

        def one(uuid, items):
            outs = self.by_uuid[uuid].glimpse_bulk(
                [(g, o) for _, _, g, o in items])
            return [(k, i, a) for (k, i, _, _), a in zip(items, outs)]

        parts = self.sim.parallel([(lambda u=u, it=it: one(u, it))
                                   for u, it in by_ost.items()])
        per_obj: dict[tuple, dict] = {}
        for plist in parts:
            for key, i, a in plist:
                per_obj[(key, i)] = a or {"size": 0, "mtime": 0.0}
        out = {}
        for key, lsm in lsms.items():
            attrs = [per_obj.get((key, i), {"size": 0, "mtime": 0.0})
                     for i in range(len(lsm.objects))]
            out[key] = {"size": logical_size(lsm,
                                             [a["size"] for a in attrs]),
                        "mtime": max((a["mtime"] for a in attrs),
                                     default=0.0)}
        if self.sim:
            self.sim.stats.count("lov.glimpse")
            self.sim.stats.count("lov.glimpse_files", len(lsms))
        return out

    def getattr_locked(self, lsm: StripeMd) -> dict:
        """getattr under PR locks: revokes writers' PW locks first, so
        their write-back caches flush and the sizes are current (the
        client-side ordering rule of §6.2.3; real Lustre uses glimpse
        ASTs — a PR enqueue is our simpler equivalent). Served from the
        cached locks' value blocks (§7.7) when possible: a warm
        sequential reader pays ZERO RPCs for its size checks."""
        outs = self.sim.parallel([
            (lambda o=o: self.by_uuid[o["ost"]].getattr_locked(
                o["group"], o["oid"]))
            for o in lsm.objects])
        return {"size": logical_size(lsm, [a["size"] for a in outs]),
                "mtime": max((a["mtime"] for a in outs), default=0.0)}

    def readahead(self, lsm: StripeMd, offset: int, length: int) -> int:
        """Populate the per-OSC clean caches for [offset, offset+length):
        the window is split over the stripe objects and fetched as ONE
        vectored OST_READ per stripe object (runs already cached are
        skipped by the OSC). Returns the number of bytes requested."""
        runs = _chunks(lsm, offset, length)
        if not runs:
            return 0
        by_stripe: dict[int, list] = {}
        for sidx, obj_off, ln, _ in runs:
            by_stripe.setdefault(sidx, []).append((obj_off, ln))

        def ra(sidx, iov):
            o = lsm.objects[sidx]
            self._osc(lsm, sidx).readv(o["group"], o["oid"], iov)

        self.sim.parallel([(lambda s=s, v=v: ra(s, v))
                           for s, v in by_stripe.items()])
        if self.sim:
            self.sim.stats.count("lov.readahead")
            self.sim.stats.count("lov.readahead_bytes", length)
        return length

    def destroy(self, lsm: StripeMd, cookies: list | None = None):
        def rm(i, o):
            ck = cookies[i] if cookies else None
            try:
                self.by_uuid[o["ost"]].destroy(o["group"], o["oid"],
                                               cookie=ck)
            except R.RpcError as e:
                if e.status != -2:
                    raise
        self.sim.parallel([(lambda i=i, o=o: rm(i, o))
                           for i, o in enumerate(lsm.objects)])

    def punch(self, lsm: StripeMd, size: int):
        # per-object truncation point
        for i, o in enumerate(lsm.objects):
            osz = self._obj_size_for(lsm, i, size)
            self.by_uuid[o["ost"]].punch(o["group"], o["oid"], osz)

    @staticmethod
    def _obj_size_for(lsm: StripeMd, i: int, logical: int) -> int:
        """Object-local size when the file is truncated to `logical`."""
        if logical == 0:
            return 0
        last = logical - 1
        snum, rem = divmod(last, lsm.stripe_size)
        full_rounds, sidx = divmod(snum, lsm.stripe_count)
        if i < sidx:
            return (full_rounds + 1) * lsm.stripe_size
        if i == sidx:
            return full_rounds * lsm.stripe_size + rem + 1
        return full_rounds * lsm.stripe_size

    def flush(self):
        self.sim.parallel([(lambda o=o: o.flush()) for o in self.oscs])

    def sync(self):
        self.sim.parallel([(lambda o=o: o.sync()) for o in self.oscs])


# ------------------------------------------------------------------ RAID1

class Raid1:
    """Redundant OSTs (ch. 15): mirror writes to two OSCs; reads prefer the
    primary and fail over; a dirty-extent log drives resync after an OST
    comes back."""

    def __init__(self, primary: osc_mod.Osc, secondary: osc_mod.Osc,
                 group: int = 0):
        self.a = primary
        self.b = secondary
        self.sim = primary.sim
        self.group = group
        self.dirty_log: list[tuple[int, int, int]] = []  # (oid, off, len)

    def create(self, oid: int | None = None) -> int:
        out = self.a.create(self.group, oid)
        self.b.create(self.group, out["oid"])
        return out["oid"]

    def write(self, oid: int, offset: int, data: bytes):
        def one(osc):
            try:
                osc.write(self.group, oid, offset, data)
                return True
            except (R.RpcError, R.TimeoutError_):
                return False
        oks = self.sim.parallel([lambda: one(self.a), lambda: one(self.b)])
        if not any(oks):
            raise R.RpcError(-5, "both mirrors failed")
        if not all(oks):
            self.dirty_log.append((oid, offset, len(data)))
            self.sim.stats.count("raid1.degraded_write")

    def read(self, oid: int, offset: int, length: int) -> bytes:
        try:
            return self.a.read(self.group, oid, offset, length)
        except (R.RpcError, R.TimeoutError_):
            self.sim.stats.count("raid1.failover_read")
            return self.b.read(self.group, oid, offset, length)

    def read_hedged(self, oid: int, offset: int, length: int) -> bytes:
        """Straggler mitigation: issue the read to BOTH mirrors, take the
        first completion (a slow/overloaded OST only costs its own link)."""
        def one(osc):
            try:
                return osc.read(self.group, oid, offset, length)
            except (R.RpcError, R.TimeoutError_):
                return None
        _, data = self.sim.race([lambda: one(self.a), lambda: one(self.b)])
        if data is None:                      # winner failed: use the other
            return self.read(oid, offset, length)
        return data

    def resync(self):
        """Replay the dirty log onto whichever mirror missed writes."""
        log, self.dirty_log = self.dirty_log, []
        for oid, off, ln in log:
            data = self.read(oid, off, ln)
            for osc in (self.a, self.b):
                try:
                    osc.write(self.group, oid, off, data)
                except (R.RpcError, R.TimeoutError_):
                    self.dirty_log.append((oid, off, ln))
        return len(log) - len(self.dirty_log)
