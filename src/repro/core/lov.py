"""LOV: Logical Object Volume — RAID0 striping over OSTs (paper ch. 10, 20),
RAID1 mirroring and RAID5/SNS parity striping (ch. 15 Redundant Object
Storage Targets).

A file's stripe metadata (`lsm`: stripe_size / stripe_count / stripe_offset
+ per-stripe object ids) is stored by the MDS in the file inode's extended
attribute — the LOV only interprets it (§10.2). I/O maps logical extents to
per-object extents and issues the per-OST OSC calls in parallel (the
concurrency the paper's striping exists to exploit).

QOS allocation policy (ch. 20): round-robin or free-space weighted choice
of the starting OST / stripe set.

raid5 pattern: `stripe_count` DATA stripes plus ONE rotating parity stripe
per stripe-round, over `stripe_count + 1` objects.  Round r's parity lives
in slot (n-1 - r%n) % n (n = cnt+1), so parity load spreads over all OSTs
instead of hammering one (the classic RAID-4 bottleneck).  Parity is
computed with the Pallas XOR kernel (`kernels.ops.parity_bytes`); a read
whose OST is down is served DEGRADED by fetching the surviving stripes +
parity and reconstructing, and `rebuild_object` regenerates a dead OST's
object onto a spare.  XOR of all n units of a round is zero, so any one
missing unit — data or parity — is the XOR of the other n-1.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Optional

from repro.core import osc as osc_mod
from repro.core import ptlrpc as R


@dataclasses.dataclass
class StripeMd:
    """lsm — lives in the MDS inode EA ("lov" key).

    pattern "raid0": `objects` has stripe_count entries, all data.
    pattern "raid5": `objects` has stripe_count + 1 entries (slots); each
    stripe-round one slot holds parity (rotating), the rest hold the
    round's stripe_count data units.  Object-local offset of round r is
    always r * stripe_size, data or parity alike."""
    stripe_size: int
    stripe_count: int
    stripe_offset: int
    objects: list            # [{"ost": uuid, "group": g, "oid": o}, ...]
    pattern: str = "raid0"   # default keeps pre-raid5 EAs decodable

    def to_ea(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_ea(cls, ea: dict) -> "StripeMd":
        return cls(**ea)


def _chunks(lsm: StripeMd, offset: int, length: int):
    """Split a logical extent into (stripe_idx, obj_offset, length, lpos)
    runs.  Zero-length I/O yields no runs, every emitted run has length
    > 0 (extents ending exactly on a stripe boundary never produce an
    empty trailing run), and object-contiguous runs of the same stripe
    (stripe_count == 1) are merged so they coalesce into one niobuf."""
    ssz, cnt = lsm.stripe_size, lsm.stripe_count
    if length <= 0 or ssz <= 0 or cnt <= 0:
        return []
    out = []
    pos = offset
    end = offset + length
    while pos < end:
        snum = pos // ssz
        sidx = snum % cnt
        in_off = pos % ssz
        run = min(ssz - in_off, end - pos)
        obj_off = (snum // cnt) * ssz + in_off
        prev = out[-1] if out else None
        if (prev is not None and prev[0] == sidx
                and prev[1] + prev[2] == obj_off
                and prev[3] + prev[2] == pos):
            # same object, contiguous on both axes: extend the run
            out[-1] = (sidx, prev[1], prev[2] + run, prev[3])
        else:
            out.append((sidx, obj_off, run, pos))
        pos += run
    return out


def logical_size(lsm: StripeMd, obj_sizes: list[int]) -> int:
    """File size from per-object sizes (§10: size management)."""
    ssz, cnt = lsm.stripe_size, lsm.stripe_count
    best = 0
    for i, s in enumerate(obj_sizes):
        if s <= 0 or i >= cnt:
            continue
        last = s - 1
        logical_last = ((last // ssz) * cnt + i) * ssz + (last % ssz)
        best = max(best, logical_last + 1)
    return best


# ----------------------------------------------------- raid5 geometry

def _r5_parity_slot(lsm: StripeMd, r: int) -> int:
    """Slot holding round r's parity unit (left-symmetric rotation)."""
    n = lsm.stripe_count + 1
    return (n - 1 - (r % n)) % n


def _r5_slot(lsm: StripeMd, r: int, i: int) -> int:
    """Slot holding data unit i (0..cnt-1) of round r."""
    p = _r5_parity_slot(lsm, r)
    return i if i < p else i + 1


def _r5_chunks(lsm: StripeMd, offset: int, length: int):
    """Split a logical extent into (round, data_idx, in_off, run, lpos)
    data-unit runs (no merging: raid5 units are parity-coupled)."""
    ssz, cnt = lsm.stripe_size, lsm.stripe_count
    if length <= 0 or ssz <= 0 or cnt <= 0:
        return []
    out = []
    pos, end = offset, offset + length
    while pos < end:
        snum = pos // ssz
        r, i = divmod(snum, cnt)
        in_off = pos % ssz
        run = min(ssz - in_off, end - pos)
        out.append((r, i, in_off, run, pos))
        pos += run
    return out


def _r5_logical_size(lsm: StripeMd, slot_sizes: list) -> int:
    """File size from per-SLOT object sizes (None = size unknown, e.g.
    the OST is dead — that slot simply contributes no witness).

    Each object's last byte pins a logical position: if the slot holds
    DATA in its final round the mapping is direct; if it holds PARITY,
    the parity unit is exactly as long as the round's longest (first)
    data unit, so it witnesses data unit 0's extent instead."""
    ssz, cnt = lsm.stripe_size, lsm.stripe_count
    best = 0
    for s, size in enumerate(slot_sizes):
        if not size or size <= 0:
            continue
        rr, rem = divmod(size - 1, ssz)
        p = _r5_parity_slot(lsm, rr)
        if s == p:
            best = max(best, (rr * cnt) * ssz + rem + 1)
        else:
            i = s if s < p else s - 1
            best = max(best, ((rr * cnt) + i) * ssz + rem + 1)
    return best


class Lov:
    """Stripes over an ordered list of OSCs (one per OST)."""

    DEFAULT_STRIPE_SIZE = 1 << 20

    def __init__(self, oscs: list[osc_mod.Osc], group: int = 0,
                 policy: str = "round_robin",
                 spares: list[osc_mod.Osc] | None = None):
        self.oscs = oscs                  # allocation set
        self.spares = list(spares or [])  # rebuild targets, never allocated
        self.by_uuid = {o.uuid: o for o in oscs}
        for o in self.spares:
            self.by_uuid.setdefault(o.uuid, o)
        self.group = group
        self.policy = policy
        self._rr = itertools.count()
        self.sim = oscs[0].sim if oscs else None

    # ------------------------------------------------------ admin state
    def is_active(self, uuid: str) -> bool:
        return self.by_uuid[uuid].active

    def set_active(self, uuid: str, on: bool):
        """Administratively (de)activate one OST's import — degraded
        raid5 paths fail fast (-19) instead of timing out per touch."""
        osc = self.by_uuid[uuid]
        if osc.active != on:
            osc.set_active(on)
            self.sim.stats.count(
                "lov.ost_active" if on else "lov.ost_inactive")

    def _mark_dead(self, osc: osc_mod.Osc):
        """Auto-detection: first TimeoutError_ marks the OST inactive so
        every later touch fails fast instead of re-walking reconnects."""
        if osc.active:
            osc.set_active(False)
            self.sim.stats.count("lov.ost_inactive")

    # ---------------------------------------------------------- allocate
    def _pick_offset(self, stripe_count: int) -> int:
        if self.policy == "free_space":
            frees = [(o.statfs()["free"], i) for i, o in enumerate(self.oscs)]
            return max(frees)[1]
        return next(self._rr) % len(self.oscs)

    def create(self, *, stripe_count: int = 0, stripe_size: int = 0,
               stripe_offset: int = -1, group: int | None = None,
               oids: list | None = None,
               pattern: str = "raid0") -> StripeMd:
        """Allocate stripe objects (one `create` per OST, in parallel).
        `oids` pins object ids (checkpoint restore / replay).  raid5
        allocates stripe_count + 1 objects (the extra rotating-parity
        slot), so stripe_count is capped at #OSTs - 1."""
        cnt = stripe_count or 1
        if pattern == "raid5":
            cnt = min(cnt, len(self.oscs) - 1)
            if cnt < 1:
                raise ValueError("raid5 needs >= 2 OSTs")
            nobj = cnt + 1
        else:
            cnt = min(cnt, len(self.oscs))
            nobj = cnt
        ssz = stripe_size or self.DEFAULT_STRIPE_SIZE
        off = stripe_offset if stripe_offset >= 0 else self._pick_offset(cnt)
        grp = self.group if group is None else group
        idxs = [(off + i) % len(self.oscs) for i in range(nobj)]

        def mk(i, k):
            osc = self.oscs[k]
            oid = oids[i] if oids else None
            out = osc.create(grp, oid)
            return {"ost": osc.uuid, "group": grp, "oid": out["oid"]}

        objs = self.sim.parallel(
            [(lambda i=i, k=k: mk(i, k)) for i, k in enumerate(idxs)])
        return StripeMd(ssz, cnt, off, objs, pattern)

    # --------------------------------------------------------------- I/O
    def _osc(self, lsm: StripeMd, sidx: int) -> osc_mod.Osc:
        return self.by_uuid[lsm.objects[sidx]["ost"]]

    def write(self, lsm: StripeMd, offset: int, data: bytes,
              gid: int = 0) -> int:
        """Striped write: logical runs are grouped per stripe object and
        dispatched concurrently as ONE vectored call per object (the OSC
        coalesces them into BRW niobuf vectors)."""
        if lsm.pattern == "raid5":
            return self._raid5_write(lsm, offset, data, gid=gid)
        runs = _chunks(lsm, offset, len(data))
        if not runs:
            return 0
        by_stripe: dict[int, list] = {}
        for sidx, obj_off, ln, lpos in runs:
            by_stripe.setdefault(sidx, []).append(
                (obj_off, data[lpos - offset:lpos - offset + ln]))

        def wr(sidx, iov):
            o = lsm.objects[sidx]
            self._osc(lsm, sidx).writev(o["group"], o["oid"], iov, gid=gid)

        self.sim.parallel([(lambda s=s, v=v: wr(s, v))
                           for s, v in by_stripe.items()])
        return len(data)

    def read(self, lsm: StripeMd, offset: int, length: int) -> bytes:
        """Striped read: one vectored OST_READ per stripe object, issued
        concurrently; partial results are merged by logical position."""
        if lsm.pattern == "raid5":
            return self._raid5_read(lsm, offset, length)
        runs = _chunks(lsm, offset, length)
        if not runs:
            return b""
        by_stripe: dict[int, list] = {}
        for sidx, obj_off, ln, lpos in runs:
            by_stripe.setdefault(sidx, []).append((obj_off, ln, lpos))

        def rd(sidx, iov):
            o = lsm.objects[sidx]
            chunks = self._osc(lsm, sidx).readv(
                o["group"], o["oid"], [(off, ln) for off, ln, _ in iov])
            return [(lpos, chunk)
                    for (_, _, lpos), chunk in zip(iov, chunks)]

        parts = self.sim.parallel([(lambda s=s, v=v: rd(s, v))
                                   for s, v in by_stripe.items()])
        buf = bytearray(length)
        for group in parts:
            for lpos, chunk in group:
                buf[lpos - offset:lpos - offset + len(chunk)] = chunk
        return bytes(buf)

    # ------------------------------------------------------------- raid5
    def _r5_read_slot_unit(self, lsm: StripeMd, r: int, s: int) -> bytes:
        """Read round r's whole unit from slot s (short past EOF)."""
        o = lsm.objects[s]
        return self.by_uuid[o["ost"]].readv(
            o["group"], o["oid"],
            [(r * lsm.stripe_size, lsm.stripe_size)], lock=False)[0]

    def _r5_rebuild_slot_unit(self, lsm: StripeMd, r: int,
                              dead: int) -> bytes:
        """Reconstruct round r's unit of slot `dead` from the other n-1
        slots via the Pallas kernel.  Data unit: XOR(other data, parity)
        = `reconstruct`; parity unit: XOR(all data) = `xor_parity`.  The
        result is padded to the round's parity length — trailing zeros
        past the true unit end are the caller's to trim."""
        from repro.kernels import ops
        n = lsm.stripe_count + 1
        psl = _r5_parity_slot(lsm, r)

        def rd(s):
            try:
                return (s, self._r5_read_slot_unit(lsm, r, s))
            except (R.RpcError, R.TimeoutError_):
                return (s, None)

        parts = self.sim.parallel([(lambda s=s: rd(s))
                                   for s in range(n) if s != dead])
        by_slot = dict(parts)
        if any(u is None for u in by_slot.values()):
            raise R.RpcError(-5, "raid5: second OST failure during "
                                 "reconstruction")
        if dead == psl:
            datas = [u for s, u in sorted(by_slot.items()) if u]
            out = ops.parity_bytes(datas) if datas else b""
        else:
            parity = by_slot[psl]
            if not parity:
                return b""             # round never written
            datas = [u for s, u in sorted(by_slot.items())
                     if s != psl and u]
            out = ops.reconstruct_bytes(datas, parity, len(parity))
        self.sim.stats.count("lov.reconstruct_unit")
        self.sim.stats.count("lov.reconstruct_bytes", len(out))
        return out

    def _r5_unit_data(self, lsm: StripeMd, r: int, i: int) -> bytes:
        """Current content of data unit i of round r, degraded-capable:
        if its OST is dead the unit is reconstructed from the others."""
        s = _r5_slot(lsm, r, i)
        osc = self.by_uuid[lsm.objects[s]["ost"]]
        try:
            return self._r5_read_slot_unit(lsm, r, s)
        except R.TimeoutError_:
            self._mark_dead(osc)
        except R.RpcError:
            pass
        # rstrip: the reconstruction is padded to parity length; genuine
        # trailing zeros in the unit are indistinguishable from padding
        # (documented caveat — affects only parity length, not bytes)
        return self._r5_rebuild_slot_unit(lsm, r, s).rstrip(b"\0")

    def _raid5_write(self, lsm: StripeMd, offset: int, data: bytes, *,
                     gid: int = 0) -> int:
        """Parity-coupled write: for every touched stripe-round, read-
        modify-write the round's data units, recompute parity with the
        XOR kernel, and ship data fragments + the parity unit as ONE
        vectored BRW per object, flushed write-through (parity must be
        durable WITH the data or the redundancy is a lie).  One dead
        OST degrades the write (its unit is recoverable from parity);
        two dead OSTs fail it with -5."""
        from repro.kernels import ops
        runs = _r5_chunks(lsm, offset, len(data))
        if not runs:
            return 0
        ssz, cnt = lsm.stripe_size, lsm.stripe_count
        by_round: dict[int, dict] = {}
        for r, i, in_off, ln, lpos in runs:
            by_round.setdefault(r, {})[i] = (in_off, ln, lpos)
        by_slot: dict[int, list] = {}     # slot -> [(obj_off, bytes)]
        pbytes = 0
        for r, touched in sorted(by_round.items()):
            units = []
            for i in range(cnt):
                if i in touched:
                    in_off, ln, lpos = touched[i]
                    frag = data[lpos - offset:lpos - offset + ln]
                    if in_off == 0 and ln == ssz:
                        unit = frag
                    else:                 # partial unit: read-modify
                        old = self._r5_unit_data(lsm, r, i)
                        u = bytearray(max(len(old), in_off + ln))
                        u[:len(old)] = old
                        u[in_off:in_off + ln] = frag
                        unit = bytes(u)
                    s = _r5_slot(lsm, r, i)
                    by_slot.setdefault(s, []).append(
                        (r * ssz + in_off, frag))
                else:                     # untouched unit still XORs in
                    unit = self._r5_unit_data(lsm, r, i)
                units.append(unit)
            live = [u for u in units if u]
            parity = ops.parity_bytes(live) if live else b""
            if parity:
                by_slot.setdefault(_r5_parity_slot(lsm, r), []).append(
                    (r * ssz, parity))
                pbytes += len(parity)

        def wr(s, iov):
            o = lsm.objects[s]
            osc = self.by_uuid[o["ost"]]
            try:
                osc.writev(o["group"], o["oid"], iov, gid=gid)
                osc.flush(o["group"], o["oid"])
                return (s, True)
            except R.TimeoutError_:
                self._mark_dead(osc)
                return (s, False)
            except R.RpcError:
                return (s, False)

        outs = self.sim.parallel([(lambda s=s, v=v: wr(s, v))
                                  for s, v in sorted(by_slot.items())])
        failed = [s for s, ok in outs if not ok]
        if len(failed) > 1:
            raise R.RpcError(-5, "raid5: multiple OST failures on write")
        if failed:
            self.sim.stats.count("lov.degraded_write")
        self.sim.stats.count("lov.parity_write")
        self.sim.stats.count("lov.parity_bytes", pbytes)
        return len(data)

    def _raid5_read(self, lsm: StripeMd, offset: int, length: int) -> bytes:
        """Read with single-failure tolerance: one vectored OST_READ per
        live slot; runs on a failed slot are served by reconstructing
        the whole unit from survivors + parity (Pallas `reconstruct`)."""
        runs = _r5_chunks(lsm, offset, length)
        if not runs:
            return b""
        ssz = lsm.stripe_size
        by_slot: dict[int, list] = {}
        for r, i, in_off, ln, lpos in runs:
            by_slot.setdefault(_r5_slot(lsm, r, i), []).append(
                (r, i, in_off, ln, lpos))

        def rd(s, items):
            o = lsm.objects[s]
            osc = self.by_uuid[o["ost"]]
            try:
                return (s, osc.readv(
                    o["group"], o["oid"],
                    [(r * ssz + in_off, ln)
                     for r, _, in_off, ln, _ in items]))
            except R.TimeoutError_:
                self._mark_dead(osc)
                return (s, None)
            except R.RpcError:
                return (s, None)

        parts = self.sim.parallel([(lambda s=s, it=it: rd(s, it))
                                   for s, it in sorted(by_slot.items())])
        buf = bytearray(length)
        degraded = False
        for s, chunks in parts:
            items = by_slot[s]
            if chunks is None:            # dead slot: reconstruct units
                degraded = True
                for r, i, in_off, ln, lpos in items:
                    unit = self._r5_rebuild_slot_unit(lsm, r, s)
                    piece = unit[in_off:in_off + ln]
                    buf[lpos - offset:lpos - offset + len(piece)] = piece
                continue
            for (r, i, in_off, ln, lpos), chunk in zip(items, chunks):
                buf[lpos - offset:lpos - offset + len(chunk)] = chunk
        if degraded:
            self.sim.stats.count("lov.degraded_read")
            self.sim.stats.count("lov.degraded_read_bytes", length)
        return bytes(buf)

    def _r5_slot_sizes(self, lsm: StripeMd, *, locked: bool = False):
        """Per-slot object sizes; None where the OST is unreachable."""
        def ga(s):
            o = lsm.objects[s]
            osc = self.by_uuid[o["ost"]]
            try:
                if locked:
                    return osc.getattr_locked(o["group"], o["oid"])
                return osc.getattr(o["group"], o["oid"])
            except R.TimeoutError_:
                self._mark_dead(osc)
                return None
            except R.RpcError:
                return None

        return self.sim.parallel([(lambda s=s: ga(s))
                                  for s in range(len(lsm.objects))])

    def _r5_degraded_size(self, lsm: StripeMd, slot_sizes: list,
                          dead: int) -> int:
        """Logical size with one dead slot: survivors witness what they
        can (`_r5_logical_size`); the dead slot may hold the logical
        tail, so its unit in the last existing round is reconstructed
        and its trailing-zero-trimmed length extends the estimate
        (genuine trailing zeros in the tail unit are indistinguishable
        from reconstruction padding — documented caveat)."""
        ssz, cnt = lsm.stripe_size, lsm.stripe_count
        best = _r5_logical_size(lsm, slot_sizes)
        sizes = [s for s in slot_sizes if s]
        if not sizes:
            return best
        for rr in range((max(sizes) - 1) // ssz, -1, -1):
            p = _r5_parity_slot(lsm, rr)
            if dead == p:
                continue                  # parity unit: no logical bytes
            i = dead if dead < p else dead - 1
            unit = self._r5_rebuild_slot_unit(lsm, rr, dead).rstrip(b"\0")
            if unit:
                best = max(best, ((rr * cnt) + i) * ssz + len(unit))
            break    # lower rounds can't extend past a survivor witness
        return best

    def _r5_getattr(self, lsm: StripeMd, *, locked: bool) -> dict:
        attrs = self._r5_slot_sizes(lsm, locked=locked)
        deadset = [s for s, a in enumerate(attrs) if a is None]
        if len(deadset) > 1:
            raise R.RpcError(-5, "raid5: multiple OST failures")
        sizes = [None if a is None else a["size"] for a in attrs]
        if deadset:
            size = self._r5_degraded_size(lsm, sizes, deadset[0])
        else:
            size = _r5_logical_size(lsm, sizes)
        live = [a for a in attrs if a is not None]
        out = {"size": size,
               "mtime": max((a["mtime"] for a in live), default=0.0)}
        if not locked:
            out["blocks"] = sum(a.get("blocks", 0) for a in live)
        return out

    @staticmethod
    def _r5_obj_size_for(lsm: StripeMd, s: int, logical: int) -> int:
        """Slot s's object size when the file is `logical` bytes long."""
        if logical <= 0:
            return 0
        ssz, cnt = lsm.stripe_size, lsm.stripe_count
        snum, rem = divmod(logical - 1, ssz)
        r, si = divmod(snum, cnt)         # tail round, tail data index
        p = _r5_parity_slot(lsm, r)
        base = r * ssz
        if s == p:                        # parity = longest data unit
            return base + (rem + 1 if si == 0 else ssz)
        i = s if s < p else s - 1
        if i < si:
            return base + ssz
        if i == si:
            return base + rem + 1
        return base

    def _r5_punch(self, lsm: StripeMd, size: int):
        """Truncate: punch every object to its per-slot size, then
        recompute the (now shorter) tail round's parity.  Best-effort
        on a dead OST — the rebuild regenerates a punched object from
        the post-punch parity anyway."""
        from repro.kernels import ops
        ssz, cnt = lsm.stripe_size, lsm.stripe_count
        for s, o in enumerate(lsm.objects):
            try:
                self.by_uuid[o["ost"]].punch(
                    o["group"], o["oid"], self._r5_obj_size_for(lsm, s, size))
            except R.TimeoutError_:
                self._mark_dead(self.by_uuid[o["ost"]])
                self.sim.stats.count("lov.degraded_punch")
            except R.RpcError:
                self.sim.stats.count("lov.degraded_punch")
        if size <= 0:
            return
        r = (size - 1) // (ssz * cnt)     # tail round
        units = [self._r5_unit_data(lsm, r, i) for i in range(cnt)]
        live = [u for u in units if u]
        if not live:
            return
        parity = ops.parity_bytes(live)
        ps = _r5_parity_slot(lsm, r)
        o = lsm.objects[ps]
        try:
            osc = self.by_uuid[o["ost"]]
            osc.writev(o["group"], o["oid"], [(r * ssz, parity)])
            osc.flush(o["group"], o["oid"])
        except (R.RpcError, R.TimeoutError_):
            self.sim.stats.count("lov.degraded_punch")

    def rebuild_object(self, lsm: StripeMd, dead_uuid: str,
                       spare_osc: osc_mod.Osc) -> Optional[StripeMd]:
        """Regenerate the dead OST's object onto `spare_osc`: reconstruct
        every unit (data AND parity rounds) from the survivors via the
        Pallas kernel, write them with ONE vectored BRW, and return the
        swapped StripeMd (caller commits it to the MDS EA under lock).
        Returns None if the file doesn't stripe over `dead_uuid`."""
        dead = next((s for s, o in enumerate(lsm.objects)
                     if o["ost"] == dead_uuid), None)
        if dead is None:
            return None
        ssz = lsm.stripe_size
        grp = lsm.objects[dead]["group"]
        attrs = self._r5_slot_sizes(lsm)
        if any(a is None for s, a in enumerate(attrs) if s != dead):
            raise R.RpcError(-5, "raid5: second OST failure during rebuild")
        sizes = [None if s == dead else a["size"]
                 for s, a in enumerate(attrs)]
        logical = self._r5_degraded_size(lsm, sizes, dead)
        osize = self._r5_obj_size_for(lsm, dead, logical)
        new = spare_osc.create(grp)
        self.by_uuid.setdefault(spare_osc.uuid, spare_osc)
        iov, nb, r = [], 0, 0
        while r * ssz < osize:
            want = min(ssz, osize - r * ssz)
            unit = self._r5_rebuild_slot_unit(lsm, r, dead)[:want]
            unit = unit + b"\0" * (want - len(unit))
            iov.append((r * ssz, unit))
            nb += len(unit)
            r += 1
        if iov:
            spare_osc.writev(grp, new["oid"], iov, lock=False)
            spare_osc.flush(grp, new["oid"])
        self.sim.stats.count("lov.rebuild_object")
        self.sim.stats.count("lov.rebuild_bytes", nb)
        objs = [dict(o) for o in lsm.objects]
        objs[dead] = {"ost": spare_osc.uuid, "group": grp,
                      "oid": new["oid"]}
        return dataclasses.replace(lsm, objects=objs)

    def getattr(self, lsm: StripeMd) -> dict:
        if lsm.pattern == "raid5":
            return self._r5_getattr(lsm, locked=False)
        outs = self.sim.parallel([
            (lambda o=o: self.by_uuid[o["ost"]].getattr(o["group"], o["oid"]))
            for o in lsm.objects])
        return {"size": logical_size(lsm, [a["size"] for a in outs]),
                "mtime": max((a["mtime"] for a in outs), default=0.0),
                "blocks": sum(a["blocks"] for a in outs)}

    def glimpse(self, lsm: StripeMd) -> dict:
        """size/mtime of ONE file via glimpse (§7.7): per-OST vectored
        glimpse_bulk RPCs; writers holding PW locks are asked for their
        LVBs, never revoked — correct even against unflushed write-back
        caches (plain getattr reads disk and misses them)."""
        return self.glimpse_files({0: lsm})[0]

    def glimpse_files(self, lsms: dict) -> dict:
        """Batched glimpse across MANY files: every file's stripe objects
        are grouped per OST and fetched with ONE vectored glimpse_bulk
        RPC per OST (a striped-directory scan pays #OSTs RPCs, not
        #files x #stripes). lsms: key -> StripeMd; returns key ->
        {"size", "mtime"} (logical size recombined per file)."""
        by_ost: dict[str, list] = {}
        for key, lsm in lsms.items():
            for i, o in enumerate(lsm.objects):
                by_ost.setdefault(o["ost"], []).append(
                    (key, i, o["group"], o["oid"]))

        def one(uuid, items):
            osc = self.by_uuid[uuid]
            try:
                outs = osc.glimpse_bulk([(g, o) for _, _, g, o in items])
            except R.TimeoutError_:
                self._mark_dead(osc)
                return []                  # degraded: no witness from it
            except R.RpcError:
                return []
            return [(k, i, a) for (k, i, _, _), a in zip(items, outs)]

        parts = self.sim.parallel([(lambda u=u, it=it: one(u, it))
                                   for u, it in by_ost.items()])
        per_obj: dict[tuple, dict] = {}
        for plist in parts:
            for key, i, a in plist:
                per_obj[(key, i)] = a or {"size": 0, "mtime": 0.0}
        out = {}
        for key, lsm in lsms.items():
            attrs = [per_obj.get((key, i))
                     for i in range(len(lsm.objects))]
            live = [a for a in attrs if a is not None]
            if lsm.pattern == "raid5":
                # best-effort: survivors witness what they can; no
                # reconstruction refinement on the bulk path
                size = _r5_logical_size(
                    lsm, [None if a is None else a["size"] for a in attrs])
            else:
                size = logical_size(
                    lsm, [(a or {"size": 0})["size"] for a in attrs])
            out[key] = {"size": size,
                        "mtime": max((a["mtime"] for a in live),
                                     default=0.0)}
        if self.sim:
            self.sim.stats.count("lov.glimpse")
            self.sim.stats.count("lov.glimpse_files", len(lsms))
        return out

    def getattr_locked(self, lsm: StripeMd) -> dict:
        """getattr under PR locks: revokes writers' PW locks first, so
        their write-back caches flush and the sizes are current (the
        client-side ordering rule of §6.2.3; real Lustre uses glimpse
        ASTs — a PR enqueue is our simpler equivalent). Served from the
        cached locks' value blocks (§7.7) when possible: a warm
        sequential reader pays ZERO RPCs for its size checks."""
        if lsm.pattern == "raid5":
            return self._r5_getattr(lsm, locked=True)
        outs = self.sim.parallel([
            (lambda o=o: self.by_uuid[o["ost"]].getattr_locked(
                o["group"], o["oid"]))
            for o in lsm.objects])
        return {"size": logical_size(lsm, [a["size"] for a in outs]),
                "mtime": max((a["mtime"] for a in outs), default=0.0)}

    def readahead(self, lsm: StripeMd, offset: int, length: int) -> int:
        """Populate the per-OSC clean caches for [offset, offset+length):
        the window is split over the stripe objects and fetched as ONE
        vectored OST_READ per stripe object (runs already cached are
        skipped by the OSC). Returns the number of bytes requested."""
        if lsm.pattern == "raid5":
            return 0                      # no readahead on parity layouts
        runs = _chunks(lsm, offset, length)
        if not runs:
            return 0
        by_stripe: dict[int, list] = {}
        for sidx, obj_off, ln, _ in runs:
            by_stripe.setdefault(sidx, []).append((obj_off, ln))

        def ra(sidx, iov):
            o = lsm.objects[sidx]
            self._osc(lsm, sidx).readv(o["group"], o["oid"], iov)

        self.sim.parallel([(lambda s=s, v=v: ra(s, v))
                           for s, v in by_stripe.items()])
        if self.sim:
            self.sim.stats.count("lov.readahead")
            self.sim.stats.count("lov.readahead_bytes", length)
        return length

    def destroy(self, lsm: StripeMd, cookies: list | None = None):
        r5 = lsm.pattern == "raid5"

        def rm(i, o):
            ck = cookies[i] if cookies else None
            try:
                self.by_uuid[o["ost"]].destroy(o["group"], o["oid"],
                                               cookie=ck)
            except R.RpcError as e:
                # -2: already gone; -19: deactivated dead OST (its
                # objects die with it — rebuild re-created the live copy)
                if e.status not in (-2, -19):
                    raise
            except R.TimeoutError_:
                if not r5:
                    raise
                self._mark_dead(self.by_uuid[o["ost"]])
        self.sim.parallel([(lambda i=i, o=o: rm(i, o))
                           for i, o in enumerate(lsm.objects)])

    def punch(self, lsm: StripeMd, size: int):
        if lsm.pattern == "raid5":
            return self._r5_punch(lsm, size)
        # per-object truncation point
        for i, o in enumerate(lsm.objects):
            osz = self._obj_size_for(lsm, i, size)
            self.by_uuid[o["ost"]].punch(o["group"], o["oid"], osz)

    @staticmethod
    def _obj_size_for(lsm: StripeMd, i: int, logical: int) -> int:
        """Object-local size when the file is truncated to `logical`."""
        if logical == 0:
            return 0
        last = logical - 1
        snum, rem = divmod(last, lsm.stripe_size)
        full_rounds, sidx = divmod(snum, lsm.stripe_count)
        if i < sidx:
            return (full_rounds + 1) * lsm.stripe_size
        if i == sidx:
            return full_rounds * lsm.stripe_size + rem + 1
        return full_rounds * lsm.stripe_size

    def flush(self):
        self.sim.parallel([(lambda o=o: o.flush()) for o in self.oscs])

    def sync(self):
        self.sim.parallel([(lambda o=o: o.sync()) for o in self.oscs])


# ------------------------------------------------------------------ RAID1

class Raid1:
    """Redundant OSTs (ch. 15): mirror writes to two OSCs; reads prefer the
    primary and fail over; a dirty-extent log drives resync after an OST
    comes back.

    Each dirty-log entry records WHICH mirror missed the write — resync
    must copy from the up-to-date mirror to the stale one (reading
    "primary first" would replay stale data over the good copy whenever
    the primary was the mirror that missed), and reads must never be
    served from a mirror with pending dirty extents for the range."""

    def __init__(self, primary: osc_mod.Osc, secondary: osc_mod.Osc,
                 group: int = 0):
        self.a = primary
        self.b = secondary
        self.sim = primary.sim
        self.group = group
        # (oid, off, len, missed) — missed in {"a", "b"}: the STALE side
        self.dirty_log: list[tuple[int, int, int, str]] = []

    def _mirror(self, name: str) -> osc_mod.Osc:
        return self.a if name == "a" else self.b

    def create(self, oid: int | None = None) -> int:
        out = self.a.create(self.group, oid)
        self.b.create(self.group, out["oid"])
        return out["oid"]

    def write(self, oid: int, offset: int, data: bytes):
        def one(osc):
            try:
                osc.write(self.group, oid, offset, data)
                return True
            except (R.RpcError, R.TimeoutError_):
                return False
        oks = self.sim.parallel([lambda: one(self.a), lambda: one(self.b)])
        if not any(oks):
            raise R.RpcError(-5, "both mirrors failed")
        if not all(oks):
            missed = "a" if not oks[0] else "b"
            self.dirty_log.append((oid, offset, len(data), missed))
            self.sim.stats.count("raid1.degraded_write")

    # ------------------------------------------------------ dirty log
    def _dirty_overlap(self, oid: int, off: int, ln: int,
                       mirror: str) -> list:
        """Dirty-log entries marking `mirror` stale over [off, off+ln)."""
        return [e for e in self.dirty_log
                if e[0] == oid and e[3] == mirror
                and e[1] < off + ln and off < e[1] + e[2]]

    def _heal_entries(self, entries: list) -> bool:
        """Copy each entry from its up-to-date mirror onto the stale one;
        on success drop it from the log. False if any copy failed (the
        entries stay logged and the stale mirror stays unserved)."""
        for e in entries:
            oid, off, ln, missed = e
            src = self._mirror("b" if missed == "a" else "a")
            dst = self._mirror(missed)
            try:
                data = src.read(self.group, oid, off, ln)
                dst.write(self.group, oid, off, data)
            except (R.RpcError, R.TimeoutError_):
                return False
            self.dirty_log.remove(e)
            self.sim.stats.count("raid1.heal_on_read")
        return True

    # ----------------------------------------------------------- reads
    def read(self, oid: int, offset: int, length: int) -> bytes:
        """Primary-preferring read that never serves stale bytes: a
        mirror with pending dirty extents overlapping the range is
        healed from the up-to-date mirror first — if healing is
        impossible (the up-to-date side is down) the stale mirror is
        SKIPPED, and -5 beats silently wrong data."""
        for name in ("a", "b"):
            stale = self._dirty_overlap(oid, offset, length, name)
            if stale and not self._heal_entries(stale):
                self.sim.stats.count("raid1.stale_read_avoided")
                continue
            try:
                data = self._mirror(name).read(self.group, oid, offset,
                                               length)
            except (R.RpcError, R.TimeoutError_):
                continue
            if name == "b":
                self.sim.stats.count("raid1.failover_read")
            return data
        raise R.RpcError(-5, "raid1: no mirror holds fresh data")

    def read_hedged(self, oid: int, offset: int, length: int) -> bytes:
        """Straggler mitigation: issue the read to BOTH mirrors, take the
        first completion (a slow/overloaded OST only costs its own link).
        Both racers run; if the winner failed, the LOSER's result is
        used as-is — no third RPC.  Ranges with pending dirty extents
        take the dirty-aware `read()` path instead."""
        if (self._dirty_overlap(oid, offset, length, "a")
                or self._dirty_overlap(oid, offset, length, "b")):
            return self.read(oid, offset, length)
        results: list = [None, None]

        def one(idx, osc):
            try:
                results[idx] = osc.read(self.group, oid, offset, length)
            except (R.RpcError, R.TimeoutError_):
                pass
            return results[idx]

        widx, data = self.sim.race([lambda: one(0, self.a),
                                    lambda: one(1, self.b)])
        if data is None:                  # winner failed: loser already ran
            data = results[1 - widx]
            if data is None:
                raise R.RpcError(-5, "both mirrors failed")
            self.sim.stats.count("raid1.hedge_loser_used")
        return data

    def resync(self):
        """Replay the dirty log: copy each extent FROM the mirror that
        took the write TO the one that missed it (direction recorded at
        write time — reading "primary first" here would overwrite the
        good secondary with stale primary data whenever the primary was
        the side that missed)."""
        log, self.dirty_log = self.dirty_log, []
        healed = 0
        for oid, off, ln, missed in log:
            src = self._mirror("b" if missed == "a" else "a")
            dst = self._mirror(missed)
            try:
                data = src.read(self.group, oid, off, ln)
                dst.write(self.group, oid, off, data)
                healed += 1
            except (R.RpcError, R.TimeoutError_):
                self.dirty_log.append((oid, off, ln, missed))
        return healed
