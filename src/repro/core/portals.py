"""Portals message-passing layer (paper ch. 4, 22, 24, 40).

Faithful concepts: a *portal table* per network interface (NI), each portal
entry holding a list of *match entries* that gate delivery into *memory
descriptors*; *events* (PUT/GET/REPLY/ACK/SENT/UNLINK/DROP) written into
*event queues* with optional handlers; `put`/`get` data movement; NAL link
types with different latency/bandwidth; *routing* through gateway nodes with
load balancing over equivalent routes and `lctl set_gw up|down` style
enable/disable (§4.4).

Delivery is synchronous (the receiver's event handler runs inline) while the
virtual clock models transfer time per hop.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import defaultdict
from typing import Any, Callable, Optional

from repro.core.sim import NALS, LinkSpec, Simulator

# Event kinds
PUT, GET, REPLY, ACK, SENT, UNLINK, DROP = (
    "PUT", "GET", "REPLY", "ACK", "SENT", "UNLINK", "DROP")

IGNORE_ALL = (1 << 64) - 1


@dataclasses.dataclass
class Event:
    kind: str
    initiator: "Nid"
    portal: int
    match_bits: int
    rlength: int
    offset: int
    md: "MemoryDescriptor"
    data: Any = None
    arrival_time: float = 0.0


@dataclasses.dataclass
class MemoryDescriptor:
    """A receive/send buffer. `buffer` holds python payloads (we model the
    wire as structured objects + an explicit byte length for timing)."""
    length: int
    threshold: int = 1                 # auto-unlink after N operations
    options: int = 0
    user_ptr: Any = None
    eq: Optional["EventQueue"] = None
    manage_remote_offset: bool = False
    # state
    buffer: list = dataclasses.field(default_factory=list)
    local_offset: int = 0
    unlinked: bool = False

    def _consume(self, nbytes: int) -> int:
        off = self.local_offset
        if self.manage_remote_offset:
            self.local_offset += nbytes
        self.threshold -= 1
        if self.threshold == 0:
            self.unlinked = True
        return off


@dataclasses.dataclass
class MatchEntry:
    match_bits: int
    ignore_bits: int
    md: MemoryDescriptor
    unlink_when_md: bool = True

    def matches(self, bits: int) -> bool:
        return (self.match_bits & ~self.ignore_bits) == (
            bits & ~self.ignore_bits)


class EventQueue:
    def __init__(self, handler: Callable[[Event], None] | None = None):
        self.handler = handler
        self.events: list[Event] = []

    def deliver(self, ev: Event):
        if self.handler is not None:
            self.handler(ev)
        else:
            self.events.append(ev)

    def pop(self) -> Event | None:
        return self.events.pop(0) if self.events else None


class Portal:
    def __init__(self):
        self.match_list: list[MatchEntry] = []

    def attach(self, me: MatchEntry, *, front: bool = False):
        if front:
            self.match_list.insert(0, me)
        else:
            self.match_list.append(me)

    def match(self, bits: int) -> MatchEntry | None:
        for me in self.match_list:
            if not me.md.unlinked and me.matches(bits):
                return me
        return None

    def gc(self):
        self.match_list = [m for m in self.match_list if not m.md.unlinked]


class NI:
    """Network interface: one portal table on one node, one NAL."""

    def __init__(self, nid: str, nal: str, network: "PortalsNetwork"):
        self.nid = nid
        self.nal = nal
        self.network = network
        self.portals: dict[int, Portal] = defaultdict(Portal)
        network.register(self)

    # ---------------------------------------------------------------- API
    def me_attach(self, portal: int, match_bits: int, ignore_bits: int,
                  md: MemoryDescriptor, front: bool = False) -> MatchEntry:
        me = MatchEntry(match_bits, ignore_bits, md)
        self.portals[portal].attach(me, front=front)
        return me

    def put(self, target_nid: str, portal: int, match_bits: int, data: Any,
            nbytes: int, *, offset: int = 0, ack: bool = False,
            reply_ev: EventQueue | None = None) -> float:
        """Send `data` (nbytes on the wire) to target portal/match_bits.
        Returns arrival virtual time (callers waiting for the result advance
        the clock to it)."""
        return self.network.transmit(
            Message(kind=PUT, src=self.nid, dst=target_nid, portal=portal,
                    match_bits=match_bits, data=data, nbytes=nbytes,
                    offset=offset, want_ack=ack, reply_eq=reply_ev))

    def get(self, target_nid: str, portal: int, match_bits: int,
            nbytes: int, reply_md: MemoryDescriptor) -> float:
        return self.network.transmit(
            Message(kind=GET, src=self.nid, dst=target_nid, portal=portal,
                    match_bits=match_bits, data=None, nbytes=nbytes,
                    reply_md=reply_md))

    # ------------------------------------------------------------ receive
    def deliver(self, msg: "Message", arrival: float):
        portal = self.portals[msg.portal]
        me = portal.match(msg.match_bits)
        if me is None:
            # Unsolicited packet with no posted buffer: dropped (Portals
            # assumes pre-posted buffers; §4.3.1).
            self.network.sim.stats.count("portals.no_match_drop")
            return
        md = me.md
        if msg.kind == PUT:
            off = md._consume(msg.nbytes)
            md.buffer.append((off, msg.data))
            if md.eq:
                md.eq.deliver(Event(PUT, msg.src, msg.portal, msg.match_bits,
                                    msg.nbytes, off, md, msg.data, arrival))
            if msg.want_ack:
                self.network.transmit(Message(
                    kind=ACK, src=self.nid, dst=msg.src, portal=msg.portal,
                    match_bits=msg.match_bits, data=None, nbytes=0,
                    reply_eq=msg.reply_eq))
        elif msg.kind == GET:
            md._consume(msg.nbytes)
            payload = md.user_ptr
            if md.eq:
                md.eq.deliver(Event(GET, msg.src, msg.portal, msg.match_bits,
                                    msg.nbytes, 0, md, None, arrival))
            self.network.transmit(Message(
                kind=REPLY, src=self.nid, dst=msg.src, portal=msg.portal,
                match_bits=msg.match_bits, data=payload, nbytes=msg.nbytes,
                reply_md=msg.reply_md))
        elif msg.kind in (REPLY, ACK):
            pass
        portal.gc()


@dataclasses.dataclass
class Message:
    kind: str
    src: str
    dst: str
    portal: int
    match_bits: int
    data: Any
    nbytes: int
    offset: int = 0
    want_ack: bool = False
    reply_eq: EventQueue | None = None
    reply_md: MemoryDescriptor | None = None


@dataclasses.dataclass
class Route:
    """dst network -> gateway nid (paper §4.4: redundant gateways)."""
    net: str
    gateway: str
    enabled: bool = True


class PortalsNetwork:
    """In-process router. Nids look like "net:host", e.g. "elan:mds0".

    Same-net messages go direct; cross-net messages hop through an enabled
    gateway (load-balanced round-robin over equivalent routes). Every hop
    pays the NAL's latency + bandwidth and consults the fault plan.
    """

    def __init__(self, sim: Simulator):
        self.sim = sim
        self.nis: dict[str, NI] = {}
        self.routes: list[Route] = []
        self._rr = itertools.count()
        self.link_busy: dict[tuple, float] = defaultdict(float)
        self.upcalls: list = []            # (event, args) log (§4.4 upcall)

    def register(self, ni: NI):
        self.nis[ni.nid] = ni

    # ------------------------------------------------------------- routes
    def add_route(self, net: str, gateway: str):
        self.routes.append(Route(net, gateway))

    def set_gw(self, gateway: str, up: bool):
        """lctl --net <nal> set_gw <nid> {up|down} (§4.4.3)."""
        for r in self.routes:
            if r.gateway == gateway:
                r.enabled = up

    def _gateways(self, net: str) -> list[str]:
        return [r.gateway for r in self.routes
                if r.net == net and r.enabled
                and r.gateway not in self.sim.faults.down_nids]

    @staticmethod
    def net_of(nid: str) -> str:
        return nid.split(":", 1)[0]

    def _path(self, src: str, dst: str) -> list[str] | None:
        if self.net_of(src) == self.net_of(dst):
            return [src, dst]
        gws = self._gateways(self.net_of(dst))
        if not gws:
            return None
        gw = gws[next(self._rr) % len(gws)]
        return [src, gw, dst]

    # ------------------------------------------------------------ deliver
    def _hop_time(self, src: str, dst: str, nbytes: int, start: float):
        nal = NALS.get(self.net_of(dst), NALS["socknal"])
        link = (src, dst)
        begin = max(start, self.link_busy[link])
        done = (begin + nal.latency + nal.small_msg_cost
                + nbytes / nal.bandwidth
                + self.sim.faults.extra_latency(src, dst))
        self.link_busy[link] = done
        return done

    def transmit(self, msg: Message) -> float:
        """Route + deliver a message. Returns arrival virtual time; on drop
        returns +inf (callers see a timeout)."""
        st = self.sim.stats
        st.count(f"portals.{msg.kind.lower()}")
        st.add_bytes("portals.wire", msg.nbytes)
        path = self._path(msg.src, msg.dst)
        if path is None:
            st.count("portals.unreachable")   # ENETUNREACH (§4.4.3)
            return float("inf")
        t = self.sim.now
        for a, b in zip(path, path[1:]):
            if self.sim.faults.should_drop(a, b):
                st.count("portals.dropped")
                # NAL peer-death detection -> router notification + upcall
                if b in self.sim.faults.down_nids and self._is_gateway(b):
                    self.upcalls.append(("ROUTER_NOTIFY", b, "down"))
                return float("inf")
            t = self._hop_time(a, b, msg.nbytes, t)
        dst_ni = self.nis.get(msg.dst)
        if dst_ni is None:
            st.count("portals.no_ni")
            return float("inf")
        if msg.kind == REPLY and msg.reply_md is not None:
            md = msg.reply_md
            md._consume(msg.nbytes)
            md.buffer.append((0, msg.data))
            if md.eq:
                md.eq.deliver(Event(REPLY, msg.src, msg.portal,
                                    msg.match_bits, msg.nbytes, 0, md,
                                    msg.data, t))
            return t
        if msg.kind == ACK:
            if msg.reply_eq:
                msg.reply_eq.deliver(Event(ACK, msg.src, msg.portal,
                                           msg.match_bits, 0, 0, None, None,
                                           t))
            return t
        dst_ni.deliver(msg, t)
        return t

    def _is_gateway(self, nid: str) -> bool:
        return any(r.gateway == nid for r in self.routes)
