"""Shared layers + parameter-definition infrastructure.

Every model builds a pytree of ParamDef (shape, logical spec, init); from it we
derive (a) real initialized arrays for CPU smoke tests, (b) ShapeDtypeStructs +
NamedShardings for the multi-pod dry-run (no allocation).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel import shardings as sh


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    logical: tuple  # logical axis per dim: "model" | "batch" | None
    init: str = "normal"  # normal | zeros | ones | embed
    scale: float = 1.0

    def struct(self, dtype) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, dtype)


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def tree_structs(defs, dtype):
    return jax.tree.map(lambda d: d.struct(dtype), defs, is_leaf=is_def)


def tree_specs(defs, mesh, fsdp: bool = False):
    """Parameter NamedShardings. With fsdp=True, each tensor additionally
    shards its largest still-replicated dim over the "data" axis (ZeRO-3
    within a pod; replicated across pods — DCN all-gathers would dominate).
    GSPMD then all-gathers per layer inside the scan and reduce-scatters
    gradients."""
    if not fsdp or "data" not in getattr(mesh, "axis_names", ()):
        return jax.tree.map(
            lambda d: sh.named(mesh, d.logical, d.shape), defs,
            is_leaf=is_def)
    dsize = mesh.shape["data"]

    def spec(d: ParamDef):
        base = list(sh.resolve_spec(mesh, d.logical, d.shape))
        cands = [(dim, i) for i, (dim, s) in enumerate(zip(d.shape, base))
                 if s is None and dim % dsize == 0 and dim >= dsize]
        if cands:
            _, i = max(cands)
            base[i] = "data"
        return jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec(*base))

    return jax.tree.map(spec, defs, is_leaf=is_def)


def tree_init(defs, key, dtype=jnp.float32):
    """Initialize real arrays (tiny smoke configs only)."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, d in zip(keys, leaves):
        if d.init == "zeros":
            a = jnp.zeros(d.shape, dtype)
        elif d.init == "ones":
            a = jnp.ones(d.shape, dtype)
        else:
            fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
            std = d.scale / np.sqrt(max(1, fan_in))
            a = (jax.random.normal(k, d.shape, jnp.float32) * std).astype(dtype)
        out.append(a)
    return jax.tree.unflatten(treedef, out)


# ---------------------------------------------------------------- layers

def rms_norm(x, w, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


def rope(x, positions, theta: float):
    """x: (..., S, H, D) rotary over D; positions (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.arange(0, half, dtype=jnp.float32)
    inv = theta ** (-freqs / half)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, half)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    sin = sin[..., None, :]  # broadcast over heads
    cos = cos[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": partial(jax.nn.gelu, approximate=True),
            "relu": jax.nn.relu}[name]


def attention_scores(q, k, v, mask, dtype=jnp.bfloat16):
    """Reference (non-flash) attention. q:(B,Sq,H,D) k/v:(B,Sk,Hkv,D).

    GQA handled by reshaping q into (B,Sq,Hkv,G,D)."""
    B, Sq, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Sq, Hkv, G, D)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32)
    logits = logits / np.sqrt(D)
    logits = jnp.where(mask[:, None, None, :, :] if mask.ndim == 3 else mask,
                       logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return out.reshape(B, Sq, H, D)


def causal_mask(Sq, Sk, window=0, prefix_len=0, q_offset=0):
    """(Sq, Sk) boolean mask. window>0 = sliding window; prefix bidirectional."""
    qp = jnp.arange(Sq)[:, None] + q_offset
    kp = jnp.arange(Sk)[None, :]
    m = kp <= qp
    if isinstance(window, (int, np.integer)):
        if window > 0:
            m = m & (qp - kp < window)
    else:  # traced scalar (per-layer, inside scan)
        m = m & jnp.where(window > 0, qp - kp < jnp.maximum(window, 1), True)
    if prefix_len:
        both_prefix = (qp < prefix_len) & (kp < prefix_len)
        m = m | both_prefix
    return m


def decode_mask(Smax, pos, window=0):
    """(1, Smax) mask for one-token decode at position `pos` (inclusive)."""
    kp = jnp.arange(Smax)[None, :]
    m = kp <= pos
    if isinstance(window, (int, np.integer)):
        if window > 0:
            m = m & (pos - kp < window)
    else:
        m = m & jnp.where(window > 0, pos - kp < jnp.maximum(window, 1), True)
    return m
