"""Zamba2 — Mamba2 (SSD) backbone with a shared full-attention block.

81 blocks = 13 groups x 6 Mamba2 blocks + 3 tail Mamba2 blocks; ONE shared
attention+MLP block (single weight set) is applied after every group, each
invocation with its own KV-cache slot (13 slots). This follows Zamba2's
shared-block design (per-invocation LoRA adapters are omitted; noted in
DESIGN.md).

Mamba2 SSD is implemented in the chunked parallel form for train/prefill
(chunk Q=128) and as a single-step state update for decode; decode state is
O(1) in context length, so the long_500k cell runs for this family.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.parallel.shardings import constrain

GROUP = 6          # mamba blocks per shared-attention application


def _dims(cfg: ModelConfig):
    d_in = cfg.d_inner or 2 * cfg.d_model
    P = cfg.ssm_head_dim
    H = d_in // P
    N = cfg.ssm_state
    conv_dim = d_in + 2 * N
    return d_in, H, P, N, conv_dim


def n_groups_tail(cfg: ModelConfig):
    return cfg.n_layers // GROUP, cfg.n_layers % GROUP


def _mamba_defs(cfg: ModelConfig, lead: tuple[int, ...]):
    d = cfg.d_model
    d_in, H, P, N, conv_dim = _dims(cfg)
    proj_out = 2 * d_in + 2 * N + H
    D = lambda *s, lg=None, init="normal": L.ParamDef(
        (*lead, *s), (None,) * len(lead) + (lg or (None,) * len(s)), init)
    return {
        "ln": D(d, init="zeros"),
        "in_proj": D(d, proj_out, lg=(None, "model")),
        "conv_w": D(cfg.conv_width, conv_dim, init="zeros"),
        "a_log": D(H, init="zeros"),
        "dt_bias": D(H, init="zeros"),
        "skip_d": D(H, init="ones"),
        "gn": D(d_in, init="zeros"),
        "out_proj": D(d_in, d, lg=("model", None)),
    }


def param_defs(cfg: ModelConfig):
    d, V = cfg.d_model, cfg.vocab
    ng, tail = n_groups_tail(cfg)
    defs = {
        "embed": L.ParamDef((V, d), ("model", None), scale=float(np.sqrt(d))),
        "final_ln": L.ParamDef((d,), (None,), init="zeros"),
        "mamba_groups": _mamba_defs(cfg, (ng, GROUP)),
        "shared_attn": {"attn": tfm._attn_defs(cfg, 1),
                        "mlp": tfm._mlp_defs(cfg, 1)},
        "lm_head": L.ParamDef((d, V), (None, "model")),
    }
    if tail:
        defs["mamba_tail"] = _mamba_defs(cfg, (tail,))
    return defs


# ------------------------------------------------------------------ SSD

def _conv1d(x, w, x_prev=None):
    """Causal depthwise conv. x: (B,S,C), w: (W,C). x_prev: (B,W-1,C)."""
    W = w.shape[0]
    pad = (jnp.zeros_like(x[:, : W - 1]) if x_prev is None else x_prev)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i: i + x.shape[1]] * w[i] for i in range(W))
    return out, xp[:, -(W - 1):]


def _ssd_chunked(xh, Bm, Cm, da, dt, chunk, cdt=jnp.bfloat16):
    """Chunked SSD. xh:(B,S,H,P) Bm/Cm:(B,S,N) da:(B,S,H) (log-decay <0),
    dt:(B,S,H). Returns y:(B,S,H,P), final state (B,H,N,P).

    Decay accumulation (cumsum/exp) stays f32; the big (B,nc,Q,Q,H)
    intra-chunk tensor + its einsum run in `cdt` (bf16): halves the
    dominant HBM traffic (EXPERIMENTS.md §Perf zamba2 iter-3)."""
    Bsz, S, H, P = xh.shape
    N = Bm.shape[-1]
    nc = S // chunk
    r = lambda a: a.reshape(Bsz, nc, chunk, *a.shape[2:])
    xh, Bm, Cm, da, dt = r(xh), r(Bm), r(Cm), r(da), r(dt)
    # the BIG tensors (xh/Bm/Cm and the (Q,Q,H) intra term) stay bf16 so
    # forward AND cotangents stay bf16; only the small decay accumulators
    # (B,S,H) run f32 (exp/cumsum numerics)
    seg = jnp.cumsum(da, axis=2)                       # (B,nc,Q,H) f32
    seg_last = seg[:, :, -1:]                          # (B,nc,1,H)
    # intra-chunk ("diagonal") term
    li = seg[:, :, :, None, :] - seg[:, :, None, :, :]  # (B,nc,Qi,Qj,H)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    Lm = jnp.where(mask[None, None, :, :, None], jnp.exp(li), 0.0)
    cb = jnp.einsum("bcin,bcjn->bcij", Cm, Bm,
                    preferred_element_type=jnp.float32)  # (B,nc,Qi,Qj)
    att = (cb[..., None] * Lm * dt[:, :, None, :, :]).astype(cdt)
    y = jnp.einsum("bcijh,bcjhp->bcihp", att, xh,
                   preferred_element_type=jnp.float32)
    # chunk-local end states
    dec = jnp.exp(seg_last - seg)                       # (B,nc,Q,H)
    st = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", Bm,
                    (dec * dt).astype(cdt), xh,
                    preferred_element_type=jnp.float32)
    # inter-chunk scan
    gl = jnp.exp(seg_last[:, :, 0])                     # (B,nc,H)

    def step(Sprev, t):
        st_c, gl_c = t
        return gl_c[..., None, None] * Sprev + st_c, Sprev

    state0 = jnp.zeros((Bsz, st.shape[2], N, xh.shape[-1]), jnp.float32)
    state, Sprevs = jax.lax.scan(
        step, state0, (jnp.moveaxis(st, 1, 0), jnp.moveaxis(gl, 1, 0)))
    Sprevs = jnp.moveaxis(Sprevs, 0, 1)                 # (B,nc,H,N,P)
    y_inter = jnp.einsum("bcin,bchnp,bcih->bcihp", Cm.astype(jnp.float32),
                         Sprevs, jnp.exp(seg))
    y = y.astype(jnp.float32) + y_inter
    return y.reshape(Bsz, S, H, P), state


def _mamba_block(cfg, p, x, rc, conv_prev=None, state=None):
    """Returns (x_out, new_conv_state, new_ssm_state)."""
    cdt = jnp.dtype(rc.compute_dtype)
    d_in, H, P, N, conv_dim = _dims(cfg)
    B_, S, d = x.shape
    h = L.rms_norm(x, p["ln"], cfg.norm_eps)
    zxbcdt = h @ p["in_proj"].astype(cdt)
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in: d_in + conv_dim]
    dt_raw = zxbcdt[..., d_in + conv_dim:]
    xbc, conv_state = _conv1d(xbc, p["conv_w"].astype(cdt), conv_prev)
    xbc = jax.nn.silu(xbc)
    xh = xbc[..., :d_in].reshape(B_, S, H, P)           # bf16 (big)
    Bm = xbc[..., d_in: d_in + N]                        # bf16 (big)
    Cm = xbc[..., d_in + N:]                             # bf16 (big)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))        # (H,)
    da = dt * a                                          # (B,S,H) f32 small
    if state is None:
        chunk = next(c for c in (rc.ssm_chunk, 128, 64, 32, 16, 8, 4, 2, 1)
                     if c <= S and S % c == 0)
        y, state = _ssd_chunked(xh, Bm, Cm, da, dt, chunk, cdt)
    else:  # single-step decode (S == 1)
        kv = jnp.einsum("bsn,bsh,bshp->bhnp", Bm.astype(jnp.float32), dt,
                        xh.astype(jnp.float32))
        state = jnp.exp(da)[:, 0, :, None, None] * state + kv
        y = jnp.einsum("bsn,bhnp->bshp", Cm.astype(jnp.float32), state)
    y = y.astype(cdt) + p["skip_d"].astype(cdt)[None, None, :, None] * xh
    y = y.reshape(B_, S, d_in)
    y = y * jax.nn.silu(z)
    y = L.rms_norm(y, p["gn"], cfg.norm_eps)
    out = y @ p["out_proj"].astype(cdt)
    return constrain(x + out, ("batch", None, None)), conv_state, state


def _shared(params):
    return jax.tree.map(lambda a: a[0], params["shared_attn"])


def forward(cfg: ModelConfig, params, batch, rc, return_cache=False):
    cdt = jnp.dtype(rc.compute_dtype)
    tokens = batch["tokens"]
    x = constrain(params["embed"].astype(cdt)[tokens], ("batch", None, None))
    shared = _shared(params)
    ng, tail = n_groups_tail(cfg)

    def mamba_body(x, pl):
        x, cs, st = _mamba_block(cfg, pl, x, rc)
        return x, (cs, st) if return_cache else None

    # nested remat: the outer group checkpoint bounds liveness to one
    # group; the inner per-block checkpoint bounds it to one BLOCK during
    # the group replay. Dropping the inner one saves ~8% HBO traffic but
    # raises temp 8.2 -> 15.7 GB/device (rejected: too close to 16 GB;
    # EXPERIMENTS.md §Perf zamba2 iter-4).
    mb = jax.checkpoint(mamba_body) if rc.remat == "full" else mamba_body

    def group_body(x, pg):
        x, mcache = jax.lax.scan(mb, x, pg)
        x, kv = tfm.attn_block(cfg, shared["attn"], x, 0, 0, rc)
        x, _ = tfm.mlp_block(cfg, shared["mlp"], x, rc)
        return x, (mcache, kv) if return_cache else None

    # remat the WHOLE group (shared attention included): without this the
    # 13 groups' f32 attention tensors are saved for backward — measured
    # 62 GB/device temp on train_4k (EXPERIMENTS.md §Perf zamba2)
    gb = jax.checkpoint(group_body) if rc.remat == "full" else group_body
    x, gcache = jax.lax.scan(gb, x, params["mamba_groups"])
    tcache = None
    if tail:
        x, tcache = jax.lax.scan(mb, x, params["mamba_tail"])
    x = L.rms_norm(x, params["final_ln"], cfg.norm_eps)
    cache = None
    if return_cache:
        (mconv, mstate), (k, v) = gcache
        cache = {"conv": mconv, "state": mstate, "k": k, "v": v}
        if tail:
            cache["tail_conv"], cache["tail_state"] = tcache
    return x, 0, cache, None, jnp.zeros((), jnp.float32)


def init_cache(cfg: ModelConfig, batch_size: int, seq_len: int, dtype):
    d_in, H, P, N, conv_dim = _dims(cfg)
    ng, tail = n_groups_tail(cfg)
    W = cfg.conv_width
    c = {
        "conv": ((ng, GROUP, batch_size, W - 1, conv_dim), dtype),
        "state": ((ng, GROUP, batch_size, H, N, P), jnp.float32),
        "k": ((ng, batch_size, seq_len, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": ((ng, batch_size, seq_len, cfg.n_kv_heads, cfg.head_dim), dtype),
    }
    if tail:
        c["tail_conv"] = ((tail, batch_size, W - 1, conv_dim), dtype)
        c["tail_state"] = ((tail, batch_size, H, N, P), jnp.float32)
    return c


def cache_logical():
    return {"conv": (None, None, "batch", None, "model"),
            "state": (None, None, "batch", None, None, "model"),
            "k": (None, "batch", "batch2", "model", "model2"),
            "v": (None, "batch", "batch2", "model", "model2"),
            "tail_conv": (None, "batch", None, "model"),
            "tail_state": (None, "batch", None, None, "model")}


def decode(cfg: ModelConfig, params, cache, token, pos, rc):
    cdt = jnp.dtype(rc.compute_dtype)
    x = params["embed"].astype(cdt)[token]
    shared = _shared(params)
    ng, tail = n_groups_tail(cfg)

    def mamba_body(x, sl):
        pl, cs, st = sl
        x, cs, st = _mamba_block(cfg, pl, x, rc, conv_prev=cs, state=st)
        return x, (cs, st)

    def group_body(x, sl):
        pg, cs, st, ck, cv = sl
        x, (cs, st) = jax.lax.scan(mamba_body, x, (pg, cs, st))
        x, (ck, cv) = tfm.decode_attn_block(
            cfg, shared["attn"], x, 0, ck, cv, pos, rc)
        x, _ = tfm.mlp_block(cfg, shared["mlp"], x, rc)
        return x, (cs, st, ck, cv)

    x, (cs, st, ck, cv) = jax.lax.scan(
        group_body, x, (params["mamba_groups"], cache["conv"],
                        cache["state"], cache["k"], cache["v"]))
    new_cache = dict(cache, conv=cs, state=st, k=ck, v=cv)
    if tail:
        x, (tc, ts) = jax.lax.scan(
            mamba_body, x, (params["mamba_tail"], cache["tail_conv"],
                            cache["tail_state"]))
        new_cache["tail_conv"], new_cache["tail_state"] = tc, ts
    x = L.rms_norm(x, params["final_ln"], cfg.norm_eps)
    logits = x @ params["lm_head"].astype(cdt)
    return constrain(logits, ("batch", None, "model")), new_cache
