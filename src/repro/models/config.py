"""Architecture + run configuration dataclasses."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # transformer | rwkv6 | zamba2
    n_layers: int
    d_model: int
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab: int = 0
    # attention details
    rope_theta: float = 1e4
    qk_norm: bool = False
    qkv_bias: bool = False
    sliding_window: int = 0          # window size for local layers (0 = full)
    global_every: int = 0            # gemma3: every Nth layer is global attn
    logit_softcap: float = 0.0
    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    # encoder-decoder (whisper) / VLM (paligemma) stub frontends
    enc_layers: int = 0
    enc_frames: int = 0              # precomputed frame embeddings (stub)
    n_patches: int = 0               # precomputed patch embeddings (stub)
    # SSM / hybrid
    ssm_state: int = 0
    d_inner: int = 0
    ssm_head_dim: int = 64
    conv_width: int = 4
    attn_every: int = 0              # zamba2: shared attn block period
    rwkv_head_dim: int = 64
    # misc
    act: str = "silu"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    embed_scale: bool = False        # gemma multiplies embeddings by sqrt(d)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def n_params(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6*N*D)."""
        from repro.models.registry import count_params
        return count_params(self)

    @property
    def n_active_params(self) -> int:
        from repro.models.registry import count_params
        return count_params(self, active_only=True)

    def scaled(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """One dry-run / training cell."""
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode
    num_microbatches: int = 1
    remat: str = "full"              # full | none
    param_dtype: str = "float32"     # train: fp32 master; serve: bf16
    compute_dtype: str = "bfloat16"
    attn_impl: str = "auto"          # auto | ref | chunked | flash (pallas)
    attn_chunk: int = 512            # q-row chunk for chunked attention
    shard_moe_tokens: bool = False   # hillclimb: shard_map all_to_all dispatch
    chunked_ce: int = 0              # hillclimb: vocab-chunked cross-entropy
    fsdp: str = "auto"               # auto|on|off: shard params over "data"
                                     # (ZeRO-3 in-pod); auto: train always,
                                     # serve when params/chip > 3 GB
    ssm_chunk: int = 128             # SSD intra-chunk length (mamba2):
                                     # memory & intra flops scale ~linearly
    grad_reduce_dtype: str = "float32"  # bf16 halves the grad RS volume
    windowed_cache: bool = False     # local-attn layers keep a ring buffer
                                     # of `window` keys instead of full S

    def fsdp_enabled(self, param_bytes_per_model_shard: int = 0) -> bool:
        if self.fsdp == "on":
            return True
        if self.fsdp == "off":
            return False
        if self.kind == "train":
            return True
        return param_bytes_per_model_shard > 3 << 30


SHAPES = {
    "train_4k":    RunConfig(seq_len=4096,   global_batch=256, kind="train",
                             num_microbatches=4),
    "prefill_32k": RunConfig(seq_len=32768,  global_batch=32,  kind="prefill",
                             param_dtype="bfloat16"),
    "decode_32k":  RunConfig(seq_len=32768,  global_batch=128, kind="decode",
                             param_dtype="bfloat16"),
    "long_500k":   RunConfig(seq_len=524288, global_batch=1,   kind="decode",
                             param_dtype="bfloat16"),
}
