"""RWKV-6 "Finch" — data-dependent decay linear-attention RNN.

Recurrence (per head, Dk x Dv state S):
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
Token shift + ddlerp mixing feed r/k/v/g/w projections; decay w_t is
data-dependent through a small LoRA (d -> 64 -> d).

Train/prefill scan over time carries only the (B,H,Dk,Dv) state; decode is a
single-step state update — context length never enters the state size, which
is why the long_500k cell runs for this family.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.parallel.shardings import constrain

LORA = 64


def param_defs(cfg: ModelConfig):
    d, V, n = cfg.d_model, cfg.vocab, cfg.n_layers
    H = d // cfg.rwkv_head_dim
    Dh = cfg.rwkv_head_dim
    D = lambda *s, init="normal": L.ParamDef((n, *s), (None,) * (len(s) + 1), init)
    Dm = lambda *s, lg, init="normal": L.ParamDef((n, *s), (None, *lg), init)
    att = {
        "ln": D(d, init="zeros"),
        "mu": D(5, d, init="zeros"),           # ddlerp base mix for r,k,v,g,w
        "lora_a": D(d, LORA),                   # decay lora
        "lora_b": D(LORA, d),
        "w0": D(d, init="zeros"),
        "u": D(H, Dh, init="zeros"),            # bonus
        "wr": Dm(d, d, lg=(None, "model")),
        "wk": Dm(d, d, lg=(None, "model")),
        "wv": Dm(d, d, lg=(None, "model")),
        "wg": Dm(d, d, lg=(None, "model")),
        "wo": Dm(d, d, lg=("model", None)),
        "gn": D(d, init="zeros"),               # per-channel group-norm scale
    }
    ffn = {
        "ln": D(d, init="zeros"),
        "mu": D(2, d, init="zeros"),
        "wk": Dm(d, cfg.d_ff, lg=(None, "model")),
        "wv": Dm(cfg.d_ff, d, lg=("model", None)),
        "wr": Dm(d, d, lg=(None, "model")),
    }
    return {
        "embed": L.ParamDef((V, d), ("model", None), scale=float(np.sqrt(d))),
        "layers": {"att": att, "ffn": ffn},
        "final_ln": L.ParamDef((d,), (None,), init="zeros"),
        "lm_head": L.ParamDef((d, V), (None, "model")),
    }


def _shift(x, x_prev=None):
    """Token shift: x_{t-1} (zeros/x_prev at t=0). x: (B,S,d)."""
    if x_prev is None:
        pad = jnp.zeros_like(x[:, :1])
    else:
        pad = x_prev[:, None]
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def _mix(x, xs, mu):
    return x + (xs - x) * mu


def _wkv_seq(r, k, v, w, u, state):
    """r,k,w: (B,S,H,Dk) v: (B,S,H,Dv) u: (H,Dk) state: (B,H,Dk,Dv).

    Returns y: (B,S,H,Dv), final state. Scan over time in f32."""
    def step(S, t):
        r_t, k_t, v_t, w_t = t
        kv = k_t[..., :, None] * v_t[..., None, :]          # (B,H,Dk,Dv)
        y = jnp.einsum("bhk,bhkv->bhv", r_t, S + u[..., None] * kv)
        S = w_t[..., None] * S + kv
        return S, y

    rs, ks, vs, ws = (jnp.moveaxis(a, 1, 0).astype(jnp.float32)
                      for a in (r, k, v, w))
    state, ys = jax.lax.scan(step, state.astype(jnp.float32),
                             (rs, ks, vs, ws))
    return jnp.moveaxis(ys, 0, 1), state


def _time_mix(cfg, p, x, x_prev, state, rc):
    cdt = jnp.dtype(rc.compute_dtype)
    B, S, d = x.shape
    H, Dh = d // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    h = L.rms_norm(x, p["ln"], cfg.norm_eps)
    hs = _shift(h, x_prev)
    mu = p["mu"].astype(cdt)
    xr, xk, xv, xg, xw = (_mix(h, hs, mu[i]) for i in range(5))
    r = (xr @ p["wr"].astype(cdt)).reshape(B, S, H, Dh)
    k = (xk @ p["wk"].astype(cdt)).reshape(B, S, H, Dh)
    v = (xv @ p["wv"].astype(cdt)).reshape(B, S, H, Dh)
    g = jax.nn.silu(xg @ p["wg"].astype(cdt))
    dw = jnp.tanh(xw @ p["lora_a"].astype(cdt)) @ p["lora_b"].astype(cdt)
    w = jnp.exp(-jnp.exp((p["w0"].astype(jnp.float32) + dw.astype(jnp.float32))
                         )).reshape(B, S, H, Dh)
    u = p["u"].astype(jnp.float32)
    y, state = _wkv_seq(r, k, v, w, u, state)
    y = y.reshape(B, S, d).astype(cdt)
    # group-norm per head
    y = y.reshape(B, S, H, Dh)
    y = y * jax.lax.rsqrt(jnp.mean(jnp.square(y.astype(jnp.float32)),
                                   axis=-1, keepdims=True) + 64e-5).astype(cdt)
    y = y.reshape(B, S, d) * (1.0 + p["gn"].astype(cdt))
    out = (y * g) @ p["wo"].astype(cdt)
    return constrain(x + out, ("batch", None, None)), h[:, -1], state


def _channel_mix(cfg, p, x, x_prev, rc):
    cdt = jnp.dtype(rc.compute_dtype)
    h = L.rms_norm(x, p["ln"], cfg.norm_eps)
    hs = _shift(h, x_prev)
    mu = p["mu"].astype(cdt)
    xk, xr = _mix(h, hs, mu[0]), _mix(h, hs, mu[1])
    k = jnp.square(jax.nn.relu(xk @ p["wk"].astype(cdt)))
    k = constrain(k, ("batch", None, "model"))
    v = k @ p["wv"].astype(cdt)
    r = jax.nn.sigmoid(xr @ p["wr"].astype(cdt))
    return constrain(x + r * v, ("batch", None, None)), h[:, -1]


def forward(cfg: ModelConfig, params, batch, rc, return_cache=False):
    cdt = jnp.dtype(rc.compute_dtype)
    tokens = batch["tokens"]
    x = constrain(params["embed"].astype(cdt)[tokens], ("batch", None, None))
    B, S, d = x.shape
    H, Dh = d // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    state0 = jnp.zeros((B, H, Dh, Dh), jnp.float32)

    def body(x, pl):
        x, xa, st = _time_mix(cfg, pl["att"], x, None, state0, rc)
        x, xf = _channel_mix(cfg, pl["ffn"], x, None, rc)
        return x, (xa, xf, st) if return_cache else None

    fn = jax.checkpoint(body) if rc.remat == "full" else body
    x, cache = jax.lax.scan(fn, x, params["layers"])
    x = L.rms_norm(x, params["final_ln"], cfg.norm_eps)
    if return_cache:
        xa, xf, st = cache
        cache = {"x_att": xa, "x_ffn": xf, "state": st}
    return x, 0, cache, None, jnp.zeros((), jnp.float32)


def init_cache(cfg: ModelConfig, batch_size: int, seq_len: int, dtype):
    d, n = cfg.d_model, cfg.n_layers
    H, Dh = d // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    return {"state": ((n, batch_size, H, Dh, Dh), jnp.float32),
            "x_att": ((n, batch_size, d), dtype),
            "x_ffn": ((n, batch_size, d), dtype)}


def cache_logical():
    return {"state": (None, "batch", None, None, "model2"),
            "x_att": (None, "batch", None),
            "x_ffn": (None, "batch", None)}


def decode(cfg: ModelConfig, params, cache, token, pos, rc):
    cdt = jnp.dtype(rc.compute_dtype)
    x = params["embed"].astype(cdt)[token]      # (B,1,d)

    def body(x, sl):
        pl, xa, xf, st = sl
        x, xa2, st2 = _time_mix(cfg, pl["att"], x, xa, st, rc)
        x, xf2 = _channel_mix(cfg, pl["ffn"], x, xf, rc)
        return x, (xa2, xf2, st2)

    x, (xa, xf, st) = jax.lax.scan(
        body, x, (params["layers"], cache["x_att"], cache["x_ffn"],
                  cache["state"]))
    x = L.rms_norm(x, params["final_ln"], cfg.norm_eps)
    logits = x @ params["lm_head"].astype(cdt)
    return constrain(logits, ("batch", None, "model")), {
        "x_att": xa, "x_ffn": xf, "state": st}
