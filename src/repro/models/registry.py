"""Family dispatch + analytic parameter counts."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import rwkv6, transformer, zamba2
from repro.models.config import ModelConfig

FAMILY = {"transformer": transformer, "rwkv6": rwkv6, "zamba2": zamba2}


def module(cfg: ModelConfig):
    return FAMILY[cfg.family]


def param_defs(cfg: ModelConfig):
    return module(cfg).param_defs(cfg)


def init_cache(cfg, batch_size, seq_len, dtype, windowed=False):
    if cfg.family == "transformer":
        return module(cfg).init_cache(cfg, batch_size, seq_len, dtype,
                                      windowed)
    return module(cfg).init_cache(cfg, batch_size, seq_len, dtype)


def cache_logical(cfg):
    return module(cfg).cache_logical()


def forward(cfg, params, batch, rc, return_cache=False):
    return module(cfg).forward(cfg, params, batch, rc, return_cache)


def decode(cfg, params, cache, token, pos, rc):
    return module(cfg).decode(cfg, params, cache, token, pos, rc)


unembed = transformer.unembed  # shared: all families use embed/lm_head


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    """Matmul-relevant parameter count (excludes embedding gather tables &
    positional tables; includes lm_head). MoE expert weights are scaled by
    top_k/n_experts when active_only."""
    from jax.tree_util import tree_flatten_with_path
    defs = param_defs(cfg)
    leaves, _ = tree_flatten_with_path(defs, is_leaf=lambda x: hasattr(x, "shape") and hasattr(x, "logical"))
    total = 0.0
    for path, d in leaves:
        keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        if "embed" in keys or "dec_pos" in keys:
            continue
        n = math.prod(d.shape)
        if cfg.is_moe and len(d.shape) == 4 and d.shape[1] == cfg.n_experts:
            if active_only:
                n = n * cfg.top_k / cfg.n_experts
        total += n
    return int(total)
