"""Top-k MoE FFN with sort-based, capacity-bounded dispatch.

Expert weights are sharded over the "model" mesh axis (expert parallelism).
Dispatch is a sort + scatter into an (E, C, d) buffer so the expert matmuls
are dense batched GEMMs with the *active* flop count (top_k * capacity_factor
x dense-one-expert), unlike one-hot-einsum dispatch which pays all-experts
flops.

Two dispatch strategies:
  * global (GSPMD): one logical (E, C, d) buffer; the cross-shard scatter
    makes XLA replicate + all-reduce it — simple but collective-heavy;
  * shard-local (shard_map, `rc.shard_moe_tokens`): activations are
    replicated over the "model" axis anyway (TP), so each device routes
    its LOCAL tokens to its LOCAL experts and a psum over "model" combines
    the partial outputs — zero token movement, buffer is (E/mp, C_l, d).
    This is the production layout; EXPERIMENTS.md §Perf quantifies the
    delta against the global baseline.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.shardings import constrain, get_ambient_mesh


def capacity(cfg, n_tokens: int) -> int:
    c = int(n_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(8, -(-c // 8) * 8)  # round up to 8


def moe_ffn(cfg, p, x, rc):
    """x: (T, d) -> (T, d), aux load-balance loss (scalar)."""
    if rc.shard_moe_tokens:
        mesh = get_ambient_mesh()
        if mesh is not None and "model" in mesh.axis_names \
                and cfg.n_experts % mesh.shape["model"] == 0:
            return moe_ffn_sharded(cfg, p, x, rc, mesh)
    return _moe_ffn_global(cfg, p, x, rc)


# ------------------------------------------------------------ shard-local

def _local_dispatch_ffn(cfg, rc, x_l, router, wg, wu, wd, e_off, E_l):
    """Per-device MoE: route local tokens to this device's experts.
    Returns the partial output (sum over local experts) + local aux."""
    cdt = jnp.dtype(rc.compute_dtype)
    T_l, d = x_l.shape
    E, k = cfg.n_experts, cfg.top_k
    C = capacity(cfg, T_l)

    gates = x_l.astype(jnp.float32) @ router.astype(jnp.float32)
    probs = jax.nn.softmax(gates, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(top_e[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(me * ce)

    flat_e = top_e.reshape(-1)
    mine = (flat_e >= e_off) & (flat_e < e_off + E_l)
    loc_e = jnp.where(mine, flat_e - e_off, E_l)        # E_l = drop bucket
    order = jnp.argsort(loc_e, stable=True)
    sorted_e = loc_e[order]
    counts = jnp.zeros(E_l + 1, jnp.int32).at[loc_e].add(1)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(T_l * k, dtype=jnp.int32) - starts[sorted_e]
    keep = (pos < C) & (sorted_e < E_l)
    dest = jnp.where(keep, sorted_e * C + pos, E_l * C)
    tok = order // k

    buf = jnp.zeros((E_l * C + 1, d), cdt).at[dest].set(
        x_l[tok].astype(cdt))
    xe = buf[:E_l * C].reshape(E_l, C, d)
    g = jnp.einsum("ecd,edf->ecf", xe, wg.astype(cdt))
    u = jnp.einsum("ecd,edf->ecf", xe, wu.astype(cdt))
    h = jax.nn.silu(g) * u
    out = jnp.einsum("ecf,efd->ecd", h, wd.astype(cdt))

    out_flat = jnp.concatenate(
        [out.reshape(E_l * C, d), jnp.zeros((1, d), cdt)], axis=0)
    gathered = out_flat[dest]
    w = top_p.reshape(-1)[order].astype(cdt)
    y = jnp.zeros((T_l, d), cdt).at[tok].add(gathered * w[:, None])
    return y, aux


def moe_ffn_sharded(cfg, p, x, rc, mesh):
    """shard_map dispatch: tokens stay put; psum over "model" combines the
    per-expert-shard partial outputs (experts ride the TP axis)."""
    import math
    mp = mesh.shape["model"]
    E_l = cfg.n_experts // mp
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_size = math.prod(mesh.shape[a] for a in dp_axes) if dp_axes else 1
    # tokens sharded over the dp axes iff divisible
    tok_dim = dp_axes if (dp_axes and x.shape[0] % dp_size == 0) else None

    def local(x_l, router, wg, wu, wd):
        e_off = jax.lax.axis_index("model") * E_l
        y, aux = _local_dispatch_ffn(cfg, rc, x_l, router, wg, wu, wd,
                                     e_off, E_l)
        y = jax.lax.psum(y, "model")
        aux = jax.lax.pmean(aux, "model")
        if tok_dim:
            aux = jax.lax.pmean(aux, tok_dim)
        return y, aux

    fn = jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(tok_dim, None), P(None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=(P(tok_dim, None), P()),
        check_vma=False)
    return fn(x, p["router"], p["wg"], p["wu"], p["wd"])


# ----------------------------------------------------------- global GSPMD

def _moe_ffn_global(cfg, p, x, rc):
    cdt = jnp.dtype(rc.compute_dtype)
    T, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    C = capacity(cfg, T)

    # keep tokens data-sharded through the (B,S)->(T,) reshape — without
    # this GSPMD replicates the whole dispatch (observed 21x flops bloat)
    x = constrain(x, ("batch", None))
    gates = x.astype(jnp.float32) @ p["router"].astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(gates, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                 # (T, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # load-balance aux (Switch-style): E * sum_e f_e * P_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(top_e[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(me * ce)

    flat_e = top_e.reshape(-1)                              # (T*k,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.zeros(E, jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(T * k, dtype=jnp.int32) - starts[sorted_e]
    keep = pos_in_e < C
    dest = jnp.where(keep, sorted_e * C + pos_in_e, E * C)  # E*C = drop slot
    tok = order // k

    buf = jnp.zeros((E * C + 1, d), cdt).at[dest].set(x[tok].astype(cdt))
    xe = constrain(buf[: E * C].reshape(E, C, d), ("model", None, None))

    g = jnp.einsum("ecd,edf->ecf", xe, p["wg"].astype(cdt))
    u = jnp.einsum("ecd,edf->ecf", xe, p["wu"].astype(cdt))
    h = jax.nn.silu(g) * u
    h = constrain(h, ("model", None, None))
    out = jnp.einsum("ecf,efd->ecd", h, p["wd"].astype(cdt))
    out = constrain(out, ("model", None, None))

    out_flat = jnp.concatenate(
        [out.reshape(E * C, d), jnp.zeros((1, d), cdt)], axis=0)
    gathered = out_flat[dest]                               # (T*k, d)
    w = top_p.reshape(-1)[order].astype(cdt)
    y = jnp.zeros((T, d), cdt).at[tok].add(gathered * w[:, None])
    return y, aux
