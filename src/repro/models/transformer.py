"""Unified decoder/encoder-decoder transformer.

Covers: yi-9b, gemma3-12b (5:1 local:global), qwen3-4b (qk_norm), qwen2-7b
(qkv bias), paligemma-3b (patch-prefix VLM), phi3.5-moe & dbrx (MoE),
whisper-tiny (enc-dec, frame-stub encoder).

All layer stacks are lax.scan over stacked params; per-layer attention windows
are a scanned int32 array so local/global mixes share one traced body.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models.config import ModelConfig
from repro.parallel.shardings import constrain


# ----------------------------------------------------------------- params

def _attn_defs(cfg: ModelConfig, n: int, cross: bool = False):
    d, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    D = lambda *s, lg, init="normal": L.ParamDef((n, *s), (None, *lg), init)
    p = {
        "ln": D(d, lg=(None,), init="zeros"),
        "wq": D(d, H * Dh, lg=(None, "model")),
        "wk": D(d, Hkv * Dh, lg=(None, "model")),
        "wv": D(d, Hkv * Dh, lg=(None, "model")),
        "wo": D(H * Dh, d, lg=("model", None)),
    }
    if cfg.qkv_bias and not cross:
        p |= {"bq": D(H * Dh, lg=("model",), init="zeros"),
              "bk": D(Hkv * Dh, lg=("model",), init="zeros"),
              "bv": D(Hkv * Dh, lg=("model",), init="zeros")}
    if cfg.qk_norm and not cross:
        p |= {"qn": D(Dh, lg=(None,), init="zeros"),
              "kn": D(Dh, lg=(None,), init="zeros")}
    return p


def _mlp_defs(cfg: ModelConfig, n: int):
    d = cfg.d_model
    D = lambda *s, lg, init="normal": L.ParamDef((n, *s), (None, *lg), init)
    if cfg.is_moe:
        E, f = cfg.n_experts, cfg.d_ff_expert
        return {
            "ln": D(d, lg=(None,), init="zeros"),
            "router": D(d, E, lg=(None, None)),
            "wg": D(E, d, f, lg=("model", None, None)),
            "wu": D(E, d, f, lg=("model", None, None)),
            "wd": D(E, f, d, lg=("model", None, None)),
        }
    f = cfg.d_ff
    return {
        "ln": D(d, lg=(None,), init="zeros"),
        "wg": D(d, f, lg=(None, "model")),
        "wu": D(d, f, lg=(None, "model")),
        "wd": D(f, d, lg=("model", None)),
    }


def param_defs(cfg: ModelConfig):
    d, V = cfg.d_model, cfg.vocab
    n = cfg.n_layers
    defs = {
        "embed": L.ParamDef((V, d), ("model", None), scale=float(np.sqrt(d))),
        "final_ln": L.ParamDef((d,), (None,), init="zeros"),
        "layers": {"attn": _attn_defs(cfg, n), "mlp": _mlp_defs(cfg, n)},
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = L.ParamDef((d, V), (None, "model"))
    if cfg.enc_layers:  # whisper-style encoder + cross attention
        ne = cfg.enc_layers
        defs["enc_layers"] = {"attn": _attn_defs(cfg, ne),
                              "mlp": _mlp_defs(cfg, ne)}
        defs["enc_final_ln"] = L.ParamDef((d,), (None,), init="zeros")
        defs["layers"]["xattn"] = _attn_defs(cfg, n, cross=True)
        defs["dec_pos"] = L.ParamDef((32768, d), (None, None), init="zeros")
    if cfg.n_patches:  # paligemma: projection for stub patch embeddings
        defs["patch_proj"] = L.ParamDef((d, d), (None, "model"))
    return defs


def windows(cfg: ModelConfig) -> np.ndarray:
    """Per-layer attention window (0 = global/full)."""
    w = np.zeros(cfg.n_layers, np.int32)
    if cfg.sliding_window and cfg.global_every:
        for i in range(cfg.n_layers):
            if (i + 1) % cfg.global_every != 0:
                w[i] = cfg.sliding_window
    elif cfg.sliding_window:
        w[:] = cfg.sliding_window
    return w


# ----------------------------------------------------------------- blocks

def _qkv(cfg, p, x, cdt):
    B, S, d = x.shape
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    h = L.rms_norm(x, p["ln"], cfg.norm_eps)
    q = h @ p["wq"].astype(cdt)
    k = h @ p["wk"].astype(cdt)
    v = h @ p["wv"].astype(cdt)
    if "bq" in p:
        q = q + p["bq"].astype(cdt)
        k = k + p["bk"].astype(cdt)
        v = v + p["bv"].astype(cdt)
    q = q.reshape(B, S, H, Dh)
    k = k.reshape(B, S, Hkv, Dh)
    v = v.reshape(B, S, Hkv, Dh)
    if "qn" in p:
        q = L.rms_norm(q, p["qn"], cfg.norm_eps)
        k = L.rms_norm(k, p["kn"], cfg.norm_eps)
    q = constrain(q, ("batch", None, "model", None))
    return q, k, v


def _attn_out(cfg, p, out, x, cdt):
    B, S = x.shape[:2]
    out = out.reshape(B, S, cfg.n_heads * cfg.head_dim)
    out = out @ p["wo"].astype(cdt)
    return constrain(x + out, ("batch", None, None))


def _chunked_attention(q, k, v, window, prefix_len, chunk, cdt,
                       q_offset_base=0):
    """Row-chunked softmax attention: bounds logits memory to
    B*H*chunk*Sk. Used for the 32k prefill cells."""
    B, Sq, H, Dh = q.shape
    nchunk = Sq // chunk
    qs = q.reshape(B, nchunk, chunk, H, Dh).transpose(1, 0, 2, 3, 4)

    def body(_, qc_i):
        qc, i = qc_i
        mask = L.causal_mask(chunk, k.shape[1], window, prefix_len,
                             q_offset=q_offset_base + i * chunk)
        oc = L.attention_scores(qc, k, v, mask[None], dtype=cdt)
        return None, oc

    _, out = jax.lax.scan(body, None, (qs, jnp.arange(nchunk)))
    return out.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, Dh)


def attn_block(cfg, p, x, window, prefix_len, rc, positions=None):
    """Full-sequence self attention (train / prefill). Returns (x, (k, v))."""
    cdt = jnp.dtype(rc.compute_dtype)
    B, S, _ = x.shape
    q, k, v = _qkv(cfg, p, x, cdt)
    if positions is None:
        positions = jnp.arange(S)[None, :]
    if cfg.rope_theta:
        q = L.rope(q, positions, cfg.rope_theta)
        k = L.rope(k, positions, cfg.rope_theta)
    k = constrain(k, ("batch", None, "model", None))
    v = constrain(v, ("batch", None, "model", None))
    if rc.attn_impl == "flash" and not prefix_len \
            and isinstance(window, (int, np.integer)):
        # Pallas TPU kernel (kernels/flash_attention.py); prefix
        # (bidirectional) attention and per-layer traced windows fall back
        # to the chunked path below.
        from repro.kernels import ops as kops
        out = kops.flash_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=True, window=int(window))
        out = out.transpose(0, 2, 1, 3).astype(cdt)
    elif rc.attn_impl == "chunked" or (rc.attn_impl == "auto" and S > 2048):
        chunk = next((c for c in (rc.attn_chunk, 512, 256, 128, 64)
                      if c <= S and S % c == 0), S)
        out = _chunked_attention(q, k, v, window, prefix_len, chunk, cdt)
    else:
        mask = L.causal_mask(S, S, window, prefix_len)
        out = L.attention_scores(q, k, v, mask[None], dtype=cdt)
    return _attn_out(cfg, p, out, x, cdt), (k, v)


def cross_attn_block(cfg, p, x, enc_kv, rc):
    cdt = jnp.dtype(rc.compute_dtype)
    B, S, d = x.shape
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    h = L.rms_norm(x, p["ln"], cfg.norm_eps)
    q = (h @ p["wq"].astype(cdt)).reshape(B, S, H, Dh)
    k, v = enc_kv  # (B, F, Hkv, Dh) precomputed from encoder output
    mask = jnp.ones((1, S, k.shape[1]), bool)
    out = L.attention_scores(q, k, v, mask, dtype=cdt)
    return _attn_out(cfg, p, out, x, cdt)


def decode_attn_block(cfg, p, x, window, cache_k, cache_v, pos, rc):
    """One-token decode. cache_[kv]: (B, Smax, Hkv, Dh). Returns updated."""
    cdt = jnp.dtype(rc.compute_dtype)
    B = x.shape[0]
    q, k, v = _qkv(cfg, p, x, cdt)  # S == 1
    posv = jnp.full((B, 1), pos)
    if cfg.rope_theta:
        q = L.rope(q, posv, cfg.rope_theta)
        k = L.rope(k, posv, cfg.rope_theta)
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype), pos, 1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype), pos, 1)
    mask = L.decode_mask(cache_k.shape[1], pos, window)
    out = L.attention_scores(q, cache_k, cache_v, mask[None], dtype=cdt)
    return _attn_out(cfg, p, out, x, cdt), (cache_k, cache_v)


def mlp_block(cfg, p, x, rc):
    cdt = jnp.dtype(rc.compute_dtype)
    h = L.rms_norm(x, p["ln"], cfg.norm_eps)
    if cfg.is_moe:
        B, S, d = x.shape
        y, aux = moe_lib.moe_ffn(cfg, p, h.reshape(B * S, d), rc)
        return constrain(x + y.reshape(B, S, d), ("batch", None, None)), aux
    g = h @ p["wg"].astype(cdt)
    u = h @ p["wu"].astype(cdt)
    hidden = L.act_fn(cfg.act)(g) * u
    hidden = constrain(hidden, ("batch", None, "model"))
    y = hidden @ p["wd"].astype(cdt)
    return constrain(x + y, ("batch", None, None)), jnp.zeros((), jnp.float32)


# ----------------------------------------------------------------- stacks

def _maybe_remat(fn, rc):
    if rc.remat == "full":
        return jax.checkpoint(fn)
    return fn


def encoder_forward(cfg, params, frames, rc):
    """Whisper-style encoder over stub frame embeddings (B, F, d)."""
    x = frames.astype(jnp.dtype(rc.compute_dtype))

    def body(x, pl):
        x, _ = attn_block(cfg, pl["attn"], x, 0, x.shape[1], rc)
        x, _ = mlp_block(cfg, pl["mlp"], x, rc)
        return x, None

    x, _ = jax.lax.scan(_maybe_remat(body, rc), x, params["enc_layers"])
    return L.rms_norm(x, params["enc_final_ln"], cfg.norm_eps)


def _embed(cfg, params, tokens, rc):
    cdt = jnp.dtype(rc.compute_dtype)
    x = params["embed"].astype(cdt)[tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), cdt)
    return constrain(x, ("batch", None, None))


def _inputs_with_prefix(cfg, params, tokens, batch, rc):
    """Handle VLM patch prefix / whisper decoder positions."""
    x = _embed(cfg, params, tokens, rc)
    prefix_len = 0
    if cfg.n_patches:
        cdt = x.dtype
        patches = batch["patches"].astype(cdt) @ params["patch_proj"].astype(cdt)
        x = jnp.concatenate([patches, x], axis=1)
        prefix_len = cfg.n_patches
    if cfg.enc_layers:
        S = x.shape[1]
        x = x + params["dec_pos"].astype(x.dtype)[:S][None]
    return x, prefix_len


def forward(cfg: ModelConfig, params, batch, rc, return_cache=False):
    """Train/prefill forward. batch: tokens (B,S) [+ patches/frames].

    Returns (logits_source_x, prefix_len, cache, enc_kv, aux)."""
    tokens = batch["tokens"]
    x, prefix_len = _inputs_with_prefix(cfg, params, tokens, batch, rc)
    w_arr = windows(cfg)
    # uniform window -> keep it static (enables the flash kernel + avoids
    # a per-layer where() in the HLO)
    uniform = int(w_arr[0]) if (w_arr == w_arr[0]).all() else None
    win = jnp.asarray(w_arr)
    enc_kv = None
    if cfg.enc_layers:
        enc_out = encoder_forward(cfg, params, batch["frames"], rc)
        # Pre-compute per-layer cross K/V (B,F,Hkv,Dh) inside the scan below.
        enc_kv = enc_out

    def body(x, sl):
        if uniform is None:
            pl, w = sl
        else:
            pl, w = sl, uniform
        x, kv = attn_block(cfg, pl["attn"], x, w, prefix_len, rc)
        xkv = None
        if cfg.enc_layers:
            cdt = x.dtype
            B, F, d = enc_kv.shape
            Hkv, Dh = cfg.n_kv_heads, cfg.head_dim
            xk = (enc_kv @ pl["xattn"]["wk"].astype(cdt)).reshape(B, F, Hkv, Dh)
            xv = (enc_kv @ pl["xattn"]["wv"].astype(cdt)).reshape(B, F, Hkv, Dh)
            x = cross_attn_block(cfg, pl["xattn"], x, (xk, xv), rc)
            xkv = (xk, xv)
        x, aux = mlp_block(cfg, pl["mlp"], x, rc)
        out = (kv, xkv) if return_cache else None
        return x, (out, aux)

    xs = params["layers"] if uniform is not None else (params["layers"], win)
    x, (cache, aux) = jax.lax.scan(_maybe_remat(body, rc), x, xs)
    x = L.rms_norm(x, params["final_ln"], cfg.norm_eps)
    if return_cache:
        (k, v), xkv = cache
        cache = {"k": k, "v": v}
        if cfg.enc_layers:
            cache["xk"], cache["xv"] = xkv
    return x, prefix_len, cache, enc_kv, jnp.sum(aux)


def unembed(cfg, params, x, rc):
    cdt = jnp.dtype(rc.compute_dtype)
    head = (params["embed"].astype(cdt).T if cfg.tie_embeddings
            else params["lm_head"].astype(cdt))
    logits = x @ head
    return constrain(logits, ("batch", None, "model"))


def init_cache(cfg: ModelConfig, batch_size: int, seq_len: int, dtype,
               windowed: bool = False):
    """KV-cache ShapeDtypeStruct-compatible zero pytree spec (shapes only).

    windowed=True (gemma3-style local:global mixes): local-attention
    layers keep a `sliding_window`-slot ring buffer instead of the full
    context — 6x less cache for a 5:1 mix (EXPERIMENTS.md §Perf gemma3)."""
    n, Hkv, Dh = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    if windowed and cfg.sliding_window and cfg.global_every \
            and n % cfg.global_every == 0:
        ng = n // cfg.global_every
        nloc = cfg.global_every - 1
        W = min(cfg.sliding_window, seq_len)
        return {
            "k_loc": ((ng, nloc, batch_size, W, Hkv, Dh), dtype),
            "v_loc": ((ng, nloc, batch_size, W, Hkv, Dh), dtype),
            "k_glob": ((ng, batch_size, seq_len, Hkv, Dh), dtype),
            "v_glob": ((ng, batch_size, seq_len, Hkv, Dh), dtype),
        }
    c = {"k": ((n, batch_size, seq_len, Hkv, Dh), dtype),
         "v": ((n, batch_size, seq_len, Hkv, Dh), dtype)}
    if cfg.enc_layers:
        c["xk"] = ((n, batch_size, cfg.enc_frames, Hkv, Dh), dtype)
        c["xv"] = ((n, batch_size, cfg.enc_frames, Hkv, Dh), dtype)
    return c


def cache_logical():
    # seq dim falls back to the data axes ("batch2") when batch cannot
    # claim them (e.g. long_500k with global_batch=1)
    base = (None, "batch", "batch2", "model", "model2")
    return {"k": base, "v": base, "xk": base, "xv": base,
            "k_loc": (None, None, "batch", None, "model", "model2"),
            "v_loc": (None, None, "batch", None, "model", "model2"),
            "k_glob": base, "v_glob": base}


def decode_attn_block_ring(cfg, p, x, window, cache_k, cache_v, pos, rc):
    """Sliding-window decode against a RING buffer of `window` slots.
    Slot s holds absolute position pos - ((pos - s) mod window); the mask
    only rejects slots whose position is still negative (cold start)."""
    cdt = jnp.dtype(rc.compute_dtype)
    B = x.shape[0]
    W = cache_k.shape[1]
    q, k, v = _qkv(cfg, p, x, cdt)
    posv = jnp.full((B, 1), pos)
    if cfg.rope_theta:
        q = L.rope(q, posv, cfg.rope_theta)
        k = L.rope(k, posv, cfg.rope_theta)
    slot = jnp.mod(pos, W)
    cache_k = jax.lax.dynamic_update_slice_in_dim(
        cache_k, k.astype(cache_k.dtype), slot, 1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(
        cache_v, v.astype(cache_v.dtype), slot, 1)
    slots = jnp.arange(W)
    abs_pos = pos - jnp.mod(pos - slots, W)
    mask = (abs_pos >= 0)[None, :]
    out = L.attention_scores(q, cache_k, cache_v, mask[None], dtype=cdt)
    return _attn_out(cfg, p, out, x, cdt), (cache_k, cache_v)


def decode_windowed(cfg: ModelConfig, params, cache, token, pos, rc):
    """Decode for local:global mixes with ring-buffered local caches.
    Layers are scanned as (ng, global_every) groups: `global_every - 1`
    local layers then one global layer (gemma3's 5:1 pattern)."""
    x = _embed(cfg, params, token, rc)
    per = cfg.global_every
    ng = cfg.n_layers // per
    W = cfg.sliding_window
    grouped = jax.tree.map(
        lambda a: a.reshape(ng, per, *a.shape[1:]), params["layers"])

    def loc_body(x, sl):
        pl, ck, cv = sl
        x, (ck, cv) = decode_attn_block_ring(cfg, pl["attn"], x, W, ck, cv,
                                             pos, rc)
        x, _ = mlp_block(cfg, pl["mlp"], x, rc)
        return x, (ck, cv)

    def group_body(x, sl):
        pg, ckl, cvl, ckg, cvg = sl
        loc = jax.tree.map(lambda a: a[: per - 1], pg)
        glob = jax.tree.map(lambda a: a[per - 1], pg)
        x, (ckl, cvl) = jax.lax.scan(loc_body, x, (loc, ckl, cvl))
        x, (ckg, cvg) = decode_attn_block(cfg, glob["attn"], x, 0, ckg,
                                          cvg, pos, rc)
        x, _ = mlp_block(cfg, glob["mlp"], x, rc)
        return x, (ckl, cvl, ckg, cvg)

    x, (ckl, cvl, ckg, cvg) = jax.lax.scan(
        group_body, x, (grouped, cache["k_loc"], cache["v_loc"],
                        cache["k_glob"], cache["v_glob"]))
    new_cache = {"k_loc": ckl, "v_loc": cvl, "k_glob": ckg, "v_glob": cvg}
    x = L.rms_norm(x, params["final_ln"], cfg.norm_eps)
    logits = unembed(cfg, params, x, rc)
    return logits, new_cache


def decode(cfg: ModelConfig, params, cache, token, pos, rc):
    """One-token decode step. token (B,1) int32; pos scalar int32.

    cache: {"k": (L,B,Smax,Hkv,Dh), "v": ..., ["xk","xv"]} or the
    windowed layout {"k_loc", "v_loc", "k_glob", "v_glob"}."""
    if "k_loc" in cache:
        return decode_windowed(cfg, params, cache, token, pos, rc)
    x = _embed(cfg, params, token, rc)
    if cfg.enc_layers:
        x = x + params["dec_pos"].astype(x.dtype)[pos][None, None]
    win = jnp.asarray(windows(cfg))
    has_cross = cfg.enc_layers > 0

    def body(x, sl):
        if has_cross:
            pl, w, ck, cv, xk, xv = sl
        else:
            pl, w, ck, cv = sl
        x, (ck, cv) = decode_attn_block(cfg, pl["attn"], x, w, ck, cv, pos, rc)
        if has_cross:
            x = cross_attn_block(cfg, pl["xattn"], x, (xk, xv), rc)
        x, _ = mlp_block(cfg, pl["mlp"], x, rc)
        return x, (ck, cv)

    xs = (params["layers"], win, cache["k"], cache["v"])
    if has_cross:
        xs = xs + (cache["xk"], cache["xv"])
    x, (ck, cv) = jax.lax.scan(body, x, xs)
    new_cache = dict(cache, k=ck, v=cv)
    x = L.rms_norm(x, params["final_ln"], cfg.norm_eps)
    logits = unembed(cfg, params, x, rc)
    return logits, new_cache
