"""Batched serving: lockstep batched decode at smoke scale.

A wave of requests is padded to a common prompt length and decoded in
lockstep — one jit'd decode step per token for the whole batch (this is
the `serve_step` the dry-run lowers at production shapes). Weights come
from a Lustre checkpoint (the storage architecture serving a read-heavy
load, optionally through the collaborative cache).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import registry
from repro.models.config import ModelConfig, RunConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int = 16
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class BatchedServer:
    """Serve one wave of B requests in lockstep."""

    def __init__(self, cfg: ModelConfig, params, *, max_seq: int = 256,
                 eos: int = -1, pad: int = 0):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.eos = eos
        self.pad = pad
        self.rc = RunConfig(seq_len=max_seq, global_batch=0, kind="decode",
                            param_dtype="float32", attn_impl="ref")
        self._decode = jax.jit(
            lambda p, c, t, pos: registry.decode(cfg, p, c, t, pos, self.rc))

    def _fresh_cache(self, batch: int):
        spec = registry.init_cache(self.cfg, batch, self.max_seq,
                                   jnp.dtype(self.rc.compute_dtype))
        return jax.tree.map(
            lambda s: jnp.zeros(s[0], s[1]), spec,
            is_leaf=lambda x: isinstance(x, tuple) and isinstance(
                x[0], tuple))

    def generate(self, requests: list[Request]) -> list[Request]:
        B = len(requests)
        plen = max(len(r.prompt) for r in requests)
        toks = np.full((B, plen), self.pad, np.int32)
        for i, r in enumerate(requests):
            # left-pad so every prompt ends at the same position
            toks[i, plen - len(r.prompt):] = r.prompt
        cache = self._fresh_cache(B)
        # prefill via lockstep single-token decode (exact; batched prefill
        # is the perf path exercised by the prefill_32k dry-run cells)
        last = None
        for j in range(plen):
            t = jnp.asarray(toks[:, j:j + 1])
            logits, cache = self._decode(self.params, cache, t,
                                         jnp.asarray(j, jnp.int32))
            last = logits
        nxt = np.asarray(jnp.argmax(last, axis=-1)).reshape(-1)
        max_new = max(r.max_new for r in requests)
        for step in range(max_new):
            for i, r in enumerate(requests):
                if not r.done and len(r.out) < r.max_new:
                    r.out.append(int(nxt[i]))
                    if int(nxt[i]) == self.eos or \
                            len(r.out) >= r.max_new:
                        r.done = True
            if all(r.done for r in requests):
                break
            pos = plen + step
            if pos >= self.max_seq - 1:
                break
            t = jnp.asarray(nxt.reshape(B, 1).astype(np.int32))
            logits, cache = self._decode(self.params, cache, t,
                                         jnp.asarray(pos, jnp.int32))
            nxt = np.asarray(jnp.argmax(logits, axis=-1)).reshape(-1)
        for r in requests:
            r.done = True
        return requests
