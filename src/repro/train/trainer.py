"""Fault-tolerant trainer: JAX training loop over the Lustre substrate.

End-to-end integration of the paper's storage architecture with a real
training job:
  * data: deterministic sharded TokenPipeline reading a striped corpus;
  * checkpoints: CheckpointManager (striped, parity-coded, crash-consistent
    manifests) — save every `ckpt_every`, `Trainer.resume()` restores the
    latest complete checkpoint and continues at the exact step;
  * fault tolerance: OST/MDS failures during the run surface as timeouts
    inside the storage clients and recover transparently (failover ring /
    replay); a *trainer* death is recovered by constructing a fresh Trainer
    and calling resume();
  * elasticity: resume() re-shards the restored arrays onto whatever mesh
    the new trainer has (shapes come from the manifest, placement from the
    new step bundle);
  * straggler mitigation: batch reads fan out over stripes; a slow OST
    link delays only its stripe, and hedged reads (mirror path) cap the
    tail when RAID1 mirrors exist.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np

from repro.ckpt import CheckpointManager
from repro.core.cluster import LustreCluster
from repro.data import TokenDataset, TokenPipeline
from repro.fsio import LustreClient
from repro.launch.mesh import make_host_mesh
from repro.models import layers as L
from repro.models import registry
from repro.models.config import ModelConfig, RunConfig
from repro.parallel import shardings as sh
from repro.train import steps as steps_mod


@dataclasses.dataclass
class TrainerConfig:
    model: ModelConfig
    rc: RunConfig
    n_steps: int = 50
    ckpt_every: int = 10
    ckpt_base: str = "/ckpt"
    data_path: str = "/data/tokens.bin"
    n_writers: int = 2
    parity: bool = True
    dataset_seqs: int = 2048
    seed: int = 0


class Trainer:
    def __init__(self, cluster: LustreCluster, cfg: TrainerConfig,
                 mesh=None):
        self.cluster = cluster
        self.cfg = cfg
        self.mesh = mesh if mesh is not None else make_host_mesh()
        sh.set_ambient_mesh(self.mesh)
        self.bundle = steps_mod.build_train_step(cfg.model, cfg.rc, self.mesh)
        # storage clients: writer 0 is also the data-plane reader
        n_clients = len(cluster.client_nodes)
        self.writers = [LustreClient(cluster, i % n_clients).mount()
                        for i in range(cfg.n_writers)]
        self.fs = self.writers[0]
        self.ckpt = CheckpointManager(
            self.writers, cfg.ckpt_base, parity=cfg.parity,
            stripe_count=min(3, len(cluster.ost_targets)),
            stripe_size=1 << 18)
        self.dataset = TokenDataset(
            self.fs, cfg.data_path, vocab=cfg.model.vocab,
            seq_len=cfg.rc.seq_len, n_seqs=cfg.dataset_seqs,
            seed=cfg.seed).build()
        gb = cfg.rc.global_batch
        self.pipeline = TokenPipeline(self.fs, self.dataset, dp_rank=0,
                                      dp_size=1, batch_per_rank=gb,
                                      seed=cfg.seed)
        self.step = 0
        self.params = None
        self.opt_state = None
        self.metrics: list[dict] = []

    # ---------------------------------------------------------------- init
    def init_state(self):
        params, opt = self.bundle.init(jax.random.PRNGKey(self.cfg.seed))
        self.params, self.opt_state = params, opt
        return self

    # ---------------------------------------------------------------- data
    def _batch(self, step: int) -> dict:
        toks = self.pipeline.batch_at(step)
        b = {"tokens": jax.numpy.asarray(toks)}
        # next-token labels within the stored sequence
        lab = np.roll(toks, -1, axis=-1)
        lab[:, -1] = 0
        b["labels"] = jax.numpy.asarray(lab)
        rc = self.cfg.rc
        if rc.num_microbatches > 1:
            nmb = rc.num_microbatches
            b = {k: v.reshape(nmb, v.shape[0] // nmb, *v.shape[1:])
                 for k, v in b.items()}
        cfgm = self.cfg.model
        key = jax.random.PRNGKey(step)
        lead = b["tokens"].shape[:-1]
        if cfgm.enc_layers:
            b["frames"] = jax.random.normal(
                key, (*lead, cfgm.enc_frames, cfgm.d_model),
                jax.numpy.bfloat16)
        if cfgm.n_patches:
            b["patches"] = jax.random.normal(
                key, (*lead, cfgm.n_patches, cfgm.d_model),
                jax.numpy.bfloat16)
        return b

    # ---------------------------------------------------------------- loop
    def run(self, n_steps: int | None = None, *, fail_at: dict | None = None
            ) -> list[dict]:
        """Train. `fail_at` maps step -> callable(cluster) fault injection
        (e.g. lambda c: c.fail_node('ost1'))."""
        n = n_steps if n_steps is not None else self.cfg.n_steps
        if self.params is None:
            self.init_state()
        end = self.step + n
        while self.step < end:
            if fail_at and self.step in fail_at:
                fail_at[self.step](self.cluster)
            batch = self._batch(self.step)
            self.params, self.opt_state, m = self.bundle.fn(
                self.params, self.opt_state, batch)
            self.step += 1
            rec = {"step": self.step, "loss": float(m["loss"]),
                   "grad_norm": float(m["grad_norm"])}
            self.metrics.append(rec)
            if self.step % self.cfg.ckpt_every == 0 or self.step == end:
                self.save_checkpoint()
        return self.metrics

    # ---------------------------------------------------------- checkpoint
    def _state_tree(self) -> dict:
        return {"params": jax.tree.map(np.asarray, self.params),
                "opt": {"step": np.asarray(self.opt_state["step"]),
                        "m": jax.tree.map(np.asarray, self.opt_state["m"]),
                        "v": jax.tree.map(np.asarray, self.opt_state["v"])}}

    def save_checkpoint(self):
        self.ckpt.save(self.step, self._state_tree(),
                       extra_meta={"arch": self.cfg.model.name})

    @classmethod
    def resume(cls, cluster: LustreCluster, cfg: TrainerConfig,
               mesh=None) -> "Trainer":
        """Fresh trainer (possibly a different mesh — elastic) restored
        from the latest complete checkpoint."""
        t = cls(cluster, cfg, mesh)
        t.ckpt.cleanup_incomplete()
        flat, manifest = t.ckpt.restore()
        t.step = manifest["step"]
        defs = registry.param_defs(cfg.model)
        pdt = cfg.rc.param_dtype

        param_structs, opt_structs, _ = t.bundle.arg_structs
        pspecs, ospecs, _ = t.bundle.in_shardings

        def build(prefix, structs, specs):
            # jax.tree.leaves_with_path only exists in newer jax;
            # tree_util has carried it for much longer
            leaves_s = jax.tree_util.tree_leaves_with_path(structs)
            leaves_p = jax.tree_util.tree_leaves_with_path(specs)
            out_leaves = []
            for (path, s), (_, spec) in zip(leaves_s, leaves_p):
                name = prefix + ".".join(
                    _path_key(p) for p in path)
                arr = flat[name].astype(s.dtype)
                out_leaves.append(jax.device_put(arr, spec))
            return jax.tree.unflatten(
                jax.tree.structure(structs), out_leaves)

        t.params = build("params.", param_structs, pspecs)
        t.opt_state = build("opt.", opt_structs, ospecs)
        return t


def _path_key(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)
