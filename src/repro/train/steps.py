"""Step builders: train_step / prefill_step / serve_step (decode).

Each builder returns a StepBundle with the jit'd function, the
ShapeDtypeStruct inputs (for lowering without allocation) and the
in/out NamedShardings — the multi-pod dry-run and the real trainer both
consume the same bundle.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models import registry
from repro.models.config import ModelConfig, RunConfig
from repro.optim import adamw
from repro.parallel import shardings as sh


@dataclasses.dataclass
class StepBundle:
    fn: Any                      # jit'd callable
    arg_structs: tuple           # ShapeDtypeStructs (positional)
    in_shardings: tuple
    out_shardings: Any
    init: Callable | None = None  # real-array initializer (smoke tests)

    def lower(self):
        return self.fn.lower(*self.arg_structs)


# ----------------------------------------------------------------- batches

def batch_structs(cfg: ModelConfig, rc: RunConfig, with_labels: bool):
    """ShapeDtypeStructs for one global batch."""
    B, S = rc.global_batch, rc.seq_len
    nmb = rc.num_microbatches
    lead = (nmb, B // nmb) if nmb > 1 else (B,)
    out = {"tokens": jax.ShapeDtypeStruct((*lead, S), jnp.int32)}
    if with_labels:
        out["labels"] = jax.ShapeDtypeStruct((*lead, S), jnp.int32)
    if cfg.enc_layers:
        out["frames"] = jax.ShapeDtypeStruct(
            (*lead, cfg.enc_frames, cfg.d_model), jnp.bfloat16)
    if cfg.n_patches:
        out["patches"] = jax.ShapeDtypeStruct(
            (*lead, cfg.n_patches, cfg.d_model), jnp.bfloat16)
    return out


def batch_logical(cfg: ModelConfig, rc: RunConfig, with_labels: bool):
    nmb = rc.num_microbatches
    lead = (None, "batch") if nmb > 1 else ("batch",)
    out = {"tokens": (*lead, None)}
    if with_labels:
        out["labels"] = (*lead, None)
    if cfg.enc_layers:
        out["frames"] = (*lead, None, None)
    if cfg.n_patches:
        out["patches"] = (*lead, None, None)
    return out


def batch_shardings(cfg, rc, mesh, with_labels):
    logical = batch_logical(cfg, rc, with_labels)
    structs = batch_structs(cfg, rc, with_labels)
    return jax.tree.map(
        lambda lg, s: sh.named(mesh, lg, s.shape), logical, structs,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


def make_batch(cfg: ModelConfig, rc: RunConfig, key, with_labels=True):
    """Real (host) batch for smoke tests/examples; tiny configs only."""
    structs = batch_structs(cfg, rc, with_labels)
    ks = jax.random.split(key, len(structs))
    out = {}
    for k, (name, s) in zip(ks, structs.items()):
        if s.dtype == jnp.int32:
            out[name] = jax.random.randint(k, s.shape, 0, cfg.vocab, jnp.int32)
        else:
            out[name] = jax.random.normal(k, s.shape, jnp.float32).astype(s.dtype)
    return out


# ----------------------------------------------------------------- loss

def _ce(logits, labels):
    """Token-mean cross entropy in fp32. logits (B,S,V), labels (B,S)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - ll)


def _ce_chunked(cfg, params, x, labels, rc):
    """Vocab peak-memory-bounded CE: scan over sequence chunks, remat the
    chunk logits in backward. x (B,S,d)."""
    B, S, d = x.shape
    c = rc.chunked_ce
    nc = S // c
    xs = x.reshape(B, nc, c, d).swapaxes(0, 1)
    ls = labels.reshape(B, nc, c).swapaxes(0, 1)

    @jax.checkpoint
    def body(acc, t):
        xc, lc = t
        logits = registry.unembed(cfg, params, xc, rc)
        logits = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(lse - ll), None

    tot, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xs, ls))
    return tot / (B * S)


def loss_fn(cfg: ModelConfig, params, batch, rc: RunConfig):
    x, prefix_len, _, _, aux = registry.forward(cfg, params, batch, rc)
    if prefix_len:
        x = x[:, prefix_len:]
    if rc.chunked_ce:
        loss = _ce_chunked(cfg, params, x, batch["labels"], rc)
    else:
        logits = registry.unembed(cfg, params, x, rc)
        loss = _ce(logits, batch["labels"])
    if cfg.is_moe:
        loss = loss + 0.01 * aux
    return loss


# ----------------------------------------------------------------- train

def _param_specs(cfg, rc, defs, mesh, pdt):
    """Parameter shardings, honouring the RunConfig's fsdp policy."""
    import math as _math
    msize = mesh.shape.get("model", 1)
    per_shard = sum(
        _math.prod(d.shape) for d in jax.tree.leaves(
            defs, is_leaf=L.is_def)) * pdt.itemsize // max(1, msize)
    return L.tree_specs(defs, mesh, fsdp=rc.fsdp_enabled(per_shard))


def build_train_step(cfg: ModelConfig, rc: RunConfig, mesh,
                     opt: adamw.AdamWConfig | None = None) -> StepBundle:
    opt = opt or adamw.AdamWConfig()
    pdt = jnp.dtype(rc.param_dtype)
    defs = registry.param_defs(cfg)
    param_structs = L.tree_structs(defs, pdt)
    param_specs = _param_specs(cfg, rc, defs, mesh, pdt)
    opt_structs = adamw.init_state_structs(param_structs)
    opt_specs = {"step": jax.sharding.NamedSharding(
                     mesh, jax.sharding.PartitionSpec()),
                 "m": param_specs, "v": param_specs}
    bstructs = batch_structs(cfg, rc, with_labels=True)
    bspecs = batch_shardings(cfg, rc, mesh, with_labels=True)
    scalar = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())

    nmb = rc.num_microbatches

    def step(params, opt_state, batch):
        gr_dt = jnp.dtype(rc.grad_reduce_dtype)
        cdt = jnp.dtype(rc.compute_dtype)

        def cast_once(params):
            """Mixed precision: ONE f32->bf16 cast per step (outside the
            layer scan) so (a) the scan reads bf16 weights (half the HBM
            traffic), (b) per-layer grad reduce-scatters run in bf16."""
            if gr_dt == jnp.float32:
                return params
            return jax.tree.map(
                lambda p: p.astype(cdt) if p.dtype == jnp.float32 else p,
                params)

        if nmb == 1:
            loss, grads = jax.value_and_grad(
                partial(loss_fn, cfg, rc=rc))(cast_once(params), batch)
        else:
            cparams = cast_once(params)

            def mb(carry, mbatch):
                l, g = jax.value_and_grad(
                    partial(loss_fn, cfg, rc=rc))(cparams, mbatch)
                acc_l, acc_g = carry
                return (acc_l + l,
                        jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                     acc_g, g)), None

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                mb, (jnp.zeros((), jnp.float32), zero_g), batch)
            loss = loss / nmb
            grads = jax.tree.map(lambda g: g / nmb, grads)
        new_params, new_opt, gnorm = adamw.apply_updates(
            opt, params, grads, opt_state)
        metrics = {"loss": loss, "grad_norm": gnorm}
        return new_params, new_opt, metrics

    fn = jax.jit(
        step,
        in_shardings=(param_specs, opt_specs, bspecs),
        out_shardings=(param_specs, opt_specs,
                       {"loss": scalar, "grad_norm": scalar}),
        donate_argnums=(0, 1),
    )

    def init(key):
        params = L.tree_init(defs, key, pdt)
        return params, adamw.init_state(params)

    return StepBundle(fn, (param_structs, opt_structs, bstructs),
                      (param_specs, opt_specs, bspecs), None, init)


# ----------------------------------------------------------------- prefill

def build_prefill_step(cfg: ModelConfig, rc: RunConfig, mesh) -> StepBundle:
    pdt = jnp.dtype(rc.param_dtype)
    defs = registry.param_defs(cfg)
    param_structs = L.tree_structs(defs, pdt)
    param_specs = _param_specs(cfg, rc, defs, mesh, pdt)
    bstructs = batch_structs(cfg, rc, with_labels=False)
    bspecs = batch_shardings(cfg, rc, mesh, with_labels=False)

    def step(params, batch):
        x, prefix_len, cache, _, _ = registry.forward(
            cfg, params, batch, rc, return_cache=True)
        logits = registry.unembed(cfg, params, x[:, -1:], rc)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, cache

    cache_specs = _cache_shardings(cfg, mesh, _prefill_cache_structs(cfg, rc))
    tok_spec = sh.named(mesh, ("batch", None), (rc.global_batch, 1))
    fn = jax.jit(step, in_shardings=(param_specs, bspecs),
                 out_shardings=(tok_spec, cache_specs))
    return StepBundle(fn, (param_structs, bstructs),
                      (param_specs, bspecs), None)


def _prefill_cache_structs(cfg, rc):
    """Cache emitted by forward(return_cache=True) as ShapeDtypeStructs."""
    B, S = rc.global_batch, rc.seq_len
    cdt = jnp.dtype(rc.compute_dtype)
    if cfg.family == "transformer":
        S_tot = S + cfg.n_patches
        n, Hkv, Dh = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
        c = {"k": jax.ShapeDtypeStruct((n, B, S_tot, Hkv, Dh), cdt),
             "v": jax.ShapeDtypeStruct((n, B, S_tot, Hkv, Dh), cdt)}
        if cfg.enc_layers:
            c["xk"] = jax.ShapeDtypeStruct(
                (n, B, cfg.enc_frames, Hkv, Dh), cdt)
            c["xv"] = jax.ShapeDtypeStruct(
                (n, B, cfg.enc_frames, Hkv, Dh), cdt)
        return c
    spec = registry.init_cache(cfg, B, S, cdt)
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s[0], s[1]), spec,
                        is_leaf=lambda x: isinstance(x, tuple)
                        and isinstance(x[0], tuple))


# ----------------------------------------------------------------- decode

def decode_cache_structs(cfg: ModelConfig, rc: RunConfig):
    B, S = rc.global_batch, rc.seq_len
    cdt = jnp.dtype(rc.compute_dtype)
    if cfg.family == "transformer":
        S = S + cfg.n_patches
    spec = registry.init_cache(cfg, B, S, cdt,
                               windowed=rc.windowed_cache)
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s[0], s[1]), spec,
                        is_leaf=lambda x: isinstance(x, tuple)
                        and isinstance(x[0], tuple))


def _cache_shardings(cfg, mesh, structs):
    logical = registry.cache_logical(cfg)
    logical = {k: v for k, v in logical.items() if k in structs}
    return jax.tree.map(
        lambda lg, s: sh.named(mesh, lg, s.shape), logical, structs,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


def build_serve_step(cfg: ModelConfig, rc: RunConfig, mesh) -> StepBundle:
    """One-token decode against a seq_len KV cache."""
    pdt = jnp.dtype(rc.param_dtype)
    defs = registry.param_defs(cfg)
    param_structs = L.tree_structs(defs, pdt)
    param_specs = _param_specs(cfg, rc, defs, mesh, pdt)
    cache_structs = decode_cache_structs(cfg, rc)
    cache_specs = _cache_shardings(cfg, mesh, cache_structs)
    B = rc.global_batch
    tok_struct = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    tok_spec = sh.named(mesh, ("batch", None), (B, 1))
    pos_struct = jax.ShapeDtypeStruct((), jnp.int32)
    scalar = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())

    def step(params, cache, token, pos):
        logits, new_cache = registry.decode(cfg, params, cache, token, pos, rc)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, new_cache

    fn = jax.jit(step,
                 in_shardings=(param_specs, cache_specs, tok_spec, scalar),
                 out_shardings=(tok_spec, cache_specs),
                 donate_argnums=(1,))
    return StepBundle(
        fn, (param_structs, cache_structs, tok_struct, pos_struct),
        (param_specs, cache_specs, tok_spec, scalar), None)


def build_step(cfg: ModelConfig, rc: RunConfig, mesh) -> StepBundle:
    if rc.kind == "train":
        return build_train_step(cfg, rc, mesh)
    if rc.kind == "prefill":
        return build_prefill_step(cfg, rc, mesh)
    if rc.kind == "decode":
        return build_serve_step(cfg, rc, mesh)
    raise ValueError(rc.kind)
