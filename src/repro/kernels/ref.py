"""Pure-jnp oracles for the Pallas kernels (allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        scale: float | None = None):
    """q (B,H,Sq,D), k/v (B,Hkv,Sk,D); GQA via head grouping.

    Plain softmax attention in f32 — the oracle for the Pallas kernel."""
    B, H, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    qg = q.reshape(B, Hkv, G, Sq, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kf) * scale
    if causal:
        qp = jnp.arange(Sq)[:, None] + (Sk - Sq)
        kp = jnp.arange(Sk)[None, :]
        m = kp <= qp
        if window > 0:
            m &= (qp - kp) < window
        logits = jnp.where(m[None, None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", probs, vf)
    return out.reshape(B, H, Sq, D).astype(q.dtype)


def xor_parity_ref(blocks: jax.Array) -> jax.Array:
    """blocks (K, N) int32 lanes -> (N,) XOR parity (RAID-5 column)."""
    out = blocks[0]
    for i in range(1, blocks.shape[0]):
        out = jnp.bitwise_xor(out, blocks[i])
    return out


def reconstruct_ref(survivors: jax.Array, parity: jax.Array) -> jax.Array:
    """Recover one missing block: XOR of survivors and parity."""
    return jnp.bitwise_xor(xor_parity_ref(survivors), parity)
