"""Flash attention Pallas TPU kernel (online-softmax, causal, GQA, window).

TPU adaptation notes (DESIGN.md §hardware-adaptation): the GPU algorithm
tiles for shared memory + warps; here BlockSpecs tile HBM->VMEM and the MXU
eats (BQ, D) x (D, BK) tiles. Block sizes default to MXU/VREG-aligned
(128, 128); the kv loop is the innermost grid dim so q/o tiles stay resident
in VMEM across it ("revisiting" order). Causal blocks fully above the
diagonal are skipped via `when` predication.

Validated in interpret=True mode against ref.flash_attention_ref (CPU has
no real Pallas lowering; TPU is the target).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int,
                  block_q: int, block_k: int, seq_k: int):
    """Grid = (BH, nq, nk); one (block_q, d) q-tile vs one (block_k, d)
    kv-tile per step; running max/sum in VMEM scratch."""
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q + (seq_k - pl.num_programs(1) * block_q)
    k_start = ki * block_k

    run = True
    if causal:
        # skip fully-masked blocks above the diagonal
        run = k_start <= q_start + block_q - 1
    if window > 0:
        run = jnp.logical_and(
            run, k_start + block_k > q_start - window + 1)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)             # (bq, d)
        k = k_ref[0].astype(jnp.float32)             # (bk, d)
        v = v_ref[0].astype(jnp.float32)             # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                          # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                       # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        denom = jnp.where(l_ref[...] == 0.0, 1.0, l_ref[...])
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "scale", "block_q",
                              "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale: float | None = None, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """q (B,H,Sq,D), k/v (B,Hkv,Sk,D) -> (B,H,Sq,D). GQA folded by
    repeating kv heads into the BH grid dim (zero-copy via indexing)."""
    B, H, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = float(scale if scale is not None else 1.0 / np.sqrt(D))
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0

    qf = q.reshape(B * H, Sq, D)
    kf = k.reshape(B * Hkv, Sk, D)
    vf = v.reshape(B * Hkv, Sk, D)

    # flat q index b = batch * H + h  ->  flat kv index batch * Hkv + h // G
    def kv_map(b, i, j):
        return ((b // H) * Hkv + (b % H) // G, j, 0)

    grid = (B * H, Sq // block_q, Sk // block_k)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          window=window, block_q=block_q, block_k=block_k,
                          seq_k=Sk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, D), kv_map),
            pl.BlockSpec((1, block_k, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, D), q.dtype),
        scratch_shapes=[
            # running max, denominator, accumulator — VMEM-resident
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sq, D)
