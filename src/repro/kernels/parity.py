"""XOR-parity (RAID-5 style erasure) Pallas TPU kernel (paper ch. 15:
Redundant Object Storage Targets — "a mirroring OBD driver ... other
mechanisms for use in an archive").

Checkpoint stripes are erasure-coded before hitting the OSTs: P = XOR of
the K data stripes; any single lost stripe (dead OST) is reconstructed as
XOR of the survivors + P. The compute is pure VPU lane work: int32 lanes,
(K, N) -> (N,), tiled over N so each tile's working set (K x block + block)
sits in VMEM.

TPU adaptation: a GPU implementation would coalesce over warps; here the
natural layout is (8, 128)-aligned int32 tiles and a grid over columns.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _xor_kernel(x_ref, o_ref):
    blk = x_ref[...]                       # (K, block) int32
    K = blk.shape[0]
    acc = blk[0]
    for i in range(1, K):                  # K is small + static: unrolled
        acc = jnp.bitwise_xor(acc, blk[i])
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def xor_parity(blocks: jax.Array, *, block: int = 4096,
               interpret: bool = False) -> jax.Array:
    """blocks (K, N) int32 -> parity (N,) int32.

    N need not be a multiple of `block`: the grid must tile N evenly, so
    a ragged tail is zero-padded up to the next block boundary before the
    call (0 is the XOR identity — padding never changes the parity) and
    the pad lanes are sliced back off the result. Shapes are static, so
    the pad amount is resolved at trace time (one compiled kernel per
    distinct padded shape, exactly like the unpadded path)."""
    K, N = blocks.shape
    block = min(block, N)
    padded = -(-N // block) * block
    if padded != N:
        blocks = jnp.pad(blocks, ((0, 0), (0, padded - N)))
    out = pl.pallas_call(
        _xor_kernel,
        grid=(padded // block,),
        in_specs=[pl.BlockSpec((K, block), lambda j: (0, j))],
        out_specs=pl.BlockSpec((block,), lambda j: (j,)),
        out_shape=jax.ShapeDtypeStruct((padded,), jnp.int32),
        interpret=interpret,
    )(blocks)
    return out[:N] if padded != N else out


def reconstruct(survivors: jax.Array, parity: jax.Array, *,
                block: int = 4096, interpret: bool = False) -> jax.Array:
    """Recover the one missing stripe: XOR(survivors, parity)."""
    stacked = jnp.concatenate([survivors, parity[None]], axis=0)
    return xor_parity(stacked, block=block, interpret=interpret)
