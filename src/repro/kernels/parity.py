"""XOR-parity (RAID-5 style erasure) Pallas TPU kernel (paper ch. 15:
Redundant Object Storage Targets — "a mirroring OBD driver ... other
mechanisms for use in an archive").

Checkpoint stripes are erasure-coded before hitting the OSTs: P = XOR of
the K data stripes; any single lost stripe (dead OST) is reconstructed as
XOR of the survivors + P. The compute is pure VPU lane work: int32 lanes,
(K, N) -> (N,), tiled over N so each tile's working set (K x block + block)
sits in VMEM.

TPU adaptation: a GPU implementation would coalesce over warps; here the
natural layout is (8, 128)-aligned int32 tiles and a grid over columns.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _xor_kernel(x_ref, o_ref):
    blk = x_ref[...]                       # (K, block) int32
    K = blk.shape[0]
    acc = blk[0]
    for i in range(1, K):                  # K is small + static: unrolled
        acc = jnp.bitwise_xor(acc, blk[i])
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def xor_parity(blocks: jax.Array, *, block: int = 4096,
               interpret: bool = False) -> jax.Array:
    """blocks (K, N) int32 -> parity (N,) int32."""
    K, N = blocks.shape
    block = min(block, N)
    assert N % block == 0, (N, block)
    return pl.pallas_call(
        _xor_kernel,
        grid=(N // block,),
        in_specs=[pl.BlockSpec((K, block), lambda j: (0, j))],
        out_specs=pl.BlockSpec((block,), lambda j: (j,)),
        out_shape=jax.ShapeDtypeStruct((N,), jnp.int32),
        interpret=interpret,
    )(blocks)


def reconstruct(survivors: jax.Array, parity: jax.Array, *,
                block: int = 4096, interpret: bool = False) -> jax.Array:
    """Recover the one missing stripe: XOR(survivors, parity)."""
    stacked = jnp.concatenate([survivors, parity[None]], axis=0)
    return xor_parity(stacked, block=block, interpret=interpret)
