"""Public jit'd wrappers for the Pallas kernels.

On the CPU container the kernels run in interpret mode (the kernel body is
executed op-by-op for correctness); on TPU they compile for real. Callers
use these wrappers and never touch `interpret` directly.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.kernels import flash_attention as _fa
from repro.kernels import parity as _par


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal=True, window=0, scale=None,
                    block_q=128, block_k=128):
    block_q = min(block_q, q.shape[2])
    block_k = min(block_k, k.shape[2])
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               scale=scale, block_q=block_q,
                               block_k=block_k, interpret=_interpret())


def xor_parity(blocks, *, block=4096):
    block = min(block, blocks.shape[1])
    return _par.xor_parity(blocks, block=block, interpret=_interpret())


def reconstruct(survivors, parity, *, block=4096):
    block = min(block, parity.shape[0])
    return _par.reconstruct(survivors, parity, block=block,
                            interpret=_interpret())


# ------------------------------------------------------- byte helpers
def parity_bytes(chunks: list[bytes]) -> bytes:
    """XOR parity over equal-length byte chunks (pads the tail)."""
    n = max(len(c) for c in chunks)
    n4 = -(-n // 4) * 4
    arr = np.zeros((len(chunks), n4 // 4), np.int32)
    for i, c in enumerate(chunks):
        buf = np.zeros(n4, np.uint8)
        buf[:len(c)] = np.frombuffer(c, np.uint8)
        arr[i] = buf.view(np.int32)
    out = np.asarray(xor_parity(jax.numpy.asarray(arr)))
    return out.view(np.uint8).tobytes()[:n]


def reconstruct_bytes(survivors: list[bytes], parity: bytes,
                      length: int) -> bytes:
    return parity_bytes(survivors + [parity])[:length]
